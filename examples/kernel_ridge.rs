//! Kernel ridge regression with (CA-)block coordinate descent — the
//! paper's §6 future-work extension, built on the same s-step inner solve
//! as CA-BCD (see `rust/src/kernel`).
//!
//! Fits an RBF-kernel regressor to a nonlinear function of the abalone
//! clone's features, demonstrating: (a) the CA unrolling applies verbatim
//! to the kernelized problem, (b) s× fewer "synchronization points" (here:
//! sampled-kernel-block rounds), (c) identical trajectories for every s.
//!
//! ```sh
//! cargo run --release --example kernel_ridge
//! ```

use cabcd::gram::NativeBackend;
use cabcd::kernel::{fit, Kernel, KrrOpts};
use cabcd::matrix::gen::{generate, scaled_specs};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // Small abalone clone; targets are a nonlinear function of features,
    // so the linear model underfits and RBF wins — the reason KRR exists.
    let spec = &scaled_specs(8)[0];
    let ds = generate(spec, 11)?;
    let n = ds.n();
    let rows = match ds.x.transpose() {
        cabcd::matrix::Matrix::Dense(m) => m,
        cabcd::matrix::Matrix::Csr(m) => m.to_dense(),
    };
    let y: Vec<f64> = (0..n)
        .map(|j| {
            let r = rows.row(j);
            (r[0] * 0.01).sin() + (r[1] * 0.01).cos()
        })
        .collect();

    println!(
        "KRR on {} clone: d={}, n={}, target = sin/cos of features",
        ds.name,
        ds.d(),
        n
    );
    println!("\n{:>8} {:>4} {:>14} {:>14} {:>10}", "kernel", "s", "residual", "train MSE", "rounds");
    let mut be = NativeBackend::new();
    for (name, kernel) in [
        ("linear", Kernel::Linear),
        ("rbf", Kernel::Rbf { gamma: 1e-4 }),
    ] {
        let mut base: Option<Vec<f64>> = None;
        for s in [1usize, 4, 8] {
            let opts = KrrOpts {
                kernel,
                lam: 1e-6,
                b: 8,
                s,
                iters: 1600,
                seed: 3,
                record_every: 0,
            };
            let model = fit(&ds.x, &y, &opts, &mut be)?;
            let preds = model.predict(&ds.x)?;
            let mse: f64 = preds
                .iter()
                .zip(&y)
                .map(|(p, t)| (p - t) * (p - t))
                .sum::<f64>()
                / n as f64;
            let resid = model.history.records.last().unwrap().obj_err;
            println!(
                "{:>8} {:>4} {:>14.3e} {:>14.3e} {:>10}",
                name,
                s,
                resid,
                mse,
                1600 / s
            );
            match &base {
                None => base = Some(model.alpha.clone()),
                Some(a0) => {
                    let dev = model
                        .alpha
                        .iter()
                        .zip(a0)
                        .map(|(a, b)| (a - b).abs())
                        .fold(0.0f64, f64::max);
                    let scale = a0.iter().map(|v| v.abs()).fold(1e-12, f64::max);
                    // The near-singular RBF system (λ = 1e-6) amplifies
                    // roundoff; equality holds to the conditioning floor.
                    assert!(
                        dev / scale < 1e-4,
                        "s={s} deviated by {dev} (rel {})",
                        dev / scale
                    );
                }
            }
        }
    }
    println!(
        "\nSame α for every s (to the conditioning floor); the RBF kernel fits the \
         nonlinear target the linear kernel cannot — and the CA \
         transformation carried over to the kernel problem unchanged, \
         as the paper's §6 anticipated."
    );
    Ok(())
}
