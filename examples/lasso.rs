//! End-to-end sparse recovery with CA-Prox-BCD: plant a sparse weight
//! vector, observe noisy linear measurements, and recover the support
//! with the communication-avoiding lasso solver — certified by the
//! Fenchel duality gap, with exactly H/s collectives of the same packed
//! `[G|r]` payload the ridge solvers ship.
//!
//! ```sh
//! cargo run --release --example lasso            # plain
//! cargo run --release --example lasso -- --trace lasso.trace.json
//! cargo run --release --example lasso -- --telemetry lasso.telemetry.json
//! cargo run --release --example lasso -- --transport process --ranks 4
//! ```
//!
//! Runs SPMD over 4 simulated ranks, then sweeps the elastic-net mixing
//! ratio to show the regularization-path seam. CI runs this example as an
//! acceptance check (gap ≤ 1e-6, exact support recovery) and validates
//! the `--trace` Chrome trace-event output with `python/check_trace.py`
//! and the `--telemetry` snapshot/exposition pair with
//! `python/check_telemetry.py`.
//!
//! `--transport process` switches to the multi-process path: the driver
//! re-execs this binary once per rank (loopback TCP, see
//! `cabcd::comm::process`), runs the same CA-Prox-BCD solve, and asserts
//! it lands bitwise-identical to an in-process thread-transport twin —
//! trajectory, duality certificates, and wire meters. `--topology
//! twolevel` routes the collectives through the hierarchical two-level
//! allreduce. The `--trace`/`--telemetry` artifacts then come from the
//! process run, so the same CI schema checkers validate exports gathered
//! across a real process boundary.

use cabcd::comm::thread::run_spmd;
use cabcd::coordinator::partition_primal;
use cabcd::gram::NativeBackend;
use cabcd::matrix::io::Dataset;
use cabcd::matrix::{DenseMatrix, Matrix};
use cabcd::prox::Reg;
use cabcd::solvers::{bcd, SolverOpts};
use cabcd::telemetry::{self, Registry, TelemetrySummary};
use cabcd::trace::{self, TraceSummary, Tracer};
use cabcd::util::Rng64;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // Worker-rank dispatch: under `--transport process` the driver
    // re-execs this binary once per rank with the rendezvous address in
    // the environment; those children run their rank here and exit
    // before any demo output.
    match cabcd::coordinator::maybe_run_process_child() {
        Ok(false) => {}
        Ok(true) => return Ok(()),
        Err(e) => return Err(Box::new(e)),
    }

    // Optional, in any order: `--trace PATH` writes a per-rank Chrome
    // trace-event JSON of the main SPMD solve (loadable in Perfetto);
    // `--telemetry PATH` writes the cluster health snapshots as JSON plus
    // a Prometheus exposition at PATH with a `.prom` extension. Both are
    // schema-checked in CI. `--transport process` (with optional
    // `--ranks P` and `--topology flat|twolevel`) runs the
    // multi-process acceptance path instead of the in-process demo.
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut trace_path: Option<std::path::PathBuf> = None;
    let mut telemetry_path: Option<std::path::PathBuf> = None;
    let mut transport = String::from("thread");
    let mut topology = String::from("flat");
    let mut ranks = 4usize;
    let mut it = argv.iter();
    while let Some(flag) = it.next() {
        let Some(val) = it.next() else {
            return Err(format!("{flag} needs an argument").into());
        };
        match flag.as_str() {
            "--trace" => trace_path = Some(std::path::PathBuf::from(val)),
            "--telemetry" => telemetry_path = Some(std::path::PathBuf::from(val)),
            "--transport" => transport = val.clone(),
            "--topology" => topology = val.clone(),
            "--ranks" => {
                ranks = val
                    .parse()
                    .map_err(|e| format!("--ranks {val:?}: {e}"))?
            }
            other => {
                return Err(format!(
                    "usage: lasso [--trace PATH] [--telemetry PATH] \
                     [--transport thread|process] [--ranks P] \
                     [--topology flat|twolevel], got {other:?}"
                )
                .into())
            }
        }
    }
    match transport.as_str() {
        "thread" => {}
        "process" => return run_process_transport(ranks, &topology, trace_path, telemetry_path),
        other => return Err(format!("--transport {other:?}: want thread or process").into()),
    }

    // 1. Planted sparse-recovery instance: d = 64 features, only 6
    //    active, n = 512 noisy measurements.
    let (d, n, k_active) = (64usize, 512usize, 6usize);
    let mut rng = Rng64::seed_from_u64(42);
    let data: Vec<f64> = (0..d * n).map(|_| rng.gen_normal()).collect();
    let x = Matrix::Dense(DenseMatrix::from_vec(d, n, data));
    let mut w_star = vec![0.0; d];
    for k in 0..k_active {
        w_star[k * (d / k_active)] = if k % 2 == 0 { 1.5 } else { -2.0 };
    }
    let mut y = vec![0.0; n];
    x.matvec_t(&w_star, &mut y)?;
    for v in y.iter_mut() {
        *v += 0.05 * rng.gen_normal();
    }
    let lam = 0.1;
    println!(
        "lasso sparse recovery: d={d}, n={n}, ‖w*‖₀={k_active}, λ={lam}"
    );

    // 2. CA-Prox-BCD over 4 simulated ranks (1D-block-column shards).
    let ds = Dataset {
        name: "planted-sparse".into(),
        x,
        y,
    };
    let p = 4usize;
    let shards = partition_primal(&ds, p)?;
    let opts = SolverOpts::builder()
        .b(4)
        .s(4)
        .lam(lam)
        .iters(60_000)
        .seed(7)
        .record_every(2000)
        .tol(1e-8)
        .reg(Reg::L1)
        .build();
    let tracing = trace_path.is_some();
    let telemetering = telemetry_path.is_some();
    let outs = run_spmd(p, |rank, comm| {
        if tracing {
            trace::install(Tracer::new(rank, trace::DEFAULT_SPAN_CAPACITY));
        }
        if telemetering {
            telemetry::install(Registry::new(rank, p));
        }
        let mut be = NativeBackend::new();
        let sh = &shards[rank];
        let out =
            bcd::run(&sh.a_loc, &sh.y_loc, sh.n_global, &opts, None, comm, &mut be).unwrap();
        (out, trace::take(), telemetry::take())
    });
    let mut tracers: Vec<Tracer> = Vec::new();
    let mut registries: Vec<Registry> = Vec::new();
    let outs: Vec<_> = outs
        .into_iter()
        .map(|(out, tracer, reg)| {
            tracers.extend(tracer);
            registries.extend(reg);
            out
        })
        .collect();
    if let Some(path) = &trace_path {
        std::fs::write(path, trace::chrome_trace_json(&tracers))?;
        let sum = TraceSummary::from_tracers(&tracers);
        for (tracer, out) in tracers.iter().zip(&outs) {
            trace::cross_check(tracer, &out.history.meter)?;
        }
        println!(
            "trace: {} spans over {} ranks → {} (overlap efficiency {:.3})",
            sum.spans,
            sum.ranks,
            path.display(),
            sum.overlap_efficiency()
        );
    }
    if let Some(path) = &telemetry_path {
        std::fs::write(path, telemetry::snapshots_json(&registries))?;
        let prom = path.with_extension("prom");
        std::fs::write(&prom, telemetry::prometheus_text(&registries))?;
        let sum = TelemetrySummary::from_registries(&registries);
        // Hot-path guarantees CI leans on: metric recording never
        // allocates after registry construction, every rank aggregated
        // at least the forced final snapshot, and no snapshot was lost
        // to a full ring buffer.
        assert_eq!(sum.telemetry_allocs, 0, "telemetry allocated on the hot path");
        assert!(sum.snapshots > 0, "no cluster snapshots were aggregated");
        assert_eq!(sum.dropped_snapshots, 0, "snapshot ring overflowed");
        println!(
            "telemetry: {} cluster snapshots over {} ranks, {} straggler flags → {} (+ {})",
            sum.snapshots,
            sum.ranks,
            sum.straggler_flags,
            path.display(),
            prom.display()
        );
    }
    let out = &outs[0];

    println!("\n  iter    penalized obj    duality gap    subgrad      nnz(w)");
    for r in &out.history.prox {
        println!(
            "{:>6}   {:>14.8e}   {:>10.3e}   {:>9.3e}   {:>6}",
            r.iter, r.pen_obj, r.gap, r.subgrad, r.nnz
        );
    }
    let last = out.history.prox.last().expect("no prox records");
    println!(
        "\nstopped after {} inner iterations, {} allreduces ({} inner iters per collective)",
        out.history.iters,
        out.history.meter.allreduces,
        out.history.iters as u64 / out.history.meter.allreduces.max(1)
    );

    // 3. Acceptance checks (CI runs this binary).
    assert!(
        last.gap <= 1e-6,
        "duality gap {:.3e} did not certify convergence",
        last.gap
    );
    let support: Vec<usize> = (0..d).filter(|&i| out.w[i] != 0.0).collect();
    let planted: Vec<usize> = (0..d).filter(|&i| w_star[i] != 0.0).collect();
    assert!(
        planted.iter().all(|i| support.contains(i)),
        "planted support {planted:?} not recovered (got {support:?})"
    );
    assert!(
        support.len() <= 2 * k_active,
        "support {support:?} far larger than the planted {k_active} coords"
    );
    // Ranks agree bitwise on the replicated iterate.
    for (rank, o) in outs.iter().enumerate() {
        assert_eq!(o.w, out.w, "rank {rank} disagrees");
    }
    let max_err = out
        .w
        .iter()
        .zip(&w_star)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!(
        "recovered the planted support ({} of {} nonzero coords); \
         max |w − w*| = {max_err:.3} (soft-threshold shrinkage bias ~λ)",
        planted.len(),
        support.len()
    );

    // 4. The same seam sweeps the elastic-net path: ratio 1 → lasso,
    //    ratio 0 → ridge through the prox machinery.
    println!("\nelastic-net path (b=4, s=4, λ={lam}):");
    println!("{:>9} {:>8} {:>14}", "l1_ratio", "nnz(w)", "penalized obj");
    for ratio in [1.0, 0.75, 0.5, 0.25, 0.0] {
        let opts = {
            let mut o = opts.clone();
            o.iters = 20_000;
            o.tol = Some(1e-7);
            o.reg = Reg::Elastic { l1_ratio: ratio };
            o.record_every = 2000;
            o
        };
        let outs = run_spmd(p, |rank, comm| {
            let mut be = NativeBackend::new();
            let sh = &shards[rank];
            bcd::run(&sh.a_loc, &sh.y_loc, sh.n_global, &opts, None, comm, &mut be).unwrap()
        });
        let last = outs[0].history.prox.last().unwrap();
        println!("{:>9.2} {:>8} {:>14.8e}", ratio, last.nnz, last.pen_obj);
    }
    println!("\nlasso example: OK");
    Ok(())
}

/// `--transport process`: the same CA-Prox-BCD lasso machinery, but with
/// the ranks as OS processes over loopback TCP. The driver re-execs this
/// binary once per rank (`maybe_run_process_child` at the top of `main`
/// routes the children), gathers status/trace/telemetry back over the
/// wire, and the parent then re-runs the identical config over the
/// thread transport and asserts the two land **bitwise-identical** —
/// trajectory errors, per-iteration records, duality certificates, and
/// the seven wire-meter fields. Any `--trace`/`--telemetry` artifacts
/// come from the process run, so the CI schema checkers validate exports
/// that crossed a real process boundary.
fn run_process_transport(
    ranks: usize,
    topology: &str,
    trace_path: Option<std::path::PathBuf>,
    telemetry_path: Option<std::path::PathBuf>,
) -> Result<(), Box<dyn std::error::Error>> {
    use cabcd::config::{DatasetConfig, ExperimentConfig, RunConfig, SolverConfig};
    use cabcd::coordinator::run_experiment;

    let node_size = if topology == "twolevel" { 2 } else { 1 };
    let cfg = |transport: &str| ExperimentConfig {
        dataset: DatasetConfig {
            kind: "synthetic".into(),
            name: Some("abalone".into()),
            path: None,
            scale: 16,
            seed: 1,
        },
        solver: SolverConfig {
            method: "cabcd".into(),
            b: 2,
            s: 4,
            lam: None,
            iters: 80,
            seed: 7,
            record_every: 20,
            track_gram_cond: false,
            tol: None,
            overlap: true,
            reg: "l1".into(),
            l1_ratio: 0.5,
            local_iters: 1,
        },
        run: RunConfig {
            ranks,
            backend: "native".into(),
            transport: transport.into(),
            topology: topology.into(),
            node_size,
            artifact_dir: std::env::temp_dir().join("cabcd-lasso-process"),
            // Observability artifacts come from the process run only; the
            // thread twin is a reference trajectory, not an export demo
            // (both are observer-neutral, so this does not perturb the
            // bitwise comparison).
            trace: if transport == "process" {
                trace_path.clone()
            } else {
                None
            },
            telemetry: if transport == "process" {
                telemetry_path.clone()
            } else {
                None
            },
            telemetry_z: None,
            // Hang backstop: a lost worker surfaces as Error::Comm naming
            // the peer and op tag instead of a stuck CI job.
            comm_timeout_ms: Some(30_000),
            checkpoint_every: 0,
            checkpoint_dir: None,
        },
    };

    println!(
        "lasso over {ranks} worker processes (topology {topology}), then the \
         thread-transport twin…"
    );
    let proc = run_experiment(&cfg("process"))?;
    let thrd = run_experiment(&cfg("thread"))?;
    for (label, r) in [("process", &proc), ("thread", &thrd)] {
        assert!(
            r.aborted_at.is_none(),
            "{label} run aborted: {:?}",
            r.aborted_at.as_ref().map(|a| &a.error)
        );
    }
    assert_eq!(proc.transport, "process");
    assert_eq!(proc.ranks, ranks);

    // Bitwise drop-in: trajectory, certificates, wire meters.
    assert_eq!(
        proc.final_sol_err.to_bits(),
        thrd.final_sol_err.to_bits(),
        "solution error diverged across transports"
    );
    assert_eq!(
        proc.final_obj_err.to_bits(),
        thrd.final_obj_err.to_bits(),
        "objective error diverged across transports"
    );
    assert_eq!(proc.history.prox.len(), thrd.history.prox.len());
    for (a, b) in proc.history.prox.iter().zip(&thrd.history.prox) {
        assert_eq!(a.iter, b.iter);
        assert_eq!(a.nnz, b.nnz);
        assert_eq!(a.pen_obj.to_bits(), b.pen_obj.to_bits(), "iter {}", a.iter);
        assert_eq!(a.gap.to_bits(), b.gap.to_bits(), "iter {}", a.iter);
        assert_eq!(a.subgrad.to_bits(), b.subgrad.to_bits(), "iter {}", a.iter);
    }
    let (pm, tm) = (&proc.history.meter, &thrd.history.meter);
    assert_eq!(
        (pm.msgs, pm.words, pm.recv_msgs, pm.recv_words),
        (tm.msgs, tm.words, tm.recv_msgs, tm.recv_words),
        "wire volume diverged across transports"
    );
    assert_eq!(
        (pm.allreduces, pm.all_to_alls, pm.collective_waits),
        (tm.allreduces, tm.all_to_alls, tm.collective_waits),
        "collective counts diverged across transports"
    );

    let last = proc.history.prox.last().expect("no prox records");
    assert!(
        last.gap.is_finite() && last.gap >= 0.0,
        "duality gap {} is not a certificate",
        last.gap
    );
    if trace_path.is_some() {
        let t = proc.trace.as_ref().expect("trace summary missing");
        assert_eq!(t.ranks, ranks, "trace tracks did not cross the process boundary");
    }
    if telemetry_path.is_some() {
        let t = proc.telemetry.as_ref().expect("telemetry summary missing");
        assert_eq!(t.ranks, ranks, "telemetry registries did not cross the process boundary");
    }
    println!(
        "process == thread (bitwise): final gap {:.3e}, {} allreduces, \
         {} msgs / {} words per rank",
        last.gap, pm.allreduces, pm.msgs, pm.words
    );
    println!("\nlasso example (process transport): OK");
    Ok(())
}
