//! End-to-end sparse recovery with CA-Prox-BCD: plant a sparse weight
//! vector, observe noisy linear measurements, and recover the support
//! with the communication-avoiding lasso solver — certified by the
//! Fenchel duality gap, with exactly H/s collectives of the same packed
//! `[G|r]` payload the ridge solvers ship.
//!
//! ```sh
//! cargo run --release --example lasso            # plain
//! cargo run --release --example lasso -- --trace lasso.trace.json
//! cargo run --release --example lasso -- --telemetry lasso.telemetry.json
//! ```
//!
//! Runs SPMD over 4 simulated ranks, then sweeps the elastic-net mixing
//! ratio to show the regularization-path seam. CI runs this example as an
//! acceptance check (gap ≤ 1e-6, exact support recovery) and validates
//! the `--trace` Chrome trace-event output with `python/check_trace.py`
//! and the `--telemetry` snapshot/exposition pair with
//! `python/check_telemetry.py`.

use cabcd::comm::thread::run_spmd;
use cabcd::coordinator::partition_primal;
use cabcd::gram::NativeBackend;
use cabcd::matrix::io::Dataset;
use cabcd::matrix::{DenseMatrix, Matrix};
use cabcd::prox::Reg;
use cabcd::solvers::{bcd, SolverOpts};
use cabcd::telemetry::{self, Registry, TelemetrySummary};
use cabcd::trace::{self, TraceSummary, Tracer};
use cabcd::util::Rng64;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // Optional, in any order: `--trace PATH` writes a per-rank Chrome
    // trace-event JSON of the main SPMD solve (loadable in Perfetto);
    // `--telemetry PATH` writes the cluster health snapshots as JSON plus
    // a Prometheus exposition at PATH with a `.prom` extension. Both are
    // schema-checked in CI.
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut trace_path: Option<std::path::PathBuf> = None;
    let mut telemetry_path: Option<std::path::PathBuf> = None;
    let mut it = argv.iter();
    while let Some(flag) = it.next() {
        let slot = match flag.as_str() {
            "--trace" => &mut trace_path,
            "--telemetry" => &mut telemetry_path,
            other => {
                return Err(
                    format!("usage: lasso [--trace PATH] [--telemetry PATH], got {other:?}")
                        .into(),
                )
            }
        };
        let Some(path) = it.next() else {
            return Err(format!("{flag} needs a PATH argument").into());
        };
        *slot = Some(std::path::PathBuf::from(path));
    }

    // 1. Planted sparse-recovery instance: d = 64 features, only 6
    //    active, n = 512 noisy measurements.
    let (d, n, k_active) = (64usize, 512usize, 6usize);
    let mut rng = Rng64::seed_from_u64(42);
    let data: Vec<f64> = (0..d * n).map(|_| rng.gen_normal()).collect();
    let x = Matrix::Dense(DenseMatrix::from_vec(d, n, data));
    let mut w_star = vec![0.0; d];
    for k in 0..k_active {
        w_star[k * (d / k_active)] = if k % 2 == 0 { 1.5 } else { -2.0 };
    }
    let mut y = vec![0.0; n];
    x.matvec_t(&w_star, &mut y)?;
    for v in y.iter_mut() {
        *v += 0.05 * rng.gen_normal();
    }
    let lam = 0.1;
    println!(
        "lasso sparse recovery: d={d}, n={n}, ‖w*‖₀={k_active}, λ={lam}"
    );

    // 2. CA-Prox-BCD over 4 simulated ranks (1D-block-column shards).
    let ds = Dataset {
        name: "planted-sparse".into(),
        x,
        y,
    };
    let p = 4usize;
    let shards = partition_primal(&ds, p)?;
    let opts = SolverOpts::builder()
        .b(4)
        .s(4)
        .lam(lam)
        .iters(60_000)
        .seed(7)
        .record_every(2000)
        .tol(1e-8)
        .reg(Reg::L1)
        .build();
    let tracing = trace_path.is_some();
    let telemetering = telemetry_path.is_some();
    let outs = run_spmd(p, |rank, comm| {
        if tracing {
            trace::install(Tracer::new(rank, trace::DEFAULT_SPAN_CAPACITY));
        }
        if telemetering {
            telemetry::install(Registry::new(rank, p));
        }
        let mut be = NativeBackend::new();
        let sh = &shards[rank];
        let out =
            bcd::run(&sh.a_loc, &sh.y_loc, sh.n_global, &opts, None, comm, &mut be).unwrap();
        (out, trace::take(), telemetry::take())
    });
    let mut tracers: Vec<Tracer> = Vec::new();
    let mut registries: Vec<Registry> = Vec::new();
    let outs: Vec<_> = outs
        .into_iter()
        .map(|(out, tracer, reg)| {
            tracers.extend(tracer);
            registries.extend(reg);
            out
        })
        .collect();
    if let Some(path) = &trace_path {
        std::fs::write(path, trace::chrome_trace_json(&tracers))?;
        let sum = TraceSummary::from_tracers(&tracers);
        for (tracer, out) in tracers.iter().zip(&outs) {
            trace::cross_check(tracer, &out.history.meter)?;
        }
        println!(
            "trace: {} spans over {} ranks → {} (overlap efficiency {:.3})",
            sum.spans,
            sum.ranks,
            path.display(),
            sum.overlap_efficiency()
        );
    }
    if let Some(path) = &telemetry_path {
        std::fs::write(path, telemetry::snapshots_json(&registries))?;
        let prom = path.with_extension("prom");
        std::fs::write(&prom, telemetry::prometheus_text(&registries))?;
        let sum = TelemetrySummary::from_registries(&registries);
        // Hot-path guarantees CI leans on: metric recording never
        // allocates after registry construction, every rank aggregated
        // at least the forced final snapshot, and no snapshot was lost
        // to a full ring buffer.
        assert_eq!(sum.telemetry_allocs, 0, "telemetry allocated on the hot path");
        assert!(sum.snapshots > 0, "no cluster snapshots were aggregated");
        assert_eq!(sum.dropped_snapshots, 0, "snapshot ring overflowed");
        println!(
            "telemetry: {} cluster snapshots over {} ranks, {} straggler flags → {} (+ {})",
            sum.snapshots,
            sum.ranks,
            sum.straggler_flags,
            path.display(),
            prom.display()
        );
    }
    let out = &outs[0];

    println!("\n  iter    penalized obj    duality gap    subgrad      nnz(w)");
    for r in &out.history.prox {
        println!(
            "{:>6}   {:>14.8e}   {:>10.3e}   {:>9.3e}   {:>6}",
            r.iter, r.pen_obj, r.gap, r.subgrad, r.nnz
        );
    }
    let last = out.history.prox.last().expect("no prox records");
    println!(
        "\nstopped after {} inner iterations, {} allreduces ({} inner iters per collective)",
        out.history.iters,
        out.history.meter.allreduces,
        out.history.iters as u64 / out.history.meter.allreduces.max(1)
    );

    // 3. Acceptance checks (CI runs this binary).
    assert!(
        last.gap <= 1e-6,
        "duality gap {:.3e} did not certify convergence",
        last.gap
    );
    let support: Vec<usize> = (0..d).filter(|&i| out.w[i] != 0.0).collect();
    let planted: Vec<usize> = (0..d).filter(|&i| w_star[i] != 0.0).collect();
    assert!(
        planted.iter().all(|i| support.contains(i)),
        "planted support {planted:?} not recovered (got {support:?})"
    );
    assert!(
        support.len() <= 2 * k_active,
        "support {support:?} far larger than the planted {k_active} coords"
    );
    // Ranks agree bitwise on the replicated iterate.
    for (rank, o) in outs.iter().enumerate() {
        assert_eq!(o.w, out.w, "rank {rank} disagrees");
    }
    let max_err = out
        .w
        .iter()
        .zip(&w_star)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!(
        "recovered the planted support ({} of {} nonzero coords); \
         max |w − w*| = {max_err:.3} (soft-threshold shrinkage bias ~λ)",
        planted.len(),
        support.len()
    );

    // 4. The same seam sweeps the elastic-net path: ratio 1 → lasso,
    //    ratio 0 → ridge through the prox machinery.
    println!("\nelastic-net path (b=4, s=4, λ={lam}):");
    println!("{:>9} {:>8} {:>14}", "l1_ratio", "nnz(w)", "penalized obj");
    for ratio in [1.0, 0.75, 0.5, 0.25, 0.0] {
        let opts = {
            let mut o = opts.clone();
            o.iters = 20_000;
            o.tol = Some(1e-7);
            o.reg = Reg::Elastic { l1_ratio: ratio };
            o.record_every = 2000;
            o
        };
        let outs = run_spmd(p, |rank, comm| {
            let mut be = NativeBackend::new();
            let sh = &shards[rank];
            bcd::run(&sh.a_loc, &sh.y_loc, sh.n_global, &opts, None, comm, &mut be).unwrap()
        });
        let last = outs[0].history.prox.last().unwrap();
        println!("{:>9.2} {:>8} {:>14.8e}", ratio, last.nnz, last.pen_obj);
    }
    println!("\nlasso example: OK");
    Ok(())
}
