//! Primal (BCD) vs dual (BDCD) on opposite dataset shapes — the paper's
//! §5.1 observation: the method iterating in the *small* dimension wins,
//! so d ≫ n favors the dual and n ≫ d favors the primal (block sizes
//! proportional to the sampled dimension equalize them).
//!
//! ```sh
//! cargo run --release --example primal_vs_dual
//! ```

use cabcd::comm::SerialComm;
use cabcd::gram::NativeBackend;
use cabcd::matrix::gen::{generate, scaled_specs};
use cabcd::solvers::{bcd, bdcd, cg, SolverOpts};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // news20-like (d ≫ n, sparse) and abalone-like (n ≫ d, dense) clones,
    // scaled so the example runs in seconds.
    let specs = scaled_specs(16);
    let news = specs.iter().find(|s| s.name.starts_with("news20")).unwrap();
    let abal = specs.iter().find(|s| s.name.starts_with("abalone")).unwrap();

    for spec in [abal, news] {
        let ds = generate(spec, 1)?;
        let lam = spec.lambda();
        let (d, n) = (ds.d(), ds.n());
        println!(
            "\n=== {} — d={d}, n={n} ({}) ===",
            spec.name,
            if d > n {
                "d ≫ n: dual territory"
            } else {
                "n ≫ d: primal territory"
            }
        );
        let mut comm = SerialComm::new();
        let reference = cg::compute_reference(&ds.x, &ds.y, n, lam, &mut comm)?;

        // Block sizes proportional to the sampled dimension (paper §5.1.3).
        let b_primal = (d / 8).clamp(1, 32);
        let b_dual = (n / 8).clamp(1, 32);
        let iters = 600;

        let opts = SolverOpts::builder()
            .b(b_primal)
            .s(1)
            .lam(lam)
            .iters(iters)
            .seed(3)
            .record_every(0)
            .track_gram_cond(false)
            .overlap(false)
            .build();
        let mut be = NativeBackend::new();
        let p = bcd::run(&ds.x, &ds.y, n, &opts, Some(&reference), &mut comm, &mut be)?;

        let a = ds.x.transpose();
        let opts_d = {
            let mut o = opts.clone();
            o.b = b_dual;
            o
        };
        let du = bdcd::run(&a, &ds.y, d, 0, &opts_d, Some(&reference), &mut comm, &mut be)?;

        println!(
            "BCD  (b ={b_primal:>3}): after {iters} iters  |obj err| = {:.3e}, sol err = {:.3e}",
            p.history.final_obj_err(),
            p.history.final_sol_err()
        );
        println!(
            "BDCD (b'={b_dual:>3}): after {iters} iters  |obj err| = {:.3e}, sol err = {:.3e}",
            du.history.final_obj_err(),
            du.history.final_sol_err()
        );
        let (ep, ed) = (p.history.final_obj_err(), du.history.final_obj_err());
        if ep.max(ed) <= 1e-14 || (ep / ed).max(ed / ep) < 2.0 {
            println!("→ tie (both converged)");
        } else if ep < ed {
            println!("→ primal method wins on this shape");
        } else {
            println!("→ dual method wins on this shape");
        }
    }
    Ok(())
}
