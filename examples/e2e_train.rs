//! End-to-end driver — proves all layers compose on a real small workload.
//!
//! Pipeline exercised:
//!   Table-3 a9a clone (123 features × 32651 points, 11% sparse)
//!     → 1D-block-column partitioning over SPMD ranks (ThreadComm)
//!     → CA-BCD with the fused Gram+residual hot path
//!         · leg 1: native Rust backend, P=4, full training run
//!         · leg 2: AOT JAX/Pallas artifacts through PJRT (XLA backend),
//!           P=2 — the three-layer claim, end to end
//!     → binomial-tree allreduce per outer iteration (measured meters)
//!     → loss curve against a CG-computed optimum
//!     → modeled Cori-MPI/Spark time from the *measured* message counts.
//!
//! Results land in `results/e2e_train.json`. Recorded in EXPERIMENTS.md.
//!
//! ```sh
//! cargo run --release --example e2e_train
//! ```

use cabcd::config::{DatasetConfig, ExperimentConfig, RunConfig, SolverConfig};
use cabcd::coordinator::run_experiment;
use cabcd::costmodel::Machine;

fn cfg(backend: &str, ranks: usize, iters: usize) -> ExperimentConfig {
    ExperimentConfig {
        dataset: DatasetConfig {
            kind: "synthetic".into(),
            name: Some("a9a".into()),
            path: None,
            scale: 1,
            seed: 42,
        },
        solver: SolverConfig {
            method: "cabcd".into(),
            b: 8,
            s: 4,
            lam: None, // 1000·σ_min from the spec
            iters,
            seed: 7,
            record_every: (iters / 10).max(1),
            track_gram_cond: false,
            tol: None,
            overlap: false,
            reg: "l2".into(),
            l1_ratio: 0.5,
            local_iters: 100,
        },
        run: RunConfig {
            ranks,
            backend: backend.into(),
            artifact_dir: "artifacts".into(),
            trace: None,
        },
    }
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    std::fs::create_dir_all("results")?;

    // ---- Leg 1: full training run, native backend, P=4 -----------------
    println!("=== leg 1: CA-BCD on a9a clone, native backend, P=4 ===");
    let native = run_experiment(&cfg("native", 4, 2000))?;
    println!(
        "dataset {} (d={}, n={}), λ={:.3e}, b={} s={}",
        native.dataset, native.d, native.n, native.lambda, native.b, native.s
    );
    println!("loss curve (relative objective error vs optimum):");
    println!("  {:>6}  {:>14}  {:>12}", "iter", "|obj err|", "sol err");
    for r in &native.history.records {
        println!(
            "  {:>6}  {:>14.4e}  {:>12.4e}",
            r.iter,
            r.obj_err.abs(),
            r.sol_err
        );
    }
    println!(
        "wall {:.0} ms | {} allreduces | critical path {} msgs / {} words",
        native.wall_ms,
        native.history.meter.allreduces,
        native.critical_msgs,
        native.critical_words
    );

    // Modeled Cori time from MEASURED communication (γF omitted — the
    // flops term is identical for BCD and CA-BCD up to the s-fold Gram
    // widening and cancels qualitatively; see costmodel for full curves).
    for m in [Machine::cori_mpi(), Machine::cori_spark()] {
        let t_ca = m.time(0.0, native.critical_msgs as f64, native.critical_words as f64);
        // classical BCD at the same H: s× the synchronizations, words/s.
        let t_bcd = m.time(
            0.0,
            (native.critical_msgs * native.s as u64) as f64,
            (native.critical_words as f64) / native.s as f64,
        );
        println!(
            "modeled comm time on {}: BCD {:.3e} s vs CA-BCD {:.3e} s → {:.1}×",
            m.name,
            t_bcd,
            t_ca,
            t_bcd / t_ca
        );
    }

    // ---- Leg 2: the three-layer path (Pallas→HLO→PJRT), P=2 ------------
    // 80 inner iterations: the wall time is dominated by the per-rank
    // artifact compile (~9 s) plus interpret-mode Pallas execution — this
    // leg proves composition, not speed (DESIGN.md §Hardware-Adaptation).
    println!("\n=== leg 2: same workload through the AOT XLA artifacts, P=2 ===");
    let xla = run_experiment(&cfg("xla", 2, 80))?;
    println!(
        "xla backend: wall {:.0} ms, final |obj err| {:.4e}, sol err {:.4e}",
        xla.wall_ms, xla.final_obj_err, xla.final_sol_err
    );

    // Cross-check: identical sampling seed ⇒ a native rerun of the same
    // 80 iterations must match the XLA leg record-for-record (backend
    // parity at the whole-system level).
    let native_short = run_experiment(&cfg("native", 2, 80))?;
    let mut max_dev = 0.0f64;
    let mut shared = 0;
    for (a, b) in native_short.history.records.iter().zip(&xla.history.records) {
        assert_eq!(a.iter, b.iter);
        shared += 1;
        max_dev = max_dev.max((a.sol_err - b.sol_err).abs());
    }
    println!("max |sol-err deviation| over {shared} shared record points: {max_dev:.3e}");
    assert!(shared >= 5);
    assert!(
        max_dev < 1e-9,
        "native and XLA legs diverged: {max_dev}"
    );

    // ---- Persist -------------------------------------------------------
    let payload = format!(
        "{{\"native\":{},\"xla\":{}}}",
        native.to_json(),
        xla.to_json()
    );
    std::fs::write("results/e2e_train.json", &payload)?;
    println!("\nwrote results/e2e_train.json ({} bytes)", payload.len());
    println!("all three layers composed: Pallas kernel → HLO artifact → PJRT → Rust coordinator ✓");
    Ok(())
}
