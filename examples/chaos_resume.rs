//! Fault-tolerance walkthrough: kill a rank mid-lasso, resume from the
//! last checkpoint, land on the bitwise-identical answer.
//!
//! ```sh
//! cargo run --release --example chaos_resume
//! ```
//!
//! Three acts over the same planted sparse-recovery instance (4 ranks,
//! CA-Prox-BCD):
//!
//! 1. **Baseline** — fault-free run with file-backed checkpointing every
//!    50 s-step blocks ([`FileSink`], one snapshot file per rank).
//! 2. **Chaos** — the same run under a seeded [`ChaosComm`]: rank 2 dies
//!    at its 300th collective without a farewell. Peers discover the
//!    death through their receive deadlines, the group poisons, and
//!    every rank reports an actionable `Error::Comm` — nobody hangs.
//! 3. **Resume** — [`Session::resume`] restarts each rank from its last
//!    on-disk snapshot and replays to completion. The final iterate,
//!    prox certificates, and wire meters are asserted **bitwise equal**
//!    to the baseline (buffer-pool warm-up misses are the one legitimate
//!    difference; see the `engine::checkpoint` module docs).
//!
//! CI runs this binary as the chaos acceptance check.

use std::time::Duration;

use cabcd::comm::thread::run_spmd;
use cabcd::comm::{ChaosComm, ChaosSpec, Communicator, CostMeter, ThreadComm};
use cabcd::coordinator::partition_primal;
use cabcd::engine::{checkpoint, FileSink, Problem, Session};
use cabcd::error::Result;
use cabcd::matrix::io::Dataset;
use cabcd::matrix::{DenseMatrix, Matrix};
use cabcd::prox::Reg;
use cabcd::solvers::SolverOpts;
use cabcd::util::Rng64;

const P: usize = 4;
const CKPT_EVERY: usize = 50;

/// One rank's outcome: the solve result plus the endpoint's final meter
/// (the meter survives a failed solve; the output does not).
type RankResult = (std::result::Result<(Vec<f64>, cabcd::metrics::History), String>, CostMeter);

fn main() -> std::result::Result<(), Box<dyn std::error::Error>> {
    // Planted sparse instance, as in the lasso example but smaller.
    let (d, n, k_active) = (32usize, 256usize, 4usize);
    let mut rng = Rng64::seed_from_u64(42);
    let data: Vec<f64> = (0..d * n).map(|_| rng.gen_normal()).collect();
    let x = Matrix::Dense(DenseMatrix::from_vec(d, n, data));
    let mut w_star = vec![0.0; d];
    for k in 0..k_active {
        w_star[k * (d / k_active)] = if k % 2 == 0 { 1.5 } else { -2.0 };
    }
    let mut y = vec![0.0; n];
    x.matvec_t(&w_star, &mut y)?;
    for v in y.iter_mut() {
        *v += 0.05 * rng.gen_normal();
    }
    let ds = Dataset {
        name: "planted-sparse".into(),
        x,
        y,
    };
    let shards = partition_primal(&ds, P)?;
    let opts = SolverOpts::builder()
        .b(4)
        .s(4)
        .lam(0.1)
        .iters(2_000)
        .seed(7)
        .record_every(500)
        .reg(Reg::L1)
        .build();
    let ckpt_dir = std::env::temp_dir().join(format!("cabcd_chaos_resume_{}", std::process::id()));

    let run = |spec: ChaosSpec, deadline: Option<Duration>, resume: bool| -> Vec<RankResult> {
        let shards = &shards;
        let opts = &opts;
        let ckpt_dir = &ckpt_dir;
        run_spmd(P, move |rank, comm| {
            // The chaos wrapper wants ownership of the endpoint; swap in a
            // one-rank placeholder for the duration of the solve.
            let mut stub_group = ThreadComm::group(1);
            let stub = stub_group.pop().expect("group(1) returns one endpoint");
            let inner = std::mem::replace(comm, stub);
            let mut chaos = ChaosComm::new(inner, spec);
            chaos.set_deadline(deadline);
            let run_one = || -> Result<(Vec<f64>, cabcd::metrics::History)> {
                checkpoint::install(Box::new(FileSink::new(ckpt_dir)?), CKPT_EVERY);
                let sh = &shards[rank];
                let problem = Problem::primal(&sh.a_loc, &sh.y_loc, sh.n_global);
                let mut be = cabcd::gram::NativeBackend::new();
                let mut session = Session::new(&problem)
                    .opts(opts.clone())
                    .comm(&mut chaos)
                    .backend(&mut be);
                if resume {
                    let ckpt = FileSink::new(ckpt_dir)?
                        .load(rank)?
                        .ok_or_else(|| {
                            cabcd::error::Error::Runtime(format!(
                                "rank {rank}: no checkpoint on disk"
                            ))
                        })?;
                    session = session.resume(ckpt);
                }
                let out = session.run()?.into_primal()?;
                Ok((out.w, out.history))
            };
            let res = run_one().map_err(|e| e.to_string());
            checkpoint::take();
            chaos.set_deadline(None);
            let meter = *chaos.meter();
            *comm = chaos.into_inner();
            (res, meter)
        })
    };

    // Act 1: fault-free baseline, checkpointing on.
    println!("act 1: fault-free lasso (P={P}, checkpoint every {CKPT_EVERY} blocks)");
    let baseline = run(ChaosSpec::default(), None, false);
    let (base_w, base_h) = match &baseline[0].0 {
        Ok((w, h)) => (w.clone(), h.clone()),
        Err(e) => return Err(format!("baseline failed: {e}").into()),
    };
    println!(
        "  {} iters, {} allreduces, gap {:.3e}",
        base_h.iters,
        base_h.meter.allreduces,
        base_h.prox.last().map(|r| r.gap).unwrap_or(f64::NAN)
    );

    // Act 2: rank 2 dies mid-run; peers poison via their deadlines.
    println!("act 2: rank 2 dies at collective 300 (peer deadline 500 ms)");
    let spec = ChaosSpec {
        die_at: Some(300),
        victim: 2,
        ..ChaosSpec::default()
    };
    let dead = run(spec, Some(Duration::from_millis(500)), false);
    for (rank, (res, meter)) in dead.iter().enumerate() {
        let err = match res {
            Err(e) => e,
            Ok(_) => return Err(format!("rank {rank} survived a dead peer").into()),
        };
        let actionable = err.contains("died at collective")
            || err.contains("timed out")
            || err.contains("poisoned");
        if !actionable {
            return Err(format!("rank {rank}: unactionable error: {err}").into());
        }
        println!("  rank {rank}: {err} (timeouts metered: {})", meter.timeouts);
    }

    // Act 3: resume every rank from its last on-disk snapshot.
    let probe = FileSink::new(&ckpt_dir)?
        .load(0)?
        .ok_or("no checkpoint survived the crash")?;
    println!(
        "act 3: resuming all ranks from block {} ({} state words per rank)",
        probe.next_k,
        probe.state_words()
    );
    let resumed = run(ChaosSpec::default(), None, true);
    for (rank, (res, _)) in resumed.iter().enumerate() {
        let (w, h) = match res {
            Ok(out) => out,
            Err(e) => return Err(format!("resume failed on rank {rank}: {e}").into()),
        };
        // Bitwise recovery: iterate, certificates, and wire meters all
        // match the fault-free run (buf_allocs — pool re-warm — differs
        // by design and is excluded).
        if w.iter().zip(&base_w).any(|(a, b)| a.to_bits() != b.to_bits()) {
            return Err(format!("rank {rank}: resumed iterate diverged").into());
        }
        let same_certs = h.prox.len() == base_h.prox.len()
            && h.prox
                .iter()
                .zip(&base_h.prox)
                .all(|(a, b)| a.gap.to_bits() == b.gap.to_bits() && a.nnz == b.nnz);
        if !same_certs {
            return Err(format!("rank {rank}: resumed certificates diverged").into());
        }
        let base_rank_meter = match &baseline[rank].0 {
            Ok((_, h)) => h.meter,
            Err(_) => unreachable!("baseline succeeded on every rank"),
        };
        let (m, b) = (h.meter, base_rank_meter);
        let wire_equal = m.msgs == b.msgs
            && m.words == b.words
            && m.recv_msgs == b.recv_msgs
            && m.recv_words == b.recv_words
            && m.allreduces == b.allreduces
            && m.all_to_alls == b.all_to_alls
            && m.collective_waits == b.collective_waits;
        if !wire_equal {
            return Err(format!("rank {rank}: resumed wire meters diverged").into());
        }
    }
    println!(
        "  recovered bitwise: {} allreduces total, identical wire meters on all ranks",
        resumed[0].0.as_ref().map(|(_, h)| h.meter.allreduces).unwrap_or(0)
    );
    std::fs::remove_dir_all(&ckpt_dir).ok();
    println!("\nchaos_resume example: OK");
    Ok(())
}
