//! Tuning the loop-blocking factor s — the paper's Figure 4 in miniature:
//! for fixed b, sweep s and verify (a) the trajectory is unchanged,
//! (b) synchronizations drop by s, (c) the Gram condition number grows
//! with s but stays benign, (d) flops/bandwidth grow with s — the tradeoff
//! that bounds practical s.
//!
//! ```sh
//! cargo run --release --example ca_tuning
//! ```

use cabcd::comm::SerialComm;
use cabcd::costmodel::{AlgoCosts, CostParams, Method};
use cabcd::gram::NativeBackend;
use cabcd::matrix::gen::{generate, spec_by_name};
use cabcd::solvers::{bcd, cg, SolverOpts};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let spec = spec_by_name("abalone")?;
    let ds = generate(&spec, 42)?;
    let lam = spec.lambda();
    let mut comm = SerialComm::new();
    let reference = cg::compute_reference(&ds.x, &ds.y, ds.n(), lam, &mut comm)?;

    let b = 4usize;
    let iters = 1000usize;
    println!(
        "CA-BCD s-sweep on {} (d={}, n={}, b={b}, H={iters}, λ={:.2e})\n",
        ds.name,
        ds.d(),
        ds.n(),
        lam
    );
    println!(
        "{:>5} {:>12} {:>12} {:>11} {:>22} {:>14} {:>14}",
        "s", "|obj err|", "sol err", "allreduce", "cond(G) min/med/max", "flops (seq)", "words"
    );

    let mut baseline: Option<Vec<f64>> = None;
    for s in [1usize, 2, 5, 10, 20, 50, 100] {
        let opts = SolverOpts::builder()
            .b(b)
            .s(s)
            .lam(lam)
            .iters(iters)
            .seed(9)
            .record_every(0)
            .track_gram_cond(true)
            .overlap(false)
            .build();
        let mut be = NativeBackend::new();
        let mut c = SerialComm::new();
        let out = bcd::run(&ds.x, &ds.y, ds.n(), &opts, Some(&reference), &mut c, &mut be)?;
        let cs = out.history.cond_stats();
        let cp = CostParams {
            d: ds.d() as f64,
            n: ds.n() as f64,
            p: 1.0,
            b: b as f64,
            s: s as f64,
            h: iters as f64,
        };
        let costs = AlgoCosts::of(Method::CaBcd, &cp);
        println!(
            "{:>5} {:>12.3e} {:>12.3e} {:>11} {:>7.1}/{:>6.1}/{:>6.1} {:>14.3e} {:>14.3e}",
            s,
            out.history.final_obj_err(),
            out.history.final_sol_err(),
            out.history.meter.allreduces,
            cs.min,
            cs.median,
            cs.max,
            costs.flops,
            costs.bandwidth
        );
        match &baseline {
            None => baseline = Some(out.w),
            Some(w0) => {
                let dev = out
                    .w
                    .iter()
                    .zip(w0)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f64, f64::max);
                assert!(
                    dev < 1e-8,
                    "s={s} trajectory deviated from classical by {dev}"
                );
            }
        }
    }
    println!(
        "\nEvery s produced the SAME solution (checked to 1e-8) while the \
         synchronization count fell by s — \"without altering the \
         convergence behaviour\", as claimed."
    );
    Ok(())
}
