//! The paper's §5.2 modeled-performance experiments (Figures 8 and 9):
//! strong and weak scaling of BCD vs CA-BCD on Cori under the MPI and
//! Spark machine models, reproducing the headline speedups.
//!
//! ```sh
//! cargo run --release --example scaling_speedup
//! ```

use cabcd::costmodel::{
    scaling::{paper_p_range, strong_scaling, weak_scaling},
    Machine,
};

fn main() {
    let pr = paper_p_range();
    let b = 4.0;
    let h = 100.0;

    println!("=== Figure 8: strong scaling (b=4, d=1024) ===");
    for (m, log2n) in [(Machine::cori_mpi(), 35u32), (Machine::cori_spark(), 40)] {
        let n = (1u64 << log2n) as f64;
        let series = strong_scaling(&m, 1024.0, n, b, h, &pr, 1000);
        let (mx, at_p, at_s) = series.max_speedup();
        println!("\n{} (n=2^{log2n}):", m.name);
        println!("{:>12} {:>13} {:>13} {:>7} {:>9}", "P", "T_BCD", "T_CA-BCD", "s*", "speedup");
        for pt in series.points.iter().step_by(3) {
            println!(
                "{:>12} {:>13.4e} {:>13.4e} {:>7} {:>9.2}",
                pt.p, pt.t_classical, pt.t_ca, pt.best_s, pt.speedup
            );
        }
        println!("→ max modeled speedup {mx:.0}× at P={at_p} (s={at_s})");
    }

    println!("\n=== Figure 9: weak scaling (b=4, d=1024, n/P=2^11) ===");
    for m in [Machine::cori_mpi(), Machine::cori_spark()] {
        let series = weak_scaling(&m, 1024.0, 2048.0, b, h, &pr, 1000);
        let (mx, at_p, at_s) = series.max_speedup();
        println!("\n{}:", m.name);
        println!("{:>12} {:>13} {:>13} {:>7} {:>9}", "P", "T_BCD", "T_CA-BCD", "s*", "speedup");
        for pt in series.points.iter().step_by(3) {
            println!(
                "{:>12} {:>13.4e} {:>13.4e} {:>7} {:>9.2}",
                pt.p, pt.t_classical, pt.t_ca, pt.best_s, pt.speedup
            );
        }
        println!("→ max modeled speedup {mx:.0}× at P={at_p} (s={at_s})");
    }

    println!(
        "\nPaper's headline numbers for comparison: strong scaling 14× (MPI) \
         and 165× (Spark); weak scaling 12× (MPI) and 396× (Spark)."
    );
}
