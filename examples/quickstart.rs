//! Quickstart: solve a regularized least-squares problem with CA-BCD and
//! see the paper's headline effect — identical convergence to classical
//! BCD with 1/s as many synchronizations.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use cabcd::comm::thread::run_spmd;
use cabcd::comm::SerialComm;
use cabcd::coordinator::partition_primal;
use cabcd::gram::NativeBackend;
use cabcd::matrix::gen::{generate, spec_by_name};
use cabcd::solvers::{bcd, cg, SolverOpts};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. A dataset: the abalone clone from the paper's Table 3
    //    (8 features × 4177 points, dense, planted spectrum).
    let spec = spec_by_name("abalone")?;
    let ds = generate(&spec, /*seed=*/ 42)?;
    let lam = spec.lambda(); // the paper's λ = 1000·σ_min
    println!(
        "dataset {}: d={}, n={}, λ={:.3e}",
        ds.name,
        ds.d(),
        ds.n(),
        lam
    );

    // 2. Ground truth from CG at tol 1e-15 (exactly the paper's protocol).
    let mut comm = SerialComm::new();
    let reference = cg::compute_reference(&ds.x, &ds.y, ds.n(), lam, &mut comm)?;

    // 3. Classical BCD vs communication-avoiding BCD, identical sampling.
    for s in [1usize, 8] {
        let opts = SolverOpts::builder()
            .b(4)
            .s(s)
            .lam(lam)
            .iters(2000)
            .seed(7)
            .record_every(400)
            .track_gram_cond(false)
            .overlap(false)
            .build();
        let mut backend = NativeBackend::new();
        let out = bcd::run(
            &ds.x,
            &ds.y,
            ds.n(),
            &opts,
            Some(&reference),
            &mut comm,
            &mut backend,
        )?;
        let label = if s == 1 { "BCD    " } else { "CA-BCD " };
        println!(
            "\n{label} (b=4, s={s}): {} inner iterations, {} allreduces",
            out.history.iters, out.history.meter.allreduces
        );
        println!("  iter    |objective err|   solution err");
        for r in &out.history.records {
            println!(
                "  {:>5}   {:>14.3e}   {:>12.3e}",
                r.iter,
                r.obj_err.abs(),
                r.sol_err
            );
        }
        comm = SerialComm::new(); // fresh meter per run
    }

    println!(
        "\nSame trajectory, 8× fewer synchronizations — that is Theorem 6's \
         L = O((H/s)·log P) in action."
    );

    // 4. The same CA-BCD run distributed over P=4 ranks (1D block-column
    //    partition, shared sampling seed), to see what each rank actually
    //    puts on the wire: one packed [G|r] allreduce per outer iteration.
    let p = 4;
    let opts = SolverOpts::builder()
        .b(4)
        .s(8)
        .lam(lam)
        .iters(2000)
        .seed(7)
        .record_every(400)
        .build();
    let shards = partition_primal(&ds, p)?;
    let histories = run_spmd(p, |rank, comm| {
        let sh = &shards[rank];
        let mut backend = NativeBackend::new();
        bcd::run(
            &sh.a_loc,
            &sh.y_loc,
            sh.n_global,
            &opts,
            Some(&reference),
            comm,
            &mut backend,
        )
        .map(|out| out.history)
    });
    println!("\nCA-BCD (b=4, s=8) on P={p} ranks — per-rank wire summary:");
    println!("  rank   allreduces       msgs      words");
    for (rank, h) in histories.iter().enumerate() {
        let m = h.as_ref().map_err(|e| e.to_string())?.meter;
        println!(
            "  {:>4}   {:>10}   {:>8}   {:>8}",
            rank, m.allreduces, m.msgs, m.words
        );
    }
    Ok(())
}
