//! Figure 8 — modeled strong scaling of BCD vs CA-BCD on NERSC Cori,
//! b = 4, d = 1024: MPI with n = 2³⁵ (8a) and Spark with n = 2⁴⁰ (8b),
//! P = 2² … 2²⁸, CA curve at its best s per P.
//!
//! Paper headline: 14× (MPI), 165× (Spark). Shape checks asserted: BCD
//! scales until communication dominates then flattens/worsens; CA-BCD
//! keeps scaling; Spark's gap ≫ MPI's.

use cabcd::costmodel::{
    scaling::{paper_p_range, strong_scaling, strong_scaling_wire},
    Machine, Wire,
};

fn main() {
    let pr = paper_p_range();
    let mut headlines = Vec::new();
    for (panel, m, log2n) in [
        ("8a", Machine::cori_mpi(), 35u32),
        ("8b", Machine::cori_spark(), 40),
    ] {
        let n = (1u64 << log2n) as f64;
        let series = strong_scaling(&m, 1024.0, n, 4.0, 100.0, &pr, 2000);
        println!("\n=== Figure {panel}: {} strong scaling (d=1024, n=2^{log2n}, b=4) ===", m.name);
        println!(
            "{:>12} {:>14} {:>14} {:>8} {:>10}",
            "P", "T_BCD (s)", "T_CA-BCD (s)", "best s", "speedup"
        );
        for pt in &series.points {
            println!(
                "{:>12} {:>14.6e} {:>14.6e} {:>8} {:>10.2}",
                pt.p, pt.t_classical, pt.t_ca, pt.best_s, pt.speedup
            );
        }
        let (mx, at_p, at_s) = series.max_speedup();
        println!("→ max modeled speedup {mx:.1}× at P={at_p} (s={at_s})");
        headlines.push((m.name, mx));

        // Shape assertions.
        let first = &series.points[0];
        let last = series.points.last().unwrap();
        assert!(first.speedup < 1.2, "flop-dominated regime should be ~1×");
        assert!(last.speedup > first.speedup, "CA advantage must grow with P");
        // BCD eventually stops strong-scaling (t at max P ≥ t at some
        // smaller P within the tail), while CA keeps improving or flat.
        let t_bcd_tail: Vec<f64> = series.points.iter().rev().take(8).map(|p| p.t_classical).collect();
        assert!(
            t_bcd_tail.windows(2).any(|w| w[0] >= w[1]),
            "BCD should flatten in the communication-dominated tail"
        );
    }
    assert!(
        headlines[1].1 > headlines[0].1 * 4.0,
        "Spark headline should dwarf MPI: {headlines:?}"
    );

    // Measured-machine mode (ROADMAP cost-model calibration): the same
    // sweep with the wire charged as the packed sb(sb+1)/2+sb payload
    // through the calibrated RD/Rabenseifner collective formulas.
    {
        let n = (1u64 << 35) as f64;
        let m = Machine::cori_mpi();
        let theory = strong_scaling(&m, 1024.0, n, 4.0, 100.0, &pr, 2000);
        let measured = strong_scaling_wire(&m, Wire::Measured, 1024.0, n, 4.0, 100.0, &pr, 2000);
        println!("\n=== Figure 8a, measured wire (packed payload, RD/Rabenseifner) ===");
        println!(
            "{:>12} {:>14} {:>14} {:>8} {:>10}",
            "P", "T_BCD (s)", "T_CA-BCD (s)", "best s", "speedup"
        );
        for pt in &measured.points {
            println!(
                "{:>12} {:>14.6e} {:>14.6e} {:>8} {:>10.2}",
                pt.p, pt.t_classical, pt.t_ca, pt.best_s, pt.speedup
            );
        }
        let (mx, at_p, at_s) = measured.max_speedup();
        println!("→ max measured-wire speedup {mx:.1}× at P={at_p} (s={at_s})");
        // At this figure's b = 4 the calibration only tightens the model
        // (b(b+1)/2 + b = 14 ≤ 16 = b² per allreduce; b ≤ 2 would tip the
        // other way): the measured wire never charges the classical
        // algorithm more than the Theorem bound.
        for (t, ms) in theory.points.iter().zip(&measured.points) {
            assert!(
                ms.t_classical <= t.t_classical * (1.0 + 1e-12),
                "P={}: measured classical above Theorem bound",
                ms.p
            );
        }
        assert!(mx > 2.0, "measured wire should still reward CA: {mx:.2}×");
    }

    // Cross-check the model's L = (H/s)·log₂P latency charge against the
    // real communicator: with recursive doubling, one small-payload
    // allreduce costs exactly log₂P send rounds per active rank (the seed's
    // reduce-then-broadcast charged 2·log₂P).
    {
        use cabcd::comm::thread::{expected_allreduce_sends, run_spmd};
        use cabcd::comm::Communicator;
        for p in [4usize, 8, 16] {
            let meters = run_spmd(p, |_r, comm| {
                let mut buf = vec![1.0f64; 8];
                comm.allreduce_sum(&mut buf).unwrap();
                *comm.meter()
            });
            let logp = (p as f64).log2() as u64;
            for (rank, m) in meters.iter().enumerate() {
                let (msgs, _) = expected_allreduce_sends(p, rank, 8);
                assert_eq!(m.msgs, msgs, "P={p} rank={rank}: formula mismatch");
                assert_eq!(msgs, logp, "P={p}: RD rounds != log₂P");
            }
        }
        println!("\nmeasured allreduce rounds match the model's log₂P latency term");
    }
    println!(
        "\nheadlines: {} {:.0}× / {} {:.0}× (paper: 14× / 165×)",
        headlines[0].0, headlines[0].1, headlines[1].0, headlines[1].1
    );
    println!("fig8_strong_scaling: OK");
}
