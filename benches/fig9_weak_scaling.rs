//! Figure 9 — modeled weak scaling of BCD vs CA-BCD on NERSC Cori,
//! b = 4, d = 1024, n/P = 2¹¹ fixed, MPI (9a) and Spark (9b),
//! P = 2² … 2²⁸.
//!
//! Paper headline: 12× (MPI), 396× (Spark). Shape checks asserted:
//! the BCD-vs-CA gap opens as P grows (communication share rises) and the
//! CA curve stays much flatter than BCD.

use cabcd::costmodel::{
    scaling::{paper_p_range, weak_scaling, weak_scaling_wire},
    Machine, Wire,
};

fn main() {
    let pr = paper_p_range();
    let mut headlines = Vec::new();
    for (panel, m) in [("9a", Machine::cori_mpi()), ("9b", Machine::cori_spark())] {
        let series = weak_scaling(&m, 1024.0, 2048.0, 4.0, 100.0, &pr, 2000);
        println!(
            "\n=== Figure {panel}: {} weak scaling (d=1024, n/P=2^11, b=4) ===",
            m.name
        );
        println!(
            "{:>12} {:>14} {:>14} {:>8} {:>10}",
            "P", "T_BCD (s)", "T_CA-BCD (s)", "best s", "speedup"
        );
        for pt in &series.points {
            println!(
                "{:>12} {:>14.6e} {:>14.6e} {:>8} {:>10.2}",
                pt.p, pt.t_classical, pt.t_ca, pt.best_s, pt.speedup
            );
        }
        let (mx, at_p, at_s) = series.max_speedup();
        println!("→ max modeled speedup {mx:.1}× at P={at_p} (s={at_s})");
        headlines.push((m.name, mx));

        // Gap must widen monotonically-ish with P.
        let first = &series.points[0];
        let last = series.points.last().unwrap();
        assert!(last.speedup >= first.speedup);
        // Ideal weak scaling = flat time; CA must be closer to flat:
        let bcd_growth = last.t_classical / first.t_classical;
        let ca_growth = last.t_ca / first.t_ca;
        assert!(
            ca_growth < bcd_growth,
            "CA should weak-scale flatter: {ca_growth} vs {bcd_growth}"
        );
    }
    assert!(headlines[1].1 > headlines[0].1 * 4.0);

    // Measured-machine mode (ROADMAP cost-model calibration): regenerate
    // the MPI panel charging the packed sb(sb+1)/2+sb payload through the
    // calibrated RD/Rabenseifner formulas instead of O(b²s²·log P).
    {
        let m = Machine::cori_mpi();
        let theory = weak_scaling(&m, 1024.0, 2048.0, 4.0, 100.0, &pr, 2000);
        let measured =
            weak_scaling_wire(&m, Wire::Measured, 1024.0, 2048.0, 4.0, 100.0, &pr, 2000);
        let (mx, at_p, at_s) = measured.max_speedup();
        println!(
            "\nFigure 9a, measured wire: max speedup {mx:.1}× at P={at_p} (s={at_s})"
        );
        // b = 4 here, so the packed payload (14 words) stays under the
        // Theorems' b² = 16 words per allreduce and the calibration only
        // tightens the model (not true at b ≤ 2).
        for (t, ms) in theory.points.iter().zip(&measured.points) {
            assert!(
                ms.t_classical <= t.t_classical * (1.0 + 1e-12),
                "P={}: measured classical above the Theorem bound",
                ms.p
            );
        }
        assert!(mx > 2.0, "measured wire should still reward CA: {mx:.2}×");
    }

    println!(
        "\nheadlines: {} {:.0}× / {} {:.0}× (paper: 12× / 396×)",
        headlines[0].0, headlines[0].1, headlines[1].0, headlines[1].1
    );
    println!("fig9_weak_scaling: OK");
}
