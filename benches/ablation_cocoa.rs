//! Ablation — the paper's §1 contrast with CoCoA, measured:
//!
//! * **CA-BDCD** reduces synchronizations by s *provably*, with a
//!   P-invariant, classical-identical trajectory.
//! * **CoCoA** (local solves + γ=1/P averaging) also reduces
//!   synchronizations per coordinate update — but its trajectory depends on
//!   P and its effective progress per round is damped by the averaging.
//!
//! Both run on the abalone clone at equal *communication budgets*
//! (allreduce counts) and the table reports the accuracy each achieves.

use cabcd::comm::thread::run_spmd;
use cabcd::comm::SerialComm;
use cabcd::coordinator::{partition_dual, partition_primal};
use cabcd::gram::NativeBackend;
use cabcd::matrix::gen::{generate, scaled_specs};
use cabcd::solvers::{bdcd, cg, cocoa, SolverOpts};

fn main() {
    let spec = &scaled_specs(4)[0]; // abalone-s4
    let ds = generate(spec, 42).unwrap();
    let lam = spec.lambda();
    let (d, n) = (ds.d(), ds.n());
    println!("ablation: CA-BDCD vs CoCoA on {} (d={d}, n={n}, λ={lam:.2e})", ds.name);
    let mut comm = SerialComm::new();
    let reference = cg::compute_reference(&ds.x, &ds.y, n, lam, &mut comm).unwrap();
    let p = 4usize;

    println!(
        "\n{:<22} {:>10} {:>12} {:>12} {:>14}",
        "method", "allreduce", "|obj err|", "sol err", "P-invariant?"
    );

    // Communication budget: 50 allreduces.
    let budget = 50usize;

    // --- CA-BDCD: 50 outer iterations × s inner each -------------------
    for s in [1usize, 8] {
        let opts = SolverOpts::builder()
            .b(16)
            .s(s)
            .lam(lam)
            .iters(budget * s)
            .seed(7)
            .record_every(0)
            .track_gram_cond(false)
            .overlap(false)
            .build();
        let shards = partition_dual(&ds, p).unwrap();
        let rref = &reference;
        let opts2 = opts.clone();
        let outs = run_spmd(p, move |rank, comm| {
            let sh = &shards[rank];
            let mut be = NativeBackend::new();
            bdcd::run(
                &sh.a_loc, &sh.y, sh.d_global, sh.d_offset, &opts2, Some(rref), comm, &mut be,
            )
            .unwrap()
        });
        let h = &outs[0].history;
        println!(
            "{:<22} {:>10} {:>12.3e} {:>12.3e} {:>14}",
            format!("CA-BDCD (b'=16, s={s})"),
            h.meter.allreduces,
            h.final_obj_err(),
            h.final_sol_err(),
            "yes (tested)"
        );
    }

    // --- CoCoA at the same allreduce budget -----------------------------
    for local_iters in [16usize * 8, 2000] {
        let opts = cocoa::CocoaOpts {
            lam,
            rounds: budget,
            local_iters,
            seed: 7,
            record_every: 0,
            overlap: false,
        };
        let shards = partition_primal(&ds, p).unwrap();
        let rref = &reference;
        let opts2 = opts.clone();
        let outs = run_spmd(p, move |rank, comm| {
            let sh = &shards[rank];
            cocoa::run(&sh.a_loc, &sh.y_loc, sh.n_global, &opts2, Some(rref), comm).unwrap()
        });
        let h = &outs[0].history;
        println!(
            "{:<22} {:>10} {:>12.3e} {:>12.3e} {:>14}",
            format!("CoCoA (H_loc={local_iters})"),
            h.meter.allreduces,
            h.final_obj_err(),
            h.final_sol_err(),
            "NO (P-dep.)"
        );
    }

    println!(
        "\nBoth frameworks trade extra local work for fewer synchronizations \
         and on this small, well-conditioned clone both reach good accuracy \
         at the fixed 50-allreduce budget (CoCoA can even lead). The \
         paper's contrast (§1) is about the GUARANTEE, and it is what the \
         table's last column records: CA-BDCD's trajectory is provably \
         identical to classical BDCD and P-invariant (asserted by the \
         integration tests), while CoCoA's γ=1/P averaging changes the \
         convergence behaviour and its outcome moves with P \
         (cocoa_changes_convergence_with_rank_count_unlike_ca)."
    );
    println!("ablation_cocoa: OK");
}
