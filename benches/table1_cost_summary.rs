//! Table 1 + Table 2 — critical-path cost summary of BCD/BDCD vs the CA
//! variants and the survey methods (Krylov, TSQR), instantiated at several
//! concrete parameter points, plus a measured-vs-theory check: the
//! communicator's per-rank message counts for CA-BCD must equal the
//! recursive-doubling / Rabenseifner formula times the H/s collectives.

use cabcd::comm::cost::CostMeter;
use cabcd::comm::thread::{expected_allreduce_sends, run_spmd};
use cabcd::comm::Communicator;
use cabcd::coordinator::partition_primal;
use cabcd::costmodel::{AlgoCosts, CostParams, Method};
use cabcd::gram::NativeBackend;
use cabcd::matrix::gen::{generate, scaled_specs};
use cabcd::solvers::{bcd, SolverOpts};

fn print_table(label: &str, cp: &CostParams) {
    println!(
        "\n--- {label}: d={} n={} P={} b={} s={} H={} ---",
        cp.d, cp.n, cp.p, cp.b, cp.s, cp.h
    );
    println!(
        "{:<10} {:>13} {:>12} {:>13} {:>13}",
        "Algorithm", "Flops F", "Latency L", "Bandwidth W", "Memory M"
    );
    let rows: Vec<(&str, Method, f64)> = vec![
        ("BCD", Method::Bcd, 1.0),
        ("CA-BCD", Method::CaBcd, cp.s),
        ("BDCD", Method::Bdcd, 1.0),
        ("CA-BDCD", Method::CaBdcd, cp.s),
        ("Krylov", Method::Krylov, 1.0),
        ("TSQR", Method::Tsqr, 1.0),
    ];
    for (name, method, s_eff) in rows {
        let mut c = *cp;
        c.s = s_eff;
        let costs = AlgoCosts::of(method, &c);
        println!(
            "{:<10} {:>13.4e} {:>12.4e} {:>13.4e} {:>13.4e}",
            name, costs.flops, costs.latency, costs.bandwidth, costs.memory
        );
    }
}

fn main() {
    println!("=== Table 1 / Table 2 reproduction (cost formulas, Thms 1–9) ===");
    // The paper's Table-3 shapes at representative (P, b, s, H).
    print_table(
        "news20-shaped",
        &CostParams {
            d: 62061.0,
            n: 15935.0,
            p: 1024.0,
            b: 64.0,
            s: 8.0,
            h: 1000.0,
        },
    );
    print_table(
        "abalone-shaped",
        &CostParams {
            d: 8.0,
            n: 4177.0,
            p: 64.0,
            b: 4.0,
            s: 8.0,
            h: 1000.0,
        },
    );
    print_table(
        "modeled-scaling point (Fig 8 regime)",
        &CostParams {
            d: 1024.0,
            n: (1u64 << 35) as f64,
            p: (1u64 << 20) as f64,
            b: 4.0,
            s: 40.0,
            h: 100.0,
        },
    );

    // Headline ratios of Table 1, asserted.
    let base = CostParams {
        d: 4096.0,
        n: 1e6,
        p: 256.0,
        b: 8.0,
        s: 1.0,
        h: 960.0,
    };
    let mut ca = base;
    ca.s = 16.0;
    let c0 = AlgoCosts::of(Method::Bcd, &base);
    let c1 = AlgoCosts::of(Method::CaBcd, &ca);
    println!("\nTable-1 ratios at s=16: latency ÷{} bandwidth ×{} memory(extra) ×{}",
        c0.latency / c1.latency,
        c1.bandwidth / c0.bandwidth,
        (c1.memory - base.d * base.n / base.p) / (c0.memory - base.d * base.n / base.p),
    );
    assert_eq!(c0.latency / c1.latency, 16.0);
    assert_eq!(c1.bandwidth / c0.bandwidth, 16.0);

    // Measured message counts vs the L column, on the real communicator.
    println!("\n--- measured vs theory: CA-BCD allreduce rounds (P=8) ---");
    let spec = &scaled_specs(8)[0];
    let ds = generate(spec, 1).unwrap();
    println!("{:>4} {:>12} {:>18} {:>18}", "s", "outer iters", "measured msgs", "formula msgs");
    for s in [1usize, 2, 4, 8] {
        let opts = SolverOpts::builder()
            .b(2)
            .s(s)
            .lam(spec.lambda())
            .iters(64)
            .seed(3)
            .record_every(0)
            .track_gram_cond(false)
            .overlap(false)
            .build();
        let shards = partition_primal(&ds, 8).unwrap();
        let meters: Vec<CostMeter> = run_spmd(8, |rank, comm| {
            let mut be = NativeBackend::new();
            let sh = &shards[rank];
            bcd::run(&sh.a_loc, &sh.y_loc, sh.n_global, &opts, None, comm, &mut be).unwrap();
            *comm.meter()
        });
        let (msgs, _) = CostMeter::critical_path(&meters);
        // Exact per-allreduce accounting: sends from the RD/Rabenseifner
        // formula (the packed [G|r] payload sb(sb+1)/2 + sb selects the
        // algorithm), plus the equal number of receives, times H/s
        // collectives.
        let sb = 2 * s;
        let payload = sb * (sb + 1) / 2 + sb;
        let (sends, _) = expected_allreduce_sends(8, 0, payload);
        let expect = 2 * sends * (64 / s) as u64;
        println!("{:>4} {:>12} {:>18} {:>18}", s, 64 / s, msgs, expect);
        assert_eq!(msgs, expect, "s={s}");
    }
    println!("\ntable1_cost_summary: OK");
}
