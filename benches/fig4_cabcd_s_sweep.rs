//! Figure 4 — CA-BCD vs BCD across the loop-blocking factor s on the four
//! Table-3 clones: convergence must MATCH the classical algorithm for
//! every s (4a–h), while the Gram condition number grows mildly with s
//! (4i–l). Block sizes per the paper: abalone b=4, news20 b=64, a9a b=16,
//! real-sim b=32 (clipped to the scaled d).

use cabcd::comm::SerialComm;
use cabcd::gram::NativeBackend;
use cabcd::matrix::gen::{generate, scaled_specs};
use cabcd::solvers::{bcd, cg, SolverOpts};

fn main() {
    // (clone, scale, paper b, s list, iters)
    let plan: Vec<(&str, usize, usize, Vec<usize>, usize)> = vec![
        ("abalone", 2, 4, vec![1, 5, 20, 100], 2000),
        ("news20", 32, 64, vec![1, 5, 20, 50], 2000),
        ("a9a", 4, 16, vec![1, 5, 20, 50], 2000),
        ("real-sim", 32, 32, vec![1, 5, 20, 50], 2000),
    ];
    for (name, factor, b, svals, iters) in plan {
        let spec = scaled_specs(factor)
            .into_iter()
            .find(|s| s.name.starts_with(name))
            .unwrap();
        let ds = generate(&spec, 42).unwrap();
        let (d, n) = (ds.d(), ds.n());
        let b = b.min(d / 2).max(1);
        let lam = spec.lambda();
        println!(
            "\n=== {} (scale 1/{factor}): d={d}, n={n}, b={b}, λ={lam:.2e} ===",
            spec.name
        );
        let mut comm = SerialComm::new();
        let reference = cg::compute_reference(&ds.x, &ds.y, n, lam, &mut comm).unwrap();

        println!(
            "{:>5} {:>12} {:>12} {:>10} {:>26} {:>12}",
            "s", "|obj err|", "sol err", "allreduce", "cond(G) min/med/max", "vs s=1 max|Δw|"
        );
        let mut w_base: Option<Vec<f64>> = None;
        for s in svals {
            let opts = SolverOpts::builder()
                .b(b)
                .s(s)
                .lam(lam)
                .iters(iters)
                .seed(9)
                .record_every(0)
                .track_gram_cond(true)
                .overlap(false)
                .build();
            let mut be = NativeBackend::new();
            let mut c = SerialComm::new();
            let out = bcd::run(&ds.x, &ds.y, n, &opts, Some(&reference), &mut c, &mut be)
                .unwrap();
            let cs = out.history.cond_stats();
            let dev = match &w_base {
                None => {
                    w_base = Some(out.w.clone());
                    0.0
                }
                Some(w0) => out
                    .w
                    .iter()
                    .zip(w0)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f64, f64::max),
            };
            println!(
                "{:>5} {:>12.3e} {:>12.3e} {:>10} {:>8.1}/{:>7.1}/{:>7.1} {:>12.2e}",
                s,
                out.history.final_obj_err(),
                out.history.final_sol_err(),
                out.history.meter.allreduces,
                cs.min,
                cs.median,
                cs.max,
                dev
            );
            // Stability claim: trajectory matches classical to fp noise.
            assert!(
                dev < 1e-6,
                "{name}: s={s} deviated from classical by {dev}"
            );
        }
    }
    println!("\nfig4_cabcd_s_sweep: OK — CA-BCD ≡ BCD for every s tested");
}
