//! Figures 5 and 6 — BDCD block-size sweeps on the four Table-3 clones:
//! the dual counterpart of Figures 2/3. Convergence per b' (Fig 5) and
//! theoretical cost axes (Fig 6, using Theorem 2's d-contracted terms).

use cabcd::comm::SerialComm;
use cabcd::costmodel::{AlgoCosts, CostParams, Method};
use cabcd::gram::NativeBackend;
use cabcd::matrix::gen::{generate, scaled_specs};
use cabcd::solvers::{bdcd, cg, SolverOpts};

fn main() {
    let plan: Vec<(&str, usize, Vec<usize>, usize)> = vec![
        ("abalone", 2, vec![1, 4, 16, 32], 4000),
        ("news20", 32, vec![1, 8, 16, 64], 4000),
        ("a9a", 4, vec![1, 8, 32, 128], 4000),
        ("real-sim", 32, vec![1, 8, 32, 128], 4000),
    ];
    for (name, factor, bs, iters) in plan {
        let spec = scaled_specs(factor)
            .into_iter()
            .find(|s| s.name.starts_with(name))
            .unwrap();
        let ds = generate(&spec, 42).unwrap();
        let (d, n) = (ds.d(), ds.n());
        let lam = spec.lambda();
        println!("\n=== {} (scale 1/{factor}): d={d}, n={n}, λ={lam:.2e} ===", spec.name);
        let mut comm = SerialComm::new();
        let reference = cg::compute_reference(&ds.x, &ds.y, n, lam, &mut comm).unwrap();
        let a = ds.x.transpose();

        println!(
            "{:>4} {:>12} {:>12} | {:>12} {:>12} {:>10}  (Fig 6 axes @ final err)",
            "b'", "|obj err|", "sol err", "flops", "words", "msgs"
        );
        for b in bs {
            let b = b.min(n / 2).max(1);
            let opts = SolverOpts::builder()
                .b(b)
                .s(1)
                .lam(lam)
                .iters(iters)
                .seed(5)
                .record_every(iters / 8)
                .track_gram_cond(false)
                .overlap(false)
                .build();
            let mut be = NativeBackend::new();
            let out = bdcd::run(&a, &ds.y, d, 0, &opts, Some(&reference), &mut comm, &mut be)
                .unwrap();
            let cp = CostParams {
                d: d as f64,
                n: n as f64,
                p: 1.0,
                b: b as f64,
                s: 1.0,
                h: out.history.iters as f64,
            };
            let c = AlgoCosts::of(Method::Bdcd, &cp);
            println!(
                "{:>4} {:>12.3e} {:>12.3e} | {:>12.3e} {:>12.3e} {:>10.1e}",
                b,
                out.history.final_obj_err(),
                out.history.final_sol_err(),
                c.flops,
                c.bandwidth,
                c.latency
            );
            print!("     curve(|obj|):");
            for r in &out.history.records {
                print!(" ({},{:.1e})", r.iter, r.obj_err.abs());
            }
            println!();
        }
    }
    println!("\nfig5_6_bdcd_blocksize: OK");
}
