//! Figure 1 — objective-error convergence of BCD, BDCD, CG and TSQR
//! against their theoretical algorithm costs (flops, bandwidth, messages)
//! on the news20-shaped dataset (d > n), accuracy target 1e-2, b = b' = 4.
//!
//! The paper runs the real d=62061 × n=15935 matrix; we run a 16×-scaled
//! clone with the same shape/density/spectrum targets (the cost axes are
//! evaluated from the Theorem formulas at the clone's own dimensions, so
//! the *relative* positions of the curves — who is cheapest per digit on
//! which axis — reproduce). TSQR's single-pass behaviour is exact.

use cabcd::comm::SerialComm;
use cabcd::costmodel::{AlgoCosts, CostParams, Method};
use cabcd::gram::NativeBackend;
use cabcd::matrix::gen::{generate, scaled_specs};
use cabcd::metrics::History;
use cabcd::solvers::{bcd, bdcd, cg, tsqr_ls, SolverOpts};

struct Series {
    name: &'static str,
    method: Method,
    b: f64,
    /// (iterations h, |objective error|)
    points: Vec<(f64, f64)>,
}

fn from_history(name: &'static str, method: Method, b: f64, h: &History) -> Series {
    Series {
        name,
        method,
        b,
        points: h
            .records
            .iter()
            .map(|r| (r.iter as f64, r.obj_err.abs().max(1e-17)))
            .collect(),
    }
}

fn main() {
    let spec = scaled_specs(16)
        .into_iter()
        .find(|s| s.name.starts_with("news20"))
        .unwrap();
    let ds = generate(&spec, 42).unwrap();
    let (d, n) = (ds.d(), ds.n());
    let lam = spec.lambda();
    let tol = 1e-2;
    println!(
        "Figure 1 — method comparison on {} (d={d}, n={n}, λ={lam:.2e}, target {tol:.0e})",
        ds.name
    );

    let mut comm = SerialComm::new();
    let reference = cg::compute_reference(&ds.x, &ds.y, n, lam, &mut comm).unwrap();
    let mut be = NativeBackend::new();

    // --- BCD, b=4 ---
    let opts = SolverOpts::builder()
        .b(4)
        .s(1)
        .lam(lam)
        .iters(40_000)
        .seed(1)
        .record_every(500)
        .track_gram_cond(false)
        .tol(tol)
        .overlap(false)
        .build();
    let p = bcd::run(&ds.x, &ds.y, n, &opts, Some(&reference), &mut comm, &mut be).unwrap();
    let s_bcd = from_history("BCD", Method::Bcd, 4.0, &p.history);

    // --- BDCD, b'=4 ---
    let a = ds.x.transpose();
    let du = bdcd::run(&a, &ds.y, d, 0, &opts, Some(&reference), &mut comm, &mut be).unwrap();
    let s_bdcd = from_history("BDCD", Method::Bdcd, 4.0, &du.history);

    // --- CG ---
    let cg_out = cg::run(
        &ds.x,
        &ds.y,
        n,
        &cg::CgOpts {
            lam,
            max_iters: 2000,
            tol: 1e-14,
            record_every: 5,
        },
        Some(&reference),
        &mut comm,
    )
    .unwrap();
    let s_cg = from_history("CG", Method::Krylov, 1.0, &cg_out.history);

    // --- TSQR (single pass; machine precision afterwards) ---
    let ts = tsqr_ls::run(&ds.x, &ds.y, lam, 64, Some(&reference)).unwrap();
    let s_tsqr = from_history("TSQR", Method::Tsqr, 1.0, &ts.history);

    // Print the three panels: error vs flops / bandwidth / messages.
    for (panel, axis) in [
        ("1a: flops", 0usize),
        ("1b: bandwidth (words)", 1),
        ("1c: messages", 2),
    ] {
        println!("\n--- Figure {panel} ---");
        println!("{:<6} {:>14} {:>14}", "method", "cost@target", "final err");
        for s in [&s_bcd, &s_bdcd, &s_cg, &s_tsqr] {
            // Cost of h iterations from the Theorem formulas (sequential
            // flops, log P dropped — paper §5.1 protocol).
            let cost_at = |h: f64| {
                let cp = CostParams {
                    d: d as f64,
                    n: n as f64,
                    p: 1.0,
                    b: s.b,
                    s: 1.0,
                    h: h.max(1.0),
                };
                let c = AlgoCosts::of(s.method, &cp);
                match axis {
                    0 => c.flops,
                    1 => c.bandwidth,
                    _ => c.latency,
                }
            };
            // First point reaching the target (or the last point).
            let hit = s
                .points
                .iter()
                .find(|(_, e)| *e <= tol)
                .or(s.points.last())
                .unwrap();
            println!(
                "{:<6} {:>14.4e} {:>14.3e}",
                s.name,
                cost_at(hit.0),
                s.points.last().unwrap().1
            );
            // Full curve for plotting.
            print!("  curve:");
            for (h, e) in s.points.iter().take(12) {
                print!(" ({:.3e},{:.1e})", cost_at(*h), e);
            }
            println!();
        }
    }

    // The paper's qualitative ordering on the latency axis: TSQR needs one
    // reduction; CG needs k; BCD/BDCD need orders of magnitude more.
    let msgs = |s: &Series| {
        let hit = s.points.iter().find(|(_, e)| *e <= tol).or(s.points.last()).unwrap();
        let cp = CostParams {
            d: d as f64,
            n: n as f64,
            p: 1.0,
            b: s.b,
            s: 1.0,
            h: hit.0.max(1.0),
        };
        AlgoCosts::of(s.method, &cp).latency
    };
    assert!(msgs(&s_tsqr) <= msgs(&s_cg));
    assert!(msgs(&s_cg) < msgs(&s_bcd));
    println!("\nordering on messages axis: TSQR ≤ CG < BCD — matches Figure 1c");
    println!("fig1_method_comparison: OK");
}
