//! Figure 7 — CA-BDCD vs BDCD across s on the four Table-3 clones: the
//! dual counterpart of Figure 4. Convergence must match BDCD for every s
//! (7a–h); the Θ-scaled Gram condition numbers stay benign (7i–l). Paper
//! block sizes: abalone b'=32, news20 b'=64, a9a b'=32, real-sim b'=32.

use cabcd::comm::SerialComm;
use cabcd::gram::NativeBackend;
use cabcd::matrix::gen::{generate, scaled_specs};
use cabcd::solvers::{bdcd, cg, SolverOpts};

fn main() {
    let plan: Vec<(&str, usize, usize, Vec<usize>, usize)> = vec![
        ("abalone", 2, 32, vec![1, 5, 20, 100], 2000),
        ("news20", 32, 64, vec![1, 5, 20, 50], 2000),
        ("a9a", 4, 32, vec![1, 5, 20, 50], 2000),
        ("real-sim", 32, 32, vec![1, 5, 20, 50], 2000),
    ];
    for (name, factor, b, svals, iters) in plan {
        let spec = scaled_specs(factor)
            .into_iter()
            .find(|s| s.name.starts_with(name))
            .unwrap();
        let ds = generate(&spec, 42).unwrap();
        let (d, n) = (ds.d(), ds.n());
        let b = b.min(n / 4).max(1);
        let lam = spec.lambda();
        println!(
            "\n=== {} (scale 1/{factor}): d={d}, n={n}, b'={b}, λ={lam:.2e} ===",
            spec.name
        );
        let mut comm = SerialComm::new();
        let reference = cg::compute_reference(&ds.x, &ds.y, n, lam, &mut comm).unwrap();
        let a = ds.x.transpose();

        println!(
            "{:>5} {:>12} {:>12} {:>10} {:>30} {:>12}",
            "s", "|obj err|", "sol err", "allreduce", "cond(Θ-Gram) min/med/max", "vs s=1 max|Δw|"
        );
        let mut w_base: Option<Vec<f64>> = None;
        for s in svals {
            let opts = SolverOpts::builder()
                .b(b)
                .s(s)
                .lam(lam)
                .iters(iters)
                .seed(9)
                .record_every(0)
                .track_gram_cond(true)
                .overlap(false)
                .build();
            let mut be = NativeBackend::new();
            let mut c = SerialComm::new();
            let out = bdcd::run(&a, &ds.y, d, 0, &opts, Some(&reference), &mut c, &mut be)
                .unwrap();
            let cs = out.history.cond_stats();
            let dev = match &w_base {
                None => {
                    w_base = Some(out.w_full.clone());
                    0.0
                }
                Some(w0) => out
                    .w_full
                    .iter()
                    .zip(w0)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f64, f64::max),
            };
            println!(
                "{:>5} {:>12.3e} {:>12.3e} {:>10} {:>10.2}/{:>8.2}/{:>8.2} {:>12.2e}",
                s,
                out.history.final_obj_err(),
                out.history.final_sol_err(),
                out.history.meter.allreduces,
                cs.min,
                cs.median,
                cs.max,
                dev
            );
            assert!(
                dev < 1e-6,
                "{name}: dual s={s} deviated from classical by {dev}"
            );
        }
    }
    println!("\nfig7_cabdcd_s_sweep: OK — CA-BDCD ≡ BDCD for every s tested");
}
