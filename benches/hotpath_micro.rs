//! Hot-path micro-benchmarks — the §Perf instrument (EXPERIMENTS.md).
//!
//! Times the per-iteration kernels of every solver (packed Gram +
//! residual, s-step inner solve), the Gustavson-vs-merge CSR Gram duel,
//! the collectives on the packed `[G|r]` payload, the end-to-end outer
//! iteration, and — when artifacts are present — the XLA backend's
//! per-call latency.
//!
//! Two modes:
//! * full (default) — the complete sweep, including the PR-2 allreduce
//!   ≥2×-vs-seed assertion at P=8.
//! * `--quick` — the deterministic CI subset: small shapes, few
//!   repetitions, no cross-process timing assertions except the
//!   machine-independent Gustavson-vs-merge ≥2× floor (same-process,
//!   same-thread kernel duel — stable on shared runners).
//!
//! Both modes write `BENCH_hotpath.json` (allreduce words/rank, Gram
//! kernel timings, packed-vs-full payload ratio) so future PRs have a
//! perf baseline to diff against. In `--quick` mode, before overwriting,
//! the machine-independent **wire/word-count fields of the committed
//! seed are re-checked**: a current value more than 25% above the seed's
//! fails the bench (and therefore CI) — a payload-format regression
//! cannot land silently.

use std::path::Path;

use cabcd::comm::thread::{expected_allreduce_sends, run_spmd};
use cabcd::comm::{expected_two_level_allreduce_sends, Communicator, Topology};
use cabcd::costmodel::theory::two_level_allreduce_cost;
use cabcd::gram::{ComputeBackend, NativeBackend};
use cabcd::linalg::packed::packed_len;
use cabcd::matrix::{CsrMatrix, DenseMatrix, Matrix};
use cabcd::runtime::XlaBackend;
use cabcd::sampling::{overlap_tensor, BlockSampler};
use cabcd::util::bench::{fmt_secs, time_runs};
use cabcd::util::{json, Rng64};

fn dense_mat(d: usize, n: usize, seed: u64) -> DenseMatrix {
    let mut rng = Rng64::seed_from_u64(seed);
    let data: Vec<f64> = (0..d * n).map(|_| rng.gen_normal()).collect();
    DenseMatrix::from_vec(d, n, data)
}

fn sparse_mat(d: usize, n: usize, density: f64, seed: u64) -> CsrMatrix {
    let mut rng = Rng64::seed_from_u64(seed);
    let total = ((d * n) as f64 * density) as usize;
    let trip: Vec<(usize, usize, f64)> = (0..total)
        .map(|_| (rng.gen_range(0, d), rng.gen_range(0, n), rng.gen_normal()))
        .collect();
    CsrMatrix::from_triplets(d, n, trip)
}

/// Minimal numeric-field extraction from the committed seed JSON (the
/// crate is serde-free offline; the seed format is flat `"key": number`).
fn json_num_field(text: &str, key: &str) -> Option<f64> {
    let needle = format!("\"{key}\":");
    let at = text.find(&needle)? + needle.len();
    let rest = text[at..].trim_start();
    let end = rest
        .find(|c: char| c == ',' || c == '}')
        .unwrap_or(rest.len());
    rest[..end].trim().parse().ok()
}

/// CI regression gate: compare the current run's machine-independent
/// wire/word-count metrics against the committed `BENCH_hotpath.json`
/// seed and fail on >25% growth (timing fields are machine-dependent and
/// deliberately not gated).
fn check_against_seed(seed_text: &str, current: &[(&str, f64)]) {
    const WIRE_FIELDS: &[&str] = &[
        "allreduce_payload_words_packed",
        "allreduce_words_per_rank_p8_packed",
        "hier_allreduce_msgs_leader_p8_ns4",
        "hier_allreduce_words_leader_p8_ns4",
        "hier_allreduce_msgs_member_p8_ns4",
        "prox_overlap_allreduces_per_outer",
        "trace_allocs_steady_state",
        "trace_spans_per_outer",
        "comm_retries_fault_free",
        "comm_timeouts_fault_free",
        "checkpoint_state_words_bcd",
        "telemetry_allocs_steady_state",
        "telemetry_snapshot_words",
    ];
    for &key in WIRE_FIELDS {
        let Some(seed_val) = json_num_field(seed_text, key) else {
            println!("  seed check: field {key} missing from seed, skipping");
            continue;
        };
        let Some(&(_, cur)) = current.iter().find(|(k, _)| *k == key) else {
            panic!("seed check: current run never measured {key}");
        };
        let limit = seed_val * 1.25;
        println!("  seed check: {key} = {cur} (seed {seed_val}, limit {limit:.0})");
        assert!(
            cur <= limit,
            "wire regression: {key} = {cur} exceeds 1.25× the committed seed \
             ({seed_val}) — the packed [G|r] payload grew"
        );
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (warm, runs) = if quick { (1usize, 5usize) } else { (3, 15) };
    println!(
        "=== hot-path micro benchmarks (native backend{}) ===",
        if quick { ", quick mode" } else { "" }
    );
    let mut be = NativeBackend::new();
    let mut report: Vec<(&str, String)> = Vec::new();
    report.push(("mode", json::string(if quick { "quick" } else { "full" })));
    // Machine-independent wire metrics, gated against the committed seed.
    let mut wire_metrics: Vec<(&str, f64)> = Vec::new();

    // --- packed gram_resid over dense operands -------------------------
    let n_loc = if quick { 2048 } else { 8192 };
    println!("\ngram_resid (dense, packed [G|r]), n_loc={n_loc}:");
    println!("{:>6} {:>14} {:>16} {:>14}", "sb", "median", "per inner-iter*", "GF/s");
    let dense_sbs: &[usize] = if quick { &[8, 32] } else { &[8, 16, 32, 64] };
    for &sb in dense_sbs {
        let a = Matrix::Dense(dense_mat(128, n_loc, 1));
        let mut sampler = BlockSampler::new(128, 7);
        let idx = sampler.draw_block(sb);
        let z: Vec<f64> = (0..n_loc).map(|i| (i as f64).sin()).collect();
        let mut g = vec![0.0; packed_len(sb)];
        let mut r = vec![0.0; sb];
        let (med, _, _) = time_runs(warm, runs, || {
            be.gram_resid(&a, &idx, &z, &mut g, &mut r).unwrap();
            g[0]
        });
        // syrk touches each symmetric pair once + matvec.
        let flops = (sb * (sb + 1) + 2 * sb) as f64 * n_loc as f64;
        println!(
            "{:>6} {:>14} {:>16} {:>14.2}",
            sb,
            fmt_secs(med),
            fmt_secs(med / sb as f64),
            flops / med / 1e9
        );
        if sb == 32 {
            report.push(("gram_dense_sb32_ns", json::num(med * 1e9)));
        }
    }

    // --- CSR Gram: Gustavson vs the merge-based kernel ------------------
    // The acceptance shape: sb=64 at 1% density (news20-like panels are
    // sparser still; 1% is the conservative end for the Gustavson win).
    {
        let (d, n) = (4096usize, 16384usize);
        println!("\nCSR sampled_gram at d={d}, n_loc={n}, 1% density, sb=64:");
        let csr = sparse_mat(d, n, 0.01, 2);
        let nnz_row = csr.nnz() as f64 / d as f64;
        let mut sampler = BlockSampler::new(d, 7);
        let idx = sampler.draw_block(64);
        let mut g_fast = vec![0.0; packed_len(64)];
        let mut g_slow = vec![0.0; packed_len(64)];
        let (t_fast, _, _) = time_runs(warm, runs, || {
            csr.sampled_gram_packed(&idx, &mut g_fast);
            g_fast[0]
        });
        let (t_slow, _, _) = time_runs(warm, runs, || {
            csr.sampled_gram_merge_packed(&idx, &mut g_slow);
            g_slow[0]
        });
        assert!(g_fast == g_slow, "Gustavson and merge kernels disagree");
        let speedup = t_slow / t_fast;
        println!(
            "  gustavson {}   merge {}   speedup {speedup:.2}×  (~{nnz_row:.0} nnz/row)",
            fmt_secs(t_fast),
            fmt_secs(t_slow)
        );
        // Same-process kernel duel — stable enough to assert in CI too.
        assert!(
            speedup >= 2.0,
            "Gustavson CSR sampled_gram only {speedup:.2}× over the merge kernel \
             at sb=64, 1% density (want ≥2×)"
        );
        report.push(("gram_csr_merge_sb64_ns", json::num(t_slow * 1e9)));
        report.push(("gram_csr_gustavson_sb64_ns", json::num(t_fast * 1e9)));
        report.push(("gustavson_speedup", json::num(speedup)));
    }

    // --- inner solve (packed G) ----------------------------------------
    println!("\nca_inner_solve:");
    println!("{:>10} {:>14}", "(s, b)", "median");
    let solve_shapes: &[(usize, usize)] = if quick {
        &[(4usize, 8usize), (8, 8)]
    } else {
        &[(1, 8), (4, 8), (8, 8), (16, 8), (8, 16)]
    };
    for &(s, b) in solve_shapes {
        let sb = s * b;
        let m = dense_mat(sb, sb + 32, 3);
        let mut g_raw = vec![0.0; packed_len(sb)];
        let idx: Vec<usize> = (0..sb).collect();
        m.sampled_gram_packed(&idx, &mut g_raw);
        let mut rng = Rng64::seed_from_u64(4);
        let r_raw: Vec<f64> = (0..sb).map(|_| rng.gen_normal()).collect();
        let w_blk: Vec<f64> = (0..sb).map(|_| rng.gen_normal()).collect();
        let blocks: Vec<Vec<usize>> = (0..s)
            .map(|j| (0..b).map(|i| (j * b + i) % (sb / 2 + 1)).collect())
            .collect();
        let ov = overlap_tensor(&blocks);
        let (med, _, _) = time_runs(warm, runs, || {
            be.ca_inner_solve(s, b, &g_raw, &r_raw, &w_blk, &ov, 0.5, 1e-3)
                .unwrap()
        });
        println!("{:>10} {:>14}", format!("({s},{b})"), fmt_secs(med));
    }

    // --- collectives: packed [G|r] payload ------------------------------
    // Wire accounting first (machine-independent): packed vs full volume.
    {
        let sb = 64usize;
        let packed = packed_len(sb) + sb;
        let full = sb * sb + sb;
        let (_, w_packed) = expected_allreduce_sends(8, 0, packed);
        let (_, w_full) = expected_allreduce_sends(8, 0, full);
        let ratio = w_packed as f64 / w_full as f64;
        println!(
            "\npacked [G|r] payload at sb=64: {packed} words (full: {full}) — \
             P=8 Rabenseifner sends {w_packed} vs {w_full} words/rank ({ratio:.3}×)"
        );
        assert_eq!(packed, sb * (sb + 1) / 2 + sb);
        assert!(
            ratio < 0.55,
            "packing should roughly halve the wire volume, got {ratio:.3}"
        );
        report.push(("allreduce_payload_words_packed", json::num(packed as f64)));
        report.push(("allreduce_payload_words_full", json::num(full as f64)));
        report.push(("allreduce_words_per_rank_p8_packed", json::num(w_packed as f64)));
        report.push(("allreduce_words_per_rank_p8_full", json::num(w_full as f64)));
        report.push(("packed_vs_full_payload_ratio", json::num(ratio)));
        wire_metrics.push(("allreduce_payload_words_packed", packed as f64));
        wire_metrics.push(("allreduce_words_per_rank_p8_packed", w_packed as f64));
    }

    // --- hierarchical two-level allreduce wire accounting ---------------
    // Same packed [G|r] payload, P=8 split into two 4-rank nodes: members
    // hand their payload to the node leader, the two leaders run the flat
    // exchange, the result fans back out. Three independent accounts of
    // the per-rank send volume must agree exactly — the communicator's
    // integer closed form, the cost model's continuous closed form, and
    // the live wire meter of an actual two-level allreduce.
    {
        let sb = 64usize;
        let len = packed_len(sb) + sb;
        let (p, ns) = (8usize, 4usize);
        let (lm, lw) = expected_two_level_allreduce_sends(p, ns, 0, len);
        let (mm, mw) = expected_two_level_allreduce_sends(p, ns, 1, len);
        let ((clm, clw), (cmm, cmw)) =
            two_level_allreduce_cost(p as f64, ns as f64, len as f64);
        assert_eq!(
            (clm, clw),
            (lm as f64, lw as f64),
            "leader: cost model disagrees with the communicator closed form"
        );
        assert_eq!(
            (cmm, cmw),
            (mm as f64, mw as f64),
            "member: cost model disagrees with the communicator closed form"
        );
        let metered = run_spmd(p, |rank, comm| {
            comm.set_topology(Topology::TwoLevel { node_size: ns });
            let mut buf: Vec<f64> = (0..len).map(|i| (rank * len + i) as f64).collect();
            comm.allreduce_sum(&mut buf).expect("two-level allreduce");
            (comm.meter().msgs, comm.meter().words)
        });
        for (rank, &(msgs, words)) in metered.iter().enumerate() {
            let expect = expected_two_level_allreduce_sends(p, ns, rank, len);
            assert_eq!(
                (msgs, words),
                expect,
                "rank {rank}: measured two-level sends diverge from the closed form"
            );
        }
        let (fm, fw) = expected_allreduce_sends(p, 0, len);
        println!(
            "two-level allreduce at P={p}, node_size={ns}, {len} words: leader {lm} msgs / \
             {lw} words, member {mm} msgs / {mw} words (flat rank 0: {fm} msgs / {fw} words)"
        );
        report.push(("hier_allreduce_msgs_leader_p8_ns4", json::num(lm as f64)));
        report.push(("hier_allreduce_words_leader_p8_ns4", json::num(lw as f64)));
        report.push(("hier_allreduce_msgs_member_p8_ns4", json::num(mm as f64)));
        report.push(("hier_allreduce_words_member_p8_ns4", json::num(mw as f64)));
        wire_metrics.push(("hier_allreduce_msgs_leader_p8_ns4", lm as f64));
        wire_metrics.push(("hier_allreduce_words_leader_p8_ns4", lw as f64));
        wire_metrics.push(("hier_allreduce_msgs_member_p8_ns4", mm as f64));
    }

    // --- prox inner solve (same packed [G|r] inputs, soft-threshold path)
    {
        use cabcd::prox::Reg;
        let (s, b) = (4usize, 8usize);
        let sb = s * b;
        let m = dense_mat(sb, sb + 32, 5);
        let mut g_raw = vec![0.0; packed_len(sb)];
        let idx: Vec<usize> = (0..sb).collect();
        m.sampled_gram_packed(&idx, &mut g_raw);
        let mut rng = Rng64::seed_from_u64(6);
        let r_raw: Vec<f64> = (0..sb).map(|_| rng.gen_normal()).collect();
        let w_blk: Vec<f64> = (0..sb).map(|_| rng.gen_normal()).collect();
        let blocks: Vec<Vec<usize>> = (0..s)
            .map(|j| (0..b).map(|i| (j * b + i) % (sb / 2 + 1)).collect())
            .collect();
        let ov = overlap_tensor(&blocks);
        let reg = Reg::L1;
        let (med, _, _) = time_runs(warm, runs, || {
            be.ca_prox_inner_solve(s, b, &g_raw, &r_raw, &w_blk, &ov, 0.5, 1e-3, &reg)
                .unwrap()
        });
        println!("\nca_prox_inner_solve (s=4, b=8, l1): {}", fmt_secs(med));
        report.push(("prox_inner_solve_s4_b8_ns", json::num(med * 1e9)));
    }

    // --- CA-Prox-BCD overlap pipeline (engine prefetch schedule) --------
    // The engine port gave the prox loops the smooth solvers' Gram
    // prefetch: with `overlap`, the next iteration's packed Gram computes
    // under the in-flight [G|r] reduction. Timing rows land in
    // BENCH_hotpath.json; the machine-independent collective count (still
    // exactly one allreduce per outer iteration — the pipeline must not
    // add collectives) is gated against the committed seed.
    {
        use cabcd::coordinator::partition_primal;
        use cabcd::matrix::io::Dataset;
        use cabcd::prox::Reg;
        use cabcd::solvers::{bcd, SolverOpts};

        let (d, n) = if quick { (96usize, 4096usize) } else { (192, 16384) };
        let x = Matrix::Dense(dense_mat(d, n, 21));
        let mut y = vec![0.0; n];
        x.matvec_t(&vec![1.0; d], &mut y).unwrap();
        let ds = Dataset {
            name: "prox-bench".into(),
            x,
            y,
        };
        let p = 2usize;
        let shards = partition_primal(&ds, p).unwrap();
        let s = 4usize;
        let outer = if quick { 4usize } else { 8 };
        println!("\nCA-Prox-BCD (l1) outer iteration at P={p} (d={d}, n={n}, b=8, s={s}):");
        let mut medians = Vec::new();
        let mut overlap_allreduces = 0u64;
        let (mut ff_retries, mut ff_timeouts) = (0u64, 0u64);
        for overlap in [false, true] {
            let opts = SolverOpts::builder()
                .b(8)
                .s(s)
                .lam(0.1)
                .iters(outer * s)
                .seed(5)
                .record_every(0)
                .overlap(overlap)
                .reg(Reg::L1)
                .build();
            let shards_ref = &shards;
            let optsr = &opts;
            // Wire accounting (one un-timed run): the prefetch pipeline
            // must keep exactly H/s collectives, and a fault-free run
            // must never touch the retry/timeout paths.
            let counts = run_spmd(p, move |rank, comm| {
                let sh = &shards_ref[rank];
                let mut be = NativeBackend::new();
                let m = bcd::run(&sh.a_loc, &sh.y_loc, sh.n_global, optsr, None, comm, &mut be)
                    .unwrap()
                    .history
                    .meter;
                (m.allreduces, m.retries, m.timeouts)
            });
            assert_eq!(
                counts[0].0 as usize, outer,
                "overlap={overlap}: prox collective count != H/s"
            );
            for &(_, r, t) in &counts {
                ff_retries += r;
                ff_timeouts += t;
            }
            if overlap {
                overlap_allreduces = counts[0].0;
            }
            let (med, _, _) = time_runs(1, if quick { 3 } else { 5 }, || {
                run_spmd(p, move |rank, comm| {
                    let sh = &shards_ref[rank];
                    let mut be = NativeBackend::new();
                    bcd::run(&sh.a_loc, &sh.y_loc, sh.n_global, optsr, None, comm, &mut be)
                        .unwrap()
                        .w[0]
                })
            });
            println!(
                "  overlap={overlap:<5} median/outer = {}",
                fmt_secs(med / outer as f64)
            );
            medians.push(med / outer as f64);
        }
        println!(
            "  prox Gram-prefetch pipeline speedup: {:.2}×",
            medians[0] / medians[1]
        );
        report.push(("prox_bcd_blocking_outer_ns", json::num(medians[0] * 1e9)));
        report.push(("prox_bcd_overlap_outer_ns", json::num(medians[1] * 1e9)));
        let per_outer = overlap_allreduces as f64 / outer as f64;
        report.push(("prox_overlap_allreduces_per_outer", json::num(per_outer)));
        wire_metrics.push(("prox_overlap_allreduces_per_outer", per_outer));
        // PR-8 fault-tolerance invariant: with no chaos and no deadline,
        // the retry/timeout counters stay flat at zero. Seeded at 0 in
        // the committed baseline, so any nonzero value fails the gate.
        report.push(("comm_retries_fault_free", json::num(ff_retries as f64)));
        report.push(("comm_timeouts_fault_free", json::num(ff_timeouts as f64)));
        wire_metrics.push(("comm_retries_fault_free", ff_retries as f64));
        wire_metrics.push(("comm_timeouts_fault_free", ff_timeouts as f64));
    }

    // --- span tracer: zero-alloc steady state + span accounting ---------
    // A traced overlapped CA-BCD run at P=4 (the acceptance config).
    // Machine-independent gates: the tracer ring must never grow
    // (`trace_allocs == 0` — preallocated, wrap-in-place) and the spans
    // per outer iteration are a fixed function of the prefetch schedule
    // (7·outer + 2 per rank), so any instrumentation drift shows up as a
    // seed regression. The overlap-efficiency figure is printed for the
    // record (timing-dependent, not gated).
    {
        use cabcd::coordinator::partition_primal;
        use cabcd::matrix::io::Dataset;
        use cabcd::solvers::{bcd, SolverOpts};
        use cabcd::trace::{self, TraceSummary, Tracer};

        let (d, n) = (96usize, 4096usize);
        let x = Matrix::Dense(dense_mat(d, n, 31));
        let mut y = vec![0.0; n];
        x.matvec_t(&vec![1.0; d], &mut y).unwrap();
        let ds = Dataset {
            name: "trace-bench".into(),
            x,
            y,
        };
        let p = 4usize;
        let shards = partition_primal(&ds, p).unwrap();
        let (s, outer) = (4usize, 8usize);
        let opts = SolverOpts::builder()
            .b(8)
            .s(s)
            .lam(0.1)
            .iters(outer * s)
            .seed(5)
            .record_every(0)
            .overlap(true)
            .build();
        let shards_ref = &shards;
        let optsr = &opts;
        let outs = run_spmd(p, move |rank, comm| {
            trace::install(Tracer::new(rank, trace::DEFAULT_SPAN_CAPACITY));
            let sh = &shards_ref[rank];
            let mut be = NativeBackend::new();
            let out = bcd::run(&sh.a_loc, &sh.y_loc, sh.n_global, optsr, None, comm, &mut be)
                .unwrap();
            (out.history.meter, trace::take().unwrap())
        });
        let mut tracers = Vec::with_capacity(p);
        for (rank, (meter, tracer)) in outs.into_iter().enumerate() {
            trace::cross_check(&tracer, &meter)
                .unwrap_or_else(|e| panic!("trace/meter cross-check, rank {rank}: {e}"));
            tracers.push(tracer);
        }
        let sum = TraceSummary::from_tracers(&tracers);
        assert_eq!(
            sum.trace_allocs, 0,
            "tracer ring reallocated in steady state"
        );
        assert_eq!(sum.dropped, 0, "default ring capacity dropped spans");
        let spans_per_outer = sum.spans as f64 / (p * outer) as f64;
        let bd0 = &sum.breakdown[0];
        println!(
            "\nspan tracer (CA-BCD overlap, P={p}, {outer} outers): {} spans \
             ({spans_per_outer} per rank-outer), 0 ring allocs",
            sum.spans
        );
        println!(
            "  overlap efficiency = {:.3} ({} windows)   rank0 breakdown: \
             compute {} / wire {} / idle {}",
            sum.overlap_efficiency(),
            sum.overlap.pairs,
            fmt_secs(bd0.compute_ns as f64 * 1e-9),
            fmt_secs(bd0.wire_ns as f64 * 1e-9),
            fmt_secs(bd0.idle_ns as f64 * 1e-9),
        );
        report.push(("trace_allocs_steady_state", json::num(sum.trace_allocs as f64)));
        report.push(("trace_spans_per_outer", json::num(spans_per_outer)));
        report.push(("trace_overlap_efficiency", json::num(sum.overlap_efficiency())));
        wire_metrics.push(("trace_allocs_steady_state", sum.trace_allocs as f64));
        wire_metrics.push(("trace_spans_per_outer", spans_per_outer));
    }

    // --- telemetry registry: zero-alloc steady state + snapshot size ----
    // A telemetered overlapped CA-BCD run at P=4. Machine-independent
    // gates: metric recording must never allocate after registry
    // construction (`telemetry_allocs == 0` — fixed-size counter/gauge/
    // histogram arrays plus a preallocated snapshot ring), and the
    // aggregation allreduce payload is a fixed function of the registry
    // layout (P · REGISTRY_WORDS), so any metric added to the wire format
    // shows up as a seed regression.
    {
        use cabcd::coordinator::partition_primal;
        use cabcd::matrix::io::Dataset;
        use cabcd::solvers::{bcd, SolverOpts};
        use cabcd::telemetry::{self, Registry, TelemetrySummary};

        let (d, n) = (96usize, 4096usize);
        let x = Matrix::Dense(dense_mat(d, n, 41));
        let mut y = vec![0.0; n];
        x.matvec_t(&vec![1.0; d], &mut y).unwrap();
        let ds = Dataset {
            name: "telemetry-bench".into(),
            x,
            y,
        };
        let p = 4usize;
        let shards = partition_primal(&ds, p).unwrap();
        let (s, outer) = (4usize, 8usize);
        let opts = SolverOpts::builder()
            .b(8)
            .s(s)
            .lam(0.1)
            .iters(outer * s)
            .seed(5)
            .record_every(4)
            .overlap(true)
            .build();
        let shards_ref = &shards;
        let optsr = &opts;
        let regs = run_spmd(p, move |rank, comm| {
            telemetry::install(Registry::new(rank, p));
            let sh = &shards_ref[rank];
            let mut be = NativeBackend::new();
            bcd::run(&sh.a_loc, &sh.y_loc, sh.n_global, optsr, None, comm, &mut be).unwrap();
            telemetry::take().unwrap()
        });
        let sum = TelemetrySummary::from_registries(&regs);
        assert_eq!(
            sum.telemetry_allocs, 0,
            "telemetry registry allocated in steady state"
        );
        assert_eq!(sum.dropped_snapshots, 0, "snapshot ring dropped snapshots");
        assert!(sum.snapshots > 0, "record cadence produced no snapshots");
        let snapshot_words = (p * telemetry::REGISTRY_WORDS) as f64;
        assert_eq!(sum.snapshot_words as f64, snapshot_words);
        let last = sum.last.as_ref().expect("no final cluster snapshot");
        println!(
            "\ntelemetry registry (CA-BCD overlap, P={p}, {outer} outers): {} cluster \
             snapshots, {} allreduce words each, 0 registry allocs, {} straggler flag(s)",
            sum.snapshots,
            snapshot_words,
            sum.straggler_flags
        );
        println!(
            "  final snapshot @ outer {}: fleet allreduce p99 {} — rank0 \
             compute {} / wire {} / idle {}",
            last.outer,
            fmt_secs(last.fleet.allreduce.p99 as f64 * 1e-9),
            fmt_secs(last.ranks[0].compute_ns as f64 * 1e-9),
            fmt_secs(last.ranks[0].wire_ns as f64 * 1e-9),
            fmt_secs(last.ranks[0].idle_ns as f64 * 1e-9),
        );
        report.push((
            "telemetry_allocs_steady_state",
            json::num(sum.telemetry_allocs as f64),
        ));
        report.push(("telemetry_snapshot_words", json::num(snapshot_words)));
        wire_metrics.push(("telemetry_allocs_steady_state", sum.telemetry_allocs as f64));
        wire_metrics.push(("telemetry_snapshot_words", snapshot_words));
    }

    // --- checkpoint snapshot size (machine-independent) -----------------
    // One serial CA-BCD run with an in-memory sink: the snapshot's solver
    // state (sampler RNG + w + alpha_loc) is a fixed function of the
    // problem shape — 4 + d + n_loc words here — so growth means a new
    // state segment slipped into the capture path. Gated against the
    // committed seed like the other wire fields.
    {
        use cabcd::comm::SerialComm;
        use cabcd::engine::{checkpoint, MemorySink};
        use cabcd::solvers::{bcd, SolverOpts};

        let (d, n) = (64usize, 512usize);
        let x = Matrix::Dense(dense_mat(d, n, 51));
        let mut y = vec![0.0; n];
        x.matvec_t(&vec![1.0; d], &mut y).unwrap();
        let opts = SolverOpts::builder()
            .b(8)
            .s(4)
            .lam(0.1)
            .iters(32)
            .seed(5)
            .record_every(0)
            .overlap(false)
            .build();
        let sink = MemorySink::new();
        checkpoint::install(Box::new(sink.clone()), 4);
        let mut c = SerialComm::new();
        bcd::run(&x, &y, n, &opts, None, &mut c, &mut be).unwrap();
        checkpoint::take();
        let ck = sink
            .load(0)
            .unwrap()
            .expect("checkpointed run left no snapshot");
        let words = ck.state_words() as f64;
        println!(
            "\ncheckpoint snapshot (CA-BCD serial, d={d}, n={n}): {words} state words \
             (next_k = {})",
            ck.next_k
        );
        report.push(("checkpoint_state_words_bcd", json::num(words)));
        wire_metrics.push(("checkpoint_state_words_bcd", words));
    }

    // Measured allreduce latency on the packed payload.
    let rounds = if quick { 8usize } else { 20 };
    println!("\nallreduce (thread communicator), packed sb(sb+1)/2+sb payloads:");
    println!(
        "{:>6} {:>8} {:>14} {:>16} {:>9}",
        "sb", "P", "new median", "seed reduce+bc", "speedup"
    );
    let comm_sbs: &[usize] = if quick { &[64] } else { &[8, 64, 256] };
    let comm_ps: &[usize] = if quick { &[8] } else { &[2, 4, 8] };
    for &sb in comm_sbs {
        let payload = packed_len(sb) + sb;
        for &p in comm_ps {
            let (new_med, _, _) = time_runs(2, if quick { 4 } else { 8 }, || {
                run_spmd(p, |_r, comm| {
                    let mut buf = vec![1.0f64; payload];
                    for _ in 0..rounds {
                        comm.allreduce_sum(&mut buf).unwrap();
                    }
                    buf[0]
                })
            });
            let (old_med, _, _) = time_runs(2, if quick { 4 } else { 8 }, || {
                run_spmd(p, |_r, comm| {
                    let mut buf = vec![1.0f64; payload];
                    for _ in 0..rounds {
                        comm.allreduce_sum_reference(&mut buf).unwrap();
                    }
                    buf[0]
                })
            });
            let speedup = old_med / new_med;
            println!(
                "{:>6} {:>8} {:>14} {:>16} {:>8.2}×",
                sb,
                p,
                fmt_secs(new_med / rounds as f64),
                fmt_secs(old_med / rounds as f64),
                speedup
            );
            if sb == 64 && p == 8 {
                report.push((
                    "allreduce_packed_sb64_p8_ns",
                    json::num(new_med / rounds as f64 * 1e9),
                ));
            }
            // Cross-process timing assertion: full mode only (CI runners
            // schedule 8 threads too noisily for a hard floor).
            if !quick && p == 8 && sb == 256 {
                assert!(
                    speedup >= 2.0,
                    "P=8 sb=256: new allreduce only {speedup:.2}× faster than the \
                     seed reduce+broadcast (want ≥2×)"
                );
            }
        }
    }

    // Zero-allocation invariant: after warmup, the pooled collective path
    // takes no heap allocations per call (CostMeter::buf_allocs is flat).
    run_spmd(8, |_r, comm| {
        let mut buf = vec![1.0f64; packed_len(64) + 64];
        for _ in 0..8 {
            comm.allreduce_sum(&mut buf).unwrap();
        }
        let warm = comm.meter().buf_allocs;
        for _ in 0..100 {
            comm.allreduce_sum(&mut buf).unwrap();
        }
        assert_eq!(
            comm.meter().buf_allocs,
            warm,
            "allreduce allocated after warmup"
        );
        buf[0]
    });
    println!("zero-alloc check: 100 post-warmup allreduces at P=8, 0 pool allocations");

    if !quick {
        // --- full outer iteration (solver-level) ------------------------
        println!("\nfull CA-BCD outer iteration (dense d=256, n=32768, b=8):");
        println!("{:>6} {:>14} {:>18}", "s", "median/outer", "median/inner-iter");
        let x = Matrix::Dense(dense_mat(256, 32768, 9));
        let mut y = vec![0.0; 32768];
        x.matvec_t(&[1.0; 256], &mut y).unwrap();
        for s in [1usize, 4, 8] {
            use cabcd::comm::SerialComm;
            use cabcd::solvers::{bcd, SolverOpts};
            let opts = SolverOpts::builder()
                .b(8)
                .s(s)
                .lam(0.1)
                .iters(4 * s)
                .seed(3)
                .record_every(0)
                .track_gram_cond(false)
                .overlap(false)
                .build();
            let mut c = SerialComm::new();
            let (med, _, _) = time_runs(1, 5, || {
                bcd::run(&x, &y, 32768, &opts, None, &mut c, &mut be).unwrap().w[0]
            });
            let per_outer = med / 4.0;
            println!(
                "{:>6} {:>14} {:>18}",
                s,
                fmt_secs(per_outer),
                fmt_secs(per_outer / s as f64)
            );
        }

        // Overlap pipeline: CA-BCD end-to-end, blocking vs non-blocking.
        use cabcd::coordinator::partition_primal;
        use cabcd::matrix::io::Dataset;
        use cabcd::solvers::{bcd, SolverOpts};
        let x = Matrix::Dense(dense_mat(192, 16384, 12));
        let mut y = vec![0.0; 16384];
        x.matvec_t(&[1.0; 192], &mut y).unwrap();
        let ds = Dataset {
            name: "bench".into(),
            x,
            y,
        };
        let shards = partition_primal(&ds, 8).unwrap();
        println!("\nCA-BCD outer iteration at P=8 (d=192, n=16384, b=8, s=4):");
        let mut medians = Vec::new();
        for overlap in [false, true] {
            let opts = SolverOpts::builder()
                .b(8)
                .s(4)
                .lam(0.1)
                .iters(16)
                .seed(3)
                .record_every(0)
                .track_gram_cond(false)
                .overlap(overlap)
                .build();
            let shards_ref = &shards;
            let optsr = &opts;
            let (med, _, _) = time_runs(1, 5, || {
                run_spmd(8, move |rank, comm| {
                    let sh = &shards_ref[rank];
                    let mut be = NativeBackend::new();
                    bcd::run(&sh.a_loc, &sh.y_loc, sh.n_global, optsr, None, comm, &mut be)
                        .unwrap()
                        .w[0]
                })
            });
            println!(
                "  overlap={overlap:<5} median/outer = {}",
                fmt_secs(med / 4.0)
            );
            medians.push(med);
        }
        println!(
            "  overlap pipeline speedup: {:.2}×",
            medians[0] / medians[1]
        );
    }

    // --- XLA backend latency (optional) ---------------------------------
    let art = Path::new("artifacts");
    if !quick && art.join("manifest.tsv").exists() {
        println!("\nXLA backend per-call latency (artifact path):");
        let mut xb = XlaBackend::new(art).unwrap();
        let a = Matrix::Dense(dense_mat(128, 8192, 1));
        let mut sampler = BlockSampler::new(128, 7);
        let idx = sampler.draw_block(32);
        let z: Vec<f64> = (0..8192).map(|i| (i as f64).sin()).collect();
        let mut g = vec![0.0; packed_len(32)];
        let mut r = vec![0.0; 32];
        let (med, _, _) = time_runs(2, 8, || {
            xb.gram_resid(&a, &idx, &z, &mut g, &mut r).unwrap();
            g[0]
        });
        println!("  gram_resid sb=32 n_loc=8192: {}", fmt_secs(med));
        let (mn, _, _) = time_runs(2, 8, || {
            be.gram_resid(&a, &idx, &z, &mut g, &mut r).unwrap();
            g[0]
        });
        println!(
            "  native same shape:           {}  (xla/native = {:.1}×)",
            fmt_secs(mn),
            med / mn
        );
        println!(
            "  note: interpret-mode Pallas on CPU PJRT — structural parity, \
             not a TPU performance proxy (DESIGN.md §Hardware-Adaptation)."
        );
    } else if !quick {
        println!("\n(artifacts/ missing — skipping XLA latency section)");
    }

    // --- CI regression gate against the committed seed -------------------
    // Quick mode runs in CI from a fresh checkout, so BENCH_hotpath.json
    // on disk IS the committed seed at this point; compare before
    // overwriting. >25% growth of any wire/word-count field fails here.
    if quick {
        match std::fs::read_to_string("BENCH_hotpath.json") {
            Ok(seed_text) => {
                println!("\nseed regression check (≤1.25× committed wire counts):");
                check_against_seed(&seed_text, &wire_metrics);
            }
            Err(e) => println!("\n(no committed BENCH_hotpath.json seed to check: {e})"),
        }
    }

    // --- perf baseline for future PRs -----------------------------------
    let json_out = json::object(&report);
    std::fs::write("BENCH_hotpath.json", &json_out).expect("write BENCH_hotpath.json");
    println!("\nwrote BENCH_hotpath.json: {json_out}");

    println!("\n* per inner-iter = gram cost amortized over the sb rows' s steps");
    println!("hotpath_micro: OK");
}
