//! Hot-path micro-benchmarks — the §Perf instrument (EXPERIMENTS.md).
//!
//! Times the three per-iteration kernels of every solver (raw Gram +
//! residual, s-step inner solve, deferred vector update) on dense and CSR
//! operands for the native backend, the end-to-end outer iteration, the
//! collectives, and — when artifacts are present — the XLA backend's
//! per-call latency for comparison.

use std::path::Path;

use cabcd::comm::thread::run_spmd;
use cabcd::comm::Communicator;
use cabcd::gram::{ComputeBackend, NativeBackend};
use cabcd::matrix::{CsrMatrix, DenseMatrix, Matrix};
use cabcd::runtime::XlaBackend;
use cabcd::sampling::{overlap_tensor, BlockSampler};
use cabcd::util::bench::{fmt_secs, time_runs};
use cabcd::util::Rng64;

fn dense_mat(d: usize, n: usize, seed: u64) -> DenseMatrix {
    let mut rng = Rng64::seed_from_u64(seed);
    let data: Vec<f64> = (0..d * n).map(|_| rng.gen_normal()).collect();
    DenseMatrix::from_vec(d, n, data)
}

fn sparse_mat(d: usize, n: usize, density: f64, seed: u64) -> CsrMatrix {
    let mut rng = Rng64::seed_from_u64(seed);
    let total = ((d * n) as f64 * density) as usize;
    let trip: Vec<(usize, usize, f64)> = (0..total)
        .map(|_| (rng.gen_range(0, d), rng.gen_range(0, n), rng.gen_normal()))
        .collect();
    CsrMatrix::from_triplets(d, n, trip)
}

fn main() {
    println!("=== hot-path micro benchmarks (native backend) ===");
    let mut be = NativeBackend::new();

    // --- gram_resid over dense operands -------------------------------
    println!("\ngram_resid (dense), n_loc=8192:");
    println!("{:>6} {:>14} {:>16} {:>14}", "sb", "median", "per inner-iter*", "GF/s");
    for sb in [8usize, 16, 32, 64] {
        let a = Matrix::Dense(dense_mat(128, 8192, 1));
        let mut sampler = BlockSampler::new(128, 7);
        let idx = sampler.draw_block(sb);
        let z: Vec<f64> = (0..8192).map(|i| (i as f64).sin()).collect();
        let mut g = vec![0.0; sb * sb];
        let mut r = vec![0.0; sb];
        let (med, _, _) = time_runs(3, 15, || {
            be.gram_resid(&a, &idx, &z, &mut g, &mut r).unwrap();
            g[0]
        });
        let flops = (sb * sb + 2 * sb) as f64 * 8192.0; // syrk (sym) + matvec
        println!(
            "{:>6} {:>14} {:>16} {:>14.2}",
            sb,
            fmt_secs(med),
            fmt_secs(med / sb as f64),
            flops / med / 1e9
        );
    }

    // --- gram_resid over CSR (news20-like density) --------------------
    println!("\ngram_resid (CSR 0.3% dense, d=4096, n_loc=16384):");
    println!("{:>6} {:>14} {:>16}", "sb", "median", "Mmerge-ops/s");
    let csr = sparse_mat(4096, 16384, 0.003, 2);
    let nnz_per_row = csr.nnz() as f64 / 4096.0;
    let a = Matrix::Csr(csr);
    for sb in [8usize, 32, 64] {
        let mut sampler = BlockSampler::new(4096, 7);
        let idx = sampler.draw_block(sb);
        let z: Vec<f64> = (0..16384).map(|i| (i as f64).cos()).collect();
        let mut g = vec![0.0; sb * sb];
        let mut r = vec![0.0; sb];
        let (med, _, _) = time_runs(3, 15, || {
            be.gram_resid(&a, &idx, &z, &mut g, &mut r).unwrap();
            g[0]
        });
        // Two-pointer merge touches ~2·nnz/row per row pair.
        let merge_ops = (sb * sb) as f64 * nnz_per_row;
        println!(
            "{:>6} {:>14} {:>16.1}",
            sb,
            fmt_secs(med),
            merge_ops / med / 1e6
        );
    }

    // --- inner solve ----------------------------------------------------
    println!("\nca_inner_solve:");
    println!("{:>10} {:>14}", "(s, b)", "median");
    for (s, b) in [(1usize, 8usize), (4, 8), (8, 8), (16, 8), (8, 16)] {
        let sb = s * b;
        let m = dense_mat(sb, sb + 32, 3);
        let mut g_raw = vec![0.0; sb * sb];
        let idx: Vec<usize> = (0..sb).collect();
        m.sampled_gram(&idx, &mut g_raw);
        let mut rng = Rng64::seed_from_u64(4);
        let r_raw: Vec<f64> = (0..sb).map(|_| rng.gen_normal()).collect();
        let w_blk: Vec<f64> = (0..sb).map(|_| rng.gen_normal()).collect();
        let blocks: Vec<Vec<usize>> = (0..s)
            .map(|j| (0..b).map(|i| (j * b + i) % (sb / 2 + 1)).collect())
            .collect();
        let ov = overlap_tensor(&blocks);
        let (med, _, _) = time_runs(3, 30, || {
            be.ca_inner_solve(s, b, &g_raw, &r_raw, &w_blk, &ov, 0.5, 1e-3)
                .unwrap()
        });
        println!("{:>10} {:>14}", format!("({s},{b})"), fmt_secs(med));
    }

    // --- full outer iteration (solver-level) ----------------------------
    println!("\nfull CA-BCD outer iteration (dense d=256, n=32768, b=8):");
    println!("{:>6} {:>14} {:>18}", "s", "median/outer", "median/inner-iter");
    let x = Matrix::Dense(dense_mat(256, 32768, 9));
    let mut y = vec![0.0; 32768];
    x.matvec_t(&[1.0; 256], &mut y).unwrap();
    for s in [1usize, 4, 8] {
        use cabcd::comm::SerialComm;
        use cabcd::solvers::{bcd, SolverOpts};
        let opts = SolverOpts {
            b: 8,
            s,
            lam: 0.1,
            iters: 4 * s,
            seed: 3,
            record_every: 0,
            track_gram_cond: false,
            tol: None,
            overlap: false,
        };
        let mut c = SerialComm::new();
        let (med, _, _) = time_runs(1, 5, || {
            bcd::run(&x, &y, 32768, &opts, None, &mut c, &mut be).unwrap().w[0]
        });
        let per_outer = med / 4.0;
        println!(
            "{:>6} {:>14} {:>18}",
            s,
            fmt_secs(per_outer),
            fmt_secs(per_outer / s as f64)
        );
    }

    // --- collectives ------------------------------------------------------
    // New RD/Rabenseifner pooled allreduce vs the seed's reduce-then-
    // broadcast, on the solver's sb²+sb Gram payloads. Acceptance: at P=8
    // the large-payload (bandwidth-bound) regime must be ≥2× faster per
    // call, and the pooled path must do zero heap allocations per call
    // after warmup.
    println!("\nallreduce (thread communicator), sb²+sb Gram payloads:");
    println!(
        "{:>6} {:>8} {:>14} {:>16} {:>9}",
        "sb", "P", "new median", "seed reduce+bc", "speedup"
    );
    let rounds = 20usize;
    for sb in [8usize, 64, 256] {
        let payload = sb * sb + sb;
        for p in [2usize, 4, 8] {
            let (new_med, _, _) = time_runs(2, 8, || {
                run_spmd(p, |_r, comm| {
                    let mut buf = vec![1.0f64; payload];
                    for _ in 0..rounds {
                        comm.allreduce_sum(&mut buf).unwrap();
                    }
                    buf[0]
                })
            });
            let (old_med, _, _) = time_runs(2, 8, || {
                run_spmd(p, |_r, comm| {
                    let mut buf = vec![1.0f64; payload];
                    for _ in 0..rounds {
                        comm.allreduce_sum_reference(&mut buf).unwrap();
                    }
                    buf[0]
                })
            });
            let speedup = old_med / new_med;
            println!(
                "{:>6} {:>8} {:>14} {:>16} {:>8.2}×",
                sb,
                p,
                fmt_secs(new_med / rounds as f64),
                fmt_secs(old_med / rounds as f64),
                speedup
            );
            if p == 8 && sb == 256 {
                assert!(
                    speedup >= 2.0,
                    "P=8 sb=256: new allreduce only {speedup:.2}× faster than the \
                     seed reduce+broadcast (want ≥2×)"
                );
            }
        }
    }

    // Zero-allocation invariant: after warmup, the pooled collective path
    // takes no heap allocations per call (CostMeter::buf_allocs is flat).
    run_spmd(8, |_r, comm| {
        let mut buf = vec![1.0f64; 64 * 64 + 64];
        for _ in 0..8 {
            comm.allreduce_sum(&mut buf).unwrap();
        }
        let warm = comm.meter().buf_allocs;
        for _ in 0..100 {
            comm.allreduce_sum(&mut buf).unwrap();
        }
        assert_eq!(
            comm.meter().buf_allocs,
            warm,
            "allreduce allocated after warmup"
        );
        buf[0]
    });
    println!("zero-alloc check: 100 post-warmup allreduces at P=8, 0 pool allocations");

    // Overlap pipeline: CA-BCD end-to-end, blocking vs non-blocking comm.
    {
        use cabcd::coordinator::partition_primal;
        use cabcd::matrix::io::Dataset;
        use cabcd::solvers::{bcd, SolverOpts};
        let x = Matrix::Dense(dense_mat(192, 16384, 12));
        let mut y = vec![0.0; 16384];
        x.matvec_t(&[1.0; 192], &mut y).unwrap();
        let ds = Dataset {
            name: "bench".into(),
            x,
            y,
        };
        let shards = partition_primal(&ds, 8).unwrap();
        println!("\nCA-BCD outer iteration at P=8 (d=192, n=16384, b=8, s=4):");
        let mut medians = Vec::new();
        for overlap in [false, true] {
            let opts = SolverOpts {
                b: 8,
                s: 4,
                lam: 0.1,
                iters: 16,
                seed: 3,
                record_every: 0,
                track_gram_cond: false,
                tol: None,
                overlap,
            };
            let shards_ref = &shards;
            let optsr = &opts;
            let (med, _, _) = time_runs(1, 5, || {
                run_spmd(8, move |rank, comm| {
                    let sh = &shards_ref[rank];
                    let mut be = NativeBackend::new();
                    bcd::run(&sh.a_loc, &sh.y_loc, sh.n_global, optsr, None, comm, &mut be)
                        .unwrap()
                        .w[0]
                })
            });
            println!(
                "  overlap={overlap:<5} median/outer = {}",
                fmt_secs(med / 4.0)
            );
            medians.push(med);
        }
        println!(
            "  overlap pipeline speedup: {:.2}×",
            medians[0] / medians[1]
        );
    }

    // --- XLA backend latency (optional) -----------------------------------
    let art = Path::new("artifacts");
    if art.join("manifest.tsv").exists() {
        println!("\nXLA backend per-call latency (artifact path):");
        let mut xb = XlaBackend::new(art).unwrap();
        let a = Matrix::Dense(dense_mat(128, 8192, 1));
        let mut sampler = BlockSampler::new(128, 7);
        let idx = sampler.draw_block(32);
        let z: Vec<f64> = (0..8192).map(|i| (i as f64).sin()).collect();
        let mut g = vec![0.0; 32 * 32];
        let mut r = vec![0.0; 32];
        let (med, _, _) = time_runs(2, 8, || {
            xb.gram_resid(&a, &idx, &z, &mut g, &mut r).unwrap();
            g[0]
        });
        println!("  gram_resid sb=32 n_loc=8192: {}", fmt_secs(med));
        let (mn, _, _) = time_runs(2, 8, || {
            be.gram_resid(&a, &idx, &z, &mut g, &mut r).unwrap();
            g[0]
        });
        println!(
            "  native same shape:           {}  (xla/native = {:.1}×)",
            fmt_secs(mn),
            med / mn
        );
        println!(
            "  note: interpret-mode Pallas on CPU PJRT — structural parity, \
             not a TPU performance proxy (DESIGN.md §Hardware-Adaptation)."
        );
    } else {
        println!("\n(artifacts/ missing — skipping XLA latency section)");
    }

    println!("\n* per inner-iter = gram cost amortized over the sb rows' s steps");
    println!("hotpath_micro: OK");
}
