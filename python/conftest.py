import jax

# Artifacts and the coordinator's native path are float64; keep the test
# numerics identical.
jax.config.update("jax_enable_x64", True)
