#!/usr/bin/env python3
"""Schema checker for the tracer's Chrome trace-event JSON export.

CI runs the lasso example with ``--trace`` and validates the emitted file
here: the Rust exporter is hand-rolled (no serde in the offline vendor
set), so a malformed envelope or a drifting field name would otherwise
only surface when someone loads a trace into Perfetto months later.

Checks:
  * the envelope parses as JSON and has ``traceEvents`` (list) plus
    ``displayTimeUnit``;
  * every rank track announces itself with a ``thread_name`` metadata
    event (``ph: "M"``);
  * every span is a complete event (``ph: "X"``) with numeric
    ``ts``/``dur`` (``dur >= 0``), integer ``pid``/``tid``, a ``name``
    from the span taxonomy, a ``cat`` from the op-class taxonomy, and
    ``args.tag``/``args.words``;
  * the kinds a solver run must produce (Sample, GramLocal,
    CollectiveStart, CollectiveWait, InnerSolve, Apply) all appear, and
    every metadata-announced rank has at least one span.

Usage: python3 python/check_trace.py <trace.json>
"""

from __future__ import annotations

import json
import sys

SPAN_KINDS = {
    "Sample",
    "GramLocal",
    "CollectiveStart",
    "CollectiveWait",
    "InnerSolve",
    "Apply",
    "ProxStep",
    "Record",
    "Retry",
}
OP_CLASSES = {"compute", "allreduce", "all_to_all", "barrier"}
# Kinds any traced solver run is guaranteed to emit (ProxStep/Record are
# config-dependent and not required).
REQUIRED_KINDS = {
    "Sample",
    "GramLocal",
    "CollectiveStart",
    "CollectiveWait",
    "InnerSolve",
    "Apply",
}


def fail(msg: str) -> None:
    print(f"check_trace: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def check(path: str) -> None:
    with open(path, encoding="utf-8") as f:
        try:
            doc = json.load(f)
        except json.JSONDecodeError as e:
            fail(f"{path} is not valid JSON: {e}")

    if not isinstance(doc, dict):
        fail("top level is not an object")
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail("traceEvents missing, not a list, or empty")
    if doc.get("displayTimeUnit") not in ("ms", "ns"):
        fail(f"displayTimeUnit {doc.get('displayTimeUnit')!r} invalid")

    meta_ranks: set[int] = set()
    span_ranks: set[int] = set()
    kinds_seen: set[str] = set()
    spans = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            fail(f"traceEvents[{i}] is not an object")
        ph = ev.get("ph")
        if ph == "M":
            if ev.get("name") != "thread_name":
                fail(f"traceEvents[{i}]: metadata event is not thread_name")
            if not isinstance(ev.get("tid"), int):
                fail(f"traceEvents[{i}]: metadata tid is not an integer")
            meta_ranks.add(ev["tid"])
            continue
        if ph != "X":
            fail(f"traceEvents[{i}]: unexpected ph {ph!r} (want 'X' or 'M')")
        spans += 1
        name = ev.get("name")
        if name not in SPAN_KINDS:
            fail(f"traceEvents[{i}]: span name {name!r} not in taxonomy")
        kinds_seen.add(name)
        if ev.get("cat") not in OP_CLASSES:
            fail(f"traceEvents[{i}]: cat {ev.get('cat')!r} not an op class")
        for key in ("ts", "dur"):
            v = ev.get(key)
            if not isinstance(v, (int, float)) or isinstance(v, bool):
                fail(f"traceEvents[{i}]: {key} is {v!r}, want a number")
        if ev["dur"] < 0:
            fail(f"traceEvents[{i}]: negative dur {ev['dur']}")
        if ev["ts"] < 0:
            fail(f"traceEvents[{i}]: negative ts {ev['ts']}")
        for key in ("pid", "tid"):
            if not isinstance(ev.get(key), int):
                fail(f"traceEvents[{i}]: {key} is {ev.get(key)!r}, want int")
        span_ranks.add(ev["tid"])
        args = ev.get("args")
        if not isinstance(args, dict):
            fail(f"traceEvents[{i}]: args missing")
        for key in ("tag", "words"):
            v = args.get(key)
            if not isinstance(v, (int, float)) or isinstance(v, bool):
                fail(f"traceEvents[{i}]: args.{key} is {v!r}, want a number")

    if not meta_ranks:
        fail("no thread_name metadata events (rank tracks unnamed)")
    missing_kinds = REQUIRED_KINDS - kinds_seen
    if missing_kinds:
        fail(f"required span kinds never emitted: {sorted(missing_kinds)}")
    silent = meta_ranks - span_ranks
    if silent:
        fail(f"ranks announced but produced no spans: {sorted(silent)}")
    orphans = span_ranks - meta_ranks
    if orphans:
        fail(f"spans on unannounced rank tracks: {sorted(orphans)}")

    print(
        f"check_trace: OK: {path}: {spans} spans on {len(span_ranks)} rank "
        f"track(s), kinds {sorted(kinds_seen)}"
    )


def main() -> None:
    if len(sys.argv) != 2:
        fail("usage: check_trace.py <trace.json>")
    check(sys.argv[1])


if __name__ == "__main__":
    main()
