"""Pure-jnp reference oracles for the L1 Pallas kernels.

These are the ground truth the Pallas kernels (and, transitively, the Rust
native backend — tested against the same closed forms) are validated against.

Everything here is deliberately written as the *obvious* dense expression:
no tiling, no fusion, no accumulation tricks.
"""

from __future__ import annotations

import jax.numpy as jnp


def gram_resid_ref(y_block: jnp.ndarray, z: jnp.ndarray):
    """Reference for the fused partial-Gram + residual kernel.

    Args:
      y_block: ``(sb, n_loc)`` — the sampled rows of X held by one rank
        (primal), or the transpose of the sampled columns (dual).
      z: ``(n_loc,)`` — the vector the residual matvec contracts against
        (primal: ``y - alpha``; dual: ``w``).

    Returns:
      ``(G_partial, r_partial)`` with ``G_partial = Y Yᵀ`` (``(sb, sb)``)
      and ``r_partial = Y z`` (``(sb,)``). Scaling by ``1/n`` and the
      ``+λI`` shift happen *after* the cross-rank allreduce, in the
      coordinator, so the kernel stays scale-free.
    """
    g = y_block @ y_block.T
    r = y_block @ z
    return g, r


def ca_inner_solve_ref(g: jnp.ndarray, overlap: jnp.ndarray,
                       r0: jnp.ndarray, lam: float):
    """Reference for the CA-BCD s-step inner solve (Alg. 2, lines 8–12).

    Args:
      g: ``(s*b, s*b)`` Gram matrix ``(1/n) Y Yᵀ + λ I`` (already reduced).
      overlap: ``(s, s, b, b)`` block-overlap tensor,
        ``overlap[j, t] = I_{sk+j}ᵀ I_{sk+t}`` (0/1 entries).
      r0: ``(s, b)`` per-inner-step base residuals
        ``-λ I_jᵀ w_sk - (1/n) I_jᵀ X α_sk + (1/n) I_jᵀ X y``.
      lam: regularization parameter λ.

    Returns:
      ``(s, b)`` array of Δw blocks.
    """
    s, b = r0.shape
    deltas = jnp.zeros((s, b), dtype=g.dtype)
    for j in range(s):
        rhs = r0[j]
        for t in range(j):
            cross = lam * overlap[j, t] + g[j * b:(j + 1) * b, t * b:(t + 1) * b]
            rhs = rhs - cross @ deltas[t]
        gamma = g[j * b:(j + 1) * b, j * b:(j + 1) * b]
        dw = jnp.linalg.solve(gamma, rhs)
        deltas = deltas.at[j].set(dw)
    return deltas
