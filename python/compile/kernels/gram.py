"""L1 Pallas kernel: fused partial-Gram + residual.

This is the compute hot-spot of every solver in the repo (Algorithms 1–4 of
Devarakonda et al. 2016): given the sampled row-block ``Y ∈ R^{sb×n_loc}``
held by one rank, produce

    G_partial = Y Yᵀ        (sb × sb)
    r_partial = Y z         (sb,)

in ONE pass over ``Y``. The coordinator allreduces both across ranks and then
applies the ``1/n`` scaling and ``+λI`` shift.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid walks ``n_loc`` in
``nt``-wide column tiles; each step streams one ``(sb, nt)`` tile of ``Y``
HBM→VMEM, contracts it on the MXU (``Y_t @ Y_tᵀ`` is an (sb×nt)·(nt×sb)
matmul), and accumulates into an ``(sb, sb)`` VMEM-resident output block that
the index_map pins in place across the whole grid. The residual matvec reuses
the same tile load — Gram and residual share one HBM pass.

The kernel MUST be lowered with ``interpret=True`` in this image: real-TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["gram_resid", "DEFAULT_NT", "vmem_report"]

# Default column-tile width. 512 keeps the MXU contraction dimension ≥ 128
# lanes while the VMEM budget (see vmem_report) stays far under 16 MiB for
# every sb we AOT-compile.
DEFAULT_NT = 512


def _gram_resid_kernel(y_ref, z_ref, g_ref, r_ref):
    """One grid step: accumulate the tile's Gram and residual contribution."""
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        r_ref[...] = jnp.zeros_like(r_ref)

    y_t = y_ref[...]                      # (sb, nt) tile, VMEM
    z_t = z_ref[...]                      # (nt,)
    acc = y_ref.dtype
    # Symmetric rank-nt update on the MXU; f32 (or f64) accumulation.
    g_ref[...] += jnp.dot(y_t, y_t.T, preferred_element_type=acc)
    # Residual matvec reuses the same y_t tile — fused, single HBM pass.
    r_ref[...] += jnp.dot(y_t, z_t, preferred_element_type=acc)


@functools.partial(jax.jit, static_argnames=("nt",))
def gram_resid(y_block: jnp.ndarray, z: jnp.ndarray, *, nt: int = DEFAULT_NT):
    """Fused ``(Y Yᵀ, Y z)`` over column tiles of width ``nt``.

    ``y_block.shape[1]`` must be a multiple of ``nt`` — the Rust runtime
    zero-pads the final tile (zero columns contribute nothing to either
    output, so padding is exact, not approximate).
    """
    sb, n_loc = y_block.shape
    if n_loc % nt != 0:
        raise ValueError(f"n_loc={n_loc} must be a multiple of nt={nt}")
    grid = (n_loc // nt,)
    return pl.pallas_call(
        _gram_resid_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((sb, nt), lambda j: (0, j)),
            pl.BlockSpec((nt,), lambda j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((sb, sb), lambda j: (0, 0)),
            pl.BlockSpec((sb,), lambda j: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((sb, sb), y_block.dtype),
            jax.ShapeDtypeStruct((sb,), y_block.dtype),
        ],
        interpret=True,
    )(y_block, z)


def vmem_report(sb: int, nt: int, itemsize: int = 4) -> dict:
    """Estimate the VMEM working set and MXU utilization of one grid step.

    Used by ``aot.py --report`` and recorded in DESIGN.md/EXPERIMENTS.md; on
    this image the kernel runs under interpret=True so these are *structural*
    estimates (the quantity we optimize), not measurements.
    """
    tile_y = sb * nt * itemsize
    tile_z = nt * itemsize
    acc_g = sb * sb * itemsize
    acc_r = sb * itemsize
    total = tile_y + tile_z + acc_g + acc_r
    # MXU does 128×128 systolic matmul; utilization of the (sb,nt)x(nt,sb)
    # contraction is limited by how well sb fills the 128-lane dimension.
    mxu_fill = min(sb, 128) / 128.0
    flops_per_tile = 2 * sb * sb * nt + 2 * sb * nt
    return {
        "sb": sb,
        "nt": nt,
        "vmem_bytes": total,
        "vmem_mib": total / (1 << 20),
        "fits_16mib": total <= (16 << 20),
        "mxu_fill": mxu_fill,
        "flops_per_tile": flops_per_tile,
        "arithmetic_intensity": flops_per_tile / max(1, tile_y + tile_z),
    }
