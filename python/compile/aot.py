"""AOT-lower the L2 model (and its L1 Pallas kernel) to HLO text artifacts.

Run once at build time (``make artifacts``); the Rust coordinator loads the
emitted ``artifacts/*.hlo.txt`` via the PJRT C API and never touches Python
again.

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly. All computations are lowered with ``return_tuple=True``
and the Rust side unwraps the tuple.

Artifacts (all float64 — parity with the coordinator's native f64 path):

  gram_resid_packed_sb{SB}_n{NLOC}
      (Y[SB,NLOC], z[NLOC]) -> (Gpacked[SB(SB+1)/2], r[SB])
      G rides as its packed lower triangle — the coordinator's wire/solve
      format — so the Rust runtime accumulates artifact tiles with one
      elementwise add instead of a fold-to-packed copy.
  inner_solve_s{S}_b{B}        (Graw, rraw, wblk, overlap, lam, inv_n) -> d[S,B]
  alpha_update_sb{SB}_n{NLOC}  (Y[SB,NLOC], dflat[SB]) -> a[NLOC]

plus ``manifest.json`` describing every artifact so the Rust runtime can
discover shapes without re-parsing HLO.
"""

from __future__ import annotations

import argparse
import functools
import hashlib
import json
import os
import sys

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402
from .kernels.gram import vmem_report  # noqa: E402

DTYPE = jnp.float64

# Default artifact shape set. NLOC is the fixed column-chunk width the Rust
# runtime feeds (it pads the final chunk with zero columns — exact, since
# zero columns contribute nothing); SB values cover the b·s products used by
# the examples and the e2e driver; (S, B) are the inner-solve shapes.
GRAM_SHAPES = [(16, 2048), (32, 2048), (64, 2048)]
SOLVE_SHAPES = [(4, 4), (4, 8), (8, 8)]
NT = 512  # pallas column-tile width inside one chunk


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, DTYPE)


def lower_gram(sb: int, nloc: int):
    fn = functools.partial(model.gram_resid_packed_partial, nt=NT)
    return jax.jit(fn).lower(spec(sb, nloc), spec(nloc))


def lower_inner_solve(s: int, b: int):
    return jax.jit(model.ca_inner_solve).lower(
        spec(s * b, s * b), spec(s * b), spec(s, b), spec(s, s, b, b),
        spec(), spec())


def lower_dual_inner_solve(s: int, b: int):
    return jax.jit(model.ca_dual_inner_solve).lower(
        spec(s * b, s * b), spec(s * b), spec(s, b), spec(s, b),
        spec(s, s, b, b), spec(), spec())


def lower_alpha_update(sb: int, nloc: int):
    return jax.jit(model.alpha_update_partial).lower(spec(sb, nloc), spec(sb))


def emit(out_dir: str, name: str, lowered, meta: dict, manifest: list,
         verbose: bool = True) -> None:
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    digest = hashlib.sha256(text.encode()).hexdigest()[:16]
    manifest.append({"name": name, "file": f"{name}.hlo.txt",
                     "sha256_16": digest, "dtype": "f64", **meta})
    if verbose:
        print(f"  {name}.hlo.txt  ({len(text)} chars, sha={digest})")


def build_all(out_dir: str, gram_shapes, solve_shapes, verbose=True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest: list = []
    for sb, nloc in gram_shapes:
        emit(out_dir, f"gram_resid_packed_sb{sb}_n{nloc}",
             lower_gram(sb, nloc),
             {"kind": "gram_resid_packed", "sb": sb, "nloc": nloc, "nt": NT},
             manifest, verbose)
        emit(out_dir, f"alpha_update_sb{sb}_n{nloc}",
             lower_alpha_update(sb, nloc),
             {"kind": "alpha_update", "sb": sb, "nloc": nloc},
             manifest, verbose)
    for s, b in solve_shapes:
        emit(out_dir, f"inner_solve_s{s}_b{b}", lower_inner_solve(s, b),
             {"kind": "inner_solve", "s": s, "b": b}, manifest, verbose)
        emit(out_dir, f"dual_inner_solve_s{s}_b{b}",
             lower_dual_inner_solve(s, b),
             {"kind": "dual_inner_solve", "s": s, "b": b}, manifest, verbose)
    man = {"version": 1, "dtype": "f64", "nt": NT, "artifacts": manifest}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(man, f, indent=2)
    # TSV twin for the Rust runtime (kept serde-free offline):
    #   #meta dtype=f64 nt=512
    #   name \t file \t kind \t sb \t nloc \t s \t b
    with open(os.path.join(out_dir, "manifest.tsv"), "w") as f:
        f.write(f"#meta dtype=f64 nt={NT}\n")
        for a in manifest:
            f.write("\t".join(str(x) for x in (
                a["name"], a["file"], a["kind"],
                a.get("sb", 0), a.get("nloc", 0),
                a.get("s", 0), a.get("b", 0))) + "\n")
    if verbose:
        print(f"wrote {len(manifest)} artifacts + manifest.json to {out_dir}")
    return man


def report(gram_shapes) -> None:
    print("L1 kernel VMEM/MXU structural report (per grid step):")
    print(f"{'sb':>5} {'nt':>5} {'VMEM MiB':>9} {'≤16MiB':>7} "
          f"{'MXU fill':>9} {'AI flop/B':>10}")
    for sb, _ in gram_shapes:
        r = vmem_report(sb, NT, itemsize=8)
        print(f"{r['sb']:>5} {r['nt']:>5} {r['vmem_mib']:>9.3f} "
              f"{str(r['fits_16mib']):>7} {r['mxu_fill']:>9.2f} "
              f"{r['arithmetic_intensity']:>10.1f}")


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--out", default="../artifacts",
                   help="output directory for *.hlo.txt + manifest.json")
    p.add_argument("--report", action="store_true",
                   help="print the VMEM/MXU structural report and exit")
    args = p.parse_args(argv)
    if args.report:
        report(GRAM_SHAPES)
        return
    build_all(args.out, GRAM_SHAPES, SOLVE_SHAPES)
    report(GRAM_SHAPES)


if __name__ == "__main__":
    main()
