"""L2 JAX model: the CA-BCD/CA-BDCD outer-iteration compute graph.

Two jittable entry points are AOT-lowered by ``aot.py`` (plus one small
vector-update helper), matching the decomposition of Algorithm 2/4 around the
single allreduce per outer iteration:

  1. ``gram_resid_partial``   — per-rank, BEFORE the allreduce. Calls the L1
     Pallas kernel (``kernels/gram.py``) so the hot loop lowers into the same
     HLO module. Produces the rank's additive contribution to the ``sb×sb``
     Gram matrix and the ``sb`` residual vector.
  2. ``ca_inner_solve``       — replicated, AFTER the allreduce. Solves the s
     deferred ``b×b`` subproblems (Alg. 2 lines 8–12) from the reduced Gram
     matrix, the reduced residual, the gathered ``w`` entries and the block
     overlap tensor. Runs identically on every rank (same inputs), exactly as
     the paper's "solve the sub-problem redundantly on all processors".
  3. ``alpha_update_partial`` — per-rank: ``Yᵀ δ``, the rank-local piece of
     the deferred ``α`` update (Alg. 2 line 12 batched over the s steps).

IMPORTANT (runtime constraint): nothing here may lower to a LAPACK/FFI
custom-call — the Rust PJRT runtime (xla_extension 0.5.1) has no jaxlib FFI
registry. ``jnp.linalg.*`` is therefore off-limits; the ``b×b`` SPD solves
use an unrolled pure-jnp Cholesky (all basic HLO ops).
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels.gram import gram_resid, DEFAULT_NT

__all__ = [
    "gram_resid_partial",
    "gram_resid_packed_partial",
    "ca_inner_solve",
    "ca_dual_inner_solve",
    "alpha_update_partial",
    "cholesky_unrolled",
    "chol_solve",
]


def gram_resid_partial(y_block, z, *, nt: int = DEFAULT_NT):
    """Per-rank fused partial Gram + residual (wraps the L1 Pallas kernel)."""
    return gram_resid(y_block, z, nt=nt)


def gram_resid_packed_partial(y_block, z, *, nt: int = DEFAULT_NT):
    """``gram_resid_partial`` emitting G as its **packed lower triangle**.

    The coordinator's wire/solve format is the packed triangle (entry
    ``(r, c)``, ``r ≥ c``, at ``r(r+1)/2 + c`` — ``rust/src/linalg/packed.rs``);
    emitting it straight from the artifact removes the fold-to-packed copy
    the Rust runtime used to perform per column chunk. ``jnp.tril_indices``
    enumerates the triangle in exactly that row-major order, so the gather
    below IS the packed layout; the first ``sb(sb+1)/2`` entries of a
    larger artifact's triangle are the complete triangle of any logical
    ``sb`` ≤ the artifact's (row offsets don't depend on the matrix size),
    which is what lets the runtime accumulate a zero-padded artifact tile
    into the logical packed buffer with one elementwise add.
    """
    g, r = gram_resid(y_block, z, nt=nt)
    rows, cols = jnp.tril_indices(g.shape[0])
    return g[rows, cols], r


def cholesky_unrolled(a: jnp.ndarray) -> jnp.ndarray:
    """Lower-triangular Cholesky factor of an SPD ``b×b`` matrix.

    Column-by-column classical Cholesky, fully unrolled at trace time (``b``
    is static for every AOT artifact), built only from basic HLO ops
    (mul/add/sqrt/div + static slices) so the Rust PJRT runtime can execute
    it. Cost O(b³) flops — identical to the coordinator's native path.
    """
    b = a.shape[0]
    l = jnp.zeros_like(a)
    for k in range(b):
        if k == 0:
            akk = a[0, 0]
        else:
            akk = a[k, k] - jnp.dot(l[k, :k], l[k, :k])
        lkk = jnp.sqrt(akk)
        l = l.at[k, k].set(lkk)
        if k + 1 < b:
            if k == 0:
                col = a[k + 1:, 0] / lkk
            else:
                col = (a[k + 1:, k] - l[k + 1:, :k] @ l[k, :k]) / lkk
            l = l.at[k + 1:, k].set(col)
    return l


def chol_solve(a: jnp.ndarray, rhs: jnp.ndarray) -> jnp.ndarray:
    """Solve SPD ``a x = rhs`` via unrolled Cholesky + two triangular solves."""
    b = a.shape[0]
    l = cholesky_unrolled(a)
    # Forward substitution: L y = rhs.
    y = jnp.zeros_like(rhs)
    for k in range(b):
        acc = rhs[k] if k == 0 else rhs[k] - jnp.dot(l[k, :k], y[:k])
        y = y.at[k].set(acc / l[k, k])
    # Back substitution: Lᵀ x = y.
    x = jnp.zeros_like(rhs)
    for k in reversed(range(b)):
        acc = y[k] if k == b - 1 else y[k] - jnp.dot(l[k + 1:, k], x[k + 1:])
        x = x.at[k].set(acc / l[k, k])
    return x


def ca_inner_solve(g_raw, r_raw, w_blocks, overlap, lam, inv_n):
    """The s deferred subproblem solves of Algorithm 2 (lines 8–12).

    Args:
      g_raw: ``(s*b, s*b)`` — allreduced raw Gram ``Y Yᵀ`` (NO 1/n, NO λ).
      r_raw: ``(s*b,)`` — allreduced raw residual ``Y (y − α_sk)``.
      w_blocks: ``(s, b)`` — ``I_{sk+j}ᵀ w_sk`` for each inner step j.
      overlap: ``(s, s, b, b)`` — ``I_jᵀ I_t`` block overlap indicators
        (strictly-lower blocks used; computed by the coordinator from the
        shared-seed sample indices, zero communication).
      lam: scalar λ (traced input → one artifact serves every λ).
      inv_n: scalar 1/n.

    Returns:
      ``(s, b)`` Δw blocks. The scale-free inputs keep the artifact reusable
      across datasets of any n.
    """
    s, b = w_blocks.shape
    deltas = jnp.zeros((s, b), dtype=g_raw.dtype)
    for j in range(s):
        # Base residual: -λ I_jᵀ w_sk + (1/n)·[Y(y − α_sk)]_j.
        rhs = -lam * w_blocks[j] + inv_n * r_raw[j * b:(j + 1) * b]
        for t in range(j):
            # Cross term: (λ I_jᵀI_t + (1/n) I_jᵀXXᵀI_t) Δw_t  (eq. 8).
            cross = lam * overlap[j, t] + inv_n * g_raw[j * b:(j + 1) * b,
                                                        t * b:(t + 1) * b]
            rhs = rhs - cross @ deltas[t]
        # Γ_j = (1/n)(YYᵀ)_jj + λ I_b  (the diagonal block of G).
        gamma = inv_n * g_raw[j * b:(j + 1) * b, j * b:(j + 1) * b] \
            + lam * jnp.eye(b, dtype=g_raw.dtype)
        deltas = deltas.at[j].set(chol_solve(gamma, rhs))
    return deltas


def ca_dual_inner_solve(g_raw, r_raw, a_blocks, y_blocks, overlap, lam, inv_n):
    """The s deferred dual subproblem solves of Algorithm 4 (lines 9–13).

    Implements eq. (18) of the paper with scale-free inputs:

      Θ_j   = (1/(λn²))·G_jj_raw + (1/n)·I
      rhs_j = -(1/n)·r_raw_j·... — concretely:
      Δα_j  = -(1/n)·Θ_j⁻¹ ( -[Y w]_j + (1/(λn))·Σ_{t<j} G_raw[j,t] Δα_t
                              + α_Jj + Σ_{t<j} O[j,t] Δα_t + y_Jj )

    Args:
      g_raw: ``(s*b', s*b')`` allreduced raw Gram ``Yᵀ... = (XI)ᵀ(XI)``
        cross-block matrix (NO 1/(λn²) scaling, NO 1/n shift).
      r_raw: ``(s*b',)`` allreduced ``[X I]ᵀ w_sk`` stacked per block.
      a_blocks: ``(s, b')`` — ``I_jᵀ α_sk`` (replicated α gathered at j's
        sample indices).
      y_blocks: ``(s, b')`` — ``I_jᵀ y``.
      overlap: ``(s, s, b', b')`` — ``I_jᵀ I_t`` indicators.
      lam, inv_n: scalars λ and 1/n (traced — one artifact per (s, b')).

    Returns:
      ``(s, b')`` Δα blocks.
    """
    s, b = a_blocks.shape
    deltas = jnp.zeros((s, b), dtype=g_raw.dtype)
    eye = jnp.eye(b, dtype=g_raw.dtype)
    for j in range(s):
        rhs = -r_raw[j * b:(j + 1) * b] + a_blocks[j] + y_blocks[j]
        for t in range(j):
            # (1/(λn))·G_raw[j,t] + I_jᵀI_t   (eq. 18 cross term; note the
            # paper's Δα sign convention folds the minus into Δα_t itself).
            cross = (inv_n / lam) * g_raw[j * b:(j + 1) * b,
                                          t * b:(t + 1) * b] + overlap[j, t]
            rhs = rhs + cross @ deltas[t]
        theta = (inv_n * inv_n / lam) * g_raw[j * b:(j + 1) * b,
                                              j * b:(j + 1) * b] + inv_n * eye
        deltas = deltas.at[j].set(-inv_n * chol_solve(theta, rhs))
    return deltas


def alpha_update_partial(y_block, deltas_flat):
    """Rank-local deferred α update: ``α_loc += Yᵀ δ`` (Alg. 2, line 12).

    ``deltas_flat`` is the ``(s*b,)`` concatenation of the Δw blocks; the
    coordinator scatters the returned ``(n_loc,)`` vector into its local α
    slice. (Duplicate sampled coordinates across inner steps are handled
    naturally: their rows appear once per occurrence in ``y_block``.)
    """
    return y_block.T @ deltas_flat
