"""L2 model tests: unrolled Cholesky, CA inner solve vs oracle, and the
paper's exact-arithmetic claim — s steps of CA-BCD ≡ s sequential BCD steps.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from numpy.testing import assert_allclose

from tests._hypothesis_compat import given, settings, st

from compile.model import (alpha_update_partial, ca_dual_inner_solve,
                           ca_inner_solve, cholesky_unrolled, chol_solve,
                           gram_resid_packed_partial, gram_resid_partial)
from compile.kernels.ref import ca_inner_solve_ref


def _spd(b, seed, cond=None):
    rng = np.random.default_rng(seed)
    m = rng.standard_normal((b, b + 8))
    a = m @ m.T + 0.1 * np.eye(b)
    return a


@pytest.mark.parametrize("b", [1, 2, 5, 8, 16])
def test_cholesky_unrolled_matches_numpy(b):
    a = _spd(b, seed=b)
    l = np.asarray(cholesky_unrolled(jnp.asarray(a)))
    assert_allclose(l, np.linalg.cholesky(a), rtol=1e-10, atol=1e-10)


@pytest.mark.parametrize("b", [1, 3, 8, 16])
def test_chol_solve_residual(b):
    a = _spd(b, seed=100 + b)
    rng = np.random.default_rng(b)
    rhs = rng.standard_normal(b)
    x = np.asarray(chol_solve(jnp.asarray(a), jnp.asarray(rhs)))
    assert_allclose(a @ x, rhs, rtol=1e-9, atol=1e-9)


@settings(max_examples=20, deadline=None)
@given(b=st.integers(min_value=1, max_value=12),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_chol_solve_hypothesis(b, seed):
    rng = np.random.default_rng(seed)
    m = rng.standard_normal((b, b + 4))
    a = m @ m.T + 0.05 * np.eye(b)
    rhs = rng.standard_normal(b)
    x = np.asarray(chol_solve(jnp.asarray(a), jnp.asarray(rhs)))
    assert_allclose(a @ x, rhs, rtol=1e-8, atol=1e-8)


@pytest.mark.parametrize("sb", [1, 4, 8])
def test_gram_resid_packed_is_the_lower_triangle(sb):
    """The packed artifact entry point must emit exactly the coordinator's
    wire layout: entry (r, c), r ≥ c, at r(r+1)/2 + c — bitwise equal to
    the full kernel's lower triangle (same accumulation, just a gather)."""
    rng = np.random.default_rng(sb)
    nt = 16
    y = jnp.asarray(rng.standard_normal((sb, 4 * nt)))
    z = jnp.asarray(rng.standard_normal(4 * nt))
    g_full, r_full = gram_resid_partial(y, z, nt=nt)
    g_packed, r_packed = gram_resid_packed_partial(y, z, nt=nt)
    assert g_packed.shape == (sb * (sb + 1) // 2,)
    g_full = np.asarray(g_full)
    g_packed = np.asarray(g_packed)
    for r in range(sb):
        for c in range(r + 1):
            assert g_packed[r * (r + 1) // 2 + c] == g_full[r, c], (r, c)
    np.testing.assert_array_equal(np.asarray(r_packed), np.asarray(r_full))


def test_packed_prefix_property_for_smaller_logical_sb():
    """First packed_len(sb) entries of a larger artifact's triangle ARE the
    logical sb-triangle — the layout property the Rust runtime's one-add
    accumulation of zero-padded tiles relies on. (fp tolerance, not
    bitwise: XLA's dot picks a different internal summation order per tile
    height; the runtime itself only ever evaluates the padded shape, so
    its accumulation is self-consistent.)"""
    rng = np.random.default_rng(7)
    nt = 16
    sb_art, sb = 8, 5
    y_small = rng.standard_normal((sb, 2 * nt))
    y_pad = np.zeros((sb_art, 2 * nt))
    y_pad[:sb] = y_small
    z = rng.standard_normal(2 * nt)
    g_small, _ = gram_resid_packed_partial(jnp.asarray(y_small),
                                           jnp.asarray(z), nt=nt)
    g_pad, _ = gram_resid_packed_partial(jnp.asarray(y_pad),
                                         jnp.asarray(z), nt=nt)
    assert_allclose(np.asarray(g_pad)[: sb * (sb + 1) // 2],
                    np.asarray(g_small), rtol=1e-12, atol=1e-12)
    # Every entry past the logical triangle involves a padded (all-zero)
    # row, so the tail is identically zero — padding is exact.
    assert np.all(np.asarray(g_pad)[sb * (sb + 1) // 2:] == 0.0)


def _random_blocks(d, s, b, rng):
    """s sample index blocks (without replacement within a block)."""
    return [rng.choice(d, size=b, replace=False) for _ in range(s)]


def _overlap_tensor(blocks, s, b):
    ov = np.zeros((s, s, b, b))
    for j in range(s):
        for t in range(s):
            ov[j, t] = (blocks[j][:, None] == blocks[t][None, :]).astype(float)
    return ov


def _bcd_step(x, y, w, alpha, idx, lam, n):
    """One step of classical BCD (Algorithm 1) in plain numpy."""
    xi = x[idx, :]                                   # (b, n)
    gamma = xi @ xi.T / n + lam * np.eye(len(idx))
    rhs = -lam * w[idx] - xi @ alpha / n + xi @ y / n
    dw = np.linalg.solve(gamma, rhs)
    w = w.copy()
    np.add.at(w, idx, dw)
    alpha = alpha + xi.T @ dw
    return w, alpha


@pytest.mark.parametrize("s,b", [(2, 3), (4, 4), (8, 2), (3, 8)])
def test_ca_inner_solve_equals_sequential_bcd(s, b):
    """The paper's central claim (§3.1, eq. 8): the unrolled s-step solve
    reproduces s sequential BCD iterations exactly (up to roundoff)."""
    rng = np.random.default_rng(42 + s * b)
    d, n = 30, 64
    x = rng.standard_normal((d, n))
    y = rng.standard_normal(n)
    lam = 0.5
    w = rng.standard_normal(d)
    alpha = x.T @ w

    blocks = _random_blocks(d, s, b, rng)

    # --- sequential BCD, s steps ---
    w_seq, a_seq = w.copy(), alpha.copy()
    for j in range(s):
        w_seq, a_seq = _bcd_step(x, y, w_seq, a_seq, blocks[j], lam, n)

    # --- CA inner solve from (w, alpha) at the start of the outer iter ---
    ystack = np.concatenate([x[blk, :] for blk in blocks], axis=0)  # (s*b, n)
    g_raw = ystack @ ystack.T
    r_raw = ystack @ (y - alpha)
    w_blk = np.stack([w[blk] for blk in blocks])
    ov = _overlap_tensor(blocks, s, b)
    deltas = np.asarray(ca_inner_solve(
        jnp.asarray(g_raw), jnp.asarray(r_raw), jnp.asarray(w_blk),
        jnp.asarray(ov), lam, 1.0 / n))

    w_ca = w.copy()
    for j in range(s):
        np.add.at(w_ca, blocks[j], deltas[j])
    a_ca = alpha + ystack.T @ deltas.reshape(-1)

    assert_allclose(w_ca, w_seq, rtol=1e-9, atol=1e-10)
    assert_allclose(a_ca, a_seq, rtol=1e-9, atol=1e-10)


def _bdcd_step(x, y, w, alpha, idx, lam, n):
    """One step of classical BDCD (Algorithm 3 / eq. 17) in plain numpy."""
    xi = x[:, idx]                                    # (d, b')
    theta = xi.T @ xi / (lam * n * n) + np.eye(len(idx)) / n
    rhs = -xi.T @ w + alpha[idx] + y[idx]
    da = -np.linalg.solve(theta, rhs) / n
    alpha = alpha.copy()
    np.add.at(alpha, idx, da)
    w = w - xi @ da / (lam * n)
    return w, alpha


@pytest.mark.parametrize("s,b", [(2, 3), (4, 4), (3, 8)])
def test_ca_dual_inner_solve_equals_sequential_bdcd(s, b):
    """Dual counterpart of the unrolling claim (§3.2, eq. 18)."""
    rng = np.random.default_rng(17 + s * b)
    d, n = 40, 50
    x = rng.standard_normal((d, n))
    y = rng.standard_normal(n)
    lam = 0.8
    alpha = rng.standard_normal(n)
    w = -x @ alpha / (lam * n)                        # eq. 12 coupling

    blocks = _random_blocks(n, s, b, rng)

    w_seq, a_seq = w.copy(), alpha.copy()
    for j in range(s):
        w_seq, a_seq = _bdcd_step(x, y, w_seq, a_seq, blocks[j], lam, n)

    # CA path: Y = (X·[I_1..I_s])ᵀ, raw Gram and raw residual.
    ystack = np.concatenate([x[:, blk].T for blk in blocks], axis=0)  # (s*b, d)
    g_raw = ystack @ ystack.T
    r_raw = ystack @ w
    a_blk = np.stack([alpha[blk] for blk in blocks])
    y_blk = np.stack([y[blk] for blk in blocks])
    ov = _overlap_tensor(blocks, s, b)
    deltas = np.asarray(ca_dual_inner_solve(
        jnp.asarray(g_raw), jnp.asarray(r_raw), jnp.asarray(a_blk),
        jnp.asarray(y_blk), jnp.asarray(ov), lam, 1.0 / n))

    a_ca = alpha.copy()
    for j in range(s):
        np.add.at(a_ca, blocks[j], deltas[j])
    w_ca = w - ystack.T @ deltas.reshape(-1) / (lam * n)

    assert_allclose(a_ca, a_seq, rtol=1e-9, atol=1e-10)
    assert_allclose(w_ca, w_seq, rtol=1e-9, atol=1e-10)


def test_ca_inner_solve_matches_ref():
    rng = np.random.default_rng(5)
    s, b, n = 4, 6, 200
    m = rng.standard_normal((s * b, n))
    g_raw = m @ m.T
    r_raw = rng.standard_normal(s * b)
    w_blk = rng.standard_normal((s, b))
    ov = (rng.random((s, s, b, b)) < 0.05).astype(float)
    lam, inv_n = 0.3, 1.0 / n
    d1 = np.asarray(ca_inner_solve(jnp.asarray(g_raw), jnp.asarray(r_raw),
                                   jnp.asarray(w_blk), jnp.asarray(ov),
                                   lam, inv_n))
    g = inv_n * g_raw + lam * np.eye(s * b)
    r0 = -lam * w_blk + inv_n * r_raw.reshape(s, b)
    d2 = np.asarray(ca_inner_solve_ref(jnp.asarray(g), jnp.asarray(ov),
                                       jnp.asarray(r0), lam))
    assert_allclose(d1, d2, rtol=1e-12, atol=1e-12)


def test_s_equals_one_is_plain_bcd_subproblem():
    """With s=1 the inner solve degenerates to the classical Γ⁻¹·residual."""
    rng = np.random.default_rng(9)
    b, n = 8, 100
    m = rng.standard_normal((b, n))
    g_raw = m @ m.T
    r_raw = rng.standard_normal(b)
    w_blk = rng.standard_normal((1, b))
    ov = np.eye(b)[None, None]
    lam, inv_n = 0.7, 1.0 / n
    d = np.asarray(ca_inner_solve(jnp.asarray(g_raw), jnp.asarray(r_raw),
                                  jnp.asarray(w_blk), jnp.asarray(ov),
                                  lam, inv_n))[0]
    gamma = inv_n * g_raw + lam * np.eye(b)
    expect = np.linalg.solve(gamma, -lam * w_blk[0] + inv_n * r_raw)
    assert_allclose(d, expect, rtol=1e-11, atol=1e-12)


def test_alpha_update_partial():
    rng = np.random.default_rng(3)
    y = rng.standard_normal((12, 64))
    d = rng.standard_normal(12)
    out = np.asarray(alpha_update_partial(jnp.asarray(y), jnp.asarray(d)))
    assert_allclose(out, y.T @ d, rtol=1e-12, atol=1e-12)
