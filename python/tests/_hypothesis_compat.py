"""Optional-dependency shim for `hypothesis`.

The offline CI image has no hypothesis wheel; importing it unconditionally
made the whole module fail collection and took the deterministic tests down
with it. Importing `given`/`settings`/`st` from here keeps the
deterministic tests running everywhere: with hypothesis installed the real
decorators pass through, without it the property sweeps turn into cleanly
skipped tests.

Set ``CABCD_REQUIRE_HYPOTHESIS=1`` (the CI default) to make a missing
wheel a hard ImportError instead of silent skips — the shim must never
mask absent property coverage on a machine that claims to provide it.
"""

import os

import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only on minimal images
    if os.environ.get("CABCD_REQUIRE_HYPOTHESIS", "").lower() not in ("", "0", "false"):
        raise
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for `hypothesis.strategies`: any strategy constructor
        returns None; the values are never drawn because the test body is
        replaced by a skip."""

        def __getattr__(self, _name):
            return lambda *args, **kwargs: None

    st = _AnyStrategy()

    def given(*_args, **_kwargs):
        def decorate(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def skipped(*a, **k):  # pragma: no cover
                raise AssertionError("skipped test body executed")

            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped

        return decorate

    def settings(*_args, **_kwargs):
        return lambda fn: fn
