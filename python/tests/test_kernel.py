"""L1 Pallas kernel vs pure-jnp oracle — the core correctness signal."""

import numpy as np
import jax.numpy as jnp
import pytest
from numpy.testing import assert_allclose

from tests._hypothesis_compat import given, settings, st

from compile.kernels.gram import gram_resid, vmem_report, DEFAULT_NT
from compile.kernels.ref import gram_resid_ref


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


@pytest.mark.parametrize("sb", [1, 4, 16, 64])
@pytest.mark.parametrize("nloc,nt", [(512, 512), (2048, 512), (1024, 256)])
def test_gram_resid_matches_ref_f64(sb, nloc, nt):
    y = _rand((sb, nloc), jnp.float64, seed=sb * nloc)
    z = _rand((nloc,), jnp.float64, seed=sb + nloc)
    g, r = gram_resid(y, z, nt=nt)
    gr, rr = gram_resid_ref(y, z)
    assert_allclose(np.asarray(g), np.asarray(gr), rtol=1e-12, atol=1e-12)
    assert_allclose(np.asarray(r), np.asarray(rr), rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("dtype,rtol", [(jnp.float32, 2e-4), (jnp.float64, 1e-12)])
def test_gram_resid_dtypes(dtype, rtol):
    y = _rand((32, 1024), dtype, seed=7)
    z = _rand((1024,), dtype, seed=8)
    g, r = gram_resid(y, z, nt=512)
    gr, rr = gram_resid_ref(y, z)
    assert g.dtype == dtype and r.dtype == dtype
    assert_allclose(np.asarray(g), np.asarray(gr), rtol=rtol, atol=rtol)
    assert_allclose(np.asarray(r), np.asarray(rr), rtol=rtol, atol=rtol)


def test_gram_is_symmetric_psd():
    y = _rand((24, 2048), jnp.float64, seed=3)
    z = jnp.zeros((2048,), jnp.float64)
    g, r = gram_resid(y, z)
    g = np.asarray(g)
    assert_allclose(g, g.T, rtol=0, atol=1e-12)
    evals = np.linalg.eigvalsh(g)
    assert evals.min() >= -1e-10
    assert_allclose(np.asarray(r), 0.0)


def test_zero_padding_is_exact():
    """Padding the final column chunk with zeros must not change outputs."""
    y = _rand((16, 768), jnp.float64, seed=11)
    z = _rand((768,), jnp.float64, seed=12)
    ypad = jnp.concatenate([y, jnp.zeros((16, 256), jnp.float64)], axis=1)
    zpad = jnp.concatenate([z, jnp.zeros((256,), jnp.float64)])
    g1, r1 = gram_resid(y, z, nt=256)
    g2, r2 = gram_resid(ypad, zpad, nt=256)
    assert_allclose(np.asarray(g1), np.asarray(g2), rtol=0, atol=0)
    assert_allclose(np.asarray(r1), np.asarray(r2), rtol=0, atol=0)


def test_nt_must_divide_nloc():
    y = _rand((4, 100), jnp.float64, seed=1)
    z = _rand((100,), jnp.float64, seed=2)
    with pytest.raises(ValueError, match="multiple of nt"):
        gram_resid(y, z, nt=512)


@settings(max_examples=25, deadline=None)
@given(
    sb=st.integers(min_value=1, max_value=48),
    chunks=st.integers(min_value=1, max_value=4),
    nt=st.sampled_from([64, 128, 256]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_gram_resid_hypothesis_sweep(sb, chunks, nt, seed):
    """Property sweep over shapes: kernel ≡ oracle for any (sb, nloc, nt)."""
    nloc = chunks * nt
    y = _rand((sb, nloc), jnp.float64, seed=seed)
    z = _rand((nloc,), jnp.float64, seed=seed + 1)
    g, r = gram_resid(y, z, nt=nt)
    gr, rr = gram_resid_ref(y, z)
    assert_allclose(np.asarray(g), np.asarray(gr), rtol=1e-11, atol=1e-11)
    assert_allclose(np.asarray(r), np.asarray(rr), rtol=1e-11, atol=1e-11)


@settings(max_examples=15, deadline=None)
@given(
    sb=st.integers(min_value=1, max_value=32),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_gram_linearity_in_z(sb, seed):
    """r = Y z is linear in z; G is independent of z (fusion is side-effect
    free)."""
    rng = np.random.default_rng(seed)
    y = jnp.asarray(rng.standard_normal((sb, 256)))
    z1 = jnp.asarray(rng.standard_normal(256))
    z2 = jnp.asarray(rng.standard_normal(256))
    g1, r1 = gram_resid(y, z1, nt=128)
    g2, r2 = gram_resid(y, z2, nt=128)
    g3, r3 = gram_resid(y, z1 + 2.0 * z2, nt=128)
    assert_allclose(np.asarray(g1), np.asarray(g2), rtol=0, atol=0)
    assert_allclose(np.asarray(g1), np.asarray(g3), rtol=0, atol=0)
    assert_allclose(np.asarray(r3), np.asarray(r1) + 2.0 * np.asarray(r2),
                    rtol=1e-10, atol=1e-10)


def test_vmem_report_structure():
    r = vmem_report(64, DEFAULT_NT, itemsize=8)
    assert r["fits_16mib"]
    assert 0 < r["mxu_fill"] <= 1
    assert r["vmem_bytes"] == 64 * 512 * 8 + 512 * 8 + 64 * 64 * 8 + 64 * 8
