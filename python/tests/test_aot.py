"""AOT pipeline tests: HLO text artifacts parse, contain no custom-calls
(the Rust runtime has no jaxlib FFI registry), and numerically round-trip
through the local CPU PJRT client exactly as the jitted function does.
"""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from numpy.testing import assert_allclose

from jax._src.lib import xla_client as xc

from compile import aot, model


@pytest.fixture(scope="module")
def small_artifacts(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    man = aot.build_all(out, gram_shapes=[(8, 1024)], solve_shapes=[(2, 4)],
                        verbose=False)
    return out, man


def test_manifest_schema(small_artifacts):
    out, man = small_artifacts
    assert man["dtype"] == "f64"
    names = {a["name"] for a in man["artifacts"]}
    assert names == {"gram_resid_packed_sb8_n1024", "alpha_update_sb8_n1024",
                     "inner_solve_s2_b4", "dual_inner_solve_s2_b4"}
    kinds = {a["kind"] for a in man["artifacts"]}
    assert "gram_resid_packed" in kinds
    assert "gram_resid" not in kinds  # obsolete full-matrix layout
    with open(os.path.join(out, "manifest.json")) as f:
        assert json.load(f) == man


def test_artifacts_have_no_custom_calls(small_artifacts):
    out, man = small_artifacts
    for a in man["artifacts"]:
        text = open(os.path.join(out, a["file"])).read()
        assert "custom-call" not in text, f"{a['name']} has a custom-call"
        assert text.startswith("HloModule")


def test_artifacts_parse_as_hlo(small_artifacts):
    """HLO text must re-parse (the Rust runtime uses XLA's text parser;
    execution parity native-vs-XLA is covered by the Rust integration
    tests, which run on the exact xla_extension 0.5.1 the paper repo
    ships against)."""
    out, man = small_artifacts
    for a in man["artifacts"]:
        text = open(os.path.join(out, a["file"])).read()
        mod = xc._xla.hlo_module_from_text(text)
        assert mod.name.startswith("jit_")


def test_gram_artifact_declares_expected_io(small_artifacts):
    out, _ = small_artifacts
    text = open(os.path.join(out,
                             "gram_resid_packed_sb8_n1024.hlo.txt")).read()
    # entry layout: (Y[8,1024], z[1024]) -> (Gpacked[36], r[8]) — G ships
    # as its packed lower triangle (sb(sb+1)/2 = 36 words), the
    # coordinator's wire/solve format end-to-end.
    assert "f64[8,1024]" in text
    assert "(f64[36]{0},f64[8]{0})" in text.replace(" ", "")


def test_inner_solve_artifact_declares_expected_io(small_artifacts):
    out, _ = small_artifacts
    text = open(os.path.join(out, "inner_solve_s2_b4.hlo.txt")).read()
    assert "f64[8,8]" in text          # G_raw (s*b = 8)
    assert "f64[2,2,4,4]" in text      # overlap tensor
    assert "f64[2,4]" in text          # deltas out / w_blocks in


def test_vmem_report_all_default_shapes_fit():
    for sb, _ in aot.GRAM_SHAPES:
        from compile.kernels.gram import vmem_report
        assert vmem_report(sb, aot.NT, itemsize=8)["fits_16mib"]
