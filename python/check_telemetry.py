#!/usr/bin/env python3
"""Schema checker for the telemetry JSON snapshot dump + Prometheus text.

CI runs the lasso example with ``--telemetry`` and validates both emitted
files here: the Rust exporters are hand-rolled (no serde in the offline
vendor set), so a malformed envelope, a drifting key, or a broken
cumulative-bucket invariant would otherwise only surface when someone
points a scraper at the exposition months later.

JSON checks (``<telemetry.json>``):
  * parses; ``ranks``/``registry_words``/``snapshot_words`` are consistent
    (``snapshot_words == ranks * registry_words``);
  * ``z_threshold`` > 0, ``min_dev_ns`` >= 0;
  * at least one snapshot, each with monotone non-decreasing ``outer``,
    a per-rank health list of length ``ranks`` plus a ``"fleet"`` rollup,
    every health block carrying the full key set with non-negative
    numbers and ``p50 <= p99`` quantile pairs;
  * every straggler flag names a valid rank and an op from the detector
    taxonomy (``gram``/``wait``), and ``straggler_flags`` equals the sum
    over snapshots;
  * the hot-path tripwires hold: ``telemetry_allocs == 0`` and
    ``dropped_snapshots == 0``.

Prometheus checks (``<telemetry.prom>``, default: JSON path with a
``.prom`` extension):
  * exposition-format 0.0.4 lines only (``# HELP``/``# TYPE`` comments and
    ``name{labels} value`` samples);
  * every metric family of the registry taxonomy is declared with the
    right type (counters ``cabcd_*_total``, gauges, histograms);
  * every sample carries a ``rank`` label covering all ranks;
  * histogram bucket series are cumulative (non-decreasing in ``le``)
    and end with ``+Inf == _count``.

Usage: python3 python/check_telemetry.py <telemetry.json> [<telemetry.prom>]
"""

from __future__ import annotations

import json
import re
import sys

PREFIX = "cabcd"
COUNTERS = [
    "outers",
    "inners",
    "records",
    "collectives",
    "retries",
    "timeouts",
    "ckpt_saves",
    "ckpt_restores",
]
GAUGES = ["last_outer", "last_h", "inflight_ns", "payload_words"]
HISTS = [
    "gram_ns",
    "inner_solve_ns",
    "apply_ns",
    "sample_ns",
    "allreduce_ns",
    "all_to_all_ns",
    "barrier_ns",
    "wait_ns",
    "allreduce_words",
    "all_to_all_words",
    "ckpt_save_ns",
    "ckpt_restore_ns",
]
STRAGGLER_OPS = {"gram", "wait"}
HEALTH_KEYS = {
    "rank",
    "wall_ns",
    "compute_ns",
    "wire_ns",
    "idle_ns",
    "wire_words",
    "gram",
    "allreduce",
    "all_to_all",
    "barrier",
    "wait",
}
QUANTILE_KEYS = ("gram", "allreduce", "all_to_all", "barrier", "wait")

SAMPLE_RE = re.compile(r'^([a-z_0-9]+)\{([^}]*)\}\s+(\S+)$')
LABEL_RE = re.compile(r'(\w+)="([^"]*)"')


def fail(msg: str) -> None:
    print(f"check_telemetry: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def num(obj: dict, key: str, ctx: str) -> float:
    v = obj.get(key)
    if not isinstance(v, (int, float)) or isinstance(v, bool):
        fail(f"{ctx}: {key} is {v!r}, want a number")
    return v


def check_health(rh: object, ranks: int, fleet: bool, ctx: str) -> None:
    if not isinstance(rh, dict):
        fail(f"{ctx}: health block is not an object")
    missing = HEALTH_KEYS - rh.keys()
    if missing:
        fail(f"{ctx}: health keys missing: {sorted(missing)}")
    if fleet:
        if rh.get("rank") != "fleet":
            fail(f"{ctx}: fleet rollup rank is {rh.get('rank')!r}")
    else:
        r = rh.get("rank")
        if not isinstance(r, int) or not 0 <= r < ranks:
            fail(f"{ctx}: rank {r!r} outside 0..{ranks}")
    for key in ("wall_ns", "compute_ns", "wire_ns", "idle_ns", "wire_words"):
        if num(rh, key, ctx) < 0:
            fail(f"{ctx}: negative {key}")
    for key in QUANTILE_KEYS:
        q = rh.get(key)
        if not isinstance(q, dict):
            fail(f"{ctx}: {key} quantiles missing")
        p50, p99 = num(q, "p50", f"{ctx}.{key}"), num(q, "p99", f"{ctx}.{key}")
        if not 0 <= p50 <= p99:
            fail(f"{ctx}: {key} quantiles disordered (p50={p50}, p99={p99})")


def check_json(path: str) -> int:
    with open(path, encoding="utf-8") as f:
        try:
            doc = json.load(f)
        except json.JSONDecodeError as e:
            fail(f"{path} is not valid JSON: {e}")
    if not isinstance(doc, dict):
        fail("top level is not an object")

    ranks = doc.get("ranks")
    if not isinstance(ranks, int) or ranks < 1:
        fail(f"ranks is {ranks!r}, want a positive integer")
    registry_words = num(doc, "registry_words", "doc")
    snapshot_words = num(doc, "snapshot_words", "doc")
    if snapshot_words != ranks * registry_words:
        fail(
            f"snapshot_words {snapshot_words} != ranks {ranks} × "
            f"registry_words {registry_words}"
        )
    if num(doc, "z_threshold", "doc") <= 0:
        fail("z_threshold must be > 0")
    if num(doc, "min_dev_ns", "doc") < 0:
        fail("min_dev_ns must be >= 0")

    snaps = doc.get("snapshots")
    if not isinstance(snaps, list) or not snaps:
        fail("snapshots missing, not a list, or empty")
    prev_outer = -1.0
    flags = 0
    for i, snap in enumerate(snaps):
        ctx = f"snapshots[{i}]"
        if not isinstance(snap, dict):
            fail(f"{ctx} is not an object")
        outer = num(snap, "outer", ctx)
        if outer < prev_outer:
            fail(f"{ctx}: outer {outer} went backwards (prev {prev_outer})")
        prev_outer = outer
        num(snap, "h", ctx)
        num(snap, "at_collective", ctx)
        rank_healths = snap.get("ranks")
        if not isinstance(rank_healths, list) or len(rank_healths) != ranks:
            fail(f"{ctx}: per-rank health list is not {ranks} entries")
        for j, rh in enumerate(rank_healths):
            check_health(rh, ranks, False, f"{ctx}.ranks[{j}]")
        check_health(snap.get("fleet"), ranks, True, f"{ctx}.fleet")
        stragglers = snap.get("stragglers")
        if not isinstance(stragglers, list):
            fail(f"{ctx}: stragglers is not a list")
        for j, s in enumerate(stragglers):
            sctx = f"{ctx}.stragglers[{j}]"
            if not isinstance(s, dict):
                fail(f"{sctx} is not an object")
            r = s.get("rank")
            if not isinstance(r, int) or not 0 <= r < ranks:
                fail(f"{sctx}: rank {r!r} outside 0..{ranks}")
            if s.get("op") not in STRAGGLER_OPS:
                fail(f"{sctx}: op {s.get('op')!r} not in {sorted(STRAGGLER_OPS)}")
            num(s, "z", sctx)
            num(s, "dev_ns", sctx)
            num(s, "at_collective", sctx)
        flags += len(stragglers)

    if num(doc, "straggler_flags", "doc") != flags:
        fail(f"straggler_flags {doc['straggler_flags']} != counted {flags}")
    if num(doc, "dropped_snapshots", "doc") != 0:
        fail(f"dropped_snapshots = {doc['dropped_snapshots']} (ring overflowed)")
    if num(doc, "telemetry_allocs", "doc") != 0:
        fail(f"telemetry_allocs = {doc['telemetry_allocs']} (hot path allocated)")

    print(
        f"check_telemetry: OK: {path}: {len(snaps)} snapshot(s) over {ranks} "
        f"rank(s), {flags} straggler flag(s)"
    )
    return ranks


def check_prom(path: str, ranks: int) -> None:
    with open(path, encoding="utf-8") as f:
        text = f.read()
    if not text.endswith("\n"):
        fail(f"{path}: exposition does not end with a newline")

    declared: dict[str, str] = {}
    # family -> rank label -> list of (le, cumulative count) / scalar samples
    buckets: dict[tuple[str, str], list[tuple[str, float]]] = {}
    tails: dict[tuple[str, str], dict[str, float]] = {}
    seen_ranks: dict[str, set[str]] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line:
            fail(f"{path}:{lineno}: blank line in exposition")
        if line.startswith("# HELP ") or line.startswith("# TYPE "):
            parts = line.split(maxsplit=3)
            if len(parts) < 4:
                fail(f"{path}:{lineno}: malformed comment {line!r}")
            if parts[1] == "TYPE":
                declared[parts[2]] = parts[3]
            continue
        m = SAMPLE_RE.match(line)
        if not m:
            fail(f"{path}:{lineno}: unparsable sample {line!r}")
        name, labels_raw, value_raw = m.groups()
        labels = dict(LABEL_RE.findall(labels_raw))
        if "rank" not in labels:
            fail(f"{path}:{lineno}: sample {name} has no rank label")
        try:
            value = float(value_raw)
        except ValueError:
            fail(f"{path}:{lineno}: value {value_raw!r} is not a number")
        if value < 0:
            fail(f"{path}:{lineno}: negative sample {line!r}")
        family = re.sub(r"_(bucket|sum|count)$", "", name)
        seen_ranks.setdefault(family, set()).add(labels["rank"])
        if name.endswith("_bucket"):
            if "le" not in labels:
                fail(f"{path}:{lineno}: bucket sample has no le label")
            buckets.setdefault((family, labels["rank"]), []).append(
                (labels["le"], value)
            )
        elif name.endswith(("_sum", "_count")) and family in {
            f"{PREFIX}_{h}" for h in HISTS
        }:
            tails.setdefault((family, labels["rank"]), {})[
                name.rsplit("_", 1)[1]
            ] = value

    expect = (
        [(f"{PREFIX}_{c}_total", "counter") for c in COUNTERS]
        + [(f"{PREFIX}_{g}", "gauge") for g in GAUGES]
        + [(f"{PREFIX}_{h}", "histogram") for h in HISTS]
    )
    want_ranks = {str(r) for r in range(ranks)}
    for family, kind in expect:
        if declared.get(family) != kind:
            fail(f"{family}: declared {declared.get(family)!r}, want {kind!r}")
        if seen_ranks.get(family) != want_ranks:
            fail(
                f"{family}: rank labels {sorted(seen_ranks.get(family, set()))} "
                f"!= {sorted(want_ranks)}"
            )
    for h in HISTS:
        family = f"{PREFIX}_{h}"
        for rank in want_ranks:
            series = buckets.get((family, rank))
            if not series:
                fail(f"{family}{{rank={rank}}}: no bucket series")
            if series[-1][0] != "+Inf":
                fail(f"{family}{{rank={rank}}}: last bucket le != +Inf")
            counts = [v for _, v in series]
            if counts != sorted(counts):
                fail(f"{family}{{rank={rank}}}: buckets not cumulative")
            tail = tails.get((family, rank), {})
            if tail.get("count") != counts[-1]:
                fail(
                    f"{family}{{rank={rank}}}: _count {tail.get('count')} != "
                    f"+Inf bucket {counts[-1]}"
                )
            if "sum" not in tail:
                fail(f"{family}{{rank={rank}}}: _sum series missing")

    print(
        f"check_telemetry: OK: {path}: {len(expect)} metric families over "
        f"{ranks} rank(s)"
    )


def main() -> None:
    if len(sys.argv) not in (2, 3):
        fail("usage: check_telemetry.py <telemetry.json> [<telemetry.prom>]")
    json_path = sys.argv[1]
    prom_path = (
        sys.argv[2] if len(sys.argv) == 3 else re.sub(r"\.[^./]*$", "", json_path) + ".prom"
    )
    ranks = check_json(json_path)
    check_prom(prom_path, ranks)


if __name__ == "__main__":
    main()
