#!/usr/bin/env python3
"""Generate rust/tests/fixtures/engine_schedules.tsv.

Mirrors the abstract collective schedule that `engine::drive`
(rust/src/engine/step.rs) executes for each of the 48 configurations of
rust/tests/engine_equivalence.rs, in the token grammar of
`cabcd::analysis::spec::SpecEvent::token`:

    A<tag>/<len>    blocking allreduce             S<tag>/<len>  non-blocking start
    W<tag>          allreduce wait (tag of start)  X<tag>/<recv> blocking all-to-all
    Y<tag>/<recv>   all-to-all start               Z<tag>        all-to-all wait
    m<prefix>       metered (meter-excluded diagnostic traffic)

Tags mirror ThreadComm's per-endpoint op sequence: every collective
*entry* (blocking or start, metered or not, any P) pre-increments the
counter; waits carry the tag of the operation they complete. All-to-all
tokens carry the total receive-contract words (send splits are
rank-dependent by Lemma 3 and checked cross-rank by the checker, not
pinned here). Streams are rank-identical, so one row pins every rank.

The schedule model below restates, method by method, exactly which
callbacks issue collectives (see the corresponding CaStep impls):

  matched (bcd, bdcd, prox_bcd, prox_bdcd): the engine's one [G|r]
    reduction per outer iteration (A blocking, S/W prefetch-overlap);
    record() = one metered allreduce (bcd 1 word, bdcd n+2, prox_bcd
    d+2, prox_bdcd n+1; prox records unconditionally, bcd/bdcd under a
    reference — always present in the fixture runs); bdcd/prox_bdcd end
    with a metered d-word w gather after drive().
  cocoa: non-prefetch — one d-word reduction per round (A or S/W);
    record() = one metered scalar allreduce.
  bcdrow: record() = metered 3-word allreduce; final metered d-word
    gather. Blocking: per iteration, metered P-word Lemma-3 load
    allreduce, blocking exchange X, then A. Overlap (pipeline): the
    look-ahead posts exchange k+1 (Y + metered load) while draining
    k (Z) under the in-flight [G|r|w] reduction (S/W).

Run:  python3 python/gen_engine_schedules.py  (from the repo root)
"""

import os

D, N, B, ITERS, RECORD_EVERY = 12, 48, 2, 16, 4

METHODS = ["bcd", "bdcd", "bcdrow", "cocoa", "prox_bcd", "prox_bdcd"]


class Stream:
    """Rank-0 event stream with ThreadComm tag discipline."""

    def __init__(self):
        self.t = 0
        self.ar_fifo = []   # tags of in-flight iallreduces
        self.a2a_fifo = []  # tags of in-flight all-to-alls
        self.ev = []

    def _begin(self):
        self.t += 1
        return self.t

    def allreduce(self, ln, metered=False):
        self.ev.append(f"{'m' if metered else ''}A{self._begin()}/{ln}")

    def istart(self, ln):
        tag = self._begin()
        self.ar_fifo.append(tag)
        self.ev.append(f"S{tag}/{ln}")

    def iwait(self):
        self.ev.append(f"W{self.ar_fifo.pop(0)}")

    def a2a(self, recv_total, metered=False):
        self.ev.append(f"{'m' if metered else ''}X{self._begin()}/{recv_total}")

    def ia2a_start(self, recv_total):
        tag = self._begin()
        self.a2a_fifo.append(tag)
        self.ev.append(f"Y{tag}/{recv_total}")

    def ia2a_wait(self):
        self.ev.append(f"Z{self.a2a_fifo.pop(0)}")


def should_record(h_now, s):
    # solvers::common::should_record with record_every = 4.
    re = max(RECORD_EVERY, s)
    return h_now % (max(re // s, 1) * s) == 0


def packed_len(sb):
    return sb * (sb + 1) // 2


def record_len(method):
    return {
        "bcd": 1,
        "bdcd": N + 2,
        "bcdrow": 3,
        "cocoa": 1,
        "prox_bcd": D + 2,
        "prox_bdcd": N + 1,
    }[method]


def gen(method, s, overlap, p):
    st = Stream()
    rec = lambda: st.allreduce(record_len(method), metered=True)

    if method == "cocoa":
        # CocoaStep drives with SolverOpts{s=1,b=1}; `s` is local_iters,
        # which never touches the wire. Non-prefetch, d-word payload.
        outer, eff_s, total = ITERS, 1, D
        prefetch = False
    elif method == "bcdrow":
        sb = s * B
        outer, eff_s, total = ITERS // s, s, packed_len(sb) + 2 * sb
        prefetch = overlap  # pipeline = overlap && tol.is_none()
    else:
        sb = s * B
        outer, eff_s, total = ITERS // s, s, packed_len(sb) + sb
        prefetch = overlap

    n_loc = N // p
    recv_total = (s if method == "bcdrow" else 0) * B * n_loc

    def post_exchange():  # BcdRowStep::post_exchange
        st.ia2a_start(recv_total)
        st.allreduce(p, metered=True)  # Lemma-3 load meter

    rec()  # drive(): step.record(comm, history, 0)

    if method == "bcdrow" and prefetch:
        # Prologue: sample(0) posts exchange 0; local_gram(0) drains it
        # and posts the look-ahead exchange for iteration 1.
        post_exchange()
        st.ia2a_wait()
        if outer > 1:
            post_exchange()
        for k in range(outer):
            st.istart(total)  # the [G|r|w] reduction
            if k + 1 < outer:
                # engine pending block: sample(k+1) returns the look-ahead
                # (no comm); local_gram(k+1) drains exchange k+1 and, if
                # k+2 exists, posts its exchange.
                st.ia2a_wait()
                if k + 2 < outer:
                    post_exchange()
            st.iwait()
            if should_record((k + 1) * eff_s, eff_s) or k + 1 == outer:
                rec()
    elif method == "bcdrow":
        # Blocking: local_payload = metered load allreduce, blocking
        # exchange, then the engine's blocking reduction.
        for k in range(outer):
            st.allreduce(p, metered=True)
            st.a2a(recv_total)
            st.allreduce(total)
            if should_record((k + 1) * eff_s, eff_s) or k + 1 == outer:
                rec()
    else:
        # Matched methods and cocoa: the only loop collective is the
        # engine's reduction (prefetch and non-prefetch overlap produce
        # the same S/W stream — sampling and gram are communication-free).
        for k in range(outer):
            if overlap:
                st.istart(total)
                st.iwait()
            else:
                st.allreduce(total)
            if should_record((k + 1) * eff_s, eff_s) or k + 1 == outer:
                rec()

    if method in ("bdcd", "bcdrow", "prox_bdcd"):
        st.allreduce(D, metered=True)  # final metered w gather

    assert not st.ar_fifo and not st.a2a_fifo, (method, s, overlap, p)
    return st.ev


def main():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out_path = os.path.join(root, "rust", "tests", "fixtures", "engine_schedules.tsv")
    rows = []
    for method in METHODS:
        for s in ([2, 8] if method == "cocoa" else [1, 4]):
            for overlap in (False, True):
                for p in (1, 4):
                    ev = gen(method, s, overlap, p)
                    rows.append(
                        f"{method}\t{s}\t{str(overlap).lower()}\t{p}"
                        f"\t{len(ev)}\t{' '.join(ev)}"
                    )
    header = [
        "# Golden per-rank collective schedules (PR 7), one row per",
        "# engine_equivalence.rs config, token grammar of",
        "# cabcd::analysis::spec::SpecEvent::token (A/S/W allreduce,",
        "# X/Y/Z all-to-all by total recv words, m = metered).",
        "# Streams are rank-identical (checker invariant (a)), so one row",
        "# pins every rank. Regenerate: python3 python/gen_engine_schedules.py",
        "# method\ts\toverlap\tp\tn_events\tevents",
    ]
    with open(out_path, "w") as f:
        f.write("\n".join(header) + "\n")
        f.write("\n".join(rows) + "\n")
    print(f"wrote {len(rows)} rows -> {out_path}")


if __name__ == "__main__":
    main()
