//! Observability suite for the per-rank span tracer (PR 6).
//!
//! Three gates:
//!
//! 1. **Observer neutrality** — running any method with a tracer
//!    installed must leave the iterates, the history records, and every
//!    CostMeter field bitwise identical to the untraced run. The tracer
//!    reads the clock and appends to a preallocated ring; it must never
//!    touch the numerics or the wire.
//! 2. **Span/meter cross-check** — per rank, the number of
//!    `CollectiveStart` spans per class equals the meter's collective
//!    counts exactly, and under overlap the `CollectiveWait` spans equal
//!    the new `collective_waits` counter (one deferred completion per
//!    posted non-blocking collective; 0 under the blocking schedule).
//! 3. **Steady-state zero-alloc** — the ring never grows (`trace_allocs
//!    == 0`), wraps in place when full, and drops the oldest spans with
//!    an exact `dropped` count.
//!
//! Plus the PR's acceptance criterion: a P=4 overlapped CA-BCD run must
//! report strictly positive overlap efficiency (some of each in-flight
//! allreduce window is covered by prefetched Gram compute).

use cabcd::comm::thread::run_spmd;
use cabcd::comm::{ChaosComm, ChaosSpec, Communicator, SerialComm};
use cabcd::coordinator::{partition_dual, partition_primal, partition_rows};
use cabcd::matrix::io::Dataset;
use cabcd::matrix::{DenseMatrix, Matrix};
use cabcd::metrics::{History, Reference};
use cabcd::prox::Reg;
use cabcd::solvers::cocoa::CocoaOpts;
use cabcd::solvers::{cg, SolverOpts};
use cabcd::trace::{self, OpClass, Span, SpanKind, TraceSummary, Tracer};

const LAM: f64 = 0.2;
const ITERS: usize = 16;
const SEED: u64 = 7;
const B: usize = 2;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum M {
    Bcd,
    Bdcd,
    BcdRow,
    Cocoa,
    ProxBcd,
    ProxBdcd,
}

impl M {
    const ALL: [M; 6] = [M::Bcd, M::Bdcd, M::BcdRow, M::Cocoa, M::ProxBcd, M::ProxBdcd];

    fn id(self) -> &'static str {
        match self {
            M::Bcd => "bcd",
            M::Bdcd => "bdcd",
            M::BcdRow => "bcdrow",
            M::Cocoa => "cocoa",
            M::ProxBcd => "prox_bcd",
            M::ProxBdcd => "prox_bdcd",
        }
    }
}

fn toy_dataset() -> Dataset {
    let (d, n) = (12usize, 48usize);
    let mut st = 0x5EED5EEDu64;
    let data: Vec<f64> = (0..d * n)
        .map(|_| {
            st ^= st << 13;
            st ^= st >> 7;
            st ^= st << 17;
            (st as f64 / u64::MAX as f64) - 0.5
        })
        .collect();
    let x = Matrix::Dense(DenseMatrix::from_vec(d, n, data));
    let mut y = vec![0.0; n];
    let mut w_star = vec![0.0; d];
    w_star[0] = 1.5;
    w_star[d / 2] = -2.0;
    w_star[d - 1] = 0.75;
    x.matvec_t(&w_star, &mut y).unwrap();
    Dataset {
        name: "trace-suite".into(),
        x,
        y,
    }
}

fn reference(ds: &Dataset) -> Reference {
    let mut comm = SerialComm::new();
    cg::compute_reference(&ds.x, &ds.y, ds.n(), LAM, &mut comm).unwrap()
}

fn solver_opts(m: M, s: usize, overlap: bool) -> SolverOpts {
    let reg = match m {
        M::ProxBcd | M::ProxBdcd => Reg::L1,
        _ => Reg::L2,
    };
    SolverOpts::builder()
        .b(B)
        .s(s)
        .lam(LAM)
        .iters(ITERS)
        .seed(SEED)
        .record_every(4)
        .overlap(overlap)
        .reg(reg)
        .build()
}

/// One rank's output: concatenated iterate vectors, the history, and the
/// tracer (when `traced`).
struct RankOut {
    vecs: Vec<f64>,
    history: History,
    tracer: Option<Tracer>,
}

/// Run one engine config at P ranks, optionally with a per-rank tracer
/// installed for the whole solve.
fn run_config(m: M, s: usize, overlap: bool, p: usize, traced: bool) -> Vec<RankOut> {
    use cabcd::gram::NativeBackend;
    let ds = toy_dataset();
    let rf = reference(&ds);
    let n = ds.n();
    let finish = |vecs: Vec<f64>, history: History| RankOut {
        vecs,
        history,
        tracer: trace::take(),
    };
    match m {
        M::Bcd | M::ProxBcd => {
            let shards = partition_primal(&ds, p).unwrap();
            let opts = solver_opts(m, s, overlap);
            let rref = if m == M::Bcd { Some(&rf) } else { None };
            run_spmd(p, move |rank, comm| {
                if traced {
                    trace::install(Tracer::new(rank, trace::DEFAULT_SPAN_CAPACITY));
                }
                let sh = &shards[rank];
                let mut be = NativeBackend::new();
                let out =
                    cabcd::solvers::bcd::run(&sh.a_loc, &sh.y_loc, n, &opts, rref, comm, &mut be)
                        .unwrap();
                let mut vecs = out.w;
                vecs.extend_from_slice(&out.alpha_loc);
                finish(vecs, out.history)
            })
        }
        M::Bdcd | M::ProxBdcd => {
            let shards = partition_dual(&ds, p).unwrap();
            let opts = solver_opts(m, s, overlap);
            let rref = if m == M::Bdcd { Some(&rf) } else { None };
            run_spmd(p, move |rank, comm| {
                if traced {
                    trace::install(Tracer::new(rank, trace::DEFAULT_SPAN_CAPACITY));
                }
                let sh = &shards[rank];
                let mut be = NativeBackend::new();
                let out = cabcd::solvers::bdcd::run(
                    &sh.a_loc,
                    &sh.y,
                    sh.d_global,
                    sh.d_offset,
                    &opts,
                    rref,
                    comm,
                    &mut be,
                )
                .unwrap();
                let mut vecs = out.w_full;
                vecs.extend_from_slice(&out.w_loc);
                vecs.extend_from_slice(&out.alpha);
                finish(vecs, out.history)
            })
        }
        M::BcdRow => {
            let shards = partition_rows(&ds, p).unwrap();
            let opts = solver_opts(m, s, overlap);
            run_spmd(p, move |rank, comm| {
                if traced {
                    trace::install(Tracer::new(rank, trace::DEFAULT_SPAN_CAPACITY));
                }
                let sh = &shards[rank];
                let mut be = NativeBackend::new();
                let out = cabcd::solvers::bcd_row::run(
                    &sh.x_rows,
                    &sh.y_loc,
                    sh.d_global,
                    sh.d_offset,
                    &opts,
                    Some(&rf),
                    comm,
                    &mut be,
                )
                .unwrap();
                let mut vecs = out.w_full;
                vecs.extend_from_slice(&out.w_loc);
                finish(vecs, out.history)
            })
        }
        M::Cocoa => {
            let shards = partition_primal(&ds, p).unwrap();
            let copts = CocoaOpts {
                lam: LAM,
                rounds: ITERS,
                local_iters: s,
                seed: SEED,
                record_every: 4,
                overlap,
            };
            run_spmd(p, move |rank, comm| {
                if traced {
                    trace::install(Tracer::new(rank, trace::DEFAULT_SPAN_CAPACITY));
                }
                let sh = &shards[rank];
                let out =
                    cabcd::solvers::cocoa::run(&sh.a_loc, &sh.y_loc, n, &copts, Some(&rf), comm)
                        .unwrap();
                let mut vecs = out.w;
                vecs.extend_from_slice(&out.alpha_loc);
                finish(vecs, out.history)
            })
        }
    }
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// The s axis per method (local_iters for cocoa), matching the
/// engine_equivalence fixture.
fn s_of(m: M) -> usize {
    match m {
        M::Cocoa => 2,
        _ => 4,
    }
}

// ---------------------- 1. observer neutrality -------------------------

#[test]
fn tracing_is_observer_neutral_bitwise() {
    for m in M::ALL {
        for overlap in [false, true] {
            let ctx = format!("{} overlap={}", m.id(), overlap);
            let plain = run_config(m, s_of(m), overlap, 4, false);
            let traced = run_config(m, s_of(m), overlap, 4, true);
            assert_eq!(plain.len(), traced.len());
            for (rank, (a, b)) in plain.iter().zip(&traced).enumerate() {
                assert!(a.tracer.is_none(), "{ctx}: untraced rank {rank} has a tracer");
                assert!(b.tracer.is_some(), "{ctx}: traced rank {rank} lost its tracer");
                assert_eq!(
                    bits(&a.vecs),
                    bits(&b.vecs),
                    "{ctx}: rank {rank} iterates changed under tracing"
                );
                assert_eq!(
                    a.history.meter, b.history.meter,
                    "{ctx}: rank {rank} meter changed under tracing"
                );
                assert_eq!(a.history.iters, b.history.iters, "{ctx}: iters");
                assert_eq!(
                    a.history.records.len(),
                    b.history.records.len(),
                    "{ctx}: record count"
                );
                for (ra, rb) in a.history.records.iter().zip(&b.history.records) {
                    assert_eq!(ra.obj_err.to_bits(), rb.obj_err.to_bits(), "{ctx}: obj_err");
                    assert_eq!(ra.sol_err.to_bits(), rb.sol_err.to_bits(), "{ctx}: sol_err");
                }
                for (ra, rb) in a.history.prox.iter().zip(&b.history.prox) {
                    assert_eq!(ra.pen_obj.to_bits(), rb.pen_obj.to_bits(), "{ctx}: pen_obj");
                }
            }
        }
    }
}

// ------------------- 2. span/meter cross-validation --------------------

#[test]
fn span_counts_match_meters_for_all_methods() {
    for m in M::ALL {
        for overlap in [false, true] {
            let ctx = format!("{} overlap={}", m.id(), overlap);
            let outs = run_config(m, s_of(m), overlap, 4, true);
            for (rank, out) in outs.iter().enumerate() {
                let tracer = out.tracer.as_ref().unwrap();
                let meter = &out.history.meter;
                trace::cross_check(tracer, meter)
                    .unwrap_or_else(|e| panic!("{ctx} rank {rank}: {e}"));
                // The new counter: one deferred completion per posted
                // non-blocking collective, zero under blocking.
                let want_waits = if overlap {
                    meter.allreduces
                        + if m == M::BcdRow { meter.all_to_alls } else { 0 }
                } else {
                    0
                };
                assert_eq!(
                    meter.collective_waits, want_waits,
                    "{ctx} rank {rank}: collective_waits"
                );
                assert_eq!(tracer.dropped(), 0, "{ctx} rank {rank}: ring dropped spans");
                assert_eq!(
                    tracer.trace_allocs(),
                    0,
                    "{ctx} rank {rank}: ring reallocated"
                );
                assert!(!tracer.is_empty(), "{ctx} rank {rank}: no spans at all");
            }
        }
    }
}

#[test]
fn every_span_kind_is_exercised() {
    // One overlapped prox run + one bcdrow run together touch the whole
    // fault-free taxonomy (ProxStep comes from the prox inner solve, the
    // all-to-all spans from bcdrow).
    let mut seen = std::collections::HashSet::new();
    for outs in [
        run_config(M::ProxBcd, 4, true, 4, true),
        run_config(M::BcdRow, 4, true, 4, true),
    ] {
        for out in &outs {
            for sp in out.tracer.as_ref().unwrap().spans() {
                seen.insert(sp.kind);
            }
        }
    }
    // `Retry` fires only on the transient-fault path: a seeded chaos
    // endpoint over SerialComm (fault injection is transport-agnostic)
    // covers the ninth kind without an SPMD group.
    trace::install(Tracer::new(0, trace::DEFAULT_SPAN_CAPACITY));
    let spec = ChaosSpec {
        seed: 9,
        transient_prob: 0.5,
        max_retries: 64,
        backoff_base_ms: 0,
        ..ChaosSpec::default()
    };
    let mut chaos = ChaosComm::new(SerialComm::new(), spec);
    let mut buf = [1.0f64; 4];
    for _ in 0..16 {
        chaos.allreduce_sum(&mut buf).unwrap();
    }
    assert!(chaos.meter().retries > 0, "seeded coin never flipped a retry");
    let chaos_tracer = trace::take().unwrap();
    for sp in chaos_tracer.spans() {
        seen.insert(sp.kind);
    }
    for kind in SpanKind::ALL {
        assert!(seen.contains(&kind), "span kind {kind:?} never emitted");
    }
}

// --------------------- 3. acceptance: overlap wins ---------------------

#[test]
fn overlapped_cabcd_reports_positive_overlap_efficiency() {
    let outs = run_config(M::Bcd, 4, true, 4, true);
    let tracers: Vec<Tracer> = outs.into_iter().map(|o| o.tracer.unwrap()).collect();
    let sum = TraceSummary::from_tracers(&tracers);
    assert_eq!(sum.ranks, 4);
    assert!(sum.overlap.pairs > 0, "no collective windows paired");
    let eff = sum.overlap_efficiency();
    assert!(
        eff > 0.0,
        "overlap efficiency must be strictly positive for the prefetch \
         schedule, got {eff} ({:?})",
        sum.overlap
    );
    assert!(eff <= 1.0, "efficiency {eff} > 1");
}

#[test]
fn blocking_schedule_reports_zero_overlap_efficiency() {
    // Blocking collectives have (by construction) empty in-flight
    // windows: the CollectiveStart mark and the CollectiveWait span are
    // adjacent, so nothing can be covered.
    let outs = run_config(M::Bcd, 4, false, 4, true);
    let tracers: Vec<Tracer> = outs.into_iter().map(|o| o.tracer.unwrap()).collect();
    let sum = TraceSummary::from_tracers(&tracers);
    assert_eq!(sum.overlap_efficiency(), 0.0);
}

// ----------------- 4. ring discipline & zero-alloc ---------------------

#[test]
fn ring_wraps_in_place_without_reallocating() {
    let cap = 8usize;
    let mut tr = Tracer::new(3, cap);
    for i in 0..20u64 {
        tr.push(Span {
            kind: SpanKind::Sample,
            op: OpClass::Compute,
            tag: i,
            rank: 3,
            t_start: 10 * i,
            t_end: 10 * i + 5,
            words: 1,
        });
    }
    assert_eq!(tr.len(), cap);
    assert_eq!(tr.dropped(), 20 - cap as u64);
    assert_eq!(tr.trace_allocs(), 0);
    assert_eq!(tr.capacity(), cap);
    // The survivors are exactly the newest `cap` spans.
    let mut tags: Vec<u64> = tr.spans().iter().map(|s| s.tag).collect();
    tags.sort_unstable();
    assert_eq!(tags, (12..20).collect::<Vec<u64>>());
}

#[test]
fn tiny_ring_drops_spans_but_keeps_counts_honest() {
    // A deliberately undersized ring on a real run: the solve itself is
    // untouched (observer neutrality does not depend on capacity), spans
    // are dropped, and cross_check refuses to certify the lossy trace.
    use cabcd::gram::NativeBackend;
    let ds = toy_dataset();
    let shards = partition_primal(&ds, 1).unwrap();
    let opts = solver_opts(M::Bcd, 1, false);
    let outs = run_spmd(1, move |rank, comm| {
        trace::install(Tracer::new(rank, 4));
        let sh = &shards[rank];
        let mut be = NativeBackend::new();
        let out = cabcd::solvers::bcd::run(
            &sh.a_loc,
            &sh.y_loc,
            ds.n(),
            &opts,
            None,
            comm,
            &mut be,
        )
        .unwrap();
        (out.history, trace::take().unwrap())
    });
    let (history, tracer) = &outs[0];
    assert_eq!(tracer.len(), 4);
    assert!(tracer.dropped() > 0, "16 outers cannot fit in 4 slots");
    assert_eq!(tracer.trace_allocs(), 0, "ring grew under pressure");
    let err = trace::cross_check(tracer, &history.meter).unwrap_err();
    assert!(err.contains("dropped"), "unexpected cross_check error: {err}");
}

// --------------------------- 5. exporters ------------------------------

#[test]
fn chrome_trace_export_covers_every_rank_track() {
    let outs = run_config(M::Bcd, 4, true, 4, true);
    let tracers: Vec<Tracer> = outs.into_iter().map(|o| o.tracer.unwrap()).collect();
    let json = trace::chrome_trace_json(&tracers);
    assert!(json.starts_with("{\"traceEvents\":["), "bad envelope");
    for rank in 0..4 {
        assert!(
            json.contains(&format!("\"name\":\"rank {rank}\"")),
            "missing thread_name track for rank {rank}"
        );
    }
    for name in ["Sample", "GramLocal", "CollectiveStart", "CollectiveWait", "InnerSolve"] {
        assert!(json.contains(&format!("\"name\":\"{name}\"")), "missing {name}");
    }
    assert!(json.contains("\"cat\":\"allreduce\""));
    assert!(json.contains("\"displayTimeUnit\":\"ms\""));

    let summary = trace::summary_json(&TraceSummary::from_tracers(&tracers));
    for key in ["\"overlap_efficiency\"", "\"compute_ns\"", "\"wire_ns\"", "\"idle_ns\""] {
        assert!(summary.contains(key), "summary missing {key}");
    }
}
