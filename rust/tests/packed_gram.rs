//! The packed-triangular Gram engine's contract, property-tested end to
//! end:
//!
//! * kernel level — the packed dense/CSR Gram is **bitwise** the lower
//!   triangle of the full-matrix Gram, the Gustavson CSR kernel is
//!   bitwise equal to the historical two-pointer merge, and CSR agrees
//!   with dense to fp tolerance across random sparsity patterns
//!   (including empty rows and duplicate sampled indices);
//! * solve level — the inner solves indexing the packed triangle directly
//!   are **bitwise** equal to the pre-packing full-matrix recurrences;
//! * solver level — all four solvers' trajectories are invariant across
//!   storage formats and the overlap pipeline, at random `s`/`b`/`P`;
//! * wire level — `CostMeter` word counts prove the `[G|r]` allreduce
//!   payload is exactly `sb(sb+1)/2 + sb` words for bcd/bdcd (the
//!   Theorem-4 layout's `sb(sb+1)/2 + 2sb` for bcd_row, and the minimal
//!   `d`-word Δw combine for CoCoA, which has no Gram payload).

use cabcd::comm::thread::{expected_allreduce_sends, run_spmd};
use cabcd::comm::{Communicator, SerialComm};
use cabcd::coordinator::{partition_dual, partition_primal};
use cabcd::gram::{ComputeBackend, NativeBackend};
use cabcd::linalg::chol_solve;
use cabcd::linalg::packed::{pack_lower, packed_len, pidx, tri_row};
use cabcd::matrix::csr::GRAM_DENSE_FALLBACK_DENSITY;
use cabcd::matrix::io::Dataset;
use cabcd::matrix::{CsrMatrix, DenseMatrix, Matrix};
use cabcd::partition::BlockPartition;
use cabcd::prop_assert;
use cabcd::sampling::BlockSampler;
use cabcd::solvers::{bcd, bcd_row, bdcd, cocoa, SolverOpts};
use cabcd::util::proptest::{check, Gen};

/// Random CSR with genuinely empty rows and an approximate target density.
fn random_csr(g: &mut Gen, rows: usize, cols: usize, density: f64) -> CsrMatrix {
    let mut trip = Vec::new();
    for r in 0..rows {
        if g.f64_unit() < 0.2 {
            continue; // empty row
        }
        for c in 0..cols {
            if g.f64_unit() < density {
                trip.push((r, c, g.normal()));
            }
        }
    }
    CsrMatrix::from_triplets(rows, cols, trip)
}

/// Sampled index list with deliberate repeats (blocks resample across the
/// s inner steps, so the Gram kernels must accept duplicates).
fn random_idx(g: &mut Gen, sb: usize, rows: usize) -> Vec<usize> {
    (0..sb).map(|_| g.usize_in(0, rows)).collect()
}

#[test]
fn prop_dense_packed_is_bitwise_lower_triangle_of_full() {
    check(24, |g| {
        let rows = g.usize_in(2, 24);
        let cols = g.usize_in(1, 70);
        let sb = g.usize_in(1, 18);
        let m = DenseMatrix::from_vec(rows, cols, g.vec_normal(rows * cols));
        let idx = random_idx(g, sb, rows);
        let mut full = vec![0.0; sb * sb];
        m.sampled_gram(&idx, &mut full);
        let mut packed = vec![f64::NAN; packed_len(sb)];
        m.sampled_gram_packed(&idx, &mut packed);
        for r in 0..sb {
            for c in 0..=r {
                prop_assert!(
                    packed[tri_row(r) + c] == full[r * sb + c],
                    "({r},{c}): packed {} != full {} (rows={rows} cols={cols} sb={sb})",
                    packed[tri_row(r) + c],
                    full[r * sb + c]
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_csr_packed_equals_full_bitwise_across_density() {
    check(24, |g| {
        let rows = g.usize_in(2, 20);
        let cols = g.usize_in(4, 60);
        let sb = g.usize_in(1, 14);
        // Sweep from ultra-sparse through the dense-panel fallback regime.
        let density = g.f64_unit() * 0.6;
        let m = random_csr(g, rows, cols, density);
        let idx = random_idx(g, sb, rows);
        let mut full = vec![0.0; sb * sb];
        m.sampled_gram(&idx, &mut full);
        let mut packed = vec![f64::NAN; packed_len(sb)];
        m.sampled_gram_packed(&idx, &mut packed);
        for r in 0..sb {
            for c in 0..sb {
                prop_assert!(
                    packed[pidx(r, c)] == full[r * sb + c],
                    "({r},{c}) differs (density={density:.3} sb={sb})"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_csr_gustavson_is_bitwise_equal_to_merge() {
    check(24, |g| {
        let rows = g.usize_in(2, 24);
        let cols = g.usize_in(16, 80);
        let sb = g.usize_in(1, 16);
        let density = g.f64_unit() * 0.08; // sparse regime
        let m = random_csr(g, rows, cols, density);
        let idx = random_idx(g, sb, rows);
        // Stay out of the dense-panel fallback so the Gustavson passes are
        // what actually runs.
        let panel_nnz: usize = idx.iter().map(|&i| m.row(i).0.len()).sum();
        if panel_nnz as f64 > GRAM_DENSE_FALLBACK_DENSITY * (sb * cols) as f64 {
            return Ok(());
        }
        let mut fast = vec![f64::NAN; packed_len(sb)];
        let mut slow = vec![f64::NAN; packed_len(sb)];
        m.sampled_gram_packed(&idx, &mut fast);
        m.sampled_gram_merge_packed(&idx, &mut slow);
        prop_assert!(
            fast == slow,
            "Gustavson != merge (rows={rows} cols={cols} sb={sb} density={density:.4})"
        );
        Ok(())
    });
}

#[test]
fn prop_csr_matches_dense_gram_within_fp() {
    check(20, |g| {
        let rows = g.usize_in(2, 16);
        let cols = g.usize_in(4, 48);
        let sb = g.usize_in(1, 12);
        let density = g.f64_unit(); // full sparsity sweep, fallback included
        let m = random_csr(g, rows, cols, density);
        let d = m.to_dense();
        let idx = random_idx(g, sb, rows);
        let mut ps = vec![0.0; packed_len(sb)];
        let mut pd = vec![0.0; packed_len(sb)];
        m.sampled_gram_packed(&idx, &mut ps);
        d.sampled_gram_packed(&idx, &mut pd);
        for k in 0..packed_len(sb) {
            let scale = pd[k].abs().max(1.0);
            prop_assert!(
                (ps[k] - pd[k]).abs() <= 1e-10 * scale,
                "[{k}]: csr {} vs dense {} (density={density:.3})",
                ps[k],
                pd[k]
            );
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Inner solves: packed-indexed production code vs the pre-packing
// full-matrix recurrence, bitwise.
// ---------------------------------------------------------------------

/// The full-matrix primal inner solve exactly as it existed before the
/// packed refactor (eq. 8 recurrence) — the bitwise oracle.
#[allow(clippy::too_many_arguments)]
fn ref_ca_inner_solve(
    s: usize,
    b: usize,
    g_full: &[f64],
    r_raw: &[f64],
    w_blocks: &[f64],
    overlap: &[f64],
    lam: f64,
    inv_n: f64,
) -> Vec<f64> {
    let sb = s * b;
    let mut deltas = vec![0.0; sb];
    let mut gamma = vec![0.0; b * b];
    let mut rhs = vec![0.0; b];
    for j in 0..s {
        for i in 0..b {
            rhs[i] = -lam * w_blocks[j * b + i] + inv_n * r_raw[j * b + i];
        }
        for t in 0..j {
            let ov = &overlap[(j * s + t) * b * b..(j * s + t + 1) * b * b];
            let dt = deltas[t * b..(t + 1) * b].to_vec();
            for i in 0..b {
                let grow = &g_full[(j * b + i) * sb + t * b..(j * b + i) * sb + (t + 1) * b];
                let orow = &ov[i * b..(i + 1) * b];
                let mut acc = 0.0;
                for c in 0..b {
                    acc += (lam * orow[c] + inv_n * grow[c]) * dt[c];
                }
                rhs[i] -= acc;
            }
        }
        for i in 0..b {
            for c in 0..b {
                gamma[i * b + c] =
                    inv_n * g_full[(j * b + i) * sb + j * b + c] + if i == c { lam } else { 0.0 };
            }
        }
        chol_solve(&gamma, b, &mut rhs).unwrap();
        deltas[j * b..(j + 1) * b].copy_from_slice(&rhs);
    }
    deltas
}

/// The full-matrix dual inner solve as before the packed refactor (eq. 18).
#[allow(clippy::too_many_arguments)]
fn ref_ca_dual_inner_solve(
    s: usize,
    b: usize,
    g_full: &[f64],
    r_raw: &[f64],
    a_blocks: &[f64],
    y_blocks: &[f64],
    overlap: &[f64],
    lam: f64,
    inv_n: f64,
) -> Vec<f64> {
    let sb = s * b;
    let mut deltas = vec![0.0; sb];
    let mut gamma = vec![0.0; b * b];
    let mut rhs = vec![0.0; b];
    for j in 0..s {
        for i in 0..b {
            rhs[i] = -r_raw[j * b + i] + a_blocks[j * b + i] + y_blocks[j * b + i];
        }
        for t in 0..j {
            let ov = &overlap[(j * s + t) * b * b..(j * s + t + 1) * b * b];
            let dt = deltas[t * b..(t + 1) * b].to_vec();
            for i in 0..b {
                let grow = &g_full[(j * b + i) * sb + t * b..(j * b + i) * sb + (t + 1) * b];
                let orow = &ov[i * b..(i + 1) * b];
                let mut acc = 0.0;
                for c in 0..b {
                    acc += ((inv_n / lam) * grow[c] + orow[c]) * dt[c];
                }
                rhs[i] += acc;
            }
        }
        for i in 0..b {
            for c in 0..b {
                gamma[i * b + c] = (inv_n * inv_n / lam)
                    * g_full[(j * b + i) * sb + j * b + c]
                    + if i == c { inv_n } else { 0.0 };
            }
        }
        chol_solve(&gamma, b, &mut rhs).unwrap();
        for i in 0..b {
            deltas[j * b + i] = -inv_n * rhs[i];
        }
    }
    deltas
}

#[test]
fn prop_packed_inner_solves_are_bitwise_equal_to_full_matrix_reference() {
    check(20, |g| {
        let s = g.usize_in(1, 6);
        let b = g.usize_in(1, 7);
        let sb = s * b;
        // SPD-ish raw Gram from a random factor, mirrored exactly.
        let cols = sb + g.usize_in(4, 24);
        let m = g.vec_normal(sb * cols);
        let mut g_full = vec![0.0; sb * sb];
        for i in 0..sb {
            for j in 0..=i {
                let mut acc = 0.0;
                for k in 0..cols {
                    acc += m[i * cols + k] * m[j * cols + k];
                }
                g_full[i * sb + j] = acc;
                g_full[j * sb + i] = acc;
            }
        }
        let mut g_packed = vec![0.0; packed_len(sb)];
        pack_lower(&g_full, sb, &mut g_packed);
        let r_raw = g.vec_normal(sb);
        let w_blk = g.vec_normal(sb);
        let y_blk = g.vec_normal(sb);
        let mut ov = vec![0.0; s * s * b * b];
        for v in ov.iter_mut() {
            if g.f64_unit() < 0.1 {
                *v = 1.0;
            }
        }
        let (lam, inv_n) = (0.2 + g.f64_unit(), 1.0 / (cols as f64));
        let mut be = NativeBackend::new();
        let got = be
            .ca_inner_solve(s, b, &g_packed, &r_raw, &w_blk, &ov, lam, inv_n)
            .map_err(|e| e.to_string())?;
        let want = ref_ca_inner_solve(s, b, &g_full, &r_raw, &w_blk, &ov, lam, inv_n);
        prop_assert!(got == want, "primal inner solve drifted (s={s}, b={b})");
        let got = be
            .ca_dual_inner_solve(s, b, &g_packed, &r_raw, &w_blk, &y_blk, &ov, lam, inv_n)
            .map_err(|e| e.to_string())?;
        let want =
            ref_ca_dual_inner_solve(s, b, &g_full, &r_raw, &w_blk, &y_blk, &ov, lam, inv_n);
        prop_assert!(got == want, "dual inner solve drifted (s={s}, b={b})");
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Solver-level invariants at random s/b/P.
// ---------------------------------------------------------------------

fn random_dataset(g: &mut Gen, d: usize, n: usize) -> Dataset {
    let x = Matrix::Dense(DenseMatrix::from_vec(d, n, g.vec_normal(d * n)));
    let mut y = vec![0.0; n];
    let w_star = g.vec_normal(d);
    x.matvec_t(&w_star, &mut y).unwrap();
    Dataset {
        name: "packed-prop".into(),
        x,
        y,
    }
}

#[test]
fn prop_trajectories_invariant_across_storage_and_overlap() {
    check(6, |g| {
        let d = g.usize_in(5, 12);
        let n = g.usize_in(24, 60);
        let s = g.usize_in(1, 5);
        let b = g.usize_in(1, (d / 2).max(2));
        let outer = g.usize_in(3, 7);
        let ds = random_dataset(g, d, n);
        let csr = match &ds.x {
            Matrix::Dense(m) => Matrix::Csr(CsrMatrix::from_dense(m)),
            _ => unreachable!(),
        };
        let mk = |overlap: bool| SolverOpts::builder()
            .b(b)
            .s(s)
            .lam(0.3)
            .iters(outer * s)
            .seed(g.seed ^ 0xFEED)
            .record_every(0)
            .track_gram_cond(false)
            .overlap(overlap)
            .build();
        let mut be = NativeBackend::new();
        let mut c = SerialComm::new();
        // Primal: blocking ≡ overlapped, bitwise, on both storages.
        let w_block = bcd::run(&ds.x, &ds.y, n, &mk(false), None, &mut c, &mut be)
            .map_err(|e| e.to_string())?
            .w;
        let w_over = bcd::run(&ds.x, &ds.y, n, &mk(true), None, &mut c, &mut be)
            .map_err(|e| e.to_string())?
            .w;
        prop_assert!(w_block == w_over, "primal overlap not bitwise (s={s} b={b})");
        let w_csr = bcd::run(&csr, &ds.y, n, &mk(false), None, &mut c, &mut be)
            .map_err(|e| e.to_string())?
            .w;
        let scale: f64 = w_block.iter().map(|v| v.abs()).fold(1e-12, f64::max);
        for (i, (p, q)) in w_block.iter().zip(&w_csr).enumerate() {
            prop_assert!(
                (p - q).abs() <= 1e-8 * scale,
                "w[{i}]: dense {p} vs csr {q} (s={s} b={b})"
            );
        }
        // Dual: blocking ≡ overlapped, bitwise.
        let a = ds.x.transpose();
        let w1 = bdcd::run(&a, &ds.y, d, 0, &mk(false), None, &mut c, &mut be)
            .map_err(|e| e.to_string())?
            .w_full;
        let w2 = bdcd::run(&a, &ds.y, d, 0, &mk(true), None, &mut c, &mut be)
            .map_err(|e| e.to_string())?
            .w_full;
        prop_assert!(w1 == w2, "dual overlap not bitwise (s={s} b={b})");
        Ok(())
    });
}

#[test]
fn prop_row_layout_matches_column_layout_at_random_shapes() {
    check(4, |g| {
        let d = g.usize_in(8, 14);
        let n = g.usize_in(24, 48);
        let s = g.usize_in(1, 4);
        let b = g.usize_in(1, 3);
        let outer = g.usize_in(2, 5);
        let p = g.usize_in(2, 5);
        let ds = random_dataset(g, d, n);
        let opts = SolverOpts::builder()
            .b(b)
            .s(s)
            .lam(0.25)
            .iters(outer * s)
            .seed(g.seed ^ 0xB10C)
            .record_every(0)
            .track_gram_cond(false)
            .overlap(g.bool())
            .build();
        let mut be = NativeBackend::new();
        let mut c = SerialComm::new();
        let w_col = bcd::run(&ds.x, &ds.y, n, &opts, None, &mut c, &mut be)
            .map_err(|e| e.to_string())?
            .w;
        let row_part = BlockPartition::new(d, p);
        let col_part = BlockPartition::new(n, p);
        let x2 = &ds.x;
        let y2 = &ds.y;
        let opts2 = opts.clone();
        let (rp, cp) = (row_part.clone(), col_part.clone());
        let outs = run_spmd(p, move |rank, comm| {
            let (rlo, rhi) = rp.range(rank);
            let (clo, chi) = cp.range(rank);
            let idx: Vec<usize> = (rlo..rhi).collect();
            let mut slab = vec![0.0; idx.len() * y2.len()];
            x2.gather_rows(&idx, &mut slab).unwrap();
            let slab = Matrix::Dense(DenseMatrix::from_vec(idx.len(), y2.len(), slab));
            let mut be = NativeBackend::new();
            bcd_row::run(&slab, &y2[clo..chi], d, rlo, &opts2, None, comm, &mut be).unwrap()
        });
        for (i, (a, bv)) in w_col.iter().zip(&outs[0].w_full).enumerate() {
            prop_assert!(
                (a - bv).abs() < 1e-10,
                "P={p} w[{i}]: col {a} vs row {bv} (s={s} b={b})"
            );
        }
        Ok(())
    });
}

#[test]
fn cocoa_overlap_is_bitwise_stable() {
    let mut g = Gen::new(0xC0C0);
    let ds = random_dataset(&mut g, 6, 40);
    let mk = |overlap: bool| cocoa::CocoaOpts {
        lam: 0.05,
        rounds: 12,
        local_iters: 40,
        seed: 5,
        record_every: 0,
        overlap,
    };
    for p in [2usize, 3] {
        let shards = partition_primal(&ds, p).unwrap();
        let mut runs = Vec::new();
        for overlap in [false, true] {
            let opts = mk(overlap);
            let shards_ref = &shards;
            let outs = run_spmd(p, move |rank, comm| {
                let sh = &shards_ref[rank];
                cocoa::run(&sh.a_loc, &sh.y_loc, sh.n_global, &opts, None, comm).unwrap()
            });
            runs.push(outs.into_iter().map(|o| o.w).collect::<Vec<_>>());
        }
        for (rank, (wb, wo)) in runs[0].iter().zip(&runs[1]).enumerate() {
            assert!(wb == wo, "P={p} rank={rank}: cocoa overlap changed w");
        }
    }
}

// ---------------------------------------------------------------------
// Wire level: exact per-rank word counts of the packed payloads.
// ---------------------------------------------------------------------

#[test]
fn bcd_and_bdcd_allreduce_payload_is_exactly_packed_triangle_plus_resid() {
    let mut g = Gen::new(0x313E);
    let ds = random_dataset(&mut g, 8, 48);
    for (p, s, b, overlap) in [
        (2usize, 1usize, 3usize, false),
        (2, 4, 2, true),
        (4, 2, 4, false),
        (3, 2, 2, true),
    ] {
        let sb = s * b;
        let payload = packed_len(sb) + sb;
        let outer = 6usize;
        let opts = SolverOpts::builder()
            .b(b)
            .s(s)
            .lam(0.2)
            .iters(outer * s)
            .seed(9)
            .record_every(0)
            .track_gram_cond(false)
            .overlap(overlap)
            .build();
        // Primal.
        let shards = partition_primal(&ds, p).unwrap();
        let opts2 = opts.clone();
        let shards_ref = &shards;
        let meters = run_spmd(p, move |rank, comm| {
            let mut be = NativeBackend::new();
            let sh = &shards_ref[rank];
            bcd::run(&sh.a_loc, &sh.y_loc, sh.n_global, &opts2, None, comm, &mut be).unwrap();
            *comm.meter()
        });
        for (rank, m) in meters.iter().enumerate() {
            let (msgs, words) = expected_allreduce_sends(p, rank, payload);
            assert_eq!(m.allreduces, outer as u64, "bcd P={p} s={s} b={b}");
            assert_eq!(
                m.words,
                words * outer as u64,
                "bcd P={p} rank={rank}: payload is not sb(sb+1)/2+sb={payload}"
            );
            assert_eq!(m.msgs, msgs * outer as u64, "bcd P={p} rank={rank}");
        }
        // Dual (d = 8 supports up to 4 ranks).
        let shards = partition_dual(&ds, p).unwrap();
        let opts2 = opts.clone();
        let shards_ref = &shards;
        let meters = run_spmd(p, move |rank, comm| {
            let mut be = NativeBackend::new();
            let sh = &shards_ref[rank];
            bdcd::run(
                &sh.a_loc,
                &sh.y,
                sh.d_global,
                sh.d_offset,
                &opts2,
                None,
                comm,
                &mut be,
            )
            .unwrap();
            *comm.meter()
        });
        for (rank, m) in meters.iter().enumerate() {
            let (msgs, words) = expected_allreduce_sends(p, rank, payload);
            assert_eq!(m.allreduces, outer as u64, "bdcd P={p} s={s} b={b}");
            assert_eq!(
                m.words,
                words * outer as u64,
                "bdcd P={p} rank={rank}: payload is not sb(sb+1)/2+sb={payload}"
            );
            assert_eq!(m.msgs, msgs * outer as u64, "bdcd P={p} rank={rank}");
        }
    }
}

#[test]
fn bcd_row_payload_is_packed_triangle_plus_two_vectors_plus_lemma3_volume() {
    let mut g = Gen::new(0xA2A);
    let (d, n) = (12usize, 40usize);
    let ds = random_dataset(&mut g, d, n);
    for (p, s, b) in [(2usize, 2usize, 3usize), (3, 1, 4)] {
        let sb = s * b;
        let payload = packed_len(sb) + 2 * sb; // Theorem-4 layout: [G|r|w]
        let outer = 5usize;
        let opts = SolverOpts::builder()
            .b(b)
            .s(s)
            .lam(0.3)
            .iters(outer * s)
            .seed(21)
            .record_every(0)
            .track_gram_cond(false)
            .overlap(false)
            .build();
        let row_part = BlockPartition::new(d, p);
        let col_part = BlockPartition::new(n, p);
        let x2 = &ds.x;
        let y2 = &ds.y;
        let opts2 = opts.clone();
        let (rp, cp) = (row_part.clone(), col_part.clone());
        let meters = run_spmd(p, move |rank, comm| {
            let (rlo, rhi) = rp.range(rank);
            let (clo, chi) = cp.range(rank);
            let idx: Vec<usize> = (rlo..rhi).collect();
            let mut slab = vec![0.0; idx.len() * n];
            x2.gather_rows(&idx, &mut slab).unwrap();
            let slab = Matrix::Dense(DenseMatrix::from_vec(idx.len(), n, slab));
            let mut be = NativeBackend::new();
            bcd_row::run(&slab, &y2[clo..chi], d, rlo, &opts2, None, comm, &mut be).unwrap();
            *comm.meter()
        });
        // Replay the shared-seed sampler to predict each rank's exact
        // all-to-all send volume (owned rows × the columns everyone else
        // holds), then assert total sent words to the word.
        let mut sampler = BlockSampler::new(d, opts.seed);
        let mut a2a_words = vec![0u64; p];
        for _ in 0..outer {
            let blocks = sampler.draw_blocks(s, b);
            for &i in blocks.iter().flatten() {
                let owner = row_part.owner(i);
                let (clo, chi) = col_part.range(owner);
                a2a_words[owner] += (n - (chi - clo)) as u64;
            }
        }
        for (rank, m) in meters.iter().enumerate() {
            let (_, words) = expected_allreduce_sends(p, rank, payload);
            assert_eq!(m.allreduces, outer as u64, "P={p}");
            assert_eq!(m.all_to_alls, outer as u64, "P={p}");
            assert_eq!(
                m.words,
                words * outer as u64 + a2a_words[rank],
                "bcd_row P={p} rank={rank}: [G|r|w] payload is not {payload} words"
            );
        }
    }
}

#[test]
fn cocoa_round_payload_is_exactly_d_words() {
    // CoCoA has no Gram payload to pack; its one collective per round is
    // the length-d Δw combine — asserted minimal here.
    let mut g = Gen::new(0xD00D);
    let d = 7usize;
    let ds = random_dataset(&mut g, d, 30);
    for (p, overlap) in [(2usize, false), (3, true)] {
        let rounds = 8usize;
        let opts = cocoa::CocoaOpts {
            lam: 0.05,
            rounds,
            local_iters: 20,
            seed: 3,
            record_every: 0,
            overlap,
        };
        let shards = partition_primal(&ds, p).unwrap();
        let shards_ref = &shards;
        let optsr = &opts;
        let meters = run_spmd(p, move |rank, comm| {
            let sh = &shards_ref[rank];
            cocoa::run(&sh.a_loc, &sh.y_loc, sh.n_global, optsr, None, comm).unwrap();
            *comm.meter()
        });
        for (rank, m) in meters.iter().enumerate() {
            let (_, words) = expected_allreduce_sends(p, rank, d);
            assert_eq!(m.allreduces, rounds as u64, "P={p}");
            assert_eq!(
                m.words,
                words * rounds as u64,
                "cocoa P={p} rank={rank}: round payload is not d={d} words"
            );
        }
    }
}
