//! Static-analysis suite (PR 7): the symbolic SPMD schedule verifier and
//! the project lint gate.
//!
//! * The verifier sweep proves every solver schedule (6 methods ×
//!   {blocking, overlap} × P ∈ {1, 3, 4}, plus the early-tolerance-stop
//!   drain paths and the two-level-topology neutrality runs) satisfies
//!   the checker's four invariants.
//! * The 48-config matrix of `engine_equivalence.rs` is pinned, event by
//!   event, to `fixtures/engine_schedules.tsv`, and the symbolic meters
//!   are cross-checked against `fixtures/engine_meters.tsv`.
//! * Seeded faults — a rank-divergent collective, a skipped wait, tag
//!   aliasing, traffic after poison — must be *caught* with actionable
//!   errors (the verifier's reason to exist).
//! * The lint pass must be clean with its allowlist frozen at the
//!   audited counts.

use std::collections::HashMap;

use cabcd::analysis::lint::ALLOW;
use cabcd::analysis::{
    check_streams, engine_schedule_runs, run_lint, verify_all, ScheduleRun, SpecComm, SpecEvent,
    SpecOp,
};
use cabcd::comm::Communicator;
use cabcd::engine::{drive, CaStep, Sample};
use cabcd::error::Result;
use cabcd::metrics::History;
use cabcd::solvers::SolverOpts;

// ---------------------------------------------------------------------------
// Verifier sweep
// ---------------------------------------------------------------------------

#[test]
fn verifier_passes_every_method_schedule_and_drain_path() {
    // 6 methods x 2 s-values x {blocking, overlap} x P in {1,3,4} = 72
    // steady configs, plus 3 drain methods x 3 P = 9 tolerance-stop runs,
    // plus 6 methods x P in {3,4} = 12 two-level-topology neutrality runs.
    let verified = verify_all().expect("symbolic schedule verification failed");
    assert_eq!(verified, 93, "config sweep shrank — update the sweep or this count");
}

// ---------------------------------------------------------------------------
// Fixture pinning: schedules and meters
// ---------------------------------------------------------------------------

struct MeterRow {
    allreduces: u64,
    all_to_alls: u64,
    msgs: u64,
    words: Option<u64>,
    waits: u64,
}

fn fixture_key(method: &str, s: usize, overlap: bool, p: usize) -> String {
    format!("{method}/s{s}/overlap{overlap}/p{p}")
}

fn load_meters() -> HashMap<String, MeterRow> {
    let text = include_str!("fixtures/engine_meters.tsv");
    let mut out = HashMap::new();
    for line in text.lines() {
        if line.starts_with('#') || line.trim().is_empty() {
            continue;
        }
        let f: Vec<&str> = line.split('\t').collect();
        assert_eq!(f.len(), 9, "meters fixture row: {line}");
        let key = fixture_key(
            f[0],
            f[1].parse().unwrap(),
            f[2] == "1",
            f[3].parse().unwrap(),
        );
        out.insert(
            key,
            MeterRow {
                allreduces: f[4].parse().unwrap(),
                all_to_alls: f[5].parse().unwrap(),
                msgs: f[6].parse().unwrap(),
                words: if f[7] == "-" { None } else { Some(f[7].parse().unwrap()) },
                waits: f[8].parse().unwrap(),
            },
        );
    }
    assert_eq!(out.len(), 48);
    out
}

fn load_schedules() -> Vec<(String, usize, String)> {
    let text = include_str!("fixtures/engine_schedules.tsv");
    let mut out = Vec::new();
    for line in text.lines() {
        if line.starts_with('#') || line.trim().is_empty() {
            continue;
        }
        let f: Vec<&str> = line.split('\t').collect();
        assert_eq!(f.len(), 6, "schedules fixture row: {line}");
        let key = fixture_key(
            f[0],
            f[1].parse().unwrap(),
            f[2] == "true",
            f[3].parse().unwrap(),
        );
        out.push((key, f[4].parse().unwrap(), f[5].to_string()));
    }
    assert_eq!(out.len(), 48);
    out
}

/// Count a run's unmetered tokens per collective class; they must equal
/// the meter counters (metered diagnostic traffic nets to zero through
/// the `metered_out` snapshot/restore, so only solver traffic counts).
fn token_counts(run: &ScheduleRun, rank: usize) -> (u64, u64, u64) {
    let (mut ars, mut a2as, mut waits) = (0u64, 0u64, 0u64);
    for e in &run.streams[rank] {
        if e.metered {
            continue;
        }
        match &e.op {
            SpecOp::Allreduce { .. } | SpecOp::IAllreduceStart { .. } => ars += 1,
            SpecOp::AllToAll { .. } | SpecOp::IAllToAllStart { .. } => a2as += 1,
            SpecOp::IAllreduceWait { .. } | SpecOp::IAllToAllWait { .. } => waits += 1,
            _ => {}
        }
    }
    (ars, a2as, waits)
}

#[test]
fn engine_schedules_match_fixture_and_meters() {
    let runs = engine_schedule_runs().expect("symbolic runs failed");
    assert_eq!(runs.len(), 48);
    let schedules = load_schedules();
    let meters = load_meters();

    for (run, (key, n_events, events)) in runs.iter().zip(&schedules) {
        let got_key = fixture_key(run.method, run.s, run.overlap, run.p);
        assert_eq!(&got_key, key, "fixture row order diverged");

        // Every rank's stream verifies and matches rank 0 (invariant (a)),
        // so pinning rank 0 pins them all.
        check_streams(&run.streams)
            .unwrap_or_else(|e| panic!("[{key}] checker rejected engine schedule: {e}"));
        let got = run.rank0_tokens().join(" ");
        assert_eq!(
            run.streams[0].len(),
            *n_events,
            "[{key}] event count: fixture {n_events}, got {} — stream:\n{got}",
            run.streams[0].len(),
        );
        assert_eq!(
            &got, events,
            "[{key}] schedule drifted from fixture.\nexpected: {events}\ngot:      {got}"
        );

        // Meters: symbolic counters must match the engine_meters golden
        // row on every rank (counts are rank-invariant; wire words for
        // the row layout's exchange are not pinned there — '-').
        let mrow = meters.get(key).unwrap_or_else(|| panic!("no meter row {key}"));
        for (rank, m) in run.meters.iter().enumerate() {
            assert_eq!(m.allreduces, mrow.allreduces, "[{key}] rank {rank} allreduces");
            assert_eq!(m.all_to_alls, mrow.all_to_alls, "[{key}] rank {rank} all_to_alls");
            assert_eq!(m.collective_waits, mrow.waits, "[{key}] rank {rank} waits");
            assert_eq!(m.msgs, mrow.msgs, "[{key}] rank {rank} msgs");
            if let Some(words) = mrow.words {
                assert_eq!(m.words, words, "[{key}] rank {rank} words");
            }

            // Token-level cross-check: unmetered events are the meter.
            let (ars, a2as, waits) = token_counts(run, rank);
            assert_eq!(ars, m.allreduces, "[{key}] rank {rank} AR tokens vs meter");
            assert_eq!(a2as, m.all_to_alls, "[{key}] rank {rank} a2a tokens vs meter");
            assert_eq!(waits, m.collective_waits, "[{key}] rank {rank} wait tokens vs meter");
        }
    }
}

// ---------------------------------------------------------------------------
// Seeded faults: the verifier must catch them, with actionable errors
// ---------------------------------------------------------------------------

/// Minimal CaStep whose only purpose is to inject schedule faults.
struct ToyStep {
    rank: usize,
    fault: Fault,
}

#[derive(Clone, Copy, PartialEq)]
enum Fault {
    None,
    /// Rank 1 issues an extra collective inside `record` — the classic
    /// "metric code communicates on one rank only" deadlock.
    DivergentRecord,
    /// Every rank posts a non-blocking reduction it never waits for.
    SkippedWait,
}

impl<C: Communicator> CaStep<C> for ToyStep {
    fn payload_split(&self) -> (usize, usize) {
        (2, 2)
    }

    fn sample(&mut self, _comm: &mut C, k: usize) -> Result<Sample> {
        Ok(Sample::empty(k))
    }

    fn local_gram(&mut self, _comm: &mut C, _smp: &Sample, head: &mut [f64]) -> Result<()> {
        head.fill(0.0);
        Ok(())
    }

    fn local_state(&mut self, _smp: &Sample, tail: &mut [f64]) -> Result<()> {
        tail.fill(0.0);
        Ok(())
    }

    fn local_payload(
        &mut self,
        comm: &mut C,
        smp: &Sample,
        head: &mut [f64],
        tail: &mut [f64],
    ) -> Result<()> {
        if self.fault == Fault::SkippedWait && smp.k == 1 {
            // Post and drop: the handle never reaches a wait.
            let _ = comm.iallreduce_start(vec![0.0])?;
        }
        head.fill(0.0);
        tail.fill(0.0);
        Ok(())
    }

    fn hidden_work(&mut self, _smp: &Sample) -> Result<()> {
        Ok(())
    }

    fn inner_solve(&mut self, _smp: &Sample, _head: &[f64], _tail: &[f64]) -> Result<Vec<f64>> {
        Ok(Vec::new()) // identity solve: apply the payload tail directly
    }

    fn apply(&mut self, _smp: &Sample, _deltas: &[f64]) -> Result<()> {
        Ok(())
    }

    fn record(&mut self, comm: &mut C, _history: &mut History, h_now: usize) -> Result<()> {
        if self.fault == Fault::DivergentRecord && self.rank == 1 && h_now == 4 {
            let mut extra = [0.0];
            comm.allreduce_sum(&mut extra)?;
        }
        Ok(())
    }
}

fn drive_toy(fault: Fault, p: usize) -> Vec<Vec<SpecEvent>> {
    let opts = SolverOpts::builder()
        .b(1)
        .s(1)
        .iters(4)
        .record_every(4)
        .build();
    let mut streams = Vec::new();
    for rank in 0..p {
        let mut comm = SpecComm::new(rank, p);
        let mut step = ToyStep { rank, fault };
        let mut history = History::default();
        drive(&mut step, &opts, &mut comm, &mut history).expect("toy drive failed");
        streams.push(comm.into_events());
    }
    streams
}

#[test]
fn clean_toy_step_verifies() {
    check_streams(&drive_toy(Fault::None, 3)).expect("clean toy schedule must verify");
}

#[test]
fn rank_divergent_collective_is_caught() {
    let err = check_streams(&drive_toy(Fault::DivergentRecord, 3))
        .expect_err("divergent record must be caught");
    let msg = err.to_string();
    assert!(
        msg.contains("schedule violation") && msg.contains("rank"),
        "diagnosis must name the violation and the rank: {msg}"
    );
}

#[test]
fn skipped_wait_is_caught() {
    let err =
        check_streams(&drive_toy(Fault::SkippedWait, 2)).expect_err("orphan start must be caught");
    let msg = err.to_string();
    assert!(
        msg.contains("still in flight") && msg.contains("iallreduce_wait"),
        "diagnosis must point at the missing wait: {msg}"
    );
}

#[test]
fn tag_aliasing_is_caught() {
    let mut c = SpecComm::new(0, 2);
    let h1 = c.iallreduce_start(vec![0.0; 3]).unwrap();
    c.set_freeze_tags(true); // next entry reuses the in-flight tag
    let h2 = c.iallreduce_start(vec![0.0; 3]).unwrap();
    let _ = c.iallreduce_wait(h1).unwrap();
    let _ = c.iallreduce_wait(h2).unwrap();
    let err = check_streams(&[c.into_events()]).expect_err("tag reuse must be caught");
    assert!(
        err.to_string().contains("tag aliasing"),
        "diagnosis must name the aliased tag: {err}"
    );
}

#[test]
fn rank_divergent_tags_are_caught() {
    let mut streams = Vec::new();
    for rank in 0..2 {
        let mut c = SpecComm::new(rank, 2);
        if rank == 1 {
            c.set_tag_skew(7); // rank 1's tag stream diverged
        }
        c.allreduce_sum(&mut [0.0; 4]).unwrap();
        streams.push(c.into_events());
    }
    let err = check_streams(&streams).expect_err("tag divergence must be caught");
    assert!(
        err.to_string().contains("rank divergence"),
        "diagnosis must show both sides: {err}"
    );
}

#[test]
fn traffic_after_poison_is_caught() {
    let stream = vec![
        SpecEvent {
            tag: 3,
            metered: false,
            op: SpecOp::Refused,
        },
        SpecEvent {
            tag: 4,
            metered: false,
            op: SpecOp::Allreduce { len: 2 },
        },
    ];
    let err = check_streams(&[stream]).expect_err("post-poison traffic must be caught");
    assert!(
        err.to_string().contains("poisoned"),
        "diagnosis must name the poison position: {err}"
    );
}

#[test]
fn poisoned_endpoint_refuses_and_refusals_verify() {
    let mut c = SpecComm::new(0, 2);
    c.allreduce_sum(&mut [0.0]).unwrap();
    let _ = c.poison("seeded fault");
    assert!(c.allreduce_sum(&mut [0.0]).is_err(), "poisoned endpoint must refuse");
    assert!(c.barrier().is_err());
    // A stream that refuses everything after the poison is exactly the
    // fail-fast behaviour invariant (d) demands.
    check_streams(&[c.into_events()]).expect("all-refused tail must verify");
}

#[test]
fn wait_without_start_is_caught() {
    let stream = vec![SpecEvent {
        tag: 1,
        metered: false,
        op: SpecOp::IAllreduceWait { len: 2 },
    }];
    let err = check_streams(&[stream]).expect_err("bare wait must be caught");
    assert!(
        err.to_string().contains("none in flight"),
        "diagnosis must say nothing was in flight: {err}"
    );
}

#[test]
fn mismatched_a2a_contracts_are_caught() {
    // Rank 0 sends 5 words to rank 1, but rank 1 expects 6 from rank 0.
    let mk = |send: Vec<usize>, recv: Vec<usize>| {
        vec![SpecEvent {
            tag: 1,
            metered: false,
            op: SpecOp::AllToAll {
                send_lens: send,
                recv_lens: recv,
            },
        }]
    };
    let err = check_streams(&[mk(vec![0, 5], vec![0, 5]), mk(vec![5, 0], vec![6, 0])])
        .expect_err("transpose-condition break must be caught");
    let msg = err.to_string();
    assert!(
        msg.contains("sends 5 words") && msg.contains("expects 6 words"),
        "diagnosis must show both sides of the contract: {msg}"
    );
}

// ---------------------------------------------------------------------------
// Lint gate
// ---------------------------------------------------------------------------

#[test]
fn lint_is_clean_and_allowlist_is_frozen() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src");
    let report = run_lint(&root).expect("lint scan failed");
    assert!(
        report.is_clean(),
        "ca_lint found violations (fix them or re-audit ALLOW in \
         rust/src/analysis/lint.rs):\n{report}"
    );
    assert!(
        report.files_scanned > 30,
        "lint scanned only {} files — wrong root?",
        report.files_scanned
    );
    // The freeze: every audited exemption is present and exact. Adding an
    // unwrap/collective/alloc bumps a count and fails `is_clean`; removing
    // one leaves a stale entry, which also fails `is_clean` — this gate
    // pins the list itself so it cannot silently grow.
    assert_eq!(report.allow_matched, ALLOW.len(), "allowlist no longer exact:\n{report}");
    assert!(!ALLOW.is_empty());
}
