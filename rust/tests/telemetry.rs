//! Observability suite for the cross-rank telemetry registry (PR 9).
//!
//! Four gates:
//!
//! 1. **Observer neutrality** — running any method with a registry
//!    installed must leave the iterates, the history records, and every
//!    wire-relevant CostMeter field bitwise identical to the plain run.
//!    The registry reads the clock, bumps inline counters, and — on the
//!    record cadence — runs one meter-excluded aggregation allreduce; it
//!    must never touch the numerics or the metered wire counts. The one
//!    audited exception is `buf_allocs`: the aggregation payload warms
//!    the buffer pool with its own unique size, so pool growth is
//!    excluded from the comparison (same policy as the checkpoint suite).
//! 2. **Registry discipline** — snapshots are aggregated on the record
//!    cadence, every rank decodes the identical snapshot sequence (the
//!    allreduce is the broadcast), and recording never allocates after
//!    registry construction (`telemetry_allocs == 0`, `dropped == 0`).
//! 3. **Histogram bucket math under load** — on a real run, every
//!    histogram's bucket mass equals its exact count, the sidecars bound
//!    the distribution, and the serialized words survive the f64
//!    aggregation payload bit-exactly.
//! 4. **Straggler acceptance** — a seeded ChaosComm stall at P = 4 flags
//!    exactly the victim rank with the `wait` verdict (the late arriver
//!    waits the least); the fault-free run flags nobody.

use cabcd::comm::thread::run_spmd;
use cabcd::comm::{ChaosComm, ChaosSpec, CostMeter, SerialComm, ThreadComm};
use cabcd::coordinator::{partition_dual, partition_primal, partition_rows};
use cabcd::gram::NativeBackend;
use cabcd::matrix::io::Dataset;
use cabcd::matrix::{DenseMatrix, Matrix};
use cabcd::metrics::{History, Reference};
use cabcd::prox::Reg;
use cabcd::solvers::cocoa::CocoaOpts;
use cabcd::solvers::{cg, SolverOpts};
use cabcd::telemetry::{self, ClusterSnapshot, Histogram, Hist, Registry};

const LAM: f64 = 0.2;
const ITERS: usize = 16;
const SEED: u64 = 7;
const B: usize = 2;
const P: usize = 4;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum M {
    Bcd,
    Bdcd,
    BcdRow,
    Cocoa,
    ProxBcd,
    ProxBdcd,
}

impl M {
    const ALL: [M; 6] = [M::Bcd, M::Bdcd, M::BcdRow, M::Cocoa, M::ProxBcd, M::ProxBdcd];

    fn id(self) -> &'static str {
        match self {
            M::Bcd => "bcd",
            M::Bdcd => "bdcd",
            M::BcdRow => "bcdrow",
            M::Cocoa => "cocoa",
            M::ProxBcd => "prox_bcd",
            M::ProxBdcd => "prox_bdcd",
        }
    }
}

fn toy_dataset() -> Dataset {
    let (d, n) = (12usize, 48usize);
    let mut st = 0x7E1E7E1Eu64;
    let data: Vec<f64> = (0..d * n)
        .map(|_| {
            st ^= st << 13;
            st ^= st >> 7;
            st ^= st << 17;
            (st as f64 / u64::MAX as f64) - 0.5
        })
        .collect();
    let x = Matrix::Dense(DenseMatrix::from_vec(d, n, data));
    let mut y = vec![0.0; n];
    let mut w_star = vec![0.0; d];
    w_star[0] = 1.5;
    w_star[d / 2] = -2.0;
    w_star[d - 1] = 0.75;
    x.matvec_t(&w_star, &mut y).unwrap();
    Dataset {
        name: "telemetry-suite".into(),
        x,
        y,
    }
}

fn reference(ds: &Dataset) -> Reference {
    let mut comm = SerialComm::new();
    cg::compute_reference(&ds.x, &ds.y, ds.n(), LAM, &mut comm).unwrap()
}

fn solver_opts(m: M, s: usize, overlap: bool) -> SolverOpts {
    let reg = match m {
        M::ProxBcd | M::ProxBdcd => Reg::L1,
        _ => Reg::L2,
    };
    SolverOpts::builder()
        .b(B)
        .s(s)
        .lam(LAM)
        .iters(ITERS)
        .seed(SEED)
        .record_every(4)
        .overlap(overlap)
        .reg(reg)
        .build()
}

/// One rank's output: concatenated iterate vectors, the history, and the
/// registry (when `telemetered`).
struct RankOut {
    vecs: Vec<f64>,
    history: History,
    registry: Option<Registry>,
}

/// Run one engine config at P ranks, optionally with a per-rank
/// telemetry registry installed for the whole solve.
fn run_config(m: M, s: usize, overlap: bool, p: usize, telemetered: bool) -> Vec<RankOut> {
    let ds = toy_dataset();
    let rf = reference(&ds);
    let n = ds.n();
    let install = move |rank: usize| {
        if telemetered {
            telemetry::install(Registry::new(rank, p));
        }
    };
    let finish = |vecs: Vec<f64>, history: History| RankOut {
        vecs,
        history,
        registry: telemetry::take(),
    };
    match m {
        M::Bcd | M::ProxBcd => {
            let shards = partition_primal(&ds, p).unwrap();
            let opts = solver_opts(m, s, overlap);
            let rref = if m == M::Bcd { Some(&rf) } else { None };
            run_spmd(p, move |rank, comm| {
                install(rank);
                let sh = &shards[rank];
                let mut be = NativeBackend::new();
                let out =
                    cabcd::solvers::bcd::run(&sh.a_loc, &sh.y_loc, n, &opts, rref, comm, &mut be)
                        .unwrap();
                let mut vecs = out.w;
                vecs.extend_from_slice(&out.alpha_loc);
                finish(vecs, out.history)
            })
        }
        M::Bdcd | M::ProxBdcd => {
            let shards = partition_dual(&ds, p).unwrap();
            let opts = solver_opts(m, s, overlap);
            let rref = if m == M::Bdcd { Some(&rf) } else { None };
            run_spmd(p, move |rank, comm| {
                install(rank);
                let sh = &shards[rank];
                let mut be = NativeBackend::new();
                let out = cabcd::solvers::bdcd::run(
                    &sh.a_loc,
                    &sh.y,
                    sh.d_global,
                    sh.d_offset,
                    &opts,
                    rref,
                    comm,
                    &mut be,
                )
                .unwrap();
                let mut vecs = out.w_full;
                vecs.extend_from_slice(&out.w_loc);
                vecs.extend_from_slice(&out.alpha);
                finish(vecs, out.history)
            })
        }
        M::BcdRow => {
            let shards = partition_rows(&ds, p).unwrap();
            let opts = solver_opts(m, s, overlap);
            run_spmd(p, move |rank, comm| {
                install(rank);
                let sh = &shards[rank];
                let mut be = NativeBackend::new();
                let out = cabcd::solvers::bcd_row::run(
                    &sh.x_rows,
                    &sh.y_loc,
                    sh.d_global,
                    sh.d_offset,
                    &opts,
                    Some(&rf),
                    comm,
                    &mut be,
                )
                .unwrap();
                let mut vecs = out.w_full;
                vecs.extend_from_slice(&out.w_loc);
                finish(vecs, out.history)
            })
        }
        M::Cocoa => {
            let shards = partition_primal(&ds, p).unwrap();
            let copts = CocoaOpts {
                lam: LAM,
                rounds: ITERS,
                local_iters: s,
                seed: SEED,
                record_every: 4,
                overlap,
            };
            run_spmd(p, move |rank, comm| {
                install(rank);
                let sh = &shards[rank];
                let out =
                    cabcd::solvers::cocoa::run(&sh.a_loc, &sh.y_loc, n, &copts, Some(&rf), comm)
                        .unwrap();
                let mut vecs = out.w;
                vecs.extend_from_slice(&out.alpha_loc);
                finish(vecs, out.history)
            })
        }
    }
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// The s axis per method (local_iters for cocoa), matching the
/// engine_equivalence fixture.
fn s_of(m: M) -> usize {
    match m {
        M::Cocoa => 2,
        _ => 4,
    }
}

/// Wire meters must be bitwise-equal except `buf_allocs` (the aggregation
/// allreduce legitimately warms the pool with its own payload size).
fn assert_wire_meters_eq(a: &CostMeter, b: &CostMeter, ctx: &str) {
    let (mut a, mut b) = (*a, *b);
    a.buf_allocs = 0;
    b.buf_allocs = 0;
    assert_eq!(a, b, "{ctx}: wire meters diverged under telemetry");
}

// ---------------------- 1. observer neutrality -------------------------

#[test]
fn telemetry_is_observer_neutral_bitwise() {
    for m in M::ALL {
        for overlap in [false, true] {
            let ctx = format!("{} overlap={}", m.id(), overlap);
            let plain = run_config(m, s_of(m), overlap, P, false);
            let telemetered = run_config(m, s_of(m), overlap, P, true);
            assert_eq!(plain.len(), telemetered.len());
            for (rank, (a, b)) in plain.iter().zip(&telemetered).enumerate() {
                assert!(
                    a.registry.is_none(),
                    "{ctx}: plain rank {rank} has a registry"
                );
                assert!(
                    b.registry.is_some(),
                    "{ctx}: telemetered rank {rank} lost its registry"
                );
                assert_eq!(
                    bits(&a.vecs),
                    bits(&b.vecs),
                    "{ctx}: rank {rank} iterates changed under telemetry"
                );
                assert_wire_meters_eq(
                    &a.history.meter,
                    &b.history.meter,
                    &format!("{ctx} rank {rank}"),
                );
                assert_eq!(a.history.iters, b.history.iters, "{ctx}: iters");
                assert_eq!(
                    a.history.records.len(),
                    b.history.records.len(),
                    "{ctx}: record count"
                );
                for (ra, rb) in a.history.records.iter().zip(&b.history.records) {
                    assert_eq!(ra.obj_err.to_bits(), rb.obj_err.to_bits(), "{ctx}: obj_err");
                    assert_eq!(ra.sol_err.to_bits(), rb.sol_err.to_bits(), "{ctx}: sol_err");
                }
                for (ra, rb) in a.history.prox.iter().zip(&b.history.prox) {
                    assert_eq!(ra.pen_obj.to_bits(), rb.pen_obj.to_bits(), "{ctx}: pen_obj");
                    assert_eq!(ra.gap.to_bits(), rb.gap.to_bits(), "{ctx}: gap");
                }
            }
        }
    }
}

// -------------- 2. registry discipline across the matrix ---------------

#[test]
fn registries_agree_and_never_allocate() {
    for m in M::ALL {
        for overlap in [false, true] {
            let ctx = format!("{} overlap={}", m.id(), overlap);
            let outs = run_config(m, s_of(m), overlap, P, true);
            let first = outs[0].registry.as_ref().unwrap();
            assert!(
                !first.snapshots().is_empty(),
                "{ctx}: record cadence produced no snapshots"
            );
            for (rank, out) in outs.iter().enumerate() {
                let reg = out.registry.as_ref().unwrap();
                assert_eq!(reg.rank() as usize, rank, "{ctx}: rank mislabelled");
                assert_eq!(reg.ranks() as usize, P, "{ctx}: group size mislabelled");
                assert_eq!(
                    reg.telemetry_allocs(),
                    0,
                    "{ctx} rank {rank}: registry allocated on the hot path"
                );
                assert_eq!(
                    reg.dropped_snapshots(),
                    0,
                    "{ctx} rank {rank}: snapshot ring overflowed"
                );
                // The aggregation allreduce doubles as the broadcast:
                // every rank decodes the identical snapshot sequence.
                assert_eq!(
                    reg.snapshots(),
                    first.snapshots(),
                    "{ctx} rank {rank}: snapshot sequence diverged"
                );
                // The per-rank health blocks carry real observations.
                let last = reg.snapshots().last().unwrap();
                assert_eq!(last.ranks.len(), P, "{ctx}: health list size");
                assert!(
                    last.ranks[rank].wire_ns > 0,
                    "{ctx} rank {rank}: no wire time observed"
                );
                assert!(
                    last.fleet.wire_words > 0,
                    "{ctx}: fleet moved no payload words"
                );
            }
        }
    }
}

// -------------- 3. histogram bucket math on a real run -----------------

#[test]
fn histogram_bucket_mass_matches_exact_sidecars_under_load() {
    let outs = run_config(M::Bcd, 4, true, P, true);
    let mut nonempty = 0usize;
    for out in &outs {
        let reg = out.registry.as_ref().unwrap();
        for h in Hist::ALL {
            let hist = reg.hist(h);
            let mass: u64 = (0..cabcd::telemetry::histogram::BUCKETS)
                .map(|i| hist.bucket(i))
                .sum();
            assert_eq!(
                mass,
                hist.count(),
                "{}: bucket mass != count",
                h.name()
            );
            if hist.count() == 0 {
                continue;
            }
            nonempty += 1;
            assert!(hist.min() <= hist.max(), "{}: min > max", h.name());
            assert!(
                hist.mean() >= hist.min() as f64 && hist.mean() <= hist.max() as f64,
                "{}: mean outside [min, max]",
                h.name()
            );
            assert_eq!(hist.quantile(1.0), hist.max(), "{}: p100 != max", h.name());
            assert!(
                hist.quantile(0.5) <= hist.quantile(0.99),
                "{}: quantiles disordered",
                h.name()
            );
            // The f64 aggregation payload must carry the histogram
            // losslessly (counts are far below the 2^53 mantissa).
            let mut words = vec![0.0; Histogram::WORDS];
            hist.write_words(&mut words);
            assert_eq!(
                Histogram::from_words(&words),
                *hist,
                "{}: words roundtrip diverged",
                h.name()
            );
        }
    }
    assert!(nonempty > 0, "no histogram recorded anything");
}

// ------------------- 4. straggler acceptance (P = 4) -------------------

/// One-rank placeholder endpoint for the chaos stub swap (`run_spmd`
/// hands out `&mut ThreadComm`, the chaos wrapper wants ownership).
fn stub() -> ThreadComm {
    let mut g = ThreadComm::group(1);
    let Some(c) = g.pop() else {
        unreachable!("group(1) returns one endpoint")
    };
    c
}

/// A telemetered CA-BCD run at P = 4 with an optional fault plan;
/// `record_every = 0` so the only snapshot is the forced final one —
/// cumulative over the whole run, where the stall dominates.
fn run_bcd_telemetered(spec: Option<ChaosSpec>) -> Vec<Registry> {
    let ds = toy_dataset();
    let n = ds.n();
    let shards = partition_primal(&ds, P).unwrap();
    let opts = SolverOpts::builder()
        .b(B)
        .s(4)
        .lam(LAM)
        .iters(24)
        .seed(SEED)
        .record_every(0)
        .reg(Reg::L1)
        .build();
    run_spmd(P, move |rank, comm| {
        telemetry::install(Registry::new(rank, P));
        let sh = &shards[rank];
        let mut be = NativeBackend::new();
        match spec {
            Some(spec) => {
                let inner = std::mem::replace(comm, stub());
                let mut chaos = ChaosComm::new(inner, spec);
                cabcd::solvers::bcd::run(&sh.a_loc, &sh.y_loc, n, &opts, None, &mut chaos, &mut be)
                    .unwrap();
                *comm = chaos.into_inner();
            }
            None => {
                cabcd::solvers::bcd::run(&sh.a_loc, &sh.y_loc, n, &opts, None, comm, &mut be)
                    .unwrap();
            }
        }
        telemetry::take().unwrap()
    })
}

#[test]
fn stalled_rank_is_flagged_as_the_straggler() {
    // Rank 2 sleeps 80 ms before its 6th collective; its peers spend that
    // window blocked inside the allreduce (metered as wire time), while
    // the victim — arriving last — barely waits at all. The low-tail
    // `wait` detector therefore indicts exactly the victim: z ≈ −√3 at
    // P = 4, and the 60 ms deviation clears the 10 ms noise floor.
    let spec = ChaosSpec {
        stall_at: Some(5),
        stall_ms: 80,
        victim: 2,
        ..ChaosSpec::default()
    };
    let regs = run_bcd_telemetered(Some(spec));
    let snaps: Vec<&[ClusterSnapshot]> = regs.iter().map(|r| r.snapshots()).collect();
    for (rank, s) in snaps.iter().enumerate() {
        assert_eq!(*s, snaps[0], "rank {rank}: snapshot sequence diverged");
    }
    let last = snaps[0].last().expect("no final snapshot");
    assert_eq!(
        last.stragglers.len(),
        1,
        "want exactly the victim flagged, got {:?}",
        last.stragglers
    );
    let flag = &last.stragglers[0];
    assert_eq!(flag.rank, 2, "flagged the wrong rank: {flag:?}");
    assert_eq!(flag.op, "wait", "flagged the wrong op: {flag:?}");
    assert!(flag.z <= -1.25, "z {} above the low-tail threshold", flag.z);
    assert!(flag.dev_ns < 0, "victim must be below the wire mean: {flag:?}");
    assert!(
        flag.dev_ns.unsigned_abs() >= 10_000_000,
        "deviation {} ns under the noise floor",
        flag.dev_ns
    );
    // The peers' blocked windows show up as wire time: every non-victim
    // rank's cumulative wire exceeds the victim's.
    let victim_wire = last.ranks[2].wire_ns;
    for rh in &last.ranks {
        if rh.rank != 2 {
            assert!(
                rh.wire_ns > victim_wire,
                "rank {} wire {} not above victim's {}",
                rh.rank,
                rh.wire_ns,
                victim_wire
            );
        }
    }
}

#[test]
fn fault_free_run_flags_no_stragglers() {
    let regs = run_bcd_telemetered(None);
    for (rank, reg) in regs.iter().enumerate() {
        for snap in reg.snapshots() {
            assert!(
                snap.stragglers.is_empty(),
                "rank {rank}: fault-free run flagged {:?}",
                snap.stragglers
            );
        }
    }
}
