//! Golden-equivalence suite for the unified s-step engine (PR 5).
//!
//! The redesign's hard constraint: porting all six solver loops onto the
//! one `engine::Session` pipeline core must leave every trajectory AND
//! every per-rank wire count **bitwise identical** to the pre-redesign
//! per-solver loops. Two golden fixtures enforce that:
//!
//! 1. **Frozen legacy loops** (`mod legacy` below): verbatim copies of
//!    the pre-engine `run()`/`run_overlapped()` implementations of all
//!    six methods, captured at the commit before the redesign. The matrix
//!    test runs every method × s∈{1,4} × overlap∈{off,on} × P∈{1,4}
//!    through both the frozen loop and the engine path and asserts
//!    bitwise equality of iterates, records, prox certificates, Gram
//!    conditioning samples, measured Lemma-3 loads, and CostMeters.
//! 2. **Committed closed-form meter fixture**
//!    (`fixtures/engine_meters.tsv`): the exact per-rank allreduce /
//!    all-to-all / message / word counts each config must produce,
//!    derived from the recursive-doubling formulas — so a payload or
//!    collective-count regression fails even if both paths drift
//!    together.
//!
//! `buf_allocs` (pool warm-up misses) is asserted equal wherever the
//! schedule is unchanged; the four configs whose overlap schedule the PR
//! deliberately improves (prox Gram prefetch, bcd_row's a2a look-ahead,
//! cocoa's pooled combine) exempt only that one field — their wire
//! fields and trajectories stay bitwise-locked.
//!
//! The file also hosts the tooling gate freezing the per-site
//! `clippy::too_many_arguments` allow count in `rust/src/`.

#![allow(clippy::too_many_arguments)]

use std::collections::HashMap;

use cabcd::comm::thread::run_spmd;
use cabcd::comm::SerialComm;
use cabcd::coordinator::{partition_dual, partition_primal, partition_rows};
use cabcd::matrix::io::Dataset;
use cabcd::matrix::{DenseMatrix, Matrix};
use cabcd::metrics::{History, Reference};
use cabcd::prox::Reg;
use cabcd::solvers::cocoa::CocoaOpts;
use cabcd::solvers::{cg, SolverOpts};

/// Frozen pre-engine solver loops — the golden reference implementations,
/// copied verbatim (modulo `crate::` → `cabcd::` paths) from the commit
/// before the engine redesign. DO NOT "improve" this module: its whole
/// value is that it never changes.
mod legacy {
    use cabcd::comm::Communicator;
    use cabcd::error::{Error, Result};
    use cabcd::gram::ComputeBackend;
    use cabcd::linalg::packed::packed_len;
    use cabcd::matrix::{DenseMatrix, Matrix};
    use cabcd::metrics::{
        relative_objective_error, relative_solution_error, History, IterRecord, ProxRecord,
        Reference,
    };
    use cabcd::partition::BlockPartition;
    use cabcd::prox::{Reg, Regularizer};
    use cabcd::sampling::{overlap_tensor_into, BlockSampler};
    use cabcd::solvers::bcd_row::RowPrimalOutput;
    use cabcd::solvers::cocoa::{CocoaOpts, CocoaOutput};
    use cabcd::solvers::common::{
        cond_stride, flatten_blocks, metered_out, objective_value, packed_gram_cond,
        should_record, DualOutput, PrimalOutput, SolverOpts,
    };

    // ---------------- legacy solvers::bcd ------------------------------

    pub fn bcd_run<C: Communicator>(
        a_loc: &Matrix,
        y_loc: &[f64],
        n_global: usize,
        opts: &SolverOpts,
        reference: Option<&Reference>,
        comm: &mut C,
        backend: &mut dyn ComputeBackend,
    ) -> Result<PrimalOutput> {
        if !opts.reg.is_exact_l2() {
            return prox_bcd_run(a_loc, y_loc, n_global, opts, comm, backend);
        }
        if opts.overlap {
            return bcd_run_overlapped(a_loc, y_loc, n_global, opts, reference, comm, backend);
        }
        let d = a_loc.rows();
        let n_loc = a_loc.cols();
        opts.validate(d)?;
        let (s, b) = (opts.s, opts.b);
        let sb = s * b;
        let inv_n = 1.0 / n_global as f64;
        let lam = opts.lam;

        let mut w = vec![0.0; d];
        let mut alpha_loc = vec![0.0; n_loc];
        let mut history = History::default();

        let gl = packed_len(sb);
        let mut buf = vec![0.0; gl + sb];
        let mut z = vec![0.0; n_loc];
        let mut w_blocks = vec![0.0; sb];
        let mut gram_scaled = vec![0.0; sb * sb];
        let mut idx_flat = vec![0usize; sb];
        let mut overlap = vec![0.0; s * s * b * b];

        let mut sampler = BlockSampler::new(d, opts.seed);

        bcd_record(
            &mut history,
            0,
            &w,
            &alpha_loc,
            y_loc,
            n_global,
            lam,
            reference,
            comm,
        )?;

        let outer = opts.outer_iters();
        let stride = cond_stride(sb, outer);
        'outer_loop: for k in 0..outer {
            let blocks = sampler.draw_blocks(s, b);
            flatten_blocks(&blocks, b, &mut idx_flat);

            for ((zi, yi), ai) in z.iter_mut().zip(y_loc).zip(&alpha_loc) {
                *zi = yi - ai;
            }

            let (g_buf, r_buf) = buf.split_at_mut(gl);
            backend.gram_resid(a_loc, &idx_flat, &z, g_buf, r_buf)?;

            comm.allreduce_sum(&mut buf)?;

            if opts.track_gram_cond && k % stride == 0 {
                history
                    .gram_conds
                    .push(packed_gram_cond(&buf, sb, inv_n, lam, &mut gram_scaled));
            }

            overlap_tensor_into(&blocks, &mut overlap);
            for (j, blk) in blocks.iter().enumerate() {
                for (i, &row) in blk.iter().enumerate() {
                    w_blocks[j * b + i] = w[row];
                }
            }
            let (g_buf, r_buf) = buf.split_at(gl);
            let deltas =
                backend.ca_inner_solve(s, b, g_buf, r_buf, &w_blocks, &overlap, lam, inv_n)?;

            for (j, blk) in blocks.iter().enumerate() {
                for (i, &row) in blk.iter().enumerate() {
                    w[row] += deltas[j * b + i];
                }
            }
            backend.alpha_update(a_loc, &idx_flat, &deltas, &mut alpha_loc)?;

            let h_now = (k + 1) * s;
            history.iters = h_now;
            if should_record(h_now, s, opts) || k + 1 == outer {
                bcd_record(
                    &mut history,
                    h_now,
                    &w,
                    &alpha_loc,
                    y_loc,
                    n_global,
                    lam,
                    reference,
                    comm,
                )?;
                if let (Some(tol), Some(_)) = (opts.tol, reference) {
                    if history.final_obj_err() <= tol {
                        break 'outer_loop;
                    }
                }
            }
        }

        history.meter = *comm.meter();
        Ok(PrimalOutput {
            w,
            alpha_loc,
            history,
        })
    }

    fn bcd_run_overlapped<C: Communicator>(
        a_loc: &Matrix,
        y_loc: &[f64],
        n_global: usize,
        opts: &SolverOpts,
        reference: Option<&Reference>,
        comm: &mut C,
        backend: &mut dyn ComputeBackend,
    ) -> Result<PrimalOutput> {
        let d = a_loc.rows();
        let n_loc = a_loc.cols();
        opts.validate(d)?;
        let (s, b) = (opts.s, opts.b);
        let sb = s * b;
        let gl = packed_len(sb);
        let inv_n = 1.0 / n_global as f64;
        let lam = opts.lam;

        let mut w = vec![0.0; d];
        let mut alpha_loc = vec![0.0; n_loc];
        let mut history = History::default();

        let mut z = vec![0.0; n_loc];
        let mut w_blocks = vec![0.0; sb];
        let mut gram_scaled = vec![0.0; sb * sb];
        let mut idx_cur = vec![0usize; sb];
        let mut idx_next = vec![0usize; sb];
        let mut overlap = vec![0.0; s * s * b * b];

        let mut sampler = BlockSampler::new(d, opts.seed);

        bcd_record(
            &mut history,
            0,
            &w,
            &alpha_loc,
            y_loc,
            n_global,
            lam,
            reference,
            comm,
        )?;

        let outer = opts.outer_iters();
        let stride = cond_stride(sb, outer);

        let mut blocks: Vec<Vec<usize>> = Vec::new();
        let mut next_buf: Vec<f64> = Vec::new();
        if outer > 0 {
            blocks = sampler.draw_blocks(s, b);
            flatten_blocks(&blocks, b, &mut idx_cur);
            next_buf = comm.take_buf(gl + sb);
            backend.gram_only(a_loc, &idx_cur, &mut next_buf[..gl])?;
        }
        'outer_loop: for k in 0..outer {
            let mut buf = std::mem::take(&mut next_buf);

            for ((zi, yi), ai) in z.iter_mut().zip(y_loc).zip(&alpha_loc) {
                *zi = yi - ai;
            }
            backend.resid_only(a_loc, &idx_cur, &z, &mut buf[gl..])?;

            let handle = comm.iallreduce_start(buf)?;

            let mut pending_blocks: Option<Vec<Vec<usize>>> = None;
            if k + 1 < outer {
                let nb = sampler.draw_blocks(s, b);
                flatten_blocks(&nb, b, &mut idx_next);
                next_buf = comm.take_buf(gl + sb);
                backend.gram_only(a_loc, &idx_next, &mut next_buf[..gl])?;
                pending_blocks = Some(nb);
            }
            overlap_tensor_into(&blocks, &mut overlap);
            for (j, blk) in blocks.iter().enumerate() {
                for (i, &row) in blk.iter().enumerate() {
                    w_blocks[j * b + i] = w[row];
                }
            }
            let buf = comm.iallreduce_wait(handle)?;

            if opts.track_gram_cond && k % stride == 0 {
                history
                    .gram_conds
                    .push(packed_gram_cond(&buf, sb, inv_n, lam, &mut gram_scaled));
            }

            let (g_buf, r_buf) = buf.split_at(gl);
            let deltas =
                backend.ca_inner_solve(s, b, g_buf, r_buf, &w_blocks, &overlap, lam, inv_n)?;
            for (j, blk) in blocks.iter().enumerate() {
                for (i, &row) in blk.iter().enumerate() {
                    w[row] += deltas[j * b + i];
                }
            }
            backend.alpha_update(a_loc, &idx_cur, &deltas, &mut alpha_loc)?;
            comm.give_buf(buf);

            if let Some(nb) = pending_blocks {
                blocks = nb;
                std::mem::swap(&mut idx_cur, &mut idx_next);
            }

            let h_now = (k + 1) * s;
            history.iters = h_now;
            if should_record(h_now, s, opts) || k + 1 == outer {
                bcd_record(
                    &mut history,
                    h_now,
                    &w,
                    &alpha_loc,
                    y_loc,
                    n_global,
                    lam,
                    reference,
                    comm,
                )?;
                if let (Some(tol), Some(_)) = (opts.tol, reference) {
                    if history.final_obj_err() <= tol {
                        break 'outer_loop;
                    }
                }
            }
        }
        if !next_buf.is_empty() {
            comm.give_buf(next_buf);
        }

        history.meter = *comm.meter();
        Ok(PrimalOutput {
            w,
            alpha_loc,
            history,
        })
    }

    fn bcd_record<C: Communicator>(
        history: &mut History,
        iter: usize,
        w: &[f64],
        alpha_loc: &[f64],
        y_loc: &[f64],
        n_global: usize,
        lam: f64,
        reference: Option<&Reference>,
        comm: &mut C,
    ) -> Result<()> {
        let Some(r) = reference else { return Ok(()) };
        let resid_sq = metered_out(comm, |c| {
            let mut part = [alpha_loc
                .iter()
                .zip(y_loc)
                .map(|(a, y)| (a - y) * (a - y))
                .sum::<f64>()];
            c.allreduce_sum(&mut part)?;
            Ok(part[0])
        })?;
        let w_norm_sq: f64 = w.iter().map(|v| v * v).sum();
        let f_alg = objective_value(resid_sq, w_norm_sq, n_global, lam);
        history.records.push(IterRecord {
            iter,
            obj_err: relative_objective_error(f_alg, r.f_opt),
            sol_err: relative_solution_error(w, &r.w_opt),
        });
        Ok(())
    }

    // ---------------- legacy solvers::bdcd -----------------------------

    pub fn bdcd_run<C: Communicator>(
        a_loc: &Matrix,
        y: &[f64],
        d_global: usize,
        d_offset: usize,
        opts: &SolverOpts,
        reference: Option<&Reference>,
        comm: &mut C,
        backend: &mut dyn ComputeBackend,
    ) -> Result<DualOutput> {
        if !opts.reg.is_exact_l2() {
            return prox_bdcd_run(a_loc, y, d_global, d_offset, opts, comm, backend);
        }
        if opts.overlap {
            return bdcd_run_overlapped(a_loc, y, d_global, d_offset, opts, reference, comm, backend);
        }
        let n = a_loc.rows();
        let d_loc = a_loc.cols();
        opts.validate(n)?;
        let (s, b) = (opts.s, opts.b);
        let sb = s * b;
        let inv_n = 1.0 / n as f64;
        let lam = opts.lam;

        let mut alpha = vec![0.0; n];
        let mut w_loc = vec![0.0; d_loc];
        let mut history = History::default();

        let gl = packed_len(sb);
        let mut buf = vec![0.0; gl + sb];
        let mut a_blocks = vec![0.0; sb];
        let mut y_blocks = vec![0.0; sb];
        let mut gram_scaled = vec![0.0; sb * sb];
        let mut idx_flat = vec![0usize; sb];
        let mut scaled_deltas = vec![0.0; sb];
        let mut overlap = vec![0.0; s * s * b * b];

        let mut sampler = BlockSampler::new(n, opts.seed);

        bdcd_record(
            &mut history,
            0,
            &w_loc,
            d_offset,
            a_loc,
            y,
            lam,
            reference,
            comm,
        )?;

        let outer = opts.outer_iters();
        let stride = cond_stride(sb, outer);
        'outer_loop: for k in 0..outer {
            let blocks = sampler.draw_blocks(s, b);
            flatten_blocks(&blocks, b, &mut idx_flat);

            let (g_buf, r_buf) = buf.split_at_mut(gl);
            backend.gram_resid(a_loc, &idx_flat, &w_loc, g_buf, r_buf)?;

            comm.allreduce_sum(&mut buf)?;

            if opts.track_gram_cond && k % stride == 0 {
                history.gram_conds.push(packed_gram_cond(
                    &buf,
                    sb,
                    inv_n * inv_n / lam,
                    inv_n,
                    &mut gram_scaled,
                ));
            }

            overlap_tensor_into(&blocks, &mut overlap);
            for (j, blk) in blocks.iter().enumerate() {
                for (i, &row) in blk.iter().enumerate() {
                    a_blocks[j * b + i] = alpha[row];
                    y_blocks[j * b + i] = y[row];
                }
            }
            let (g_buf, r_buf) = buf.split_at(gl);
            let deltas = backend.ca_dual_inner_solve(
                s, b, g_buf, r_buf, &a_blocks, &y_blocks, &overlap, lam, inv_n,
            )?;

            for (j, blk) in blocks.iter().enumerate() {
                for (i, &row) in blk.iter().enumerate() {
                    alpha[row] += deltas[j * b + i];
                }
            }
            let scale = -1.0 / (lam * n as f64);
            for (sd, &dv) in scaled_deltas.iter_mut().zip(&deltas) {
                *sd = scale * dv;
            }
            backend.alpha_update(a_loc, &idx_flat, &scaled_deltas, &mut w_loc)?;

            let h_now = (k + 1) * s;
            history.iters = h_now;
            if should_record(h_now, s, opts) || k + 1 == outer {
                bdcd_record(
                    &mut history,
                    h_now,
                    &w_loc,
                    d_offset,
                    a_loc,
                    y,
                    lam,
                    reference,
                    comm,
                )?;
                if let (Some(tol), Some(_)) = (opts.tol, reference) {
                    if history.final_obj_err() <= tol {
                        break 'outer_loop;
                    }
                }
            }
        }

        history.meter = *comm.meter();
        let w_full = gather_w(&w_loc, d_global, d_offset, comm)?;
        Ok(DualOutput {
            w_loc,
            w_full,
            alpha,
            history,
        })
    }

    fn bdcd_run_overlapped<C: Communicator>(
        a_loc: &Matrix,
        y: &[f64],
        d_global: usize,
        d_offset: usize,
        opts: &SolverOpts,
        reference: Option<&Reference>,
        comm: &mut C,
        backend: &mut dyn ComputeBackend,
    ) -> Result<DualOutput> {
        let n = a_loc.rows();
        let d_loc = a_loc.cols();
        opts.validate(n)?;
        let (s, b) = (opts.s, opts.b);
        let sb = s * b;
        let gl = packed_len(sb);
        let inv_n = 1.0 / n as f64;
        let lam = opts.lam;

        let mut alpha = vec![0.0; n];
        let mut w_loc = vec![0.0; d_loc];
        let mut history = History::default();

        let mut a_blocks = vec![0.0; sb];
        let mut y_blocks = vec![0.0; sb];
        let mut gram_scaled = vec![0.0; sb * sb];
        let mut idx_cur = vec![0usize; sb];
        let mut idx_next = vec![0usize; sb];
        let mut scaled_deltas = vec![0.0; sb];
        let mut overlap = vec![0.0; s * s * b * b];

        let mut sampler = BlockSampler::new(n, opts.seed);

        bdcd_record(
            &mut history,
            0,
            &w_loc,
            d_offset,
            a_loc,
            y,
            lam,
            reference,
            comm,
        )?;

        let outer = opts.outer_iters();
        let stride = cond_stride(sb, outer);

        let mut blocks: Vec<Vec<usize>> = Vec::new();
        let mut next_buf: Vec<f64> = Vec::new();
        if outer > 0 {
            blocks = sampler.draw_blocks(s, b);
            flatten_blocks(&blocks, b, &mut idx_cur);
            next_buf = comm.take_buf(gl + sb);
            backend.gram_only(a_loc, &idx_cur, &mut next_buf[..gl])?;
        }
        'outer_loop: for k in 0..outer {
            let mut buf = std::mem::take(&mut next_buf);

            backend.resid_only(a_loc, &idx_cur, &w_loc, &mut buf[gl..])?;

            let handle = comm.iallreduce_start(buf)?;

            let mut pending_blocks: Option<Vec<Vec<usize>>> = None;
            if k + 1 < outer {
                let nb = sampler.draw_blocks(s, b);
                flatten_blocks(&nb, b, &mut idx_next);
                next_buf = comm.take_buf(gl + sb);
                backend.gram_only(a_loc, &idx_next, &mut next_buf[..gl])?;
                pending_blocks = Some(nb);
            }
            overlap_tensor_into(&blocks, &mut overlap);
            for (j, blk) in blocks.iter().enumerate() {
                for (i, &row) in blk.iter().enumerate() {
                    a_blocks[j * b + i] = alpha[row];
                    y_blocks[j * b + i] = y[row];
                }
            }
            let buf = comm.iallreduce_wait(handle)?;

            if opts.track_gram_cond && k % stride == 0 {
                history.gram_conds.push(packed_gram_cond(
                    &buf,
                    sb,
                    inv_n * inv_n / lam,
                    inv_n,
                    &mut gram_scaled,
                ));
            }

            let (g_buf, r_buf) = buf.split_at(gl);
            let deltas = backend.ca_dual_inner_solve(
                s, b, g_buf, r_buf, &a_blocks, &y_blocks, &overlap, lam, inv_n,
            )?;
            for (j, blk) in blocks.iter().enumerate() {
                for (i, &row) in blk.iter().enumerate() {
                    alpha[row] += deltas[j * b + i];
                }
            }
            let scale = -1.0 / (lam * n as f64);
            for (sd, &dv) in scaled_deltas.iter_mut().zip(&deltas) {
                *sd = scale * dv;
            }
            backend.alpha_update(a_loc, &idx_cur, &scaled_deltas, &mut w_loc)?;
            comm.give_buf(buf);

            if let Some(nb) = pending_blocks {
                blocks = nb;
                std::mem::swap(&mut idx_cur, &mut idx_next);
            }

            let h_now = (k + 1) * s;
            history.iters = h_now;
            if should_record(h_now, s, opts) || k + 1 == outer {
                bdcd_record(
                    &mut history,
                    h_now,
                    &w_loc,
                    d_offset,
                    a_loc,
                    y,
                    lam,
                    reference,
                    comm,
                )?;
                if let (Some(tol), Some(_)) = (opts.tol, reference) {
                    if history.final_obj_err() <= tol {
                        break 'outer_loop;
                    }
                }
            }
        }
        if !next_buf.is_empty() {
            comm.give_buf(next_buf);
        }

        history.meter = *comm.meter();
        let w_full = gather_w(&w_loc, d_global, d_offset, comm)?;
        Ok(DualOutput {
            w_loc,
            w_full,
            alpha,
            history,
        })
    }

    fn gather_w<C: Communicator>(
        w_loc: &[f64],
        d_global: usize,
        d_offset: usize,
        comm: &mut C,
    ) -> Result<Vec<f64>> {
        metered_out(comm, |c| {
            let mut full = vec![0.0; d_global];
            full[d_offset..d_offset + w_loc.len()].copy_from_slice(w_loc);
            c.allreduce_sum(&mut full)?;
            Ok(full)
        })
    }

    fn bdcd_record<C: Communicator>(
        history: &mut History,
        iter: usize,
        w_loc: &[f64],
        d_offset: usize,
        a_loc: &Matrix,
        y: &[f64],
        lam: f64,
        reference: Option<&Reference>,
        comm: &mut C,
    ) -> Result<()> {
        let Some(r) = reference else { return Ok(()) };
        let n = a_loc.rows();
        let (xtw, w_norm_sq, sol_err_sq) = metered_out(comm, |c| {
            let mut payload = vec![0.0; n + 2];
            let (head, tail) = payload.split_at_mut(n);
            a_loc.matvec(w_loc, head)?;
            tail[0] = w_loc.iter().map(|v| v * v).sum();
            tail[1] = w_loc
                .iter()
                .zip(&r.w_opt[d_offset..d_offset + w_loc.len()])
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            c.allreduce_sum(&mut payload)?;
            let wns = payload[n];
            let ses = payload[n + 1];
            payload.truncate(n);
            Ok((payload, wns, ses))
        })?;
        let resid_sq: f64 = xtw.iter().zip(y).map(|(a, b)| (a - b) * (a - b)).sum();
        let f_alg = objective_value(resid_sq, w_norm_sq, n, lam);
        let w_opt_norm_sq: f64 = r.w_opt.iter().map(|v| v * v).sum();
        history.records.push(IterRecord {
            iter,
            obj_err: relative_objective_error(f_alg, r.f_opt),
            sol_err: (sol_err_sq / w_opt_norm_sq.max(1e-300)).sqrt(),
        });
        Ok(())
    }

    // ---------------- legacy solvers::bcd_row --------------------------

    pub fn bcd_row_run<C: Communicator>(
        x_rows: &Matrix,
        y_loc: &[f64],
        d_global: usize,
        d_offset: usize,
        opts: &SolverOpts,
        reference: Option<&Reference>,
        comm: &mut C,
        backend: &mut dyn ComputeBackend,
    ) -> Result<RowPrimalOutput> {
        if !opts.reg.is_exact_l2() {
            return Err(Error::InvalidArg("legacy bcd_row: l2 only".into()));
        }
        let d_loc = x_rows.rows();
        let n = x_rows.cols();
        opts.validate(d_global)?;
        let p = comm.size();
        let rank = comm.rank();
        let row_part = BlockPartition::new(d_global, p);
        let col_part = BlockPartition::new(n, p);
        let (col_lo, col_hi) = col_part.range(rank);
        let n_loc = col_hi - col_lo;
        if y_loc.len() != n_loc {
            return Err(Error::Shape("legacy bcd_row: y_loc length".into()));
        }
        let (s, b) = (opts.s, opts.b);
        let sb = s * b;
        let inv_n = 1.0 / n as f64;
        let lam = opts.lam;

        let mut w_loc = vec![0.0; d_loc];
        let mut alpha_loc = vec![0.0; n_loc];
        let mut history = History::default();
        let mut max_loads = Vec::new();

        let gl = packed_len(sb);
        let mut buf = vec![0.0; gl + sb + sb];
        let mut z = vec![0.0; n_loc];
        let mut overlap = vec![0.0; s * s * b * b];
        let mut deltas_scratch: Vec<f64>;

        let mut sampler = BlockSampler::new(d_global, opts.seed);

        bcd_row_record(
            &mut history, 0, &w_loc, &alpha_loc, y_loc, n, lam, reference, comm,
        )?;

        let outer = opts.outer_iters();
        'outer_loop: for k in 0..outer {
            let blocks = sampler.draw_blocks(s, b);
            let flat: Vec<usize> = blocks.iter().flatten().copied().collect();

            let mut send: Vec<Vec<f64>> = (0..p).map(|_| Vec::new()).collect();
            let mut owned = 0usize;
            for &i in &flat {
                if row_part.owner(i) == rank {
                    owned += 1;
                    let local_row = i - d_offset;
                    for (q, dst) in send.iter_mut().enumerate() {
                        let (lo, hi) = col_part.range(q);
                        let start = dst.len();
                        dst.resize(start + (hi - lo), 0.0);
                        gather_row_segment(x_rows, local_row, lo, hi, &mut dst[start..])?;
                    }
                }
            }
            let mut recv_lens = vec![0usize; p];
            for &i in &flat {
                recv_lens[row_part.owner(i)] += n_loc;
            }
            let mut load_buf = vec![0.0f64; p];
            load_buf[rank] = owned as f64;
            let received = if opts.overlap {
                let handle = comm.iall_to_all_start(send, &recv_lens)?;
                metered_out(comm, |c| c.allreduce_sum(&mut load_buf))?;
                comm.iall_to_all_wait(handle)?
            } else {
                metered_out(comm, |c| c.allreduce_sum(&mut load_buf))?;
                comm.all_to_all_expect(send, &recv_lens)?
            };
            max_loads.push(load_buf.iter().fold(0.0f64, |a, &v| a.max(v)) as usize);
            let mut y_cols = DenseMatrix::zeros(sb, n_loc);
            let mut cursor = vec![0usize; p];
            for (row_slot, &i) in flat.iter().enumerate() {
                let owner = row_part.owner(i);
                let seg = &received[owner][cursor[owner]..cursor[owner] + n_loc];
                y_cols.data_mut()[row_slot * n_loc..(row_slot + 1) * n_loc].copy_from_slice(seg);
                cursor[owner] += n_loc;
            }
            let y_cols = Matrix::Dense(y_cols);

            for ((zi, yi), ai) in z.iter_mut().zip(y_loc).zip(&alpha_loc) {
                *zi = yi - ai;
            }
            let all_idx: Vec<usize> = (0..sb).collect();
            {
                let (g_buf, rest) = buf.split_at_mut(gl);
                let (r_buf, w_buf) = rest.split_at_mut(sb);
                backend.gram_resid(&y_cols, &all_idx, &z, g_buf, r_buf)?;
                w_buf.fill(0.0);
                for (slot, &i) in flat.iter().enumerate() {
                    if row_part.owner(i) == rank {
                        w_buf[slot] = w_loc[i - d_offset];
                    }
                }
            }
            if opts.overlap {
                let handle = comm.iallreduce_start(std::mem::take(&mut buf))?;
                overlap_tensor_into(&blocks, &mut overlap);
                buf = comm.iallreduce_wait(handle)?;
            } else {
                comm.allreduce_sum(&mut buf)?;
                overlap_tensor_into(&blocks, &mut overlap);
            }
            {
                let (g_buf, rest) = buf.split_at(gl);
                let (r_buf, w_buf) = rest.split_at(sb);
                deltas_scratch =
                    backend.ca_inner_solve(s, b, g_buf, r_buf, w_buf, &overlap, lam, inv_n)?;
            }

            for (slot, &i) in flat.iter().enumerate() {
                if row_part.owner(i) == rank {
                    w_loc[i - d_offset] += deltas_scratch[slot];
                }
            }
            backend.alpha_update(&y_cols, &all_idx, &deltas_scratch, &mut alpha_loc)?;

            let h_now = (k + 1) * s;
            history.iters = h_now;
            if should_record(h_now, s, opts) || k + 1 == outer {
                bcd_row_record(
                    &mut history, h_now, &w_loc, &alpha_loc, y_loc, n, lam, reference, comm,
                )?;
                if let (Some(tol), Some(_)) = (opts.tol, reference) {
                    if history.final_obj_err() <= tol {
                        break 'outer_loop;
                    }
                }
            }
        }

        history.meter = *comm.meter();
        let w_full = metered_out(comm, |c| {
            let mut full = vec![0.0; d_global];
            full[d_offset..d_offset + d_loc].copy_from_slice(&w_loc);
            c.allreduce_sum(&mut full)?;
            Ok(full)
        })?;
        Ok(RowPrimalOutput {
            w_loc,
            w_full,
            history,
            max_loads,
        })
    }

    fn gather_row_segment(
        x: &Matrix,
        row: usize,
        lo: usize,
        hi: usize,
        out: &mut [f64],
    ) -> Result<()> {
        match x {
            Matrix::Dense(m) => {
                out.copy_from_slice(&m.row(row)[lo..hi]);
            }
            Matrix::Csr(m) => {
                out.fill(0.0);
                let (cols, vals) = m.row(row);
                for (&c, &v) in cols.iter().zip(vals) {
                    let c = c as usize;
                    if c >= lo && c < hi {
                        out[c - lo] = v;
                    }
                }
            }
        }
        Ok(())
    }

    fn bcd_row_record<C: Communicator>(
        history: &mut History,
        iter: usize,
        w_loc: &[f64],
        alpha_loc: &[f64],
        y_loc: &[f64],
        n: usize,
        lam: f64,
        reference: Option<&Reference>,
        comm: &mut C,
    ) -> Result<()> {
        let Some(r) = reference else { return Ok(()) };
        let rank = comm.rank();
        let p = comm.size();
        let d_part = BlockPartition::new(r.w_opt.len(), p);
        let (d_lo, _d_hi) = d_part.range(rank);
        let sums = metered_out(comm, |c| {
            let mut part = [
                alpha_loc
                    .iter()
                    .zip(y_loc)
                    .map(|(a, y)| (a - y) * (a - y))
                    .sum::<f64>(),
                w_loc.iter().map(|v| v * v).sum::<f64>(),
                w_loc
                    .iter()
                    .zip(&r.w_opt[d_lo..d_lo + w_loc.len()])
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f64>(),
            ];
            c.allreduce_sum(&mut part)?;
            Ok(part)
        })?;
        let f_alg = objective_value(sums[0], sums[1], n, lam);
        let w_opt_norm_sq: f64 = r.w_opt.iter().map(|v| v * v).sum();
        history.records.push(IterRecord {
            iter,
            obj_err: relative_objective_error(f_alg, r.f_opt),
            sol_err: (sums[2] / w_opt_norm_sq.max(1e-300)).sqrt(),
        });
        Ok(())
    }

    // ---------------- legacy solvers::cocoa ----------------------------

    pub fn cocoa_run<C: Communicator>(
        a_loc: &Matrix,
        y_loc: &[f64],
        n_global: usize,
        opts: &CocoaOpts,
        reference: Option<&Reference>,
        comm: &mut C,
    ) -> Result<CocoaOutput> {
        let d = a_loc.rows();
        let n_loc = a_loc.cols();
        let lam = opts.lam;
        let n = n_global as f64;
        let p = comm.size() as f64;

        let mut w = vec![0.0; d];
        let mut alpha_loc = vec![0.0; n_loc];
        let mut history = History::default();
        let at = a_loc.transpose();
        let mut col_norms = vec![0.0; n_loc];
        for j in 0..n_loc {
            let mut row = vec![0.0; d];
            at.gather_rows(&[j], &mut row)?;
            col_norms[j] = row.iter().map(|v| v * v).sum();
        }

        let mut sampler = if n_loc > 0 {
            Some(BlockSampler::new(n_loc, opts.seed ^ (comm.rank() as u64) << 32))
        } else {
            None
        };

        cocoa_record(&mut history, 0, &w, a_loc, y_loc, n_global, lam, reference, comm)?;

        let mut xrow = vec![0.0; d];
        let mut alpha_work = vec![0.0; n_loc];
        for round in 1..=opts.rounds {
            let mut w_local = w.clone();
            let mut dw = vec![0.0; d];
            alpha_work.copy_from_slice(&alpha_loc);
            if let Some(sampler) = sampler.as_mut() {
                for _ in 0..opts.local_iters {
                    let j = sampler.draw_block(1)[0];
                    at.gather_rows(&[j], &mut xrow)?;
                    let theta = col_norms[j] / (lam * n * n) + 1.0 / n;
                    let xw: f64 = xrow.iter().zip(&w_local).map(|(a, b)| a * b).sum();
                    let rhs = -xw + alpha_work[j] + y_loc[j];
                    let da = -(1.0 / n) * rhs / theta;
                    alpha_work[j] += da;
                    let scale = -da / (lam * n);
                    for (t, &xv) in xrow.iter().enumerate() {
                        w_local[t] += scale * xv;
                        dw[t] += scale * xv;
                    }
                }
            }
            if opts.overlap {
                let handle = comm.iallreduce_start(dw)?;
                for (a, &work) in alpha_loc.iter_mut().zip(&alpha_work) {
                    *a += (work - *a) / p;
                }
                let dw = comm.iallreduce_wait(handle)?;
                for (wi, dv) in w.iter_mut().zip(&dw) {
                    *wi += dv / p;
                }
                comm.give_buf(dw);
            } else {
                comm.allreduce_sum(&mut dw)?;
                for (wi, dv) in w.iter_mut().zip(&dw) {
                    *wi += dv / p;
                }
                for (a, &work) in alpha_loc.iter_mut().zip(&alpha_work) {
                    *a += (work - *a) / p;
                }
            }

            if (opts.record_every > 0 && round % opts.record_every == 0) || round == opts.rounds {
                cocoa_record(&mut history, round, &w, a_loc, y_loc, n_global, lam, reference, comm)?;
            }
            history.iters = round;
        }

        history.meter = *comm.meter();
        Ok(CocoaOutput {
            w,
            alpha_loc,
            history,
        })
    }

    fn cocoa_record<C: Communicator>(
        history: &mut History,
        iter: usize,
        w: &[f64],
        a_loc: &Matrix,
        y_loc: &[f64],
        n_global: usize,
        lam: f64,
        reference: Option<&Reference>,
        comm: &mut C,
    ) -> Result<()> {
        let Some(r) = reference else { return Ok(()) };
        let resid_sq = metered_out(comm, |c| {
            let mut xtw = vec![0.0; a_loc.cols()];
            a_loc.matvec_t(w, &mut xtw)?;
            let mut part = [xtw
                .iter()
                .zip(y_loc)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()];
            c.allreduce_sum(&mut part)?;
            Ok(part[0])
        })?;
        let w_norm_sq: f64 = w.iter().map(|v| v * v).sum();
        let f_alg = objective_value(resid_sq, w_norm_sq, n_global, lam);
        history.records.push(IterRecord {
            iter,
            obj_err: relative_objective_error(f_alg, r.f_opt),
            sol_err: relative_solution_error(w, &r.w_opt),
        });
        Ok(())
    }

    // ---------------- legacy prox::bcd ---------------------------------

    pub fn prox_bcd_run<C: Communicator>(
        a_loc: &Matrix,
        y_loc: &[f64],
        n_global: usize,
        opts: &SolverOpts,
        comm: &mut C,
        backend: &mut dyn ComputeBackend,
    ) -> Result<PrimalOutput> {
        let d = a_loc.rows();
        let n_loc = a_loc.cols();
        opts.validate(d)?;
        let (s, b) = (opts.s, opts.b);
        let sb = s * b;
        let gl = packed_len(sb);
        let inv_n = 1.0 / n_global as f64;
        let lam = opts.lam;
        let reg = opts.reg;

        let mut w = vec![0.0; d];
        let mut alpha_loc = vec![0.0; n_loc];
        let mut history = History::default();

        let mut buf = vec![0.0; gl + sb];
        let mut z = vec![0.0; n_loc];
        let mut w_blocks = vec![0.0; sb];
        let mut gram_scaled = vec![0.0; sb * sb];
        let mut idx_flat = vec![0usize; sb];
        let mut overlap = vec![0.0; s * s * b * b];

        let mut sampler = BlockSampler::new(d, opts.seed);

        prox_bcd_record(
            &mut history,
            0,
            &w,
            &alpha_loc,
            y_loc,
            a_loc,
            n_global,
            lam,
            &reg,
            comm,
        )?;

        let outer = opts.outer_iters();
        let stride = cond_stride(sb, outer);
        'outer_loop: for k in 0..outer {
            let blocks = sampler.draw_blocks(s, b);
            flatten_blocks(&blocks, b, &mut idx_flat);

            for ((zi, yi), ai) in z.iter_mut().zip(y_loc).zip(&alpha_loc) {
                *zi = yi - ai;
            }
            {
                let (g_buf, r_buf) = buf.split_at_mut(gl);
                backend.gram_resid(a_loc, &idx_flat, &z, g_buf, r_buf)?;
            }

            if opts.overlap {
                let handle = comm.iallreduce_start(std::mem::take(&mut buf))?;
                overlap_tensor_into(&blocks, &mut overlap);
                gather_w_blocks(&blocks, b, &w, &mut w_blocks);
                buf = comm.iallreduce_wait(handle)?;
            } else {
                comm.allreduce_sum(&mut buf)?;
                overlap_tensor_into(&blocks, &mut overlap);
                gather_w_blocks(&blocks, b, &w, &mut w_blocks);
            }

            if opts.track_gram_cond && k % stride == 0 {
                let (_, mu2) = reg.weights(lam);
                history
                    .gram_conds
                    .push(packed_gram_cond(&buf, sb, inv_n, mu2, &mut gram_scaled));
            }

            let (g_buf, r_buf) = buf.split_at(gl);
            let deltas = backend
                .ca_prox_inner_solve(s, b, g_buf, r_buf, &w_blocks, &overlap, lam, inv_n, &reg)?;
            for (j, blk) in blocks.iter().enumerate() {
                for (i, &row) in blk.iter().enumerate() {
                    w[row] += deltas[j * b + i];
                }
            }
            backend.alpha_update(a_loc, &idx_flat, &deltas, &mut alpha_loc)?;

            let h_now = (k + 1) * s;
            history.iters = h_now;
            if should_record(h_now, s, opts) || k + 1 == outer {
                prox_bcd_record(
                    &mut history,
                    h_now,
                    &w,
                    &alpha_loc,
                    y_loc,
                    a_loc,
                    n_global,
                    lam,
                    &reg,
                    comm,
                )?;
                if let Some(tol) = opts.tol {
                    if prox_converged(&history, tol) {
                        break 'outer_loop;
                    }
                }
            }
        }

        history.meter = *comm.meter();
        Ok(PrimalOutput {
            w,
            alpha_loc,
            history,
        })
    }

    fn gather_w_blocks(blocks: &[Vec<usize>], b: usize, w: &[f64], w_blocks: &mut [f64]) {
        for (j, blk) in blocks.iter().enumerate() {
            for (i, &row) in blk.iter().enumerate() {
                w_blocks[j * b + i] = w[row];
            }
        }
    }

    fn prox_converged(history: &History, tol: f64) -> bool {
        match history.prox.last() {
            Some(r) if r.gap.is_finite() => r.gap <= tol,
            Some(r) => r.subgrad <= tol,
            None => false,
        }
    }

    fn prox_bcd_record<C: Communicator>(
        history: &mut History,
        iter: usize,
        w: &[f64],
        alpha_loc: &[f64],
        y_loc: &[f64],
        a_loc: &Matrix,
        n_global: usize,
        lam: f64,
        reg: &Reg,
        comm: &mut C,
    ) -> Result<()> {
        let d = w.len();
        let payload = metered_out(comm, |c| {
            let mut payload = vec![0.0; d + 2];
            let z: Vec<f64> = y_loc
                .iter()
                .zip(alpha_loc)
                .map(|(y, a)| y - a)
                .collect();
            a_loc.matvec(&z, &mut payload[..d])?;
            payload[d] = z.iter().map(|v| v * v).sum();
            payload[d + 1] = y_loc.iter().zip(&z).map(|(a, b)| a * b).sum();
            c.allreduce_sum(&mut payload)?;
            Ok(payload)
        })?;
        let (resid_sq, y_dot_z) = (payload[d], payload[d + 1]);
        let n = n_global as f64;
        let sigma: Vec<f64> = payload[..d].iter().map(|v| v / n).collect();
        let smooth_grad: Vec<f64> = sigma.iter().map(|v| -v).collect();
        let pen_obj = resid_sq / (2.0 * n) + reg.penalty(w, lam);
        let gap = reg.duality_gap(w, &sigma, resid_sq, y_dot_z, n_global, lam);
        let subgrad = reg.subgrad_residual(&smooth_grad, w, lam);
        history.prox.push(ProxRecord {
            iter,
            pen_obj,
            gap,
            subgrad,
            nnz: Reg::nnz(w),
        });
        Ok(())
    }

    // ---------------- legacy prox::bdcd --------------------------------

    pub fn prox_bdcd_run<C: Communicator>(
        a_loc: &Matrix,
        y: &[f64],
        d_global: usize,
        d_offset: usize,
        opts: &SolverOpts,
        comm: &mut C,
        backend: &mut dyn ComputeBackend,
    ) -> Result<DualOutput> {
        let n = a_loc.rows();
        let d_loc = a_loc.cols();
        opts.validate(n)?;
        let (s, b) = (opts.s, opts.b);
        let sb = s * b;
        let gl = packed_len(sb);
        let inv_n = 1.0 / n as f64;
        let lam = opts.lam;
        let reg = opts.reg;

        let mut alpha = vec![0.0; n];
        let mut w_loc = vec![0.0; d_loc];
        let mut history = History::default();

        let mut buf = vec![0.0; gl + sb];
        let mut a_blocks = vec![0.0; sb];
        let mut y_blocks = vec![0.0; sb];
        let mut gram_scaled = vec![0.0; sb * sb];
        let mut idx_flat = vec![0usize; sb];
        let mut scaled_deltas = vec![0.0; sb];
        let mut overlap = vec![0.0; s * s * b * b];

        let mut sampler = BlockSampler::new(n, opts.seed);

        prox_bdcd_record(&mut history, 0, &alpha, &w_loc, y, a_loc, lam, &reg, comm)?;

        let outer = opts.outer_iters();
        let stride = cond_stride(sb, outer);
        'outer_loop: for k in 0..outer {
            let blocks = sampler.draw_blocks(s, b);
            flatten_blocks(&blocks, b, &mut idx_flat);

            {
                let (g_buf, r_buf) = buf.split_at_mut(gl);
                backend.gram_resid(a_loc, &idx_flat, &w_loc, g_buf, r_buf)?;
            }

            if opts.overlap {
                let handle = comm.iallreduce_start(std::mem::take(&mut buf))?;
                overlap_tensor_into(&blocks, &mut overlap);
                gather_blocks(&blocks, b, &alpha, y, &mut a_blocks, &mut y_blocks);
                buf = comm.iallreduce_wait(handle)?;
            } else {
                comm.allreduce_sum(&mut buf)?;
                overlap_tensor_into(&blocks, &mut overlap);
                gather_blocks(&blocks, b, &alpha, y, &mut a_blocks, &mut y_blocks);
            }

            if opts.track_gram_cond && k % stride == 0 {
                history.gram_conds.push(packed_gram_cond(
                    &buf,
                    sb,
                    inv_n * inv_n / lam,
                    inv_n,
                    &mut gram_scaled,
                ));
            }

            let (g_buf, r_buf) = buf.split_at(gl);
            let deltas = backend.ca_prox_dual_inner_solve(
                s, b, g_buf, r_buf, &a_blocks, &y_blocks, &overlap, lam, inv_n, &reg,
            )?;
            for (j, blk) in blocks.iter().enumerate() {
                for (i, &row) in blk.iter().enumerate() {
                    alpha[row] += deltas[j * b + i];
                }
            }
            let scale = -1.0 / (lam * n as f64);
            for (sd, &dv) in scaled_deltas.iter_mut().zip(&deltas) {
                *sd = scale * dv;
            }
            backend.alpha_update(a_loc, &idx_flat, &scaled_deltas, &mut w_loc)?;

            let h_now = (k + 1) * s;
            history.iters = h_now;
            if should_record(h_now, s, opts) || k + 1 == outer {
                prox_bdcd_record(&mut history, h_now, &alpha, &w_loc, y, a_loc, lam, &reg, comm)?;
                if let Some(tol) = opts.tol {
                    if history.prox.last().is_some_and(|r| r.subgrad <= tol) {
                        break 'outer_loop;
                    }
                }
            }
        }

        history.meter = *comm.meter();
        let w_full = metered_out(comm, |c| {
            let mut full = vec![0.0; d_global];
            full[d_offset..d_offset + w_loc.len()].copy_from_slice(&w_loc);
            c.allreduce_sum(&mut full)?;
            Ok(full)
        })?;
        Ok(DualOutput {
            w_loc,
            w_full,
            alpha,
            history,
        })
    }

    fn gather_blocks(
        blocks: &[Vec<usize>],
        b: usize,
        alpha: &[f64],
        y: &[f64],
        a_blocks: &mut [f64],
        y_blocks: &mut [f64],
    ) {
        for (j, blk) in blocks.iter().enumerate() {
            for (i, &row) in blk.iter().enumerate() {
                a_blocks[j * b + i] = alpha[row];
                y_blocks[j * b + i] = y[row];
            }
        }
    }

    fn prox_bdcd_record<C: Communicator>(
        history: &mut History,
        iter: usize,
        alpha: &[f64],
        w_loc: &[f64],
        y: &[f64],
        a_loc: &Matrix,
        lam: f64,
        reg: &Reg,
        comm: &mut C,
    ) -> Result<()> {
        let n = a_loc.rows();
        let payload = metered_out(comm, |c| {
            let mut payload = vec![0.0; n + 1];
            a_loc.matvec(w_loc, &mut payload[..n])?;
            payload[n] = w_loc.iter().map(|v| v * v).sum();
            c.allreduce_sum(&mut payload)?;
            Ok(payload)
        })?;
        let w_norm_sq = payload[n];
        let nf = n as f64;
        let mut smooth = 0.5 * lam * w_norm_sq;
        let mut grad = vec![0.0; n];
        for i in 0..n {
            smooth += alpha[i] * alpha[i] / (2.0 * nf) + y[i] * alpha[i] / nf;
            grad[i] = (-payload[i] + alpha[i] + y[i]) / nf;
        }
        history.prox.push(ProxRecord {
            iter,
            pen_obj: smooth + reg.penalty(alpha, lam),
            gap: f64::NAN,
            subgrad: reg.subgrad_residual(&grad, alpha, lam),
            nnz: Reg::nnz(alpha),
        });
        Ok(())
    }
}

// ======================= equivalence harness ===========================

const LAM: f64 = 0.2;
const ITERS: usize = 16;
const SEED: u64 = 7;
const B: usize = 2;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
enum M {
    Bcd,
    Bdcd,
    BcdRow,
    Cocoa,
    ProxBcd,
    ProxBdcd,
}

impl M {
    fn id(self) -> &'static str {
        match self {
            M::Bcd => "bcd",
            M::Bdcd => "bdcd",
            M::BcdRow => "bcdrow",
            M::Cocoa => "cocoa",
            M::ProxBcd => "prox_bcd",
            M::ProxBdcd => "prox_bdcd",
        }
    }

    const ALL: [M; 6] = [M::Bcd, M::Bdcd, M::BcdRow, M::Cocoa, M::ProxBcd, M::ProxBdcd];

    /// The "s" axis: loop-blocking factor, or local_iters for CoCoA.
    fn s_axis(self) -> [usize; 2] {
        match self {
            M::Cocoa => [2, 8],
            _ => [1, 4],
        }
    }
}

/// One rank's comparable output: concatenated iterate vectors, the full
/// history, and (bcd_row) the measured Lemma-3 loads.
struct RankOut {
    vecs: Vec<f64>,
    history: History,
    loads: Vec<usize>,
}

fn toy_dataset() -> Dataset {
    let (d, n) = (12usize, 48usize);
    let mut st = 0x5EED5EEDu64;
    let data: Vec<f64> = (0..d * n)
        .map(|_| {
            st ^= st << 13;
            st ^= st >> 7;
            st ^= st << 17;
            (st as f64 / u64::MAX as f64) - 0.5
        })
        .collect();
    let x = Matrix::Dense(DenseMatrix::from_vec(d, n, data));
    let mut y = vec![0.0; n];
    let mut w_star = vec![0.0; d];
    w_star[0] = 1.5;
    w_star[d / 2] = -2.0;
    w_star[d - 1] = 0.75;
    x.matvec_t(&w_star, &mut y).unwrap();
    Dataset {
        name: "engine-eq".into(),
        x,
        y,
    }
}

fn solver_opts(m: M, s: usize, overlap: bool) -> SolverOpts {
    let reg = match m {
        M::ProxBcd | M::ProxBdcd => Reg::L1,
        _ => Reg::L2,
    };
    SolverOpts::builder()
        .b(B)
        .s(s)
        .lam(LAM)
        .iters(ITERS)
        .seed(SEED)
        .record_every(4)
        .track_gram_cond(true)
        .overlap(overlap)
        .reg(reg)
        .build()
}

/// Run one config through either the frozen legacy loop or the engine
/// path; returns per-rank outputs.
fn run_config(
    m: M,
    use_legacy: bool,
    s: usize,
    overlap: bool,
    p: usize,
    ds: &Dataset,
    reference: &Reference,
) -> Vec<RankOut> {
    use cabcd::gram::NativeBackend;
    let n = ds.n();
    match m {
        M::Bcd | M::ProxBcd => {
            let shards = partition_primal(ds, p).unwrap();
            let opts = solver_opts(m, s, overlap);
            let rref = if m == M::Bcd { Some(reference) } else { None };
            run_spmd(p, move |rank, comm| {
                let sh = &shards[rank];
                let mut be = NativeBackend::new();
                let out = if use_legacy {
                    legacy::bcd_run(&sh.a_loc, &sh.y_loc, n, &opts, rref, comm, &mut be).unwrap()
                } else {
                    cabcd::solvers::bcd::run(&sh.a_loc, &sh.y_loc, n, &opts, rref, comm, &mut be)
                        .unwrap()
                };
                let mut vecs = out.w;
                vecs.extend_from_slice(&out.alpha_loc);
                RankOut {
                    vecs,
                    history: out.history,
                    loads: Vec::new(),
                }
            })
        }
        M::Bdcd | M::ProxBdcd => {
            let shards = partition_dual(ds, p).unwrap();
            let opts = solver_opts(m, s, overlap);
            let rref = if m == M::Bdcd { Some(reference) } else { None };
            run_spmd(p, move |rank, comm| {
                let sh = &shards[rank];
                let mut be = NativeBackend::new();
                let out = if use_legacy {
                    legacy::bdcd_run(
                        &sh.a_loc,
                        &sh.y,
                        sh.d_global,
                        sh.d_offset,
                        &opts,
                        rref,
                        comm,
                        &mut be,
                    )
                    .unwrap()
                } else {
                    cabcd::solvers::bdcd::run(
                        &sh.a_loc,
                        &sh.y,
                        sh.d_global,
                        sh.d_offset,
                        &opts,
                        rref,
                        comm,
                        &mut be,
                    )
                    .unwrap()
                };
                let mut vecs = out.w_full;
                vecs.extend_from_slice(&out.w_loc);
                vecs.extend_from_slice(&out.alpha);
                RankOut {
                    vecs,
                    history: out.history,
                    loads: Vec::new(),
                }
            })
        }
        M::BcdRow => {
            let shards = partition_rows(ds, p).unwrap();
            let opts = solver_opts(m, s, overlap);
            run_spmd(p, move |rank, comm| {
                let sh = &shards[rank];
                let mut be = NativeBackend::new();
                let out = if use_legacy {
                    legacy::bcd_row_run(
                        &sh.x_rows,
                        &sh.y_loc,
                        sh.d_global,
                        sh.d_offset,
                        &opts,
                        Some(reference),
                        comm,
                        &mut be,
                    )
                    .unwrap()
                } else {
                    cabcd::solvers::bcd_row::run(
                        &sh.x_rows,
                        &sh.y_loc,
                        sh.d_global,
                        sh.d_offset,
                        &opts,
                        Some(reference),
                        comm,
                        &mut be,
                    )
                    .unwrap()
                };
                let mut vecs = out.w_full;
                vecs.extend_from_slice(&out.w_loc);
                RankOut {
                    vecs,
                    history: out.history,
                    loads: out.max_loads,
                }
            })
        }
        M::Cocoa => {
            let shards = partition_primal(ds, p).unwrap();
            let copts = CocoaOpts {
                lam: LAM,
                rounds: ITERS,
                local_iters: s,
                seed: SEED,
                record_every: 4,
                overlap,
            };
            run_spmd(p, move |rank, comm| {
                let sh = &shards[rank];
                let out = if use_legacy {
                    legacy::cocoa_run(&sh.a_loc, &sh.y_loc, n, &copts, Some(reference), comm)
                        .unwrap()
                } else {
                    cabcd::solvers::cocoa::run(
                        &sh.a_loc,
                        &sh.y_loc,
                        n,
                        &copts,
                        Some(reference),
                        comm,
                    )
                    .unwrap()
                };
                let mut vecs = out.w;
                vecs.extend_from_slice(&out.alpha_loc);
                RankOut {
                    vecs,
                    history: out.history,
                    loads: Vec::new(),
                }
            })
        }
    }
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn assert_histories_equal(ctx: &str, a: &History, b: &History, check_allocs: bool) {
    assert_eq!(a.iters, b.iters, "{ctx}: iters");
    assert_eq!(a.records.len(), b.records.len(), "{ctx}: record count");
    for (i, (ra, rb)) in a.records.iter().zip(&b.records).enumerate() {
        assert_eq!(ra.iter, rb.iter, "{ctx}: record[{i}].iter");
        assert_eq!(
            ra.obj_err.to_bits(),
            rb.obj_err.to_bits(),
            "{ctx}: record[{i}].obj_err"
        );
        assert_eq!(
            ra.sol_err.to_bits(),
            rb.sol_err.to_bits(),
            "{ctx}: record[{i}].sol_err"
        );
    }
    assert_eq!(a.prox.len(), b.prox.len(), "{ctx}: prox record count");
    for (i, (ra, rb)) in a.prox.iter().zip(&b.prox).enumerate() {
        assert_eq!(ra.iter, rb.iter, "{ctx}: prox[{i}].iter");
        assert_eq!(
            ra.pen_obj.to_bits(),
            rb.pen_obj.to_bits(),
            "{ctx}: prox[{i}].pen_obj"
        );
        assert_eq!(ra.gap.to_bits(), rb.gap.to_bits(), "{ctx}: prox[{i}].gap");
        assert_eq!(
            ra.subgrad.to_bits(),
            rb.subgrad.to_bits(),
            "{ctx}: prox[{i}].subgrad"
        );
        assert_eq!(ra.nnz, rb.nnz, "{ctx}: prox[{i}].nnz");
    }
    assert_eq!(
        bits(&a.gram_conds),
        bits(&b.gram_conds),
        "{ctx}: gram_conds"
    );
    let (ma, mb) = (&a.meter, &b.meter);
    assert_eq!(ma.allreduces, mb.allreduces, "{ctx}: meter.allreduces");
    assert_eq!(ma.all_to_alls, mb.all_to_alls, "{ctx}: meter.all_to_alls");
    assert_eq!(
        ma.collective_waits, mb.collective_waits,
        "{ctx}: meter.collective_waits"
    );
    assert_eq!(ma.msgs, mb.msgs, "{ctx}: meter.msgs");
    assert_eq!(ma.words, mb.words, "{ctx}: meter.words");
    assert_eq!(ma.recv_msgs, mb.recv_msgs, "{ctx}: meter.recv_msgs");
    assert_eq!(ma.recv_words, mb.recv_words, "{ctx}: meter.recv_words");
    if check_allocs {
        assert_eq!(ma.buf_allocs, mb.buf_allocs, "{ctx}: meter.buf_allocs");
    }
}

/// Parsed row of fixtures/engine_meters.tsv.
struct FixtureRow {
    allreduces: u64,
    all_to_alls: u64,
    msgs: u64,
    words: Option<u64>,
    collective_waits: u64,
}

fn load_fixture() -> HashMap<(String, usize, bool, usize), FixtureRow> {
    let text = include_str!("fixtures/engine_meters.tsv");
    let mut map = HashMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let f: Vec<&str> = line.split_whitespace().collect();
        assert_eq!(f.len(), 9, "fixture row {line:?}");
        map.insert(
            (
                f[0].to_string(),
                f[1].parse::<usize>().unwrap(),
                f[2] == "1",
                f[3].parse::<usize>().unwrap(),
            ),
            FixtureRow {
                allreduces: f[4].parse().unwrap(),
                all_to_alls: f[5].parse().unwrap(),
                msgs: f[6].parse().unwrap(),
                words: if f[7] == "-" { None } else { Some(f[7].parse().unwrap()) },
                collective_waits: f[8].parse().unwrap(),
            },
        );
    }
    map
}

/// The tentpole acceptance test: every method × s × overlap × P, engine
/// path vs the frozen pre-engine loop, bitwise — plus the committed
/// closed-form meter fixture.
#[test]
fn engine_reproduces_frozen_legacy_loops_bitwise() {
    let ds = toy_dataset();
    let reference = {
        let mut comm = SerialComm::new();
        cg::compute_reference(&ds.x, &ds.y, ds.n(), LAM, &mut comm).unwrap()
    };
    let fixture = load_fixture();
    let mut configs_checked = 0usize;

    for m in M::ALL {
        for s in m.s_axis() {
            for overlap in [false, true] {
                for p in [1usize, 4] {
                    let ctx = format!("{} s={s} overlap={overlap} P={p}", m.id());
                    let legacy_outs = run_config(m, true, s, overlap, p, &ds, &reference);
                    let engine_outs = run_config(m, false, s, overlap, p, &ds, &reference);
                    // buf_allocs is exempt only where this PR deliberately
                    // changes the overlap schedule (prox Gram prefetch,
                    // bcd_row a2a look-ahead, cocoa pooled combine).
                    let check_allocs = !(overlap
                        && matches!(m, M::ProxBcd | M::ProxBdcd | M::BcdRow | M::Cocoa));
                    for (rank, (lo, eo)) in
                        legacy_outs.iter().zip(&engine_outs).enumerate()
                    {
                        let ctx = format!("{ctx} rank={rank}");
                        assert_eq!(
                            bits(&lo.vecs),
                            bits(&eo.vecs),
                            "{ctx}: iterate vectors diverged from the frozen loop"
                        );
                        assert_eq!(lo.loads, eo.loads, "{ctx}: Lemma-3 loads");
                        assert_histories_equal(&ctx, &lo.history, &eo.history, check_allocs);
                    }
                    // Committed closed-form wire fixture (engine side; the
                    // legacy side is transitively pinned by the equality
                    // assertions above).
                    let row = fixture
                        .get(&(m.id().to_string(), s, overlap, p))
                        .unwrap_or_else(|| panic!("{ctx}: missing fixture row"));
                    for (rank, eo) in engine_outs.iter().enumerate() {
                        let mt = &eo.history.meter;
                        let ctx = format!("{ctx} rank={rank} (fixture)");
                        assert_eq!(mt.allreduces, row.allreduces, "{ctx}: allreduces");
                        assert_eq!(mt.all_to_alls, row.all_to_alls, "{ctx}: all_to_alls");
                        assert_eq!(
                            mt.collective_waits, row.collective_waits,
                            "{ctx}: collective_waits"
                        );
                        assert_eq!(mt.msgs, row.msgs, "{ctx}: msgs");
                        assert_eq!(mt.recv_msgs, row.msgs, "{ctx}: recv_msgs");
                        if let Some(words) = row.words {
                            assert_eq!(mt.words, words, "{ctx}: words");
                            assert_eq!(mt.recv_words, words, "{ctx}: recv_words");
                        }
                    }
                    configs_checked += 1;
                }
            }
        }
    }
    assert_eq!(configs_checked, 48, "coverage matrix shrank");
}

/// Tolerance-based early stop must behave identically through the engine
/// (including draining the look-ahead exchange / prefetched gram).
#[test]
fn early_stop_is_identical_and_drains_pipelines() {
    let ds = toy_dataset();
    let reference = {
        let mut comm = SerialComm::new();
        cg::compute_reference(&ds.x, &ds.y, ds.n(), LAM, &mut comm).unwrap()
    };
    for m in [M::Bcd, M::BcdRow] {
        for overlap in [false, true] {
            let p = 4usize;
            // A loose tolerance the run hits mid-way: record_every=4 and
            // iters large enough that the stop fires before the end.
            let mk = |use_legacy: bool| {
                let mut opts = solver_opts(m, 4, overlap);
                opts.iters = 64;
                // An always-satisfied tolerance: the stop fires at the
                // FIRST record boundary (h = 4), deterministically — the
                // interesting part is that the overlap pipelines must
                // drain their in-flight look-ahead state on the way out.
                opts.tol = Some(f64::INFINITY);
                match m {
                    M::Bcd => {
                        let shards = partition_primal(&ds, p).unwrap();
                        let n = ds.n();
                        let rref = &reference;
                        let opts = &opts;
                        run_spmd(p, move |rank, comm| {
                            let sh = &shards[rank];
                            let mut be = cabcd::gram::NativeBackend::new();
                            let out = if use_legacy {
                                legacy::bcd_run(
                                    &sh.a_loc, &sh.y_loc, n, opts, Some(rref), comm, &mut be,
                                )
                                .unwrap()
                            } else {
                                cabcd::solvers::bcd::run(
                                    &sh.a_loc, &sh.y_loc, n, opts, Some(rref), comm, &mut be,
                                )
                                .unwrap()
                            };
                            (out.w, out.history.iters, out.history.meter)
                        })
                    }
                    _ => {
                        let shards = partition_rows(&ds, p).unwrap();
                        let rref = &reference;
                        let opts = &opts;
                        run_spmd(p, move |rank, comm| {
                            let sh = &shards[rank];
                            let mut be = cabcd::gram::NativeBackend::new();
                            let out = if use_legacy {
                                legacy::bcd_row_run(
                                    &sh.x_rows,
                                    &sh.y_loc,
                                    sh.d_global,
                                    sh.d_offset,
                                    opts,
                                    Some(rref),
                                    comm,
                                    &mut be,
                                )
                                .unwrap()
                            } else {
                                cabcd::solvers::bcd_row::run(
                                    &sh.x_rows,
                                    &sh.y_loc,
                                    sh.d_global,
                                    sh.d_offset,
                                    opts,
                                    Some(rref),
                                    comm,
                                    &mut be,
                                )
                                .unwrap()
                            };
                            (out.w_full, out.history.iters, out.history.meter)
                        })
                    }
                }
            };
            let legacy_outs = mk(true);
            let engine_outs = mk(false);
            for (rank, ((wl, il, ml), (we, ie, me))) in
                legacy_outs.iter().zip(&engine_outs).enumerate()
            {
                let ctx = format!("{:?} overlap={overlap} rank={rank}", m);
                assert_eq!(bits(wl), bits(we), "{ctx}: early-stop trajectory");
                assert_eq!(il, ie, "{ctx}: early-stop iteration count");
                assert_eq!(
                    *ie, 4,
                    "{ctx}: the always-true tolerance must stop at the first \
                     record boundary"
                );
                assert_eq!(ml.allreduces, me.allreduces, "{ctx}: allreduces");
                assert_eq!(ml.all_to_alls, me.all_to_alls, "{ctx}: all_to_alls");
            }
        }
    }
}

/// Tooling gate: the blanket crate-wide `too_many_arguments` allow was
/// removed with the engine redesign; what remains is a frozen set of
/// per-site allows (trait-contract signatures, the paper-shaped record
/// helpers, and the stable 8-argument wrappers). New 8+-argument entry
/// points should thread context through `engine::Problem`/`Session` (or a
/// step struct) instead of adding another allow.
#[test]
fn too_many_arguments_allows_are_frozen() {
    fn count_in(dir: &std::path::Path, total: &mut usize, hits: &mut Vec<String>) {
        for entry in std::fs::read_dir(dir).unwrap() {
            let path = entry.unwrap().path();
            if path.is_dir() {
                count_in(&path, total, hits);
            } else if path.extension().is_some_and(|e| e == "rs") {
                let text = std::fs::read_to_string(&path).unwrap();
                let n = text.matches("clippy::too_many_arguments").count();
                if n > 0 {
                    *total += n;
                    hits.push(format!("{}: {n}", path.display()));
                }
            }
        }
    }
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src");
    let mut total = 0usize;
    let mut hits = Vec::new();
    count_in(&root, &mut total, &mut hits);
    const FROZEN_ALLOW_COUNT: usize = 23;
    assert!(
        total <= FROZEN_ALLOW_COUNT,
        "rust/src gained new clippy::too_many_arguments allows \
         ({total} > frozen {FROZEN_ALLOW_COUNT}).\n\
         Thread context through engine::Problem/Session or a CaStep struct \
         instead of widening a signature.\nSites:\n{}",
        hits.join("\n")
    );
    assert!(
        total > 0,
        "scan found no allows at all — the gate is probably scanning the \
         wrong directory ({})",
        root.display()
    );
}
