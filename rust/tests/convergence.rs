//! Qualitative convergence claims of §5.1, verified on scaled Table-3
//! clones: all four methods reach the ridge optimum; larger blocks
//! converge in fewer iterations; the primal/dual preference follows the
//! dataset shape; TSQR and CG agree with the coordinate methods' limit.

use cabcd::comm::SerialComm;
use cabcd::gram::NativeBackend;
use cabcd::matrix::gen::{generate, scaled_specs, DatasetSpec};
use cabcd::matrix::io::Dataset;
use cabcd::metrics::relative_solution_error;
use cabcd::solvers::{bcd, bdcd, cg, tsqr_ls, SolverOpts};

fn clone_of(name: &str, factor: usize) -> (DatasetSpec, Dataset) {
    let spec = scaled_specs(factor)
        .into_iter()
        .find(|s| s.name.starts_with(name))
        .unwrap();
    let ds = generate(&spec, 42).unwrap();
    (spec, ds)
}

#[test]
fn all_four_clones_make_objective_progress_under_bcd() {
    // One scaled clone per Table-3 row; λ = 1000·σ_min as in the paper.
    // NOTE: on the ill-conditioned news20 clone the *solution* error can
    // grow for a long time (exactly the paper's Fig. 2b observation); the
    // objective, however, must decrease monotonically for exact block
    // coordinate descent on a convex quadratic — that is what we assert.
    for (name, factor, iters) in [
        ("abalone", 8, 3000),
        ("news20", 64, 1500),
        ("a9a", 8, 2000),
        ("real-sim", 64, 1500),
    ] {
        let (spec, ds) = clone_of(name, factor);
        let lam = spec.lambda();
        let mut comm = SerialComm::new();
        let reference = cg::compute_reference(&ds.x, &ds.y, ds.n(), lam, &mut comm).unwrap();
        let opts = SolverOpts::builder()
            .b((ds.d() / 4).clamp(1, 16))
            .s(1)
            .lam(lam)
            .iters(iters)
            .seed(1)
            .record_every(iters / 4)
            .track_gram_cond(false)
            .overlap(false)
            .build();
        let mut be = NativeBackend::new();
        let out = bcd::run(&ds.x, &ds.y, ds.n(), &opts, Some(&reference), &mut comm, &mut be)
            .unwrap();
        let recs = &out.history.records;
        let first = recs.first().unwrap().obj_err;
        let last = recs.last().unwrap().obj_err;
        assert!(
            last < first * 0.9,
            "{name}: objective error {first} → {last} (d={} n={})",
            ds.d(),
            ds.n()
        );
        // Objective error is non-increasing at every record point.
        for w in recs.windows(2) {
            assert!(
                w[1].obj_err <= w[0].obj_err + 1e-12,
                "{name}: objective increased {} → {} at iter {}",
                w[0].obj_err,
                w[1].obj_err,
                w[1].iter
            );
        }
    }
}

#[test]
fn larger_block_size_converges_faster_per_iteration() {
    // Paper Fig. 2: b↑ ⇒ fewer iterations to equal accuracy. Use the a9a
    // clone (d=15 at factor 8) and few iterations so block size actually
    // discriminates (the abalone clone hits machine precision too fast).
    let (spec, ds) = clone_of("a9a", 8);
    let lam = spec.lambda();
    let mut comm = SerialComm::new();
    let reference = cg::compute_reference(&ds.x, &ds.y, ds.n(), lam, &mut comm).unwrap();
    let mut errs = Vec::new();
    for b in [1usize, 4, 8] {
        let opts = SolverOpts::builder()
            .b(b)
            .s(1)
            .lam(lam)
            .iters(60)
            .seed(3)
            .record_every(0)
            .track_gram_cond(false)
            .overlap(false)
            .build();
        let mut be = NativeBackend::new();
        let out = bcd::run(&ds.x, &ds.y, ds.n(), &opts, Some(&reference), &mut comm, &mut be)
            .unwrap();
        errs.push(relative_solution_error(&out.w, &reference.w_opt));
    }
    assert!(
        errs[2] < errs[0],
        "b=8 ({}) should beat b=1 ({}) after equal iterations",
        errs[2],
        errs[0]
    );
}

#[test]
fn primal_and_dual_agree_on_the_optimum() {
    let (spec, ds) = clone_of("abalone", 8);
    let lam = spec.lambda();
    let mut comm = SerialComm::new();
    let reference = cg::compute_reference(&ds.x, &ds.y, ds.n(), lam, &mut comm).unwrap();

    let p_opts = SolverOpts::builder()
        .b(ds.d().min(4))
        .s(2)
        .lam(lam)
        .iters(3000)
        .seed(5)
        .record_every(0)
        .track_gram_cond(false)
        .overlap(false)
        .build();
    let mut be = NativeBackend::new();
    let w_primal = bcd::run(&ds.x, &ds.y, ds.n(), &p_opts, Some(&reference), &mut comm, &mut be)
        .unwrap()
        .w;

    let a = ds.x.transpose();
    let d_opts = SolverOpts::builder()
        .b(32.min(ds.n() / 4))
        .s(2)
        .lam(lam)
        .iters(6000)
        .seed(5)
        .record_every(0)
        .track_gram_cond(false)
        .overlap(false)
        .build();
    let w_dual = bdcd::run(&a, &ds.y, ds.d(), 0, &d_opts, Some(&reference), &mut comm, &mut be)
        .unwrap()
        .w_full;

    let e_p = relative_solution_error(&w_primal, &reference.w_opt);
    let e_d = relative_solution_error(&w_dual, &reference.w_opt);
    assert!(e_p < 1e-6, "primal err {e_p}");
    assert!(e_d < 1e-3, "dual err {e_d}");
}

#[test]
fn tsqr_reaches_machine_precision_in_one_pass() {
    let (spec, ds) = clone_of("abalone", 8);
    let lam = spec.lambda();
    let mut comm = SerialComm::new();
    let reference = cg::compute_reference(&ds.x, &ds.y, ds.n(), lam, &mut comm).unwrap();
    let out = tsqr_ls::run(&ds.x, &ds.y, lam, 16, Some(&reference)).unwrap();
    let final_rec = out.history.records.last().unwrap();
    assert!(
        final_rec.sol_err < 1e-8,
        "TSQR sol err {}",
        final_rec.sol_err
    );
    // Fig. 1c: single reduction — log₂(17 leaves) rounded up = 5 levels.
    assert!(out.combine_levels <= 5);
}

#[test]
fn gram_condition_number_grows_with_s_but_stays_bounded() {
    // Paper Figs. 4i–l: cond(G) increases with s yet remains "reasonably
    // small" — the key numerical-stability observation.
    let (spec, ds) = clone_of("abalone", 8);
    let lam = spec.lambda();
    let mut comm = SerialComm::new();
    let mut meds = Vec::new();
    for s in [1usize, 5, 20] {
        let opts = SolverOpts::builder()
            .b(2)
            .s(s)
            .lam(lam)
            .iters(60)
            .seed(2)
            .record_every(0)
            .track_gram_cond(true)
            .overlap(false)
            .build();
        let mut be = NativeBackend::new();
        let out = bcd::run(&ds.x, &ds.y, ds.n(), &opts, None, &mut comm, &mut be).unwrap();
        let stats = out.history.cond_stats();
        assert!(stats.count > 0);
        assert!(stats.max.is_finite(), "s={s}: singular Gram");
        meds.push(stats.median);
    }
    assert!(
        meds[2] >= meds[0] * 0.5,
        "cond should not shrink dramatically with s: {meds:?}"
    );
}
