//! Prox-subsystem integration tests (CA-Prox-BCD / CA-Prox-BDCD):
//!
//! * **s-invariance** — the proximal s-step unrolling reproduces the
//!   classical (s = 1) prox trajectory to fp tolerance for s ∈ {1,2,4,8},
//!   primal and dual, exactly like the smooth CA equivalence claim.
//! * **L2 bitwise escape hatch** — `reg = l2` dispatches to the
//!   pre-refactor exact solvers: trajectories AND per-rank CostMeter word
//!   counts are bitwise/exactly unchanged.
//! * **Lasso correctness** — CA-Prox-BCD matches a scalar reference
//!   cyclic coordinate-descent implementation on a fixed problem and
//!   certifies optimality with a duality gap ≤ 1e-6.
//! * **Wire accounting** — a prox run communicates exactly H/s
//!   collectives of the unchanged packed `sb(sb+1)/2 + sb` payload
//!   (word-exact against `expected_allreduce_sends`).

use cabcd::comm::thread::{expected_allreduce_sends, run_spmd};
use cabcd::comm::SerialComm;
use cabcd::coordinator::partition_primal;
use cabcd::gram::NativeBackend;
use cabcd::linalg::packed::packed_len;
use cabcd::matrix::gen::{generate, scaled_specs};
use cabcd::matrix::{DenseMatrix, Matrix};
use cabcd::prox::{soft_threshold, Reg};
use cabcd::solvers::{bcd, bcd_row, bdcd, SolverOpts};

/// Deterministic dense problem with a sparse ground truth.
fn sparse_problem(d: usize, n: usize, seed: u64) -> (Matrix, Vec<f64>, Vec<f64>) {
    let mut st = seed | 1;
    let mut next = move || {
        st ^= st << 13;
        st ^= st >> 7;
        st ^= st << 17;
        (st as f64 / u64::MAX as f64) - 0.5
    };
    let data: Vec<f64> = (0..d * n).map(|_| next()).collect();
    let x = Matrix::Dense(DenseMatrix::from_vec(d, n, data));
    let mut w_star = vec![0.0; d];
    for k in 0..(d / 4).max(1) {
        w_star[(k * 4 + 1) % d] = if k % 2 == 0 { 2.0 } else { -1.5 };
    }
    let mut y = vec![0.0; n];
    x.matvec_t(&w_star, &mut y).unwrap();
    for v in y.iter_mut() {
        *v += 0.01 * next();
    }
    (x, y, w_star)
}

/// Scalar reference: cyclic coordinate descent for
/// `1/(2n)‖Xᵀw − y‖² + μ₁‖w‖₁ + μ₂/2‖w‖²` run to machine stationarity —
/// the oracle the satellite task pins CA-Prox-BCD against.
fn reference_cd(x: &Matrix, y: &[f64], mu1: f64, mu2: f64, sweeps: usize) -> Vec<f64> {
    let d = x.rows();
    let n = x.cols();
    let inv_n = 1.0 / n as f64;
    // Dense row cache + per-row squared norms.
    let mut rows = vec![0.0; d * n];
    let idx: Vec<usize> = (0..d).collect();
    x.gather_rows(&idx, &mut rows).unwrap();
    let q: Vec<f64> = (0..d)
        .map(|i| rows[i * n..(i + 1) * n].iter().map(|v| v * v).sum::<f64>() * inv_n)
        .collect();
    let mut w = vec![0.0; d];
    let mut z: Vec<f64> = y.to_vec(); // z = y − Xᵀw
    for _ in 0..sweeps {
        let mut max_delta = 0.0f64;
        for i in 0..d {
            if q[i] == 0.0 {
                continue;
            }
            let r: f64 = rows[i * n..(i + 1) * n]
                .iter()
                .zip(&z)
                .map(|(a, b)| a * b)
                .sum::<f64>()
                * inv_n;
            let c = q[i] * w[i] + r;
            let w_new = soft_threshold(c, mu1) / (q[i] + mu2);
            let delta = w_new - w[i];
            if delta != 0.0 {
                for (zz, xv) in z.iter_mut().zip(&rows[i * n..(i + 1) * n]) {
                    *zz -= xv * delta;
                }
                w[i] = w_new;
                max_delta = max_delta.max(delta.abs());
            }
        }
        if max_delta < 1e-14 {
            break;
        }
    }
    w
}

#[test]
fn ca_prox_bcd_is_s_invariant() {
    let (x, y, _) = sparse_problem(12, 80, 7);
    for reg in [Reg::L1, Reg::Elastic { l1_ratio: 0.5 }] {
        let mk = |s: usize| SolverOpts::builder()
            .b(2)
            .s(s)
            .lam(0.05)
            .iters(48)
            .seed(11)
            .record_every(0)
            .reg(reg)
            .build();
        let mut be = NativeBackend::new();
        let mut comm = SerialComm::new();
        let w1 = bcd::run(&x, &y, 80, &mk(1), None, &mut comm, &mut be)
            .unwrap()
            .w;
        let scale: f64 = w1.iter().map(|v| v.abs()).fold(1e-12, f64::max);
        for s in [2usize, 4, 8] {
            let ws = bcd::run(&x, &y, 80, &mk(s), None, &mut comm, &mut be)
                .unwrap()
                .w;
            for (i, (a, b)) in w1.iter().zip(&ws).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-8 * scale,
                    "{reg:?} w[{i}]: s=1 {a} vs s={s} {b}"
                );
            }
        }
    }
}

#[test]
fn ca_prox_bdcd_is_s_invariant() {
    let (x, y, _) = sparse_problem(6, 48, 9);
    let a = x.transpose();
    for reg in [Reg::L1, Reg::None] {
        let mk = |s: usize| SolverOpts::builder()
            .b(2)
            .s(s)
            .lam(0.1)
            .iters(48)
            .seed(5)
            .record_every(0)
            .reg(reg)
            .build();
        let mut be = NativeBackend::new();
        let mut comm = SerialComm::new();
        let w1 = bdcd::run(&a, &y, 6, 0, &mk(1), None, &mut comm, &mut be)
            .unwrap()
            .w_full;
        let scale: f64 = w1.iter().map(|v| v.abs()).fold(1e-12, f64::max);
        for s in [2usize, 4, 8] {
            let ws = bdcd::run(&a, &y, 6, 0, &mk(s), None, &mut comm, &mut be)
                .unwrap()
                .w_full;
            for (i, (p, q)) in w1.iter().zip(&ws).enumerate() {
                assert!(
                    (p - q).abs() <= 1e-8 * scale,
                    "{reg:?} w[{i}]: s=1 {p} vs s={s} {q}"
                );
            }
        }
    }
}

/// `reg = l2` must take the pre-refactor exact code path. The dispatch is
/// asserted *directly* — the exact path never emits prox certificates,
/// the prox path always does — and the default-vs-explicit-L2 bitwise
/// comparison pins determinism on top (trajectories AND per-rank
/// CostMeter counts).
#[test]
fn l2_reg_is_bitwise_equal_to_pre_refactor_solvers() {
    let spec = &scaled_specs(8)[0]; // abalone-s8
    let ds = generate(spec, 5).unwrap();
    let mk = |reg: Reg| SolverOpts::builder()
        .b(2)
        .s(4)
        .lam(spec.lambda())
        .iters(32)
        .seed(13)
        .record_every(4)
        .reg(reg)
        .build();
    for p in [1usize, 3] {
        let shards = partition_primal(&ds, p).unwrap();
        let mut runs = Vec::new();
        for reg in [Reg::default(), Reg::L2, Reg::L1] {
            let opts = mk(reg);
            let outs = run_spmd(p, |rank, comm| {
                let mut be = NativeBackend::new();
                let sh = &shards[rank];
                bcd::run(&sh.a_loc, &sh.y_loc, sh.n_global, &opts, None, comm, &mut be).unwrap()
            });
            runs.push(outs);
        }
        let (default_runs, l2_runs, l1_runs) = (&runs[0], &runs[1], &runs[2]);
        for (rank, (a, b)) in default_runs.iter().zip(l2_runs).enumerate() {
            // Dispatch outcome: the exact L2 path never pushes prox
            // certificates — if L2 ever leaked into the prox loop, this
            // fires regardless of trajectory equality.
            assert!(
                a.history.prox.is_empty() && b.history.prox.is_empty(),
                "P={p} rank={rank}: reg=l2 produced prox records (routed into the prox loop?)"
            );
            assert!(a.w == b.w, "P={p} rank={rank}: reg=l2 changed the trajectory");
            assert_eq!(
                a.history.meter, b.history.meter,
                "P={p} rank={rank}: reg=l2 changed the meters"
            );
        }
        // Contrast: the same opts with L1 route through the prox loop
        // (certificates recorded, different trajectory).
        for (rank, (a, l1)) in default_runs.iter().zip(l1_runs).enumerate() {
            assert!(
                !l1.history.prox.is_empty(),
                "P={p} rank={rank}: reg=l1 recorded no prox certificates"
            );
            assert!(
                a.w != l1.w,
                "P={p} rank={rank}: l1 and l2 trajectories identical — dispatch broken"
            );
        }
    }
}

/// Acceptance criterion: with reg = l1, the CA solver matches the scalar
/// reference CD solution and certifies a duality gap ≤ 1e-6.
#[test]
fn lasso_matches_scalar_reference_cd_with_tiny_gap() {
    let (x, y, w_star) = sparse_problem(12, 80, 3);
    let lam = 0.05;
    let w_ref = reference_cd(&x, &y, lam, 0.0, 200_000);

    let opts = SolverOpts::builder()
        .b(1)
        .s(4)
        .lam(lam)
        .iters(40_000)
        .seed(2)
        .record_every(400)
        .tol(1e-9)
        .reg(Reg::L1)
        .build();
    let mut comm = SerialComm::new();
    let mut be = NativeBackend::new();
    let out = bcd::run(&x, &y, 80, &opts, None, &mut comm, &mut be).unwrap();
    let last = out.history.prox.last().expect("prox records missing");
    assert!(last.gap <= 1e-6, "duality gap {} > 1e-6", last.gap);
    // ≥ 0 up to the roundoff of the two O(1) objective evaluations.
    assert!(last.gap >= -1e-12, "negative gap {}", last.gap);

    let scale: f64 = w_ref.iter().map(|v| v.abs()).fold(1e-12, f64::max);
    for (i, (a, b)) in out.w.iter().zip(&w_ref).enumerate() {
        assert!(
            (a - b).abs() <= 1e-5 * scale,
            "w[{i}]: ca-prox {a} vs reference CD {b}"
        );
    }
    // Sparse recovery: the support is a strict subset of the dimensions,
    // and the planted support survives.
    assert!(last.nnz < 12, "no sparsity: nnz = {}", last.nnz);
    for (i, &ws) in w_star.iter().enumerate() {
        if ws != 0.0 {
            assert!(out.w[i] != 0.0, "planted coordinate {i} zeroed out");
        }
    }
}

/// Elastic net with l1_ratio = 0 is pure-L2 through the *prox* machinery:
/// different arithmetic than the exact Cholesky path, same minimizer.
#[test]
fn elastic_ratio_zero_converges_to_ridge_solution() {
    let (x, y, _) = sparse_problem(8, 60, 21);
    let lam = 0.1;
    let exact = SolverOpts::builder()
        .b(2)
        .s(1)
        .lam(lam)
        .iters(4000)
        .seed(1)
        .record_every(0)
        .build();
    let mut comm = SerialComm::new();
    let mut be = NativeBackend::new();
    let w_ridge = bcd::run(&x, &y, 60, &exact, None, &mut comm, &mut be)
        .unwrap()
        .w;
    let mut prox_opts = exact.clone();
    prox_opts.iters = 40_000;
    prox_opts.reg = Reg::Elastic { l1_ratio: 0.0 };
    let w_prox = bcd::run(&x, &y, 60, &prox_opts, None, &mut comm, &mut be)
        .unwrap()
        .w;
    let scale: f64 = w_ridge.iter().map(|v| v.abs()).fold(1e-12, f64::max);
    for (i, (a, b)) in w_prox.iter().zip(&w_ridge).enumerate() {
        assert!(
            (a - b).abs() <= 1e-6 * scale,
            "w[{i}]: prox-l2 {a} vs exact ridge {b}"
        );
    }
}

/// Acceptance criterion: a prox run communicates exactly H/s collectives
/// of the UNCHANGED packed `sb(sb+1)/2 + sb` payload — word-exact per
/// rank, SPMD, with the certificate traffic meter-excluded. The overlap
/// pipeline must be bitwise stable and keep the same counts.
#[test]
fn prox_wire_volume_is_h_over_s_packed_payloads() {
    let spec = &scaled_specs(8)[0]; // abalone-s8
    let ds = generate(spec, 4).unwrap();
    let (s, b, iters) = (4usize, 2usize, 40usize);
    let sb = s * b;
    let payload = packed_len(sb) + sb;
    let outer = (iters / s) as u64;
    for p in [2usize, 5] {
        let shards = partition_primal(&ds, p).unwrap();
        let mut runs = Vec::new();
        for overlap in [false, true] {
            let opts = SolverOpts::builder()
                .b(b)
                .s(s)
                .lam(0.05)
                .iters(iters)
                .seed(3)
                .record_every(10)
                .overlap(overlap)
                .reg(Reg::L1)
                .build();
            let outs = run_spmd(p, |rank, comm| {
                let mut be = NativeBackend::new();
                let sh = &shards[rank];
                bcd::run(&sh.a_loc, &sh.y_loc, sh.n_global, &opts, None, comm, &mut be).unwrap()
            });
            for (rank, o) in outs.iter().enumerate() {
                assert_eq!(
                    o.history.meter.allreduces, outer,
                    "P={p} rank={rank} overlap={overlap}: collective count"
                );
                let (msgs, words) = expected_allreduce_sends(p, rank, payload);
                assert_eq!(
                    o.history.meter.msgs,
                    outer * msgs,
                    "P={p} rank={rank} overlap={overlap}: message count"
                );
                assert_eq!(
                    o.history.meter.words,
                    outer * words,
                    "P={p} rank={rank} overlap={overlap}: word count"
                );
            }
            runs.push(outs.into_iter().map(|o| o.w).collect::<Vec<_>>());
        }
        for (rank, (wb, wo)) in runs[0].iter().zip(&runs[1]).enumerate() {
            assert!(
                wb == wo,
                "P={p} rank={rank}: prox overlap trajectory not bitwise stable"
            );
        }
    }
}

/// Prox numerics are rank-count invariant like every CA solver.
#[test]
fn prox_rank_count_does_not_change_numerics() {
    let spec = &scaled_specs(8)[0];
    let ds = generate(spec, 6).unwrap();
    let opts = SolverOpts::builder()
        .b(2)
        .s(2)
        .lam(0.05)
        .iters(60)
        .seed(17)
        .record_every(0)
        .reg(Reg::L1)
        .build();
    let mut solutions = Vec::new();
    for p in [1usize, 4] {
        let shards = partition_primal(&ds, p).unwrap();
        let ws = run_spmd(p, |rank, comm| {
            let mut be = NativeBackend::new();
            let sh = &shards[rank];
            bcd::run(&sh.a_loc, &sh.y_loc, sh.n_global, &opts, None, comm, &mut be)
                .unwrap()
                .w
        });
        for w in &ws[1..] {
            assert_eq!(w, &ws[0], "P={p}: ranks disagree on replicated w");
        }
        solutions.push(ws.into_iter().next().unwrap());
    }
    for (a, b) in solutions[0].iter().zip(&solutions[1]) {
        assert!((a - b).abs() < 1e-10, "P changed prox numerics: {a} vs {b}");
    }
}

/// The mismatched-layout solver declares its L2-only contract loudly.
#[test]
fn bcd_row_rejects_prox_regularizers() {
    let (x, y, _) = sparse_problem(8, 32, 1);
    let opts = SolverOpts::builder()
        .reg(Reg::L1)
        .build();
    let mut comm = SerialComm::new();
    let mut be = NativeBackend::new();
    let err = bcd_row::run(&x, &y[..32], 8, 0, &opts, None, &mut comm, &mut be).unwrap_err();
    assert!(
        err.to_string().contains("reg = l2"),
        "unexpected error: {err}"
    );
}
