//! Property tests of the communicator substrate: the collectives must be
//! exact (allreduce ≡ serial sum, all-to-all ≡ transpose of payload
//! matrix, broadcast ≡ replication) for arbitrary rank counts, payload
//! sizes, and roots; the non-blocking path must be bitwise identical to
//! the blocking one; measured message/word counts must equal the
//! recursive-doubling / Rabenseifner formulas the cost model charges; and
//! protocol violations must poison the group instead of deadlocking it.

use cabcd::comm::cost::CostMeter;
use cabcd::comm::thread::{expected_allreduce_sends, run_spmd, RABENSEIFNER_MIN_WORDS};
use cabcd::comm::Communicator;
use cabcd::prop_assert;
use cabcd::util::proptest::{check, Gen};

#[test]
fn prop_allreduce_equals_serial_sum() {
    check(20, |g| {
        let p = g.usize_in(1, 9);
        let len = g.usize_in(1, 300);
        // Per-rank payloads derived deterministically from (seed, rank).
        let seed = g.seed;
        let results = run_spmd(p, move |rank, comm| {
            let mut gen = Gen::new(seed ^ (rank as u64).wrapping_mul(0x9E37));
            let buf = gen.vec_normal(len);
            let mut reduced = buf.clone();
            comm.allreduce_sum(&mut reduced).unwrap();
            (buf, reduced)
        });
        let mut expect = vec![0.0; len];
        for (buf, _) in &results {
            for (e, v) in expect.iter_mut().zip(buf) {
                *e += v;
            }
        }
        for (rank, (_, reduced)) in results.iter().enumerate() {
            for (i, (r, e)) in reduced.iter().zip(&expect).enumerate() {
                prop_assert!(
                    (r - e).abs() <= 1e-12 * e.abs().max(1.0),
                    "p={p} rank={rank} idx={i}: {r} vs {e}"
                );
            }
        }
        Ok(())
    });
}

/// Regression coverage for the non-power-of-two fold/unfold branches at
/// exactly the rank counts the seed's wrap-around logic mishandled, in
/// both the recursive-doubling and Rabenseifner regimes, for blocking and
/// non-blocking entry points and for broadcast from every root.
#[test]
fn non_power_of_two_rank_counts_are_exact() {
    for p in [3usize, 5, 6, 7] {
        for len in [1usize, 9, RABENSEIFNER_MIN_WORDS + 3] {
            let results = run_spmd(p, move |rank, comm| {
                let data: Vec<f64> = (0..len)
                    .map(|i| ((rank + 1) * (i + 2)) as f64)
                    .collect();
                let mut blocking = data.clone();
                comm.allreduce_sum(&mut blocking).unwrap();
                let handle = comm.iallreduce_start(data).unwrap();
                let nonblocking = comm.iallreduce_wait(handle).unwrap();
                comm.barrier().unwrap();
                (blocking, nonblocking)
            });
            for i in 0..len {
                let expect: f64 = (0..p).map(|r| ((r + 1) * (i + 2)) as f64).sum();
                for (rank, (b, nb)) in results.iter().enumerate() {
                    assert_eq!(b[i], expect, "p={p} len={len} rank={rank} idx={i}");
                    assert_eq!(b[i], nb[i], "p={p} len={len} rank={rank}: nb differs");
                }
            }
        }
        for root in 0..p {
            let results = run_spmd(p, move |rank, comm| {
                let mut buf = if rank == root {
                    vec![root as f64 + 0.5; 5]
                } else {
                    vec![0.0; 5]
                };
                comm.broadcast(root, &mut buf).unwrap();
                buf
            });
            for (rank, r) in results.iter().enumerate() {
                assert_eq!(r, &[root as f64 + 0.5; 5], "p={p} root={root} rank={rank}");
            }
        }
    }
}

#[test]
fn prop_broadcast_replicates_from_any_root() {
    check(15, |g| {
        let p = g.usize_in(2, 9);
        let root = g.usize_in(0, p);
        let len = g.usize_in(1, 64);
        let seed = g.seed;
        let results = run_spmd(p, move |rank, comm| {
            let mut buf = if rank == root {
                let mut gen = Gen::new(seed);
                gen.vec_normal(len)
            } else {
                vec![0.0; len]
            };
            comm.broadcast(root, &mut buf).unwrap();
            buf
        });
        let expect = &results[root];
        for (rank, got) in results.iter().enumerate() {
            prop_assert!(got == expect, "p={p} root={root} rank={rank} differs");
        }
        Ok(())
    });
}

#[test]
fn prop_all_to_all_transposes_payloads() {
    check(15, |g| {
        let p = g.usize_in(1, 8);
        let len = g.usize_in(1, 16);
        let results = run_spmd(p, move |rank, comm| {
            let send: Vec<Vec<f64>> = (0..p)
                .map(|dst| {
                    (0..len)
                        .map(|k| (rank * 1000 + dst * 10 + k) as f64)
                        .collect()
                })
                .collect();
            comm.all_to_all(send).unwrap()
        });
        for (rank, got) in results.iter().enumerate() {
            for (src, payload) in got.iter().enumerate() {
                for (k, v) in payload.iter().enumerate() {
                    let expect = (src * 1000 + rank * 10 + k) as f64;
                    prop_assert!(
                        *v == expect,
                        "p={p} rank={rank} src={src} k={k}: {v} vs {expect}"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_allreduce_critical_path_is_logarithmic() {
    check(10, |g| {
        let p = 1usize << g.usize_in(0, 6); // powers of two up to 32
        let rounds = g.usize_in(1, 5);
        let meters: Vec<CostMeter> = run_spmd(p, move |_rank, comm| {
            for _ in 0..rounds {
                let mut buf = vec![1.0; 8];
                comm.allreduce_sum(&mut buf).unwrap();
            }
            *comm.meter()
        });
        let (msgs, _) = CostMeter::critical_path(&meters);
        let logp = (p as f64).log2().ceil() as u64;
        prop_assert!(
            msgs <= 2 * logp * rounds as u64,
            "p={p} rounds={rounds}: msgs {msgs} > {}",
            2 * logp * rounds as u64
        );
        Ok(())
    });
}

/// Theorem-level accounting, measured: per-rank sends and send-words of
/// one allreduce must equal the recursive-doubling formula (`log₂P` full
/// payloads) for small buffers and the Rabenseifner formula
/// (`≈2·len·(P−1)/P` words over `2·log₂P` halving rounds) for large ones,
/// including the non-power-of-two fold/unfold corrections.
#[test]
fn prop_allreduce_message_counts_match_formulas() {
    check(20, |g| {
        let p = g.usize_in(2, 10);
        let len = if g.bool() {
            g.usize_in(1, 128) // recursive-doubling regime
        } else {
            g.usize_in(RABENSEIFNER_MIN_WORDS, RABENSEIFNER_MIN_WORDS + 512)
        };
        let meters: Vec<CostMeter> = run_spmd(p, move |_rank, comm| {
            let mut buf = vec![1.0; len];
            comm.allreduce_sum(&mut buf).unwrap();
            *comm.meter()
        });
        for (rank, m) in meters.iter().enumerate() {
            let (msgs, words) = expected_allreduce_sends(p, rank, len);
            prop_assert!(
                m.msgs == msgs,
                "p={p} len={len} rank={rank}: {} msgs, formula says {msgs}",
                m.msgs
            );
            prop_assert!(
                m.words == words,
                "p={p} len={len} rank={rank}: {} words, formula says {words}",
                m.words
            );
        }
        // Global sanity: sends and receives balance across the group.
        let sent: u64 = meters.iter().map(|m| m.msgs).sum();
        let recvd: u64 = meters.iter().map(|m| m.recv_msgs).sum();
        prop_assert!(sent == recvd, "p={p} len={len}: {sent} sends vs {recvd} recvs");
        Ok(())
    });
}

/// Rabenseifner must beat recursive doubling on bandwidth for the large
/// `sb² + sb` Gram payloads — the reason the tentpole switches algorithm.
#[test]
fn rabenseifner_words_beat_recursive_doubling_scaling() {
    let len = 64 * 64 + 64; // sb²+sb at sb=64
    for p in [4usize, 8, 16] {
        let (_, words) = expected_allreduce_sends(p, p - 1, len);
        let rd_words = (p.trailing_zeros() as u64) * len as u64;
        assert!(
            words * 2 < rd_words * (p as u64).min(4),
            "p={p}: rabenseifner {words} vs rd {rd_words}"
        );
        // Exact bandwidth bound: 2·len·(P−1)/P words per active rank
        // (+1 word slack per round for uneven chunk boundaries).
        let bound = 2 * (len as u64) * (p as u64 - 1) / p as u64 + 2 * p.trailing_zeros() as u64;
        assert!(words <= bound, "p={p}: {words} > bound {bound}");
    }
}

/// Property: the non-blocking start/wait pair is bitwise identical to the
/// blocking allreduce across random rank counts and payload sizes (both
/// algorithm regimes), and the buffer pool reaches an allocation-free
/// steady state.
#[test]
fn prop_nonblocking_allreduce_bitwise_equals_blocking() {
    check(16, |g| {
        let p = g.usize_in(2, 9);
        let len = if g.bool() {
            g.usize_in(1, 200)
        } else {
            g.usize_in(RABENSEIFNER_MIN_WORDS, 2 * RABENSEIFNER_MIN_WORDS)
        };
        let seed = g.seed;
        let results = run_spmd(p, move |rank, comm| {
            let mut gen = Gen::new(seed ^ (rank as u64).wrapping_mul(0xABCD));
            let data = gen.vec_normal(len);
            let mut blocking = data.clone();
            comm.allreduce_sum(&mut blocking).unwrap();
            let payload = {
                let mut b = comm.take_buf(len);
                b.copy_from_slice(&data);
                b
            };
            let handle = comm.iallreduce_start(payload).unwrap();
            let nonblocking = comm.iallreduce_wait(handle).unwrap();
            let ok = blocking == nonblocking;
            comm.give_buf(nonblocking);
            (ok, comm.meter().allreduces)
        });
        for (rank, (ok, allreduces)) in results.iter().enumerate() {
            prop_assert!(*ok, "p={p} len={len} rank={rank}: nb != blocking");
            prop_assert!(
                *allreduces == 2,
                "p={p} rank={rank}: iallreduce not metered as an allreduce"
            );
        }
        Ok(())
    });
}

/// Non-blocking all-to-all (start/wait pair) must be bitwise identical to
/// the blocking `all_to_all_expect` on the same payloads — including with
/// a *different collective running between start and wait* (the bcd_row
/// overlap pattern: the Lemma-3 load-metering allreduce rides inside the
/// in-flight Theorem-4 exchange). Operation tags keep the two message
/// streams apart even when payload lengths collide.
#[test]
fn prop_nonblocking_all_to_all_bitwise_equals_blocking_with_interleave() {
    check(12, |g| {
        let p = g.usize_in(1, 8);
        let len = g.usize_in(1, 12);
        let seed = g.seed;
        let results = run_spmd(p, move |rank, comm| {
            let mk_send = || -> Vec<Vec<f64>> {
                (0..p)
                    .map(|dst| {
                        let mut gen = Gen::new(seed ^ ((rank * 31 + dst) as u64));
                        gen.vec_normal(len)
                    })
                    .collect()
            };
            let lens = vec![len; p];
            let blocking = comm.all_to_all_expect(mk_send(), &lens).unwrap();
            let h = comm.iall_to_all_start(mk_send(), &lens).unwrap();
            // Interleaved collective with the SAME payload length as the
            // in-flight exchange — the tag-matching stress case.
            let mut inter = vec![rank as f64; len];
            comm.allreduce_sum(&mut inter).unwrap();
            let nonblocking = comm.iall_to_all_wait(h).unwrap();
            let expect_sum = (0..p).sum::<usize>() as f64;
            (blocking, nonblocking, inter, expect_sum)
        });
        for (rank, (b, nb, inter, expect_sum)) in results.iter().enumerate() {
            prop_assert!(b == nb, "p={p} len={len} rank={rank}: a2a nb != blocking");
            for v in inter {
                prop_assert!(
                    *v == *expect_sum,
                    "p={p} rank={rank}: interleaved allreduce corrupted ({v})"
                );
            }
        }
        Ok(())
    });
}

/// Same interleave guarantee for the non-blocking allreduce: another
/// allreduce of the SAME length may run between start and wait without
/// either operation stealing the other's messages.
#[test]
fn nonblocking_allreduce_tolerates_interleaved_collective() {
    for p in [2usize, 3, 5, 8] {
        for len in [7usize, RABENSEIFNER_MIN_WORDS + 5] {
            let results = run_spmd(p, move |rank, comm| {
                let data: Vec<f64> = (0..len).map(|i| ((rank + 1) * (i + 1)) as f64).collect();
                let mut blocking = data.clone();
                comm.allreduce_sum(&mut blocking).unwrap();
                let h = comm.iallreduce_start(data).unwrap();
                let mut inter: Vec<f64> = (0..len).map(|i| (rank * len + i) as f64).collect();
                comm.allreduce_sum(&mut inter).unwrap();
                let nonblocking = comm.iallreduce_wait(h).unwrap();
                (blocking, nonblocking, inter)
            });
            for i in 0..len {
                let inter_expect: f64 = (0..p).map(|r| (r * len + i) as f64).sum();
                for (rank, (b, nb, inter)) in results.iter().enumerate() {
                    assert_eq!(
                        b[i], nb[i],
                        "p={p} len={len} rank={rank}: interleave broke the in-flight reduce"
                    );
                    assert_eq!(
                        inter[i], inter_expect,
                        "p={p} len={len} rank={rank}: in-flight reduce broke the interleave"
                    );
                }
            }
        }
    }
}

/// Receive-side poison semantics of the non-blocking all-to-all: a length
/// contract violated by a peer's payload poisons the group at wait time —
/// every rank errors, nobody hangs.
#[test]
fn nonblocking_all_to_all_length_mismatch_poisons_group() {
    for p in [2usize, 5] {
        let outcomes = run_spmd(p, |rank, comm| {
            let send: Vec<Vec<f64>> = (0..p)
                .map(|_| vec![rank as f64; if rank == 0 { 2 } else { 4 }])
                .collect();
            let lens = vec![4usize; p];
            let first = match comm.iall_to_all_start(send, &lens) {
                Ok(h) => comm.iall_to_all_wait(h).err().map(|e| e.to_string()),
                Err(e) => Some(e.to_string()),
            };
            let second = comm.barrier().err().map(|e| e.to_string());
            (first, second)
        });
        for (rank, (first, second)) in outcomes.iter().enumerate() {
            let failed = first.as_ref().or(second.as_ref());
            let msg = failed.unwrap_or_else(|| {
                panic!("p={p} rank={rank}: no collective failed after nb a2a mismatch")
            });
            assert!(
                msg.contains("poisoned") || msg.contains("terminated"),
                "p={p} rank={rank}: unexpected error {msg:?}"
            );
        }
    }
}

/// Pool steady state under the solver-shaped workload: repeated
/// fixed-size non-blocking allreduces stop allocating after warmup.
#[test]
fn nonblocking_pool_reaches_zero_alloc_steady_state() {
    for p in [2usize, 4, 8] {
        run_spmd(p, |_rank, comm| {
            let len = 16 * 16 + 16; // an sb²+sb payload
            for _ in 0..32 {
                let buf = comm.take_buf(len);
                let h = comm.iallreduce_start(buf).unwrap();
                let out = comm.iallreduce_wait(h).unwrap();
                comm.give_buf(out);
            }
            let warm = comm.meter().buf_allocs;
            for _ in 0..16 {
                let buf = comm.take_buf(len);
                let h = comm.iallreduce_start(buf).unwrap();
                let out = comm.iallreduce_wait(h).unwrap();
                comm.give_buf(out);
            }
            assert_eq!(comm.meter().buf_allocs, warm, "p={p}: pool drift");
        });
    }
}

/// A payload-length mismatch must surface as a poisoned-group error on
/// every rank — not as one `Error::Comm` plus P−1 ranks blocked forever
/// in `recv` (the seed behavior). Every rank runs two collectives; the
/// sticky poison guarantees all of them fail by the second attempt, and
/// `run_spmd` returning at all proves nobody deadlocked.
#[test]
fn length_mismatch_poisons_group_instead_of_hanging() {
    for p in [2usize, 5] {
        let outcomes = run_spmd(p, |rank, comm| {
            let len = if rank == 0 { 3 } else { 7 };
            let mut buf = vec![1.0; len];
            let first = comm.allreduce_sum(&mut buf);
            let second = comm.allreduce_sum(&mut buf);
            (
                first.err().map(|e| e.to_string()),
                second.err().map(|e| e.to_string()),
            )
        });
        for (rank, (first, second)) in outcomes.iter().enumerate() {
            let failed = first.as_ref().or(second.as_ref());
            let msg = failed.unwrap_or_else(|| {
                panic!("p={p} rank={rank}: no collective failed after poisoning")
            });
            assert!(
                msg.contains("poisoned"),
                "p={p} rank={rank}: unexpected error {msg:?}"
            );
        }
    }
}

/// Receive-side twin of the test above (ROADMAP open item): an
/// `all_to_all_expect` payload whose length violates the receiver's
/// contract must poison the group — every rank errors by its second
/// collective, and `run_spmd` returning at all proves no receiver hung.
#[test]
fn all_to_all_length_mismatch_poisons_receivers_instead_of_hanging() {
    for p in [2usize, 5] {
        let outcomes = run_spmd(p, |rank, comm| {
            // Everyone expects 4-word payloads; rank 0 ships 2-word ones.
            let send: Vec<Vec<f64>> = (0..p)
                .map(|_| vec![rank as f64; if rank == 0 { 2 } else { 4 }])
                .collect();
            let lens = vec![4usize; p];
            let first = comm
                .all_to_all_expect(send, &lens)
                .err()
                .map(|e| e.to_string());
            let second = comm.barrier().err().map(|e| e.to_string());
            (first, second)
        });
        for (rank, (first, second)) in outcomes.iter().enumerate() {
            let failed = first.as_ref().or(second.as_ref());
            let msg = failed.unwrap_or_else(|| {
                panic!("p={p} rank={rank}: no collective failed after receive-side mismatch")
            });
            assert!(
                msg.contains("poisoned") || msg.contains("terminated"),
                "p={p} rank={rank}: unexpected error {msg:?}"
            );
        }
    }
}

#[test]
fn spmd_rank_count_does_not_change_solver_numerics() {
    // End-to-end SPMD equivalence: same dataset, P ∈ {1, 2, 5} → same w.
    use cabcd::coordinator::partition_primal;
    use cabcd::gram::NativeBackend;
    use cabcd::matrix::gen::{generate, scaled_specs};
    use cabcd::solvers::{bcd, SolverOpts};

    let spec = &scaled_specs(8)[0]; // abalone-s8
    let ds = generate(spec, 3).unwrap();
    let opts = SolverOpts::builder()
        .b(2)
        .s(3)
        .lam(spec.lambda())
        .iters(60)
        .seed(7)
        .record_every(0)
        .track_gram_cond(false)
        .overlap(false)
        .build();
    let mut solutions = Vec::new();
    for p in [1usize, 2, 5] {
        let shards = partition_primal(&ds, p).unwrap();
        let ws = run_spmd(p, |rank, comm| {
            let mut be = NativeBackend::new();
            let sh = &shards[rank];
            bcd::run(&sh.a_loc, &sh.y_loc, sh.n_global, &opts, None, comm, &mut be)
                .unwrap()
                .w
        });
        // All ranks agree (w is replicated).
        for w in &ws[1..] {
            assert_eq!(w, &ws[0], "P={p}: ranks disagree on replicated w");
        }
        solutions.push(ws.into_iter().next().unwrap());
    }
    for w in &solutions[1..] {
        for (a, b) in w.iter().zip(&solutions[0]) {
            assert!(
                (a - b).abs() < 1e-10,
                "rank-count changed numerics: {a} vs {b}"
            );
        }
    }
}
