//! Property tests of the communicator substrate: the collectives must be
//! exact (allreduce ≡ serial sum, all-to-all ≡ transpose of payload
//! matrix, broadcast ≡ replication) for arbitrary rank counts, payload
//! sizes, and roots — and their measured message counts must stay within
//! the binomial-tree bounds the cost model charges.

use cabcd::comm::cost::CostMeter;
use cabcd::comm::thread::run_spmd;
use cabcd::comm::Communicator;
use cabcd::prop_assert;
use cabcd::util::proptest::{check, Gen};

#[test]
fn prop_allreduce_equals_serial_sum() {
    check(20, |g| {
        let p = g.usize_in(1, 9);
        let len = g.usize_in(1, 300);
        // Per-rank payloads derived deterministically from (seed, rank).
        let seed = g.seed;
        let results = run_spmd(p, move |rank, comm| {
            let mut gen = Gen::new(seed ^ (rank as u64).wrapping_mul(0x9E37));
            let buf = gen.vec_normal(len);
            let mut reduced = buf.clone();
            comm.allreduce_sum(&mut reduced).unwrap();
            (buf, reduced)
        });
        let mut expect = vec![0.0; len];
        for (buf, _) in &results {
            for (e, v) in expect.iter_mut().zip(buf) {
                *e += v;
            }
        }
        for (rank, (_, reduced)) in results.iter().enumerate() {
            for (i, (r, e)) in reduced.iter().zip(&expect).enumerate() {
                prop_assert!(
                    (r - e).abs() <= 1e-12 * e.abs().max(1.0),
                    "p={p} rank={rank} idx={i}: {r} vs {e}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_broadcast_replicates_from_any_root() {
    check(15, |g| {
        let p = g.usize_in(2, 9);
        let root = g.usize_in(0, p);
        let len = g.usize_in(1, 64);
        let seed = g.seed;
        let results = run_spmd(p, move |rank, comm| {
            let mut buf = if rank == root {
                let mut gen = Gen::new(seed);
                gen.vec_normal(len)
            } else {
                vec![0.0; len]
            };
            comm.broadcast(root, &mut buf).unwrap();
            buf
        });
        let expect = &results[root];
        for (rank, got) in results.iter().enumerate() {
            prop_assert!(got == expect, "p={p} root={root} rank={rank} differs");
        }
        Ok(())
    });
}

#[test]
fn prop_all_to_all_transposes_payloads() {
    check(15, |g| {
        let p = g.usize_in(1, 8);
        let len = g.usize_in(1, 16);
        let results = run_spmd(p, move |rank, comm| {
            let send: Vec<Vec<f64>> = (0..p)
                .map(|dst| {
                    (0..len)
                        .map(|k| (rank * 1000 + dst * 10 + k) as f64)
                        .collect()
                })
                .collect();
            comm.all_to_all(send).unwrap()
        });
        for (rank, got) in results.iter().enumerate() {
            for (src, payload) in got.iter().enumerate() {
                for (k, v) in payload.iter().enumerate() {
                    let expect = (src * 1000 + rank * 10 + k) as f64;
                    prop_assert!(
                        *v == expect,
                        "p={p} rank={rank} src={src} k={k}: {v} vs {expect}"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_allreduce_critical_path_is_logarithmic() {
    check(10, |g| {
        let p = 1usize << g.usize_in(0, 6); // powers of two up to 32
        let rounds = g.usize_in(1, 5);
        let meters: Vec<CostMeter> = run_spmd(p, move |_rank, comm| {
            for _ in 0..rounds {
                let mut buf = vec![1.0; 8];
                comm.allreduce_sum(&mut buf).unwrap();
            }
            *comm.meter()
        });
        let (msgs, _) = CostMeter::critical_path(&meters);
        let logp = (p as f64).log2().ceil() as u64;
        prop_assert!(
            msgs <= 2 * logp * rounds as u64,
            "p={p} rounds={rounds}: msgs {msgs} > {}",
            2 * logp * rounds as u64
        );
        Ok(())
    });
}

#[test]
fn prop_allreduce_word_count_matches_payload() {
    // Theorem 1 charges O(b² log P) words per allreduce of a b² payload:
    // every word a rank sends is the payload length times its tree sends.
    check(10, |g| {
        let p = g.usize_in(2, 9);
        let len = g.usize_in(1, 100);
        let meters: Vec<CostMeter> = run_spmd(p, move |_rank, comm| {
            let mut buf = vec![1.0; len];
            comm.allreduce_sum(&mut buf).unwrap();
            *comm.meter()
        });
        for (rank, m) in meters.iter().enumerate() {
            prop_assert!(
                m.words % len as u64 == 0,
                "p={p} rank={rank}: {} words not a multiple of payload {len}",
                m.words
            );
        }
        // Total traffic of reduce+bcast over a binomial tree: 2(P−1) sends.
        let total: u64 = meters.iter().map(|m| m.msgs).sum();
        prop_assert!(
            total == 2 * (p as u64 - 1),
            "p={p}: total sends {total} != {}",
            2 * (p as u64 - 1)
        );
        Ok(())
    });
}

#[test]
fn spmd_rank_count_does_not_change_solver_numerics() {
    // End-to-end SPMD equivalence: same dataset, P ∈ {1, 2, 5} → same w.
    use cabcd::gram::NativeBackend;
    use cabcd::matrix::gen::{generate, scaled_specs};
    use cabcd::coordinator::partition_primal;
    use cabcd::solvers::{bcd, SolverOpts};

    let spec = &scaled_specs(8)[0]; // abalone-s8
    let ds = generate(spec, 3).unwrap();
    let opts = SolverOpts {
        b: 2,
        s: 3,
        lam: spec.lambda(),
        iters: 60,
        seed: 7,
        record_every: 0,
        track_gram_cond: false,
        tol: None,
    };
    let mut solutions = Vec::new();
    for p in [1usize, 2, 5] {
        let shards = partition_primal(&ds, p).unwrap();
        let ws = run_spmd(p, |rank, comm| {
            let mut be = NativeBackend::new();
            let sh = &shards[rank];
            bcd::run(&sh.a_loc, &sh.y_loc, sh.n_global, &opts, None, comm, &mut be)
                .unwrap()
                .w
        });
        // All ranks agree (w is replicated).
        for w in &ws[1..] {
            assert_eq!(w, &ws[0], "P={p}: ranks disagree on replicated w");
        }
        solutions.push(ws.into_iter().next().unwrap());
    }
    for w in &solutions[1..] {
        for (a, b) in w.iter().zip(&solutions[0]) {
            assert!(
                (a - b).abs() < 1e-10,
                "rank-count changed numerics: {a} vs {b}"
            );
        }
    }
}
