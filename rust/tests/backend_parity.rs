//! Native-vs-XLA backend parity: the AOT JAX/Pallas artifacts executed
//! through PJRT must reproduce the hand-written Rust hot path bit-for-bit
//! (both are f64; the artifact computation mirrors `NativeBackend`
//! operation-for-operation, modulo summation order inside the tiled Gram —
//! tolerance 1e-10 relative).
//!
//! Artifact contract (`aot.py` / `runtime/mod.rs`): the gram artifact kind
//! is `gram_resid_packed` — G arrives as the **packed lower triangle** of
//! the artifact's sb_art×sb_art tile (entry (r, c), r ≥ c, at
//! r(r+1)/2 + c), so the runtime accumulates the first packed_len(sb)
//! words elementwise into the logical packed buffer; there is no
//! fold-to-packed copy anywhere. Old full-matrix `gram_resid` manifests
//! are rejected at load with a regenerate hint. Both `gram_resid` calls
//! below therefore exercise the packed artifact path end-to-end.
//!
//! Requires `artifacts/` (run `make artifacts`); tests panic with a clear
//! message if it is missing, since the three-layer claim is untestable
//! without the build product.
//!
//! Compiled only under `--cfg cabcd_xla`: the default offline build has no
//! vendored `xla` crate (the runtime module falls back to a fail-fast
//! stub), so exercising PJRT parity here would fail at client construction
//! rather than test anything.
#![cfg(cabcd_xla)]

use std::path::Path;

use cabcd::comm::SerialComm;
use cabcd::gram::{ComputeBackend, NativeBackend};
use cabcd::linalg::packed::{pack_lower, packed_len};
use cabcd::matrix::{CsrMatrix, DenseMatrix, Matrix};
use cabcd::runtime::XlaBackend;
use cabcd::solvers::{bcd, bdcd, SolverOpts};
use cabcd::util::proptest::Gen;

fn artifact_dir() -> &'static Path {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    assert!(
        dir.join("manifest.tsv").exists(),
        "artifacts/ missing — run `make artifacts` before `cargo test`"
    );
    Box::leak(dir.into_boxed_path())
}

#[test]
fn gram_resid_parity_dense_and_sparse() {
    let mut xb = XlaBackend::new(artifact_dir()).unwrap();
    let mut nb = NativeBackend::new();
    let mut g = Gen::new(1);
    for (sb, n_loc) in [(3usize, 100usize), (8, 2048), (13, 3000), (16, 2500)] {
        let d = sb + 4;
        let dense = DenseMatrix::from_vec(d, n_loc, g.vec_normal(d * n_loc));
        for a in [
            Matrix::Dense(dense.clone()),
            Matrix::Csr(CsrMatrix::from_dense(&dense)),
        ] {
            let idx: Vec<usize> = (0..sb).map(|i| (i * 7 + 1) % d).collect();
            // NOTE: sampled indices may repeat rows here only if (i*7+1)%d
            // collides — dedupe to keep the test's meaning clean.
            let mut idx = idx;
            idx.dedup();
            let sb = idx.len();
            let z = g.vec_normal(n_loc);
            let mut g_n = vec![0.0; packed_len(sb)];
            let mut r_n = vec![0.0; sb];
            nb.gram_resid(&a, &idx, &z, &mut g_n, &mut r_n).unwrap();
            let mut g_x = vec![0.0; packed_len(sb)];
            let mut r_x = vec![0.0; sb];
            xb.gram_resid(&a, &idx, &z, &mut g_x, &mut r_x).unwrap();
            for (i, (p, q)) in g_n.iter().zip(&g_x).enumerate() {
                assert!(
                    (p - q).abs() <= 1e-10 * p.abs().max(1.0),
                    "G[{i}]: native {p} vs xla {q} (sb={sb}, n_loc={n_loc})"
                );
            }
            for (i, (p, q)) in r_n.iter().zip(&r_x).enumerate() {
                assert!(
                    (p - q).abs() <= 1e-10 * p.abs().max(1.0),
                    "r[{i}]: native {p} vs xla {q}"
                );
            }
        }
    }
}

#[test]
fn inner_solve_parity_primal_and_dual() {
    let mut xb = XlaBackend::new(artifact_dir()).unwrap();
    let mut nb = NativeBackend::new();
    let mut g = Gen::new(2);
    for (s, b) in [(1usize, 3usize), (2, 4), (4, 8), (3, 5), (8, 8)] {
        let sb = s * b;
        // SPD raw Gram from a random factor.
        let m = g.vec_normal(sb * (sb + 16));
        let cols = sb + 16;
        let mut g_full = vec![0.0; sb * sb];
        for i in 0..sb {
            for j in 0..sb {
                let mut acc = 0.0;
                for k in 0..cols {
                    acc += m[i * cols + k] * m[j * cols + k];
                }
                g_full[i * sb + j] = acc;
            }
        }
        // Both backends consume the packed wire format.
        let mut g_raw = vec![0.0; packed_len(sb)];
        pack_lower(&g_full, sb, &mut g_raw);
        let r_raw = g.vec_normal(sb);
        let w_blk = g.vec_normal(sb);
        let y_blk = g.vec_normal(sb);
        // Random sparse overlap (symmetric-ish is not required).
        let mut ov = vec![0.0; s * s * b * b];
        for v in ov.iter_mut() {
            if g.f64_unit() < 0.04 {
                *v = 1.0;
            }
        }
        let (lam, inv_n) = (0.4, 1.0 / 500.0);
        let dn = nb
            .ca_inner_solve(s, b, &g_raw, &r_raw, &w_blk, &ov, lam, inv_n)
            .unwrap();
        let dx = xb
            .ca_inner_solve(s, b, &g_raw, &r_raw, &w_blk, &ov, lam, inv_n)
            .unwrap();
        for (i, (p, q)) in dn.iter().zip(&dx).enumerate() {
            assert!(
                (p - q).abs() <= 1e-9 * p.abs().max(1.0),
                "primal Δ[{i}]: native {p} vs xla {q} (s={s}, b={b})"
            );
        }
        let dn = nb
            .ca_dual_inner_solve(s, b, &g_raw, &r_raw, &w_blk, &y_blk, &ov, lam, inv_n)
            .unwrap();
        let dx = xb
            .ca_dual_inner_solve(s, b, &g_raw, &r_raw, &w_blk, &y_blk, &ov, lam, inv_n)
            .unwrap();
        for (i, (p, q)) in dn.iter().zip(&dx).enumerate() {
            assert!(
                (p - q).abs() <= 1e-9 * p.abs().max(1.0),
                "dual Δ[{i}]: native {p} vs xla {q} (s={s}, b={b})"
            );
        }
    }
}

#[test]
fn full_solver_trajectory_parity() {
    // Whole CA-BCD and CA-BDCD runs through both backends → same w.
    let mut g = Gen::new(3);
    let (d, n) = (10, 600);
    let x = Matrix::Dense(DenseMatrix::from_vec(d, n, g.vec_normal(d * n)));
    let mut y = vec![0.0; n];
    x.matvec_t(&g.vec_normal(d), &mut y).unwrap();
    let opts = SolverOpts::builder()
        .b(4)
        .s(4)
        .lam(0.2)
        .iters(24)
        .seed(11)
        .record_every(0)
        .track_gram_cond(false)
        .overlap(false)
        .build();

    let mut nb = NativeBackend::new();
    let mut xb = XlaBackend::new(artifact_dir()).unwrap();
    let mut c = SerialComm::new();

    let w_native = bcd::run(&x, &y, n, &opts, None, &mut c, &mut nb).unwrap().w;
    let w_xla = bcd::run(&x, &y, n, &opts, None, &mut c, &mut xb).unwrap().w;
    for (i, (p, q)) in w_native.iter().zip(&w_xla).enumerate() {
        assert!(
            (p - q).abs() <= 1e-9 * p.abs().max(1.0),
            "CA-BCD w[{i}]: native {p} vs xla {q}"
        );
    }
    assert!(xb.executions > 0, "xla backend was never exercised");

    let a = x.transpose();
    let w_native = bdcd::run(&a, &y, d, 0, &opts, None, &mut c, &mut nb)
        .unwrap()
        .w_full;
    let w_xla = bdcd::run(&a, &y, d, 0, &opts, None, &mut c, &mut xb)
        .unwrap()
        .w_full;
    for (i, (p, q)) in w_native.iter().zip(&w_xla).enumerate() {
        assert!(
            (p - q).abs() <= 1e-9 * p.abs().max(1.0),
            "CA-BDCD w[{i}]: native {p} vs xla {q}"
        );
    }
}

#[test]
fn xla_backend_rejects_oversized_blocks() {
    let mut xb = XlaBackend::new(artifact_dir()).unwrap();
    let a = Matrix::Dense(DenseMatrix::zeros(200, 64));
    let idx: Vec<usize> = (0..128).collect(); // > largest artifact sb (64)
    let z = vec![0.0; 64];
    let mut g = vec![0.0; packed_len(128)];
    let mut r = vec![0.0; 128];
    let err = xb.gram_resid(&a, &idx, &z, &mut g, &mut r).unwrap_err();
    assert!(
        err.to_string().contains("no gram artifact"),
        "unexpected error: {err}"
    );
}
