//! Chaos test matrix (PR 8): every solver × schedule × injected fault.
//!
//! [`ChaosComm`] wraps each rank's thread transport with a seeded fault
//! plan, exercising the three fault-tolerance layers end to end:
//!
//! * **latency** — spikes delay collectives but touch no payload bytes:
//!   a completed run must be bitwise-equal to the fault-free run.
//! * **transient-retry** — delivery failures are retried with bounded
//!   backoff ([`CostMeter::retries`] metered); the delegated collective
//!   still runs exactly once, so the trajectory and wire counts match
//!   fault-free bitwise.
//! * **stall → timeout** — a rank sleeping past the group deadline
//!   ([`Communicator::set_deadline`]) poisons the group: every rank gets
//!   an actionable `Error::Comm` instead of a hang.
//! * **rank death → resume** — a rank dying mid-protocol is discovered
//!   through peer deadlines; a [`Session::resume`] from the last
//!   checkpoint replays to a final state bitwise-equal to the fault-free
//!   checkpointed run, with identical wire meters (`buf_allocs` — pool
//!   re-warm — and the fault-path counters are excluded by design; see
//!   `engine::checkpoint` module docs).
//!
//! All runs are P = 4, both blocking and overlap schedules, all six
//! methods (bcd, bdcd, bcd_row, cocoa, prox_bcd, prox_bdcd).

use std::time::Duration;

use cabcd::comm::thread::run_spmd;
use cabcd::comm::{ChaosComm, ChaosSpec, Communicator, CostMeter, SerialComm, ThreadComm};
use cabcd::coordinator::{partition_dual, partition_primal, partition_rows};
use cabcd::engine::{checkpoint, Checkpoint, MemorySink, Method, Problem, Session, Solution};
use cabcd::error::Result;
use cabcd::gram::NativeBackend;
use cabcd::matrix::io::Dataset;
use cabcd::matrix::{DenseMatrix, Matrix};
use cabcd::metrics::{History, Reference};
use cabcd::prox::Reg;
use cabcd::solvers::{cg, SolverOpts};

const P: usize = 4;
const LAM: f64 = 0.35;
const ITERS: usize = 24;
const S: usize = 4;
const B: usize = 2;
const SEED: u64 = 7;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum M {
    Bcd,
    Bdcd,
    BcdRow,
    Cocoa,
    ProxBcd,
    ProxBdcd,
}

impl M {
    const ALL: [M; 6] = [M::Bcd, M::Bdcd, M::BcdRow, M::Cocoa, M::ProxBcd, M::ProxBdcd];

    fn id(self) -> &'static str {
        match self {
            M::Bcd => "bcd",
            M::Bdcd => "bdcd",
            M::BcdRow => "bcd_row",
            M::Cocoa => "cocoa",
            M::ProxBcd => "prox_bcd",
            M::ProxBdcd => "prox_bdcd",
        }
    }

    fn method(self) -> Method {
        let name = match self {
            M::Bcd | M::ProxBcd => "cabcd",
            M::Bdcd | M::ProxBdcd => "cabdcd",
            M::BcdRow => "cabcdrow",
            M::Cocoa => "cocoa",
        };
        Method::parse(name).unwrap()
    }

    fn reg(self) -> Reg {
        match self {
            M::ProxBcd | M::ProxBdcd => Reg::L1,
            _ => Reg::L2,
        }
    }

    /// The ridge reference only applies to the exact-L2 runs.
    fn wants_reference(self) -> bool {
        self.reg() == Reg::L2
    }
}

fn toy_dataset() -> Dataset {
    let (d, n) = (12usize, 48usize);
    let mut st = 0xC4A05EEDu64;
    let data: Vec<f64> = (0..d * n)
        .map(|_| {
            st ^= st << 13;
            st ^= st >> 7;
            st ^= st << 17;
            (st as f64 / u64::MAX as f64) - 0.5
        })
        .collect();
    let x = Matrix::Dense(DenseMatrix::from_vec(d, n, data));
    let mut y = vec![0.0; n];
    let mut w_star = vec![0.0; d];
    w_star[0] = 1.5;
    w_star[d / 2] = -2.0;
    w_star[d - 1] = 0.75;
    x.matvec_t(&w_star, &mut y).unwrap();
    Dataset {
        name: "chaos".into(),
        x,
        y,
    }
}

fn reference(ds: &Dataset) -> Reference {
    let mut comm = SerialComm::new();
    cg::compute_reference(&ds.x, &ds.y, ds.n(), LAM, &mut comm).unwrap()
}

fn solver_opts(m: M, overlap: bool) -> SolverOpts {
    SolverOpts::builder()
        .b(B)
        .s(S)
        .lam(LAM)
        .iters(ITERS)
        .seed(SEED)
        .record_every(4)
        .overlap(overlap)
        .reg(m.reg())
        .build()
}

/// One rank's comparable output: concatenated iterate vectors + history.
struct RankOut {
    vecs: Vec<f64>,
    history: History,
}

fn unpack(m: M, sol: Solution) -> RankOut {
    match m {
        M::Bcd | M::ProxBcd => {
            let out = sol.into_primal().unwrap();
            let mut vecs = out.w;
            vecs.extend_from_slice(&out.alpha_loc);
            RankOut {
                vecs,
                history: out.history,
            }
        }
        M::Bdcd | M::ProxBdcd => {
            let out = sol.into_dual().unwrap();
            let mut vecs = out.w_full;
            vecs.extend_from_slice(&out.w_loc);
            vecs.extend_from_slice(&out.alpha);
            RankOut {
                vecs,
                history: out.history,
            }
        }
        M::BcdRow => {
            let out = sol.into_row_primal().unwrap();
            let mut vecs = out.w_full;
            vecs.extend_from_slice(&out.w_loc);
            vecs.extend(out.max_loads.iter().map(|&l| l as f64));
            RankOut {
                vecs,
                history: out.history,
            }
        }
        M::Cocoa => {
            let out = sol.into_cocoa().unwrap();
            let mut vecs = out.w;
            vecs.extend_from_slice(&out.alpha_loc);
            RankOut {
                vecs,
                history: out.history,
            }
        }
    }
}

/// One-rank placeholder endpoint: `run_spmd` hands out `&mut ThreadComm`,
/// the chaos wrapper wants ownership, so the real endpoint is swapped out
/// for the solve and restored after.
fn stub() -> ThreadComm {
    let mut g = ThreadComm::group(1);
    let Some(c) = g.pop() else {
        unreachable!("group(1) returns one endpoint")
    };
    c
}

/// Run one (method, schedule) config at P = 4 under a fault plan.
/// `deadline` bounds every blocking receive; `ckpt = (sink, every)`
/// installs per-rank checkpointing; `resume` restarts each rank from its
/// entry in the sink. Per rank: the solve result (error stringified) and
/// the endpoint's final meter (available even when the solve failed).
fn run_config(
    m: M,
    overlap: bool,
    ds: &Dataset,
    rref: Option<&Reference>,
    spec: ChaosSpec,
    deadline: Option<Duration>,
    ckpt: Option<(MemorySink, usize)>,
    resume: bool,
) -> Vec<(std::result::Result<RankOut, String>, CostMeter)> {
    let opts = solver_opts(m, overlap);
    let method = m.method();
    let rref = rref.filter(|_| m.wants_reference());
    enum Shards {
        Primal(Vec<cabcd::coordinator::PrimalShard>),
        Dual(Vec<cabcd::coordinator::DualShard>),
        Rows(Vec<cabcd::coordinator::RowShard>),
    }
    let shards = match m {
        M::Bcd | M::ProxBcd | M::Cocoa => Shards::Primal(partition_primal(ds, P).unwrap()),
        M::Bdcd | M::ProxBdcd => Shards::Dual(partition_dual(ds, P).unwrap()),
        M::BcdRow => Shards::Rows(partition_rows(ds, P).unwrap()),
    };
    run_spmd(P, move |rank, comm| {
        let inner = std::mem::replace(comm, stub());
        let mut chaos = ChaosComm::new(inner, spec);
        chaos.set_deadline(deadline);
        if let Some((sink, every)) = &ckpt {
            checkpoint::install(Box::new(sink.clone()), *every);
        }
        let run_one = || -> Result<RankOut> {
            let problem = match &shards {
                Shards::Primal(v) => {
                    let sh = &v[rank];
                    Problem::primal(&sh.a_loc, &sh.y_loc, sh.n_global)
                }
                Shards::Dual(v) => {
                    let sh = &v[rank];
                    Problem::dual(&sh.a_loc, &sh.y, sh.d_global, sh.d_offset)
                }
                Shards::Rows(v) => {
                    let sh = &v[rank];
                    Problem::primal_rows(&sh.x_rows, &sh.y_loc, sh.d_global, sh.d_offset)
                }
            };
            let problem = problem.with_reference(rref);
            let mut be = NativeBackend::new();
            let mut session = Session::new(&problem)
                .opts(opts.clone())
                .method(method)
                .local_iters(S)
                .comm(&mut chaos);
            if method.needs_backend() {
                session = session.backend(&mut be);
            }
            if resume {
                let (sink, _) = ckpt.as_ref().expect("resume needs a checkpoint sink");
                let c = sink.load(rank)?.expect("no checkpoint to resume from");
                session = session.resume(c);
            }
            Ok(unpack(m, session.run()?))
        };
        let res = run_one().map_err(|e| e.to_string());
        checkpoint::take();
        chaos.set_deadline(None);
        let meter = *chaos.meter();
        *comm = chaos.into_inner();
        (res, meter)
    })
}

fn wire(m: &CostMeter) -> [u64; 7] {
    [
        m.msgs,
        m.words,
        m.recv_msgs,
        m.recv_words,
        m.allreduces,
        m.all_to_alls,
        m.collective_waits,
    ]
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn assert_histories_equal(ctx: &str, a: &History, b: &History) {
    assert_eq!(a.iters, b.iters, "{ctx}: iters");
    let rec = |h: &History| -> Vec<(usize, u64, u64)> {
        h.records
            .iter()
            .map(|r| (r.iter, r.obj_err.to_bits(), r.sol_err.to_bits()))
            .collect()
    };
    assert_eq!(rec(a), rec(b), "{ctx}: iterate records");
    let prox = |h: &History| -> Vec<(usize, u64, u64, u64, usize)> {
        h.prox
            .iter()
            .map(|r| {
                (
                    r.iter,
                    r.pen_obj.to_bits(),
                    r.gap.to_bits(),
                    r.subgrad.to_bits(),
                    r.nnz,
                )
            })
            .collect()
    };
    assert_eq!(prox(a), prox(b), "{ctx}: prox records");
    assert_eq!(bits(&a.gram_conds), bits(&b.gram_conds), "{ctx}: gram conds");
    assert_eq!(wire(&a.meter), wire(&b.meter), "{ctx}: wire meters");
}

fn assert_rank_outs_equal(
    ctx: &str,
    a: &[(std::result::Result<RankOut, String>, CostMeter)],
    b: &[(std::result::Result<RankOut, String>, CostMeter)],
) {
    for (rank, ((ra, ma), (rb, mb))) in a.iter().zip(b).enumerate() {
        let oa = ra.as_ref().unwrap_or_else(|e| panic!("{ctx}: rank {rank} failed: {e}"));
        let ob = rb.as_ref().unwrap_or_else(|e| panic!("{ctx}: rank {rank} failed: {e}"));
        assert_eq!(
            bits(&oa.vecs),
            bits(&ob.vecs),
            "{ctx}: rank {rank} iterate vectors diverged"
        );
        assert_histories_equal(&format!("{ctx}: rank {rank}"), &oa.history, &ob.history);
        assert_eq!(wire(ma), wire(mb), "{ctx}: rank {rank} endpoint wire meters");
    }
}

#[test]
fn latency_spikes_leave_results_bitwise_intact() {
    let ds = toy_dataset();
    let rref = reference(&ds);
    for m in M::ALL {
        for overlap in [false, true] {
            let ctx = format!("latency/{}/overlap={overlap}", m.id());
            let clean = run_config(
                m,
                overlap,
                &ds,
                Some(&rref),
                ChaosSpec::default(),
                None,
                None,
                false,
            );
            let spec = ChaosSpec {
                seed: 11,
                latency_prob: 0.3,
                latency_ms: 1,
                ..ChaosSpec::default()
            };
            let faulted = run_config(m, overlap, &ds, Some(&rref), spec, None, None, false);
            assert_rank_outs_equal(&ctx, &clean, &faulted);
            for (_, meter) in &faulted {
                assert_eq!(meter.retries, 0, "{ctx}: latency must not retry");
                assert_eq!(meter.timeouts, 0, "{ctx}: latency must not time out");
            }
        }
    }
}

#[test]
fn transient_faults_retry_to_the_same_answer() {
    let ds = toy_dataset();
    let rref = reference(&ds);
    for m in M::ALL {
        for overlap in [false, true] {
            let ctx = format!("transient/{}/overlap={overlap}", m.id());
            let clean = run_config(
                m,
                overlap,
                &ds,
                Some(&rref),
                ChaosSpec::default(),
                None,
                None,
                false,
            );
            let spec = ChaosSpec {
                seed: 23,
                transient_prob: 0.4,
                max_retries: 64,
                backoff_base_ms: 0,
                ..ChaosSpec::default()
            };
            let faulted = run_config(m, overlap, &ds, Some(&rref), spec, None, None, false);
            assert_rank_outs_equal(&ctx, &clean, &faulted);
            let retries: u64 = faulted.iter().map(|(_, meter)| meter.retries).sum();
            assert!(
                retries > 0,
                "{ctx}: p = 0.4 over every collective never drew a fault"
            );
        }
    }
}

#[test]
fn stalls_hit_the_deadline_and_poison_every_rank() {
    let ds = toy_dataset();
    let rref = reference(&ds);
    for m in M::ALL {
        for overlap in [false, true] {
            let ctx = format!("stall/{}/overlap={overlap}", m.id());
            let spec = ChaosSpec {
                stall_at: Some(5),
                stall_ms: 1_000,
                victim: 1,
                ..ChaosSpec::default()
            };
            let outs = run_config(
                m,
                overlap,
                &ds,
                Some(&rref),
                spec,
                Some(Duration::from_millis(150)),
                None,
                false,
            );
            let mut timed_out = 0u64;
            for (rank, (res, meter)) in outs.iter().enumerate() {
                let err = match res {
                    Err(e) => e,
                    Ok(_) => panic!("{ctx}: rank {rank} completed through a stalled group"),
                };
                assert!(
                    err.contains("timed out") || err.contains("poisoned"),
                    "{ctx}: rank {rank} error not actionable: {err}"
                );
                timed_out += meter.timeouts;
            }
            assert!(timed_out > 0, "{ctx}: no rank metered a timeout");
        }
    }
}

#[test]
fn rank_death_resumes_bitwise_from_the_last_checkpoint() {
    let ds = toy_dataset();
    let rref = reference(&ds);
    const EVERY: usize = 2;
    for m in M::ALL {
        for overlap in [false, true] {
            let ctx = format!("death/{}/overlap={overlap}", m.id());

            // Fault-free baseline WITH checkpointing at the same cadence
            // (checkpointing pins the capture-compatible schedule, so this
            // is the state a resume must reproduce bitwise).
            let sink_base = MemorySink::new();
            let clean = run_config(
                m,
                overlap,
                &ds,
                Some(&rref),
                ChaosSpec::default(),
                None,
                Some((sink_base, EVERY)),
                false,
            );

            // Chaos run: rank 2 dies mid-protocol; peers discover the
            // death through their receive deadlines.
            let sink = MemorySink::new();
            let spec = ChaosSpec {
                die_at: Some(7),
                victim: 2,
                ..ChaosSpec::default()
            };
            let dead = run_config(
                m,
                overlap,
                &ds,
                Some(&rref),
                spec,
                Some(Duration::from_millis(400)),
                Some((sink.clone(), EVERY)),
                false,
            );
            for (rank, (res, _)) in dead.iter().enumerate() {
                let err = match res {
                    Err(e) => e,
                    Ok(_) => panic!("{ctx}: rank {rank} survived a dead peer"),
                };
                assert!(
                    err.contains("died at collective")
                        || err.contains("timed out")
                        || err.contains("poisoned"),
                    "{ctx}: rank {rank} error not actionable: {err}"
                );
            }

            // Every rank checkpointed the same block before the death.
            let ckpts: Vec<Checkpoint> = (0..P)
                .map(|r| {
                    sink.load(r)
                        .unwrap()
                        .unwrap_or_else(|| panic!("{ctx}: rank {r} has no checkpoint"))
                })
                .collect();
            let next_k = ckpts[0].next_k;
            assert!(next_k > 0, "{ctx}: checkpoint captured nothing");
            for c in &ckpts {
                assert_eq!(c.next_k, next_k, "{ctx}: ranks checkpointed different blocks");
            }

            // Resume from the survivors' checkpoints: bitwise-equal final
            // state and identical wire meters vs the fault-free baseline.
            let resumed = run_config(
                m,
                overlap,
                &ds,
                Some(&rref),
                ChaosSpec::default(),
                None,
                Some((sink, EVERY)),
                true,
            );
            assert_rank_outs_equal(&ctx, &clean, &resumed);
        }
    }
}
