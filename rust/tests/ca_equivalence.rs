//! The paper's central claim (§3): the communication-avoiding variants
//! reproduce the classical iterations **exactly** (in exact arithmetic) —
//! unrolling the recurrence changes the communication pattern, not the
//! math. Here: trajectory equality to fp tolerance over randomized
//! problems, for both the primal and the dual method.

use cabcd::comm::SerialComm;
use cabcd::gram::NativeBackend;
use cabcd::matrix::{DenseMatrix, Matrix};
use cabcd::solvers::{bcd, bdcd, SolverOpts};
use cabcd::util::proptest::{check, Gen};
use cabcd::{prop_assert, prop_assert_close};

fn random_problem(g: &mut Gen, d: usize, n: usize) -> (Matrix, Vec<f64>) {
    let x = Matrix::Dense(DenseMatrix::from_vec(d, n, g.vec_normal(d * n)));
    let mut y = vec![0.0; n];
    let w_star = g.vec_normal(d);
    x.matvec_t(&w_star, &mut y).unwrap();
    for v in y.iter_mut() {
        *v += 0.05 * g.normal();
    }
    (x, y)
}

#[test]
fn prop_ca_bcd_equals_bcd_for_random_s_and_b() {
    check(12, |g| {
        let d = g.usize_in(6, 20);
        let n = g.usize_in(24, 80);
        let (x, y) = random_problem(g, d, n);
        let b = g.usize_in(1, (d / 2).max(2));
        let s = g.usize_in(2, 7);
        let outer = g.usize_in(3, 9);
        let lam = 0.02 + g.f64_unit();
        let seed = g.seed ^ 0xABCD;
        let total_inner = outer * s; // SAME inner-iteration count for both
        let mk = |s: usize| SolverOpts::builder()
            .b(b)
            .s(s)
            .lam(lam)
            .iters(total_inner)
            .seed(seed)
            .record_every(0)
            .track_gram_cond(false)
            .overlap(false)
            .build();
        let mut be = NativeBackend::new();
        let mut c = SerialComm::new();
        let w1 = bcd::run(&x, &y, n, &mk(1), None, &mut c, &mut be)
            .map_err(|e| e.to_string())?
            .w;
        let ws = bcd::run(&x, &y, n, &mk(s), None, &mut c, &mut be)
            .map_err(|e| e.to_string())?
            .w;
        let scale: f64 = w1.iter().map(|v| v.abs()).fold(1e-12, f64::max);
        for (i, (a, bv)) in w1.iter().zip(&ws).enumerate() {
            prop_assert!(
                (a - bv).abs() <= 1e-8 * scale,
                "w[{i}]: s=1 {a} vs s={s} {bv} (b={b}, d={d}, n={n}, λ={lam})"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_ca_bdcd_equals_bdcd_for_random_s_and_b() {
    check(12, |g| {
        let d = g.usize_in(5, 16);
        let n = g.usize_in(20, 60);
        let (x, y) = random_problem(g, d, n);
        let a = x.transpose();
        let b = g.usize_in(1, (n / 4).max(2));
        let s = g.usize_in(2, 6);
        let outer = g.usize_in(3, 8);
        let lam = 0.05 + g.f64_unit();
        let seed = g.seed ^ 0x1234;
        let total_inner = outer * s;
        let mk = |s: usize| SolverOpts::builder()
            .b(b)
            .s(s)
            .lam(lam)
            .iters(total_inner)
            .seed(seed)
            .record_every(0)
            .track_gram_cond(false)
            .overlap(false)
            .build();
        let mut be = NativeBackend::new();
        let mut c = SerialComm::new();
        let w1 = bdcd::run(&a, &y, d, 0, &mk(1), None, &mut c, &mut be)
            .map_err(|e| e.to_string())?
            .w_full;
        let ws = bdcd::run(&a, &y, d, 0, &mk(s), None, &mut c, &mut be)
            .map_err(|e| e.to_string())?
            .w_full;
        let scale: f64 = w1.iter().map(|v| v.abs()).fold(1e-12, f64::max);
        for (i, (p, q)) in w1.iter().zip(&ws).enumerate() {
            prop_assert!(
                (p - q).abs() <= 1e-8 * scale,
                "w[{i}]: s=1 {p} vs s={s} {q} (b'={b}, d={d}, n={n})"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_duplicate_coordinates_across_inner_blocks_are_exact() {
    // Tiny sample dimension forces heavy overlap between the s inner
    // blocks — the Σ I_jᵀI_t cross terms must keep CA exact anyway.
    check(16, |g| {
        let d = g.usize_in(3, 5); // b=2, s=4 over d≤5 → guaranteed overlaps
        let n = 40;
        let (x, y) = random_problem(g, d, n);
        let mk = |s: usize| SolverOpts::builder()
            .b(2)
            .s(s)
            .lam(0.3)
            .iters(12)
            .seed(g.seed)
            .record_every(0)
            .track_gram_cond(false)
            .overlap(false)
            .build();
        let mut be = NativeBackend::new();
        let mut c = SerialComm::new();
        let w1 = bcd::run(&x, &y, n, &mk(1), None, &mut c, &mut be)
            .map_err(|e| e.to_string())?
            .w;
        let w4 = bcd::run(&x, &y, n, &mk(4), None, &mut c, &mut be)
            .map_err(|e| e.to_string())?
            .w;
        for (a, b) in w1.iter().zip(&w4) {
            prop_assert_close!(*a, *b, 1e-9);
        }
        Ok(())
    });
}

/// Acceptance criterion of the non-blocking overhaul: the SPMD trajectory
/// is **bitwise stable** across the blocking and overlapped communication
/// paths, for both the primal and the dual solver, at power-of-two and
/// non-power-of-two rank counts — and the allreduce count stays exactly
/// H/s in both modes (the pipeline does not add collectives).
#[test]
fn overlap_pipeline_is_bitwise_stable_spmd() {
    use cabcd::comm::thread::run_spmd;
    use cabcd::coordinator::{partition_dual, partition_primal};
    use cabcd::matrix::gen::{generate, scaled_specs};

    let spec = &scaled_specs(8)[0]; // abalone-s8
    let ds = generate(spec, 5).unwrap();
    let mk = |overlap: bool| SolverOpts::builder()
        .b(2)
        .s(4)
        .lam(spec.lambda())
        .iters(48)
        .seed(13)
        .record_every(0)
        .track_gram_cond(false)
        .overlap(overlap)
        .build();
    for p in [2usize, 3, 5] {
        // Primal.
        let shards = partition_primal(&ds, p).unwrap();
        let mut runs = Vec::new();
        for overlap in [false, true] {
            let opts = mk(overlap);
            let outs = run_spmd(p, |rank, comm| {
                let mut be = NativeBackend::new();
                let sh = &shards[rank];
                bcd::run(&sh.a_loc, &sh.y_loc, sh.n_global, &opts, None, comm, &mut be).unwrap()
            });
            assert_eq!(
                outs[0].history.meter.allreduces,
                48 / 4,
                "P={p} overlap={overlap}: collective count changed"
            );
            runs.push(outs.into_iter().map(|o| o.w).collect::<Vec<_>>());
        }
        for (rank, (wb, wo)) in runs[0].iter().zip(&runs[1]).enumerate() {
            assert!(
                wb == wo,
                "P={p} rank={rank}: primal overlap trajectory not bitwise stable"
            );
        }
        // Dual (feature dimension d=4 caps the dual rank count).
        let p = p.min(4);
        let shards = partition_dual(&ds, p).unwrap();
        let mut runs = Vec::new();
        for overlap in [false, true] {
            let opts = mk(overlap);
            let outs = run_spmd(p, |rank, comm| {
                let mut be = NativeBackend::new();
                let sh = &shards[rank];
                bdcd::run(
                    &sh.a_loc,
                    &sh.y,
                    sh.d_global,
                    sh.d_offset,
                    &opts,
                    None,
                    comm,
                    &mut be,
                )
                .unwrap()
            });
            runs.push(outs.into_iter().map(|o| o.w_full).collect::<Vec<_>>());
        }
        for (rank, (wb, wo)) in runs[0].iter().zip(&runs[1]).enumerate() {
            assert!(
                wb == wo,
                "P={p} rank={rank}: dual overlap trajectory not bitwise stable"
            );
        }
    }
}

#[test]
fn allreduce_counts_scale_as_h_over_s() {
    // Theorem 6's L term, measured: CA-BCD with factor s must enter
    // exactly H/s allreduces where BCD enters H.
    let mut g = Gen::new(99);
    let (x, y) = random_problem(&mut g, 10, 50);
    for s in [1usize, 2, 5, 10] {
        let opts = SolverOpts::builder()
            .b(3)
            .s(s)
            .lam(0.1)
            .iters(40)
            .seed(5)
            .record_every(0)
            .track_gram_cond(false)
            .overlap(false)
            .build();
        let mut be = NativeBackend::new();
        let mut c = SerialComm::new();
        let out = bcd::run(&x, &y, 50, &opts, None, &mut c, &mut be).unwrap();
        assert_eq!(out.history.meter.allreduces as usize, 40 / s, "s={s}");
    }
}
