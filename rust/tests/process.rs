//! Multi-process transport integration suite (PR 10).
//!
//! The process transport must be a drop-in replacement for the thread
//! transport behind the `Communicator` seam — same protocol engine, same
//! element order, same arithmetic — so entire experiment reports must
//! come out **bitwise identical** across the two transports:
//!
//! * every method × {blocking, overlap} at P = 4: trajectories,
//!   certificates, and the rank-0 wire meter match bit for bit;
//! * the two-level hierarchical topology runs identically over both
//!   transports (same topology ⇒ same reduction association);
//! * a worker rank that dies mid-collective aborts the run with an
//!   actionable error naming the lost peer and the operation tag — the
//!   kill-a-child regression;
//! * the epilogue gathers ship span traces and telemetry registries from
//!   worker processes into the parent's artifacts.
//!
//! # Worker re-exec under the test harness
//!
//! The launcher re-execs `current_exe()`, which here is this libtest
//! binary. The driver's `ENV_SPAWN_ARGS` hook routes the workers into
//! [`proc_child_entry`] — an `#[ignore]`d test that dispatches on the
//! inherited `CABCD_TEST_SCENARIO` variable, normally straight into
//! [`cabcd::coordinator::maybe_run_process_child`]. Environment
//! variables are process-global, so every test here serializes on one
//! mutex and restores the environment on drop.

use std::sync::{Mutex, MutexGuard};

use cabcd::config::{DatasetConfig, ExperimentConfig, RunConfig, SolverConfig};
use cabcd::coordinator::driver::{ENV_CONFIG, ENV_SPAWN_ARGS};
use cabcd::coordinator::{run_experiment, ExperimentReport};

/// Scenario selector inherited by re-exec'd worker ranks.
const SCENARIO: &str = "CABCD_TEST_SCENARIO";

/// Serializes every process test: the spawn hook and scenario selector
/// live in the (process-global) environment.
static PROC_ENV: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    // A previous test's assert panic must not wedge the whole suite.
    PROC_ENV.lock().unwrap_or_else(|e| e.into_inner())
}

/// Installs the worker-spawn hook (and optional scenario) for one test;
/// restores a clean environment on drop, pass or fail.
struct SpawnEnv;

impl SpawnEnv {
    fn install(scenario: Option<&str>) -> SpawnEnv {
        std::env::set_var(
            ENV_SPAWN_ARGS,
            "--exact proc_child_entry --ignored --nocapture",
        );
        match scenario {
            Some(s) => std::env::set_var(SCENARIO, s),
            None => std::env::remove_var(SCENARIO),
        }
        SpawnEnv
    }
}

impl Drop for SpawnEnv {
    fn drop(&mut self) {
        std::env::remove_var(ENV_SPAWN_ARGS);
        std::env::remove_var(SCENARIO);
    }
}

/// Worker-rank entry point: the launcher re-execs this test binary with
/// `--exact proc_child_entry --ignored`, so exactly this function runs in
/// each worker process. Ignored in the parent's normal test pass.
#[test]
#[ignore]
fn proc_child_entry() {
    match std::env::var(SCENARIO).as_deref() {
        // Kill-a-child regression: rank 2 completes the bootstrap
        // handshake, then vanishes before the first collective. The
        // surviving ranks' solves fail with the group poisoned — their
        // error exits are expected, so the result is deliberately not
        // asserted.
        Ok("die-rank-2") => {
            let (addr, rank, ranks) = cabcd::comm::process::child_spec_from_env()
                .expect("worker launched without rendezvous environment");
            if rank == 2 {
                let comm = cabcd::comm::process::connect(&addr, rank, ranks)
                    .expect("rank 2 bootstrap failed");
                drop(comm);
                std::process::exit(0);
            }
            let _ = cabcd::coordinator::maybe_run_process_child();
        }
        _ => {
            let ran = cabcd::coordinator::maybe_run_process_child()
                .expect("worker rank failed");
            assert!(ran, "child entry reached without rendezvous environment");
        }
    }
}

/// P = 4 experiment fixture shared by both transports. Small enough for
/// CI (abalone clone at scale 16: d = 4, n = 261) but large enough that
/// every collective path runs many times.
fn cfg(method: &str, reg: &str, overlap: bool, transport: &str) -> ExperimentConfig {
    ExperimentConfig {
        dataset: DatasetConfig {
            kind: "synthetic".into(),
            name: Some("abalone".into()),
            path: None,
            scale: 16,
            seed: 1,
        },
        solver: SolverConfig {
            method: method.into(),
            b: 2,
            s: 4,
            lam: None,
            iters: 60,
            seed: 3,
            record_every: 20,
            track_gram_cond: false,
            tol: None,
            overlap,
            reg: reg.into(),
            l1_ratio: 0.5,
            local_iters: 25,
        },
        run: RunConfig {
            ranks: 4,
            backend: "native".into(),
            transport: transport.into(),
            topology: "flat".into(),
            node_size: 1,
            artifact_dir: std::env::temp_dir().join("cabcd-process-tests"),
            trace: None,
            telemetry: None,
            telemetry_z: None,
            // A generous receive deadline converts any transport bug into
            // a failing test instead of a hung CI job.
            comm_timeout_ms: Some(30_000),
            checkpoint_every: 0,
            checkpoint_dir: None,
        },
    }
}

/// Bitwise comparison of everything the solve produced: trajectory
/// records, prox certificates, the rank-0 wire meter, and the cross-rank
/// critical path. `f64::to_bits` equality is deliberate — "close" would
/// hide a transport that reorders arithmetic.
fn assert_bitwise_equal(t: &ExperimentReport, p: &ExperimentReport, label: &str) {
    assert!(t.aborted_at.is_none(), "{label}: thread run aborted");
    assert!(p.aborted_at.is_none(), "{label}: process run aborted");
    assert_eq!(
        t.final_sol_err.to_bits(),
        p.final_sol_err.to_bits(),
        "{label}: final_sol_err {} vs {}",
        t.final_sol_err,
        p.final_sol_err
    );
    assert_eq!(
        t.final_obj_err.to_bits(),
        p.final_obj_err.to_bits(),
        "{label}: final_obj_err {} vs {}",
        t.final_obj_err,
        p.final_obj_err
    );
    assert_eq!(
        t.history.records.len(),
        p.history.records.len(),
        "{label}: record count"
    );
    for (i, (a, b)) in t.history.records.iter().zip(&p.history.records).enumerate() {
        assert_eq!(a.iter, b.iter, "{label}: record {i} iter");
        assert_eq!(
            a.obj_err.to_bits(),
            b.obj_err.to_bits(),
            "{label}: record {i} obj_err {} vs {}",
            a.obj_err,
            b.obj_err
        );
        assert_eq!(
            a.sol_err.to_bits(),
            b.sol_err.to_bits(),
            "{label}: record {i} sol_err {} vs {}",
            a.sol_err,
            b.sol_err
        );
    }
    assert_eq!(t.history.prox.len(), p.history.prox.len(), "{label}: prox count");
    for (i, (a, b)) in t.history.prox.iter().zip(&p.history.prox).enumerate() {
        assert_eq!(a.iter, b.iter, "{label}: prox {i} iter");
        assert_eq!(a.nnz, b.nnz, "{label}: prox {i} nnz");
        assert_eq!(
            a.pen_obj.to_bits(),
            b.pen_obj.to_bits(),
            "{label}: prox {i} pen_obj {} vs {}",
            a.pen_obj,
            b.pen_obj
        );
        assert_eq!(
            a.gap.to_bits(),
            b.gap.to_bits(),
            "{label}: prox {i} gap {} vs {}",
            a.gap,
            b.gap
        );
        assert_eq!(
            a.subgrad.to_bits(),
            b.subgrad.to_bits(),
            "{label}: prox {i} subgrad {} vs {}",
            a.subgrad,
            b.subgrad
        );
    }
    // The seven wire-traffic fields of the rank-0 meter. The fault-path
    // counters (retries, timeouts) and the pool tripwire (buf_allocs) are
    // transport-internal and excluded by design: a deadline-armed socket
    // receive and an in-memory channel receive may count housekeeping
    // differently without the wire schedule diverging.
    let (tm, pm) = (&t.history.meter, &p.history.meter);
    assert_eq!(tm.msgs, pm.msgs, "{label}: meter msgs");
    assert_eq!(tm.words, pm.words, "{label}: meter words");
    assert_eq!(tm.recv_msgs, pm.recv_msgs, "{label}: meter recv_msgs");
    assert_eq!(tm.recv_words, pm.recv_words, "{label}: meter recv_words");
    assert_eq!(tm.allreduces, pm.allreduces, "{label}: meter allreduces");
    assert_eq!(tm.all_to_alls, pm.all_to_alls, "{label}: meter all_to_alls");
    assert_eq!(
        tm.collective_waits, pm.collective_waits,
        "{label}: meter collective_waits"
    );
    assert_eq!(t.critical_msgs, p.critical_msgs, "{label}: critical_msgs");
    assert_eq!(t.critical_words, p.critical_words, "{label}: critical_words");
}

/// The six methods of the equivalence matrix × {blocking, overlap}: the
/// exact-l2 solvers, the CoCoA baseline, and the two CA-Prox L1 loops,
/// each run over both transports at P = 4 and compared bit for bit.
#[test]
fn process_transport_is_bitwise_identical_to_thread_transport() {
    let _l = lock();
    let _e = SpawnEnv::install(None);
    let matrix = [
        ("cabcd", "l2"),
        ("cabdcd", "l2"),
        ("cabcdrow", "l2"),
        ("cocoa", "l2"),
        ("cabcd", "l1"),
        ("cabdcd", "l1"),
    ];
    for (method, reg) in matrix {
        for overlap in [false, true] {
            let label = format!("{method}/{reg}/overlap={overlap}");
            let t = run_experiment(&cfg(method, reg, overlap, "thread"))
                .unwrap_or_else(|e| panic!("{label}: thread run failed: {e}"));
            let p = run_experiment(&cfg(method, reg, overlap, "process"))
                .unwrap_or_else(|e| panic!("{label}: process run failed: {e}"));
            assert_eq!(p.transport, "process", "{label}");
            assert_eq!(p.ranks, 4, "{label}");
            assert!(
                p.to_json().contains("\"transport\":\"process\""),
                "{label}: report JSON must name the transport"
            );
            assert_bitwise_equal(&t, &p, &label);
        }
    }
}

/// Same topology ⇒ same reduction association ⇒ bitwise equality holds
/// for the hierarchical collective across transports too (unlike
/// two-level vs flat, which legitimately re-associates the sum).
#[test]
fn twolevel_topology_is_bitwise_identical_across_transports() {
    let _l = lock();
    let _e = SpawnEnv::install(None);
    let mk = |transport: &str| {
        let mut c = cfg("cabcd", "l2", true, transport);
        c.run.topology = "twolevel".into();
        c.run.node_size = 2;
        c
    };
    let t = run_experiment(&mk("thread")).expect("thread twolevel run failed");
    let p = run_experiment(&mk("process")).expect("process twolevel run failed");
    assert_eq!(p.topology, "twolevel");
    assert_eq!(p.node_size, 2);
    assert!(p.to_json().contains("\"topology\":\"twolevel\""));
    assert_bitwise_equal(&t, &p, "twolevel/cabcd");
}

/// Kill-a-child regression: a worker that dies mid-run must surface as an
/// `Error::Comm`-style abort naming the lost peer and the operation tag —
/// never a panic, never a hang (the receive deadline is the backstop, but
/// the peer-down latch should fire long before it).
#[test]
fn dead_worker_rank_aborts_with_peer_and_op_tag_named() {
    let _l = lock();
    let _e = SpawnEnv::install(Some("die-rank-2"));
    let mut c = cfg("cabcd", "l2", false, "process");
    c.run.comm_timeout_ms = Some(10_000);
    let report = run_experiment(&c).expect("an aborted run still yields a report");
    let abort = report
        .aborted_at
        .as_ref()
        .expect("a dead worker rank must abort the run");
    assert!(
        abort.error.contains("lost rank 2"),
        "abort error must name the dead peer: {}",
        abort.error
    );
    assert!(
        abort.error.contains("op tag"),
        "abort error must name the failing operation tag: {}",
        abort.error
    );
    assert!(
        report.notes.iter().any(|n| n.contains("aborted")),
        "report notes must record the abort: {:?}",
        report.notes
    );
}

/// The post-solve epilogue gathers must ship worker-side span traces and
/// telemetry registries to the parent: the report's trace and telemetry
/// summaries then cover all four ranks, and the artifacts land on disk.
#[test]
fn trace_and_telemetry_artifacts_cross_the_process_boundary() {
    let _l = lock();
    let _e = SpawnEnv::install(None);
    let dir = std::env::temp_dir().join(format!("cabcd-proc-artifacts-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("artifact dir");
    let trace_path = dir.join("trace.json");
    let telem_path = dir.join("telemetry.json");
    let mut c = cfg("cabcd", "l2", false, "process");
    c.solver.iters = 40;
    c.run.trace = Some(trace_path.clone());
    c.run.telemetry = Some(telem_path.clone());
    let report = run_experiment(&c).expect("traced process run failed");
    assert!(report.aborted_at.is_none(), "run aborted: {:?}", report.notes);
    let trace = report.trace.as_ref().expect("trace summary missing");
    assert_eq!(trace.ranks, 4, "all four ranks' spans must reach the parent");
    let telem = report.telemetry.as_ref().expect("telemetry summary missing");
    assert_eq!(telem.ranks, 4, "all four ranks' registries must reach the parent");
    assert!(trace_path.is_file(), "chrome trace not written");
    assert!(telem_path.is_file(), "telemetry snapshots not written");
    assert!(
        telem_path.with_extension("prom").is_file(),
        "prometheus exposition not written"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// `ENV_CONFIG` is the launcher's only config channel: the serialized
/// form must parse back to an identical experiment (the driver relies on
/// every rank deriving bitwise-identical inputs from it).
#[test]
fn spawned_config_channel_round_trips() {
    let c = cfg("cabcdrow", "l2", true, "process");
    let ini = c.to_ini();
    let back = ExperimentConfig::from_str(&ini).expect("serialized config must parse");
    assert_eq!(format!("{c:?}"), format!("{back:?}"));
    // The channel is plain INI text — sanity-check the env-var names the
    // external-launch docs promise stay wired.
    assert_eq!(ENV_CONFIG, "CABCD_PROC_CONFIG");
    assert_eq!(ENV_SPAWN_ARGS, "CABCD_PROC_SPAWN_ARGS");
}
