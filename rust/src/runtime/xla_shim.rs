//! Build-offline stand-in for the vendored `xla` crate.
//!
//! The three-layer stack executes AOT JAX/Pallas artifacts through PJRT,
//! which needs the vendored `xla` bindings. This container builds with zero
//! external crates, so by default [`crate::runtime`] compiles against this
//! shim: the same type/method surface, with every runtime entry point
//! failing fast at client construction. Vendoring the real crate and
//! building with `RUSTFLAGS="--cfg cabcd_xla"` swaps the real bindings in
//! without touching any call site.

use std::fmt;
use std::path::Path;

/// Shim error — carries the single "unavailable" diagnostic.
#[derive(Debug)]
pub struct Error(pub String);

impl Error {
    fn unavailable() -> Error {
        Error(
            "XLA/PJRT runtime unavailable: built without the vendored `xla` crate \
             (rebuild with RUSTFLAGS=\"--cfg cabcd_xla\" and the vendored dependency)"
                .into(),
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// PJRT client handle (construction always fails in the shim).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error::unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::unavailable())
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::unavailable())
    }
}

/// Device buffer returned by execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }
}

/// Parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &Path) -> Result<HloModuleProto, Error> {
        Err(Error::unavailable())
    }
}

/// XLA computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Host literal (tensor) value.
#[derive(Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f64]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(Error::unavailable())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        Err(Error::unavailable())
    }
}

impl From<f64> for Literal {
    fn from(_v: f64) -> Literal {
        Literal
    }
}
