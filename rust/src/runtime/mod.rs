//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and exposes them as a [`ComputeBackend`].
//!
//! Python never runs here — artifacts are compiled once at `make artifacts`
//! and this module only parses HLO text (`HloModuleProto::from_text_file`),
//! compiles it on the PJRT CPU client at startup, and executes on the hot
//! path.
//!
//! Shape adaptation: artifacts have fixed shapes; inputs are zero-padded to
//! the smallest compatible artifact. Zero rows/columns contribute nothing
//! to Gram/residual products, and padded subproblem blocks solve to Δ = 0
//! against a λI (resp. I/n) diagonal — padding is **exact**, not
//! approximate (asserted by the backend-parity integration test).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::gram::ComputeBackend;
use crate::linalg::packed::{packed_len, pidx};
use crate::matrix::Matrix;

// Default offline build: compile against the fail-fast shim. A vendored
// `xla` dependency plus `RUSTFLAGS="--cfg cabcd_xla"` swaps in the real
// PJRT bindings (the `xla::` paths below resolve to the extern crate).
#[cfg(not(cabcd_xla))]
#[path = "xla_shim.rs"]
mod xla;

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Parsed `artifacts/manifest.tsv` (see aot.py; the JSON twin is for
/// humans/tooling — Rust reads the TSV to stay serde-free offline).
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dtype: String,
    pub nt: usize,
    pub artifacts: Vec<ArtifactMeta>,
}

#[derive(Clone, Debug, Default)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub sb: usize,
    pub nloc: usize,
    pub s: usize,
    pub b: usize,
}

impl Manifest {
    /// Parse the TSV: a `#meta` header line (`dtype`, `nt`), then one line
    /// per artifact: `name<TAB>file<TAB>kind<TAB>sb<TAB>nloc<TAB>s<TAB>b`.
    pub fn parse_tsv(text: &str) -> Result<Manifest> {
        let mut dtype = String::new();
        let mut nt = 0usize;
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if let Some(meta) = line.strip_prefix("#meta") {
                for tok in meta.split_whitespace() {
                    if let Some(v) = tok.strip_prefix("dtype=") {
                        dtype = v.to_string();
                    } else if let Some(v) = tok.strip_prefix("nt=") {
                        nt = v.parse().map_err(|e| {
                            Error::Runtime(format!("manifest nt: {e}"))
                        })?;
                    }
                }
                continue;
            }
            if line.starts_with('#') {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 7 {
                return Err(Error::Runtime(format!(
                    "manifest line {}: want 7 tab-separated fields, got {}",
                    lineno + 1,
                    cols.len()
                )));
            }
            let pu = |i: usize| -> Result<usize> {
                cols[i]
                    .parse()
                    .map_err(|e| Error::Runtime(format!("manifest line {}: {e}", lineno + 1)))
            };
            artifacts.push(ArtifactMeta {
                name: cols[0].to_string(),
                file: cols[1].to_string(),
                kind: cols[2].to_string(),
                sb: pu(3)?,
                nloc: pu(4)?,
                s: pu(5)?,
                b: pu(6)?,
            });
        }
        if dtype.is_empty() {
            return Err(Error::Runtime("manifest missing #meta dtype line".into()));
        }
        Ok(Manifest {
            dtype,
            nt,
            artifacts,
        })
    }
}

struct Loaded {
    exe: xla::PjRtLoadedExecutable,
}

/// Compiled-artifact cache + PJRT client.
pub struct XlaRuntime {
    dir: PathBuf,
    client: xla::PjRtClient,
    manifest: Manifest,
    /// (sb, nloc) → gram_resid_packed executable.
    gram: BTreeMap<(usize, usize), Loaded>,
    /// (sb, nloc) → alpha_update executable.
    alpha: BTreeMap<(usize, usize), Loaded>,
    /// (s, b) → inner_solve executable.
    inner: BTreeMap<(usize, usize), Loaded>,
    /// (s, b) → dual_inner_solve executable.
    dual_inner: BTreeMap<(usize, usize), Loaded>,
}

impl XlaRuntime {
    /// Load the manifest and compile every artifact on the CPU PJRT client.
    pub fn load(dir: &Path) -> Result<XlaRuntime> {
        let manifest_path = dir.join("manifest.tsv");
        let data = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {manifest_path:?} — run `make artifacts` first: {e}"
            ))
        })?;
        let manifest = Manifest::parse_tsv(&data)?;
        if manifest.dtype != "f64" {
            return Err(Error::Runtime(format!(
                "artifact dtype {} unsupported (want f64)",
                manifest.dtype
            )));
        }
        let client = xla::PjRtClient::cpu()?;
        let mut rt = XlaRuntime {
            dir: dir.to_path_buf(),
            client,
            manifest,
            gram: BTreeMap::new(),
            alpha: BTreeMap::new(),
            inner: BTreeMap::new(),
            dual_inner: BTreeMap::new(),
        };
        for meta in rt.manifest.artifacts.clone() {
            let exe = rt.compile(&meta.file)?;
            let loaded = Loaded { exe };
            match meta.kind.as_str() {
                "gram_resid_packed" => {
                    rt.gram.insert((meta.sb, meta.nloc), loaded);
                }
                "gram_resid" => {
                    // Pre-packed-artifact manifests are rejected loudly:
                    // the runtime's accumulation path assumes the packed
                    // triangle output layout.
                    return Err(Error::Runtime(
                        "artifact kind gram_resid is the obsolete full-matrix \
                         layout; regenerate with `make artifacts` (aot.py now \
                         emits gram_resid_packed)"
                            .into(),
                    ));
                }
                "alpha_update" => {
                    rt.alpha.insert((meta.sb, meta.nloc), loaded);
                }
                "inner_solve" => {
                    rt.inner.insert((meta.s, meta.b), loaded);
                }
                "dual_inner_solve" => {
                    rt.dual_inner.insert((meta.s, meta.b), loaded);
                }
                other => {
                    return Err(Error::Runtime(format!("unknown artifact kind {other:?}")));
                }
            }
        }
        Ok(rt)
    }

    fn compile(&self, file: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }

    /// Smallest gram artifact with `sb_art ≥ sb`; errors if none fits.
    fn pick_gram(&self, sb: usize) -> Result<(usize, usize)> {
        self.gram
            .keys()
            .find(|(s, _)| *s >= sb)
            .copied()
            .ok_or_else(|| {
                Error::Runtime(format!(
                    "no gram artifact with sb ≥ {sb} (have {:?}); extend aot.py GRAM_SHAPES",
                    self.gram.keys().collect::<Vec<_>>()
                ))
            })
    }

    fn pick_inner(&self, map_is_dual: bool, s: usize, b: usize) -> Result<(usize, usize)> {
        let map = if map_is_dual { &self.dual_inner } else { &self.inner };
        map.keys()
            .filter(|(sa, ba)| *sa >= s && *ba >= b)
            .min_by_key(|(sa, ba)| sa * ba)
            .copied()
            .ok_or_else(|| {
                Error::Runtime(format!(
                    "no inner-solve artifact covering (s={s}, b={b}); extend aot.py SOLVE_SHAPES"
                ))
            })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }
}

/// [`ComputeBackend`] implementation backed by the AOT artifacts.
///
/// One per rank (PJRT handles are not `Send`); ranks construct their own.
pub struct XlaBackend {
    rt: XlaRuntime,
    /// Dense row-gather scratch (sb × n_loc).
    rows: Vec<f64>,
    /// Executions performed (observability/tests).
    pub executions: u64,
}

impl XlaBackend {
    pub fn new(artifact_dir: &Path) -> Result<XlaBackend> {
        Ok(XlaBackend {
            rt: XlaRuntime::load(artifact_dir)?,
            rows: Vec::new(),
            executions: 0,
        })
    }

}

/// Execute a tuple-returning artifact and unwrap its outputs.
fn run_tuple(exe: &xla::PjRtLoadedExecutable, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
    let result = exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
    Ok(result.to_tuple()?)
}

impl ComputeBackend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn gram_resid(
        &mut self,
        a: &Matrix,
        idx: &[usize],
        z: &[f64],
        g: &mut [f64],
        r: &mut [f64],
    ) -> Result<()> {
        let sb = idx.len();
        let n_loc = a.cols();
        let (sb_art, nloc_art) = self.rt.pick_gram(sb)?;
        // Gather sampled rows densely once. The artifact emits G already
        // as the packed lower triangle of its sb_art × sb_art tile; the
        // packed row offsets are size-independent, so the logical
        // triangle is exactly the first packed_len(sb) words of the
        // artifact's — accumulation is one elementwise add, with no
        // fold-to-packed copy anywhere.
        self.rows.resize(sb * n_loc, 0.0);
        a.gather_rows(idx, &mut self.rows)?;
        debug_assert_eq!(g.len(), packed_len(sb));
        g.fill(0.0);
        r.fill(0.0);
        // Stream column chunks of the artifact width, zero-padding the tail.
        let mut y_chunk = vec![0.0; sb_art * nloc_art];
        let mut z_chunk = vec![0.0; nloc_art];
        let mut lo = 0;
        while lo < n_loc {
            let hi = (lo + nloc_art).min(n_loc);
            let w = hi - lo;
            y_chunk.fill(0.0);
            for j in 0..sb {
                y_chunk[j * nloc_art..j * nloc_art + w]
                    .copy_from_slice(&self.rows[j * n_loc + lo..j * n_loc + hi]);
            }
            z_chunk.fill(0.0);
            z_chunk[..w].copy_from_slice(&z[lo..hi]);
            let y_lit = xla::Literal::vec1(&y_chunk)
                .reshape(&[sb_art as i64, nloc_art as i64])?;
            let z_lit = xla::Literal::vec1(&z_chunk);
            self.executions += 1;
            let exe = &self
                .rt
                .gram
                .get(&(sb_art, nloc_art))
                .ok_or_else(|| {
                    Error::Xla(format!(
                        "missing AOT gram artifact for (sb={sb_art}, n_loc={nloc_art})"
                    ))
                })?
                .exe;
            let outs = run_tuple(exe, &[y_lit, z_lit])?;
            let gv = outs[0].to_vec::<f64>()?;
            let rv = outs[1].to_vec::<f64>()?;
            debug_assert_eq!(gv.len(), packed_len(sb_art));
            for (dst, &src) in g.iter_mut().zip(&gv[..packed_len(sb)]) {
                *dst += src;
            }
            for (dst, &src) in r.iter_mut().zip(&rv[..sb]) {
                *dst += src;
            }
            lo = hi;
        }
        Ok(())
    }

    #[allow(clippy::too_many_arguments)] // trait-contract signature
    fn ca_inner_solve(
        &mut self,
        s: usize,
        b: usize,
        g_raw: &[f64],
        r_raw: &[f64],
        w_blocks: &[f64],
        overlap: &[f64],
        lam: f64,
        inv_n: f64,
    ) -> Result<Vec<f64>> {
        let (sa, ba) = self.rt.pick_inner(false, s, b)?;
        let (g_p, r_p, ov_p) = pad_solve_inputs(s, b, sa, ba, g_raw, r_raw, overlap);
        let w_p = pad_blocks(s, b, sa, ba, w_blocks);
        let args = [
            xla::Literal::vec1(&g_p).reshape(&[(sa * ba) as i64, (sa * ba) as i64])?,
            xla::Literal::vec1(&r_p),
            xla::Literal::vec1(&w_p).reshape(&[sa as i64, ba as i64])?,
            xla::Literal::vec1(&ov_p).reshape(&[sa as i64, sa as i64, ba as i64, ba as i64])?,
            xla::Literal::from(lam),
            xla::Literal::from(inv_n),
        ];
        self.executions += 1;
        let inner = self.rt.inner.get(&(sa, ba)).ok_or_else(|| {
            Error::Xla(format!("missing AOT inner-solve artifact for (s={sa}, b={ba})"))
        })?;
        let outs = run_tuple(&inner.exe, &args)?;
        let d_p = outs[0].to_vec::<f64>()?;
        Ok(unpad_blocks(s, b, sa, ba, &d_p))
    }

    #[allow(clippy::too_many_arguments)] // trait-contract signature
    fn ca_dual_inner_solve(
        &mut self,
        s: usize,
        b: usize,
        g_raw: &[f64],
        r_raw: &[f64],
        a_blocks: &[f64],
        y_blocks: &[f64],
        overlap: &[f64],
        lam: f64,
        inv_n: f64,
    ) -> Result<Vec<f64>> {
        let (sa, ba) = self.rt.pick_inner(true, s, b)?;
        let (g_p, r_p, ov_p) = pad_solve_inputs(s, b, sa, ba, g_raw, r_raw, overlap);
        let a_p = pad_blocks(s, b, sa, ba, a_blocks);
        let y_p = pad_blocks(s, b, sa, ba, y_blocks);
        let args = [
            xla::Literal::vec1(&g_p).reshape(&[(sa * ba) as i64, (sa * ba) as i64])?,
            xla::Literal::vec1(&r_p),
            xla::Literal::vec1(&a_p).reshape(&[sa as i64, ba as i64])?,
            xla::Literal::vec1(&y_p).reshape(&[sa as i64, ba as i64])?,
            xla::Literal::vec1(&ov_p).reshape(&[sa as i64, sa as i64, ba as i64, ba as i64])?,
            xla::Literal::from(lam),
            xla::Literal::from(inv_n),
        ];
        self.executions += 1;
        let dual = self.rt.dual_inner.get(&(sa, ba)).ok_or_else(|| {
            Error::Xla(format!(
                "missing AOT dual-inner-solve artifact for (s={sa}, b={ba})"
            ))
        })?;
        let outs = run_tuple(&dual.exe, &args)?;
        let d_p = outs[0].to_vec::<f64>()?;
        Ok(unpad_blocks(s, b, sa, ba, &d_p))
    }

    fn alpha_update(
        &mut self,
        a: &Matrix,
        idx: &[usize],
        d: &[f64],
        acc: &mut [f64],
    ) -> Result<()> {
        let sb = idx.len();
        let n_loc = a.cols();
        let (sb_art, nloc_art) = self.rt.pick_gram(sb)?;
        if self.rt.alpha.get(&(sb_art, nloc_art)).is_none() {
            return Err(Error::Runtime(format!(
                "no alpha_update artifact for (sb={sb_art}, nloc={nloc_art})"
            )));
        }
        self.rows.resize(sb * n_loc, 0.0);
        a.gather_rows(idx, &mut self.rows)?;
        let mut y_chunk = vec![0.0; sb_art * nloc_art];
        let mut d_pad = vec![0.0; sb_art];
        d_pad[..sb].copy_from_slice(d);
        let d_lit = xla::Literal::vec1(&d_pad);
        let mut lo = 0;
        while lo < n_loc {
            let hi = (lo + nloc_art).min(n_loc);
            let w = hi - lo;
            y_chunk.fill(0.0);
            for j in 0..sb {
                y_chunk[j * nloc_art..j * nloc_art + w]
                    .copy_from_slice(&self.rows[j * n_loc + lo..j * n_loc + hi]);
            }
            let y_lit = xla::Literal::vec1(&y_chunk)
                .reshape(&[sb_art as i64, nloc_art as i64])?;
            self.executions += 1;
            let exe = &self
                .rt
                .alpha
                .get(&(sb_art, nloc_art))
                .ok_or_else(|| {
                    Error::Xla(format!(
                        "missing AOT alpha-update artifact for (sb={sb_art}, n_loc={nloc_art})"
                    ))
                })?
                .exe;
            let outs = run_tuple(exe, &[y_lit, d_lit.clone()])?;
            let av = outs[0].to_vec::<f64>()?;
            for (dst, &v) in acc[lo..hi].iter_mut().zip(&av[..w]) {
                *dst += v;
            }
            lo = hi;
        }
        Ok(())
    }
}

/// Zero-pad (G, r, overlap) from logical (s, b) to artifact (sa, ba).
/// `g` arrives as the packed lower triangle (the coordinator's wire
/// format) and is expanded straight into the padded artifact layout — the
/// only full-matrix copy lives here, on the artifact boundary.
fn pad_solve_inputs(
    s: usize,
    b: usize,
    sa: usize,
    ba: usize,
    g: &[f64],
    r: &[f64],
    ov: &[f64],
) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    let sba = sa * ba;
    debug_assert_eq!(g.len(), packed_len(s * b));
    let mut g_p = vec![0.0; sba * sba];
    let mut r_p = vec![0.0; sba];
    let mut ov_p = vec![0.0; sa * sa * ba * ba];
    let pos = |j: usize, i: usize| j * ba + i; // block j, offset i in padded
    for j in 0..s {
        for i in 0..b {
            r_p[pos(j, i)] = r[j * b + i];
            for t in 0..s {
                for c in 0..b {
                    g_p[pos(j, i) * sba + pos(t, c)] = g[pidx(j * b + i, t * b + c)];
                    ov_p[((j * sa + t) * ba + i) * ba + c] = ov[((j * s + t) * b + i) * b + c];
                }
            }
        }
    }
    (g_p, r_p, ov_p)
}

fn pad_blocks(s: usize, b: usize, sa: usize, ba: usize, blocks: &[f64]) -> Vec<f64> {
    let mut out = vec![0.0; sa * ba];
    for j in 0..s {
        out[j * ba..j * ba + b].copy_from_slice(&blocks[j * b..(j + 1) * b]);
    }
    out
}

fn unpad_blocks(s: usize, b: usize, _sa: usize, ba: usize, padded: &[f64]) -> Vec<f64> {
    let mut out = vec![0.0; s * b];
    for j in 0..s {
        out[j * b..(j + 1) * b].copy_from_slice(&padded[j * ba..j * ba + b]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_unpad_roundtrip() {
        let (s, b, sa, ba) = (2usize, 3usize, 4usize, 8usize);
        let blocks: Vec<f64> = (0..s * b).map(|i| i as f64).collect();
        let p = pad_blocks(s, b, sa, ba, &blocks);
        assert_eq!(p.len(), sa * ba);
        assert_eq!(p[0], 0.0);
        assert_eq!(p[ba], 3.0);
        let u = unpad_blocks(s, b, sa, ba, &p);
        assert_eq!(u, blocks);
    }

    #[test]
    fn pad_solve_inputs_places_gram_blocks() {
        let (s, b, sa, ba) = (2usize, 2usize, 2usize, 4usize);
        let sb = s * b;
        // Symmetric full G, packed to the wire format before padding.
        let mut g_full = vec![0.0; sb * sb];
        for i in 0..sb {
            for j in 0..=i {
                let v = (i * sb + j + 1) as f64;
                g_full[i * sb + j] = v;
                g_full[j * sb + i] = v;
            }
        }
        let mut g = vec![0.0; packed_len(sb)];
        crate::linalg::packed::pack_lower(&g_full, sb, &mut g);
        let r: Vec<f64> = (0..sb).map(|i| (i + 1) as f64).collect();
        let ov = vec![0.5; s * s * b * b];
        let (gp, rp, ovp) = pad_solve_inputs(s, b, sa, ba, &g, &r, &ov);
        let sba = sa * ba;
        // Every logical entry lands at its padded position, mirrored.
        for i in 0..sb {
            for j in 0..sb {
                let (bi, oi) = (i / b, i % b);
                let (bj, oj) = (j / b, j % b);
                assert_eq!(
                    gp[(bi * ba + oi) * sba + bj * ba + oj],
                    g_full[i * sb + j],
                    "({i},{j})"
                );
            }
        }
        // padded rows are zero
        assert_eq!(gp[2 * sba + 2], 0.0);
        assert_eq!(rp[ba], r[2]);
        // Overlap entry (j=0, t=1, i=1, c=0) at ((0·sa+1)·ba+1)·ba+0.
        assert_eq!(ovp[(ba + 1) * ba], 0.5);
    }
}
