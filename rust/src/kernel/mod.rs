//! Kernel ridge regression — the paper's §6 future-work extension:
//! "BCD and BDCD methods are especially important when applied to solving
//! the kernel ridge regression problem … The algorithms developed in this
//! work can also be applied to the kernelized regression problem."
//!
//! KRR solves `(K + λn·I) α = y` for the implicit kernel matrix
//! `K[i,j] = k(x_i, x_j)`. Block coordinate descent on the quadratic
//! `f(α) = ½·αᵀ(K+λnI)α − yᵀα` maintains the auxiliary `u = K·α`
//! (the kernel analogue of the paper's α = Xᵀw trick) and per iteration:
//!
//!   Δ = (K_II + λn·I_b)⁻¹ (y_I − u_I − λn·α_I),   α_I += Δ,  u += K_{:,I}·Δ
//!
//! The s-step unrolling is **identical in form to eq. (8)** — so the CA
//! inner solve of [`crate::gram::ComputeBackend`] is reused verbatim with
//! the substitution `(1/n) G_raw → K_sampled, λ → λn, w → α, r → y−u`.
//! Kernel rows are materialized on demand from the data (K is never
//! formed), which is exactly why the paper calls the coordinate methods
//! out for this problem: Krylov methods would need full `K·v` products.

use crate::error::{Error, Result};
use crate::gram::ComputeBackend;
use crate::linalg::packed::{packed_len, tri_row};
use crate::matrix::{DenseMatrix, Matrix};
use crate::metrics::{History, IterRecord};
use crate::sampling::{overlap_tensor_into, BlockSampler};

/// Kernel functions.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Kernel {
    /// `k(x, z) = xᵀz` — recovers linear ridge regression in dual form.
    Linear,
    /// `k(x, z) = exp(−γ‖x − z‖²)`.
    Rbf { gamma: f64 },
    /// `k(x, z) = (xᵀz + coef0)^degree`.
    Polynomial { degree: u32, coef0: f64 },
}

impl Kernel {
    #[inline]
    pub fn eval(&self, x: &[f64], z: &[f64]) -> f64 {
        match *self {
            Kernel::Linear => dotv(x, z),
            Kernel::Rbf { gamma } => {
                let mut d2 = 0.0;
                for (a, b) in x.iter().zip(z) {
                    d2 += (a - b) * (a - b);
                }
                (-gamma * d2).exp()
            }
            Kernel::Polynomial { degree, coef0 } => (dotv(x, z) + coef0).powi(degree as i32),
        }
    }
}

#[inline]
fn dotv(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// KRR solver options.
#[derive(Clone, Debug)]
pub struct KrrOpts {
    pub kernel: Kernel,
    pub lam: f64,
    pub b: usize,
    /// Loop-blocking factor (1 = classical block CD; >1 = CA unrolling).
    pub s: usize,
    pub iters: usize,
    pub seed: u64,
    pub record_every: usize,
}

/// Fitted KRR model.
#[derive(Clone, Debug)]
pub struct KrrModel {
    pub kernel: Kernel,
    pub alpha: Vec<f64>,
    /// Training points (d × n) retained for prediction.
    pub x_train: DenseMatrix,
    pub history: History,
}

impl KrrModel {
    /// Predict `f(x) = Σ_i α_i·k(x_i, x)` for each column of `x_test`.
    pub fn predict(&self, x_test: &Matrix) -> Result<Vec<f64>> {
        let d = self.x_train.rows();
        if x_test.rows() != d {
            return Err(Error::Shape("predict: feature dim mismatch".into()));
        }
        let xt_t = x_test.transpose(); // m × d (test points as rows)
        let train_t = self.x_train.transpose(); // n × d
        let m = x_test.cols();
        let n = self.x_train.cols();
        let mut out = vec![0.0; m];
        let mut test_row = vec![0.0; d];
        for (j, o) in out.iter_mut().enumerate() {
            xt_t.gather_rows(&[j], &mut test_row)?;
            let mut acc = 0.0;
            for i in 0..n {
                acc += self.alpha[i] * self.kernel.eval(train_t.row(i), &test_row);
            }
            *o = acc;
        }
        Ok(out)
    }
}

/// Materialize the sampled kernel block `K[idx, idx]` as the packed lower
/// triangle (`sb(sb+1)/2` entries, the layout
/// [`ComputeBackend::ca_inner_solve`] consumes) — one kernel evaluation
/// per symmetric pair.
fn sampled_kernel(
    kernel: Kernel,
    train_rows: &DenseMatrix, // n × d (points as rows)
    idx: &[usize],
    k_out: &mut [f64],
) {
    let sb = idx.len();
    debug_assert_eq!(k_out.len(), packed_len(sb));
    for j in 0..sb {
        let xj = train_rows.row(idx[j]);
        let base = tri_row(j);
        for (t, &it) in idx[..=j].iter().enumerate() {
            k_out[base + t] = kernel.eval(xj, train_rows.row(it));
        }
    }
}

/// Fit KRR with (CA-)block coordinate descent.
///
/// `x` is `d × n` (points as columns), `y` length n. Runs on one rank
/// (data replicated); the distributed variant follows the dual solver's
/// layout and is left where the paper left it — as the natural next step.
pub fn fit(x: &Matrix, y: &[f64], opts: &KrrOpts, backend: &mut dyn ComputeBackend) -> Result<KrrModel> {
    let n = x.cols();
    if y.len() != n {
        return Err(Error::Shape("krr: y length".into()));
    }
    if opts.b == 0 || opts.b > n || opts.s == 0 {
        return Err(Error::InvalidArg("krr: bad b or s".into()));
    }
    let (s, b) = (opts.s, opts.b);
    let sb = s * b;
    let lam_n = opts.lam * n as f64;

    // Dense n×d view of the training points (kernel rows need full points;
    // clone-scale data is small in n for the regimes KRR targets).
    let train_rows = match x.transpose() {
        Matrix::Dense(m) => m,
        Matrix::Csr(m) => m.to_dense(),
    };

    let mut alpha = vec![0.0; n];
    let mut u = vec![0.0; n]; // u = K·α
    let mut history = History::default();

    let mut k_block = vec![0.0; packed_len(sb)];
    let mut overlap = vec![0.0; s * s * b * b];
    let mut r_base = vec![0.0; sb];
    let mut a_blocks = vec![0.0; sb];
    let mut sampler = BlockSampler::new(n, opts.seed);

    record_krr(&mut history, 0, &alpha, &u, y, lam_n)?;

    let outer = opts.iters / s;
    for k in 0..outer {
        let blocks = sampler.draw_blocks(s, b);
        let flat: Vec<usize> = blocks.iter().flatten().copied().collect();
        sampled_kernel(opts.kernel, &train_rows, &flat, &mut k_block);
        overlap_tensor_into(&blocks, &mut overlap);
        for (slot, &i) in flat.iter().enumerate() {
            r_base[slot] = y[i] - u[i];
            a_blocks[slot] = alpha[i];
        }
        // Reuse the paper's primal inner solve verbatim:
        //   inv_n := 1, G_raw := K_sampled, λ := λn, w := α, r := y − u
        // ⇒ Δ_j = (K_jj + λn·I)⁻¹( −λn·α_j + (y−u)_j − Σ_t (λn·O + K_jt) Δ_t )
        let deltas =
            backend.ca_inner_solve(s, b, &k_block, &r_base, &a_blocks, &overlap, lam_n, 1.0)?;

        for (slot, &i) in flat.iter().enumerate() {
            alpha[i] += deltas[slot];
        }
        // u += K[:, flat]·δ — kernel evaluations of the sampled points
        // against every training point (the kernel analogue of Yᵀδ).
        for (slot, &i) in flat.iter().enumerate() {
            let dv = deltas[slot];
            if dv != 0.0 {
                let xi = train_rows.row(i);
                for (t, uv) in u.iter_mut().enumerate() {
                    *uv += dv * opts.kernel.eval(xi, train_rows.row(t));
                }
            }
        }

        let h_now = (k + 1) * s;
        history.iters = h_now;
        let re = opts.record_every.max(s);
        if (opts.record_every > 0 && h_now % ((re / s).max(1) * s) == 0) || k + 1 == outer {
            record_krr(&mut history, h_now, &alpha, &u, y, lam_n)?;
        }
    }

    Ok(KrrModel {
        kernel: opts.kernel,
        alpha,
        x_train: match x {
            Matrix::Dense(m) => m.clone(),
            Matrix::Csr(m) => m.to_dense(),
        },
        history,
    })
}

/// KRR objective residual ‖(K+λnI)α − y‖ tracked via the maintained u.
fn record_krr(
    history: &mut History,
    iter: usize,
    alpha: &[f64],
    u: &[f64],
    y: &[f64],
    lam_n: f64,
) -> Result<()> {
    let mut res_sq = 0.0;
    let mut y_sq = 0.0;
    for i in 0..y.len() {
        let g = u[i] + lam_n * alpha[i] - y[i];
        res_sq += g * g;
        y_sq += y[i] * y[i];
    }
    history.records.push(IterRecord {
        iter,
        obj_err: (res_sq / y_sq.max(1e-300)).sqrt(),
        sol_err: f64::NAN, // no closed-form reference tracked here
    });
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gram::NativeBackend;
    use crate::linalg::chol_solve;
    use crate::util::Rng64;

    fn toy(d: usize, n: usize, seed: u64) -> (Matrix, Vec<f64>) {
        let mut rng = Rng64::seed_from_u64(seed);
        let data: Vec<f64> = (0..d * n).map(|_| rng.gen_normal()).collect();
        let x = Matrix::Dense(DenseMatrix::from_vec(d, n, data));
        // Nonlinear target so RBF has something to fit.
        let xt = x.transpose();
        let xt = match &xt {
            Matrix::Dense(m) => m.clone(),
            _ => unreachable!(),
        };
        let y: Vec<f64> = (0..n)
            .map(|j| {
                let r = xt.row(j);
                (r[0] * 2.0).sin() + 0.5 * r.iter().map(|v| v * v).sum::<f64>().sqrt()
            })
            .collect();
        (x, y)
    }

    fn direct_alpha(kernel: Kernel, x: &Matrix, y: &[f64], lam: f64) -> Vec<f64> {
        let n = x.cols();
        let rows = match x.transpose() {
            Matrix::Dense(m) => m,
            _ => unreachable!(),
        };
        let mut k = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                k[i * n + j] = kernel.eval(rows.row(i), rows.row(j));
            }
            k[i * n + i] += lam * n as f64;
        }
        let mut a = y.to_vec();
        chol_solve(&k, n, &mut a).unwrap();
        a
    }

    #[test]
    fn krr_matches_direct_solve_rbf() {
        let (x, y) = toy(3, 40, 1);
        let kernel = Kernel::Rbf { gamma: 0.5 };
        let lam = 0.05;
        let expect = direct_alpha(kernel, &x, &y, lam);
        let opts = KrrOpts {
            kernel,
            lam,
            b: 5,
            s: 1,
            iters: 4000,
            seed: 2,
            record_every: 0,
        };
        let mut be = NativeBackend::new();
        let model = fit(&x, &y, &opts, &mut be).unwrap();
        let max_dev = model
            .alpha
            .iter()
            .zip(&expect)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        let scale = expect.iter().map(|v| v.abs()).fold(1e-12, f64::max);
        assert!(max_dev / scale < 1e-6, "dev {max_dev} scale {scale}");
    }

    #[test]
    fn ca_krr_equals_classical_krr() {
        // The CA unrolling applies to the kernel problem unchanged.
        let (x, y) = toy(4, 30, 7);
        let kernel = Kernel::Polynomial { degree: 2, coef0: 1.0 };
        let mk = |s: usize| KrrOpts {
            kernel,
            lam: 0.1,
            b: 3,
            s,
            iters: 60,
            seed: 5,
            record_every: 0,
        };
        let mut be = NativeBackend::new();
        let a1 = fit(&x, &y, &mk(1), &mut be).unwrap().alpha;
        let a5 = fit(&x, &y, &mk(5), &mut be).unwrap().alpha;
        for (p, q) in a1.iter().zip(&a5) {
            assert!((p - q).abs() < 1e-9, "{p} vs {q}");
        }
    }

    #[test]
    fn linear_kernel_krr_agrees_with_primal_ridge() {
        // Representer theorem: w = X·α with α from linear-kernel KRR must
        // equal the primal ridge solution.
        let (x, y) = toy(5, 35, 3);
        let lam = 0.2;
        let opts = KrrOpts {
            kernel: Kernel::Linear,
            lam,
            b: 5,
            s: 2,
            iters: 6000,
            seed: 4,
            record_every: 0,
        };
        let mut be = NativeBackend::new();
        let model = fit(&x, &y, &opts, &mut be).unwrap();
        let mut w_dual = vec![0.0; 5];
        x.matvec(&model.alpha, &mut w_dual).unwrap();
        // Primal: (XXᵀ/n + λI) w = Xy/n.
        let n = 35.0;
        let idx: Vec<usize> = (0..5).collect();
        let mut g = vec![0.0; 25];
        x.sampled_gram(&idx, &mut g).unwrap();
        for i in 0..5 {
            for j in 0..5 {
                g[i * 5 + j] /= n;
            }
            g[i * 5 + i] += lam;
        }
        let mut rhs = vec![0.0; 5];
        x.matvec(&y, &mut rhs).unwrap();
        for v in rhs.iter_mut() {
            *v /= n;
        }
        chol_solve(&g, 5, &mut rhs).unwrap();
        for (p, q) in w_dual.iter().zip(&rhs) {
            assert!((p - q).abs() < 1e-6, "representer: {p} vs {q}");
        }
    }

    #[test]
    fn rbf_prediction_fits_training_data() {
        let (x, y) = toy(2, 50, 9);
        let opts = KrrOpts {
            kernel: Kernel::Rbf { gamma: 1.0 },
            lam: 1e-4,
            b: 10,
            s: 2,
            iters: 3000,
            seed: 6,
            record_every: 500,
        };
        let mut be = NativeBackend::new();
        let model = fit(&x, &y, &opts, &mut be).unwrap();
        let preds = model.predict(&x).unwrap();
        let mse: f64 =
            preds.iter().zip(&y).map(|(p, t)| (p - t) * (p - t)).sum::<f64>() / 50.0;
        assert!(mse < 1e-2, "training MSE {mse}");
        // Residual history decreases.
        let recs = &model.history.records;
        assert!(recs.last().unwrap().obj_err < recs.first().unwrap().obj_err * 1e-2);
    }
}
