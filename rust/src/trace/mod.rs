#![warn(missing_docs)]
//! Per-rank structured span tracing — the instrument behind the paper's
//! time claim.
//!
//! [`CostMeter`](crate::comm::CostMeter) *counts* communication; this
//! module *times* it. Each rank owns a [`Tracer`] with a preallocated
//! ring buffer of [`Span`] events (steady-state zero-alloc, same pool
//! discipline as `comm/` — guarded by the [`Tracer::trace_allocs`]
//! tripwire), installed thread-locally so the engine, the solvers, and
//! the communicator all record through the
//! [`CaStep`](crate::engine::CaStep) contract without per-solver
//! duplication.
//!
//! # Span taxonomy
//!
//! | kind              | where                 | covers                             |
//! |-------------------|-----------------------|------------------------------------|
//! | `Sample`          | `engine::drive`       | block sampling (`BlockSampler`)    |
//! | `GramLocal`       | `engine::drive`       | local Gram / `[G\|r]` payload      |
//! | `CollectiveStart` | `comm/*`              | blocking entry marker or `i*_start`|
//! | `CollectiveWait`  | `comm/*`              | blocking protocol or `i*_wait`     |
//! | `InnerSolve`      | `engine::solve_apply` | replicated s-step inner solve      |
//! | `ProxStep`        | `prox/*` (nested)     | the backend prox kernel call       |
//! | `Apply`           | `engine::solve_apply` | iterate update / `alpha_update`    |
//! | `Record`          | `engine::drive`       | convergence records (meter-excl.)  |
//! | `Retry`           | `comm/chaos.rs`       | transient-fault retry + backoff    |
//!
//! Collective spans carry an [`OpClass`] discriminant (allreduce vs
//! all-to-all vs barrier) so the analysis pass can cross-validate span
//! counts against `CostMeter.allreduces` / `all_to_alls` *exactly* — a
//! correctness gate, not just telemetry (see [`cross_check`]).
//!
//! # Observer neutrality
//!
//! Tracing never touches the communicator pool, never communicates, and
//! never reads or writes a `CostMeter`: trajectories, records, and meter
//! counts with tracing enabled are bitwise-equal to tracing disabled
//! (enforced by `rust/tests/trace.rs` over the pinned
//! `engine_equivalence` configs). Metric traffic that
//! [`metered_out`](crate::solvers::common::metered_out) excludes from
//! the meters is likewise excluded from the trace via [`pause`], so the
//! span/meter count gate holds by construction.
//!
//! # Analysis & export
//!
//! [`analysis::TraceSummary`] derives overlap efficiency (how much of
//! each in-flight collective window is covered by Gram prefetch),
//! per-rank compute/wire/idle breakdown, and per-kind histograms;
//! [`export::chrome_trace_json`] emits Perfetto-loadable Chrome
//! trace-event JSON (one track per rank), wired to `--trace <path>` /
//! `trace =` in the driver.

pub mod analysis;
pub mod export;

pub use analysis::{cross_check, OverlapStat, RankBreakdown, TraceSummary};
pub use export::{chrome_trace_json, summary_json};

use std::cell::{Cell, RefCell};
use std::sync::OnceLock;
use std::time::Instant;

/// Default per-rank ring capacity: comfortably above the span volume of
/// every in-repo run (6 spans/outer × H outers + records), small enough
/// (~3 MiB of `Span`s) to preallocate per rank without thought.
pub const DEFAULT_SPAN_CAPACITY: usize = 1 << 16;

/// What a span measures. See the module-level taxonomy table.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SpanKind {
    /// Block sampling (`BlockSampler`) in `engine::drive`.
    Sample,
    /// Local Gram / payload assembly.
    GramLocal,
    /// Blocking-collective entry marker or `i*_start`.
    CollectiveStart,
    /// Blocking protocol body or `i*_wait`.
    CollectiveWait,
    /// Replicated s-step inner solve.
    InnerSolve,
    /// Iterate update / `alpha_update`.
    Apply,
    /// Backend prox kernel call (nested inside `InnerSolve`).
    ProxStep,
    /// Convergence record (meter-excluded traffic).
    Record,
    /// Transient-fault retry taken by a fault-injecting communicator
    /// decorator ([`crate::comm::ChaosComm`]) before the delegated
    /// collective ran — covers the backoff sleep. Absent from fault-free
    /// traces.
    Retry,
}

impl SpanKind {
    /// All kinds, in fixed display order (histogram / JSON ordering).
    pub const ALL: [SpanKind; 9] = [
        SpanKind::Sample,
        SpanKind::GramLocal,
        SpanKind::CollectiveStart,
        SpanKind::CollectiveWait,
        SpanKind::InnerSolve,
        SpanKind::Apply,
        SpanKind::ProxStep,
        SpanKind::Record,
        SpanKind::Retry,
    ];

    /// Stable display name (histogram / JSON key).
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Sample => "Sample",
            SpanKind::GramLocal => "GramLocal",
            SpanKind::CollectiveStart => "CollectiveStart",
            SpanKind::CollectiveWait => "CollectiveWait",
            SpanKind::InnerSolve => "InnerSolve",
            SpanKind::Apply => "Apply",
            SpanKind::ProxStep => "ProxStep",
            SpanKind::Record => "Record",
            SpanKind::Retry => "Retry",
        }
    }
}

/// Which collective family a `CollectiveStart`/`CollectiveWait` span
/// belongs to (`Compute` for everything else). The analysis pass pairs
/// starts with waits FIFO **per class per rank** — all in-repo schedules
/// issue and wait collectives in order within a class, with at most one
/// outstanding allreduce and one outstanding all-to-all (bcdrow).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpClass {
    /// Non-collective span.
    Compute,
    /// Allreduce-family collective.
    Allreduce,
    /// All-to-all-family collective.
    AllToAll,
    /// Barrier collective.
    Barrier,
}

impl OpClass {
    /// Stable display name used by exporters.
    pub fn name(self) -> &'static str {
        match self {
            OpClass::Compute => "compute",
            OpClass::Allreduce => "allreduce",
            OpClass::AllToAll => "all_to_all",
            OpClass::Barrier => "barrier",
        }
    }
}

/// One traced event. Timestamps are nanoseconds since the process-wide
/// trace epoch (first clock read), so spans from different rank threads
/// share a timeline.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    /// What the span measures.
    pub kind: SpanKind,
    /// Collective family (`Compute` for everything else).
    pub op: OpClass,
    /// Collective op tag (`ThreadComm` op sequence) or outer-iteration
    /// index for compute spans — diagnostic only; pairing is FIFO.
    pub tag: u64,
    /// Owning rank (tracer thread).
    pub rank: u32,
    /// Start timestamp, ns since trace epoch.
    pub t_start: u64,
    /// End timestamp, ns since trace epoch.
    pub t_end: u64,
    /// Payload words for collectives / payload length for compute spans.
    pub words: u64,
}

impl Span {
    /// Span duration in nanoseconds (saturating).
    pub fn dur_ns(&self) -> u64 {
        self.t_end.saturating_sub(self.t_start)
    }
}

/// Per-rank span recorder: a fixed-capacity ring buffer. Once the buffer
/// fills, the oldest span is overwritten and `dropped` counts the loss;
/// the backing `Vec` never reallocates after construction — any capacity
/// growth trips `trace_allocs` (the tracing analogue of the comm pool's
/// `buf_allocs`), which the bench gates at 0.
#[derive(Debug)]
pub struct Tracer {
    rank: u32,
    cap: usize,
    buf: Vec<Span>,
    /// Next overwrite position once `buf.len() == cap`.
    next: usize,
    dropped: u64,
    trace_allocs: u64,
}

impl Tracer {
    /// A ring-buffer tracer for `rank` retaining at most `capacity` spans.
    pub fn new(rank: usize, capacity: usize) -> Self {
        Tracer {
            rank: rank as u32,
            cap: capacity,
            buf: Vec::with_capacity(capacity),
            next: 0,
            dropped: 0,
            trace_allocs: 0,
        }
    }

    /// Rank this tracer records for.
    pub fn rank(&self) -> u32 {
        self.rank
    }

    /// Fixed ring capacity chosen at construction.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Spans lost to ring overwrite.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Steady-state allocation tripwire; 0 for any correctly sized run.
    pub fn trace_allocs(&self) -> u64 {
        self.trace_allocs
    }

    /// Number of retained spans.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when no spans are retained.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Retained spans in ring order (NOT chronological once wrapped —
    /// the analysis pass sorts by `t_start`).
    pub fn spans(&self) -> &[Span] {
        &self.buf
    }

    /// Serialize to a flat `f64` word blob for cross-process aggregation:
    /// every field travels as its raw bit pattern (`f64::from_bits`), so
    /// the round trip through the comm layer's `f64` payloads is exact —
    /// no precision cliff at 2⁵³ ns. Layout: 6 header words (rank, cap,
    /// next, dropped, trace_allocs, len) then [`Self::WORDS_PER_SPAN`]
    /// words per retained span, in ring order.
    pub fn to_words(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(6 + self.buf.len() * Self::WORDS_PER_SPAN);
        let w = |x: u64| f64::from_bits(x);
        out.push(w(self.rank as u64));
        out.push(w(self.cap as u64));
        out.push(w(self.next as u64));
        out.push(w(self.dropped));
        out.push(w(self.trace_allocs));
        out.push(w(self.buf.len() as u64));
        for s in &self.buf {
            let kind = SpanKind::ALL.iter().position(|k| *k == s.kind).unwrap_or(0);
            out.push(w(kind as u64));
            out.push(w(match s.op {
                OpClass::Compute => 0,
                OpClass::Allreduce => 1,
                OpClass::AllToAll => 2,
                OpClass::Barrier => 3,
            }));
            out.push(w(s.tag));
            out.push(w(s.rank as u64));
            out.push(w(s.t_start));
            out.push(w(s.t_end));
            out.push(w(s.words));
        }
        out
    }

    /// Reconstruct a tracer from [`Self::to_words`] output. `None` on a
    /// malformed blob (wrong length, unknown kind/op discriminant) — the
    /// caller converts that into a comm-layer error.
    pub fn from_words(words: &[f64]) -> Option<Tracer> {
        if words.len() < 6 {
            return None;
        }
        let u = |x: f64| x.to_bits();
        let rank = u(words[0]);
        let cap = u(words[1]) as usize;
        let next = u(words[2]) as usize;
        let dropped = u(words[3]);
        let trace_allocs = u(words[4]);
        let len = u(words[5]) as usize;
        if words.len() != 6 + len * Self::WORDS_PER_SPAN || len > cap || (cap > 0 && next >= cap) {
            return None;
        }
        let mut buf = Vec::with_capacity(cap);
        for chunk in words[6..].chunks_exact(Self::WORDS_PER_SPAN) {
            let kind = *SpanKind::ALL.get(u(chunk[0]) as usize)?;
            let op = match u(chunk[1]) {
                0 => OpClass::Compute,
                1 => OpClass::Allreduce,
                2 => OpClass::AllToAll,
                3 => OpClass::Barrier,
                _ => return None,
            };
            buf.push(Span {
                kind,
                op,
                tag: u(chunk[2]),
                rank: u(chunk[3]) as u32,
                t_start: u(chunk[4]),
                t_end: u(chunk[5]),
                words: u(chunk[6]),
            });
        }
        Some(Tracer {
            rank: rank as u32,
            cap,
            buf,
            next,
            dropped,
            trace_allocs,
        })
    }

    /// Words per span in the [`Self::to_words`] encoding.
    pub const WORDS_PER_SPAN: usize = 7;

    /// Append a span, overwriting the oldest once the ring is full.
    pub fn push(&mut self, span: Span) {
        let cap_before = self.buf.capacity();
        if self.buf.len() < self.cap {
            self.buf.push(span);
        } else if self.cap > 0 {
            self.buf[self.next] = span;
            self.next = (self.next + 1) % self.cap;
            self.dropped += 1;
        } else {
            self.dropped += 1;
        }
        if self.buf.capacity() != cap_before {
            self.trace_allocs += 1;
        }
    }
}

thread_local! {
    static TRACER: RefCell<Option<Tracer>> = const { RefCell::new(None) };
    static ACTIVE: Cell<bool> = const { Cell::new(false) };
    static PAUSE_DEPTH: Cell<u32> = const { Cell::new(0) };
}

static EPOCH: OnceLock<Instant> = OnceLock::new();

fn clock_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// Install a tracer on the current thread (one per rank thread; the
/// driver installs inside the `run_spmd` closure). Replaces and returns
/// any previously installed tracer.
pub fn install(tracer: Tracer) -> Option<Tracer> {
    ACTIVE.with(|a| a.set(true));
    TRACER.with(|t| t.borrow_mut().replace(tracer))
}

/// Remove and return the current thread's tracer.
pub fn take() -> Option<Tracer> {
    ACTIVE.with(|a| a.set(false));
    TRACER.with(|t| t.borrow_mut().take())
}

/// True when spans are being recorded on this thread (installed and not
/// inside a [`pause`] scope). All record paths are no-ops otherwise, so
/// instrumented code pays two thread-local reads when tracing is off.
pub fn enabled() -> bool {
    ACTIVE.with(|a| a.get()) && PAUSE_DEPTH.with(|p| p.get()) == 0
}

/// Timestamp for an upcoming [`record`] call; 0 (and no clock read) when
/// tracing is disabled.
pub fn now() -> u64 {
    if enabled() {
        clock_ns()
    } else {
        0
    }
}

/// Record a span that started at `t_start` (from [`now`]) and ends now.
pub fn record(kind: SpanKind, op: OpClass, tag: u64, words: u64, t_start: u64) {
    if !enabled() {
        return;
    }
    let t_end = clock_ns();
    TRACER.with(|t| {
        if let Some(tr) = t.borrow_mut().as_mut() {
            let rank = tr.rank;
            tr.push(Span {
                kind,
                op,
                tag,
                rank,
                t_start,
                t_end,
                words,
            });
        }
    });
}

/// Record an instantaneous marker (e.g. the entry of a blocking
/// collective, so start counts match meter counts for both schedules).
pub fn mark(kind: SpanKind, op: OpClass, tag: u64, words: u64) {
    let t = now();
    record(kind, op, tag, words, t);
}

/// Suspends span recording on this thread until the guard drops. Used by
/// [`metered_out`](crate::solvers::common::metered_out) so diagnostic
/// traffic excluded from the meters is also excluded from the trace —
/// keeping the span/meter cross-check exact. Nests.
pub fn pause() -> PauseGuard {
    PAUSE_DEPTH.with(|p| p.set(p.get() + 1));
    PauseGuard
}

/// True while the current thread is inside a [`pause`] scope. The
/// schedule verifier ([`crate::analysis`]) uses this to tag diagnostic
/// collectives (record/`metered_out` traffic) in its symbolic event
/// streams, mirroring how the tracer excludes them from spans.
pub fn paused() -> bool {
    PAUSE_DEPTH.with(|p| p.get() > 0)
}

/// RAII guard returned by [`pause`]; recording resumes when it drops.
pub struct PauseGuard;

impl Drop for PauseGuard {
    fn drop(&mut self) {
        PAUSE_DEPTH.with(|p| p.set(p.get().saturating_sub(1)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(kind: SpanKind, t0: u64, t1: u64) -> Span {
        Span {
            kind,
            op: OpClass::Compute,
            tag: 0,
            rank: 0,
            t_start: t0,
            t_end: t1,
            words: 0,
        }
    }

    #[test]
    fn ring_wraps_without_allocating() {
        let mut tr = Tracer::new(0, 4);
        for i in 0..10u64 {
            tr.push(span(SpanKind::Sample, i, i + 1));
        }
        assert_eq!(tr.len(), 4, "ring retains exactly capacity spans");
        assert_eq!(tr.dropped(), 6);
        assert_eq!(tr.trace_allocs(), 0, "wrap must overwrite in place");
        // The retained set is the newest 4 spans (6..10), in some ring order.
        let mut starts: Vec<u64> = tr.spans().iter().map(|s| s.t_start).collect();
        starts.sort_unstable();
        assert_eq!(starts, vec![6, 7, 8, 9]);
    }

    #[test]
    fn zero_capacity_drops_everything() {
        let mut tr = Tracer::new(0, 0);
        tr.push(span(SpanKind::Apply, 0, 1));
        assert_eq!(tr.len(), 0);
        assert_eq!(tr.dropped(), 1);
        assert_eq!(tr.trace_allocs(), 0);
    }

    #[test]
    fn word_codec_round_trips_bit_exactly() {
        let mut tr = Tracer::new(5, 4);
        // Wrap the ring and use a tag above 2⁵³ to prove the codec moves
        // bit patterns, not approximated floats.
        for i in 0..6u64 {
            tr.push(Span {
                kind: SpanKind::ALL[i as usize % SpanKind::ALL.len()],
                op: OpClass::Allreduce,
                tag: (1u64 << 60) + i,
                rank: 5,
                t_start: i * 10,
                t_end: i * 10 + 3,
                words: i,
            });
        }
        let words = tr.to_words();
        let back = Tracer::from_words(&words).expect("valid blob");
        assert_eq!(back.rank(), tr.rank());
        assert_eq!(back.capacity(), tr.capacity());
        assert_eq!(back.dropped(), tr.dropped());
        assert_eq!(back.trace_allocs(), tr.trace_allocs());
        assert_eq!(back.len(), tr.len());
        for (a, b) in tr.spans().iter().zip(back.spans()) {
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.op, b.op);
            assert_eq!(a.tag, b.tag);
            assert_eq!(a.rank, b.rank);
            assert_eq!(a.t_start, b.t_start);
            assert_eq!(a.t_end, b.t_end);
            assert_eq!(a.words, b.words);
        }
        // Continued pushes land where the ring left off.
        let mut back = back;
        back.push(Span {
            kind: SpanKind::Record,
            op: OpClass::Compute,
            tag: 0,
            rank: 5,
            t_start: 100,
            t_end: 101,
            words: 0,
        });
        assert_eq!(back.trace_allocs(), tr.trace_allocs(), "no realloc on resume");
        assert_eq!(back.dropped(), tr.dropped() + 1);
    }

    #[test]
    fn word_codec_rejects_malformed_blobs() {
        let tr = Tracer::new(1, 8);
        let mut words = tr.to_words();
        assert!(Tracer::from_words(&words).is_some());
        words.push(0.0); // trailing garbage breaks the length contract
        assert!(Tracer::from_words(&words).is_none());
        assert!(Tracer::from_words(&[]).is_none());
    }

    #[test]
    fn install_record_take_roundtrip() {
        assert!(!enabled());
        // Disabled: record is a no-op, now() skips the clock.
        record(SpanKind::Sample, OpClass::Compute, 0, 0, now());
        install(Tracer::new(3, 16));
        assert!(enabled());
        let t0 = now();
        record(SpanKind::InnerSolve, OpClass::Compute, 7, 42, t0);
        {
            let _g = pause();
            assert!(!enabled());
            record(SpanKind::Sample, OpClass::Compute, 0, 0, now());
            {
                let _g2 = pause();
                assert!(!enabled());
            }
            assert!(!enabled(), "pause must nest");
        }
        assert!(enabled());
        let tr = take().unwrap();
        assert!(!enabled());
        assert_eq!(tr.len(), 1, "paused spans must not be recorded");
        let s = tr.spans()[0];
        assert_eq!(s.kind, SpanKind::InnerSolve);
        assert_eq!(s.rank, 3);
        assert_eq!(s.tag, 7);
        assert_eq!(s.words, 42);
        assert!(s.t_end >= s.t_start);
    }
}
