//! Post-run trace analysis: overlap efficiency, per-rank critical-path
//! breakdown, per-kind histograms, and the span/meter cross-check gate.

use super::{OpClass, Span, SpanKind, Tracer};
use crate::comm::CostMeter;

/// Aggregate duration statistics for one [`SpanKind`].
#[derive(Clone, Copy, Debug, Default)]
pub struct KindStat {
    /// Number of spans of this kind.
    pub count: u64,
    /// Summed duration in nanoseconds.
    pub total_ns: u64,
    /// Longest single span in nanoseconds.
    pub max_ns: u64,
}

impl KindStat {
    /// Mean span duration in nanoseconds (0 when empty).
    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total_ns as f64 / self.count as f64
        }
    }
}

/// Per-rank wall-clock decomposition. `compute_ns` sums the top-level
/// compute spans (`Sample`, `GramLocal`, `InnerSolve`, `Apply`,
/// `Record`; `ProxStep` is nested inside `InnerSolve` and deliberately
/// excluded to avoid double counting), `wire_ns` sums collective
/// start/wait spans, and `idle_ns` is the untraced remainder of the
/// rank's wall time (scheduler gaps, span overhead, hidden work).
#[derive(Clone, Copy, Debug, Default)]
pub struct RankBreakdown {
    /// Rank this breakdown describes.
    pub rank: u32,
    /// Wall-clock extent of the rank timeline (first start to last end).
    pub wall_ns: u64,
    /// Time inside compute-class spans.
    pub compute_ns: u64,
    /// Time inside collective (wire) spans.
    pub wire_ns: u64,
    /// Wall time covered by neither compute nor wire spans.
    pub idle_ns: u64,
}

/// Overlap accounting over FIFO-paired `CollectiveStart`/`CollectiveWait`
/// spans. For each pair the **in-flight window** is
/// `[start.t_end, wait.t_start]`; `covered_ns` is the `GramLocal` span
/// time falling inside such windows (the prefetch compute the pipeline
/// hid under the wire) and `exposed_ns` is the summed `CollectiveWait`
/// durations (the wire time nothing hid). Blocking schedules have empty
/// windows, so their efficiency is 0 by construction.
#[derive(Clone, Copy, Debug, Default)]
pub struct OverlapStat {
    /// Number of start/wait pairs that entered the statistic.
    pub pairs: u64,
    /// In-flight window time covered by local compute.
    pub covered_ns: u64,
    /// In-flight window time left exposed (rank idle in `wait`).
    pub exposed_ns: u64,
}

impl OverlapStat {
    /// `covered / (covered + exposed)` — the fraction of collective time
    /// the Gram-prefetch pipeline actually hid. 0 when nothing was
    /// covered (or no collectives ran).
    pub fn efficiency(&self) -> f64 {
        let denom = self.covered_ns + self.exposed_ns;
        if denom == 0 {
            0.0
        } else {
            self.covered_ns as f64 / denom as f64
        }
    }
}

/// The compact post-run summary: merged into the driver report JSON and
/// printed by `hotpath_micro`.
#[derive(Clone, Debug, Default)]
pub struct TraceSummary {
    /// Number of rank timelines summarized.
    pub ranks: usize,
    /// Total retained spans across ranks.
    pub spans: u64,
    /// Total ring-buffer overwrites across ranks.
    pub dropped: u64,
    /// Total tracer allocation-tripwire count across ranks.
    pub trace_allocs: u64,
    /// Indexed parallel to [`SpanKind::ALL`].
    pub per_kind: [KindStat; 9],
    /// Per-rank critical-path breakdowns, rank order.
    pub breakdown: Vec<RankBreakdown>,
    /// Overlap statistics per collective class.
    pub overlap: OverlapStat,
    /// `CollectiveStart` span counts per class, summed over ranks — the
    /// quantities the cross-check compares to the meters.
    pub allreduce_starts: u64,
    /// `CollectiveStart` spans of all-to-all class, for meter checks.
    pub all_to_all_starts: u64,
    /// Total `CollectiveWait` spans, for meter checks.
    pub collective_wait_spans: u64,
}

fn kind_index(kind: SpanKind) -> usize {
    SpanKind::ALL.iter().position(|&k| k == kind).unwrap()
}

fn sorted_spans(tracer: &Tracer) -> Vec<Span> {
    let mut v = tracer.spans().to_vec();
    v.sort_by_key(|s| (s.t_start, s.t_end));
    v
}

/// Clamped intersection length of `[a0,a1)` and `[b0,b1)`.
fn overlap_ns(a0: u64, a1: u64, b0: u64, b1: u64) -> u64 {
    let lo = a0.max(b0);
    let hi = a1.min(b1);
    hi.saturating_sub(lo)
}

/// FIFO-pair starts with waits per [`OpClass`] and accumulate the
/// overlap accounting for one rank's chronologically sorted spans.
fn rank_overlap(spans: &[Span]) -> OverlapStat {
    let mut stat = OverlapStat::default();
    let grams: Vec<&Span> = spans
        .iter()
        .filter(|s| s.kind == SpanKind::GramLocal)
        .collect();
    for class in [OpClass::Allreduce, OpClass::AllToAll] {
        let mut open: std::collections::VecDeque<&Span> = Default::default();
        for s in spans {
            if s.op != class {
                continue;
            }
            match s.kind {
                SpanKind::CollectiveStart => open.push_back(s),
                SpanKind::CollectiveWait => {
                    let Some(start) = open.pop_front() else {
                        continue; // unmatched wait (ring dropped the start)
                    };
                    stat.pairs += 1;
                    stat.exposed_ns += s.dur_ns();
                    let (w0, w1) = (start.t_end, s.t_start);
                    for g in &grams {
                        stat.covered_ns += overlap_ns(g.t_start, g.t_end, w0, w1);
                    }
                }
                _ => {}
            }
        }
    }
    stat
}

impl TraceSummary {
    /// Build a summary from per-rank tracers (sorts spans by start).
    pub fn from_tracers(tracers: &[Tracer]) -> Self {
        let mut sum = TraceSummary {
            ranks: tracers.len(),
            ..Default::default()
        };
        for tr in tracers {
            let spans = sorted_spans(tr);
            sum.spans += spans.len() as u64;
            sum.dropped += tr.dropped();
            sum.trace_allocs += tr.trace_allocs();
            let mut bd = RankBreakdown {
                rank: tr.rank(),
                ..Default::default()
            };
            for s in &spans {
                let st = &mut sum.per_kind[kind_index(s.kind)];
                st.count += 1;
                st.total_ns += s.dur_ns();
                st.max_ns = st.max_ns.max(s.dur_ns());
                match s.kind {
                    SpanKind::Sample
                    | SpanKind::GramLocal
                    | SpanKind::InnerSolve
                    | SpanKind::Apply
                    | SpanKind::Record => bd.compute_ns += s.dur_ns(),
                    SpanKind::CollectiveStart | SpanKind::CollectiveWait => {
                        bd.wire_ns += s.dur_ns();
                        match (s.kind, s.op) {
                            (SpanKind::CollectiveStart, OpClass::Allreduce) => {
                                sum.allreduce_starts += 1
                            }
                            (SpanKind::CollectiveStart, OpClass::AllToAll) => {
                                sum.all_to_all_starts += 1
                            }
                            (SpanKind::CollectiveWait, _) => sum.collective_wait_spans += 1,
                            _ => {}
                        }
                    }
                    SpanKind::ProxStep => {} // nested inside InnerSolve
                    // Backoff before a retried collective: time lost to
                    // the transport, not to compute.
                    SpanKind::Retry => bd.wire_ns += s.dur_ns(),
                }
            }
            if let (Some(first), Some(last)) = (spans.first(), spans.last()) {
                let t_end = spans.iter().map(|s| s.t_end).max().unwrap_or(last.t_end);
                bd.wall_ns = t_end.saturating_sub(first.t_start);
            }
            bd.idle_ns = bd.wall_ns.saturating_sub(bd.compute_ns + bd.wire_ns);
            let rank_stat = rank_overlap(&spans);
            sum.overlap.pairs += rank_stat.pairs;
            sum.overlap.covered_ns += rank_stat.covered_ns;
            sum.overlap.exposed_ns += rank_stat.exposed_ns;
            sum.breakdown.push(bd);
        }
        sum
    }

    /// Fraction of in-flight collective time hidden by compute, 0..=1.
    pub fn overlap_efficiency(&self) -> f64 {
        self.overlap.efficiency()
    }

    /// Histogram entry for one span kind.
    pub fn kind_stat(&self, kind: SpanKind) -> KindStat {
        self.per_kind[kind_index(kind)]
    }
}

/// The correctness gate: one rank's collective span counts must equal its
/// `CostMeter` exactly — every metered collective produced exactly one
/// `CollectiveStart`, and every deferred wait (`collective_waits`)
/// produced exactly one non-blocking `CollectiveWait`. Metric traffic is
/// excluded from both sides (`metered_out` in `solvers::common` pauses
/// the tracer), so any drift means an instrumentation seam is missing
/// or double-counting.
pub fn cross_check(tracer: &Tracer, meter: &CostMeter) -> Result<(), String> {
    if tracer.dropped() > 0 {
        return Err(format!(
            "rank {}: ring dropped {} spans — counts unusable; raise capacity",
            tracer.rank(),
            tracer.dropped()
        ));
    }
    let count = |kind: SpanKind, op: OpClass| -> u64 {
        tracer
            .spans()
            .iter()
            .filter(|s| s.kind == kind && s.op == op)
            .count() as u64
    };
    let checks = [
        (
            "allreduce starts",
            count(SpanKind::CollectiveStart, OpClass::Allreduce),
            meter.allreduces,
        ),
        (
            "all_to_all starts",
            count(SpanKind::CollectiveStart, OpClass::AllToAll),
            meter.all_to_alls,
        ),
        (
            "allreduce waits",
            count(SpanKind::CollectiveWait, OpClass::Allreduce),
            meter.allreduces,
        ),
        (
            "all_to_all waits",
            count(SpanKind::CollectiveWait, OpClass::AllToAll),
            meter.all_to_alls,
        ),
    ];
    for (what, got, want) in checks {
        if got != want {
            return Err(format!(
                "rank {}: {what}: {got} spans vs {want} metered",
                tracer.rank()
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sp(kind: SpanKind, op: OpClass, t0: u64, t1: u64) -> Span {
        Span {
            kind,
            op,
            tag: 0,
            rank: 0,
            t_start: t0,
            t_end: t1,
            words: 0,
        }
    }

    /// Hand-built prefetch timeline: start[10,11], gram[12,20] inside the
    /// window, wait[22,25]. covered = 8 (gram ∩ [11,22]), exposed = 3.
    #[test]
    fn overlap_efficiency_covers_prefetch_window() {
        let mut tr = Tracer::new(0, 16);
        tr.push(sp(SpanKind::CollectiveStart, OpClass::Allreduce, 10, 11));
        tr.push(sp(SpanKind::GramLocal, OpClass::Compute, 12, 20));
        tr.push(sp(SpanKind::CollectiveWait, OpClass::Allreduce, 22, 25));
        let sum = TraceSummary::from_tracers(&[tr]);
        assert_eq!(sum.overlap.pairs, 1);
        assert_eq!(sum.overlap.covered_ns, 8);
        assert_eq!(sum.overlap.exposed_ns, 3);
        let eff = sum.overlap_efficiency();
        assert!((eff - 8.0 / 11.0).abs() < 1e-12, "{eff}");
    }

    /// Blocking timeline: the start marker is instantaneous and the wait
    /// immediately follows — zero window, zero covered, efficiency 0.
    #[test]
    fn blocking_schedule_has_zero_efficiency() {
        let mut tr = Tracer::new(0, 16);
        tr.push(sp(SpanKind::GramLocal, OpClass::Compute, 0, 9));
        tr.push(sp(SpanKind::CollectiveStart, OpClass::Allreduce, 10, 10));
        tr.push(sp(SpanKind::CollectiveWait, OpClass::Allreduce, 10, 14));
        let sum = TraceSummary::from_tracers(&[tr]);
        assert_eq!(sum.overlap.covered_ns, 0);
        assert_eq!(sum.overlap.exposed_ns, 4);
        assert_eq!(sum.overlap_efficiency(), 0.0);
    }

    #[test]
    fn breakdown_splits_compute_wire_idle() {
        let mut tr = Tracer::new(2, 16);
        tr.push(sp(SpanKind::Sample, OpClass::Compute, 0, 5));
        tr.push(sp(SpanKind::InnerSolve, OpClass::Compute, 5, 15));
        tr.push(sp(SpanKind::ProxStep, OpClass::Compute, 6, 14)); // nested
        tr.push(sp(SpanKind::CollectiveWait, OpClass::Allreduce, 20, 30));
        let sum = TraceSummary::from_tracers(&[tr]);
        let bd = &sum.breakdown[0];
        assert_eq!(bd.rank, 2);
        assert_eq!(bd.wall_ns, 30);
        assert_eq!(bd.compute_ns, 15, "ProxStep must not double count");
        assert_eq!(bd.wire_ns, 10);
        assert_eq!(bd.idle_ns, 5);
        assert_eq!(sum.kind_stat(SpanKind::ProxStep).count, 1);
    }

    #[test]
    fn cross_check_counts_spans_against_meter() {
        let mut tr = Tracer::new(0, 16);
        tr.push(sp(SpanKind::CollectiveStart, OpClass::Allreduce, 0, 0));
        tr.push(sp(SpanKind::CollectiveWait, OpClass::Allreduce, 0, 1));
        let mut meter = CostMeter::default();
        meter.allreduces = 1;
        assert!(cross_check(&tr, &meter).is_ok());
        meter.allreduces = 2;
        let err = cross_check(&tr, &meter).unwrap_err();
        assert!(err.contains("allreduce starts"), "{err}");
    }
}
