//! Trace exporters: Chrome trace-event JSON (load at `ui.perfetto.dev`
//! or `chrome://tracing`) and the compact summary object merged into the
//! driver report.

use super::analysis::TraceSummary;
use super::{OpClass, SpanKind, Tracer};
use crate::util::json;

fn micros(ns: u64) -> f64 {
    ns as f64 / 1000.0
}

/// Emit the Chrome trace-event JSON object for a set of per-rank
/// tracers: one complete (`ph:"X"`) event per span on `pid 0`, one
/// thread track per rank (`tid = rank`, named via `thread_name`
/// metadata), `cat` = the span's [`OpClass`] so Perfetto can filter
/// compute vs wire.
pub fn chrome_trace_json(tracers: &[Tracer]) -> String {
    let mut events: Vec<String> = Vec::new();
    for tr in tracers {
        events.push(json::object(&[
            ("name", json::string("thread_name")),
            ("ph", json::string("M")),
            ("pid", json::num(0.0)),
            ("tid", json::num(tr.rank() as f64)),
            (
                "args",
                json::object(&[("name", json::string(&format!("rank {}", tr.rank())))]),
            ),
        ]));
    }
    for tr in tracers {
        let mut spans = tr.spans().to_vec();
        spans.sort_by_key(|s| (s.t_start, s.t_end));
        for s in spans {
            events.push(json::object(&[
                ("name", json::string(s.kind.name())),
                ("cat", json::string(s.op.name())),
                ("ph", json::string("X")),
                ("ts", json::num(micros(s.t_start))),
                ("dur", json::num(micros(s.dur_ns()))),
                ("pid", json::num(0.0)),
                ("tid", json::num(s.rank as f64)),
                (
                    "args",
                    json::object(&[
                        ("tag", json::num(s.tag as f64)),
                        ("words", json::num(s.words as f64)),
                    ]),
                ),
            ]));
        }
    }
    json::object(&[
        ("traceEvents", json::array(events)),
        ("displayTimeUnit", json::string("ms")),
    ])
}

/// The compact summary block: overlap efficiency, per-rank
/// compute/wire/idle, per-kind histograms, and the ring counters. Keys
/// are stable — `python/check_trace.py` and `BENCH_hotpath.json` consume
/// them.
pub fn summary_json(sum: &TraceSummary) -> String {
    let per_kind = json::object(
        &SpanKind::ALL
            .iter()
            .map(|&k| {
                let st = sum.kind_stat(k);
                (
                    k.name(),
                    json::object(&[
                        ("count", json::num(st.count as f64)),
                        ("total_ns", json::num(st.total_ns as f64)),
                        ("max_ns", json::num(st.max_ns as f64)),
                        ("mean_ns", json::num(st.mean_ns())),
                    ]),
                )
            })
            .collect::<Vec<_>>(),
    );
    let ranks = json::array(sum.breakdown.iter().map(|bd| {
        json::object(&[
            ("rank", json::num(bd.rank as f64)),
            ("wall_ns", json::num(bd.wall_ns as f64)),
            ("compute_ns", json::num(bd.compute_ns as f64)),
            ("wire_ns", json::num(bd.wire_ns as f64)),
            ("idle_ns", json::num(bd.idle_ns as f64)),
        ])
    }));
    json::object(&[
        ("spans", json::num(sum.spans as f64)),
        ("dropped", json::num(sum.dropped as f64)),
        ("trace_allocs", json::num(sum.trace_allocs as f64)),
        ("allreduce_starts", json::num(sum.allreduce_starts as f64)),
        ("all_to_all_starts", json::num(sum.all_to_all_starts as f64)),
        (
            "collective_wait_spans",
            json::num(sum.collective_wait_spans as f64),
        ),
        ("overlap_pairs", json::num(sum.overlap.pairs as f64)),
        ("overlap_covered_ns", json::num(sum.overlap.covered_ns as f64)),
        ("overlap_exposed_ns", json::num(sum.overlap.exposed_ns as f64)),
        ("overlap_efficiency", json::num(sum.overlap_efficiency())),
        ("per_kind", per_kind),
        ("ranks", ranks),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Span;

    #[test]
    fn chrome_trace_shape() {
        let mut tr = Tracer::new(1, 8);
        tr.push(Span {
            kind: SpanKind::GramLocal,
            op: OpClass::Compute,
            tag: 3,
            rank: 1,
            t_start: 1000,
            t_end: 2500,
            words: 20,
        });
        let out = chrome_trace_json(&[tr]);
        assert!(out.starts_with("{\"traceEvents\":["), "{out}");
        assert!(out.contains("\"name\":\"GramLocal\""));
        assert!(out.contains("\"ph\":\"X\""));
        assert!(out.contains("\"ts\":1"));
        assert!(out.contains("\"dur\":1.5"));
        assert!(out.contains("\"thread_name\""));
        assert!(out.contains("\"tid\":1"));
    }

    #[test]
    fn summary_has_stable_keys() {
        let sum = TraceSummary::from_tracers(&[Tracer::new(0, 4)]);
        let out = summary_json(&sum);
        for key in [
            "\"spans\"",
            "\"trace_allocs\"",
            "\"overlap_efficiency\"",
            "\"per_kind\"",
            "\"ranks\"",
            "\"GramLocal\"",
        ] {
            assert!(out.contains(key), "missing {key} in {out}");
        }
    }
}
