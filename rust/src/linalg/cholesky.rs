//! In-place Cholesky factorization and SPD solves for the `b×b`
//! subproblems (paper: "the subproblem is solved implicitly by first
//! constructing the Gram matrix and computing its Cholesky factorization").
//!
//! Mirrors `python/compile/model.py::cholesky_unrolled` — the Rust native
//! path and the AOT artifact must produce identical results (verified by
//! the backend-parity integration test).

use crate::error::{Error, Result};

/// Factor an SPD `b×b` row-major matrix in place into its lower-triangular
/// Cholesky factor `L` (upper triangle left untouched).
pub fn chol_factor(a: &mut [f64], b: usize) -> Result<()> {
    if a.len() != b * b {
        return Err(Error::Shape(format!("chol_factor: {} != {b}²", a.len())));
    }
    for k in 0..b {
        let mut akk = a[k * b + k];
        for t in 0..k {
            akk -= a[k * b + t] * a[k * b + t];
        }
        if akk <= 0.0 || !akk.is_finite() {
            return Err(Error::Linalg(format!(
                "matrix not SPD at pivot {k}: {akk}"
            )));
        }
        let lkk = akk.sqrt();
        a[k * b + k] = lkk;
        for i in (k + 1)..b {
            let mut v = a[i * b + k];
            for t in 0..k {
                v -= a[i * b + t] * a[k * b + t];
            }
            a[i * b + k] = v / lkk;
        }
    }
    Ok(())
}

/// Solve `L Lᵀ x = rhs` given the factored matrix; `rhs` is overwritten
/// with the solution.
pub fn chol_solve_factored(l: &[f64], b: usize, rhs: &mut [f64]) -> Result<()> {
    if l.len() != b * b || rhs.len() != b {
        return Err(Error::Shape("chol_solve_factored dims".into()));
    }
    // Forward: L y = rhs.
    for k in 0..b {
        let mut v = rhs[k];
        for t in 0..k {
            v -= l[k * b + t] * rhs[t];
        }
        rhs[k] = v / l[k * b + k];
    }
    // Backward: Lᵀ x = y.
    for k in (0..b).rev() {
        let mut v = rhs[k];
        for t in (k + 1)..b {
            v -= l[t * b + k] * rhs[t];
        }
        rhs[k] = v / l[k * b + k];
    }
    Ok(())
}

/// One-shot SPD solve: copies `a`, factors, solves. `rhs` overwritten.
pub fn chol_solve(a: &[f64], b: usize, rhs: &mut [f64]) -> Result<()> {
    let mut l = a.to_vec();
    chol_factor(&mut l, b)?;
    chol_solve_factored(&l, b, rhs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spd(b: usize, seed: u64) -> Vec<f64> {
        // A = M Mᵀ + 0.5 I
        let mut m = vec![0.0; b * b];
        let mut state = seed.wrapping_add(0x9E3779B97F4A7C15);
        for v in m.iter_mut() {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            *v = (state as f64 / u64::MAX as f64) - 0.5;
        }
        let mut a = vec![0.0; b * b];
        for i in 0..b {
            for j in 0..b {
                let mut s = 0.0;
                for k in 0..b {
                    s += m[i * b + k] * m[j * b + k];
                }
                a[i * b + j] = s + if i == j { 0.5 } else { 0.0 };
            }
        }
        a
    }

    #[test]
    fn solve_residual_small() {
        for b in [1usize, 2, 5, 16] {
            let a = spd(b, b as u64);
            let rhs: Vec<f64> = (0..b).map(|i| (i as f64).cos()).collect();
            let mut x = rhs.clone();
            chol_solve(&a, b, &mut x).unwrap();
            for i in 0..b {
                let mut s = 0.0;
                for j in 0..b {
                    s += a[i * b + j] * x[j];
                }
                assert!((s - rhs[i]).abs() < 1e-9, "b={b} i={i}: {s} vs {}", rhs[i]);
            }
        }
    }

    #[test]
    fn rejects_non_spd() {
        let a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        let mut rhs = vec![1.0, 1.0];
        assert!(chol_solve(&a, 2, &mut rhs).is_err());
    }

    #[test]
    fn factor_matches_known() {
        // A = [[4, 2], [2, 2]] → L = [[2, 0], [1, 1]]
        let mut a = vec![4.0, 2.0, 2.0, 2.0];
        chol_factor(&mut a, 2).unwrap();
        assert!((a[0] - 2.0).abs() < 1e-15);
        assert!((a[2] - 1.0).abs() < 1e-15);
        assert!((a[3] - 1.0).abs() < 1e-15);
    }
}
