//! Small dense linear algebra: SPD Cholesky solves (the per-iteration
//! subproblem of every solver), symmetric eigenvalues (Gram condition
//! numbers, Figures 4i–l / 7i–l), packed lower-triangular symmetric
//! storage (the Gram hot path's native layout), and TSQR (the paper's
//! §2.1 direct baseline).

pub mod cholesky;
pub mod cond;
pub mod packed;
pub mod tsqr;

pub use cholesky::{chol_factor, chol_solve, chol_solve_factored};
pub use cond::{condition_number, symmetric_eigenvalues};
pub use packed::{pack_lower, packed_len, pidx, tri_row, unpack_symmetric};
pub use tsqr::{tsqr_solve_ls, Tsqr};
