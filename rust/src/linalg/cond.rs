//! Symmetric eigenvalues via cyclic Jacobi — used to report the Gram-matrix
//! condition-number statistics of Figures 4(i–l) and 7(i–l).
//!
//! The Gram matrices are at most `sb × sb` (a few hundred), where Jacobi is
//! plenty fast, unconditionally stable, and dependency-free.

/// Eigenvalues of a symmetric `n×n` row-major matrix, ascending.
pub fn symmetric_eigenvalues(a: &[f64], n: usize) -> Vec<f64> {
    assert_eq!(a.len(), n * n, "symmetric_eigenvalues: bad shape");
    let mut m = a.to_vec();
    // Cyclic Jacobi sweeps until off-diagonal mass is negligible.
    let max_sweeps = 64;
    for _ in 0..max_sweeps {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[i * n + j] * m[i * n + j];
            }
        }
        let scale: f64 = m.iter().map(|v| v * v).sum::<f64>().max(1e-300);
        if off / scale < 1e-30 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[p * n + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[p * n + p];
                let aqq = m[q * n + q];
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Apply the rotation A ← Jᵀ A J on rows/cols p, q.
                for k in 0..n {
                    let akp = m[k * n + p];
                    let akq = m[k * n + q];
                    m[k * n + p] = c * akp - s * akq;
                    m[k * n + q] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = m[p * n + k];
                    let aqk = m[q * n + k];
                    m[p * n + k] = c * apk - s * aqk;
                    m[q * n + k] = s * apk + c * aqk;
                }
            }
        }
    }
    let mut eigs: Vec<f64> = (0..n).map(|i| m[i * n + i]).collect();
    eigs.sort_by(|x, y| x.partial_cmp(y).unwrap());
    eigs
}

/// 2-norm condition number `λ_max / λ_min` of a symmetric PSD matrix.
///
/// Returns `f64::INFINITY` for singular (λ_min ≤ 0) matrices.
/// Exact (Jacobi) for n ≤ 96; power + inverse-power estimate above that
/// (the Figures 4/7 Gram matrices reach sb = 3200, where an O(n³)-per-sweep
/// eigensolve per outer iteration is prohibitive).
pub fn condition_number(a: &[f64], n: usize) -> f64 {
    if n <= 96 {
        let eigs = symmetric_eigenvalues(a, n);
        let lo = eigs[0];
        let hi = eigs[n - 1];
        return if lo <= 0.0 { f64::INFINITY } else { hi / lo };
    }
    condition_number_est(a, n, 120)
}

/// Estimated condition number: power iteration for λ_max, Cholesky-based
/// inverse power iteration for λ_min. Deterministic start vectors.
pub fn condition_number_est(a: &[f64], n: usize, iters: usize) -> f64 {
    // λ_max by power iteration.
    let mut v: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64 * 0.7).sin()).collect();
    let mut u = vec![0.0; n];
    let mut lam_max = 0.0;
    for _ in 0..iters {
        matvec_sym(a, n, &v, &mut u);
        lam_max = norm(&u);
        if lam_max <= 0.0 {
            return f64::INFINITY;
        }
        for (vi, ui) in v.iter_mut().zip(&u) {
            *vi = ui / lam_max;
        }
    }
    // λ_min by inverse power iteration through one Cholesky factor.
    let mut l = a.to_vec();
    if crate::linalg::cholesky::chol_factor(&mut l, n).is_err() {
        return f64::INFINITY;
    }
    let mut w: Vec<f64> = (0..n).map(|i| 1.0 - (i as f64 * 0.3).cos()).collect();
    let nw = norm(&w);
    for x in w.iter_mut() {
        *x /= nw;
    }
    let mut growth = 0.0;
    for _ in 0..iters {
        // u = A⁻¹ w
        u.copy_from_slice(&w);
        if crate::linalg::cholesky::chol_solve_factored(&l, n, &mut u).is_err() {
            return f64::INFINITY;
        }
        growth = norm(&u);
        if growth <= 0.0 {
            return f64::INFINITY;
        }
        for (wi, ui) in w.iter_mut().zip(&u) {
            *wi = ui / growth;
        }
    }
    let lam_min = 1.0 / growth;
    lam_max / lam_min
}

#[inline]
fn matvec_sym(a: &[f64], n: usize, v: &[f64], out: &mut [f64]) {
    for i in 0..n {
        let row = &a[i * n..(i + 1) * n];
        let mut s = 0.0;
        for (rv, vv) in row.iter().zip(v) {
            s += rv * vv;
        }
        out[i] = s;
    }
}

#[inline]
fn norm(v: &[f64]) -> f64 {
    v.iter().map(|x| x * x).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diagonal_matrix() {
        let a = vec![3.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 2.0];
        let e = symmetric_eigenvalues(&a, 3);
        assert!((e[0] - 1.0).abs() < 1e-12);
        assert!((e[1] - 2.0).abs() < 1e-12);
        assert!((e[2] - 3.0).abs() < 1e-12);
        assert!((condition_number(&a, 3) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] → eigenvalues 1, 3
        let a = vec![2.0, 1.0, 1.0, 2.0];
        let e = symmetric_eigenvalues(&a, 2);
        assert!((e[0] - 1.0).abs() < 1e-12);
        assert!((e[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn trace_and_frobenius_preserved() {
        // random symmetric 8×8; eigenvalue sums must match invariants
        let n = 8;
        let mut a = vec![0.0; n * n];
        let mut state = 42u64;
        for i in 0..n {
            for j in i..n {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
                let v = ((state >> 33) as f64 / (1u64 << 31) as f64) - 0.5;
                a[i * n + j] = v;
                a[j * n + i] = v;
            }
        }
        let trace: f64 = (0..n).map(|i| a[i * n + i]).sum();
        let fro: f64 = a.iter().map(|v| v * v).sum();
        let e = symmetric_eigenvalues(&a, n);
        let etr: f64 = e.iter().sum();
        let efro: f64 = e.iter().map(|v| v * v).sum();
        assert!((trace - etr).abs() < 1e-9, "{trace} vs {etr}");
        assert!((fro - efro).abs() < 1e-9, "{fro} vs {efro}");
    }

    #[test]
    fn singular_is_infinite_cond() {
        let a = vec![1.0, 1.0, 1.0, 1.0];
        assert!(condition_number(&a, 2).is_infinite());
    }
}
