//! TSQR — communication-optimal tall-skinny QR (Demmel et al. [14]) and the
//! direct regularized-least-squares baseline built on it.
//!
//! The paper's §2.1 survey (Table 2, Figure 1) compares BCD/BDCD against a
//! single-reduction TSQR solve. We implement the real algorithm: local
//! Householder QR per row-block, then a binary reduction tree that QR-factors
//! stacked `R` pairs, carrying the implicitly-applied `Qᵀ rhs` along — one
//! pass over the data, `log₂ P` combine levels.
//!
//! Regularized LS is solved through the augmented system
//! `[Xᵀ/√n; √λ·I_d] w ≅ [y/√n; 0]`, whose normal equations are exactly
//! `(XXᵀ/n + λI) w = Xy/n` — but solved QR-stably.

use crate::error::{Error, Result};
use crate::matrix::{DenseMatrix, Matrix};

/// In-place Householder QR of a tall `m×k` row-major block; `rhs` (length m)
/// is overwritten by `Qᵀ rhs`. On return the upper triangle of the first
/// `k` rows holds `R`.
pub fn householder_qr(a: &mut [f64], m: usize, k: usize, rhs: &mut [f64]) -> Result<()> {
    if a.len() != m * k || rhs.len() != m {
        return Err(Error::Shape("householder_qr dims".into()));
    }
    if m < k {
        return Err(Error::InvalidArg(format!("householder_qr: m={m} < k={k}")));
    }
    let mut v = vec![0.0; m];
    for j in 0..k {
        // Build the Householder vector for column j (rows j..m).
        let mut norm = 0.0;
        for i in j..m {
            norm += a[i * k + j] * a[i * k + j];
        }
        norm = norm.sqrt();
        if norm == 0.0 {
            continue;
        }
        let ajj = a[j * k + j];
        let alpha = if ajj >= 0.0 { -norm } else { norm };
        let mut vnorm = 0.0;
        for i in j..m {
            let vi = if i == j { ajj - alpha } else { a[i * k + j] };
            v[i] = vi;
            vnorm += vi * vi;
        }
        if vnorm == 0.0 {
            continue;
        }
        // Apply H = I − 2vvᵀ/(vᵀv) to A[j.., j..] and rhs.
        for c in j..k {
            let mut dot = 0.0;
            for i in j..m {
                dot += v[i] * a[i * k + c];
            }
            let f = 2.0 * dot / vnorm;
            for i in j..m {
                a[i * k + c] -= f * v[i];
            }
        }
        let mut dot = 0.0;
        for i in j..m {
            dot += v[i] * rhs[i];
        }
        let f = 2.0 * dot / vnorm;
        for i in j..m {
            rhs[i] -= f * v[i];
        }
        a[j * k + j] = alpha;
        for i in (j + 1)..m {
            a[i * k + j] = 0.0;
        }
    }
    Ok(())
}

/// Back-substitution `R w = c` for upper-triangular `k×k` `R` stored in the
/// first `k` rows of a row-major block with row stride `k`.
pub fn back_substitute(r: &[f64], k: usize, c: &[f64]) -> Result<Vec<f64>> {
    let mut w = vec![0.0; k];
    for i in (0..k).rev() {
        let mut s = c[i];
        for j in (i + 1)..k {
            s -= r[i * k + j] * w[j];
        }
        let d = r[i * k + i];
        if d.abs() < 1e-300 {
            return Err(Error::Linalg(format!("singular R at {i}")));
        }
        w[i] = s / d;
    }
    Ok(w)
}

/// One `(R, c)` pair — the reduced state of a row block.
#[derive(Clone, Debug)]
pub struct RFactor {
    pub k: usize,
    /// `k×k` upper-triangular, row-major.
    pub r: Vec<f64>,
    /// First `k` entries of `Qᵀ rhs`.
    pub c: Vec<f64>,
}

/// TSQR over P row-blocks: local QR per block, then binary-tree combines.
pub struct Tsqr {
    pub k: usize,
    /// Number of tree combine levels executed by the last `solve` (== the
    /// single-allreduce latency count reported in Fig. 1c / Table 2).
    pub combine_levels: usize,
}

impl Tsqr {
    pub fn new(k: usize) -> Self {
        Tsqr {
            k,
            combine_levels: 0,
        }
    }

    /// Reduce one local row block to its `(R, c)` factor.
    pub fn local_factor(&self, block: &[f64], m: usize, rhs: &[f64]) -> Result<RFactor> {
        let k = self.k;
        // Pad blocks shorter than k with zero rows (QR needs m ≥ k).
        let mp = m.max(k);
        let mut a = vec![0.0; mp * k];
        a[..m * k].copy_from_slice(block);
        let mut c = vec![0.0; mp];
        c[..m].copy_from_slice(rhs);
        householder_qr(&mut a, mp, k, &mut c)?;
        Ok(RFactor {
            k,
            r: a[..k * k].to_vec(),
            c: c[..k].to_vec(),
        })
    }

    /// Combine two `(R, c)` factors by QR of the `2k×k` stack.
    pub fn combine(&self, top: &RFactor, bot: &RFactor) -> Result<RFactor> {
        let k = self.k;
        let mut a = vec![0.0; 2 * k * k];
        a[..k * k].copy_from_slice(&top.r);
        a[k * k..].copy_from_slice(&bot.r);
        let mut c = vec![0.0; 2 * k];
        c[..k].copy_from_slice(&top.c);
        c[k..].copy_from_slice(&bot.c);
        householder_qr(&mut a, 2 * k, k, &mut c)?;
        Ok(RFactor {
            k,
            r: a[..k * k].to_vec(),
            c: c[..k].to_vec(),
        })
    }

    /// Full tree solve over already-factored leaves.
    pub fn tree_solve(&mut self, mut leaves: Vec<RFactor>) -> Result<Vec<f64>> {
        if leaves.is_empty() {
            return Err(Error::InvalidArg("tsqr: no leaves".into()));
        }
        self.combine_levels = 0;
        while leaves.len() > 1 {
            self.combine_levels += 1;
            let mut next = Vec::with_capacity(leaves.len().div_ceil(2));
            let mut it = leaves.chunks(2);
            for pair in &mut it {
                if pair.len() == 2 {
                    next.push(self.combine(&pair[0], &pair[1])?);
                } else {
                    next.push(pair[0].clone());
                }
            }
            leaves = next;
        }
        let root = &leaves[0];
        back_substitute(&root.r, self.k, &root.c)
    }
}

/// Direct regularized LS solve:
/// `min_w λ/2‖w‖² + 1/(2n)‖Xᵀw − y‖²` via TSQR over row blocks of the
/// augmented matrix. Returns `(w, combine_levels)`.
///
/// Factors in the **smaller** dimension (the paper's Table-2 cost
/// `min(d,n)²·max(d,n)`):
/// * `d ≤ n` — QR of `[Xᵀ/√n; √λ·I_d]`, back-substitute for w directly;
/// * `d > n` — QR of `[X; √(nλ)·I_n]` whose R satisfies
///   `RᵀR = XᵀX + nλ·I`, then `w = X·u` with `u = (RᵀR)⁻¹ y`
///   (the identity `(XXᵀ + nλI)⁻¹X = X(XᵀX + nλI)⁻¹`).
///
/// `p_blocks` is clamped so every leaf block is tall (≥ k rows) — short
/// blocks would be zero-padded to k and inflate the leaf QR cost.
pub fn tsqr_solve_ls(x: &Matrix, y: &[f64], lam: f64, p_blocks: usize) -> Result<(Vec<f64>, usize)> {
    let d = x.rows();
    let n = x.cols();
    if y.len() != n {
        return Err(Error::Shape("tsqr_solve_ls: y length".into()));
    }
    if d <= n {
        tsqr_primal(x, y, lam, p_blocks)
    } else {
        tsqr_dual(x, y, lam, p_blocks)
    }
}

fn clamp_blocks(p_blocks: usize, rows: usize, k: usize) -> usize {
    p_blocks.max(1).min((rows / k.max(1)).max(1))
}

fn tsqr_primal(x: &Matrix, y: &[f64], lam: f64, p_blocks: usize) -> Result<(Vec<f64>, usize)> {
    let d = x.rows();
    let n = x.cols();
    let sn = (n as f64).sqrt();
    // Augmented rows: n rows of Xᵀ/√n with rhs y/√n, then d rows √λ·I, rhs 0.
    let xt = x.transpose(); // n × d; rows are data points
    let p_blocks = clamp_blocks(p_blocks, n, d);
    let mut tsqr = Tsqr::new(d);
    let mut leaves = Vec::with_capacity(p_blocks + 1);
    let per = n.div_ceil(p_blocks);
    let mut dense_rows = vec![0.0; per * d];
    for blk in 0..p_blocks {
        let lo = blk * per;
        let hi = ((blk + 1) * per).min(n);
        if lo >= hi {
            break;
        }
        let m = hi - lo;
        let idx: Vec<usize> = (lo..hi).collect();
        xt.gather_rows(&idx, &mut dense_rows[..m * d])?;
        for v in dense_rows[..m * d].iter_mut() {
            *v /= sn;
        }
        let rhs: Vec<f64> = y[lo..hi].iter().map(|v| v / sn).collect();
        leaves.push(tsqr.local_factor(&dense_rows[..m * d], m, &rhs)?);
    }
    // Regularization block √λ·I_d.
    if lam > 0.0 {
        let mut reg = DenseMatrix::zeros(d, d);
        let sl = lam.sqrt();
        for i in 0..d {
            reg.set(i, i, sl);
        }
        leaves.push(tsqr.local_factor(reg.data(), d, &vec![0.0; d])?);
    }
    let w = tsqr.tree_solve(leaves)?;
    Ok((w, tsqr.combine_levels))
}

fn tsqr_dual(x: &Matrix, y: &[f64], lam: f64, p_blocks: usize) -> Result<(Vec<f64>, usize)> {
    let d = x.rows();
    let n = x.cols();
    let nl = (n as f64) * lam;
    // QR of [X; √(nλ)·I_n] — (d+n) × n, rhs carried as zero (we only need R).
    let p_blocks = clamp_blocks(p_blocks, d, n);
    let mut tsqr = Tsqr::new(n);
    let mut leaves = Vec::with_capacity(p_blocks + 1);
    let per = d.div_ceil(p_blocks);
    let mut dense_rows = vec![0.0; per * n];
    for blk in 0..p_blocks {
        let lo = blk * per;
        let hi = ((blk + 1) * per).min(d);
        if lo >= hi {
            break;
        }
        let m = hi - lo;
        let idx: Vec<usize> = (lo..hi).collect();
        x.gather_rows(&idx, &mut dense_rows[..m * n])?;
        leaves.push(tsqr.local_factor(&dense_rows[..m * n], m, &vec![0.0; m])?);
    }
    if lam > 0.0 {
        let mut reg = DenseMatrix::zeros(n, n);
        let snl = nl.sqrt();
        for i in 0..n {
            reg.set(i, i, snl);
        }
        leaves.push(tsqr.local_factor(reg.data(), n, &vec![0.0; n])?);
    }
    // Reduce to the root R (rhs is unused on this path).
    let mut lv = leaves;
    tsqr.combine_levels = 0;
    while lv.len() > 1 {
        tsqr.combine_levels += 1;
        let mut next = Vec::with_capacity(lv.len().div_ceil(2));
        for pair in lv.chunks(2) {
            if pair.len() == 2 {
                next.push(tsqr.combine(&pair[0], &pair[1])?);
            } else {
                next.push(pair[0].clone());
            }
        }
        lv = next;
    }
    let r = &lv[0].r;
    // Solve RᵀR u = y: forward with Rᵀ (lower), back with R.
    let mut u = y.to_vec();
    for i in 0..n {
        let mut s = u[i];
        for j in 0..i {
            s -= r[j * n + i] * u[j];
        }
        let diag = r[i * n + i];
        if diag.abs() < 1e-300 {
            return Err(Error::Linalg(format!("tsqr_dual: singular R at {i}")));
        }
        u[i] = s / diag;
    }
    let mut u2 = back_substitute(r, n, &u)?;
    // w = X u.
    let mut w = vec![0.0; d];
    x.matvec(&u2, &mut w)?;
    u2.clear();
    Ok((w, tsqr.combine_levels))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::DenseMatrix;

    fn rngv(n: usize, seed: u64) -> Vec<f64> {
        let mut state = seed.wrapping_add(0x243F6A8885A308D3);
        (0..n)
            .map(|_| {
                state ^= state << 13;
                state ^= state >> 7;
                state ^= state << 17;
                (state as f64 / u64::MAX as f64) - 0.5
            })
            .collect()
    }

    #[test]
    fn qr_reproduces_least_squares() {
        // Overdetermined 20×4; compare against normal equations.
        let (m, k) = (20, 4);
        let a = rngv(m * k, 1);
        let b = rngv(m, 2);
        let mut aa = a.clone();
        let mut bb = b.clone();
        householder_qr(&mut aa, m, k, &mut bb).unwrap();
        let w = back_substitute(&aa, k, &bb).unwrap();
        // Normal equations residual: Aᵀ(Aw − b) = 0.
        for j in 0..k {
            let mut g = 0.0;
            for i in 0..m {
                let mut awi = 0.0;
                for t in 0..k {
                    awi += a[i * k + t] * w[t];
                }
                g += a[i * k + j] * (awi - b[i]);
            }
            assert!(g.abs() < 1e-10, "gradient {j}: {g}");
        }
    }

    #[test]
    fn tree_solve_independent_of_block_count() {
        let (m, k) = (64, 5);
        let a = rngv(m * k, 3);
        let b = rngv(m, 4);
        let mut sols = Vec::new();
        for p in [1usize, 2, 4, 8] {
            let mut tsqr = Tsqr::new(k);
            let per = m / p;
            let leaves: Vec<RFactor> = (0..p)
                .map(|i| {
                    tsqr.local_factor(
                        &a[i * per * k..(i + 1) * per * k],
                        per,
                        &b[i * per..(i + 1) * per],
                    )
                    .unwrap()
                })
                .collect();
            sols.push(tsqr.tree_solve(leaves).unwrap());
        }
        for s in &sols[1..] {
            for (x, y) in s.iter().zip(&sols[0]) {
                assert!((x - y).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn dual_path_matches_normal_equations() {
        // d > n: the [X; √(nλ)I] route. Verify (XXᵀ/n + λI)w = Xy/n.
        let (d, n) = (30, 8);
        let xd = DenseMatrix::from_vec(d, n, rngv(d * n, 21));
        let x = Matrix::Dense(xd);
        let y = rngv(n, 22);
        let lam = 0.2;
        let (w, _levels) = tsqr_solve_ls(&x, &y, lam, 4).unwrap();
        let mut xty = vec![0.0; d];
        x.matvec(&y, &mut xty).unwrap();
        let mut xtw = vec![0.0; n];
        x.matvec_t(&w, &mut xtw).unwrap();
        let mut xxw = vec![0.0; d];
        x.matvec(&xtw, &mut xxw).unwrap();
        for i in 0..d {
            let g = xxw[i] / n as f64 + lam * w[i] - xty[i] / n as f64;
            assert!(g.abs() < 1e-9, "i={i}: {g}");
        }
    }

    #[test]
    fn regularized_solve_matches_normal_equations() {
        // Small d: verify (XXᵀ/n + λI) w = Xy/n.
        let (d, n) = (6, 40);
        let xd = DenseMatrix::from_vec(d, n, rngv(d * n, 7));
        let x = Matrix::Dense(xd.clone());
        let y = rngv(n, 8);
        let lam = 0.3;
        let (w, levels) = tsqr_solve_ls(&x, &y, lam, 4).unwrap();
        assert!(levels >= 2); // 4 data blocks + 1 reg block → ≥2 levels
        // residual of normal equations
        let mut xty = vec![0.0; d];
        x.matvec(&y, &mut xty).unwrap();
        let mut xtw = vec![0.0; n];
        x.matvec_t(&w, &mut xtw).unwrap();
        let mut xxw = vec![0.0; d];
        x.matvec(&xtw, &mut xxw).unwrap();
        for i in 0..d {
            let g = xxw[i] / n as f64 + lam * w[i] - xty[i] / n as f64;
            assert!(g.abs() < 1e-10, "i={i}: {g}");
        }
    }
}
