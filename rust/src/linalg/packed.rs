//! Packed symmetric (lower-triangular) storage — the native format of the
//! Gram hot path.
//!
//! The sampled Gram `G = A[I,:]·A[I,:]ᵀ` is symmetric, so only its lower
//! triangle is stored: entry `(r, c)` with `r ≥ c` lives at
//! `r(r+1)/2 + c`, row-major within the triangle. An `sb × sb` Gram packs
//! into `sb(sb+1)/2` words instead of `sb²` — halving what the kernels
//! write, what the `[G|r]` allreduce moves over the wire, and what the
//! replicated inner solves index (they read the triangle directly; no
//! unpack copy exists on the solver hot path).

/// Number of stored entries of an `n × n` symmetric matrix.
#[inline]
pub const fn packed_len(n: usize) -> usize {
    n * (n + 1) / 2
}

/// Offset of row `r`'s first stored entry (its column 0).
#[inline]
pub const fn tri_row(r: usize) -> usize {
    r * (r + 1) / 2
}

/// Index of symmetric entry `(r, c)` in the packed lower triangle.
#[inline]
pub fn pidx(r: usize, c: usize) -> usize {
    if r >= c {
        tri_row(r) + c
    } else {
        tri_row(c) + r
    }
}

/// Mirror a packed lower triangle into a full row-major `n × n` buffer
/// (diagnostics and baseline paths only — the solvers never unpack).
pub fn unpack_symmetric(packed: &[f64], n: usize, full: &mut [f64]) {
    debug_assert_eq!(packed.len(), packed_len(n));
    debug_assert_eq!(full.len(), n * n);
    for r in 0..n {
        let row = &packed[tri_row(r)..tri_row(r) + r + 1];
        for (c, &v) in row.iter().enumerate() {
            full[r * n + c] = v;
            full[c * n + r] = v;
        }
    }
}

/// Pack the lower triangle of a full row-major `n × n` buffer.
pub fn pack_lower(full: &[f64], n: usize, packed: &mut [f64]) {
    debug_assert_eq!(packed.len(), packed_len(n));
    debug_assert_eq!(full.len(), n * n);
    for r in 0..n {
        for c in 0..=r {
            packed[tri_row(r) + c] = full[r * n + c];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lengths_and_offsets() {
        assert_eq!(packed_len(0), 0);
        assert_eq!(packed_len(1), 1);
        assert_eq!(packed_len(4), 10);
        assert_eq!(tri_row(0), 0);
        assert_eq!(tri_row(3), 6);
    }

    #[test]
    fn pidx_is_symmetric_and_bijective_on_triangle() {
        let n = 7;
        let mut seen = vec![false; packed_len(n)];
        for r in 0..n {
            for c in 0..=r {
                let k = pidx(r, c);
                assert_eq!(k, pidx(c, r));
                assert!(!seen[k], "({r},{c}) collides");
                seen[k] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let n = 5;
        // Symmetric full matrix from an arbitrary seed pattern.
        let mut full = vec![0.0; n * n];
        for r in 0..n {
            for c in 0..=r {
                let v = (r * 31 + c * 7) as f64 * 0.25 - 3.0;
                full[r * n + c] = v;
                full[c * n + r] = v;
            }
        }
        let mut packed = vec![0.0; packed_len(n)];
        pack_lower(&full, n, &mut packed);
        let mut back = vec![0.0; n * n];
        unpack_symmetric(&packed, n, &mut back);
        assert_eq!(full, back);
    }
}
