//! Shared-seed block sampling.
//!
//! The paper avoids communicating the sampled coordinate indices by
//! "initializing all processors to the same seed for the random number
//! generator" (§3.1). Every rank constructs a [`BlockSampler`] from the same
//! seed and draws an identical sequence of blocks with **zero
//! communication**; this property is asserted by an SPMD integration test.
//!
//! A draw is `b` indices from `[dim]` uniformly **without replacement**
//! (partial Fisher–Yates). Consecutive draws are independent (replacement
//! across blocks), matching the paper's fully-randomized selection.

use crate::util::Rng64;

/// Deterministic sampler of coordinate blocks.
#[derive(Clone, Debug)]
pub struct BlockSampler {
    rng: Rng64,
    dim: usize,
    /// Scratch permutation buffer (identity, repaired after each draw).
    perm: Vec<u32>,
}

impl BlockSampler {
    pub fn new(dim: usize, seed: u64) -> Self {
        assert!(dim > 0, "sampler over empty dimension");
        assert!(dim <= u32::MAX as usize);
        BlockSampler {
            rng: Rng64::seed_from_u64(seed),
            dim,
            perm: (0..dim as u32).collect(),
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Snapshot the sampler's RNG state. Between draws the scratch
    /// permutation is identity (the swap log is undone after every
    /// [`BlockSampler::draw_block`]), so the four RNG words are the
    /// sampler's *entire* mutable state — restoring them with
    /// [`BlockSampler::set_rng_state`] replays the exact future draw
    /// sequence. This is what makes s-step checkpoints tiny.
    pub fn rng_state(&self) -> [u64; 4] {
        self.rng.state()
    }

    /// Restore a [`BlockSampler::rng_state`] snapshot (checkpoint resume).
    pub fn set_rng_state(&mut self, s: [u64; 4]) {
        self.rng = Rng64::from_state(s);
    }

    /// Draw `b ≤ dim` distinct indices (partial Fisher–Yates, O(b) per draw
    /// — the scratch permutation is restored by undoing the swap log, not
    /// rebuilt).
    pub fn draw_block(&mut self, b: usize) -> Vec<usize> {
        assert!(b <= self.dim, "block size {b} > dim {}", self.dim);
        let mut out = Vec::with_capacity(b);
        let mut swaps = Vec::with_capacity(b);
        for k in 0..b {
            let j = self.rng.gen_range(k, self.dim);
            self.perm.swap(k, j);
            swaps.push((k, j));
            out.push(self.perm[k] as usize);
        }
        // Undo in reverse: the scratch array is exactly identity again.
        for &(k, j) in swaps.iter().rev() {
            self.perm.swap(k, j);
        }
        out
    }

    /// Draw `s` consecutive blocks (the CA outer-iteration sample set).
    pub fn draw_blocks(&mut self, s: usize, b: usize) -> Vec<Vec<usize>> {
        (0..s).map(|_| self.draw_block(b)).collect()
    }
}

/// Block-overlap tensor `O[j][t] = I_jᵀ I_t` as dense `b×b` 0/1 blocks,
/// row-major within each block — the zero-communication cross term of
/// eq. (8)/(18).
pub fn overlap_tensor(blocks: &[Vec<usize>]) -> Vec<f64> {
    let s = blocks.len();
    let b = if s > 0 { blocks[0].len() } else { 0 };
    let mut out = vec![0.0; s * s * b * b];
    overlap_tensor_into(blocks, &mut out);
    out
}

/// In-place variant — the solvers hoist the buffer out of the iteration
/// loop (the tensor reaches s²b² = 10M entries in the Fig-4 news20 regime;
/// reallocating it per outer iteration dominated the inner solve).
pub fn overlap_tensor_into(blocks: &[Vec<usize>], out: &mut [f64]) {
    let s = blocks.len();
    let b = if s > 0 { blocks[0].len() } else { 0 };
    debug_assert_eq!(out.len(), s * s * b * b);
    out.fill(0.0);
    for j in 0..s {
        for t in 0..s {
            let base = (j * s + t) * b * b;
            for (r, &ij) in blocks[j].iter().enumerate() {
                for (c, &it) in blocks[t].iter().enumerate() {
                    if ij == it {
                        out[base + r * b + c] = 1.0;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn without_replacement_within_block() {
        let mut s = BlockSampler::new(50, 7);
        for _ in 0..200 {
            let blk = s.draw_block(10);
            let set: HashSet<usize> = blk.iter().copied().collect();
            assert_eq!(set.len(), 10, "duplicates in {blk:?}");
            assert!(blk.iter().all(|&i| i < 50));
        }
    }

    #[test]
    fn same_seed_same_stream() {
        let mut a = BlockSampler::new(100, 42);
        let mut b = BlockSampler::new(100, 42);
        for _ in 0..50 {
            assert_eq!(a.draw_block(8), b.draw_block(8));
        }
    }

    #[test]
    fn different_seed_differs() {
        let mut a = BlockSampler::new(1000, 1);
        let mut b = BlockSampler::new(1000, 2);
        let draws_a: Vec<_> = (0..5).map(|_| a.draw_block(4)).collect();
        let draws_b: Vec<_> = (0..5).map(|_| b.draw_block(4)).collect();
        assert_ne!(draws_a, draws_b);
    }

    #[test]
    fn full_block_is_permutation() {
        let mut s = BlockSampler::new(16, 3);
        let blk = s.draw_block(16);
        let mut sorted = blk.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..16).collect::<Vec<_>>());
        // And the sampler still works afterwards.
        let blk2 = s.draw_block(16);
        let mut sorted2 = blk2.clone();
        sorted2.sort_unstable();
        assert_eq!(sorted2, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn rng_state_roundtrip_replays_draws() {
        let mut a = BlockSampler::new(64, 5);
        a.draw_blocks(3, 4);
        let snap = a.rng_state();
        let future: Vec<_> = (0..20).map(|_| a.draw_block(6)).collect();
        let mut b = BlockSampler::new(64, 5);
        b.set_rng_state(snap);
        let replay: Vec<_> = (0..20).map(|_| b.draw_block(6)).collect();
        assert_eq!(future, replay, "sampler state is not just the RNG words");
    }

    #[test]
    fn draws_cover_dimension_eventually() {
        let mut s = BlockSampler::new(30, 9);
        let mut seen = HashSet::new();
        for _ in 0..100 {
            for i in s.draw_block(5) {
                seen.insert(i);
            }
        }
        assert_eq!(seen.len(), 30);
    }

    #[test]
    fn overlap_tensor_identity_on_diagonal() {
        let blocks = vec![vec![3, 1, 4], vec![1, 5, 9]];
        let ov = overlap_tensor(&blocks);
        let (s, b) = (2, 3);
        // diagonal blocks are identity
        for j in 0..s {
            for r in 0..b {
                for c in 0..b {
                    let v = ov[(j * s + j) * b * b + r * b + c];
                    assert_eq!(v, if r == c { 1.0 } else { 0.0 });
                }
            }
        }
        // cross block: blocks[0][1] == blocks[1][0] == 1
        assert_eq!(ov[(0 * s + 1) * b * b + 1 * b + 0], 1.0);
        assert_eq!(ov[(1 * s + 0) * b * b + 0 * b + 1], 1.0);
        let total: f64 = ov.iter().sum();
        assert_eq!(total, 2.0 * 3.0 + 2.0); // two identities + one shared index (both directions)
    }
}
