//! Experiment configuration (the launcher's input format).
//!
//! INI-style files parsed by [`crate::util::ini`] (the toml crate is not in
//! the offline vendor set; the format is a strict TOML subset for flat
//! sections). Example (`configs/quickstart.ini`):
//!
//! ```ini
//! [dataset]
//! kind = synthetic        # or libsvm
//! name = abalone          # Table-3 clone name (synthetic) …
//! # path = data/a9a       # … or a LIBSVM file (libsvm)
//! scale = 1               # divide both dimensions by this
//! seed = 42
//!
//! [solver]
//! method = cabcd          # bcd|cabcd|bdcd|cabdcd|bcdrow|cabcdrow|cocoa|cg
//! b = 8
//! s = 4
//! iters = 2000
//! # lam = 0.043           # default: 1000·σ_min from the spec
//! seed = 7
//! record_every = 50
//! track_gram_cond = false
//! overlap = false         # non-blocking overlap pipeline
//! reg = l2                # l2 | l1 | elastic | none (prox subsystem)
//! l1_ratio = 0.5          # elastic-net L1 fraction (reg = elastic only)
//! local_iters = 100       # local dual updates per round (cocoa only)
//!
//! [run]
//! ranks = 4
//! backend = native        # native | xla
//! transport = thread      # thread | process (one OS process per rank)
//! topology = flat         # flat | twolevel (hierarchical allreduce)
//! # node_size = 4         # ranks per node (topology = twolevel only)
//! artifact_dir = artifacts
//! # trace = run.trace.json  # per-rank span trace (Chrome trace-event JSON)
//! # telemetry = run.telemetry.json  # cluster health snapshots (+ .prom exposition)
//! # telemetry_z = 1.25      # straggler z-score threshold (default 1.25)
//! # comm_timeout_ms = 5000  # deadline per blocking receive (default: unbounded)
//! # checkpoint_every = 10   # snapshot state every k-th s-step block (0 = off)
//! # checkpoint_dir = ckpts  # default: <artifact_dir>/checkpoints
//! ```

use std::path::{Path, PathBuf};

use crate::engine::Method;
use crate::error::{Error, Result};
use crate::prox::Reg;
use crate::solvers::SolverOpts;
use crate::util::ini::{self, Section};

#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub dataset: DatasetConfig,
    pub solver: SolverConfig,
    pub run: RunConfig,
}

#[derive(Clone, Debug)]
pub struct DatasetConfig {
    /// "synthetic" (Table-3 clone generator) or "libsvm" (file on disk).
    pub kind: String,
    pub name: Option<String>,
    pub path: Option<PathBuf>,
    /// Divide d and n by this factor (synthetic only).
    pub scale: usize,
    pub seed: u64,
}

#[derive(Clone, Debug)]
pub struct SolverConfig {
    pub method: String,
    pub b: usize,
    pub s: usize,
    pub lam: Option<f64>,
    pub iters: usize,
    pub seed: u64,
    pub record_every: usize,
    pub track_gram_cond: bool,
    pub tol: Option<f64>,
    /// Overlap the Gram/residual reduction with next-iteration compute
    /// (non-blocking allreduce pipeline; bitwise-identical trajectory).
    pub overlap: bool,
    /// Regularizer: `l2` (exact ridge path, default), `l1`, `elastic`,
    /// or `none` — non-L2 routes bcd/bdcd through the CA-Prox solvers.
    pub reg: String,
    /// Elastic-net L1 fraction ∈ [0, 1] (`reg = elastic` only).
    pub l1_ratio: f64,
    /// Local dual updates per round (`method = cocoa` only).
    pub local_iters: usize,
}

#[derive(Clone, Debug)]
pub struct RunConfig {
    pub ranks: usize,
    pub backend: String,
    /// Rank-group transport: `thread` (default; one OS thread per rank,
    /// in-process channels) or `process` (one OS process per rank over
    /// loopback TCP — see [`crate::comm::process`]). Trajectories, cost
    /// meters, and certificates are bitwise-identical across the two.
    pub transport: String,
    /// Collective topology: `flat` (default; recursive doubling /
    /// Rabenseifner over all ranks) or `twolevel` (hierarchical
    /// allreduce — intra-node fan-in to a leader, flat reduction among
    /// leaders, fan-out; see `node_size`).
    pub topology: String,
    /// Ranks per node for `topology = twolevel` (ignored under `flat`).
    /// The transport clamps it to `[1, ranks]`.
    pub node_size: usize,
    pub artifact_dir: PathBuf,
    /// When set, install a per-rank span tracer ([`crate::trace`]) for the
    /// run and write the merged Chrome trace-event JSON here (loadable in
    /// Perfetto / `chrome://tracing`). Tracing is observer-neutral: the
    /// trajectory and cost meters are bitwise-identical with it on or off.
    pub trace: Option<PathBuf>,
    /// When set, install a per-rank telemetry registry
    /// ([`crate::telemetry`]) for the run and write the cluster health
    /// snapshots here as JSON, plus a Prometheus text exposition next to
    /// it (same path, `.prom` extension). Like tracing, telemetry is
    /// observer-neutral: trajectories and metered wire counts are
    /// bitwise-identical with it on or off.
    pub telemetry: Option<PathBuf>,
    /// Straggler z-score threshold for telemetry aggregation (default
    /// [`crate::telemetry::DEFAULT_Z_THRESHOLD`]). A rank whose per-class
    /// timing deviates from the fleet mean by at least this many
    /// population standard deviations (and by an absolute floor) is
    /// flagged in the snapshot.
    pub telemetry_z: Option<f64>,
    /// Deadline for every blocking receive (milliseconds). A peer that
    /// fails to deliver within the deadline counts a
    /// [`CostMeter::timeouts`](crate::comm::CostMeter) and poisons the
    /// group, so a dead or stalled rank surfaces as `Error::Comm` on every
    /// surviving rank instead of a hang. `None` = unbounded (the default).
    pub comm_timeout_ms: Option<u64>,
    /// Snapshot full solver state every k-th s-step block through a
    /// per-rank [`FileSink`](crate::engine::FileSink) (0 = off). Resuming
    /// is bitwise-exact; see `[crate::engine::checkpoint]` for what
    /// enabling this does to the prefetch schedule.
    pub checkpoint_every: usize,
    /// Directory for the per-rank checkpoint files; defaults to
    /// `<artifact_dir>/checkpoints` when checkpointing is on.
    pub checkpoint_dir: Option<PathBuf>,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            ranks: 1,
            backend: "native".into(),
            transport: "thread".into(),
            topology: "flat".into(),
            node_size: 1,
            artifact_dir: PathBuf::from("artifacts"),
            trace: None,
            telemetry: None,
            telemetry_z: None,
            comm_timeout_ms: None,
            checkpoint_every: 0,
            checkpoint_dir: None,
        }
    }
}

impl ExperimentConfig {
    pub fn from_file(path: &Path) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)?;
        let cfg = Self::from_str(&text)
            .map_err(|e| Error::Config(format!("{}: {e}", path.display())))?;
        Ok(cfg)
    }

    #[allow(clippy::should_implement_trait)]
    pub fn from_str(text: &str) -> Result<ExperimentConfig> {
        let parsed = ini::parse(text)?;
        let ds = Section::of(&parsed, "dataset");
        let sv = Section::of(&parsed, "solver");
        let rn = Section::of(&parsed, "run");
        let cfg = ExperimentConfig {
            dataset: DatasetConfig {
                kind: ds.require("kind")?.to_string(),
                name: ds.str("name").map(String::from),
                path: ds.str("path").map(PathBuf::from),
                scale: ds.usize_or("scale", 1)?,
                seed: ds.u64_or("seed", 0)?,
            },
            solver: SolverConfig {
                method: sv.require("method")?.to_string(),
                b: sv.usize_or("b", 4)?,
                s: sv.usize_or("s", 1)?,
                lam: sv.f64_opt("lam")?,
                iters: sv.usize_or("iters", 1000)?,
                seed: sv.u64_or("seed", 0)?,
                record_every: sv.usize_or("record_every", 50)?,
                track_gram_cond: sv.bool_or("track_gram_cond", false)?,
                tol: sv.f64_opt("tol")?,
                overlap: sv.bool_or("overlap", false)?,
                reg: sv.str("reg").unwrap_or("l2").to_string(),
                l1_ratio: sv.f64_opt("l1_ratio")?.unwrap_or(0.5),
                local_iters: sv.usize_or("local_iters", 100)?,
            },
            run: RunConfig {
                ranks: rn.usize_or("ranks", 1)?,
                backend: rn.str("backend").unwrap_or("native").to_string(),
                transport: rn.str("transport").unwrap_or("thread").to_string(),
                topology: rn.str("topology").unwrap_or("flat").to_string(),
                node_size: rn.usize_or("node_size", 1)?,
                artifact_dir: PathBuf::from(rn.str("artifact_dir").unwrap_or("artifacts")),
                trace: rn.str("trace").map(PathBuf::from),
                telemetry: rn.str("telemetry").map(PathBuf::from),
                telemetry_z: rn.f64_opt("telemetry_z")?,
                comm_timeout_ms: rn.u64_opt("comm_timeout_ms")?,
                checkpoint_every: rn.usize_or("checkpoint_every", 0)?,
                checkpoint_dir: rn.str("checkpoint_dir").map(PathBuf::from),
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Serialize back to the INI dialect [`Self::from_str`] parses. The
    /// process launcher ships the exact experiment to re-exec'd worker
    /// ranks through the environment with this, so a parse → serialize →
    /// parse cycle must be lossless: floats print with `{:?}` (shortest
    /// round-trip form) and unset optional keys are omitted entirely.
    pub fn to_ini(&self) -> String {
        fn kv(out: &mut String, key: &str, value: impl std::fmt::Display) {
            out.push_str(key);
            out.push_str(" = ");
            out.push_str(&value.to_string());
            out.push('\n');
        }
        let mut s = String::new();
        s.push_str("[dataset]\n");
        kv(&mut s, "kind", &self.dataset.kind);
        if let Some(name) = &self.dataset.name {
            kv(&mut s, "name", name);
        }
        if let Some(path) = &self.dataset.path {
            kv(&mut s, "path", path.display());
        }
        kv(&mut s, "scale", self.dataset.scale);
        kv(&mut s, "seed", self.dataset.seed);
        s.push_str("\n[solver]\n");
        kv(&mut s, "method", &self.solver.method);
        kv(&mut s, "b", self.solver.b);
        kv(&mut s, "s", self.solver.s);
        if let Some(lam) = self.solver.lam {
            kv(&mut s, "lam", format!("{lam:?}"));
        }
        kv(&mut s, "iters", self.solver.iters);
        kv(&mut s, "seed", self.solver.seed);
        kv(&mut s, "record_every", self.solver.record_every);
        kv(&mut s, "track_gram_cond", self.solver.track_gram_cond);
        if let Some(tol) = self.solver.tol {
            kv(&mut s, "tol", format!("{tol:?}"));
        }
        kv(&mut s, "overlap", self.solver.overlap);
        kv(&mut s, "reg", &self.solver.reg);
        kv(&mut s, "l1_ratio", format!("{:?}", self.solver.l1_ratio));
        kv(&mut s, "local_iters", self.solver.local_iters);
        s.push_str("\n[run]\n");
        kv(&mut s, "ranks", self.run.ranks);
        kv(&mut s, "backend", &self.run.backend);
        kv(&mut s, "transport", &self.run.transport);
        kv(&mut s, "topology", &self.run.topology);
        kv(&mut s, "node_size", self.run.node_size);
        kv(&mut s, "artifact_dir", self.run.artifact_dir.display());
        if let Some(path) = &self.run.trace {
            kv(&mut s, "trace", path.display());
        }
        if let Some(path) = &self.run.telemetry {
            kv(&mut s, "telemetry", path.display());
        }
        if let Some(z) = self.run.telemetry_z {
            kv(&mut s, "telemetry_z", format!("{z:?}"));
        }
        if let Some(ms) = self.run.comm_timeout_ms {
            kv(&mut s, "comm_timeout_ms", ms);
        }
        kv(&mut s, "checkpoint_every", self.run.checkpoint_every);
        if let Some(dir) = &self.run.checkpoint_dir {
            kv(&mut s, "checkpoint_dir", dir.display());
        }
        s
    }

    pub fn validate(&self) -> Result<()> {
        match self.dataset.kind.as_str() {
            "synthetic" => {
                if self.dataset.name.is_none() {
                    return Err(Error::Config("synthetic dataset needs `name`".into()));
                }
            }
            "libsvm" => {
                if self.dataset.path.is_none() {
                    return Err(Error::Config("libsvm dataset needs `path`".into()));
                }
            }
            other => {
                return Err(Error::Config(format!("unknown dataset kind {other:?}")));
            }
        }
        // Parse the method and regularizer HERE — unknown strings fail at
        // config load, not inside the driver dispatch.
        let method = self.method()?;
        let reg = self.regularizer()?;
        reg.validate().map_err(|e| Error::Config(e.to_string()))?;
        if method == Method::Cocoa && self.solver.local_iters == 0 {
            return Err(Error::Config(
                "method cocoa needs local_iters ≥ 1 (0 would allreduce \
                 all-zero Δw every round)"
                    .into(),
            ));
        }
        if !reg.is_exact_l2() && !method.supports_prox() {
            return Err(Error::Config(format!(
                "method {method} solves the smooth ridge system; reg must be l2 \
                 (prox regularizers run through bcd/cabcd/bdcd/cabdcd)"
            )));
        }
        match self.run.backend.as_str() {
            "native" | "xla" => {}
            other => return Err(Error::Config(format!("unknown backend {other:?}"))),
        }
        match self.run.transport.as_str() {
            "thread" | "process" => {}
            other => {
                return Err(Error::Config(format!(
                    "unknown transport {other:?} (want thread|process)"
                )));
            }
        }
        // Parse the topology here too, so a typo fails at config load.
        self.topology()?;
        if self.run.ranks == 0 {
            return Err(Error::Config("ranks must be ≥ 1".into()));
        }
        if self.run.comm_timeout_ms == Some(0) {
            return Err(Error::Config(
                "comm_timeout_ms must be ≥ 1 (omit the key for an unbounded wait)".into(),
            ));
        }
        if let Some(z) = self.run.telemetry_z {
            if !z.is_finite() || z <= 0.0 {
                return Err(Error::Config(
                    "telemetry_z must be a finite value > 0 (omit the key for the default)"
                        .into(),
                ));
            }
        }
        Ok(())
    }

    /// Effective λ: explicit override or the spec's 1000·σ_min rule.
    pub fn effective_lambda(&self, spec_lambda: f64) -> f64 {
        self.solver.lam.unwrap_or(spec_lambda)
    }

    /// Parse the `[run] topology` / `node_size` pair into the transport's
    /// [`Topology`](crate::comm::Topology) enum (fails loudly on unknown
    /// strings and a zero `node_size` at config load).
    pub fn topology(&self) -> Result<crate::comm::Topology> {
        match self.run.topology.as_str() {
            "flat" => Ok(crate::comm::Topology::Flat),
            "twolevel" => {
                if self.run.node_size == 0 {
                    return Err(Error::Config(
                        "topology twolevel needs node_size ≥ 1".into(),
                    ));
                }
                Ok(crate::comm::Topology::TwoLevel {
                    node_size: self.run.node_size,
                })
            }
            other => Err(Error::Config(format!(
                "unknown topology {other:?} (want flat|twolevel)"
            ))),
        }
    }

    /// Parse the `[solver] method` string into the engine's [`Method`]
    /// enum (fails loudly on unknown strings at config load).
    pub fn method(&self) -> Result<Method> {
        Method::parse(self.solver.method.as_str())
            .map_err(|e| Error::Config(e.to_string()))
    }

    /// Parse the `[solver] reg` / `l1_ratio` pair into a [`Reg`].
    pub fn regularizer(&self) -> Result<Reg> {
        match self.solver.reg.as_str() {
            "l2" => Ok(Reg::L2),
            "l1" => Ok(Reg::L1),
            "none" => Ok(Reg::None),
            "elastic" => Ok(Reg::Elastic {
                l1_ratio: self.solver.l1_ratio,
            }),
            other => Err(Error::Config(format!(
                "unknown reg {other:?} (want l1|l2|elastic|none)"
            ))),
        }
    }

    pub fn solver_opts(&self, lam: f64) -> SolverOpts {
        // The parse constructors run `validate()` so these cannot fire
        // there, but the fields are public — a hand-built config with a
        // malformed method/reg string must fail loudly here rather than
        // silently run a default path.
        let method = self
            .method()
            .expect("invalid [solver] method — call ExperimentConfig::validate() first");
        let reg = self
            .regularizer()
            .expect("invalid [solver] reg — call ExperimentConfig::validate() first");
        SolverOpts {
            b: self.solver.b,
            s: if method.is_ca() { self.solver.s } else { 1 },
            lam,
            iters: self.solver.iters,
            seed: self.solver.seed,
            record_every: self.solver.record_every,
            track_gram_cond: self.solver.track_gram_cond,
            tol: self.solver.tol,
            overlap: self.solver.overlap,
            reg,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_minimal_config() {
        let text = r#"
            [dataset]
            kind = synthetic
            name = abalone

            [solver]
            method = cabcd
            b = 8
            s = 4
        "#;
        let cfg = ExperimentConfig::from_str(text).unwrap();
        assert_eq!(cfg.solver.iters, 1000);
        assert_eq!(cfg.run.ranks, 1);
        let opts = cfg.solver_opts(0.5);
        assert_eq!(opts.s, 4);
        assert_eq!(opts.lam, 0.5);
    }

    #[test]
    fn classical_method_forces_s1() {
        let text = "[dataset]\nkind = synthetic\nname = a9a\n[solver]\nmethod = bcd\ns = 16\n";
        let cfg = ExperimentConfig::from_str(text).unwrap();
        assert_eq!(cfg.solver_opts(1.0).s, 1);
    }

    #[test]
    fn overlap_flag_parses_and_defaults_off() {
        let on = "[dataset]\nkind = synthetic\nname = a9a\n[solver]\nmethod = cabcd\noverlap = true\n";
        assert!(ExperimentConfig::from_str(on).unwrap().solver_opts(1.0).overlap);
        let off = "[dataset]\nkind = synthetic\nname = a9a\n[solver]\nmethod = cabcd\n";
        assert!(!ExperimentConfig::from_str(off).unwrap().solver_opts(1.0).overlap);
    }

    #[test]
    fn reg_parses_and_defaults_to_l2() {
        let base = "[dataset]\nkind = synthetic\nname = a9a\n[solver]\nmethod = cabcd\n";
        assert_eq!(
            ExperimentConfig::from_str(base).unwrap().solver_opts(1.0).reg,
            Reg::L2
        );
        let l1 = format!("{base}reg = l1\n");
        assert_eq!(
            ExperimentConfig::from_str(&l1).unwrap().solver_opts(1.0).reg,
            Reg::L1
        );
        let en = format!("{base}reg = elastic\nl1_ratio = 0.25\n");
        assert_eq!(
            ExperimentConfig::from_str(&en).unwrap().solver_opts(1.0).reg,
            Reg::Elastic { l1_ratio: 0.25 }
        );
        let bad = format!("{base}reg = l3\n");
        assert!(ExperimentConfig::from_str(&bad).is_err());
        let bad_ratio = format!("{base}reg = elastic\nl1_ratio = 1.5\n");
        assert!(ExperimentConfig::from_str(&bad_ratio).is_err());
        let cg_l1 = "[dataset]\nkind = synthetic\nname = a9a\n[solver]\nmethod = cg\nreg = l1\n";
        assert!(ExperimentConfig::from_str(cg_l1).is_err());
    }

    #[test]
    fn fault_tolerance_keys_parse_and_default_off() {
        let base = "[dataset]\nkind = synthetic\nname = a9a\n[solver]\nmethod = cabcd\n";
        let cfg = ExperimentConfig::from_str(base).unwrap();
        assert_eq!(cfg.run.comm_timeout_ms, None);
        assert_eq!(cfg.run.checkpoint_every, 0);
        assert_eq!(cfg.run.checkpoint_dir, None);
        let on = format!(
            "{base}[run]\ncomm_timeout_ms = 5000\ncheckpoint_every = 10\ncheckpoint_dir = ckpts\n"
        );
        let cfg = ExperimentConfig::from_str(&on).unwrap();
        assert_eq!(cfg.run.comm_timeout_ms, Some(5000));
        assert_eq!(cfg.run.checkpoint_every, 10);
        assert_eq!(cfg.run.checkpoint_dir, Some(PathBuf::from("ckpts")));
        // A zero deadline would poison every receive instantly; reject it
        // at config load, where the typo is visible.
        let zero = format!("{base}[run]\ncomm_timeout_ms = 0\n");
        assert!(ExperimentConfig::from_str(&zero).is_err());
    }

    #[test]
    fn telemetry_keys_parse_and_default_off() {
        let base = "[dataset]\nkind = synthetic\nname = a9a\n[solver]\nmethod = cabcd\n";
        let cfg = ExperimentConfig::from_str(base).unwrap();
        assert_eq!(cfg.run.telemetry, None);
        assert_eq!(cfg.run.telemetry_z, None);
        let on = format!("{base}[run]\ntelemetry = run.telemetry.json\ntelemetry_z = 2.5\n");
        let cfg = ExperimentConfig::from_str(&on).unwrap();
        assert_eq!(cfg.run.telemetry, Some(PathBuf::from("run.telemetry.json")));
        assert_eq!(cfg.run.telemetry_z, Some(2.5));
        // A non-positive threshold would flag every rank (or none,
        // NaN-style); reject it at config load.
        let zero = format!("{base}[run]\ntelemetry_z = 0\n");
        assert!(ExperimentConfig::from_str(&zero).is_err());
        let neg = format!("{base}[run]\ntelemetry_z = -1.5\n");
        assert!(ExperimentConfig::from_str(&neg).is_err());
    }

    #[test]
    fn transport_and_topology_parse_and_default() {
        let base = "[dataset]\nkind = synthetic\nname = a9a\n[solver]\nmethod = cabcd\n";
        let cfg = ExperimentConfig::from_str(base).unwrap();
        assert_eq!(cfg.run.transport, "thread");
        assert_eq!(cfg.run.topology, "flat");
        assert_eq!(cfg.topology().unwrap(), crate::comm::Topology::Flat);
        let on = format!(
            "{base}[run]\nranks = 4\ntransport = process\ntopology = twolevel\nnode_size = 2\n"
        );
        let cfg = ExperimentConfig::from_str(&on).unwrap();
        assert_eq!(cfg.run.transport, "process");
        assert_eq!(
            cfg.topology().unwrap(),
            crate::comm::Topology::TwoLevel { node_size: 2 }
        );
        let bad_transport = format!("{base}[run]\ntransport = mpi\n");
        assert!(ExperimentConfig::from_str(&bad_transport).is_err());
        let bad_topology = format!("{base}[run]\ntopology = torus\n");
        assert!(ExperimentConfig::from_str(&bad_topology).is_err());
        // node_size = 0 would make the hierarchy degenerate; reject it at
        // config load (only when twolevel actually selects it).
        let zero_ns = format!("{base}[run]\ntopology = twolevel\nnode_size = 0\n");
        assert!(ExperimentConfig::from_str(&zero_ns).is_err());
        let zero_ns_flat = format!("{base}[run]\nnode_size = 0\n");
        assert!(ExperimentConfig::from_str(&zero_ns_flat).is_ok());
    }

    #[test]
    fn to_ini_round_trips_every_field() {
        // The process launcher ships configs to worker ranks as INI text,
        // so serialization must survive a full parse cycle — including
        // floats that need shortest-round-trip printing.
        let text = r#"
            [dataset]
            kind = synthetic
            name = abalone
            scale = 4
            seed = 9

            [solver]
            method = cabcd
            b = 8
            s = 4
            lam = 0.1234567890123456789
            iters = 600
            seed = 7
            record_every = 25
            overlap = true
            reg = elastic
            l1_ratio = 0.3
            local_iters = 50

            [run]
            ranks = 4
            transport = process
            topology = twolevel
            node_size = 2
            trace = run.trace.json
            telemetry_z = 1.75
            comm_timeout_ms = 5000
            checkpoint_every = 10
            checkpoint_dir = ckpts
        "#;
        let cfg = ExperimentConfig::from_str(text).unwrap();
        let round = ExperimentConfig::from_str(&cfg.to_ini()).unwrap();
        assert_eq!(format!("{cfg:?}"), format!("{round:?}"));
        // And the second generation is a fixed point.
        assert_eq!(cfg.to_ini(), round.to_ini());
    }

    #[test]
    fn rejects_bad_method() {
        let text = "[dataset]\nkind = synthetic\nname = a9a\n[solver]\nmethod = sgd\n";
        assert!(ExperimentConfig::from_str(text).is_err());
    }

    #[test]
    fn libsvm_needs_path() {
        let text = "[dataset]\nkind = libsvm\n[solver]\nmethod = bcd\n";
        assert!(ExperimentConfig::from_str(text).is_err());
    }
}
