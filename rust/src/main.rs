//! `cabcd` — launcher CLI for the communication-avoiding block coordinate
//! descent framework.
//!
//! Subcommands (args are `--key value` pairs; clap is not in the offline
//! vendor set, so parsing is hand-rolled in [`Args`]):
//!
//! * `train`      — run one experiment (config file or flags)
//! * `gen-data`   — write a Table-3 dataset clone as a LIBSVM file
//! * `cost-table` — print Table 1 / Table 2 instantiations
//! * `scaling`    — modeled strong/weak scaling (Figures 8/9)
//! * `artifacts`  — inspect the AOT artifact manifest

use std::collections::BTreeMap;
use std::path::PathBuf;

use cabcd::config::{DatasetConfig, ExperimentConfig, RunConfig, SolverConfig};
use cabcd::coordinator::run_experiment;
use cabcd::costmodel::{
    scaling::{paper_p_range, strong_scaling, weak_scaling},
    AlgoCosts, CostParams, Machine, Method,
};
use cabcd::error::{Error, Result};
use cabcd::matrix::gen::{self, sigma_max_sq};
use cabcd::matrix::io::write_libsvm;
use cabcd::util::Rng64;

/// `--key value` argument bag with typed getters.
struct Args {
    map: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut map = BTreeMap::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| Error::InvalidArg(format!("expected --flag, got {a:?}")))?;
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                map.insert(key.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                flags.push(key.to_string());
                i += 1;
            }
        }
        Ok(Args { map, flags })
    }

    fn str_or(&self, key: &str, default: &str) -> String {
        self.map.get(key).cloned().unwrap_or_else(|| default.into())
    }

    fn str_opt(&self, key: &str) -> Option<String> {
        self.map.get(key).cloned()
    }

    fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.map.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| Error::InvalidArg(format!("--{key} {v:?}: {e}"))),
        }
    }

    fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.map.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| Error::InvalidArg(format!("--{key} {v:?}: {e}"))),
        }
    }

    fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.map.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| Error::InvalidArg(format!("--{key} {v:?}: {e}"))),
        }
    }

    fn u64_opt(&self, key: &str) -> Result<Option<u64>> {
        match self.map.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|e| Error::InvalidArg(format!("--{key} {v:?}: {e}"))),
        }
    }

    fn f64_opt(&self, key: &str) -> Result<Option<f64>> {
        match self.map.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|e| Error::InvalidArg(format!("--{key} {v:?}: {e}"))),
        }
    }

    fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

const USAGE: &str = "\
cabcd — communication-avoiding primal/dual block coordinate descent
        (Devarakonda, Fountoulakis, Demmel, Mahoney, 2016)

USAGE: cabcd <subcommand> [--key value ...] [--flag ...]

  train       --config FILE | [--dataset abalone|news20|a9a|real-sim]
              [--scale K]
              [--method bcd|cabcd|bdcd|cabdcd|bcdrow|cabcdrow|cocoa|cg]
              [--b B] [--s S] [--iters H] [--lam L] [--ranks P]
              [--transport thread|process (process = one OS process per
               rank over loopback TCP; this binary is re-exec'd into the
               worker ranks)]
              [--topology flat|twolevel] [--node-size R (ranks per node
               for the hierarchical two-level allreduce)]
              [--backend native|xla] [--artifact-dir DIR] [--seed N]
              [--overlap] [--json] [--reg l2|l1|elastic|none]
              [--l1-ratio R] [--local-iters N (cocoa)]
              [--trace FILE (Chrome trace-event JSON, one track per rank)]
              [--telemetry FILE (cluster health snapshots as JSON, plus a
               Prometheus exposition at FILE with a .prom extension)]
              [--telemetry-z Z (straggler z-score threshold, default 1.25)]
              [--comm-timeout MS (deadline per blocking receive; a stalled
               or dead rank poisons the group instead of hanging)]
              [--checkpoint-every K (snapshot state every K-th s-step
               block)] [--checkpoint-dir DIR (default ARTIFACTS/checkpoints)]
  gen-data    --out FILE [--name abalone] [--scale K] [--seed N] [--verify]
  cost-table  [--d D] [--n N] [--p P] [--b B] [--s S] [--h H]
  scaling     [--mode strong|weak] [--machine mpi|spark] [--d D] [--log2n E]
              [--b B] [--h H] [--max-s S]
  artifacts   [--dir artifacts]
";

fn main() {
    // Process-transport worker ranks re-exec this binary with their rank
    // assignment and config in the environment; they must short-circuit
    // before any argv handling (their argv is the launcher's, not ours).
    match cabcd::coordinator::maybe_run_process_child() {
        Ok(false) => {}
        Ok(true) => return,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "train" => cmd_train(&args),
        "gen-data" => cmd_gen_data(&args),
        "cost-table" => cmd_cost_table(&args),
        "scaling" => cmd_scaling(&args),
        "artifacts" => cmd_artifacts(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => Err(Error::InvalidArg(format!(
            "unknown subcommand {other:?}; run `cabcd help`"
        ))),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = if let Some(path) = args.str_opt("config") {
        ExperimentConfig::from_file(&PathBuf::from(path))?
    } else {
        let iters = args.usize_or("iters", 1000)?;
        ExperimentConfig {
            dataset: DatasetConfig {
                kind: "synthetic".into(),
                name: Some(args.str_or("dataset", "abalone")),
                path: None,
                scale: args.usize_or("scale", 1)?,
                seed: args.u64_or("seed", 0)?,
            },
            solver: SolverConfig {
                method: args.str_or("method", "cabcd"),
                b: args.usize_or("b", 4)?,
                s: args.usize_or("s", 4)?,
                lam: args.f64_opt("lam")?,
                iters,
                seed: args.u64_or("seed", 0)?,
                record_every: args.usize_or("record-every", (iters / 20).max(1))?,
                track_gram_cond: args.flag("track-gram-cond"),
                tol: args.f64_opt("tol")?,
                overlap: args.flag("overlap"),
                reg: args.str_or("reg", "l2"),
                l1_ratio: args.f64_or("l1-ratio", 0.5)?,
                local_iters: args.usize_or("local-iters", 100)?,
            },
            run: RunConfig {
                ranks: args.usize_or("ranks", 1)?,
                backend: args.str_or("backend", "native"),
                transport: args.str_or("transport", "thread"),
                topology: args.str_or("topology", "flat"),
                node_size: args.usize_or("node-size", 1)?,
                artifact_dir: PathBuf::from(args.str_or("artifact-dir", "artifacts")),
                trace: args.str_opt("trace").map(PathBuf::from),
                telemetry: args.str_opt("telemetry").map(PathBuf::from),
                telemetry_z: args.f64_opt("telemetry-z")?,
                comm_timeout_ms: args.u64_opt("comm-timeout")?,
                checkpoint_every: args.usize_or("checkpoint-every", 0)?,
                checkpoint_dir: args.str_opt("checkpoint-dir").map(PathBuf::from),
            },
        }
    };
    // These flags also override a config file's [run] settings.
    let mut cfg = cfg;
    if let Some(t) = args.str_opt("transport") {
        cfg.run.transport = t;
    }
    if let Some(t) = args.str_opt("topology") {
        cfg.run.topology = t;
    }
    if let Some(ns) = args.str_opt("node-size") {
        cfg.run.node_size = ns
            .parse()
            .map_err(|e| Error::InvalidArg(format!("--node-size {ns:?}: {e}")))?;
    }
    if let Some(p) = args.str_opt("ranks") {
        cfg.run.ranks = p
            .parse()
            .map_err(|e| Error::InvalidArg(format!("--ranks {p:?}: {e}")))?;
    }
    if let Some(path) = args.str_opt("trace") {
        cfg.run.trace = Some(PathBuf::from(path));
    }
    if let Some(path) = args.str_opt("telemetry") {
        cfg.run.telemetry = Some(PathBuf::from(path));
    }
    if let Some(z) = args.f64_opt("telemetry-z")? {
        cfg.run.telemetry_z = Some(z);
    }
    if let Some(ms) = args.u64_opt("comm-timeout")? {
        cfg.run.comm_timeout_ms = Some(ms);
    }
    if let Some(every) = args.str_opt("checkpoint-every") {
        cfg.run.checkpoint_every = every
            .parse()
            .map_err(|e| Error::InvalidArg(format!("--checkpoint-every {every:?}: {e}")))?;
    }
    if let Some(dir) = args.str_opt("checkpoint-dir") {
        cfg.run.checkpoint_dir = Some(PathBuf::from(dir));
    }
    cfg.validate()?;
    let report = run_experiment(&cfg)?;
    if args.flag("json") {
        println!("{}", report.to_json());
    } else if let Some(a) = &report.aborted_at {
        println!(
            "ABORTED: rank {} failed after {} collectives: {}",
            a.rank, a.collectives_done, a.error
        );
        match (&a.checkpoint, a.resume_at) {
            (Some(path), Some(k)) => {
                println!("resume from checkpoint {path} (restarts at s-step block {k})")
            }
            _ => println!("no resumable checkpoint (run with --checkpoint-every K)"),
        }
        // The observability artifacts are written even on abort — name
        // them so the postmortem starts from the right files.
        if let Some(path) = cfg.run.trace.as_ref() {
            println!("partial chrome trace written to {}", path.display());
        }
        if let Some(path) = cfg.run.telemetry.as_ref() {
            println!(
                "partial telemetry written to {} (+ {})",
                path.display(),
                path.with_extension("prom").display()
            );
        }
    } else {
        println!(
            "dataset={} (d={}, n={})  method={}  b={} s={}  P={}  backend={}",
            report.dataset,
            report.d,
            report.n,
            report.method,
            report.b,
            report.s,
            report.ranks,
            report.backend
        );
        println!(
            "λ={:.3e}  iters={}  wall={:.1} ms",
            report.lambda, report.history.iters, report.wall_ms
        );
        if report.history.prox.is_empty() {
            println!(
                "final |objective error|={:.3e}  solution error={:.3e}",
                report.final_obj_err, report.final_sol_err
            );
        } else {
            println!(
                "reg={}  penalized objective={:.6e}  duality gap={:.3e}  \
                 subgrad residual={:.3e}  nnz(w)={}",
                report.reg,
                report.history.final_pen_obj(),
                report.history.final_gap(),
                report.history.final_subgrad(),
                report.history.final_nnz().unwrap_or(0)
            );
        }
        println!(
            "comm: allreduces={}  critical-path msgs={}  words={}",
            report.history.meter.allreduces, report.critical_msgs, report.critical_words
        );
        if let Some(t) = &report.trace {
            println!(
                "trace: {} spans over {} ranks  overlap efficiency={:.3}  \
                 (chrome trace written to {})",
                t.spans,
                t.ranks,
                t.overlap_efficiency(),
                cfg.run.trace.as_ref().unwrap().display()
            );
        }
        if let (Some(t), Some(path)) = (&report.telemetry, cfg.run.telemetry.as_ref()) {
            println!(
                "telemetry: {} snapshots over {} ranks  straggler flags={}  \
                 (json written to {}, exposition to {})",
                t.snapshots,
                t.ranks,
                t.straggler_flags,
                path.display(),
                path.with_extension("prom").display()
            );
        }
    }
    Ok(())
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    let name = args.str_or("name", "abalone");
    let scale = args.usize_or("scale", 1)?;
    let seed = args.u64_or("seed", 0)?;
    let out = PathBuf::from(
        args.str_opt("out")
            .ok_or_else(|| Error::InvalidArg("gen-data needs --out FILE".into()))?,
    );
    let mut spec = gen::spec_by_name(&name)?;
    if scale > 1 {
        spec.name = format!("{}-s{}", spec.name, scale);
        spec.d = (spec.d / scale).max(4);
        spec.n = (spec.n / scale).max(16);
    }
    println!(
        "generating {} (d={}, n={}, density={}, σ_max={:.2e})",
        spec.name, spec.d, spec.n, spec.density, spec.sigma_max
    );
    let ds = gen::generate(&spec, seed)?;
    write_libsvm(&out, &ds)?;
    println!("wrote {} points to {}", ds.n(), out.display());
    if args.flag("verify") {
        let mut rng = Rng64::seed_from_u64(seed ^ 0xD1CE);
        let smax = sigma_max_sq(&ds.x, 80, &mut rng);
        println!(
            "measured σ_max(XᵀX) = {:.3e} (target {:.3e}), density = {:.4}",
            smax,
            spec.sigma_max,
            ds.x.density()
        );
    }
    Ok(())
}

fn cmd_cost_table(args: &Args) -> Result<()> {
    let d = args.f64_or("d", 1024.0)?;
    let n = args.f64_or("n", 1e6)?;
    let p = args.f64_or("p", 1024.0)?;
    let b = args.f64_or("b", 8.0)?;
    let s = args.f64_or("s", 8.0)?;
    let h = args.f64_or("h", 1000.0)?;
    println!("Table 1 (critical-path costs), d={d} n={n} P={p} b={b} s={s} H={h}:");
    println!(
        "{:<10} {:>14} {:>12} {:>14} {:>14}",
        "Algorithm", "Flops F", "Latency L", "Bandwidth W", "Memory M"
    );
    let rows: Vec<(&str, Method, f64)> = vec![
        ("BCD", Method::Bcd, 1.0),
        ("CA-BCD", Method::CaBcd, s),
        ("BDCD", Method::Bdcd, 1.0),
        ("CA-BDCD", Method::CaBdcd, s),
        ("Krylov", Method::Krylov, 1.0),
        ("TSQR", Method::Tsqr, 1.0),
    ];
    for (name, method, s_eff) in rows {
        let cp = CostParams {
            d,
            n,
            p,
            b,
            s: s_eff,
            h,
        };
        let c = AlgoCosts::of(method, &cp);
        println!(
            "{:<10} {:>14.4e} {:>12.4e} {:>14.4e} {:>14.4e}",
            name, c.flops, c.latency, c.bandwidth, c.memory
        );
    }
    Ok(())
}

fn cmd_scaling(args: &Args) -> Result<()> {
    let mode = args.str_or("mode", "strong");
    let machine = args.str_or("machine", "mpi");
    let d = args.f64_or("d", 1024.0)?;
    let b = args.f64_or("b", 4.0)?;
    let h = args.f64_or("h", 100.0)?;
    let max_s = args.usize_or("max-s", 1000)?;
    let m = match machine.as_str() {
        "mpi" => Machine::cori_mpi(),
        "spark" => Machine::cori_spark(),
        other => {
            return Err(Error::InvalidArg(format!(
                "machine {other:?} (want mpi|spark)"
            )))
        }
    };
    let pr = paper_p_range();
    let series = match mode.as_str() {
        "strong" => {
            let default_e = if machine == "spark" { 40 } else { 35 };
            let n = (1u64 << args.u64_or("log2n", default_e)?) as f64;
            strong_scaling(&m, d, n, b, h, &pr, max_s)
        }
        "weak" => {
            let npp = (1u64 << args.u64_or("log2n", 11)?) as f64;
            weak_scaling(&m, d, npp, b, h, &pr, max_s)
        }
        other => {
            return Err(Error::InvalidArg(format!(
                "mode {other:?} (want strong|weak)"
            )))
        }
    };
    println!("{mode} scaling on {} (b={b}, d={d}):", series.machine);
    println!(
        "{:>10} {:>14} {:>14} {:>8} {:>10}",
        "P", "T_BCD (s)", "T_CA-BCD (s)", "best s", "speedup"
    );
    for pt in &series.points {
        println!(
            "{:>10} {:>14.6e} {:>14.6e} {:>8} {:>10.2}",
            pt.p, pt.t_classical, pt.t_ca, pt.best_s, pt.speedup
        );
    }
    let (mx, at_p, at_s) = series.max_speedup();
    println!("max speedup {mx:.1}× at P={at_p} (s={at_s})");
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.str_or("dir", "artifacts"));
    let data = std::fs::read_to_string(dir.join("manifest.tsv"))?;
    let manifest = cabcd::runtime::Manifest::parse_tsv(&data)?;
    println!(
        "artifact dir {} — dtype {}, nt {}",
        dir.display(),
        manifest.dtype,
        manifest.nt
    );
    for a in &manifest.artifacts {
        println!("  {:<28} kind={:<16} file={}", a.name, a.kind, a.file);
    }
    Ok(())
}
