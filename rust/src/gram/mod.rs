//! The compute hot-spot behind all four algorithms, as a swappable backend.
//!
//! Per outer iteration every rank computes, over its local shard `A_loc`
//! and the shared sampled row set `I` (|I| = s·b):
//!
//! * the raw partial Gram  `G = A_loc[I,:] · A_loc[I,:]ᵀ`
//! * the raw partial residual `r = A_loc[I,:] · z`
//!
//! (allreduced by the coordinator), then — replicated — the s deferred
//! `b×b` subproblem solves of eq. (8) / eq. (18).
//!
//! G is symmetric and its native format here is the **packed lower
//! triangle** (`sb(sb+1)/2` words, see [`crate::linalg::packed`]): the
//! kernels write only the triangle, the `[G|r]` allreduce moves only the
//! triangle, and the inner solves index the triangle directly — there is
//! no unpack copy anywhere on the hot path.
//!
//! Two interchangeable implementations:
//! * [`NativeBackend`] — hand-written f64 Rust (works on CSR directly).
//! * [`crate::runtime::XlaBackend`] — the AOT JAX/Pallas artifacts executed
//!   through PJRT (dense tiles, zero-padded to the artifact shapes).
//!
//! A parity integration test asserts both produce identical trajectories.

use crate::error::Result;
use crate::linalg::cholesky;
use crate::linalg::packed::{packed_len, pidx, tri_row};
use crate::matrix::Matrix;

/// Strategy for the per-iteration heavy compute.
///
/// NOT `Send`: the XLA implementation holds PJRT handles, so each SPMD rank
/// constructs its own backend inside its thread.
pub trait ComputeBackend {
    fn name(&self) -> &'static str;

    /// Raw partial Gram + residual of sampled rows (pre-allreduce).
    /// `g` is the packed lower triangle (`sb(sb+1)/2` words, entry `(j,t)`
    /// with `t ≤ j` at `g[j(j+1)/2 + t]`), `r` is `idx.len()`.
    fn gram_resid(
        &mut self,
        a: &Matrix,
        idx: &[usize],
        z: &[f64],
        g: &mut [f64],
        r: &mut [f64],
    ) -> Result<()>;

    /// Gram part alone (packed triangle, same layout as
    /// [`ComputeBackend::gram_resid`]). Used by the overlapped solver
    /// pipeline, which computes the *next* iteration's Gram (independent
    /// of the evolving α/w state) while the current reduction is in
    /// flight. Must be bitwise identical to the `g` that
    /// [`ComputeBackend::gram_resid`] produces.
    fn gram_only(&mut self, a: &Matrix, idx: &[usize], g: &mut [f64]) -> Result<()> {
        // Default: run the fused kernel against a zero z (G is independent
        // of z) and discard the residual. Backends with separable kernels
        // override this.
        let z = vec![0.0; a.cols()];
        let mut r = vec![0.0; idx.len()];
        self.gram_resid(a, idx, &z, g, &mut r)
    }

    /// Residual part alone: `r = A_loc[idx,:] · z`. Counterpart of
    /// [`ComputeBackend::gram_only`] for the overlapped pipeline; must be
    /// bitwise identical to the `r` of [`ComputeBackend::gram_resid`].
    fn resid_only(&mut self, a: &Matrix, idx: &[usize], z: &[f64], r: &mut [f64]) -> Result<()> {
        let mut g = vec![0.0; packed_len(idx.len())];
        self.gram_resid(a, idx, z, &mut g, r)
    }

    /// Primal s-step inner solve (eq. 8; mirrors
    /// `python/compile/model.py::ca_inner_solve`, which consumes the full
    /// artifact-shaped matrix — the packed triangle is the coordinator's
    /// wire/solve format). `g_raw` is packed. Returns the flat `(s·b)` Δw
    /// vector.
    #[allow(clippy::too_many_arguments)]
    fn ca_inner_solve(
        &mut self,
        s: usize,
        b: usize,
        g_raw: &[f64],
        r_raw: &[f64],
        w_blocks: &[f64],
        overlap: &[f64],
        lam: f64,
        inv_n: f64,
    ) -> Result<Vec<f64>>;

    /// Dual s-step inner solve (eq. 18; mirrors
    /// `model.py::ca_dual_inner_solve`). `g_raw` is packed like in
    /// [`ComputeBackend::ca_inner_solve`]. Returns the flat `(s·b')` Δα.
    #[allow(clippy::too_many_arguments)]
    fn ca_dual_inner_solve(
        &mut self,
        s: usize,
        b: usize,
        g_raw: &[f64],
        r_raw: &[f64],
        a_blocks: &[f64],
        y_blocks: &[f64],
        overlap: &[f64],
        lam: f64,
        inv_n: f64,
    ) -> Result<Vec<f64>>;

    /// Prox-aware twin of [`ComputeBackend::ca_inner_solve`] (CA-Prox-BCD,
    /// arXiv:1712.06047): same packed `[G|r]` inputs, but each deferred
    /// step takes a Lipschitz-scaled gradient step and applies the
    /// regularizer's separable prox elementwise. The default replicates
    /// the native implementation — the solve is O(s²b²) coordinator-side
    /// work on already-reduced data, so no AOT artifact is required (an
    /// artifact-backed override is a future-work seam, mirroring
    /// `inner_solve`).
    #[allow(clippy::too_many_arguments)]
    fn ca_prox_inner_solve(
        &mut self,
        s: usize,
        b: usize,
        g_raw: &[f64],
        r_raw: &[f64],
        w_blocks: &[f64],
        overlap: &[f64],
        lam: f64,
        inv_n: f64,
        reg: &crate::prox::Reg,
    ) -> Result<Vec<f64>> {
        crate::prox::solve::ca_prox_inner_solve(
            s, b, g_raw, r_raw, w_blocks, overlap, lam, inv_n, reg,
        )
    }

    /// Prox-aware twin of [`ComputeBackend::ca_dual_inner_solve`]
    /// (CA-Prox-BDCD): proximal-gradient steps on the dual objective with
    /// a separable regularizer on the dual vector.
    #[allow(clippy::too_many_arguments)]
    fn ca_prox_dual_inner_solve(
        &mut self,
        s: usize,
        b: usize,
        g_raw: &[f64],
        r_raw: &[f64],
        a_blocks: &[f64],
        y_blocks: &[f64],
        overlap: &[f64],
        lam: f64,
        inv_n: f64,
        reg: &crate::prox::Reg,
    ) -> Result<Vec<f64>> {
        crate::prox::solve::ca_prox_dual_inner_solve(
            s, b, g_raw, r_raw, a_blocks, y_blocks, overlap, lam, inv_n, reg,
        )
    }

    /// Deferred local vector update `acc += A_loc[idx,:]ᵀ · d`.
    fn alpha_update(
        &mut self,
        a: &Matrix,
        idx: &[usize],
        d: &[f64],
        acc: &mut [f64],
    ) -> Result<()>;
}

/// Pure-Rust backend (CSR-aware; the default).
#[derive(Debug, Default)]
pub struct NativeBackend {
    /// Scratch for the per-step subproblem.
    gamma: Vec<f64>,
    rhs: Vec<f64>,
    /// Transposed-panel scratch for the CSR Gustavson Gram kernel — keeps
    /// the per-iteration compute allocation-free once warm, matching the
    /// comm layer's pooled zero-allocation invariant.
    panel: Vec<(u32, u32, f64)>,
}

impl NativeBackend {
    pub fn new() -> Self {
        NativeBackend::default()
    }
}

impl ComputeBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn gram_resid(
        &mut self,
        a: &Matrix,
        idx: &[usize],
        z: &[f64],
        g: &mut [f64],
        r: &mut [f64],
    ) -> Result<()> {
        a.sampled_gram_packed_scratch(idx, g, &mut self.panel)?;
        a.sampled_matvec(idx, z, r)?;
        Ok(())
    }

    fn gram_only(&mut self, a: &Matrix, idx: &[usize], g: &mut [f64]) -> Result<()> {
        a.sampled_gram_packed_scratch(idx, g, &mut self.panel)
    }

    fn resid_only(&mut self, a: &Matrix, idx: &[usize], z: &[f64], r: &mut [f64]) -> Result<()> {
        a.sampled_matvec(idx, z, r)
    }

    #[allow(clippy::too_many_arguments)] // trait-contract signature
    fn ca_inner_solve(
        &mut self,
        s: usize,
        b: usize,
        g_raw: &[f64],
        r_raw: &[f64],
        w_blocks: &[f64],
        overlap: &[f64],
        lam: f64,
        inv_n: f64,
    ) -> Result<Vec<f64>> {
        let sb = s * b;
        debug_assert_eq!(g_raw.len(), packed_len(sb));
        let mut deltas = vec![0.0; sb];
        self.gamma.resize(b * b, 0.0);
        self.rhs.resize(b, 0.0);
        for j in 0..s {
            // rhs = -λ·w_j + (1/n)·r_j
            for i in 0..b {
                self.rhs[i] = -lam * w_blocks[j * b + i] + inv_n * r_raw[j * b + i];
            }
            // rhs -= Σ_{t<j} (λ·O[j,t] + (1/n)·G[j,t]) Δ_t. For t < j the
            // block row G[j,t] lies strictly below the diagonal, so it is
            // a contiguous run of the packed triangle.
            for t in 0..j {
                let ov = &overlap[(j * s + t) * b * b..(j * s + t + 1) * b * b];
                let dt = &deltas[t * b..(t + 1) * b];
                for i in 0..b {
                    let base = tri_row(j * b + i);
                    let grow = &g_raw[base + t * b..base + (t + 1) * b];
                    let orow = &ov[i * b..(i + 1) * b];
                    let mut acc = 0.0;
                    for c in 0..b {
                        acc += (lam * orow[c] + inv_n * grow[c]) * dt[c];
                    }
                    self.rhs[i] -= acc;
                }
            }
            // Γ_j = (1/n)·G[j,j] + λI (diagonal block: fold the triangle's
            // symmetric entry in for c > i).
            for i in 0..b {
                for c in 0..b {
                    self.gamma[i * b + c] = inv_n * g_raw[pidx(j * b + i, j * b + c)]
                        + if i == c { lam } else { 0.0 };
                }
            }
            cholesky::chol_solve(&self.gamma, b, &mut self.rhs)?;
            deltas[j * b..(j + 1) * b].copy_from_slice(&self.rhs);
        }
        Ok(deltas)
    }

    #[allow(clippy::too_many_arguments)] // trait-contract signature
    fn ca_dual_inner_solve(
        &mut self,
        s: usize,
        b: usize,
        g_raw: &[f64],
        r_raw: &[f64],
        a_blocks: &[f64],
        y_blocks: &[f64],
        overlap: &[f64],
        lam: f64,
        inv_n: f64,
    ) -> Result<Vec<f64>> {
        let sb = s * b;
        debug_assert_eq!(g_raw.len(), packed_len(sb));
        let mut deltas = vec![0.0; sb];
        self.gamma.resize(b * b, 0.0);
        self.rhs.resize(b, 0.0);
        for j in 0..s {
            // rhs = -[Yw]_j + α_j + y_j  (+ cross terms with PLUS sign)
            for i in 0..b {
                self.rhs[i] = -r_raw[j * b + i] + a_blocks[j * b + i] + y_blocks[j * b + i];
            }
            for t in 0..j {
                let ov = &overlap[(j * s + t) * b * b..(j * s + t + 1) * b * b];
                let dt = &deltas[t * b..(t + 1) * b];
                for i in 0..b {
                    let base = tri_row(j * b + i);
                    let grow = &g_raw[base + t * b..base + (t + 1) * b];
                    let orow = &ov[i * b..(i + 1) * b];
                    let mut acc = 0.0;
                    for c in 0..b {
                        acc += ((inv_n / lam) * grow[c] + orow[c]) * dt[c];
                    }
                    self.rhs[i] += acc;
                }
            }
            // Θ_j = (1/(λn²))·G[j,j] + (1/n)I ;  Δ_j = -(1/n)·Θ⁻¹ rhs
            for i in 0..b {
                for c in 0..b {
                    self.gamma[i * b + c] = (inv_n * inv_n / lam)
                        * g_raw[pidx(j * b + i, j * b + c)]
                        + if i == c { inv_n } else { 0.0 };
                }
            }
            cholesky::chol_solve(&self.gamma, b, &mut self.rhs)?;
            for i in 0..b {
                deltas[j * b + i] = -inv_n * self.rhs[i];
            }
        }
        Ok(deltas)
    }

    fn alpha_update(
        &mut self,
        a: &Matrix,
        idx: &[usize],
        d: &[f64],
        acc: &mut [f64],
    ) -> Result<()> {
        a.scatter_rows_add(idx, d, acc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::DenseMatrix;

    fn rngv(n: usize, seed: u64) -> Vec<f64> {
        let mut state = seed.wrapping_add(0x9E3779B97F4A7C15);
        (0..n)
            .map(|_| {
                state ^= state << 13;
                state ^= state >> 7;
                state ^= state << 17;
                (state as f64 / u64::MAX as f64) - 0.5
            })
            .collect()
    }

    #[test]
    fn gram_resid_matches_direct() {
        let a = Matrix::Dense(DenseMatrix::from_vec(4, 6, rngv(24, 1)));
        let z = rngv(6, 2);
        let idx = [2usize, 0, 3];
        let mut g = vec![0.0; packed_len(3)];
        let mut r = vec![0.0; 3];
        NativeBackend::new()
            .gram_resid(&a, &idx, &z, &mut g, &mut r)
            .unwrap();
        // brute force
        let mut rows = vec![0.0; 3 * 6];
        a.gather_rows(&idx, &mut rows).unwrap();
        for j in 0..3 {
            let mut rv = 0.0;
            for c in 0..6 {
                rv += rows[j * 6 + c] * z[c];
            }
            assert!((r[j] - rv).abs() < 1e-12);
            for t in 0..3 {
                let mut gv = 0.0;
                for c in 0..6 {
                    gv += rows[j * 6 + c] * rows[t * 6 + c];
                }
                assert!((g[pidx(j, t)] - gv).abs() < 1e-12);
            }
        }
    }

    /// The split kernels feeding the overlapped pipeline must reproduce the
    /// fused kernel bit for bit.
    #[test]
    fn split_gram_and_resid_match_fused() {
        let a = Matrix::Dense(DenseMatrix::from_vec(5, 9, rngv(45, 8)));
        let z = rngv(9, 9);
        let idx = [4usize, 1, 3];
        let mut be = NativeBackend::new();
        let mut g_f = vec![0.0; packed_len(3)];
        let mut r_f = vec![0.0; 3];
        be.gram_resid(&a, &idx, &z, &mut g_f, &mut r_f).unwrap();
        let mut g_s = vec![0.0; packed_len(3)];
        let mut r_s = vec![0.0; 3];
        be.gram_only(&a, &idx, &mut g_s).unwrap();
        be.resid_only(&a, &idx, &z, &mut r_s).unwrap();
        assert_eq!(g_f, g_s);
        assert_eq!(r_f, r_s);
    }

    /// s=1 primal inner solve must equal the classical subproblem solve.
    #[test]
    fn inner_solve_s1_is_classical() {
        let b = 5;
        let m = rngv(b * 20, 3);
        // G = M Mᵀ over 20-long rows
        let mut g = vec![0.0; b * b];
        for i in 0..b {
            for j in 0..b {
                let mut s = 0.0;
                for k in 0..20 {
                    s += m[i * 20 + k] * m[j * 20 + k];
                }
                g[i * b + j] = s;
            }
        }
        let r = rngv(b, 4);
        let w = rngv(b, 5);
        let mut ov = vec![0.0; b * b];
        for i in 0..b {
            ov[i * b + i] = 1.0;
        }
        let (lam, inv_n) = (0.6, 1.0 / 20.0);
        let mut g_packed = vec![0.0; packed_len(b)];
        crate::linalg::packed::pack_lower(&g, b, &mut g_packed);
        let d = NativeBackend::new()
            .ca_inner_solve(1, b, &g_packed, &r, &w, &ov, lam, inv_n)
            .unwrap();
        // classical: (G/n + λI) Δ = -λw + r/n
        let mut gamma = vec![0.0; b * b];
        for i in 0..b {
            for j in 0..b {
                gamma[i * b + j] = inv_n * g[i * b + j] + if i == j { lam } else { 0.0 };
            }
        }
        let mut rhs: Vec<f64> = (0..b).map(|i| -lam * w[i] + inv_n * r[i]).collect();
        cholesky::chol_solve(&gamma, b, &mut rhs).unwrap();
        for i in 0..b {
            assert!((d[i] - rhs[i]).abs() < 1e-12);
        }
    }
}
