//! Telemetry exporters: Prometheus text exposition (scrape-ready), the
//! `--telemetry FILE` JSON snapshot dump, and the compact summary object
//! merged into the driver report.
//!
//! Keys and metric names are stable — `python/check_telemetry.py` and
//! `BENCH_hotpath.json` consume them.

use super::aggregate::{ClusterSnapshot, Quantiles, RankHealth, Straggler};
use super::histogram::{Histogram, BUCKETS};
use super::{Counter, Gauge, Hist, Registry, REGISTRY_WORDS};
use crate::util::json;

/// Prometheus metric-name prefix.
const PREFIX: &str = "cabcd";

/// Render the Prometheus text exposition (format 0.0.4) for a set of
/// per-rank registries: counters as `<prefix>_<name>_total`, gauges
/// bare, histograms with cumulative `_bucket{le=…}` / `_sum` / `_count`
/// series, all labeled `{rank="r"}`.
pub fn prometheus_text(regs: &[Registry]) -> String {
    let mut out = String::new();
    for c in Counter::ALL {
        let metric = format!("{PREFIX}_{}_total", c.name());
        out.push_str(&format!("# HELP {metric} Total {} events.\n", c.name()));
        out.push_str(&format!("# TYPE {metric} counter\n"));
        for reg in regs {
            out.push_str(&format!(
                "{metric}{{rank=\"{}\"}} {}\n",
                reg.rank(),
                reg.counter(c)
            ));
        }
    }
    for g in Gauge::ALL {
        let metric = format!("{PREFIX}_{}", g.name());
        out.push_str(&format!("# HELP {metric} Last observed {}.\n", g.name()));
        out.push_str(&format!("# TYPE {metric} gauge\n"));
        for reg in regs {
            out.push_str(&format!(
                "{metric}{{rank=\"{}\"}} {}\n",
                reg.rank(),
                reg.gauge(g)
            ));
        }
    }
    for h in Hist::ALL {
        let metric = format!("{PREFIX}_{}", h.name());
        out.push_str(&format!(
            "# HELP {metric} Distribution of {} observations.\n",
            h.name()
        ));
        out.push_str(&format!("# TYPE {metric} histogram\n"));
        for reg in regs {
            let hist = reg.hist(h);
            let rank = reg.rank();
            let mut cum = 0u64;
            for i in 0..BUCKETS {
                cum += hist.bucket(i);
                let le = if i == BUCKETS - 1 {
                    "+Inf".to_string()
                } else {
                    Histogram::le(i).to_string()
                };
                out.push_str(&format!(
                    "{metric}_bucket{{rank=\"{rank}\",le=\"{le}\"}} {cum}\n"
                ));
            }
            out.push_str(&format!("{metric}_sum{{rank=\"{rank}\"}} {}\n", hist.sum()));
            out.push_str(&format!(
                "{metric}_count{{rank=\"{rank}\"}} {}\n",
                hist.count()
            ));
        }
    }
    out
}

fn quantiles_json(q: &Quantiles) -> String {
    json::object(&[
        ("p50", json::num(q.p50 as f64)),
        ("p99", json::num(q.p99 as f64)),
    ])
}

fn health_json(rh: &RankHealth) -> String {
    let rank = if rh.rank == u32::MAX {
        json::string("fleet")
    } else {
        json::num(rh.rank as f64)
    };
    json::object(&[
        ("rank", rank),
        ("wall_ns", json::num(rh.wall_ns as f64)),
        ("compute_ns", json::num(rh.compute_ns as f64)),
        ("wire_ns", json::num(rh.wire_ns as f64)),
        ("idle_ns", json::num(rh.idle_ns as f64)),
        ("wire_words", json::num(rh.wire_words as f64)),
        ("gram", quantiles_json(&rh.gram)),
        ("allreduce", quantiles_json(&rh.allreduce)),
        ("all_to_all", quantiles_json(&rh.all_to_all)),
        ("barrier", quantiles_json(&rh.barrier)),
        ("wait", quantiles_json(&rh.wait)),
    ])
}

fn straggler_json(s: &Straggler) -> String {
    json::object(&[
        ("rank", json::num(s.rank as f64)),
        ("op", json::string(s.op)),
        ("z", json::num(s.z)),
        ("dev_ns", json::num(s.dev_ns as f64)),
        ("at_collective", json::num(s.at_collective as f64)),
    ])
}

fn snapshot_json(snap: &ClusterSnapshot) -> String {
    json::object(&[
        ("outer", json::num(snap.outer as f64)),
        ("h", json::num(snap.h as f64)),
        ("at_collective", json::num(snap.at_collective as f64)),
        ("ranks", json::array(snap.ranks.iter().map(health_json))),
        ("fleet", health_json(&snap.fleet)),
        (
            "stragglers",
            json::array(snap.stragglers.iter().map(straggler_json)),
        ),
    ])
}

/// The `--telemetry FILE` JSON document: run geometry, the full snapshot
/// sequence (taken from the first registry — every rank decodes the same
/// snapshots), and the health tripwires.
pub fn snapshots_json(regs: &[Registry]) -> String {
    let ranks = regs.len();
    let group = regs.first().map(|r| r.ranks() as usize).unwrap_or(ranks);
    let snaps: &[ClusterSnapshot] = regs.first().map(|r| r.snapshots()).unwrap_or(&[]);
    let straggler_flags: usize = snaps.iter().map(|s| s.stragglers.len()).sum();
    json::object(&[
        ("ranks", json::num(ranks as f64)),
        ("registry_words", json::num(REGISTRY_WORDS as f64)),
        (
            "snapshot_words",
            json::num((group * REGISTRY_WORDS) as f64),
        ),
        (
            "z_threshold",
            json::num(regs.first().map(|r| r.z_threshold()).unwrap_or(0.0)),
        ),
        (
            "min_dev_ns",
            json::num(regs.first().map(|r| r.min_dev_ns() as f64).unwrap_or(0.0)),
        ),
        ("snapshots", json::array(snaps.iter().map(snapshot_json))),
        (
            "dropped_snapshots",
            json::num(regs.first().map(|r| r.dropped_snapshots() as f64).unwrap_or(0.0)),
        ),
        (
            "telemetry_allocs",
            json::num(regs.iter().map(|r| r.telemetry_allocs()).max().unwrap_or(0) as f64),
        ),
        ("straggler_flags", json::num(straggler_flags as f64)),
    ])
}

/// The compact block merged into the driver report (`"telemetry"` key),
/// built once from the reclaimed per-rank registries.
#[derive(Clone, Debug)]
pub struct TelemetrySummary {
    /// Registries collected (ranks that ran).
    pub ranks: usize,
    /// Words one aggregation collective moves (`P · REGISTRY_WORDS`) —
    /// the machine-independent wire cost gated in `BENCH_hotpath.json`.
    pub snapshot_words: usize,
    /// Snapshots taken over the run.
    pub snapshots: usize,
    /// Snapshots lost to the bounded store.
    pub dropped_snapshots: u64,
    /// Max steady-state allocation tripwire across ranks (gated at 0).
    pub telemetry_allocs: u64,
    /// Total straggler verdicts across all snapshots.
    pub straggler_flags: usize,
    /// The final snapshot, if any was taken.
    pub last: Option<ClusterSnapshot>,
}

impl TelemetrySummary {
    /// Summarize reclaimed per-rank registries (snapshots are read from
    /// the first, which holds the same sequence as every other rank).
    pub fn from_registries(regs: &[Registry]) -> TelemetrySummary {
        let snaps: &[ClusterSnapshot] = regs.first().map(|r| r.snapshots()).unwrap_or(&[]);
        TelemetrySummary {
            ranks: regs.len(),
            snapshot_words: regs.first().map(|r| r.ranks() as usize).unwrap_or(0) * REGISTRY_WORDS,
            snapshots: snaps.len(),
            dropped_snapshots: regs.first().map(|r| r.dropped_snapshots()).unwrap_or(0),
            telemetry_allocs: regs.iter().map(|r| r.telemetry_allocs()).max().unwrap_or(0),
            straggler_flags: snaps.iter().map(|s| s.stragglers.len()).sum(),
            last: snaps.last().cloned(),
        }
    }
}

/// Render a [`TelemetrySummary`] as the driver report's `"telemetry"`
/// JSON value.
pub fn summary_json(sum: &TelemetrySummary) -> String {
    json::object(&[
        ("ranks", json::num(sum.ranks as f64)),
        ("snapshot_words", json::num(sum.snapshot_words as f64)),
        ("snapshots", json::num(sum.snapshots as f64)),
        ("dropped_snapshots", json::num(sum.dropped_snapshots as f64)),
        ("telemetry_allocs", json::num(sum.telemetry_allocs as f64)),
        ("straggler_flags", json::num(sum.straggler_flags as f64)),
        (
            "last",
            sum.last
                .as_ref()
                .map(snapshot_json)
                .unwrap_or_else(|| "null".into()),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_regs() -> Vec<Registry> {
        (0..2)
            .map(|rank| {
                let mut reg = Registry::new(rank, 2);
                reg.counters[Counter::Collectives as usize] = 4 + rank as u64;
                reg.gauges[Gauge::PayloadWords as usize] = 2144;
                for v in [3u64, 900, 70] {
                    reg.hists[Hist::AllreduceNs as usize].observe(v);
                }
                reg
            })
            .collect()
    }

    #[test]
    fn prometheus_exposition_shape() {
        let regs = sample_regs();
        let out = prometheus_text(&regs);
        assert!(out.contains("# TYPE cabcd_collectives_total counter"));
        assert!(out.contains("cabcd_collectives_total{rank=\"0\"} 4"));
        assert!(out.contains("cabcd_collectives_total{rank=\"1\"} 5"));
        assert!(out.contains("# TYPE cabcd_payload_words gauge"));
        assert!(out.contains("# TYPE cabcd_allreduce_ns histogram"));
        assert!(out.contains("cabcd_allreduce_ns_bucket{rank=\"0\",le=\"+Inf\"} 3"));
        assert!(out.contains("cabcd_allreduce_ns_sum{rank=\"0\"} 973"));
        assert!(out.contains("cabcd_allreduce_ns_count{rank=\"0\"} 3"));
        // Cumulative buckets: le=3 holds the one observation ≤ 3.
        assert!(out.contains("cabcd_allreduce_ns_bucket{rank=\"0\",le=\"3\"} 1"));
        assert!(out.ends_with('\n'));
    }

    #[test]
    fn snapshots_json_stable_keys() {
        let mut regs = sample_regs();
        let mut blocks = vec![0.0; 2 * REGISTRY_WORDS];
        regs[0].write_block(&mut blocks[..REGISTRY_WORDS], 1000);
        regs[1].write_block(&mut blocks[REGISTRY_WORDS..], 1000);
        let snap = ClusterSnapshot::from_blocks(&blocks, 2, 3, 12, 1.25, 0);
        regs[0].push_snapshot(snap);
        let out = snapshots_json(&regs);
        for key in [
            "\"ranks\":2",
            "\"registry_words\":445",
            "\"snapshot_words\":890",
            "\"z_threshold\"",
            "\"min_dev_ns\"",
            "\"snapshots\":[{\"outer\":3",
            "\"at_collective\"",
            "\"fleet\"",
            "\"stragglers\"",
            "\"telemetry_allocs\":0",
            "\"straggler_flags\"",
        ] {
            assert!(out.contains(key), "missing {key} in {out}");
        }
    }

    #[test]
    fn summary_json_stable_keys() {
        let sum = TelemetrySummary::from_registries(&sample_regs());
        assert_eq!(sum.ranks, 2);
        assert_eq!(sum.snapshot_words, 890);
        assert_eq!(sum.snapshots, 0);
        let out = summary_json(&sum);
        for key in [
            "\"ranks\":2",
            "\"snapshot_words\":890",
            "\"snapshots\":0",
            "\"telemetry_allocs\":0",
            "\"last\":null",
        ] {
            assert!(out.contains(key), "missing {key} in {out}");
        }
    }
}
