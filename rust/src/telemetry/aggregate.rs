//! Cross-rank rollup: one meter-excluded collective turns P per-rank
//! registries into a [`ClusterSnapshot`] with straggler verdicts.
//!
//! On the record cadence the engine calls [`aggregate_snapshot`]: each
//! rank serializes its [`Registry`](super::Registry) into its
//! [`REGISTRY_WORDS`]-word slice of a `P·REGISTRY_WORDS` payload (zeros
//! elsewhere) and the group allreduces the payload — after which **every
//! rank** holds every rank's block and decodes the identical snapshot, so
//! no separate broadcast is needed and rank 0 is special only for the
//! live progress line. The collective rides the same exclusion pattern
//! as [`metered_out`](crate::solvers::common::metered_out): meters
//! snapshotted and restored, tracer paused, and telemetry itself paused
//! so the aggregation never observes its own traffic.
//!
//! # Straggler semantics
//!
//! Two per-rank totals are z-scored across the group at each snapshot:
//!
//! * **`gram`** — cumulative local-Gram time. A rank flagged *high*
//!   (`z ≥ threshold`) is compute-bound relative to its peers (skewed
//!   shard, slow core).
//! * **`wait`** — cumulative wire time (collective bodies + waits). A
//!   rank flagged *low* (`z ≤ −threshold`) is the *late arriver*: every
//!   peer burns wall-clock blocked in the collective waiting for it, so
//!   the straggler is the one rank that barely waits at all.
//!
//! A flag additionally requires the absolute deviation from the group
//! mean to exceed the configured floor
//! ([`DEFAULT_MIN_DEV_NS`](super::DEFAULT_MIN_DEV_NS)), so fault-free
//! runs with microsecond jitter never flag. Note the population z-score
//! of a single outlier among P ranks is bounded by `sqrt(P−1)`; the
//! default threshold ([`super::DEFAULT_Z_THRESHOLD`]) is set below that
//! bound on purpose.

use super::histogram::Histogram;
use super::{Counter, Hist, REGISTRY_WORDS};
use crate::comm::Communicator;
use crate::error::Result;
use crate::metrics::History;

/// p50/p99 pair from one histogram (bucket-resolution estimates clamped
/// to the exact max; see [`Histogram::quantile`]).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Quantiles {
    /// Median estimate.
    pub p50: u64,
    /// 99th-percentile estimate.
    pub p99: u64,
}

impl Quantiles {
    fn of(h: &Histogram) -> Quantiles {
        Quantiles {
            p50: h.quantile(0.5),
            p99: h.quantile(0.99),
        }
    }
}

/// One rank's health at a snapshot (or the fleet-wide rollup, where the
/// histograms are merged across ranks and the time shares are summed).
#[derive(Clone, Debug, PartialEq)]
pub struct RankHealth {
    /// Owning rank (`u32::MAX` marks the fleet rollup).
    pub rank: u32,
    /// Telemetry-epoch wall clock at serialization, ns (fleet: max).
    pub wall_ns: u64,
    /// Cumulative compute time: gram + inner solve + apply + sample, ns.
    pub compute_ns: u64,
    /// Cumulative wire time: collective bodies + waits + barriers, ns.
    pub wire_ns: u64,
    /// Wall time not accounted compute or wire, ns.
    pub idle_ns: u64,
    /// Cumulative collective payload words (allreduce + all-to-all).
    pub wire_words: u64,
    /// Local-Gram latency quantiles.
    pub gram: Quantiles,
    /// Allreduce latency quantiles.
    pub allreduce: Quantiles,
    /// All-to-all latency quantiles.
    pub all_to_all: Quantiles,
    /// Barrier latency quantiles.
    pub barrier: Quantiles,
    /// Non-blocking completion (`i*_wait`) latency quantiles.
    pub wait: Quantiles,
}

/// One straggler verdict: which rank, which metric, how far out.
#[derive(Clone, Debug, PartialEq)]
pub struct Straggler {
    /// Flagged rank.
    pub rank: u32,
    /// Deviating op class: `"gram"` (compute-bound, flagged high) or
    /// `"wait"` (late arriver, flagged low — see the module docs).
    pub op: &'static str,
    /// Population z-score of the rank's total against the group.
    pub z: f64,
    /// Signed deviation from the group mean, ns.
    pub dev_ns: i64,
    /// The flagged rank's metered-collective count at the snapshot —
    /// names *when* in the schedule the imbalance was observed.
    pub at_collective: u64,
}

/// Fleet-wide health at one record boundary, identically decoded on
/// every rank from the aggregation payload.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterSnapshot {
    /// Outer iterations completed when the snapshot was taken.
    pub outer: u64,
    /// Inner iterations completed (h).
    pub h: u64,
    /// Highest metered-collective count across ranks at the snapshot.
    pub at_collective: u64,
    /// Per-rank health, indexed by rank.
    pub ranks: Vec<RankHealth>,
    /// Fleet rollup: merged histograms, summed shares.
    pub fleet: RankHealth,
    /// Straggler verdicts (empty when the group is balanced).
    pub stragglers: Vec<Straggler>,
}

/// One decoded per-rank registry block.
struct Block {
    wall_ns: u64,
    counters: [u64; super::NUM_COUNTERS],
    hists: Vec<Histogram>,
}

fn decode_block(words: &[f64]) -> Block {
    let dec = |v: f64| -> u64 {
        if v > 0.0 {
            v as u64
        } else {
            0
        }
    };
    let mut counters = [0u64; super::NUM_COUNTERS];
    for (i, c) in counters.iter_mut().enumerate() {
        *c = dec(words[1 + i]);
    }
    let h0 = 1 + super::NUM_COUNTERS + super::NUM_GAUGES;
    let hists = (0..super::NUM_HISTS)
        .map(|i| Histogram::from_words(&words[h0 + i * Histogram::WORDS..]))
        .collect();
    Block {
        wall_ns: dec(words[0]),
        counters,
        hists,
    }
}

fn health_of(rank: u32, wall_ns: u64, hists: &[Histogram]) -> RankHealth {
    let sum = |h: Hist| hists[h as usize].sum();
    let compute_ns = sum(Hist::GramNs) + sum(Hist::InnerSolveNs) + sum(Hist::ApplyNs) + sum(Hist::SampleNs);
    let wire_ns =
        sum(Hist::AllreduceNs) + sum(Hist::AllToAllNs) + sum(Hist::BarrierNs) + sum(Hist::WaitNs);
    RankHealth {
        rank,
        wall_ns,
        compute_ns,
        wire_ns,
        idle_ns: wall_ns.saturating_sub(compute_ns + wire_ns),
        wire_words: sum(Hist::AllreduceWords) + sum(Hist::AllToAllWords),
        gram: Quantiles::of(&hists[Hist::GramNs as usize]),
        allreduce: Quantiles::of(&hists[Hist::AllreduceNs as usize]),
        all_to_all: Quantiles::of(&hists[Hist::AllToAllNs as usize]),
        barrier: Quantiles::of(&hists[Hist::BarrierNs as usize]),
        wait: Quantiles::of(&hists[Hist::WaitNs as usize]),
    }
}

/// Population mean and standard deviation of per-rank totals; `None`
/// when the group is degenerate (fewer than 2 ranks, or zero spread).
fn stats(vals: &[f64]) -> Option<(f64, f64)> {
    if vals.len() < 2 {
        return None;
    }
    let n = vals.len() as f64;
    let mean = vals.iter().sum::<f64>() / n;
    let var = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n;
    let std = var.sqrt();
    if std > 0.0 {
        Some((mean, std))
    } else {
        None
    }
}

/// Z-score one per-rank total and emit verdicts. `flag_high` selects the
/// tail that indicts a straggler for this metric: high for compute
/// totals, low for wire totals (the late arriver waits the least).
fn detect(
    vals: &[f64],
    op: &'static str,
    flag_high: bool,
    z_threshold: f64,
    min_dev_ns: u64,
    collectives: &[u64],
    out: &mut Vec<Straggler>,
) {
    let Some((mean, std)) = stats(vals) else {
        return;
    };
    for (rank, &v) in vals.iter().enumerate() {
        let dev = v - mean;
        let z = dev / std;
        let outlier = if flag_high { z >= z_threshold } else { z <= -z_threshold };
        if outlier && dev.abs() >= min_dev_ns as f64 {
            out.push(Straggler {
                rank: rank as u32,
                op,
                z,
                dev_ns: dev as i64,
                at_collective: collectives[rank],
            });
        }
    }
}

impl ClusterSnapshot {
    /// Decode the allreduced `P·REGISTRY_WORDS` payload into the
    /// snapshot every rank agrees on. Pure function of the payload and
    /// the thresholds — unit-testable without a communicator.
    pub fn from_blocks(
        buf: &[f64],
        p: usize,
        outer: u64,
        h: u64,
        z_threshold: f64,
        min_dev_ns: u64,
    ) -> ClusterSnapshot {
        debug_assert!(buf.len() >= p * REGISTRY_WORDS);
        let blocks: Vec<Block> = (0..p)
            .map(|r| decode_block(&buf[r * REGISTRY_WORDS..(r + 1) * REGISTRY_WORDS]))
            .collect();
        let ranks: Vec<RankHealth> = blocks
            .iter()
            .enumerate()
            .map(|(r, b)| health_of(r as u32, b.wall_ns, &b.hists))
            .collect();

        let mut fleet_hists = vec![Histogram::new(); super::NUM_HISTS];
        let mut fleet_wall = 0u64;
        for b in &blocks {
            fleet_wall = fleet_wall.max(b.wall_ns);
            for (i, fh) in fleet_hists.iter_mut().enumerate() {
                fh.merge(&b.hists[i]);
            }
        }
        let mut fleet = health_of(u32::MAX, fleet_wall, &fleet_hists);
        // Shares are per-rank sums; idle is the sum of per-rank idles
        // (max-wall minus summed busy time would double-count skew).
        fleet.idle_ns = ranks.iter().map(|r| r.idle_ns).sum();

        let collectives: Vec<u64> = blocks
            .iter()
            .map(|b| b.counters[Counter::Collectives as usize])
            .collect();
        let gram: Vec<f64> = blocks
            .iter()
            .map(|b| b.hists[Hist::GramNs as usize].sum() as f64)
            .collect();
        let wire: Vec<f64> = ranks.iter().map(|r| r.wire_ns as f64).collect();
        let mut stragglers = Vec::new();
        detect(&gram, "gram", true, z_threshold, min_dev_ns, &collectives, &mut stragglers);
        detect(&wire, "wait", false, z_threshold, min_dev_ns, &collectives, &mut stragglers);

        ClusterSnapshot {
            outer,
            h,
            at_collective: collectives.iter().copied().max().unwrap_or(0),
            ranks,
            fleet,
            stragglers,
        }
    }
}

/// The most recent convergence certificate in `history`, for the live
/// progress line: the prox duality gap when the run records
/// certificates, else the smooth objective error.
pub fn last_cert(history: &History) -> Option<f64> {
    history
        .prox
        .last()
        .map(|r| r.gap)
        .or_else(|| history.records.last().map(|r| r.obj_err))
}

/// Aggregate every rank's registry into a [`ClusterSnapshot`] with one
/// meter-excluded, trace-paused, telemetry-paused allreduce, store the
/// snapshot in each rank's registry, and (when the registry's live flag
/// is set) print the rank-0 progress line. No-op when telemetry is
/// disabled on this thread — the caller's `enabled()` check and this one
/// are both deterministic and rank-identical, so the collective stays in
/// lockstep.
pub fn aggregate_snapshot<C: Communicator>(
    comm: &mut C,
    outer: u64,
    h: u64,
    cert: Option<f64>,
) -> Result<()> {
    if !super::enabled() {
        return Ok(());
    }
    let p = comm.size();
    let rank = comm.rank();
    let Some((z_threshold, min_dev_ns, live)) =
        super::with_registry(|r| (r.z_threshold(), r.min_dev_ns(), r.live()))
    else {
        return Ok(());
    };
    // Same exclusion pattern as `metered_out`, plus telemetry's own
    // pause: the rollup must not meter, trace, or observe itself.
    let meter_snap = *comm.meter();
    let _trace_pause = crate::trace::pause();
    let _self_pause = super::pause();
    let wall = super::wall_ns();
    let mut buf = comm.take_buf(p * REGISTRY_WORDS);
    super::with_registry(|r| {
        r.write_block(&mut buf[rank * REGISTRY_WORDS..(rank + 1) * REGISTRY_WORDS], wall)
    });
    let res = comm.allreduce_sum(&mut buf);
    *comm.meter_mut() = meter_snap;
    if let Err(e) = res {
        comm.give_buf(buf);
        return Err(e);
    }
    let snap = ClusterSnapshot::from_blocks(&buf, p, outer, h, z_threshold, min_dev_ns);
    comm.give_buf(buf);
    if live && rank == 0 {
        print_live(&snap, cert);
    }
    super::store_snapshot(snap);
    Ok(())
}

/// The rank-0 live progress line (stderr, so `--json` stdout stays
/// machine-readable).
fn print_live(snap: &ClusterSnapshot, cert: Option<f64>) {
    let secs = snap.fleet.wall_ns as f64 / 1e9;
    let words_per_s = if secs > 0.0 {
        snap.fleet.wire_words as f64 / secs
    } else {
        0.0
    };
    let cert = cert
        .map(|c| format!("{c:.3e}"))
        .unwrap_or_else(|| "-".into());
    let stragglers = if snap.stragglers.is_empty() {
        "none".to_string()
    } else {
        snap.stragglers
            .iter()
            .map(|s| format!("r{}:{}(z={:+.2})", s.rank, s.op, s.z))
            .collect::<Vec<_>>()
            .join(",")
    };
    eprintln!(
        "[telemetry] outer={} h={} cert={} wire={:.0} words/s stragglers={}",
        snap.outer, snap.h, cert, words_per_s, stragglers
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::SerialComm;

    /// Build a P-rank aggregation payload from synthetic per-rank
    /// (gram_ns, wire_ns, collectives) triples.
    fn payload(specs: &[(u64, u64, u64)]) -> Vec<f64> {
        let mut buf = vec![0.0; specs.len() * REGISTRY_WORDS];
        for (rank, &(gram, wire, colls)) in specs.iter().enumerate() {
            let mut reg = super::super::Registry::new(rank, specs.len());
            reg.counters[Counter::Collectives as usize] = colls;
            reg.hists[Hist::GramNs as usize].observe(gram);
            reg.hists[Hist::AllreduceNs as usize].observe(wire);
            reg.write_block(
                &mut buf[rank * REGISTRY_WORDS..(rank + 1) * REGISTRY_WORDS],
                gram + wire + 50,
            );
        }
        buf
    }

    #[test]
    fn balanced_group_flags_nothing() {
        let ms = 1_000_000; // 1ms per op, jitter below the 10ms floor
        let buf = payload(&[(ms, ms, 6), (ms + 99, ms, 6), (ms, ms + 99, 6), (ms, ms, 6)]);
        let snap = ClusterSnapshot::from_blocks(
            &buf,
            4,
            6,
            24,
            super::super::DEFAULT_Z_THRESHOLD,
            super::super::DEFAULT_MIN_DEV_NS,
        );
        assert!(snap.stragglers.is_empty(), "{:?}", snap.stragglers);
        assert_eq!(snap.ranks.len(), 4);
        assert_eq!(snap.at_collective, 6);
        assert_eq!(snap.fleet.rank, u32::MAX);
        assert_eq!(snap.fleet.wire_words, 0);
    }

    #[test]
    fn slow_gram_rank_is_flagged_high() {
        let ms = 1_000_000;
        // Rank 1 spends 100ms in gram vs 1ms peers.
        let buf = payload(&[(ms, ms, 9), (100 * ms, ms, 9), (ms, ms, 9), (ms, ms, 9)]);
        let snap = ClusterSnapshot::from_blocks(&buf, 4, 3, 12, 1.25, 10_000_000);
        assert_eq!(snap.stragglers.len(), 1, "{:?}", snap.stragglers);
        let s = &snap.stragglers[0];
        assert_eq!(s.rank, 1);
        assert_eq!(s.op, "gram");
        assert!(s.z > 1.25 && s.z < 1.7321, "one outlier of 4 → z≈√3: {}", s.z);
        assert!(s.dev_ns > 0);
        assert_eq!(s.at_collective, 9);
    }

    #[test]
    fn late_arriver_is_flagged_low_on_wait() {
        let ms = 1_000_000;
        // Peers burn 80ms waiting for rank 2; rank 2 itself barely waits.
        let buf = payload(&[(ms, 80 * ms, 5), (ms, 80 * ms, 5), (ms, ms, 5), (ms, 80 * ms, 5)]);
        let snap = ClusterSnapshot::from_blocks(&buf, 4, 2, 8, 1.25, 10_000_000);
        assert_eq!(snap.stragglers.len(), 1, "{:?}", snap.stragglers);
        let s = &snap.stragglers[0];
        assert_eq!(s.rank, 2);
        assert_eq!(s.op, "wait");
        assert!(s.z < -1.25);
        assert!(s.dev_ns < 0);
    }

    #[test]
    fn zero_spread_and_tiny_groups_are_degenerate() {
        let buf = payload(&[(5, 5, 1), (5, 5, 1)]);
        let snap = ClusterSnapshot::from_blocks(&buf, 2, 1, 4, 1.25, 0);
        assert!(snap.stragglers.is_empty(), "zero std must not divide");
        let buf1 = payload(&[(1_000_000_000, 0, 1)]);
        let snap1 = ClusterSnapshot::from_blocks(&buf1, 1, 1, 4, 1.25, 0);
        assert!(snap1.stragglers.is_empty(), "P=1 has no peers to deviate from");
    }

    #[test]
    fn shares_decompose_wall() {
        let buf = payload(&[(30, 20, 2), (10, 40, 2)]);
        let snap = ClusterSnapshot::from_blocks(&buf, 2, 1, 2, 1.25, 0);
        let r0 = &snap.ranks[0];
        assert_eq!(r0.compute_ns, 30);
        assert_eq!(r0.wire_ns, 20);
        assert_eq!(r0.idle_ns, 50, "wall was gram+wire+50");
        assert_eq!(snap.fleet.compute_ns, 40);
        assert_eq!(snap.fleet.wire_ns, 60);
        assert_eq!(snap.fleet.wall_ns, 100, "fleet wall is the max");
    }

    #[test]
    fn aggregate_on_serial_comm_is_meter_neutral_and_stores() {
        let mut comm = SerialComm::new();
        let before = *comm.meter();
        // Disabled: no-op.
        aggregate_snapshot(&mut comm, 1, 4, None).unwrap();
        assert_eq!(*comm.meter(), before);
        super::super::install(super::super::Registry::new(0, 1));
        super::super::observe(Hist::GramNs, 123);
        aggregate_snapshot(&mut comm, 1, 4, Some(1e-3)).unwrap();
        assert_eq!(*comm.meter(), before, "aggregation must be meter-excluded");
        let Some(reg) = super::super::take() else {
            panic!("registry was installed");
        };
        assert_eq!(reg.snapshots().len(), 1);
        let snap = &reg.snapshots()[0];
        assert_eq!(snap.outer, 1);
        assert_eq!(snap.ranks[0].compute_ns, 123);
        assert_eq!(reg.telemetry_allocs(), 0);
    }
}
