//! Log2-bucketed histogram with exact-count semantics.
//!
//! The registry's latency and payload-size distributions all use this one
//! shape: [`BUCKETS`] power-of-two buckets (bucket `i` covers
//! `[2^i, 2^{i+1})`, bucket 0 additionally absorbs 0 and 1, the last
//! bucket absorbs everything above `2^BUCKETS`) **plus** exact `count`,
//! `sum`, `min`, and `max` — so totals and means are exact while
//! quantiles are bucket-resolution estimates (within a factor of 2, which
//! is all a straggler/imbalance verdict needs).
//!
//! Everything is inline fixed-size state: observing never allocates, and
//! a histogram serializes to exactly [`Histogram::WORDS`] `f64` words for
//! the cross-rank aggregation allreduce ([`super::aggregate`]).

/// Number of log2 buckets. 32 buckets cover `[1, 2^32)` ns ≈ 4.3 s per
/// event — far above any in-repo span — before the overflow bucket.
pub const BUCKETS: usize = 32;

/// A log2-bucketed distribution with exact count/sum/min/max sidecars.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Histogram {
    count: u64,
    sum: u64,
    /// `u64::MAX` while empty (never serialized that way; see
    /// [`Histogram::write_words`]).
    min: u64,
    max: u64,
    buckets: [u64; BUCKETS],
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
            buckets: [0; BUCKETS],
        }
    }
}

impl Histogram {
    /// `f64` words one histogram occupies in the aggregation payload:
    /// count, sum, min, max, then the buckets.
    pub const WORDS: usize = 4 + BUCKETS;

    /// A fresh, empty histogram.
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Bucket index holding `v`: `floor(log2(v))` clamped into
    /// `0..BUCKETS` (0 and 1 land in bucket 0).
    pub fn bucket_of(v: u64) -> usize {
        if v <= 1 {
            0
        } else {
            ((63 - v.leading_zeros()) as usize).min(BUCKETS - 1)
        }
    }

    /// Inclusive upper bound of bucket `i` (`u64::MAX` for the overflow
    /// bucket — exported as `+Inf` by the Prometheus exposition).
    pub fn le(i: usize) -> u64 {
        if i >= BUCKETS - 1 {
            u64::MAX
        } else {
            (1u64 << (i + 1)) - 1
        }
    }

    /// Record one value.
    pub fn observe(&mut self, v: u64) {
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        self.buckets[Self::bucket_of(v)] += 1;
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact sum of all observations.
    pub fn sum(&self) -> u64 {
        self.sum
    }

    /// Smallest observation (0 while empty).
    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    /// Largest observation (0 while empty).
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Raw count of bucket `i`.
    pub fn bucket(&self, i: usize) -> u64 {
        self.buckets[i]
    }

    /// Mean observation (0.0 while empty) — exact, from the sidecars.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Quantile estimate: the inclusive upper bound of the first bucket
    /// whose cumulative count reaches `ceil(q·count)`, clamped to the
    /// exact `max` (so `quantile(1.0) == max`). 0 while empty.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for i in 0..BUCKETS {
            cum += self.buckets[i];
            if cum >= target {
                return Self::le(i).min(self.max);
            }
        }
        self.max
    }

    /// Fold `other` into `self` (bucket-wise sum, exact sidecar merge).
    pub fn merge(&mut self, other: &Histogram) {
        if other.count == 0 {
            return;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        for i in 0..BUCKETS {
            self.buckets[i] += other.buckets[i];
        }
    }

    /// Serialize into `out` (length [`Histogram::WORDS`]) as `f64` words
    /// for the aggregation payload: `[count, sum, min, max, buckets…]`.
    /// An empty histogram writes `min` as 0, so the payload never carries
    /// the `u64::MAX` sentinel (which would not survive an `f64` sum).
    pub fn write_words(&self, out: &mut [f64]) {
        debug_assert!(out.len() >= Self::WORDS);
        out[0] = self.count as f64;
        out[1] = self.sum as f64;
        out[2] = self.min() as f64;
        out[3] = self.max as f64;
        for i in 0..BUCKETS {
            out[4 + i] = self.buckets[i] as f64;
        }
    }

    /// Decode a [`Histogram::write_words`] block (the aggregation
    /// receive path). Values are clamped at 0 — a corrupt negative word
    /// decodes as empty rather than wrapping.
    pub fn from_words(words: &[f64]) -> Histogram {
        debug_assert!(words.len() >= Self::WORDS);
        let dec = |v: f64| -> u64 {
            if v > 0.0 {
                v as u64
            } else {
                0
            }
        };
        let count = dec(words[0]);
        let mut h = Histogram {
            count,
            sum: dec(words[1]),
            min: if count == 0 { u64::MAX } else { dec(words[2]) },
            max: dec(words[3]),
            buckets: [0; BUCKETS],
        };
        for i in 0..BUCKETS {
            h.buckets[i] = dec(words[4 + i]);
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries() {
        assert_eq!(Histogram::bucket_of(0), 0);
        assert_eq!(Histogram::bucket_of(1), 0);
        assert_eq!(Histogram::bucket_of(2), 1);
        assert_eq!(Histogram::bucket_of(3), 1);
        assert_eq!(Histogram::bucket_of(4), 2);
        assert_eq!(Histogram::bucket_of((1 << 31) - 1), 30);
        assert_eq!(Histogram::bucket_of(1 << 31), 31);
        assert_eq!(Histogram::bucket_of(u64::MAX), BUCKETS - 1);
        assert_eq!(Histogram::le(0), 1);
        assert_eq!(Histogram::le(1), 3);
        assert_eq!(Histogram::le(30), (1 << 31) - 1);
        assert_eq!(Histogram::le(BUCKETS - 1), u64::MAX);
    }

    #[test]
    fn exact_sidecars_and_quantiles() {
        let mut h = Histogram::new();
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.min(), 0);
        for v in [3u64, 5, 9, 1000] {
            h.observe(v);
        }
        assert_eq!(h.count(), 4);
        assert_eq!(h.sum(), 1017);
        assert_eq!(h.min(), 3);
        assert_eq!(h.max(), 1000);
        assert_eq!(h.mean(), 1017.0 / 4.0);
        // Buckets: 3→1, 5→2, 9→3, 1000→9.
        assert_eq!(h.bucket(1), 1);
        assert_eq!(h.bucket(2), 1);
        assert_eq!(h.bucket(3), 1);
        assert_eq!(h.bucket(9), 1);
        // p50 target=2 → bucket 2 (cum 2) → le=7; p99 target=4 → bucket 9
        // → le=1023, clamped to max=1000; p100 == max exactly.
        assert_eq!(h.quantile(0.5), 7);
        assert_eq!(h.quantile(0.99), 1000);
        assert_eq!(h.quantile(1.0), 1000);
    }

    #[test]
    fn merge_matches_combined_observation() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut both = Histogram::new();
        for v in [1u64, 10, 100] {
            a.observe(v);
            both.observe(v);
        }
        for v in [7u64, 70] {
            b.observe(v);
            both.observe(v);
        }
        a.merge(&b);
        assert_eq!(a, both);
        // Merging an empty histogram is the identity.
        a.merge(&Histogram::new());
        assert_eq!(a, both);
    }

    #[test]
    fn words_roundtrip() {
        let mut h = Histogram::new();
        for v in [0u64, 1, 2, 4095, 1 << 40] {
            h.observe(v);
        }
        let mut words = [0.0f64; Histogram::WORDS];
        h.write_words(&mut words);
        assert_eq!(Histogram::from_words(&words), h);
        // Empty roundtrip: min serializes as 0, decodes back to empty.
        let e = Histogram::new();
        e.write_words(&mut words);
        assert_eq!(words[2], 0.0);
        assert_eq!(Histogram::from_words(&words), e);
    }
}
