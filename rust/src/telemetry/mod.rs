#![warn(missing_docs)]
//! Cross-rank runtime telemetry: a zero-dependency metrics registry with
//! counters, gauges, and log2-bucketed histograms, aggregated over the
//! wire into cluster health snapshots.
//!
//! [`crate::trace`] records *individual* spans for post-hoc timeline
//! analysis; this module keeps *running aggregates* cheap enough to
//! export live — per-op-class latency distributions, payload-size
//! distributions, retry/timeout/checkpoint counters — and rolls every
//! rank's registry up into a [`ClusterSnapshot`] on the record cadence so
//! a fleet scheduler (or the rank-0 progress line) can spot a straggler
//! while the run is still going.
//!
//! # Metric taxonomy
//!
//! | kind      | members | semantics |
//! |-----------|---------|-----------|
//! | [`Counter`] | outers, inners, records, collectives, retries, timeouts, ckpt saves/restores | monotone totals |
//! | [`Gauge`]   | last outer, last h, in-flight window ns, last payload words | last-write-wins |
//! | [`Hist`]    | gram/inner-solve/apply/sample ns, per-collective-class ns + payload words, wait ns, checkpoint save/restore ns | [`Histogram`]: log2 buckets + exact count/sum/min/max |
//!
//! # Discipline (mirrors `trace/`)
//!
//! One [`Registry`] per rank thread, installed with [`install`] and
//! reclaimed with [`take`]; every observe path is a no-op costing two
//! thread-local reads when nothing is installed. All registry state is
//! inline fixed-size arrays — the observe hot path performs **zero heap
//! allocation**; only the bounded snapshot store can allocate, guarded by
//! the [`Registry::telemetry_allocs`] tripwire the bench gates at 0.
//! [`pause`] suspends recording (RAII, nests) so meter-excluded
//! diagnostic traffic — and the aggregation collective itself — stays
//! invisible, exactly like the tracer's pause under
//! [`metered_out`](crate::solvers::common::metered_out).
//!
//! Telemetry owns its own monotonic clock (epoch = first read), separate
//! from the tracer's, so either subsystem works alone.
//!
//! # Aggregation & export
//!
//! [`aggregate::aggregate_snapshot`] flattens the registry into
//! [`REGISTRY_WORDS`] `f64` words, allreduces the per-rank blocks
//! (meter-excluded, trace-paused, telemetry-paused), and decodes the
//! same [`ClusterSnapshot`] on every rank: per-rank and fleet-wide
//! p50/p99 per op class, compute/wire/idle shares, and z-score straggler
//! flags. [`export`] renders Prometheus text exposition, the
//! `--telemetry` JSON snapshot file, and the compact `"telemetry"`
//! section of the driver report.

pub mod aggregate;
pub mod export;
pub mod histogram;

pub use aggregate::{aggregate_snapshot, ClusterSnapshot, Quantiles, RankHealth, Straggler};
pub use export::{prometheus_text, snapshots_json, summary_json, TelemetrySummary};
pub use histogram::Histogram;

use std::cell::{Cell, RefCell};
use std::sync::OnceLock;
use std::time::Instant;

/// Number of [`Counter`] slots.
pub const NUM_COUNTERS: usize = 8;
/// Number of [`Gauge`] slots.
pub const NUM_GAUGES: usize = 4;
/// Number of [`Hist`] slots.
pub const NUM_HISTS: usize = 12;

/// `f64` words one rank's registry occupies in the aggregation payload:
/// wall-clock ns, the counters, the gauges, then the histograms.
pub const REGISTRY_WORDS: usize = 1 + NUM_COUNTERS + NUM_GAUGES + NUM_HISTS * Histogram::WORDS;

/// Snapshots retained per registry before [`Registry::dropped_snapshots`]
/// starts counting (the newest snapshot always replaces the last slot).
pub const SNAPSHOT_CAPACITY: usize = 256;

/// Default straggler z-score threshold. The population z of a single
/// outlier among P ranks is bounded by `sqrt(P−1)` (1.73 at P = 4), so a
/// "3-sigma" default would never fire; 1.25 flags the lone outlier at
/// P ≥ 3 while its peers sit below 0.6.
pub const DEFAULT_Z_THRESHOLD: f64 = 1.25;

/// Default absolute deviation floor (10 ms): a rank is only flagged when
/// its deviation from the mean also exceeds this, so fault-free runs with
/// microsecond-scale jitter never flag.
pub const DEFAULT_MIN_DEV_NS: u64 = 10_000_000;

/// Monotone event totals.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Counter {
    /// Completed outer iterations.
    Outers,
    /// Completed inner iterations (s per outer).
    Inners,
    /// Convergence records taken.
    Records,
    /// Metered collective entries (allreduce/all-to-all/broadcast/
    /// barrier starts; completions are not separate entries).
    Collectives,
    /// Transient-fault retries ([`crate::comm::ChaosComm`]).
    Retries,
    /// Receive-deadline expiries ([`crate::comm::ThreadComm`]).
    Timeouts,
    /// Checkpoint captures stored.
    CkptSaves,
    /// Checkpoint restores applied.
    CkptRestores,
}

impl Counter {
    /// All counters, in registry/serialization order.
    pub const ALL: [Counter; NUM_COUNTERS] = [
        Counter::Outers,
        Counter::Inners,
        Counter::Records,
        Counter::Collectives,
        Counter::Retries,
        Counter::Timeouts,
        Counter::CkptSaves,
        Counter::CkptRestores,
    ];

    /// Stable snake_case name (JSON keys, Prometheus metric names).
    pub fn name(self) -> &'static str {
        match self {
            Counter::Outers => "outers",
            Counter::Inners => "inners",
            Counter::Records => "records",
            Counter::Collectives => "collectives",
            Counter::Retries => "retries",
            Counter::Timeouts => "timeouts",
            Counter::CkptSaves => "ckpt_saves",
            Counter::CkptRestores => "ckpt_restores",
        }
    }
}

/// Last-write-wins instantaneous values.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Gauge {
    /// Most recently completed outer iteration (1-based).
    LastOuter,
    /// Inner-iteration count h at the last boundary.
    LastH,
    /// Width of the last overlapped in-flight window
    /// (`i*_start` → `i*_wait`), ns.
    InflightNs,
    /// Payload words of the last allreduce entry.
    PayloadWords,
}

impl Gauge {
    /// All gauges, in registry/serialization order.
    pub const ALL: [Gauge; NUM_GAUGES] = [
        Gauge::LastOuter,
        Gauge::LastH,
        Gauge::InflightNs,
        Gauge::PayloadWords,
    ];

    /// Stable snake_case name (JSON keys, Prometheus metric names).
    pub fn name(self) -> &'static str {
        match self {
            Gauge::LastOuter => "last_outer",
            Gauge::LastH => "last_h",
            Gauge::InflightNs => "inflight_ns",
            Gauge::PayloadWords => "payload_words",
        }
    }
}

/// Histogram-tracked distributions (latencies in ns, payloads in words).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Hist {
    /// Local Gram / payload assembly time per outer iteration.
    GramNs,
    /// Replicated inner-solve time.
    InnerSolveNs,
    /// Iterate-update (apply) time.
    ApplyNs,
    /// Shared-seed block-sampling time.
    SampleNs,
    /// Allreduce-family collective latency (blocking protocol body, or
    /// the start call of a non-blocking pair).
    AllreduceNs,
    /// All-to-all-family collective latency.
    AllToAllNs,
    /// Barrier latency.
    BarrierNs,
    /// Non-blocking completion (`i*_wait`) latency.
    WaitNs,
    /// Allreduce payload sizes, words.
    AllreduceWords,
    /// All-to-all payload sizes, words.
    AllToAllWords,
    /// Checkpoint capture+store time.
    CkptSaveNs,
    /// Checkpoint restore time.
    CkptRestoreNs,
}

impl Hist {
    /// All histograms, in registry/serialization order.
    pub const ALL: [Hist; NUM_HISTS] = [
        Hist::GramNs,
        Hist::InnerSolveNs,
        Hist::ApplyNs,
        Hist::SampleNs,
        Hist::AllreduceNs,
        Hist::AllToAllNs,
        Hist::BarrierNs,
        Hist::WaitNs,
        Hist::AllreduceWords,
        Hist::AllToAllWords,
        Hist::CkptSaveNs,
        Hist::CkptRestoreNs,
    ];

    /// Stable snake_case name (JSON keys, Prometheus metric names).
    pub fn name(self) -> &'static str {
        match self {
            Hist::GramNs => "gram_ns",
            Hist::InnerSolveNs => "inner_solve_ns",
            Hist::ApplyNs => "apply_ns",
            Hist::SampleNs => "sample_ns",
            Hist::AllreduceNs => "allreduce_ns",
            Hist::AllToAllNs => "all_to_all_ns",
            Hist::BarrierNs => "barrier_ns",
            Hist::WaitNs => "wait_ns",
            Hist::AllreduceWords => "allreduce_words",
            Hist::AllToAllWords => "all_to_all_words",
            Hist::CkptSaveNs => "ckpt_save_ns",
            Hist::CkptRestoreNs => "ckpt_restore_ns",
        }
    }
}

/// One rank's metrics registry. All observation state is inline
/// fixed-size arrays (the observe path never allocates); the bounded
/// snapshot store is the only growable member, guarded by the
/// [`Registry::telemetry_allocs`] tripwire.
#[derive(Debug)]
pub struct Registry {
    rank: u32,
    ranks: u32,
    counters: [u64; NUM_COUNTERS],
    gauges: [u64; NUM_GAUGES],
    hists: [Histogram; NUM_HISTS],
    snapshots: Vec<ClusterSnapshot>,
    dropped_snapshots: u64,
    telemetry_allocs: u64,
    z_threshold: f64,
    min_dev_ns: u64,
    live: bool,
}

impl Registry {
    /// A fresh registry for `rank` of `ranks` with default straggler
    /// thresholds and the live progress line off.
    pub fn new(rank: usize, ranks: usize) -> Registry {
        Registry {
            rank: rank as u32,
            ranks: ranks as u32,
            counters: [0; NUM_COUNTERS],
            gauges: [0; NUM_GAUGES],
            hists: [Histogram::new(); NUM_HISTS],
            snapshots: Vec::with_capacity(SNAPSHOT_CAPACITY),
            dropped_snapshots: 0,
            telemetry_allocs: 0,
            z_threshold: DEFAULT_Z_THRESHOLD,
            min_dev_ns: DEFAULT_MIN_DEV_NS,
            live: false,
        }
    }

    /// Override the straggler z-score threshold (builder-style).
    pub fn with_z_threshold(mut self, z: f64) -> Registry {
        self.z_threshold = z;
        self
    }

    /// Override the absolute deviation floor in ns (builder-style).
    pub fn with_min_dev_ns(mut self, ns: u64) -> Registry {
        self.min_dev_ns = ns;
        self
    }

    /// Enable the rank-0 live progress line at each aggregation
    /// (builder-style; the driver sets this, tests leave it off).
    pub fn with_live(mut self, live: bool) -> Registry {
        self.live = live;
        self
    }

    /// Rank this registry records for.
    pub fn rank(&self) -> u32 {
        self.rank
    }

    /// Group size at construction.
    pub fn ranks(&self) -> u32 {
        self.ranks
    }

    /// Current value of `c`.
    pub fn counter(&self, c: Counter) -> u64 {
        self.counters[c as usize]
    }

    /// Current value of `g`.
    pub fn gauge(&self, g: Gauge) -> u64 {
        self.gauges[g as usize]
    }

    /// The distribution behind `h`.
    pub fn hist(&self, h: Hist) -> &Histogram {
        &self.hists[h as usize]
    }

    /// Straggler z-score threshold in effect.
    pub fn z_threshold(&self) -> f64 {
        self.z_threshold
    }

    /// Absolute straggler deviation floor in effect, ns.
    pub fn min_dev_ns(&self) -> u64 {
        self.min_dev_ns
    }

    /// Whether the rank-0 live progress line is enabled.
    pub fn live(&self) -> bool {
        self.live
    }

    /// Cluster snapshots accumulated on the record cadence (identical on
    /// every rank — each rank decodes the same allreduced payload).
    pub fn snapshots(&self) -> &[ClusterSnapshot] {
        &self.snapshots
    }

    /// Snapshots lost to the bounded store (newest replaced the last
    /// slot).
    pub fn dropped_snapshots(&self) -> u64 {
        self.dropped_snapshots
    }

    /// Steady-state allocation tripwire: counts capacity growth of the
    /// snapshot store, 0 for any correctly sized run (the bench gates
    /// `telemetry_allocs_steady_state` at exactly 0). The observe paths
    /// are structurally alloc-free (inline arrays), so this is the only
    /// thing the tripwire can catch.
    pub fn telemetry_allocs(&self) -> u64 {
        self.telemetry_allocs
    }

    fn push_snapshot(&mut self, snap: ClusterSnapshot) {
        let cap_before = self.snapshots.capacity();
        if self.snapshots.len() < SNAPSHOT_CAPACITY {
            self.snapshots.push(snap);
        } else if let Some(last) = self.snapshots.last_mut() {
            *last = snap;
            self.dropped_snapshots += 1;
        }
        if self.snapshots.capacity() != cap_before {
            self.telemetry_allocs += 1;
        }
    }

    /// `f64` words of the [`Self::export_words`] encoding: 7 header words
    /// (rank, ranks, z threshold, min dev, dropped snapshots, allocs,
    /// live), the counters, the gauges, and the histograms.
    pub const EXPORT_WORDS: usize = 7 + NUM_COUNTERS + NUM_GAUGES + NUM_HISTS * Histogram::WORDS;

    /// Serialize the full registry (minus the snapshot store) for
    /// cross-process gathering: integer fields travel as raw bit patterns
    /// (`f64::from_bits`), so the round trip through the comm layer is
    /// exact. Snapshots are deliberately excluded — every rank already
    /// decodes identical [`ClusterSnapshot`]s from the aggregation
    /// allreduce, so the gathering side reads them from its own registry.
    pub fn export_words(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(Self::EXPORT_WORDS);
        let w = |x: u64| f64::from_bits(x);
        out.push(w(self.rank as u64));
        out.push(w(self.ranks as u64));
        out.push(self.z_threshold);
        out.push(w(self.min_dev_ns));
        out.push(w(self.dropped_snapshots));
        out.push(w(self.telemetry_allocs));
        out.push(w(self.live as u64));
        for c in &self.counters {
            out.push(w(*c));
        }
        for g in &self.gauges {
            out.push(w(*g));
        }
        let mut block = [0.0; Histogram::WORDS];
        for h in &self.hists {
            h.write_words(&mut block);
            out.extend_from_slice(&block);
        }
        out
    }

    /// Reconstruct a registry from [`Self::export_words`] output (empty
    /// snapshot store). `None` on a malformed blob.
    pub fn from_export_words(words: &[f64]) -> Option<Registry> {
        if words.len() != Self::EXPORT_WORDS {
            return None;
        }
        let u = |x: f64| x.to_bits();
        let mut reg = Registry::new(u(words[0]) as usize, u(words[1]) as usize);
        reg.z_threshold = words[2];
        reg.min_dev_ns = u(words[3]);
        reg.dropped_snapshots = u(words[4]);
        reg.telemetry_allocs = u(words[5]);
        reg.live = u(words[6]) != 0;
        let c0 = 7;
        for (i, c) in reg.counters.iter_mut().enumerate() {
            *c = u(words[c0 + i]);
        }
        let g0 = c0 + NUM_COUNTERS;
        for (i, g) in reg.gauges.iter_mut().enumerate() {
            *g = u(words[g0 + i]);
        }
        let h0 = g0 + NUM_GAUGES;
        for (i, h) in reg.hists.iter_mut().enumerate() {
            *h = Histogram::from_words(
                &words[h0 + i * Histogram::WORDS..h0 + (i + 1) * Histogram::WORDS],
            );
        }
        Some(reg)
    }

    /// Serialize this registry into its aggregation block (length
    /// [`REGISTRY_WORDS`]): `[wall_ns | counters | gauges | histograms]`.
    pub fn write_block(&self, out: &mut [f64], wall_ns: u64) {
        debug_assert!(out.len() >= REGISTRY_WORDS);
        out[0] = wall_ns as f64;
        for (i, c) in Counter::ALL.iter().enumerate() {
            out[1 + i] = self.counters[*c as usize] as f64;
        }
        let g0 = 1 + NUM_COUNTERS;
        for (i, g) in Gauge::ALL.iter().enumerate() {
            out[g0 + i] = self.gauges[*g as usize] as f64;
        }
        let h0 = g0 + NUM_GAUGES;
        for (i, h) in Hist::ALL.iter().enumerate() {
            self.hists[*h as usize]
                .write_words(&mut out[h0 + i * Histogram::WORDS..h0 + (i + 1) * Histogram::WORDS]);
        }
    }
}

thread_local! {
    static REGISTRY: RefCell<Option<Registry>> = const { RefCell::new(None) };
    static ACTIVE: Cell<bool> = const { Cell::new(false) };
    static PAUSE_DEPTH: Cell<u32> = const { Cell::new(0) };
}

static EPOCH: OnceLock<Instant> = OnceLock::new();

fn clock_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// Install a registry on the current thread (one per rank thread; the
/// driver installs inside the `run_spmd` closure). Replaces and returns
/// any previously installed registry.
pub fn install(registry: Registry) -> Option<Registry> {
    ACTIVE.with(|a| a.set(true));
    REGISTRY.with(|r| r.borrow_mut().replace(registry))
}

/// Remove and return the current thread's registry.
pub fn take() -> Option<Registry> {
    ACTIVE.with(|a| a.set(false));
    REGISTRY.with(|r| r.borrow_mut().take())
}

/// True when metrics are being recorded on this thread (installed and
/// not inside a [`pause`] scope). All observe paths are no-ops
/// otherwise, so instrumented code pays two thread-local reads when
/// telemetry is off.
pub fn enabled() -> bool {
    ACTIVE.with(|a| a.get()) && PAUSE_DEPTH.with(|p| p.get()) == 0
}

/// Timestamp for an upcoming [`observe_since`] call; 0 (and no clock
/// read) when telemetry is disabled.
pub fn now() -> u64 {
    if enabled() {
        clock_ns()
    } else {
        0
    }
}

/// Add `n` to counter `c`.
pub fn count(c: Counter, n: u64) {
    if !enabled() {
        return;
    }
    REGISTRY.with(|r| {
        if let Some(reg) = r.borrow_mut().as_mut() {
            reg.counters[c as usize] += n;
        }
    });
}

/// Set gauge `g` to `v`.
pub fn gauge(g: Gauge, v: u64) {
    if !enabled() {
        return;
    }
    REGISTRY.with(|r| {
        if let Some(reg) = r.borrow_mut().as_mut() {
            reg.gauges[g as usize] = v;
        }
    });
}

/// Record `v` into histogram `h`.
pub fn observe(h: Hist, v: u64) {
    if !enabled() {
        return;
    }
    REGISTRY.with(|r| {
        if let Some(reg) = r.borrow_mut().as_mut() {
            reg.hists[h as usize].observe(v);
        }
    });
}

/// Record the elapsed ns since `t0` (from [`now`]) into histogram `h`.
pub fn observe_since(h: Hist, t0: u64) {
    if !enabled() {
        return;
    }
    let v = clock_ns().saturating_sub(t0);
    observe(h, v);
}

/// Run `f` against the installed registry, if any (aggregation and
/// export paths).
pub(crate) fn with_registry<T>(f: impl FnOnce(&mut Registry) -> T) -> Option<T> {
    REGISTRY.with(|r| r.borrow_mut().as_mut().map(f))
}

/// Suspends metric recording on this thread until the guard drops. Used
/// by [`metered_out`](crate::solvers::common::metered_out) (diagnostic
/// traffic excluded from the meters is also excluded from telemetry) and
/// by the aggregation collective itself. Nests, and composes with
/// [`crate::trace::pause`].
pub fn pause() -> PauseGuard {
    PAUSE_DEPTH.with(|p| p.set(p.get() + 1));
    PauseGuard
}

/// True while the current thread is inside a [`pause`] scope.
pub fn paused() -> bool {
    PAUSE_DEPTH.with(|p| p.get() > 0)
}

/// RAII guard returned by [`pause`]; recording resumes when it drops.
pub struct PauseGuard;

impl Drop for PauseGuard {
    fn drop(&mut self) {
        PAUSE_DEPTH.with(|p| p.set(p.get().saturating_sub(1)));
    }
}

/// Epoch-relative wall clock, read even while paused (aggregation
/// stamps its block after pausing itself).
pub(crate) fn wall_ns() -> u64 {
    clock_ns()
}

/// Append a freshly decoded snapshot to the installed registry
/// (aggregation path).
pub(crate) fn store_snapshot(snap: ClusterSnapshot) {
    REGISTRY.with(|r| {
        if let Some(reg) = r.borrow_mut().as_mut() {
            reg.push_snapshot(snap);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_words_layout_is_fixed() {
        // 1 wall word + 8 counters + 4 gauges + 12 × (4 + 32) histogram
        // words. The aggregation payload (`P · REGISTRY_WORDS`) and the
        // BENCH gate both depend on this exact value.
        assert_eq!(REGISTRY_WORDS, 1 + 8 + 4 + 12 * 36);
        assert_eq!(REGISTRY_WORDS, 445);
    }

    #[test]
    fn install_observe_take_roundtrip() {
        assert!(!enabled());
        // Disabled: everything is a no-op, now() skips the clock.
        count(Counter::Outers, 1);
        observe(Hist::GramNs, 5);
        assert_eq!(now(), 0);
        install(Registry::new(2, 4));
        assert!(enabled());
        count(Counter::Outers, 3);
        gauge(Gauge::LastH, 12);
        observe(Hist::GramNs, 9);
        observe_since(Hist::ApplyNs, now());
        {
            let _g = pause();
            assert!(!enabled());
            assert!(paused());
            count(Counter::Outers, 100);
            {
                let _g2 = pause();
                assert!(!enabled());
            }
            assert!(!enabled(), "pause must nest");
        }
        assert!(enabled());
        let Some(reg) = take() else {
            panic!("registry was installed");
        };
        assert!(!enabled());
        assert_eq!(reg.rank(), 2);
        assert_eq!(reg.ranks(), 4);
        assert_eq!(reg.counter(Counter::Outers), 3, "paused adds must drop");
        assert_eq!(reg.gauge(Gauge::LastH), 12);
        assert_eq!(reg.hist(Hist::GramNs).count(), 1);
        assert_eq!(reg.hist(Hist::GramNs).max(), 9);
        assert_eq!(reg.hist(Hist::ApplyNs).count(), 1);
        assert_eq!(reg.telemetry_allocs(), 0);
    }

    #[test]
    fn export_words_round_trips_exactly() {
        let mut reg = Registry::new(3, 4).with_z_threshold(2.5).with_min_dev_ns(777);
        reg.counters[Counter::Timeouts as usize] = (1 << 60) + 5; // above 2⁵³
        reg.gauges[Gauge::PayloadWords as usize] = 2144;
        reg.hists[Hist::WaitNs as usize].observe(12345);
        reg.dropped_snapshots = 2;
        let words = reg.export_words();
        assert_eq!(words.len(), Registry::EXPORT_WORDS);
        let back = Registry::from_export_words(&words).expect("valid blob");
        assert_eq!(back.rank(), 3);
        assert_eq!(back.ranks(), 4);
        assert_eq!(back.z_threshold(), 2.5);
        assert_eq!(back.min_dev_ns(), 777);
        assert_eq!(back.dropped_snapshots(), 2);
        assert_eq!(
            back.counter(Counter::Timeouts),
            (1 << 60) + 5,
            "u64 fields must travel as bit patterns"
        );
        assert_eq!(back.gauge(Gauge::PayloadWords), 2144);
        assert_eq!(back.hist(Hist::WaitNs).count(), 1);
        assert_eq!(back.hist(Hist::WaitNs).max(), 12345);
        assert!(back.snapshots().is_empty(), "snapshots do not travel");
        assert!(Registry::from_export_words(&words[1..]).is_none());
    }

    #[test]
    fn block_serialization_layout() {
        let mut reg = Registry::new(1, 2);
        reg.counters[Counter::Collectives as usize] = 7;
        reg.gauges[Gauge::PayloadWords as usize] = 2144;
        reg.hists[Hist::AllreduceNs as usize].observe(100);
        let mut block = vec![0.0; REGISTRY_WORDS];
        reg.write_block(&mut block, 42);
        assert_eq!(block[0], 42.0);
        assert_eq!(block[1 + 3], 7.0, "collectives is counter slot 3");
        assert_eq!(block[1 + NUM_COUNTERS + 3], 2144.0, "payload_words is gauge slot 3");
        let h0 = 1 + NUM_COUNTERS + NUM_GAUGES + 4 * Histogram::WORDS;
        let h = Histogram::from_words(&block[h0..h0 + Histogram::WORDS]);
        assert_eq!(h.count(), 1, "allreduce_ns is hist slot 4");
        assert_eq!(h.max(), 100);
    }
}
