//! Dense row-major matrix.

use crate::linalg::packed::{packed_len, tri_row};

/// Dense row-major `rows × cols` matrix of `f64`.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseMatrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl DenseMatrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        DenseMatrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "from_vec: bad length");
        DenseMatrix { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    /// Raw Gram of sampled rows: `out[j*sb+t] = <row idx[j], row idx[t]>`.
    /// Upper triangle computed once and mirrored (syrk-style).
    ///
    /// Perf history (EXPERIMENTS.md §Perf): scalar 4×4 register tiling and
    /// L2 panel-blocking both measured SLOWER than vectorized pairwise
    /// dots; the winning combination is the 8-accumulator `dot` plus the
    /// 2×2 row-pair `dot2x2` below (~2× total over the baseline).
    pub fn sampled_gram(&self, idx: &[usize], out: &mut [f64]) {
        let sb = idx.len();
        // 2×2 row-pair blocking: one pass over the columns feeds four
        // accumulating dots, halving memory traffic per FLOP vs pairwise
        // (the kernel is bandwidth-bound at these shapes).
        let mut j = 0;
        while j + 1 < sb {
            let (rj0, rj1) = (self.row(idx[j]), self.row(idx[j + 1]));
            // diagonal-adjacent entries of the 2-row band
            let mut t = j;
            while t + 1 < sb {
                let (rt0, rt1) = (self.row(idx[t]), self.row(idx[t + 1]));
                let [v00, v01, v10, v11] = dot2x2(rj0, rj1, rt0, rt1);
                out[j * sb + t] = v00;
                out[j * sb + t + 1] = v01;
                out[(j + 1) * sb + t] = v10;
                out[(j + 1) * sb + t + 1] = v11;
                out[t * sb + j] = v00;
                out[(t + 1) * sb + j] = v01;
                out[t * sb + j + 1] = v10;
                out[(t + 1) * sb + j + 1] = v11;
                t += 2;
            }
            if t < sb {
                let rt = self.row(idx[t]);
                let v0 = dot(rj0, rt);
                let v1 = dot(rj1, rt);
                out[j * sb + t] = v0;
                out[t * sb + j] = v0;
                out[(j + 1) * sb + t] = v1;
                out[t * sb + j + 1] = v1;
            }
            j += 2;
        }
        if j < sb {
            let rj = self.row(idx[j]);
            for t in j..sb {
                let v = dot(rj, self.row(idx[t]));
                out[j * sb + t] = v;
                out[t * sb + j] = v;
            }
        }
    }

    /// Packed-triangle Gram of sampled rows: entry `(j, t)` with `t ≤ j`
    /// at `out[j(j+1)/2 + t]`, `out` is `sb(sb+1)/2` long. Same 2×2
    /// row-pair blocking (and same per-entry accumulation order, so the
    /// values are **bitwise identical** to [`DenseMatrix::sampled_gram`])
    /// but only the lower triangle is stored — this is the hot-path
    /// variant whose output feeds the `[G|r]` allreduce directly.
    pub fn sampled_gram_packed(&self, idx: &[usize], out: &mut [f64]) {
        let sb = idx.len();
        debug_assert_eq!(out.len(), packed_len(sb));
        let mut j = 0;
        while j + 1 < sb {
            let (rj0, rj1) = (self.row(idx[j]), self.row(idx[j + 1]));
            let mut t = j;
            while t + 1 < sb {
                let (rt0, rt1) = (self.row(idx[t]), self.row(idx[t + 1]));
                let [v00, v01, v10, v11] = dot2x2(rj0, rj1, rt0, rt1);
                out[tri_row(t) + j] = v00;
                out[tri_row(t + 1) + j] = v01;
                if t > j {
                    // (t, j+1) is strictly below the diagonal only when the
                    // 2×2 tile is off-diagonal; on the diagonal tile the
                    // cell (j, j+1) mirrors v01 (== v10) instead.
                    out[tri_row(t) + j + 1] = v10;
                }
                out[tri_row(t + 1) + j + 1] = v11;
                t += 2;
            }
            if t < sb {
                let rt = self.row(idx[t]);
                out[tri_row(t) + j] = dot(rj0, rt);
                out[tri_row(t) + j + 1] = dot(rj1, rt);
            }
            j += 2;
        }
        if j < sb {
            let rj = self.row(idx[j]);
            for t in j..sb {
                out[tri_row(t) + j] = dot(rj, self.row(idx[t]));
            }
        }
    }

    /// `out[j] = <row idx[j], z>`.
    pub fn sampled_matvec(&self, idx: &[usize], z: &[f64], out: &mut [f64]) {
        for (k, &i) in idx.iter().enumerate() {
            out[k] = dot(self.row(i), z);
        }
    }

    /// `out = A z`.
    pub fn matvec(&self, z: &[f64], out: &mut [f64]) {
        for i in 0..self.rows {
            out[i] = dot(self.row(i), z);
        }
    }

    /// `out = Aᵀ v` (row-major friendly: accumulate row-scaled adds).
    pub fn matvec_t(&self, v: &[f64], out: &mut [f64]) {
        out.fill(0.0);
        for i in 0..self.rows {
            let s = v[i];
            if s != 0.0 {
                let row = self.row(i);
                for (o, &x) in out.iter_mut().zip(row) {
                    *o += s * x;
                }
            }
        }
    }

    pub fn slice_cols(&self, lo: usize, hi: usize) -> DenseMatrix {
        let w = hi - lo;
        let mut data = Vec::with_capacity(self.rows * w);
        for i in 0..self.rows {
            data.extend_from_slice(&self.row(i)[lo..hi]);
        }
        DenseMatrix {
            rows: self.rows,
            cols: w,
            data,
        }
    }

    pub fn transpose(&self) -> DenseMatrix {
        let mut t = DenseMatrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }
}

/// Unrolled dot product — the innermost primitive of the native hot path.
///
/// Eight independent accumulators over `chunks_exact(8)` keep the loop free
/// of bounds checks and give the autovectorizer two full 4-lane AVX2 f64
/// vectors of ILP (measured ~1.9× over the 4-accumulator indexed variant —
/// EXPERIMENTS.md §Perf).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 8];
    let ca = a.chunks_exact(8);
    let cb = b.chunks_exact(8);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (xa, xb) in ca.zip(cb) {
        for i in 0..8 {
            acc[i] += xa[i] * xb[i];
        }
    }
    let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3]))
        + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for (xa, xb) in ra.iter().zip(rb) {
        s += xa * xb;
    }
    s
}

/// Four simultaneous dots of the 2×2 row pairs `(a0,a1)·(b0,b1)` in one
/// pass: 4 loads feed 8 FLOPs per column (pairwise dots need 8 loads) —
/// the bandwidth-bound Gram kernel's traffic is halved.
#[inline]
pub fn dot2x2(a0: &[f64], a1: &[f64], b0: &[f64], b1: &[f64]) -> [f64; 4] {
    let n = a0.len();
    debug_assert!(a1.len() == n && b0.len() == n && b1.len() == n);
    const W: usize = 4;
    let mut acc = [[0.0f64; W]; 4];
    let chunks = n / W;
    for c in 0..chunks {
        let i = c * W;
        let (xa0, xa1) = (&a0[i..i + W], &a1[i..i + W]);
        let (xb0, xb1) = (&b0[i..i + W], &b1[i..i + W]);
        for k in 0..W {
            acc[0][k] += xa0[k] * xb0[k];
            acc[1][k] += xa0[k] * xb1[k];
            acc[2][k] += xa1[k] * xb0[k];
            acc[3][k] += xa1[k] * xb1[k];
        }
    }
    let mut out = [0.0f64; 4];
    for (o, lanes) in out.iter_mut().zip(&acc) {
        *o = (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]);
    }
    for i in chunks * W..n {
        out[0] += a0[i] * b0[i];
        out[1] += a0[i] * b1[i];
        out[2] += a1[i] * b0[i];
        out[3] += a1[i] * b1[i];
    }
    out
}

/// `y += s·x` (axpy).
#[inline]
pub fn axpy(s: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += s * xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..17).map(|i| i as f64 * 0.5).collect();
        let b: Vec<f64> = (0..17).map(|i| (i as f64).sin()).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-12);
    }

    #[test]
    fn transpose_and_matvec_t() {
        let m = DenseMatrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let t = m.transpose();
        assert_eq!(t.row(0), &[1., 4.]);
        let mut out = vec![0.0; 3];
        m.matvec_t(&[1.0, -1.0], &mut out);
        assert_eq!(out, vec![-3.0, -3.0, -3.0]);
    }

    #[test]
    fn dot2x2_matches_separate_dots() {
        let n = 37;
        let mk = |seed: u64| -> Vec<f64> {
            let mut st = seed;
            (0..n).map(|_| { st ^= st << 13; st ^= st >> 7; st ^= st << 17;
                (st as f64 / u64::MAX as f64) - 0.5 }).collect()
        };
        let (a0, a1, b0, b1) = (mk(1), mk(2), mk(3), mk(4));
        let v = dot2x2(&a0, &a1, &b0, &b1);
        assert!((v[0] - dot(&a0, &b0)).abs() < 1e-12);
        assert!((v[1] - dot(&a0, &b1)).abs() < 1e-12);
        assert!((v[2] - dot(&a1, &b0)).abs() < 1e-12);
        assert!((v[3] - dot(&a1, &b1)).abs() < 1e-12);
    }

    #[test]
    fn gram_odd_sizes_match_bruteforce() {
        for (rows, sb) in [(5usize, 5usize), (7, 3), (9, 4), (6, 1)] {
            let n = 23;
            let mut st = rows as u64 * 31 + sb as u64;
            let data: Vec<f64> = (0..rows * n).map(|_| { st ^= st << 13; st ^= st >> 7; st ^= st << 17;
                (st as f64 / u64::MAX as f64) - 0.5 }).collect();
            let m = DenseMatrix::from_vec(rows, n, data);
            let idx: Vec<usize> = (0..sb).map(|i| (i * 3) % rows).collect();
            let mut g = vec![0.0; sb * sb];
            m.sampled_gram(&idx, &mut g);
            for j in 0..sb {
                for t in 0..sb {
                    let expect = dot(m.row(idx[j]), m.row(idx[t]));
                    assert!((g[j * sb + t] - expect).abs() < 1e-12,
                        "rows={rows} sb={sb} ({j},{t})");
                }
            }
        }
    }

    #[test]
    fn packed_gram_is_bitwise_lower_triangle_of_full() {
        // Every tile shape: even/odd sb, diagonal tiles, odd tails.
        for (rows, sb) in [(6usize, 6usize), (7, 5), (9, 4), (5, 1), (8, 2)] {
            let n = 29;
            let mut st = rows as u64 * 131 + sb as u64 + 7;
            let data: Vec<f64> = (0..rows * n)
                .map(|_| {
                    st ^= st << 13;
                    st ^= st >> 7;
                    st ^= st << 17;
                    (st as f64 / u64::MAX as f64) - 0.5
                })
                .collect();
            let m = DenseMatrix::from_vec(rows, n, data);
            // Duplicates allowed — sampled blocks repeat across inner steps.
            let idx: Vec<usize> = (0..sb).map(|i| (i * 5 + 1) % rows).collect();
            let mut full = vec![0.0; sb * sb];
            m.sampled_gram(&idx, &mut full);
            let mut packed = vec![0.0; packed_len(sb)];
            m.sampled_gram_packed(&idx, &mut packed);
            for r in 0..sb {
                for c in 0..=r {
                    assert!(
                        packed[tri_row(r) + c] == full[r * sb + c],
                        "rows={rows} sb={sb} ({r},{c}): packed not bitwise equal"
                    );
                }
            }
        }
    }

    #[test]
    fn gram_of_identity_rows() {
        let m = DenseMatrix::from_vec(2, 2, vec![1., 0., 0., 1.]);
        let mut g = vec![0.0; 4];
        m.sampled_gram(&[0, 1], &mut g);
        assert_eq!(g, vec![1., 0., 0., 1.]);
    }
}
