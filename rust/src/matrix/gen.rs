//! Synthetic dataset generator — "clones" of the paper's Table 3 datasets.
//!
//! The paper evaluates on four LIBSVM datasets. Their *relevant* properties
//! for every experiment are: shape (d vs n), density, and the spectrum
//! extremes of `XᵀX` (σ_min fixes λ = 1000·σ_min; σ_max drives conditioning
//! and hence convergence speed). The generator reproduces exactly those:
//!
//! * **dense / small-d clones** (abalone, a9a): `X = Σ^{1/2}·Q` where `Q`
//!   has orthonormal rows (QR of a Gaussian) and `Σ` is log-spaced between
//!   the target σ_min and σ_max — the nonzero spectrum of `XᵀX` (= spectrum
//!   of `XXᵀ`) is planted *exactly*.
//! * **sparse / large-d clones** (news20, real-sim): Gaussian values at
//!   uniformly-random positions with the target density, globally rescaled
//!   by power iteration so σ_max matches; σ_min of these extremely
//!   rectangular sparse matrices is naturally ≈ 0, matching the table's
//!   1e-6-scale values (λ is set from the table's σ_min regardless).
//!
//! Labels are `y = Xᵀw* + ε` with a planted `w*`, so regression recovers
//! signal rather than noise.

use crate::util::Rng64;

use crate::error::{Error, Result};
use crate::matrix::io::Dataset;
use crate::matrix::{CsrMatrix, DenseMatrix, Matrix};

/// Specification of a dataset clone (Table 3 row).
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: String,
    pub d: usize,
    pub n: usize,
    /// Fill fraction in (0, 1]; 1.0 → dense storage.
    pub density: f64,
    /// Target largest eigenvalue of XᵀX.
    pub sigma_max: f64,
    /// Table-3 smallest eigenvalue of XᵀX — used for λ = 1000·σ_min and,
    /// when the clone is dense, planted exactly.
    pub sigma_min: f64,
}

impl DatasetSpec {
    /// The paper's regularizer choice (§5.1): λ = 1000·σ_min.
    pub fn lambda(&self) -> f64 {
        1000.0 * self.sigma_min
    }
}

/// The four Table-3 rows, full size.
pub fn paper_specs() -> Vec<DatasetSpec> {
    vec![
        DatasetSpec {
            name: "abalone".into(),
            d: 8,
            n: 4177,
            density: 1.0,
            sigma_max: 2.3e4,
            sigma_min: 4.3e-5,
        },
        DatasetSpec {
            name: "news20".into(),
            d: 62061,
            n: 15935,
            density: 0.0013,
            sigma_max: 6.0e5,
            sigma_min: 1.7e-6,
        },
        DatasetSpec {
            name: "a9a".into(),
            d: 123,
            n: 32651,
            density: 0.11,
            sigma_max: 2.0e5,
            sigma_min: 4.9e-6,
        },
        DatasetSpec {
            name: "real-sim".into(),
            d: 20958,
            n: 72309,
            density: 0.0024,
            sigma_max: 9.2e2,
            sigma_min: 1.1e-3,
        },
    ]
}

/// Same four rows scaled down by `factor` in both dimensions — used by the
/// test suite and quick benches (spectrum targets preserved).
pub fn scaled_specs(factor: usize) -> Vec<DatasetSpec> {
    paper_specs()
        .into_iter()
        .map(|mut s| {
            s.name = format!("{}-s{}", s.name, factor);
            s.d = (s.d / factor).max(4);
            s.n = (s.n / factor).max(16);
            s
        })
        .collect()
}

pub fn spec_by_name(name: &str) -> Result<DatasetSpec> {
    paper_specs()
        .into_iter()
        .find(|s| s.name == name)
        .ok_or_else(|| Error::Dataset(format!("unknown dataset spec {name:?}")))
}

/// Generate a clone. Deterministic in `(spec, seed)`.
pub fn generate(spec: &DatasetSpec, seed: u64) -> Result<Dataset> {
    if spec.d == 0 || spec.n == 0 {
        return Err(Error::InvalidArg("empty dataset".into()));
    }
    let mut rng = Rng64::seed_from_u64(seed);
    let x = if spec.density >= 0.5 && spec.d <= 2048 {
        Matrix::Dense(gen_dense_planted(spec, &mut rng))
    } else {
        Matrix::Csr(gen_sparse_scaled(spec, &mut rng))
    };
    // y = Xᵀ w* + 0.01·ε
    let w_star: Vec<f64> = (0..spec.d).map(|_| gauss(&mut rng)).collect();
    let mut y = vec![0.0; spec.n];
    x.matvec_t(&w_star, &mut y)?;
    let scale = y.iter().map(|v| v * v).sum::<f64>().sqrt() / (spec.n as f64).sqrt();
    let noise = 0.01 * scale.max(1e-300);
    for v in y.iter_mut() {
        *v += noise * gauss(&mut rng);
    }
    Ok(Dataset {
        name: spec.name.clone(),
        x,
        y,
    })
}

/// Dense clone with exactly-planted nonzero spectrum of `XXᵀ`.
fn gen_dense_planted(spec: &DatasetSpec, rng: &mut Rng64) -> DenseMatrix {
    let (d, n) = (spec.d, spec.n);
    // Q: d×n with orthonormal rows — orthonormalize d Gaussian rows of
    // length n by modified Gram–Schmidt (d ≤ 2048 here, n ≥ d assumed for
    // the dense clones; falls back gracefully if not).
    let mut q = DenseMatrix::zeros(d, n);
    for i in 0..d {
        let qi: Vec<f64> = (0..n).map(|_| gauss(rng)).collect();
        q.data_mut()[i * n..(i + 1) * n].copy_from_slice(&qi);
        // orthogonalize against previous rows
        for j in 0..i {
            let (pre, cur) = q.data_mut().split_at_mut(i * n);
            let rj = &pre[j * n..(j + 1) * n];
            let ri = &mut cur[..n];
            let c = super::dense::dot(rj, ri);
            super::dense::axpy(-c, rj, ri);
        }
        let ri = &mut q.data_mut()[i * n..(i + 1) * n];
        let nrm = ri.iter().map(|v| v * v).sum::<f64>().sqrt();
        if nrm > 1e-12 {
            for v in ri.iter_mut() {
                *v /= nrm;
            }
        }
    }
    // Scale row i by sqrt(σ_i), σ log-spaced σ_max → σ_min.
    for i in 0..d {
        let t = if d == 1 { 0.0 } else { i as f64 / (d - 1) as f64 };
        let sigma = spec.sigma_max.ln() + t * (spec.sigma_min.ln() - spec.sigma_max.ln());
        let s = (sigma.exp()).sqrt();
        for v in &mut q.data_mut()[i * n..(i + 1) * n] {
            *v *= s;
        }
    }
    q
}

/// Sparse clone rescaled so σ_max(XᵀX) hits the target (power iteration).
fn gen_sparse_scaled(spec: &DatasetSpec, rng: &mut Rng64) -> CsrMatrix {
    let (d, n) = (spec.d, spec.n);
    let total = ((d as f64) * (n as f64) * spec.density).round() as usize;
    let mut triplets = Vec::with_capacity(total + n);
    // Guarantee every column has ≥1 entry (every data point exists).
    for j in 0..n {
        triplets.push((rng.gen_range(0, d), j, gauss(rng)));
    }
    for _ in n..total {
        triplets.push((rng.gen_range(0, d), rng.gen_range(0, n), gauss(rng)));
    }
    let mut x = CsrMatrix::from_triplets(d, n, triplets);
    let cur = sigma_max_sq(&Matrix::Csr(x.clone()), 60, rng);
    if cur > 0.0 {
        let s = (spec.sigma_max / cur).sqrt();
        let mut t = Vec::with_capacity(x.nnz());
        for i in 0..d {
            let (cols, vals) = x.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                t.push((i, c as usize, v * s));
            }
        }
        x = CsrMatrix::from_triplets(d, n, t);
    }
    x
}

/// Largest eigenvalue of `XᵀX` (= `XXᵀ`) via power iteration on the smaller
/// Gram operator.
pub fn sigma_max_sq(x: &Matrix, iters: usize, rng: &mut Rng64) -> f64 {
    let (d, n) = (x.rows(), x.cols());
    let small_is_rows = d <= n;
    let m = if small_is_rows { d } else { n };
    let mut v: Vec<f64> = (0..m).map(|_| gauss(rng)).collect();
    let mut tmp_big = vec![0.0; if small_is_rows { n } else { d }];
    let mut next = vec![0.0; m];
    let mut lambda = 0.0;
    for _ in 0..iters {
        if small_is_rows {
            // v ← X Xᵀ v
            x.matvec_t(&v, &mut tmp_big).unwrap();
            x.matvec(&tmp_big, &mut next).unwrap();
        } else {
            // v ← Xᵀ X v
            x.matvec(&v, &mut tmp_big).unwrap();
            x.matvec_t(&tmp_big, &mut next).unwrap();
        }
        lambda = next.iter().map(|t| t * t).sum::<f64>().sqrt();
        if lambda <= 0.0 {
            return 0.0;
        }
        for (vi, ni) in v.iter_mut().zip(&next) {
            *vi = ni / lambda;
        }
    }
    lambda
}

#[inline]
fn gauss(rng: &mut Rng64) -> f64 {
    rng.gen_normal()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_clone_plants_spectrum() {
        let spec = DatasetSpec {
            name: "t".into(),
            d: 6,
            n: 500,
            density: 1.0,
            sigma_max: 100.0,
            sigma_min: 0.01,
        };
        let ds = generate(&spec, 7).unwrap();
        // X Xᵀ should be diag(σ) in some basis: check extremes via its
        // exact 6×6 Gram.
        let mut g = vec![0.0; 36];
        ds.x.sampled_gram(&[0, 1, 2, 3, 4, 5], &mut g).unwrap();
        let eigs = crate::linalg::cond::symmetric_eigenvalues(&g, 6);
        let (lo, hi) = (eigs[0], eigs[5]);
        assert!((hi - 100.0).abs() / 100.0 < 1e-8, "hi={hi}");
        assert!((lo - 0.01).abs() / 0.01 < 1e-6, "lo={lo}");
    }

    #[test]
    fn sparse_clone_matches_density_and_sigma() {
        let spec = DatasetSpec {
            name: "t".into(),
            d: 300,
            n: 400,
            density: 0.02,
            sigma_max: 50.0,
            sigma_min: 1e-6,
        };
        let ds = generate(&spec, 3).unwrap();
        let dens = ds.x.density();
        assert!(
            (dens - 0.02).abs() < 0.005,
            "density {dens} too far from 0.02"
        );
        let mut rng = Rng64::seed_from_u64(99);
        let smax = sigma_max_sq(&ds.x, 100, &mut rng);
        assert!(
            (smax - 50.0).abs() / 50.0 < 0.05,
            "sigma_max {smax} vs 50"
        );
    }

    #[test]
    fn generation_is_deterministic() {
        let spec = &scaled_specs(16)[0];
        let a = generate(spec, 5).unwrap();
        let b = generate(spec, 5).unwrap();
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn labels_have_signal() {
        let spec = &scaled_specs(8)[0];
        let ds = generate(spec, 1).unwrap();
        let e = ds.y.iter().map(|v| v * v).sum::<f64>();
        assert!(e > 0.0);
    }
}
