//! LIBSVM-format dataset IO.
//!
//! The paper's experiments run on LIBSVM repository files (Table 3); the
//! generator in [`super::gen`] writes the same format, so synthetic clones
//! and real downloads are interchangeable at the CLI.
//!
//! Format, one data point per line: `label idx:val idx:val ...` with
//! 1-based feature indices. We store points as **columns** of `X ∈ R^{d×n}`
//! to match the paper's convention (rows = features).

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use crate::error::{Error, Result};
use crate::matrix::{CsrMatrix, Matrix};

/// A labelled dataset: `x` is `d × n` (features × points), `y` length `n`.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub x: Matrix,
    pub y: Vec<f64>,
}

impl Dataset {
    pub fn d(&self) -> usize {
        self.x.rows()
    }

    pub fn n(&self) -> usize {
        self.x.cols()
    }
}

/// Read a LIBSVM file into a `d × n` CSR matrix (d inferred unless given).
pub fn read_libsvm(path: &Path, force_d: Option<usize>) -> Result<Dataset> {
    let f = std::fs::File::open(path)?;
    let reader = BufReader::new(f);
    let mut y = Vec::new();
    let mut triplets: Vec<(usize, usize, f64)> = Vec::new();
    let mut d_max = 0usize;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let col = y.len();
        let mut parts = line.split_whitespace();
        let label = parts
            .next()
            .ok_or_else(|| Error::Dataset(format!("{path:?}:{}: empty line", lineno + 1)))?;
        y.push(label.parse::<f64>().map_err(|e| {
            Error::Dataset(format!("{path:?}:{}: bad label: {e}", lineno + 1))
        })?);
        for tok in parts {
            let (i, v) = tok.split_once(':').ok_or_else(|| {
                Error::Dataset(format!("{path:?}:{}: bad token {tok:?}", lineno + 1))
            })?;
            let i: usize = i.parse().map_err(|e| {
                Error::Dataset(format!("{path:?}:{}: bad index: {e}", lineno + 1))
            })?;
            if i == 0 {
                return Err(Error::Dataset(format!(
                    "{path:?}:{}: LIBSVM indices are 1-based",
                    lineno + 1
                )));
            }
            let v: f64 = v.parse().map_err(|e| {
                Error::Dataset(format!("{path:?}:{}: bad value: {e}", lineno + 1))
            })?;
            d_max = d_max.max(i);
            triplets.push((i - 1, col, v));
        }
    }
    let n = y.len();
    let d = force_d.unwrap_or(d_max);
    if d < d_max {
        return Err(Error::Dataset(format!(
            "force_d {d} < max feature index {d_max}"
        )));
    }
    let x = CsrMatrix::from_triplets(d, n, triplets);
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "dataset".into());
    Ok(Dataset {
        name,
        x: Matrix::Csr(x),
        y,
    })
}

/// Write a dataset in LIBSVM format (column j of X = line j).
pub fn write_libsvm(path: &Path, ds: &Dataset) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    // Column access: transpose once (points become rows).
    let xt = ds.x.transpose();
    for j in 0..ds.n() {
        write!(w, "{}", ds.y[j])?;
        match &xt {
            Matrix::Csr(m) => {
                let (cols, vals) = m.row(j);
                for (&c, &v) in cols.iter().zip(vals) {
                    write!(w, " {}:{}", c + 1, v)?;
                }
            }
            Matrix::Dense(m) => {
                for (c, &v) in m.row(j).iter().enumerate() {
                    if v != 0.0 {
                        write!(w, " {}:{}", c + 1, v)?;
                    }
                }
            }
        }
        writeln!(w)?;
    }
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::DenseMatrix;

    #[test]
    fn roundtrip() {
        let x = Matrix::Dense(DenseMatrix::from_vec(
            3,
            2,
            vec![1.0, 0.0, 0.0, 2.5, -3.0, 0.0],
        ));
        let ds = Dataset {
            name: "t".into(),
            x,
            y: vec![1.0, -1.0],
        };
        let dir = crate::util::tmp::TempDir::new("io").unwrap();
        let p = dir.path().join("t.libsvm");
        write_libsvm(&p, &ds).unwrap();
        let back = read_libsvm(&p, Some(3)).unwrap();
        assert_eq!(back.n(), 2);
        assert_eq!(back.d(), 3);
        assert_eq!(back.y, vec![1.0, -1.0]);
        let mut a = vec![0.0; 3];
        let mut b = vec![0.0; 3];
        ds.x.matvec(&[1.0, 1.0], &mut a).unwrap();
        back.x.matvec(&[1.0, 1.0], &mut b).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn rejects_zero_index() {
        let dir = crate::util::tmp::TempDir::new("io").unwrap();
        let p = dir.path().join("bad.libsvm");
        std::fs::write(&p, "1.0 0:5\n").unwrap();
        assert!(read_libsvm(&p, None).is_err());
    }

    #[test]
    fn skips_comments_and_blanks() {
        let dir = crate::util::tmp::TempDir::new("io").unwrap();
        let p = dir.path().join("c.libsvm");
        std::fs::write(&p, "# header\n\n1 1:2.0\n").unwrap();
        let ds = read_libsvm(&p, None).unwrap();
        assert_eq!(ds.n(), 1);
        assert_eq!(ds.d(), 1);
    }
}
