//! Compressed Sparse Row matrix.
//!
//! The news20/real-sim dataset clones are ~0.1–0.3% dense; storing them
//! densely (62061×15935 f64 ≈ 7.9 GB) is impossible, so every solver path
//! has a CSR-aware implementation. Column indices within each row are kept
//! sorted. The sampled Gram is computed Gustavson-style
//! ([`CsrMatrix::sampled_gram_packed`]): the sampled rows are gathered
//! once as a column-sorted transposed panel, then one sparse outer-product
//! pass per occupied column — `O(Σ_c cnt_c²)` work instead of the
//! `O(sb²·nnz/row)` of the historical pairwise two-pointer merge (kept as
//! [`CsrMatrix::sampled_gram_merge_packed`], the benchmark baseline and
//! bitwise oracle). Panels denser than
//! [`GRAM_DENSE_FALLBACK_DENSITY`] fall back to a gathered dense panel
//! driven by the 2×2-blocked dense kernel.

use super::dense::DenseMatrix;
use crate::linalg::packed::{packed_len, tri_row};

/// Sampled-panel fill fraction above which `sampled_gram_packed` gathers
/// the rows into a dense panel and uses the dense kernel: at this density
/// the sparse-accumulator bookkeeping costs more than the dense flops.
pub const GRAM_DENSE_FALLBACK_DENSITY: f64 = 0.25;

/// CSR `rows × cols` matrix of `f64` with sorted column indices per row.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    rows: usize,
    cols: usize,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f64>,
}

impl CsrMatrix {
    /// Build from per-entry triplets (unsorted OK; duplicates summed).
    pub fn from_triplets(
        rows: usize,
        cols: usize,
        mut triplets: Vec<(usize, usize, f64)>,
    ) -> Self {
        triplets.sort_unstable_by_key(|&(r, c, _)| (r, c));
        let mut indptr = vec![0usize; rows + 1];
        let mut indices = Vec::with_capacity(triplets.len());
        let mut values: Vec<f64> = Vec::with_capacity(triplets.len());
        let mut last: Option<(usize, usize)> = None;
        for (r, c, v) in triplets {
            assert!(r < rows && c < cols, "triplet out of bounds");
            if last == Some((r, c)) {
                *values.last_mut().unwrap() += v;
            } else {
                indptr[r + 1] += 1;
                indices.push(c as u32);
                values.push(v);
                last = Some((r, c));
            }
        }
        for i in 0..rows {
            indptr[i + 1] += indptr[i];
        }
        CsrMatrix {
            rows,
            cols,
            indptr,
            indices,
            values,
        }
    }

    pub fn from_dense(m: &DenseMatrix) -> Self {
        let mut trip = Vec::new();
        for i in 0..m.rows() {
            for (j, &v) in m.row(i).iter().enumerate() {
                if v != 0.0 {
                    trip.push((i, j, v));
                }
            }
        }
        CsrMatrix::from_triplets(m.rows(), m.cols(), trip)
    }

    pub fn to_dense(&self) -> DenseMatrix {
        let mut d = DenseMatrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                d.set(i, c as usize, v);
            }
        }
        d
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    #[inline]
    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// `(column indices, values)` of row `i` — indices sorted ascending.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// Sparse dot of two rows via two-pointer merge on sorted indices.
    #[inline]
    fn row_dot(&self, i: usize, j: usize) -> f64 {
        let (ci, vi) = self.row(i);
        let (cj, vj) = self.row(j);
        let (mut p, mut q, mut s) = (0usize, 0usize, 0.0);
        while p < ci.len() && q < cj.len() {
            match ci[p].cmp(&cj[q]) {
                std::cmp::Ordering::Less => p += 1,
                std::cmp::Ordering::Greater => q += 1,
                std::cmp::Ordering::Equal => {
                    s += vi[p] * vj[q];
                    p += 1;
                    q += 1;
                }
            }
        }
        s
    }

    /// Full-matrix sampled Gram — mirror of the packed kernel (single
    /// source of truth for the per-entry arithmetic). Baseline/diagnostic
    /// callers only; the solver hot path consumes the packed triangle.
    pub fn sampled_gram(&self, idx: &[usize], out: &mut [f64]) {
        let sb = idx.len();
        let mut packed = vec![0.0; packed_len(sb)];
        self.sampled_gram_packed(idx, &mut packed);
        crate::linalg::packed::unpack_symmetric(&packed, sb, out);
    }

    /// Packed-triangle sampled Gram, Gustavson-style.
    ///
    /// The sampled rows are gathered **once** into `(column, slot, value)`
    /// triples sorted by column — the transposed panel — and each occupied
    /// column contributes one sparse outer-product pass: every slot pair
    /// `(tA ≥ tB)` present in that column accumulates `vA·vB` into
    /// `out[tA(tA+1)/2 + tB]`. Each Gram entry therefore receives its
    /// products in ascending-column order, exactly the order of the
    /// two-pointer merge — on this path the results are **bitwise
    /// identical** to [`CsrMatrix::sampled_gram_merge_packed`] — at
    /// `O(nnz·log nnz + Σ_c cnt_c²)` total cost instead of the merge's
    /// `O(sb²·nnz/row)` (quadratic in `sb`).
    ///
    /// Panels filled beyond [`GRAM_DENSE_FALLBACK_DENSITY`] are gathered
    /// densely and handed to the 2×2-blocked dense kernel instead. In
    /// that regime the summation order includes the explicit zeros, so
    /// values may differ from the merge in the last ulp (packed ≡ full
    /// stays exact — both route through this dispatcher); the threshold
    /// trades that last-ulp identity with the historical merge for the
    /// dense kernel's throughput on filled panels.
    pub fn sampled_gram_packed(&self, idx: &[usize], out: &mut [f64]) {
        let mut scratch = Vec::new();
        self.sampled_gram_packed_into(idx, out, &mut scratch);
    }

    /// Scratch-reusing body of [`CsrMatrix::sampled_gram_packed`]:
    /// `scratch` carries the transposed panel across calls, so the solver
    /// hot path ([`crate::gram::NativeBackend`] passes its own) allocates
    /// nothing per iteration once its capacity is warm. The dense-panel
    /// fallback still gathers a fresh `sb × cols` panel per call — it only
    /// triggers above [`GRAM_DENSE_FALLBACK_DENSITY`], where CSR storage
    /// is the wrong choice to begin with.
    pub fn sampled_gram_packed_into(
        &self,
        idx: &[usize],
        out: &mut [f64],
        scratch: &mut Vec<(u32, u32, f64)>,
    ) {
        let sb = idx.len();
        debug_assert_eq!(out.len(), packed_len(sb));
        let panel_nnz: usize = idx
            .iter()
            .map(|&i| self.indptr[i + 1] - self.indptr[i])
            .sum();
        let cells = (sb * self.cols).max(1);
        if panel_nnz as f64 > GRAM_DENSE_FALLBACK_DENSITY * cells as f64 {
            let mut panel = DenseMatrix::zeros(sb, self.cols);
            let width = self.cols;
            let data = panel.data_mut();
            for (k, &i) in idx.iter().enumerate() {
                let (cols, vals) = self.row(i);
                let dst = &mut data[k * width..(k + 1) * width];
                for (&c, &v) in cols.iter().zip(vals) {
                    dst[c as usize] = v;
                }
            }
            let all: Vec<usize> = (0..sb).collect();
            panel.sampled_gram_packed(&all, out);
            return;
        }
        out.fill(0.0);
        scratch.clear();
        scratch.reserve(panel_nnz);
        for (slot, &i) in idx.iter().enumerate() {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                scratch.push((c, slot as u32, v));
            }
        }
        // (column, slot) is unique per entry, so the sort is deterministic.
        scratch.sort_unstable_by_key(|&(c, t, _)| (c, t));
        let entries = &scratch[..];
        let mut lo = 0;
        while lo < entries.len() {
            let c = entries[lo].0;
            let mut hi = lo + 1;
            while hi < entries.len() && entries[hi].0 == c {
                hi += 1;
            }
            let col = &entries[lo..hi];
            for (a, &(_, ta, va)) in col.iter().enumerate() {
                let base = tri_row(ta as usize);
                for &(_, tb, vb) in &col[..=a] {
                    out[base + tb as usize] += va * vb;
                }
            }
            lo = hi;
        }
    }

    /// The historical merge-based kernel: each of the `sb(sb+1)/2` entries
    /// is one two-pointer merge over two sorted rows. Quadratic in `sb` —
    /// kept as the benchmark baseline and as the bitwise oracle for the
    /// Gustavson kernel (identical per-entry accumulation order).
    pub fn sampled_gram_merge_packed(&self, idx: &[usize], out: &mut [f64]) {
        debug_assert_eq!(out.len(), packed_len(idx.len()));
        for (j, &ij) in idx.iter().enumerate() {
            let base = tri_row(j);
            for (t, &it) in idx[..=j].iter().enumerate() {
                out[base + t] = self.row_dot(ij, it);
            }
        }
    }

    pub fn sampled_matvec(&self, idx: &[usize], z: &[f64], out: &mut [f64]) {
        for (k, &i) in idx.iter().enumerate() {
            let (cols, vals) = self.row(i);
            let mut s = 0.0;
            for (&c, &v) in cols.iter().zip(vals) {
                s += v * z[c as usize];
            }
            out[k] = s;
        }
    }

    pub fn matvec(&self, z: &[f64], out: &mut [f64]) {
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            let mut s = 0.0;
            for (&c, &v) in cols.iter().zip(vals) {
                s += v * z[c as usize];
            }
            out[i] = s;
        }
    }

    pub fn matvec_t(&self, v: &[f64], out: &mut [f64]) {
        out.fill(0.0);
        for i in 0..self.rows {
            let s = v[i];
            if s != 0.0 {
                let (cols, vals) = self.row(i);
                for (&c, &x) in cols.iter().zip(vals) {
                    out[c as usize] += s * x;
                }
            }
        }
    }

    pub fn slice_cols(&self, lo: usize, hi: usize) -> CsrMatrix {
        let mut trip = Vec::new();
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                let c = c as usize;
                if c >= lo && c < hi {
                    trip.push((i, c - lo, v));
                }
            }
        }
        CsrMatrix::from_triplets(self.rows, hi - lo, trip)
    }

    pub fn transpose(&self) -> CsrMatrix {
        let mut trip = Vec::with_capacity(self.nnz());
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                trip.push((c as usize, i, v));
            }
        }
        CsrMatrix::from_triplets(self.cols, self.rows, trip)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CsrMatrix {
        CsrMatrix::from_triplets(
            3,
            4,
            vec![(0, 1, 2.0), (0, 0, 1.0), (1, 2, 4.0), (1, 1, 3.0), (2, 3, 6.0), (2, 0, 5.0)],
        )
    }

    #[test]
    fn triplets_sorted_and_dedup() {
        let m = CsrMatrix::from_triplets(2, 2, vec![(0, 0, 1.0), (0, 0, 2.0), (1, 1, 4.0)]);
        assert_eq!(m.nnz(), 2);
        let (c, v) = m.row(0);
        assert_eq!(c, &[0]);
        assert_eq!(v, &[3.0]);
    }

    #[test]
    fn row_dot_merge() {
        let m = sample();
        assert_eq!(m.row_dot(0, 0), 5.0);
        assert_eq!(m.row_dot(0, 1), 6.0);
        assert_eq!(m.row_dot(0, 2), 5.0);
    }

    #[test]
    fn gustavson_matches_merge_bitwise_with_empty_rows_and_duplicates() {
        // 8×40 at ~5% fill (below the dense fallback), rows 3 and 6 empty,
        // sampled indices repeat — the shapes the property sweep hits.
        let mut trip = Vec::new();
        let mut st = 0x5EEDu64;
        for r in [0usize, 1, 2, 4, 5, 7] {
            for _ in 0..4 {
                st ^= st << 13;
                st ^= st >> 7;
                st ^= st << 17;
                let c = (st % 40) as usize;
                let v = (st as f64 / u64::MAX as f64) - 0.5;
                trip.push((r, c, v));
            }
        }
        let m = CsrMatrix::from_triplets(8, 40, trip);
        let idx = [2usize, 3, 2, 7, 6, 0];
        let sb = idx.len();
        let plen = sb * (sb + 1) / 2;
        let mut fast = vec![f64::NAN; plen];
        let mut slow = vec![f64::NAN; plen];
        m.sampled_gram_packed(&idx, &mut fast);
        m.sampled_gram_merge_packed(&idx, &mut slow);
        assert!(fast == slow, "Gustavson != merge: {fast:?} vs {slow:?}");
        // Duplicate slots share a row: (0,2) entry equals the (0,0) diag.
        assert_eq!(fast[crate::linalg::packed::pidx(2, 0)], fast[0]);
    }

    #[test]
    fn dense_fallback_matches_dense_kernel() {
        let m = sample(); // 6 nnz / 12 cells = 0.5 fill → dense panel path
        let idx = [0usize, 2, 1];
        let plen = 6;
        let mut packed = vec![0.0; plen];
        m.sampled_gram_packed(&idx, &mut packed);
        let d = m.to_dense();
        let mut expect = vec![0.0; plen];
        d.sampled_gram_packed(&idx, &mut expect);
        assert_eq!(packed, expect);
    }

    #[test]
    fn full_gram_is_mirror_of_packed() {
        let m = sample();
        let idx = [2usize, 0, 1];
        let mut full = vec![0.0; 9];
        m.sampled_gram(&idx, &mut full);
        let mut packed = vec![0.0; 6];
        m.sampled_gram_packed(&idx, &mut packed);
        for r in 0..3 {
            for c in 0..3 {
                assert_eq!(full[r * 3 + c], packed[crate::linalg::packed::pidx(r, c)]);
            }
        }
    }

    #[test]
    fn dense_roundtrip() {
        let m = sample();
        let d = m.to_dense();
        let m2 = CsrMatrix::from_dense(&d);
        assert_eq!(m, m2);
    }

    #[test]
    fn transpose_matvec_consistency() {
        let m = sample();
        let t = m.transpose();
        let v = [1.0, -2.0, 0.5];
        let mut a = vec![0.0; 4];
        let mut b = vec![0.0; 4];
        m.matvec_t(&v, &mut a);
        t.matvec(&v, &mut b);
        assert_eq!(a, b);
    }
}
