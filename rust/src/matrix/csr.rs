//! Compressed Sparse Row matrix.
//!
//! The news20/real-sim dataset clones are ~0.1–0.3% dense; storing them
//! densely (62061×15935 f64 ≈ 7.9 GB) is impossible, so every solver path
//! has a CSR-aware implementation. Column indices within each row are kept
//! sorted — `sampled_gram` exploits this with a two-pointer merge.

use super::dense::DenseMatrix;

/// CSR `rows × cols` matrix of `f64` with sorted column indices per row.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    rows: usize,
    cols: usize,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f64>,
}

impl CsrMatrix {
    /// Build from per-entry triplets (unsorted OK; duplicates summed).
    pub fn from_triplets(
        rows: usize,
        cols: usize,
        mut triplets: Vec<(usize, usize, f64)>,
    ) -> Self {
        triplets.sort_unstable_by_key(|&(r, c, _)| (r, c));
        let mut indptr = vec![0usize; rows + 1];
        let mut indices = Vec::with_capacity(triplets.len());
        let mut values: Vec<f64> = Vec::with_capacity(triplets.len());
        let mut last: Option<(usize, usize)> = None;
        for (r, c, v) in triplets {
            assert!(r < rows && c < cols, "triplet out of bounds");
            if last == Some((r, c)) {
                *values.last_mut().unwrap() += v;
            } else {
                indptr[r + 1] += 1;
                indices.push(c as u32);
                values.push(v);
                last = Some((r, c));
            }
        }
        for i in 0..rows {
            indptr[i + 1] += indptr[i];
        }
        CsrMatrix {
            rows,
            cols,
            indptr,
            indices,
            values,
        }
    }

    pub fn from_dense(m: &DenseMatrix) -> Self {
        let mut trip = Vec::new();
        for i in 0..m.rows() {
            for (j, &v) in m.row(i).iter().enumerate() {
                if v != 0.0 {
                    trip.push((i, j, v));
                }
            }
        }
        CsrMatrix::from_triplets(m.rows(), m.cols(), trip)
    }

    pub fn to_dense(&self) -> DenseMatrix {
        let mut d = DenseMatrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                d.set(i, c as usize, v);
            }
        }
        d
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    #[inline]
    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// `(column indices, values)` of row `i` — indices sorted ascending.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// Sparse dot of two rows via two-pointer merge on sorted indices.
    #[inline]
    fn row_dot(&self, i: usize, j: usize) -> f64 {
        let (ci, vi) = self.row(i);
        let (cj, vj) = self.row(j);
        let (mut p, mut q, mut s) = (0usize, 0usize, 0.0);
        while p < ci.len() && q < cj.len() {
            match ci[p].cmp(&cj[q]) {
                std::cmp::Ordering::Less => p += 1,
                std::cmp::Ordering::Greater => q += 1,
                std::cmp::Ordering::Equal => {
                    s += vi[p] * vj[q];
                    p += 1;
                    q += 1;
                }
            }
        }
        s
    }

    pub fn sampled_gram(&self, idx: &[usize], out: &mut [f64]) {
        let sb = idx.len();
        for j in 0..sb {
            for t in j..sb {
                let v = self.row_dot(idx[j], idx[t]);
                out[j * sb + t] = v;
                out[t * sb + j] = v;
            }
        }
    }

    pub fn sampled_matvec(&self, idx: &[usize], z: &[f64], out: &mut [f64]) {
        for (k, &i) in idx.iter().enumerate() {
            let (cols, vals) = self.row(i);
            let mut s = 0.0;
            for (&c, &v) in cols.iter().zip(vals) {
                s += v * z[c as usize];
            }
            out[k] = s;
        }
    }

    pub fn matvec(&self, z: &[f64], out: &mut [f64]) {
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            let mut s = 0.0;
            for (&c, &v) in cols.iter().zip(vals) {
                s += v * z[c as usize];
            }
            out[i] = s;
        }
    }

    pub fn matvec_t(&self, v: &[f64], out: &mut [f64]) {
        out.fill(0.0);
        for i in 0..self.rows {
            let s = v[i];
            if s != 0.0 {
                let (cols, vals) = self.row(i);
                for (&c, &x) in cols.iter().zip(vals) {
                    out[c as usize] += s * x;
                }
            }
        }
    }

    pub fn slice_cols(&self, lo: usize, hi: usize) -> CsrMatrix {
        let mut trip = Vec::new();
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                let c = c as usize;
                if c >= lo && c < hi {
                    trip.push((i, c - lo, v));
                }
            }
        }
        CsrMatrix::from_triplets(self.rows, hi - lo, trip)
    }

    pub fn transpose(&self) -> CsrMatrix {
        let mut trip = Vec::with_capacity(self.nnz());
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                trip.push((c as usize, i, v));
            }
        }
        CsrMatrix::from_triplets(self.cols, self.rows, trip)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CsrMatrix {
        CsrMatrix::from_triplets(
            3,
            4,
            vec![(0, 1, 2.0), (0, 0, 1.0), (1, 2, 4.0), (1, 1, 3.0), (2, 3, 6.0), (2, 0, 5.0)],
        )
    }

    #[test]
    fn triplets_sorted_and_dedup() {
        let m = CsrMatrix::from_triplets(2, 2, vec![(0, 0, 1.0), (0, 0, 2.0), (1, 1, 4.0)]);
        assert_eq!(m.nnz(), 2);
        let (c, v) = m.row(0);
        assert_eq!(c, &[0]);
        assert_eq!(v, &[3.0]);
    }

    #[test]
    fn row_dot_merge() {
        let m = sample();
        assert_eq!(m.row_dot(0, 0), 5.0);
        assert_eq!(m.row_dot(0, 1), 6.0);
        assert_eq!(m.row_dot(0, 2), 5.0);
    }

    #[test]
    fn dense_roundtrip() {
        let m = sample();
        let d = m.to_dense();
        let m2 = CsrMatrix::from_dense(&d);
        assert_eq!(m, m2);
    }

    #[test]
    fn transpose_matvec_consistency() {
        let m = sample();
        let t = m.transpose();
        let v = [1.0, -2.0, 0.5];
        let mut a = vec![0.0; 4];
        let mut b = vec![0.0; 4];
        m.matvec_t(&v, &mut a);
        t.matvec(&v, &mut b);
        assert_eq!(a, b);
    }
}
