//! Matrix substrate: dense (row-major) and CSR sparse storage, LIBSVM IO,
//! and the Table-3 dataset-clone generator.
//!
//! Every solver in the crate views its local shard as the **operand** `A`:
//! the primal methods take `A = X` (features × data points) and the dual
//! methods take `A = Xᵀ` — both then *sample rows of A* and contract along
//! A's columns, which is what lets one Gram engine (and one set of AOT
//! artifacts) serve all four algorithms.

pub mod csr;
pub mod dense;
pub mod gen;
pub mod io;

pub use csr::CsrMatrix;
pub use dense::DenseMatrix;

use crate::error::{Error, Result};

/// A rank-local matrix block, dense or sparse.
///
/// Solvers only need three primitives, all row-sampled:
/// * gather sampled rows into a dense scratch (`gather_rows`),
/// * sparse-aware Gram of sampled rows (`sampled_gram`),
/// * sparse-aware residual matvec of sampled rows (`sampled_matvec`).
#[derive(Clone, Debug)]
pub enum Matrix {
    Dense(DenseMatrix),
    Csr(CsrMatrix),
}

impl Matrix {
    pub fn rows(&self) -> usize {
        match self {
            Matrix::Dense(m) => m.rows(),
            Matrix::Csr(m) => m.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            Matrix::Dense(m) => m.cols(),
            Matrix::Csr(m) => m.cols(),
        }
    }

    /// Number of stored non-zeros (dense counts every entry).
    pub fn nnz(&self) -> usize {
        match self {
            Matrix::Dense(m) => m.rows() * m.cols(),
            Matrix::Csr(m) => m.nnz(),
        }
    }

    /// Fill fraction in `[0, 1]`.
    pub fn density(&self) -> f64 {
        let cells = (self.rows() * self.cols()).max(1);
        self.nnz() as f64 / cells as f64
    }

    /// Copy the given rows into a dense `idx.len() × cols` row-major buffer.
    ///
    /// This is the layout the XLA gram artifact consumes (zero-padded on the
    /// column side by the runtime).
    pub fn gather_rows(&self, idx: &[usize], out: &mut [f64]) -> Result<()> {
        let c = self.cols();
        if out.len() != idx.len() * c {
            return Err(Error::Shape(format!(
                "gather_rows: out len {} != {}x{}",
                out.len(),
                idx.len(),
                c
            )));
        }
        match self {
            Matrix::Dense(m) => {
                for (k, &i) in idx.iter().enumerate() {
                    out[k * c..(k + 1) * c].copy_from_slice(m.row(i));
                }
            }
            Matrix::Csr(m) => {
                out.fill(0.0);
                for (k, &i) in idx.iter().enumerate() {
                    let (cols, vals) = m.row(i);
                    let dst = &mut out[k * c..(k + 1) * c];
                    for (&j, &v) in cols.iter().zip(vals) {
                        dst[j as usize] = v;
                    }
                }
            }
        }
        Ok(())
    }

    /// `G[j,t] = <row_{idx[j]}, row_{idx[t]}>` — the raw local Gram block
    /// as a full mirrored `idx.len()²` row-major matrix. Baseline and
    /// diagnostic callers only; the solver hot path uses
    /// [`Matrix::sampled_gram_packed`].
    pub fn sampled_gram(&self, idx: &[usize], out: &mut [f64]) -> Result<()> {
        let sb = idx.len();
        if out.len() != sb * sb {
            return Err(Error::Shape(format!(
                "sampled_gram: out len {} != {sb}²",
                out.len()
            )));
        }
        match self {
            Matrix::Dense(m) => m.sampled_gram(idx, out),
            Matrix::Csr(m) => m.sampled_gram(idx, out),
        }
        Ok(())
    }

    /// Packed lower-triangular sampled Gram — the hot-path variant: entry
    /// `(j, t)` with `t ≤ j` at `out[j(j+1)/2 + t]`, `out` is
    /// `sb(sb+1)/2` long (the exact shape of the `[G|…]` allreduce
    /// payload's Gram segment). Values are bitwise identical to the lower
    /// triangle of [`Matrix::sampled_gram`].
    pub fn sampled_gram_packed(&self, idx: &[usize], out: &mut [f64]) -> Result<()> {
        let sb = idx.len();
        if out.len() != crate::linalg::packed::packed_len(sb) {
            return Err(Error::Shape(format!(
                "sampled_gram_packed: out len {} != {sb}·({sb}+1)/2",
                out.len()
            )));
        }
        match self {
            Matrix::Dense(m) => m.sampled_gram_packed(idx, out),
            Matrix::Csr(m) => m.sampled_gram_packed(idx, out),
        }
        Ok(())
    }

    /// [`Matrix::sampled_gram_packed`] with caller-provided Gustavson
    /// scratch: CSR operands reuse `scratch` for the transposed panel
    /// (zero allocations per call once warm — the backend hot path owns
    /// one), dense operands ignore it.
    pub fn sampled_gram_packed_scratch(
        &self,
        idx: &[usize],
        out: &mut [f64],
        scratch: &mut Vec<(u32, u32, f64)>,
    ) -> Result<()> {
        let sb = idx.len();
        if out.len() != crate::linalg::packed::packed_len(sb) {
            return Err(Error::Shape(format!(
                "sampled_gram_packed: out len {} != {sb}·({sb}+1)/2",
                out.len()
            )));
        }
        match self {
            Matrix::Dense(m) => m.sampled_gram_packed(idx, out),
            Matrix::Csr(m) => m.sampled_gram_packed_into(idx, out, scratch),
        }
        Ok(())
    }

    /// `r[j] = <row_{idx[j]}, z>` — the raw local residual contributions.
    pub fn sampled_matvec(&self, idx: &[usize], z: &[f64], out: &mut [f64]) -> Result<()> {
        if z.len() != self.cols() || out.len() != idx.len() {
            return Err(Error::Shape(format!(
                "sampled_matvec: z {} (cols {}), out {} (idx {})",
                z.len(),
                self.cols(),
                out.len(),
                idx.len()
            )));
        }
        match self {
            Matrix::Dense(m) => m.sampled_matvec(idx, z, out),
            Matrix::Csr(m) => m.sampled_matvec(idx, z, out),
        }
        Ok(())
    }

    /// `acc += Aᵀ[ :, idx] · d`, i.e. scatter `Σ_j d[j] · row_{idx[j]}` into
    /// the length-`cols` accumulator. This is the deferred α/w vector update
    /// (Alg. 2 line 12 / Alg. 4 line 13) on the local shard.
    pub fn scatter_rows_add(&self, idx: &[usize], d: &[f64], acc: &mut [f64]) -> Result<()> {
        if d.len() != idx.len() || acc.len() != self.cols() {
            return Err(Error::Shape(format!(
                "scatter_rows_add: d {} idx {} acc {} cols {}",
                d.len(),
                idx.len(),
                acc.len(),
                self.cols()
            )));
        }
        match self {
            Matrix::Dense(m) => {
                for (k, &i) in idx.iter().enumerate() {
                    let row = m.row(i);
                    let s = d[k];
                    if s != 0.0 {
                        for (a, &x) in acc.iter_mut().zip(row) {
                            *a += s * x;
                        }
                    }
                }
            }
            Matrix::Csr(m) => {
                for (k, &i) in idx.iter().enumerate() {
                    let (cols, vals) = m.row(i);
                    let s = d[k];
                    if s != 0.0 {
                        for (&j, &v) in cols.iter().zip(vals) {
                            acc[j as usize] += s * v;
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Full matvec `out = A z` (used by CG and the objective evaluation).
    pub fn matvec(&self, z: &[f64], out: &mut [f64]) -> Result<()> {
        if z.len() != self.cols() || out.len() != self.rows() {
            return Err(Error::Shape("matvec dims".into()));
        }
        match self {
            Matrix::Dense(m) => m.matvec(z, out),
            Matrix::Csr(m) => m.matvec(z, out),
        }
        Ok(())
    }

    /// Full transposed matvec `out = Aᵀ v`.
    pub fn matvec_t(&self, v: &[f64], out: &mut [f64]) -> Result<()> {
        if v.len() != self.rows() || out.len() != self.cols() {
            return Err(Error::Shape("matvec_t dims".into()));
        }
        match self {
            Matrix::Dense(m) => m.matvec_t(v, out),
            Matrix::Csr(m) => m.matvec_t(v, out),
        }
        Ok(())
    }

    /// Column-range slice `A[:, lo..hi]` (1D-block column partitioning).
    pub fn slice_cols(&self, lo: usize, hi: usize) -> Result<Matrix> {
        if lo > hi || hi > self.cols() {
            return Err(Error::InvalidArg(format!("slice_cols {lo}..{hi}")));
        }
        Ok(match self {
            Matrix::Dense(m) => Matrix::Dense(m.slice_cols(lo, hi)),
            Matrix::Csr(m) => Matrix::Csr(m.slice_cols(lo, hi)),
        })
    }

    /// Transpose (used to build the dual operand `A = Xᵀ`).
    pub fn transpose(&self) -> Matrix {
        match self {
            Matrix::Dense(m) => Matrix::Dense(m.transpose()),
            Matrix::Csr(m) => Matrix::Csr(m.transpose()),
        }
    }

    /// Squared Frobenius norm.
    pub fn fro_norm_sq(&self) -> f64 {
        match self {
            Matrix::Dense(m) => m.data().iter().map(|v| v * v).sum(),
            Matrix::Csr(m) => m.values().iter().map(|v| v * v).sum(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_dense() -> Matrix {
        // 3x4
        Matrix::Dense(DenseMatrix::from_vec(
            3,
            4,
            vec![1., 2., 0., 0., 0., 3., 4., 0., 5., 0., 0., 6.],
        ))
    }

    fn small_csr() -> Matrix {
        let d = small_dense();
        match &d {
            Matrix::Dense(m) => Matrix::Csr(CsrMatrix::from_dense(m)),
            _ => unreachable!(),
        }
    }

    #[test]
    fn dense_csr_agree_on_gram() {
        let (d, s) = (small_dense(), small_csr());
        let idx = [2usize, 0];
        let mut gd = vec![0.0; 4];
        let mut gs = vec![0.0; 4];
        d.sampled_gram(&idx, &mut gd).unwrap();
        s.sampled_gram(&idx, &mut gs).unwrap();
        assert_eq!(gd, gs);
        // row2·row2 = 25+36=61, row2·row0 = 5
        assert_eq!(gd[0], 61.0);
        assert_eq!(gd[1], 5.0);
        assert_eq!(gd[2], 5.0);
    }

    #[test]
    fn dense_csr_agree_on_matvec_paths() {
        let (d, s) = (small_dense(), small_csr());
        let z = [1., -1., 2., 0.5];
        let mut rd = vec![0.0; 2];
        let mut rs = vec![0.0; 2];
        d.sampled_matvec(&[1, 2], &z, &mut rd).unwrap();
        s.sampled_matvec(&[1, 2], &z, &mut rs).unwrap();
        assert_eq!(rd, rs);
        assert_eq!(rd[0], -3. + 8.);
        let mut accd = vec![0.0; 4];
        let mut accs = vec![0.0; 4];
        d.scatter_rows_add(&[0, 0], &[1.0, 2.0], &mut accd).unwrap();
        s.scatter_rows_add(&[0, 0], &[1.0, 2.0], &mut accs).unwrap();
        assert_eq!(accd, accs);
        assert_eq!(accd[0], 3.0);
    }

    #[test]
    fn transpose_roundtrip() {
        let d = small_dense();
        let tt = d.transpose().transpose();
        let mut a = vec![0.0; 3];
        let mut b = vec![0.0; 3];
        let z = [1., 2., 3., 4.];
        d.matvec(&z, &mut a).unwrap();
        tt.matvec(&z, &mut b).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn slice_cols_matches_manual() {
        let d = small_dense();
        let sl = d.slice_cols(1, 3).unwrap();
        assert_eq!(sl.rows(), 3);
        assert_eq!(sl.cols(), 2);
        let mut out = vec![0.0; 3];
        sl.matvec(&[1.0, 1.0], &mut out).unwrap();
        assert_eq!(out, vec![2.0, 7.0, 0.0]);
    }

    #[test]
    fn packed_gram_agrees_with_full_for_both_storages() {
        for m in [small_dense(), small_csr()] {
            let idx = [2usize, 0, 1];
            let mut full = vec![0.0; 9];
            m.sampled_gram(&idx, &mut full).unwrap();
            let mut packed = vec![0.0; 6];
            m.sampled_gram_packed(&idx, &mut packed).unwrap();
            for r in 0..3 {
                for c in 0..3 {
                    assert_eq!(full[r * 3 + c], packed[crate::linalg::pidx(r, c)]);
                }
            }
        }
    }

    #[test]
    fn shape_errors() {
        let d = small_dense();
        let mut out = vec![0.0; 3];
        assert!(d.sampled_gram(&[0, 1], &mut out).is_err());
        assert!(d.sampled_gram_packed(&[0, 1], &mut out).is_ok());
        assert!(d.sampled_gram_packed(&[0, 1, 2], &mut out).is_err());
        assert!(d.slice_cols(3, 2).is_err());
        assert!(d.slice_cols(0, 9).is_err());
    }
}
