//! Thread-backed communicator: P ranks as OS threads, one tagged inbox per
//! rank, a rank-local message-buffer pool, and MPICH-style collective
//! algorithms (recursive doubling / Rabenseifner allreduce, binomial-tree
//! broadcast). See the module docs of [`crate::comm`] for the algorithm
//! selection rules, the zero-allocation invariant, and the poisoned-group
//! failure semantics.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use crate::comm::proto::{self, add_into, Group, Wire};
use crate::comm::{
    A2aState, Algo, AllToAllHandle, Communicator, CostMeter, HandleState, ReduceHandle, Topology,
};
use crate::error::{Error, Result};
use crate::telemetry;
use crate::trace::{self, OpClass, SpanKind};

/// Payload size (f64 words) at which allreduce switches from recursive
/// doubling (latency-optimal, `len·log₂P` words/rank) to Rabenseifner
/// reduce-scatter + allgather (bandwidth-optimal, `≈2·len·(P−1)/P`
/// words/rank). 256 words = 2 KiB, MPICH's long-message crossover.
pub const RABENSEIFNER_MIN_WORDS: usize = 256;

/// Upper bound on pooled buffers retained per rank (bounds worst-case
/// memory when collectives of many distinct sizes interleave).
const POOL_MAX: usize = 64;

/// Wire format of one point-to-point message. Data packets carry the
/// **operation tag** of the collective that sent them: receives match on
/// `(source, tag)`, so collectives running between a non-blocking start
/// and its wait cannot steal the in-flight operation's messages.
enum Packet {
    Data(u64, Vec<f64>),
    /// Group poisoning: a peer detected a protocol violation. Carried to
    /// every rank so nobody blocks forever in `recv`.
    Poison(String),
}

/// Rank-local endpoint of a P-rank thread communicator.
pub struct ThreadComm {
    rank: usize,
    size: usize,
    /// `send_to[p]` delivers into rank p's `inbox`, tagged with our rank.
    send_to: Vec<Sender<(usize, Packet)>>,
    inbox: Receiver<(usize, Packet)>,
    /// Out-of-order stash: `(tag, data)` that arrived from rank `s` while
    /// we were waiting on a different source or operation (per-source,
    /// per-tag FIFO order is preserved — within one operation every
    /// message has a distinct round, and rounds are matched in order).
    pending: Vec<VecDeque<(u64, Vec<f64>)>>,
    /// Recycled message buffers (the zero-allocation hot path).
    pool: Vec<Vec<f64>>,
    /// Sticky failure state: once poisoned, every collective errors.
    poisoned: Option<String>,
    /// Monotone per-endpoint collective counter — SPMD determinism means
    /// operation k on one rank is operation k on every rank, which is
    /// what makes the tag a valid cross-rank match key.
    op_seq: u64,
    /// Tag of the operation currently sending/receiving on this endpoint.
    cur_tag: u64,
    /// Per-receive deadline ([`Communicator::set_deadline`]): `None` waits
    /// forever (the pre-PR-8 behaviour), `Some(d)` bounds every blocking
    /// receive and converts an expiry into a poisoned group — so a dead or
    /// stalled peer is an `Error::Comm` on every rank, never a hang.
    deadline: Option<Duration>,
    /// Collective topology ([`Communicator::set_topology`]): flat
    /// single-level algorithms, or the hierarchical two-level composition.
    topology: Topology,
    meter: CostMeter,
}

impl ThreadComm {
    /// Create a fully-connected group of P endpoints.
    pub fn group(p: usize) -> Vec<ThreadComm> {
        assert!(p >= 1, "communicator needs at least one rank");
        let mut txs = Vec::with_capacity(p);
        let mut rxs = Vec::with_capacity(p);
        for _ in 0..p {
            let (tx, rx) = channel();
            txs.push(tx);
            rxs.push(rx);
        }
        rxs.into_iter()
            .enumerate()
            .map(|(rank, inbox)| ThreadComm {
                rank,
                size: p,
                send_to: txs.clone(),
                inbox,
                pending: (0..p).map(|_| VecDeque::new()).collect(),
                pool: Vec::new(),
                poisoned: None,
                op_seq: 0,
                cur_tag: 0,
                deadline: None,
                topology: Topology::Flat,
                meter: CostMeter::default(),
            })
            .collect()
    }

    // ---- buffer pool ----------------------------------------------------

    /// Take a cleared pooled buffer, preferring one whose capacity already
    /// fits `len` (best-fit keeps the steady state allocation-free even
    /// when message sizes vary within one collective, as in Rabenseifner's
    /// halving rounds). A pool miss or capacity growth counts as one
    /// allocation in [`CostMeter::buf_allocs`].
    fn pool_take_for(&mut self, len: usize) -> Vec<f64> {
        let picked = match self.pool.iter().rposition(|v| v.capacity() >= len) {
            Some(i) => Some(self.pool.swap_remove(i)),
            None => self.pool.pop(),
        };
        let mut v = picked.unwrap_or_default();
        if v.capacity() < len {
            self.meter.buf_allocs += 1;
        }
        v.clear();
        v
    }

    fn take_buf_inner(&mut self, len: usize) -> Vec<f64> {
        let mut v = self.pool_take_for(len);
        v.resize(len, 0.0);
        v
    }

    fn give_buf_inner(&mut self, buf: Vec<f64>) {
        if self.pool.len() < POOL_MAX {
            self.pool.push(buf);
        }
    }

    // ---- point-to-point -------------------------------------------------

    /// Enter a new collective operation: bump the sequence counter and
    /// make its tag current for every send/receive until the next entry
    /// (non-blocking waits restore their handle's tag instead).
    fn begin_op(&mut self) -> u64 {
        self.op_seq += 1;
        self.cur_tag = self.op_seq;
        self.op_seq
    }

    /// Copy `data` into a pooled buffer and send it (slice-based send: the
    /// caller's buffer is never cloned onto the heap after warmup).
    fn send_slice(&mut self, dst: usize, data: &[f64]) -> Result<()> {
        let mut msg = self.pool_take_for(data.len());
        msg.extend_from_slice(data);
        self.send_owned(dst, msg)
    }

    fn send_owned(&mut self, dst: usize, buf: Vec<f64>) -> Result<()> {
        self.meter.record_send(buf.len());
        let pkt = Packet::Data(self.cur_tag, buf);
        if self.send_to[dst].send((self.rank, pkt)).is_err() {
            // The peer dropped its endpoint — almost always because it
            // errored out of the protocol, and its poison broadcast
            // happens-before the drop, so it is already in our inbox:
            // surface that group failure rather than a bare send error.
            self.check_poison()?;
            return Err(Error::Comm(format!(
                "send {}→{dst}: peer terminated",
                self.rank
            )));
        }
        Ok(())
    }

    fn poisoned_err(msg: &str) -> Error {
        Error::Comm(format!("group poisoned: {msg}"))
    }

    /// Broadcast a poison packet to every peer, mark ourselves poisoned,
    /// and return the error to propagate.
    fn poison(&mut self, msg: String) -> Error {
        for (dst, tx) in self.send_to.iter().enumerate() {
            if dst != self.rank {
                let _ = tx.send((self.rank, Packet::Poison(msg.clone())));
            }
        }
        let err = Self::poisoned_err(&msg);
        self.poisoned = Some(msg);
        err
    }

    /// Drain any already-arrived packets (stashing data, latching poison)
    /// and fail if the group is poisoned. Called at collective entry so a
    /// rank that would only *send* in the current round still observes a
    /// peer's failure.
    fn check_poison(&mut self) -> Result<()> {
        if self.poisoned.is_none() {
            while let Ok((from, pkt)) = self.inbox.try_recv() {
                match pkt {
                    Packet::Data(tag, v) => self.pending[from].push_back((tag, v)),
                    Packet::Poison(m) => {
                        self.poisoned = Some(m);
                        break;
                    }
                }
            }
        }
        match &self.poisoned {
            Some(m) => Err(Self::poisoned_err(m)),
            None => Ok(()),
        }
    }

    /// Blocking receive from a specific source **for the current
    /// operation tag**. Messages from other sources or other operations
    /// are stashed (per-source FIFO, matched in tag order within an
    /// operation); a poison packet from *any* source aborts the wait; an
    /// expired deadline ([`Communicator::set_deadline`]) counts one
    /// [`CostMeter::timeouts`] and poisons the group, so a dead or
    /// stalled peer surfaces as `Error::Comm` everywhere instead of this
    /// rank blocking forever on its inbox.
    fn recv(&mut self, src: usize) -> Result<Vec<f64>> {
        if let Some(m) = &self.poisoned {
            return Err(Self::poisoned_err(m));
        }
        let tag = self.cur_tag;
        if let Some(pos) = self.pending[src].iter().position(|(t, _)| *t == tag) {
            let Some((_, v)) = self.pending[src].remove(pos) else {
                // Unreachable (position was just found); poison instead
                // of aborting so peers fail fast rather than hang.
                return Err(self.poison(format!(
                    "internal: stashed packet vanished (src {src}, tag {tag})"
                )));
            };
            self.meter.record_recv(v.len());
            return Ok(v);
        }
        // The deadline is per-receive, armed on entering the blocking wait
        // (not per-message-attempt: stashed traffic from other operations
        // must not extend it).
        let expiry = self.deadline.map(|d| (Instant::now() + d, d));
        loop {
            let received = match expiry {
                None => self.inbox.recv().map_err(|_| None),
                Some((limit, budget)) => {
                    let remaining = limit.saturating_duration_since(Instant::now());
                    self.inbox.recv_timeout(remaining).map_err(|e| match e {
                        RecvTimeoutError::Timeout => Some(budget),
                        RecvTimeoutError::Disconnected => None,
                    })
                }
            };
            match received {
                Ok((from, Packet::Data(t, v))) => {
                    if from == src && t == tag {
                        self.meter.record_recv(v.len());
                        return Ok(v);
                    }
                    self.pending[from].push_back((t, v));
                }
                Ok((_from, Packet::Poison(m))) => {
                    let err = Self::poisoned_err(&m);
                    self.poisoned = Some(m);
                    return Err(err);
                }
                Err(Some(budget)) => {
                    self.meter.timeouts += 1;
                    telemetry::count(telemetry::Counter::Timeouts, 1);
                    return Err(self.poison(format!(
                        "rank {} timed out after {budget:?} waiting for rank {src} (op tag {tag})",
                        self.rank,
                    )));
                }
                Err(None) => {
                    return Err(Error::Comm(format!(
                        "recv {}←{src}: channel closed",
                        self.rank
                    )))
                }
            }
        }
    }

    /// Receive with a length contract; a mismatch poisons the group.
    fn recv_expect(&mut self, src: usize, len: usize) -> Result<Vec<f64>> {
        let v = self.recv(src)?;
        if v.len() != len {
            return Err(self.poison(format!(
                "payload length mismatch: rank {} expected {len} words from rank {src}, got {}",
                self.rank,
                v.len()
            )));
        }
        Ok(v)
    }

    // ---- allreduce cores ------------------------------------------------
    //
    // The collective algorithms themselves (recursive doubling,
    // Rabenseifner, the binomial broadcast tree, and the two-level
    // composition) live in [`crate::comm::proto`], generic over the
    // [`Wire`] point-to-point seam below — shared verbatim with the
    // process transport so the two are bitwise identical.

    /// Allreduce protocol selected by the current topology: size dispatch
    /// over the flat group, or the two-level composition.
    fn algo_for(&self, len: usize) -> Algo {
        match self.topology {
            Topology::Flat => proto::select_algo(self.size, len),
            Topology::TwoLevel { node_size } => Algo::TwoLevel { node_size },
        }
    }

    /// Shared body of the personalized exchanges. A wrong buffer (or
    /// receive-length) count poisons the group; with `recv_lens` present,
    /// the self-payload is validated before any send and every receive
    /// runs through the `recv_expect` length contract, so a mis-sized
    /// payload poisons every rank instead of desynchronizing receivers.
    fn all_to_all_inner(
        &mut self,
        send: Vec<Vec<f64>>,
        recv_lens: Option<&[usize]>,
    ) -> Result<Vec<Vec<f64>>> {
        self.meter.all_to_alls += 1;
        let tag = self.begin_op();
        let words: u64 = send.iter().map(|v| v.len() as u64).sum();
        // Blocking exchange: instantaneous start marker, wait span over
        // the whole protocol (start counts thus match the meters under
        // either schedule).
        trace::mark(SpanKind::CollectiveStart, OpClass::AllToAll, tag, words);
        let t0 = trace::now();
        let u0 = telemetry::now();
        let res = self.all_to_all_body(send, recv_lens);
        trace::record(SpanKind::CollectiveWait, OpClass::AllToAll, tag, words, t0);
        telemetry::count(telemetry::Counter::Collectives, 1);
        telemetry::gauge(telemetry::Gauge::PayloadWords, words);
        telemetry::observe(telemetry::Hist::AllToAllWords, words);
        telemetry::observe_since(telemetry::Hist::AllToAllNs, u0);
        res
    }

    fn all_to_all_body(
        &mut self,
        send: Vec<Vec<f64>>,
        recv_lens: Option<&[usize]>,
    ) -> Result<Vec<Vec<f64>>> {
        let p = self.size;
        if send.len() != p {
            return Err(self.poison(format!(
                "all_to_all: rank {} supplied {} buffers for {p} ranks",
                self.rank,
                send.len()
            )));
        }
        if let Some(lens) = recv_lens {
            if lens.len() != p {
                return Err(self.poison(format!(
                    "all_to_all: rank {} supplied {} receive lengths for {p} ranks",
                    self.rank,
                    lens.len()
                )));
            }
            if send[self.rank].len() != lens[self.rank] {
                return Err(self.poison(format!(
                    "all_to_all: rank {} self-payload {} words != expected {}",
                    self.rank,
                    send[self.rank].len(),
                    lens[self.rank]
                )));
            }
        }
        if p == 1 {
            return Ok(send);
        }
        self.check_poison()?;
        let mut out: Vec<Vec<f64>> = (0..p).map(|_| Vec::new()).collect();
        for (dst, bufv) in send.into_iter().enumerate() {
            if dst == self.rank {
                out[dst] = bufv;
            } else {
                self.send_owned(dst, bufv)?;
            }
        }
        for src in 0..p {
            if src != self.rank {
                out[src] = match recv_lens {
                    Some(lens) => self.recv_expect(src, lens[src])?,
                    None => self.recv(src)?,
                };
            }
        }
        Ok(out)
    }

    /// The seed repo's reduce-to-0-then-broadcast allreduce (2⌈log₂P⌉
    /// serialized rounds, full payload each hop). Kept as the benchmark
    /// baseline and as a numerically independent cross-check oracle for
    /// the property tests; not used by any solver.
    pub fn allreduce_sum_reference(&mut self, buf: &mut [f64]) -> Result<()> {
        self.meter.allreduces += 1;
        let tag = self.begin_op();
        let words = buf.len() as u64;
        trace::mark(SpanKind::CollectiveStart, OpClass::Allreduce, tag, words);
        let t0 = trace::now();
        let res = self.allreduce_reference_body(buf);
        trace::record(SpanKind::CollectiveWait, OpClass::Allreduce, tag, words, t0);
        res
    }

    fn allreduce_reference_body(&mut self, buf: &mut [f64]) -> Result<()> {
        let p = self.size;
        if p == 1 {
            return Ok(());
        }
        self.check_poison()?;
        let mut mask = 1usize;
        while mask < p {
            if self.rank & mask != 0 {
                let dst = self.rank & !mask;
                self.send_slice(dst, buf)?;
                break;
            } else {
                let src = self.rank | mask;
                if src < p {
                    let got = self.recv_expect(src, buf.len())?;
                    add_into(buf, &got);
                    self.give_buf_inner(got);
                }
            }
            mask <<= 1;
        }
        let g = Group::flat(self.size, self.rank);
        proto::broadcast_tree(self, &g, 0, buf)
    }
}

/// Point-to-point seam of the shared collective engine
/// ([`crate::comm::proto`]): metered pooled sends, tag-matched
/// length-contracted receives, pool recycling.
impl Wire for ThreadComm {
    fn wire_rank(&self) -> usize {
        self.rank
    }

    fn wire_size(&self) -> usize {
        self.size
    }

    fn wire_send(&mut self, dst: usize, data: &[f64]) -> Result<()> {
        self.send_slice(dst, data)
    }

    fn wire_recv(&mut self, src: usize, len: usize) -> Result<Vec<f64>> {
        self.recv_expect(src, len)
    }

    fn wire_recycle(&mut self, buf: Vec<f64>) {
        self.give_buf_inner(buf)
    }
}

impl Communicator for ThreadComm {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn allreduce_sum(&mut self, buf: &mut [f64]) -> Result<()> {
        self.meter.allreduces += 1;
        let tag = self.begin_op();
        let words = buf.len() as u64;
        trace::mark(SpanKind::CollectiveStart, OpClass::Allreduce, tag, words);
        let t0 = trace::now();
        let u0 = telemetry::now();
        let algo = self.algo_for(buf.len());
        let res = if self.size == 1 {
            Ok(())
        } else {
            self.check_poison()
                .and_then(|_| proto::allreduce_dispatch(self, algo, buf, false))
        };
        trace::record(SpanKind::CollectiveWait, OpClass::Allreduce, tag, words, t0);
        telemetry::count(telemetry::Counter::Collectives, 1);
        telemetry::gauge(telemetry::Gauge::PayloadWords, words);
        telemetry::observe(telemetry::Hist::AllreduceWords, words);
        telemetry::observe_since(telemetry::Hist::AllreduceNs, u0);
        res
    }

    fn iallreduce_start(&mut self, buf: Vec<f64>) -> Result<ReduceHandle> {
        self.meter.allreduces += 1;
        let tag = self.begin_op();
        let words = buf.len() as u64;
        let t0 = trace::now();
        let res = (|| {
            if self.size == 1 {
                return Ok(ReduceHandle {
                    buf,
                    state: HandleState::Done,
                });
            }
            self.check_poison()?;
            let algo = self.algo_for(buf.len());
            let first_sent = proto::post_first_dispatch(self, algo, &buf)?;
            Ok(ReduceHandle {
                buf,
                state: HandleState::Thread {
                    algo,
                    first_sent,
                    tag,
                },
            })
        })();
        trace::record(SpanKind::CollectiveStart, OpClass::Allreduce, tag, words, t0);
        telemetry::count(telemetry::Counter::Collectives, 1);
        telemetry::gauge(telemetry::Gauge::PayloadWords, words);
        telemetry::observe(telemetry::Hist::AllreduceWords, words);
        res
    }

    fn iallreduce_wait(&mut self, handle: ReduceHandle) -> Result<Vec<f64>> {
        self.meter.collective_waits += 1;
        let ReduceHandle { mut buf, state } = handle;
        let words = buf.len() as u64;
        let t0 = trace::now();
        let u0 = telemetry::now();
        let (tag, res) = match state {
            HandleState::Done => (self.cur_tag, Ok(())),
            HandleState::Thread {
                algo,
                first_sent,
                tag,
            } => {
                // Resume under the operation tag assigned at start time —
                // collectives that ran in between used their own tags.
                self.cur_tag = tag;
                let r = proto::allreduce_dispatch(self, algo, &mut buf, first_sent);
                (tag, r)
            }
        };
        trace::record(SpanKind::CollectiveWait, OpClass::Allreduce, tag, words, t0);
        telemetry::observe_since(telemetry::Hist::WaitNs, u0);
        res.map(|()| buf)
    }

    fn broadcast(&mut self, root: usize, buf: &mut [f64]) -> Result<()> {
        self.begin_op();
        if self.size == 1 {
            return Ok(());
        }
        self.check_poison()?;
        let g = Group::flat(self.size, self.rank);
        proto::broadcast_tree(self, &g, root, buf)
    }

    /// Direct personalized exchange: P−1 sends + P−1 receives per rank
    /// (the "large message" regime of Theorems 4/8: L = O(P)).
    fn all_to_all(&mut self, send: Vec<Vec<f64>>) -> Result<Vec<Vec<f64>>> {
        self.all_to_all_inner(send, None)
    }

    /// Personalized exchange with receive-side length contracts: every
    /// incoming payload is checked against `recv_lens[src]` and a mismatch
    /// poisons the group (via `recv_expect`) — all ranks error instead of
    /// the receivers hanging on a desynchronized reassembly.
    fn all_to_all_expect(
        &mut self,
        send: Vec<Vec<f64>>,
        recv_lens: &[usize],
    ) -> Result<Vec<Vec<f64>>> {
        self.all_to_all_inner(send, Some(recv_lens))
    }

    /// Non-blocking personalized exchange: post every send now, drain the
    /// receives at [`Communicator::iall_to_all_wait`]. Validation and
    /// poison semantics are identical to the blocking
    /// [`Communicator::all_to_all_expect`]; payload bytes and per-source
    /// ordering are unchanged, so results are bitwise identical.
    fn iall_to_all_start(
        &mut self,
        send: Vec<Vec<f64>>,
        recv_lens: &[usize],
    ) -> Result<AllToAllHandle> {
        self.meter.all_to_alls += 1;
        let tag = self.begin_op();
        let words: u64 = send.iter().map(|v| v.len() as u64).sum();
        let t0 = trace::now();
        let res = self.iall_to_all_start_body(send, recv_lens, tag);
        trace::record(SpanKind::CollectiveStart, OpClass::AllToAll, tag, words, t0);
        telemetry::count(telemetry::Counter::Collectives, 1);
        telemetry::gauge(telemetry::Gauge::PayloadWords, words);
        telemetry::observe(telemetry::Hist::AllToAllWords, words);
        res
    }

    fn iall_to_all_wait(&mut self, handle: AllToAllHandle) -> Result<Vec<Vec<f64>>> {
        self.meter.collective_waits += 1;
        let t0 = trace::now();
        let u0 = telemetry::now();
        let (tag, words_hint, res) = match handle.state {
            A2aState::Ready(out) => {
                let words: u64 = out.iter().map(|v| v.len() as u64).sum();
                (self.cur_tag, words, Ok(out))
            }
            A2aState::Thread {
                tag,
                recv_lens,
                out,
            } => {
                self.cur_tag = tag;
                let words: u64 = recv_lens.iter().map(|&l| l as u64).sum();
                (tag, words, self.iall_to_all_drain(recv_lens, out))
            }
        };
        trace::record(SpanKind::CollectiveWait, OpClass::AllToAll, tag, words_hint, t0);
        telemetry::observe_since(telemetry::Hist::WaitNs, u0);
        res
    }

    fn barrier(&mut self) -> Result<()> {
        self.begin_op();
        if self.size == 1 {
            return Ok(());
        }
        self.check_poison()?;
        // Zero-payload recursive doubling: counts the message rounds, no
        // words. Always flat — a hierarchical barrier would add hops for
        // a zero-word payload with nothing to gain.
        let u0 = telemetry::now();
        let g = Group::flat(self.size, self.rank);
        let res = proto::allreduce_rd(self, &g, &mut [], false);
        telemetry::count(telemetry::Counter::Collectives, 1);
        telemetry::observe_since(telemetry::Hist::BarrierNs, u0);
        res
    }

    fn set_deadline(&mut self, deadline: Option<Duration>) {
        self.deadline = deadline;
    }

    fn set_topology(&mut self, topology: Topology) {
        self.topology = topology;
    }

    fn take_buf(&mut self, len: usize) -> Vec<f64> {
        self.take_buf_inner(len)
    }

    fn give_buf(&mut self, buf: Vec<f64>) {
        self.give_buf_inner(buf)
    }

    fn meter(&self) -> &CostMeter {
        &self.meter
    }

    fn meter_mut(&mut self) -> &mut CostMeter {
        &mut self.meter
    }
}

impl ThreadComm {
    fn iall_to_all_start_body(
        &mut self,
        send: Vec<Vec<f64>>,
        recv_lens: &[usize],
        tag: u64,
    ) -> Result<AllToAllHandle> {
        let p = self.size;
        if send.len() != p {
            return Err(self.poison(format!(
                "iall_to_all: rank {} supplied {} buffers for {p} ranks",
                self.rank,
                send.len()
            )));
        }
        if recv_lens.len() != p {
            return Err(self.poison(format!(
                "iall_to_all: rank {} supplied {} receive lengths for {p} ranks",
                self.rank,
                recv_lens.len()
            )));
        }
        if send[self.rank].len() != recv_lens[self.rank] {
            return Err(self.poison(format!(
                "iall_to_all: rank {} self-payload {} words != expected {}",
                self.rank,
                send[self.rank].len(),
                recv_lens[self.rank]
            )));
        }
        if p == 1 {
            return Ok(AllToAllHandle {
                state: A2aState::Ready(send),
            });
        }
        self.check_poison()?;
        let mut out: Vec<Vec<f64>> = (0..p).map(|_| Vec::new()).collect();
        for (dst, bufv) in send.into_iter().enumerate() {
            if dst == self.rank {
                out[dst] = bufv;
            } else {
                self.send_owned(dst, bufv)?;
            }
        }
        Ok(AllToAllHandle {
            state: A2aState::Thread {
                tag,
                recv_lens: recv_lens.to_vec(),
                out,
            },
        })
    }

    /// Receive side of an in-flight all-to-all, resumed under its tag.
    fn iall_to_all_drain(
        &mut self,
        recv_lens: Vec<usize>,
        mut out: Vec<Vec<f64>>,
    ) -> Result<Vec<Vec<f64>>> {
        for src in 0..self.size {
            if src != self.rank {
                out[src] = self.recv_expect(src, recv_lens[src])?;
            }
        }
        Ok(out)
    }
}

/// Exact per-rank (sends, send-words) of one `allreduce_sum` of `len`
/// words on a `p`-rank group — mirrors the selection and chunking logic so
/// the CostMeter tests can assert measured == formula.
pub fn expected_allreduce_sends(p: usize, rank: usize, len: usize) -> (u64, u64) {
    if p <= 1 {
        return (0, 0);
    }
    let pof2 = proto::pof2_below(p);
    let rem = p - pof2;
    let rab = len >= RABENSEIFNER_MIN_WORDS && len >= pof2 && pof2 >= 2;
    let folded_even = rank < 2 * rem && rank % 2 == 0;
    let folded_odd = rank < 2 * rem && rank % 2 == 1;
    if folded_even {
        // One fold send; the unfold is a receive.
        return (1, len as u64);
    }
    let nr = if folded_odd { rank / 2 } else { rank - rem };
    let (mut msgs, mut words) = (0u64, 0u64);
    if rab {
        let base = len / pof2;
        let ext = len % pof2;
        let displ = |i: usize| i * base + i.min(ext);
        let (mut clo, mut chi) = (0usize, pof2);
        let mut mask = pof2 >> 1;
        while mask > 0 {
            let pn = nr ^ mask;
            let mid = clo + (chi - clo) / 2;
            let (klo, khi, slo, shi) = if nr < pn {
                (clo, mid, mid, chi)
            } else {
                (mid, chi, clo, mid)
            };
            // Reduce-scatter send of the non-kept half…
            msgs += 1;
            words += (displ(shi) - displ(slo)) as u64;
            // …and the mirrored allgather send of the kept half.
            msgs += 1;
            words += (displ(khi) - displ(klo)) as u64;
            clo = klo;
            chi = khi;
            mask >>= 1;
        }
    } else {
        let log2p = pof2.trailing_zeros() as u64;
        msgs += log2p;
        words += log2p * len as u64;
    }
    if folded_odd {
        // Unfold send of the full result back to the even neighbour.
        msgs += 1;
        words += len as u64;
    }
    (msgs, words)
}

/// Run `f(rank, comm)` on P threads and collect per-rank results in rank
/// order. Panics in any rank propagate.
pub fn run_spmd<T, F>(p: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, &mut ThreadComm) -> T + Sync,
{
    let comms = ThreadComm::group(p);
    let mut out: Vec<Option<T>> = (0..p).map(|_| None).collect();
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(p);
        for (rank, mut comm) in comms.into_iter().enumerate() {
            let fref = &f;
            handles.push(scope.spawn(move || (rank, fref(rank, &mut comm), comm.meter)));
        }
        for h in handles {
            let (rank, val, _meter) = h.join().expect("SPMD rank panicked");
            out[rank] = Some(val);
        }
    });
    out.into_iter().map(|v| v.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allreduce_sums_across_ranks() {
        for p in [1usize, 2, 3, 4, 5, 6, 7, 8] {
            let results = run_spmd(p, |rank, comm| {
                let mut buf = vec![rank as f64, 1.0];
                comm.allreduce_sum(&mut buf).unwrap();
                buf
            });
            let expect = vec![(0..p).sum::<usize>() as f64, p as f64];
            for r in results {
                assert_eq!(r, expect, "p={p}");
            }
        }
    }

    #[test]
    fn large_payload_allreduce_uses_rabenseifner_and_sums() {
        // Above the crossover: exercise the reduce-scatter/allgather path,
        // including uneven chunking (len not divisible by pof2).
        for p in [2usize, 3, 4, 5, 7, 8] {
            let len = RABENSEIFNER_MIN_WORDS + 13;
            let results = run_spmd(p, move |rank, comm| {
                let mut buf: Vec<f64> = (0..len).map(|i| (rank * len + i) as f64).collect();
                comm.allreduce_sum(&mut buf).unwrap();
                buf
            });
            for i in 0..len {
                let expect: f64 = (0..p).map(|r| (r * len + i) as f64).sum();
                for (rank, r) in results.iter().enumerate() {
                    assert_eq!(r[i], expect, "p={p} rank={rank} idx={i}");
                }
            }
        }
    }

    #[test]
    fn broadcast_from_each_root() {
        for p in [2usize, 3, 7] {
            for root in 0..p {
                let results = run_spmd(p, |rank, comm| {
                    let mut buf = if rank == root {
                        vec![42.0, root as f64]
                    } else {
                        vec![0.0, 0.0]
                    };
                    comm.broadcast(root, &mut buf).unwrap();
                    buf
                });
                for r in results {
                    assert_eq!(r, vec![42.0, root as f64], "p={p} root={root}");
                }
            }
        }
    }

    #[test]
    fn all_to_all_permutes_payloads() {
        let p = 4;
        let results = run_spmd(p, |rank, comm| {
            let send: Vec<Vec<f64>> = (0..p)
                .map(|dst| vec![(rank * 10 + dst) as f64])
                .collect();
            comm.all_to_all(send).unwrap()
        });
        for (rank, got) in results.iter().enumerate() {
            for (src, v) in got.iter().enumerate() {
                assert_eq!(v, &[(src * 10 + rank) as f64]);
            }
        }
    }

    #[test]
    fn all_to_all_expect_matches_plain_all_to_all() {
        for p in [1usize, 3, 4] {
            let results = run_spmd(p, |rank, comm| {
                // Rank r sends (r + 1) words to everyone.
                let send: Vec<Vec<f64>> = (0..p)
                    .map(|dst| vec![(rank * 10 + dst) as f64; rank + 1])
                    .collect();
                let lens: Vec<usize> = (0..p).map(|src| src + 1).collect();
                comm.all_to_all_expect(send, &lens).unwrap()
            });
            for (rank, got) in results.iter().enumerate() {
                for (src, v) in got.iter().enumerate() {
                    assert_eq!(v, &vec![(src * 10 + rank) as f64; src + 1], "p={p}");
                }
            }
        }
    }

    #[test]
    fn allreduce_message_count_is_logarithmic() {
        for p in [2usize, 4, 8, 16] {
            let meters = run_spmd(p, |_rank, comm| {
                let mut buf = vec![1.0; 16];
                comm.allreduce_sum(&mut buf).unwrap();
                *comm.meter()
            });
            let (msgs, _) = CostMeter::critical_path(&meters);
            let logp = (p as f64).log2().ceil() as u64;
            assert!(
                msgs <= 2 * logp,
                "p={p}: critical-path msgs {msgs} > 2·log₂P = {}",
                2 * logp
            );
        }
    }

    #[test]
    fn nonblocking_allreduce_is_bitwise_equal_to_blocking() {
        for p in [2usize, 3, 5, 8] {
            for len in [7usize, RABENSEIFNER_MIN_WORDS + 5] {
                let results = run_spmd(p, move |rank, comm| {
                    let data: Vec<f64> =
                        (0..len).map(|i| ((rank + 1) * (i + 1)) as f64 * 0.37).collect();
                    let mut blocking = data.clone();
                    comm.allreduce_sum(&mut blocking).unwrap();
                    let h = comm.iallreduce_start(data).unwrap();
                    let nonblocking = comm.iallreduce_wait(h).unwrap();
                    (blocking, nonblocking)
                });
                for (rank, (b, nb)) in results.iter().enumerate() {
                    assert!(b == nb, "p={p} len={len} rank={rank}: bitwise mismatch");
                }
            }
        }
    }

    #[test]
    fn reference_allreduce_agrees_with_production() {
        for p in [3usize, 4, 6] {
            let results = run_spmd(p, |rank, comm| {
                let mut a = vec![rank as f64 + 0.25, -(rank as f64)];
                let mut b = a.clone();
                comm.allreduce_sum(&mut a).unwrap();
                comm.allreduce_sum_reference(&mut b).unwrap();
                (a, b)
            });
            for (a, b) in results {
                for (x, y) in a.iter().zip(&b) {
                    assert!((x - y).abs() <= 1e-12 * x.abs().max(1.0), "{x} vs {y}");
                }
            }
        }
    }

    #[test]
    fn steady_state_allreduce_does_not_allocate() {
        // Pool capacities grow monotonically and the buffer population is
        // bounded, so allocations must stop once warmed up — including the
        // uneven message sizes of non-power-of-two fold/unfold phases.
        // Covers both the Rabenseifner and recursive-doubling regimes.
        for len in [300usize, 8] {
            for p in [2usize, 5, 8] {
                run_spmd(p, move |_rank, comm| {
                    let mut buf = vec![1.0; len];
                    for _ in 0..32 {
                        comm.allreduce_sum(&mut buf).unwrap();
                    }
                    let warm = comm.meter().buf_allocs;
                    for _ in 0..16 {
                        comm.allreduce_sum(&mut buf).unwrap();
                    }
                    assert_eq!(
                        comm.meter().buf_allocs,
                        warm,
                        "pool missed after warmup (p={p}, len={len})"
                    );
                });
            }
        }
    }

    #[test]
    fn stalled_peer_times_out_and_poisons_the_group() {
        let results = run_spmd(2, |rank, comm| {
            comm.set_deadline(Some(Duration::from_millis(40)));
            let mut buf = vec![rank as f64; 4];
            if rank == 1 {
                // Stall well past rank 0's deadline before participating.
                std::thread::sleep(Duration::from_millis(400));
            }
            let res = comm.allreduce_sum(&mut buf);
            (res.err(), comm.meter().timeouts)
        });
        let (err0, t0) = &results[0];
        let e0 = format!("{:?}", err0.as_ref().expect("rank 0 should time out"));
        assert!(e0.contains("timed out"), "{e0}");
        assert!(e0.contains("poisoned"), "{e0}");
        assert_eq!(*t0, 1, "timeout must be metered");
        let (err1, t1) = &results[1];
        let e1 = format!("{:?}", err1.as_ref().expect("rank 1 should see poison"));
        assert!(e1.contains("poisoned"), "{e1}");
        assert_eq!(*t1, 0, "rank 1 stalled, it did not time out");
    }

    #[test]
    fn dead_peer_times_out_instead_of_hanging() {
        // Rank 1 "dies" before entering the collective (never sends).
        // Without a deadline this receive blocks forever — the latent hang
        // this PR closes. Either failure surface is acceptable: the
        // deadline expiry (peer still draining) or the terminated-peer
        // send error (peer already gone); both are Error::Comm, not hangs.
        let results = run_spmd(2, |rank, comm| {
            if rank == 1 {
                return (None, 0);
            }
            comm.set_deadline(Some(Duration::from_millis(40)));
            let mut buf = vec![1.0; 4];
            (comm.allreduce_sum(&mut buf).err(), comm.meter().timeouts)
        });
        let (err0, timeouts) = &results[0];
        let e = format!("{:?}", err0.as_ref().expect("rank 0 must error"));
        assert!(
            e.contains("timed out") || e.contains("peer terminated"),
            "{e}"
        );
        assert!(*timeouts <= 1);
    }

    #[test]
    fn clearing_the_deadline_restores_unbounded_waits() {
        let results = run_spmd(3, |rank, comm| {
            comm.set_deadline(Some(Duration::from_secs(5)));
            comm.set_deadline(None);
            let mut buf = vec![rank as f64; 8];
            comm.allreduce_sum(&mut buf).unwrap();
            (buf, comm.meter().timeouts)
        });
        for (buf, timeouts) in results {
            assert_eq!(buf, vec![3.0; 8]);
            assert_eq!(timeouts, 0);
        }
    }

    #[test]
    fn barrier_completes() {
        run_spmd(5, |_rank, comm| {
            for _ in 0..3 {
                comm.barrier().unwrap();
            }
        });
    }
}
