//! Thread-backed communicator: P ranks as OS threads, a crossbeam channel
//! per ordered rank pair, and MPICH-style binomial-tree collectives.

use std::sync::mpsc::{channel, Receiver, Sender};

use crate::comm::{Communicator, CostMeter};
use crate::error::{Error, Result};

/// Rank-local endpoint of a P-rank thread communicator.
pub struct ThreadComm {
    rank: usize,
    size: usize,
    /// `send_to[p]` delivers to rank p's `recv_from[self.rank]`.
    send_to: Vec<Sender<Vec<f64>>>,
    recv_from: Vec<Receiver<Vec<f64>>>,
    meter: CostMeter,
}

impl ThreadComm {
    /// Create a fully-connected group of P endpoints.
    pub fn group(p: usize) -> Vec<ThreadComm> {
        assert!(p >= 1, "communicator needs at least one rank");
        // channels[src][dst]
        let mut senders: Vec<Vec<Option<Sender<Vec<f64>>>>> =
            (0..p).map(|_| (0..p).map(|_| None).collect()).collect();
        let mut receivers: Vec<Vec<Option<Receiver<Vec<f64>>>>> =
            (0..p).map(|_| (0..p).map(|_| None).collect()).collect();
        for src in 0..p {
            for dst in 0..p {
                let (tx, rx) = channel();
                senders[src][dst] = Some(tx);
                receivers[dst][src] = Some(rx);
            }
        }
        let mut out = Vec::with_capacity(p);
        for rank in 0..p {
            let send_to = senders[rank]
                .iter_mut()
                .map(|s| s.take().unwrap())
                .collect();
            let recv_from = receivers[rank]
                .iter_mut()
                .map(|r| r.take().unwrap())
                .collect();
            out.push(ThreadComm {
                rank,
                size: p,
                send_to,
                recv_from,
                meter: CostMeter::default(),
            });
        }
        out
    }

    fn send(&mut self, dst: usize, buf: Vec<f64>) -> Result<()> {
        self.meter.record_send(buf.len());
        self.send_to[dst]
            .send(buf)
            .map_err(|e| Error::Comm(format!("send {}→{dst}: {e}", self.rank)))
    }

    fn recv(&mut self, src: usize) -> Result<Vec<f64>> {
        let buf = self.recv_from[src]
            .recv()
            .map_err(|e| Error::Comm(format!("recv {}←{src}: {e}", self.rank)))?;
        self.meter.record_recv(buf.len());
        Ok(buf)
    }
}

impl Communicator for ThreadComm {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    /// Binomial-tree reduce to rank 0, then binomial-tree broadcast —
    /// 2·⌈log₂P⌉ rounds, O(log P) messages per rank on the critical path,
    /// exactly the collective the paper's Theorems charge for.
    fn allreduce_sum(&mut self, buf: &mut [f64]) -> Result<()> {
        self.meter.allreduces += 1;
        let p = self.size;
        if p == 1 {
            return Ok(());
        }
        // --- reduce to 0 (MPICH binomial) ---
        let mut mask = 1usize;
        while mask < p {
            if self.rank & mask != 0 {
                let dst = self.rank & !mask;
                self.send(dst, buf.to_vec())?;
                break;
            } else {
                let src = self.rank | mask;
                if src < p {
                    let got = self.recv(src)?;
                    if got.len() != buf.len() {
                        return Err(Error::Comm("allreduce length mismatch".into()));
                    }
                    for (b, g) in buf.iter_mut().zip(&got) {
                        *b += g;
                    }
                }
            }
            mask <<= 1;
        }
        // --- broadcast from 0 ---
        self.broadcast_inner(0, buf)
    }

    fn broadcast(&mut self, root: usize, buf: &mut [f64]) -> Result<()> {
        self.broadcast_inner(root, buf)
    }

    /// Direct personalized exchange: P−1 sends + P−1 receives per rank
    /// (the "large message" regime of Theorems 4/8: L = O(P)).
    fn all_to_all(&mut self, send: Vec<Vec<f64>>) -> Result<Vec<Vec<f64>>> {
        self.meter.all_to_alls += 1;
        let p = self.size;
        if send.len() != p {
            return Err(Error::Comm(format!(
                "all_to_all: {} buffers for {p} ranks",
                send.len()
            )));
        }
        let mut out: Vec<Vec<f64>> = (0..p).map(|_| Vec::new()).collect();
        for (dst, bufv) in send.into_iter().enumerate() {
            if dst == self.rank {
                out[dst] = bufv;
            } else {
                self.send(dst, bufv)?;
            }
        }
        for src in 0..p {
            if src != self.rank {
                out[src] = self.recv(src)?;
            }
        }
        Ok(out)
    }

    fn barrier(&mut self) -> Result<()> {
        // Zero-payload allreduce (counts a message round, no words).
        let mut token = [0.0f64; 0];
        // Reuse tree structure with an empty buffer.
        let p = self.size;
        if p == 1 {
            return Ok(());
        }
        let mut mask = 1usize;
        while mask < p {
            if self.rank & mask != 0 {
                let dst = self.rank & !mask;
                self.send(dst, Vec::new())?;
                break;
            } else {
                let src = self.rank | mask;
                if src < p {
                    self.recv(src)?;
                }
            }
            mask <<= 1;
        }
        self.broadcast_inner(0, &mut token)
    }

    fn meter(&self) -> &CostMeter {
        &self.meter
    }

    fn meter_mut(&mut self) -> &mut CostMeter {
        &mut self.meter
    }
}

impl ThreadComm {
    fn broadcast_inner(&mut self, root: usize, buf: &mut [f64]) -> Result<()> {
        let p = self.size;
        if p == 1 {
            return Ok(());
        }
        let rel = (self.rank + p - root) % p;
        // Receive phase.
        let mut mask = 1usize;
        while mask < p {
            if rel & mask != 0 {
                let src = (self.rank + p - mask) % p;
                let got = self.recv(src)?;
                if got.len() != buf.len() {
                    return Err(Error::Comm("broadcast length mismatch".into()));
                }
                buf.copy_from_slice(&got);
                break;
            }
            mask <<= 1;
        }
        // Send phase (from the highest mask below our receive level down).
        mask >>= 1;
        while mask > 0 {
            if rel + mask < p {
                let dst = (self.rank + mask) % p;
                self.send(dst, buf.to_vec())?;
            }
            mask >>= 1;
        }
        Ok(())
    }
}

/// Run `f(rank, comm)` on P threads and collect per-rank results in rank
/// order. Panics in any rank propagate.
pub fn run_spmd<T, F>(p: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, &mut ThreadComm) -> T + Sync,
{
    let comms = ThreadComm::group(p);
    let mut out: Vec<Option<T>> = (0..p).map(|_| None).collect();
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(p);
        for (rank, mut comm) in comms.into_iter().enumerate() {
            let fref = &f;
            handles.push(scope.spawn(move || (rank, fref(rank, &mut comm), comm.meter)));
        }
        for h in handles {
            let (rank, val, _meter) = h.join().expect("SPMD rank panicked");
            out[rank] = Some(val);
        }
    });
    out.into_iter().map(|v| v.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allreduce_sums_across_ranks() {
        for p in [1usize, 2, 3, 4, 5, 8] {
            let results = run_spmd(p, |rank, comm| {
                let mut buf = vec![rank as f64, 1.0];
                comm.allreduce_sum(&mut buf).unwrap();
                buf
            });
            let expect = vec![(0..p).sum::<usize>() as f64, p as f64];
            for r in results {
                assert_eq!(r, expect, "p={p}");
            }
        }
    }

    #[test]
    fn broadcast_from_each_root() {
        for p in [2usize, 3, 7] {
            for root in 0..p {
                let results = run_spmd(p, |rank, comm| {
                    let mut buf = if rank == root {
                        vec![42.0, root as f64]
                    } else {
                        vec![0.0, 0.0]
                    };
                    comm.broadcast(root, &mut buf).unwrap();
                    buf
                });
                for r in results {
                    assert_eq!(r, vec![42.0, root as f64], "p={p} root={root}");
                }
            }
        }
    }

    #[test]
    fn all_to_all_permutes_payloads() {
        let p = 4;
        let results = run_spmd(p, |rank, comm| {
            let send: Vec<Vec<f64>> = (0..p)
                .map(|dst| vec![(rank * 10 + dst) as f64])
                .collect();
            comm.all_to_all(send).unwrap()
        });
        for (rank, got) in results.iter().enumerate() {
            for (src, v) in got.iter().enumerate() {
                assert_eq!(v, &vec![(src * 10 + rank) as f64]);
            }
        }
    }

    #[test]
    fn allreduce_message_count_is_logarithmic() {
        for p in [2usize, 4, 8, 16] {
            let meters = run_spmd(p, |_rank, comm| {
                let mut buf = vec![1.0; 16];
                comm.allreduce_sum(&mut buf).unwrap();
                *comm.meter()
            });
            let (msgs, _) = CostMeter::critical_path(&meters);
            let logp = (p as f64).log2().ceil() as u64;
            assert!(
                msgs <= 2 * logp,
                "p={p}: critical-path msgs {msgs} > 2·log₂P = {}",
                2 * logp
            );
        }
    }

    #[test]
    fn barrier_completes() {
        run_spmd(5, |_rank, comm| {
            for _ in 0..3 {
                comm.barrier().unwrap();
            }
        });
    }
}
