//! Per-rank communication meters.
//!
//! Counts are in the units of the paper's α-β-γ model: `msgs` (latency L),
//! `words` (bandwidth W, in f64 words), plus collective-call counters used
//! by the message-count validation tests (e.g. CA-BCD must show exactly
//! H/s allreduces where BCD shows H).

/// Communication counters for one rank.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CostMeter {
    /// Point-to-point messages sent.
    pub msgs: u64,
    /// f64 words sent.
    pub words: u64,
    /// Messages received (used for critical-path max).
    pub recv_msgs: u64,
    /// Words received.
    pub recv_words: u64,
    /// Number of allreduce collectives entered (blocking and non-blocking).
    pub allreduces: u64,
    /// Number of all-to-all collectives entered.
    pub all_to_alls: u64,
    /// Number of **deferred** collective completions — `iallreduce_wait`
    /// / `iall_to_all_wait` calls — counted separately from the
    /// `*_start` posts above so fixtures can assert the overlapped
    /// schedule actually defers its waits (blocking collectives complete
    /// inside the call and contribute 0 here).
    pub collective_waits: u64,
    /// Heap allocations taken by the message buffer pool (pool misses and
    /// capacity growth). Zero after warmup on a steady-state payload — the
    /// invariant the hot-path micro-bench asserts.
    pub buf_allocs: u64,
    /// Transient-fault retries taken by a fault-injecting decorator
    /// ([`crate::comm::ChaosComm`]) before the delegated collective ran.
    /// Zero on a fault-free run — the invariant the chaos tests subtract
    /// when comparing meters against the fault-free baseline.
    pub retries: u64,
    /// Receive deadlines that expired
    /// ([`crate::comm::Communicator::set_deadline`]).
    /// Each expiry poisons the group, so a nonzero count accompanies an
    /// `Error::Comm` abort rather than a completed run.
    pub timeouts: u64,
}

impl CostMeter {
    /// Count one outbound point-to-point message of `words` payload.
    pub fn record_send(&mut self, words: usize) {
        self.msgs += 1;
        self.words += words as u64;
    }

    /// Count one inbound point-to-point message of `words` payload.
    pub fn record_recv(&mut self, words: usize) {
        self.recv_msgs += 1;
        self.recv_words += words as u64;
    }

    /// Merge (sum) another meter into this one.
    pub fn merge(&mut self, other: &CostMeter) {
        self.msgs += other.msgs;
        self.words += other.words;
        self.recv_msgs += other.recv_msgs;
        self.recv_words += other.recv_words;
        self.allreduces += other.allreduces;
        self.all_to_alls += other.all_to_alls;
        self.collective_waits += other.collective_waits;
        self.buf_allocs += other.buf_allocs;
        self.retries += other.retries;
        self.timeouts += other.timeouts;
    }

    /// Critical-path message/word counts over a group of rank meters:
    /// the max over ranks of (sends + receives), which upper-bounds the
    /// serialization any single rank experiences.
    pub fn critical_path(meters: &[CostMeter]) -> (u64, u64) {
        meters
            .iter()
            .map(|m| (m.msgs + m.recv_msgs, m.words + m.recv_words))
            .fold((0, 0), |(am, aw), (m, w)| (am.max(m), aw.max(w)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_merge() {
        let mut a = CostMeter::default();
        a.record_send(10);
        a.record_send(5);
        a.record_recv(3);
        assert_eq!(a.msgs, 2);
        assert_eq!(a.words, 15);
        let mut b = CostMeter::default();
        b.record_send(1);
        b.merge(&a);
        assert_eq!(b.msgs, 3);
        assert_eq!(b.words, 16);
        assert_eq!(b.recv_words, 3);
    }

    #[test]
    fn critical_path_is_max() {
        let mut a = CostMeter::default();
        a.record_send(100);
        let mut b = CostMeter::default();
        b.record_send(1);
        b.record_send(1);
        b.record_send(1);
        let (m, w) = CostMeter::critical_path(&[a, b]);
        assert_eq!(m, 3);
        assert_eq!(w, 100);
    }
}
