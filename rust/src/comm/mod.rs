//! In-process MPI-like communicator substrate.
//!
//! The paper's machine is a distributed-memory cluster programmed with MPI
//! collectives; its cost analysis (Theorems 1–9) counts messages and words
//! along the critical path of binomial-tree collectives. This module builds
//! that substrate: P ranks as threads, point-to-point channels, and the
//! MPICH-style binomial-tree algorithms for reduce/broadcast — so the
//! message counts that enter the α-β-γ model are *measured*, not assumed.
//!
//! Every send is metered; [`CostMeter::critical_path`] takes the max over
//! ranks, which is what the paper's `O(·)` latency/bandwidth terms bound.

pub mod cost;
pub mod thread;

pub use cost::CostMeter;
pub use thread::{run_spmd, ThreadComm};

use crate::error::Result;

/// Rank-local handle to a P-rank communicator.
///
/// Mirrors the MPI subset the paper's algorithms need: allreduce (the
/// per-iteration Gram/residual sum), broadcast, all-to-all (the 1D-block-row
/// load-balancing conversion of Theorem 4), and barrier.
pub trait Communicator: Send {
    fn rank(&self) -> usize;
    fn size(&self) -> usize;

    /// Element-wise sum of `buf` across all ranks; result replicated.
    fn allreduce_sum(&mut self, buf: &mut [f64]) -> Result<()>;

    /// Broadcast `buf` from `root` to everyone.
    fn broadcast(&mut self, root: usize, buf: &mut [f64]) -> Result<()>;

    /// Personalized all-to-all: `send[p]` goes to rank p; returns the
    /// vector received from each rank.
    fn all_to_all(&mut self, send: Vec<Vec<f64>>) -> Result<Vec<Vec<f64>>>;

    /// Synchronize all ranks.
    fn barrier(&mut self) -> Result<()>;

    /// Communication meter for this rank.
    fn meter(&self) -> &CostMeter;
    fn meter_mut(&mut self) -> &mut CostMeter;
}

/// Single-rank communicator: all collectives are no-ops. Used for P=1 runs
/// (the numerics of every solver are P-independent; see the SPMD
/// equivalence integration test).
#[derive(Debug, Default)]
pub struct SerialComm {
    meter: CostMeter,
}

impl SerialComm {
    pub fn new() -> Self {
        SerialComm::default()
    }
}

impl Communicator for SerialComm {
    fn rank(&self) -> usize {
        0
    }

    fn size(&self) -> usize {
        1
    }

    fn allreduce_sum(&mut self, _buf: &mut [f64]) -> Result<()> {
        self.meter.allreduces += 1;
        Ok(())
    }

    fn broadcast(&mut self, _root: usize, _buf: &mut [f64]) -> Result<()> {
        Ok(())
    }

    fn all_to_all(&mut self, send: Vec<Vec<f64>>) -> Result<Vec<Vec<f64>>> {
        Ok(send)
    }

    fn barrier(&mut self) -> Result<()> {
        Ok(())
    }

    fn meter(&self) -> &CostMeter {
        &self.meter
    }

    fn meter_mut(&mut self) -> &mut CostMeter {
        &mut self.meter
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_comm_identity() {
        let mut c = SerialComm::new();
        let mut buf = vec![1.0, 2.0];
        c.allreduce_sum(&mut buf).unwrap();
        assert_eq!(buf, vec![1.0, 2.0]);
        assert_eq!(c.meter().allreduces, 1);
        let out = c.all_to_all(vec![vec![5.0]]).unwrap();
        assert_eq!(out, vec![vec![5.0]]);
    }
}
