#![warn(missing_docs)]
//! In-process MPI-like communicator substrate.
//!
//! The paper's machine is a distributed-memory cluster programmed with MPI
//! collectives; its cost analysis (Theorems 1–9) counts messages and words
//! along the critical path of those collectives. This module builds that
//! substrate: P ranks as threads, a tagged inbox per rank, and
//! production-grade collective algorithms — so the message counts that
//! enter the α-β-γ model are *measured*, not assumed.
//!
//! # Allreduce algorithms
//!
//! [`ThreadComm::allreduce_sum`] dispatches on payload size, exactly like
//! MPICH's `MPIR_Allreduce`:
//!
//! * **Recursive doubling** for payloads under
//!   [`thread::RABENSEIFNER_MIN_WORDS`] words: ⌈log₂P⌉ exchange rounds,
//!   each rank sending the full payload per round — latency-optimal, the
//!   `O(log P)` message term the paper's Theorems charge per allreduce
//!   (half the rounds of the seed's reduce-then-broadcast).
//! * **Rabenseifner (reduce-scatter + allgather)** for large payloads such
//!   as the per-iteration packed `sb(sb+1)/2 + sb` Gram/residual buffer:
//!   2⌈log₂P⌉ rounds of *halving/doubling* exchanges moving
//!   `≈ 2·len·(P−1)/P` words per rank instead of `len·log₂P` —
//!   bandwidth-optimal for the payloads that dominate CA-BCD/CA-BDCD
//!   traffic (and composing with the packed triangle for ~2× less wire
//!   volume than the full `sb² + sb` matrix).
//!
//! Non-power-of-two rank counts fold the `P − 2^⌊log₂P⌋` excess ranks onto
//! neighbours before the power-of-two core algorithm and unfold after
//! (the standard MPICH pre/post step). Both algorithms produce
//! *rank-identical, deterministic* results: every rank ends with the same
//! bit pattern for every element on every run.
//!
//! # Zero-allocation message path
//!
//! Every point-to-point message is carried by a buffer drawn from the
//! rank-local pool ([`Communicator::take_buf`] / [`Communicator::give_buf`]);
//! receives recycle the transported buffer back into the receiver's pool.
//! After warmup the collective hot path performs **no heap allocation** —
//! [`CostMeter::buf_allocs`] measures pool misses and the hot-path
//! micro-bench asserts it stays flat in steady state.
//!
//! # Non-blocking collectives and operation tags
//!
//! [`Communicator::iallreduce_start`] posts the protocol's first round and
//! returns a [`ReduceHandle`]; [`Communicator::iallreduce_wait`] completes
//! the remaining rounds. Between the two calls, peer messages accumulate in
//! the rank's inbox while the caller computes — the CA solvers use this to
//! hide the Gram reduction behind the next outer iteration's local Gram
//! computation (`SolverOpts::overlap`). The non-blocking path executes the
//! *same* algorithm in the *same* element order as the blocking path, so
//! results are **bitwise identical** (asserted by property test).
//!
//! [`Communicator::iall_to_all_start`] / [`Communicator::iall_to_all_wait`]
//! are the personalized-exchange twin (receive-side length contracts
//! included): the start posts every send, the wait drains the receives —
//! `bcd_row` uses the pair to hide its Lemma-3 load metering behind the
//! in-flight Theorem-4 redistribution.
//!
//! Every collective *operation* carries a **tag** (a per-endpoint sequence
//! number, MPI-communicator-context style): point-to-point messages are
//! matched on `(source, tag)`, so a collective that runs *between* a
//! non-blocking start and its wait — e.g. an allreduce overlapping an
//! in-flight all-to-all — cannot steal the in-flight operation's
//! messages. SPMD determinism makes the tags line up across ranks: every
//! rank starts its collectives in the same order.
//!
//! # Failure semantics
//!
//! A rank that detects a protocol violation (payload length mismatch)
//! *poisons the group*: it broadcasts a poison packet to every peer and
//! errors out. Peers blocked in a receive observe the poison instead of
//! hanging, and every subsequent collective on a poisoned endpoint fails
//! immediately — a length bug surfaces as `Error::Comm("group poisoned: …")`
//! on all ranks rather than a deadlock. This covers both directions:
//! sends (wrong buffer count into `all_to_all`) and receives
//! ([`Communicator::all_to_all_expect`] checks every incoming payload
//! against the caller's expected length).
//!
//! Rank *death* is covered by deadlines: [`Communicator::set_deadline`]
//! bounds every blocking receive, and an expiry counts a
//! [`CostMeter::timeouts`] and poisons the group exactly like a protocol
//! violation — so a crashed or stalled peer surfaces as `Error::Comm` on
//! every surviving rank instead of a hang. The [`chaos`] module provides
//! [`ChaosComm`], a deterministic fault-injecting decorator over any
//! transport, which is how these paths are exercised under test.
//!
//! Every send is metered; [`CostMeter::critical_path`] takes the max over
//! ranks, which is what the paper's `O(·)` latency/bandwidth terms bound.

pub mod chaos;
pub mod cost;
pub mod process;
pub(crate) mod proto;
pub mod thread;

pub use chaos::{ChaosComm, ChaosSpec};
pub use cost::CostMeter;
pub use process::{ProcessComm, Rendezvous};
pub use proto::expected_two_level_allreduce_sends;
pub use thread::{run_spmd, ThreadComm};

use crate::error::Result;

/// Which core allreduce algorithm a collective (or in-flight handle) uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Algo {
    RecursiveDoubling,
    Rabenseifner,
    /// Hierarchical two-level composition (see [`proto`] module docs):
    /// intra-node fan-in to node leaders, flat core algorithm across the
    /// leader group, fan-out back to members.
    TwoLevel { node_size: usize },
}

/// Collective topology of a communicator ([`Communicator::set_topology`]).
///
/// `Flat` runs every allreduce over all P ranks directly (recursive
/// doubling / Rabenseifner, selected on payload size). `TwoLevel` models a
/// cluster of nodes with `node_size` ranks each: allreduce fans in to node
/// leaders, runs the flat algorithm across the `⌈P/node_size⌉` leaders,
/// and fans back out — trading `O(log P)` uniform hops for cheap intra-node
/// hops plus `O(log(P/node_size))` inter-node hops (the paper's α-β model
/// prices these links differently on the Cray XC30). Broadcast, barrier,
/// and all-to-all are topology-independent (barrier traffic is
/// zero-payload and all-to-all is inherently personalized), so only the
/// allreduce family dispatches on this. `node_size` is clamped to
/// `[1, P]`; `node_size = 1` degenerates to `Flat`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Topology {
    /// Single-level collectives over all ranks (the default).
    #[default]
    Flat,
    /// Two-level hierarchy with `node_size` ranks per node.
    TwoLevel {
        /// Ranks per node; rank r belongs to node `r / node_size` and its
        /// leader is the node's lowest rank.
        node_size: usize,
    },
}

/// Protocol state carried by an in-flight [`ReduceHandle`].
#[derive(Clone, Copy, Debug)]
pub(crate) enum HandleState {
    /// Nothing left in flight (serial communicator or P = 1).
    Done,
    /// Thread protocol chosen at start time; `first_sent` records whether
    /// the round-0 send was already posted by `iallreduce_start`, `tag`
    /// is the operation tag all of this collective's messages carry.
    Thread { algo: Algo, first_sent: bool, tag: u64 },
}

/// Handle to an in-flight non-blocking allreduce. Owns the payload buffer
/// until [`Communicator::iallreduce_wait`] returns it, reduced.
///
/// A handle must be waited on by the same communicator that started it.
/// Other collectives may run between start and wait — operation tags keep
/// their message streams apart.
#[derive(Debug)]
pub struct ReduceHandle {
    pub(crate) buf: Vec<f64>,
    pub(crate) state: HandleState,
}

impl ReduceHandle {
    /// Length of the in-flight payload.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when the in-flight payload has zero length.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

/// Protocol state carried by an in-flight [`AllToAllHandle`].
#[derive(Debug)]
pub(crate) enum A2aState {
    /// Exchange already complete (serial communicator, P = 1, or a
    /// default-implementation eager exchange).
    Ready(Vec<Vec<f64>>),
    /// Thread protocol: sends posted under `tag`; the wait drains one
    /// payload per peer against the `recv_lens` length contracts.
    Thread {
        tag: u64,
        recv_lens: Vec<usize>,
        out: Vec<Vec<f64>>,
    },
}

/// Handle to an in-flight non-blocking personalized all-to-all
/// ([`Communicator::iall_to_all_start`]). Sends are posted at start; the
/// received payloads are collected by [`Communicator::iall_to_all_wait`].
#[derive(Debug)]
pub struct AllToAllHandle {
    pub(crate) state: A2aState,
}

/// Rank-local handle to a P-rank communicator.
///
/// Mirrors the MPI subset the paper's algorithms need: allreduce (the
/// per-iteration Gram/residual sum, blocking and non-blocking), broadcast,
/// all-to-all (the 1D-block-row load-balancing conversion of Theorem 4),
/// and barrier.
pub trait Communicator: Send {
    fn rank(&self) -> usize;
    fn size(&self) -> usize;

    /// Element-wise sum of `buf` across all ranks; result replicated.
    fn allreduce_sum(&mut self, buf: &mut [f64]) -> Result<()>;

    /// Begin a non-blocking allreduce of `buf`. The returned handle owns
    /// the buffer; local computation may proceed while peer traffic lands.
    fn iallreduce_start(&mut self, buf: Vec<f64>) -> Result<ReduceHandle>;

    /// Complete a non-blocking allreduce and return the reduced buffer.
    /// Bitwise identical to [`Communicator::allreduce_sum`] on the same
    /// payload.
    fn iallreduce_wait(&mut self, handle: ReduceHandle) -> Result<Vec<f64>>;

    /// Broadcast `buf` from `root` to everyone.
    fn broadcast(&mut self, root: usize, buf: &mut [f64]) -> Result<()>;

    /// Personalized all-to-all: `send[p]` goes to rank p; returns the
    /// vector received from each rank.
    fn all_to_all(&mut self, send: Vec<Vec<f64>>) -> Result<Vec<Vec<f64>>>;

    /// Personalized all-to-all with a **receive-side length contract**:
    /// `recv_lens[q]` is the exact word count this rank expects from rank
    /// q. On the thread communicator a mismatch poisons the group — every
    /// rank errors instead of the receivers hanging or desynchronizing on
    /// mis-sized payloads (receive-side twin of the send-side poison in
    /// [`Communicator::all_to_all`]). The default implementation
    /// validates after the exchange, which is sufficient for
    /// single-process communicators.
    fn all_to_all_expect(
        &mut self,
        send: Vec<Vec<f64>>,
        recv_lens: &[usize],
    ) -> Result<Vec<Vec<f64>>> {
        if recv_lens.len() != self.size() {
            return Err(crate::error::Error::Comm(format!(
                "all_to_all_expect: rank {} supplied {} receive lengths for {} ranks",
                self.rank(),
                recv_lens.len(),
                self.size()
            )));
        }
        let out = self.all_to_all(send)?;
        for (src, got) in out.iter().enumerate() {
            if got.len() != recv_lens[src] {
                return Err(crate::error::Error::Comm(format!(
                    "all_to_all_expect: rank {} expected {} words from rank {src}, got {}",
                    self.rank(),
                    recv_lens[src],
                    got.len()
                )));
            }
        }
        Ok(out)
    }

    /// Begin a non-blocking personalized all-to-all with receive-side
    /// length contracts (the non-blocking twin of
    /// [`Communicator::all_to_all_expect`]): every send is posted before
    /// returning, so independent local work — or other tagged collectives
    /// — can run before [`Communicator::iall_to_all_wait`] drains the
    /// receives. Bitwise identical to the blocking path (same payloads,
    /// same per-source ordering). The default implementation exchanges
    /// eagerly, which is correct for single-process communicators.
    fn iall_to_all_start(
        &mut self,
        send: Vec<Vec<f64>>,
        recv_lens: &[usize],
    ) -> Result<AllToAllHandle> {
        let out = self.all_to_all_expect(send, recv_lens)?;
        Ok(AllToAllHandle {
            state: A2aState::Ready(out),
        })
    }

    /// Complete a non-blocking all-to-all and return the per-source
    /// payloads (`out[q]` = the vector received from rank q).
    ///
    /// The default implementation pairs with the eager default
    /// [`Communicator::iall_to_all_start`]: the exchange already
    /// completed inside the start, so there is no deferred completion to
    /// count — it contributes neither `CostMeter::collective_waits` nor
    /// a trace span (matching `SerialComm`, which meters no all-to-alls).
    fn iall_to_all_wait(&mut self, handle: AllToAllHandle) -> Result<Vec<Vec<f64>>> {
        match handle.state {
            A2aState::Ready(out) => Ok(out),
            A2aState::Thread { .. } => Err(crate::error::Error::Comm(
                "iall_to_all_wait: thread-protocol handle waited on a \
                 communicator without a thread protocol"
                    .into(),
            )),
        }
    }

    /// Synchronize all ranks.
    fn barrier(&mut self) -> Result<()>;

    /// Set (or clear) the per-receive deadline for this endpoint's blocking
    /// receive paths — blocking collectives and the `i*_wait` completions.
    /// When a peer's message fails to arrive within the deadline, the
    /// endpoint counts a [`CostMeter::timeouts`], **poisons the group**
    /// (PR-2 propagation: every rank observes `Error::Comm` instead of
    /// hanging), and errors out. `None` restores the default unbounded
    /// wait. Single-process communicators with no inter-rank blocking
    /// (e.g. [`SerialComm`]) ignore the deadline — the default is a no-op.
    fn set_deadline(&mut self, _deadline: Option<std::time::Duration>) {}

    /// Select the collective [`Topology`] for subsequent allreduces.
    /// Communicators without a multi-rank wire (e.g. [`SerialComm`]) have
    /// nothing to restructure — the default is a no-op. Decorators
    /// ([`ChaosComm`]) forward to the inner transport.
    fn set_topology(&mut self, _topology: Topology) {}

    /// Borrow a zeroed length-`len` buffer from the rank-local pool
    /// (allocates only on pool miss).
    fn take_buf(&mut self, len: usize) -> Vec<f64> {
        vec![0.0; len]
    }

    /// Return a buffer to the rank-local pool for reuse.
    fn give_buf(&mut self, _buf: Vec<f64>) {}

    /// Communication meter for this rank.
    fn meter(&self) -> &CostMeter;
    fn meter_mut(&mut self) -> &mut CostMeter;
}

/// Gather one variable-length blob per rank to rank 0, implemented as a
/// personalized all-to-all in which every non-root destination receives an
/// empty payload. Returns `Some(blobs)` (indexed by source rank, rank 0's
/// own blob included) on rank 0 and `None` elsewhere.
///
/// This is the cross-process reporting primitive: after a solve, the
/// driver ships per-rank meters, trace rings, and telemetry registries to
/// the parent over the same communicator the solve used (observability is
/// uninstalled first, so the gather itself contributes no spans or
/// telemetry). Payload words are moved bit-exactly by every transport, so
/// non-numeric data packed via `f64::from_bits` survives round trips —
/// the trace and telemetry word codecs rely on this.
pub fn gather_to_root<C: Communicator + ?Sized>(
    c: &mut C,
    blob: Vec<f64>,
) -> Result<Option<Vec<Vec<f64>>>> {
    let p = c.size();
    if p == 1 {
        return Ok(Some(vec![blob]));
    }
    let mut send: Vec<Vec<f64>> = (0..p).map(|_| Vec::new()).collect();
    send[0] = blob;
    let out = c.all_to_all(send)?;
    Ok(if c.rank() == 0 { Some(out) } else { None })
}

/// Single-rank communicator: all collectives are no-ops. Used for P=1 runs
/// (the numerics of every solver are P-independent; see the SPMD
/// equivalence integration test).
#[derive(Debug, Default)]
pub struct SerialComm {
    meter: CostMeter,
}

impl SerialComm {
    /// A fresh single-rank communicator with zeroed meters.
    pub fn new() -> Self {
        SerialComm::default()
    }
}

impl Communicator for SerialComm {
    fn rank(&self) -> usize {
        0
    }

    fn size(&self) -> usize {
        1
    }

    fn allreduce_sum(&mut self, buf: &mut [f64]) -> Result<()> {
        self.meter.allreduces += 1;
        // Blocking collective: instantaneous start marker + wait marker,
        // so span counts match the meters under either schedule.
        let words = buf.len() as u64;
        let u0 = crate::telemetry::now();
        crate::trace::mark(
            crate::trace::SpanKind::CollectiveStart,
            crate::trace::OpClass::Allreduce,
            0,
            words,
        );
        crate::trace::mark(
            crate::trace::SpanKind::CollectiveWait,
            crate::trace::OpClass::Allreduce,
            0,
            words,
        );
        crate::telemetry::count(crate::telemetry::Counter::Collectives, 1);
        crate::telemetry::gauge(crate::telemetry::Gauge::PayloadWords, words);
        crate::telemetry::observe(crate::telemetry::Hist::AllreduceWords, words);
        crate::telemetry::observe_since(crate::telemetry::Hist::AllreduceNs, u0);
        Ok(())
    }

    fn iallreduce_start(&mut self, buf: Vec<f64>) -> Result<ReduceHandle> {
        self.meter.allreduces += 1;
        let words = buf.len() as u64;
        crate::trace::mark(
            crate::trace::SpanKind::CollectiveStart,
            crate::trace::OpClass::Allreduce,
            0,
            words,
        );
        crate::telemetry::count(crate::telemetry::Counter::Collectives, 1);
        crate::telemetry::gauge(crate::telemetry::Gauge::PayloadWords, words);
        crate::telemetry::observe(crate::telemetry::Hist::AllreduceWords, words);
        Ok(ReduceHandle {
            buf,
            state: HandleState::Done,
        })
    }

    fn iallreduce_wait(&mut self, handle: ReduceHandle) -> Result<Vec<f64>> {
        self.meter.collective_waits += 1;
        let u0 = crate::telemetry::now();
        crate::trace::mark(
            crate::trace::SpanKind::CollectiveWait,
            crate::trace::OpClass::Allreduce,
            0,
            handle.buf.len() as u64,
        );
        crate::telemetry::observe_since(crate::telemetry::Hist::WaitNs, u0);
        Ok(handle.buf)
    }

    fn broadcast(&mut self, _root: usize, _buf: &mut [f64]) -> Result<()> {
        Ok(())
    }

    fn all_to_all(&mut self, send: Vec<Vec<f64>>) -> Result<Vec<Vec<f64>>> {
        Ok(send)
    }

    fn barrier(&mut self) -> Result<()> {
        Ok(())
    }

    fn meter(&self) -> &CostMeter {
        &self.meter
    }

    fn meter_mut(&mut self) -> &mut CostMeter {
        &mut self.meter
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_comm_identity() {
        let mut c = SerialComm::new();
        let mut buf = vec![1.0, 2.0];
        c.allreduce_sum(&mut buf).unwrap();
        assert_eq!(buf, vec![1.0, 2.0]);
        assert_eq!(c.meter().allreduces, 1);
        let out = c.all_to_all(vec![vec![5.0]]).unwrap();
        assert_eq!(out, vec![vec![5.0]]);
    }

    #[test]
    fn serial_nonblocking_all_to_all_roundtrips() {
        let mut c = SerialComm::new();
        let h = c
            .iall_to_all_start(vec![vec![1.0, 2.0]], &[2usize])
            .unwrap();
        let out = c.iall_to_all_wait(h).unwrap();
        assert_eq!(out, vec![vec![1.0, 2.0]]);
        // Length-contract violation surfaces through the default impl too.
        assert!(c.iall_to_all_start(vec![vec![1.0]], &[3usize]).is_err());
    }

    #[test]
    fn serial_nonblocking_roundtrips_and_counts() {
        let mut c = SerialComm::new();
        let h = c.iallreduce_start(vec![3.0, 4.0]).unwrap();
        assert_eq!(h.len(), 2);
        let out = c.iallreduce_wait(h).unwrap();
        assert_eq!(out, vec![3.0, 4.0]);
        assert_eq!(c.meter().allreduces, 1);
    }
}
