//! Deterministic fault injection over any [`Communicator`].
//!
//! [`ChaosComm`] decorates a transport and injects seeded faults at
//! collective entry, giving every invariant the symbolic verifier
//! ([`crate::analysis`]) checks statically a dynamic twin:
//!
//! * **Latency spikes** — a per-collective coin flip adds a fixed sleep
//!   before the collective runs. Payload bytes are untouched, so a run
//!   that completes is bitwise-equal to the fault-free run (the chaos
//!   test matrix asserts exactly this).
//! * **Transient delivery failures** — a per-collective coin flip makes
//!   the attempt "fail" before anything is sent; the decorator retries
//!   with bounded exponential backoff, metering each retry in
//!   [`CostMeter::retries`](crate::comm::CostMeter::retries) and tracing
//!   it as a [`SpanKind::Retry`] span. The delegated collective still
//!   runs **exactly once**, so wire traffic is identical to fault-free.
//!   Exhausting `max_retries` surfaces as `Error::Comm`.
//! * **Rank stalls** — at a chosen collective index the victim rank
//!   sleeps past its peers' deadline
//!   ([`Communicator::set_deadline`]), driving the timeout → poison →
//!   `Error::Comm`-everywhere path.
//! * **Hard rank death** — at a chosen collective index the victim rank
//!   errors out *without communicating*, mid-protocol from its peers'
//!   point of view. Peers discover the death through their receive
//!   deadlines; recovery is a checkpoint resume
//!   ([`crate::engine::Session::resume`]).
//!
//! All randomness comes from a [`Rng64`] seeded with `seed ^ rank`, so a
//! fault schedule is a pure function of ([`ChaosSpec`], rank, collective
//! index) — reproducible across runs, machines, and schedules.

use std::time::Duration;

use crate::comm::{AllToAllHandle, Communicator, CostMeter, ReduceHandle, Topology};
use crate::error::{Error, Result};
use crate::trace::{self, OpClass, SpanKind};
use crate::util::Rng64;

/// Seeded fault plan for one [`ChaosComm`] endpoint. The default spec
/// injects nothing — `ChaosComm` with a default spec behaves exactly
/// like its inner transport (plus one RNG construction).
#[derive(Clone, Copy, Debug)]
pub struct ChaosSpec {
    /// Seed for the per-rank fault stream (the endpoint draws from
    /// `Rng64::seed_from_u64(seed ^ rank)`).
    pub seed: u64,
    /// Probability (0..=1) that a collective entry takes a latency spike.
    pub latency_prob: f64,
    /// Sleep injected by a latency spike, in milliseconds.
    pub latency_ms: u64,
    /// Probability (0..=1) that a collective attempt transiently fails
    /// before sending (each retry re-flips the coin).
    pub transient_prob: f64,
    /// Retry budget per collective; exceeding it is `Error::Comm`.
    pub max_retries: u32,
    /// First backoff sleep in milliseconds; doubles per retry.
    pub backoff_base_ms: u64,
    /// Collective index at which the victim rank stalls.
    pub stall_at: Option<u64>,
    /// Stall duration in milliseconds (set it above the group deadline).
    pub stall_ms: u64,
    /// Collective index at which the victim rank dies (errors out
    /// without communicating).
    pub die_at: Option<u64>,
    /// Rank subject to `stall_at` / `die_at`.
    pub victim: usize,
}

impl Default for ChaosSpec {
    fn default() -> Self {
        ChaosSpec {
            seed: 0,
            latency_prob: 0.0,
            latency_ms: 1,
            transient_prob: 0.0,
            max_retries: 4,
            backoff_base_ms: 1,
            stall_at: None,
            stall_ms: 100,
            die_at: None,
            victim: 0,
        }
    }
}

/// Fault-injecting decorator over any transport. See the module docs
/// for the fault taxonomy and determinism contract.
pub struct ChaosComm<C: Communicator> {
    inner: C,
    spec: ChaosSpec,
    rng: Rng64,
    /// Monotone count of collective entries on this endpoint — the
    /// cross-run-stable index `stall_at` / `die_at` select on (SPMD
    /// determinism makes index k the same operation on every rank).
    op_idx: u64,
}

impl<C: Communicator> ChaosComm<C> {
    /// Wrap `inner` under the fault plan `spec`.
    pub fn new(inner: C, spec: ChaosSpec) -> Self {
        let rank = inner.rank() as u64;
        ChaosComm {
            inner,
            spec,
            rng: Rng64::seed_from_u64(spec.seed ^ rank),
            op_idx: 0,
        }
    }

    /// Unwrap the inner transport.
    pub fn into_inner(self) -> C {
        self.inner
    }

    /// Collective entries seen so far (the next entry's index).
    pub fn op_idx(&self) -> u64 {
        self.op_idx
    }

    /// Run the fault plan for one collective entry: targeted death /
    /// stall first (deterministic, index-based), then the seeded latency
    /// and transient-failure coins. Returns `Ok` once the delegated
    /// collective may run (exactly once).
    fn inject(&mut self, what: &str, words: u64) -> Result<()> {
        let idx = self.op_idx;
        self.op_idx += 1;
        let rank = self.inner.rank();
        let targeted = rank == self.spec.victim;
        if targeted && self.spec.die_at == Some(idx) {
            // Hard death: no poison, no farewell — peers must discover
            // this through their receive deadlines.
            return Err(Error::Comm(format!(
                "chaos: rank {rank} died at collective {idx} ({what})"
            )));
        }
        if targeted && self.spec.stall_at == Some(idx) {
            std::thread::sleep(Duration::from_millis(self.spec.stall_ms));
        }
        if self.spec.latency_prob > 0.0 && self.rng.gen_f64() < self.spec.latency_prob {
            std::thread::sleep(Duration::from_millis(self.spec.latency_ms));
        }
        if self.spec.transient_prob > 0.0 {
            let mut attempt = 0u32;
            while self.rng.gen_f64() < self.spec.transient_prob {
                attempt += 1;
                if attempt > self.spec.max_retries {
                    return Err(Error::Comm(format!(
                        "chaos: rank {rank} collective {idx} ({what}) failed \
                         {attempt} transient attempts (budget {})",
                        self.spec.max_retries
                    )));
                }
                self.inner.meter_mut().retries += 1;
                crate::telemetry::count(crate::telemetry::Counter::Retries, 1);
                let t0 = trace::now();
                std::thread::sleep(Duration::from_millis(
                    self.spec.backoff_base_ms << (attempt - 1).min(16),
                ));
                trace::record(SpanKind::Retry, OpClass::Compute, idx, words, t0);
            }
        }
        Ok(())
    }
}

impl<C: Communicator> Communicator for ChaosComm<C> {
    fn rank(&self) -> usize {
        self.inner.rank()
    }

    fn size(&self) -> usize {
        self.inner.size()
    }

    fn allreduce_sum(&mut self, buf: &mut [f64]) -> Result<()> {
        self.inject("allreduce", buf.len() as u64)?;
        self.inner.allreduce_sum(buf)
    }

    fn iallreduce_start(&mut self, buf: Vec<f64>) -> Result<ReduceHandle> {
        self.inject("iallreduce_start", buf.len() as u64)?;
        self.inner.iallreduce_start(buf)
    }

    fn iallreduce_wait(&mut self, handle: ReduceHandle) -> Result<Vec<f64>> {
        // Completions are not separate entries: the fault plan indexed
        // the start, and a wait never initiates traffic of its own.
        self.inner.iallreduce_wait(handle)
    }

    fn broadcast(&mut self, root: usize, buf: &mut [f64]) -> Result<()> {
        self.inject("broadcast", buf.len() as u64)?;
        self.inner.broadcast(root, buf)
    }

    fn all_to_all(&mut self, send: Vec<Vec<f64>>) -> Result<Vec<Vec<f64>>> {
        let words: u64 = send.iter().map(|v| v.len() as u64).sum();
        self.inject("all_to_all", words)?;
        self.inner.all_to_all(send)
    }

    fn all_to_all_expect(
        &mut self,
        send: Vec<Vec<f64>>,
        recv_lens: &[usize],
    ) -> Result<Vec<Vec<f64>>> {
        let words: u64 = send.iter().map(|v| v.len() as u64).sum();
        self.inject("all_to_all_expect", words)?;
        self.inner.all_to_all_expect(send, recv_lens)
    }

    fn iall_to_all_start(
        &mut self,
        send: Vec<Vec<f64>>,
        recv_lens: &[usize],
    ) -> Result<AllToAllHandle> {
        let words: u64 = send.iter().map(|v| v.len() as u64).sum();
        self.inject("iall_to_all_start", words)?;
        self.inner.iall_to_all_start(send, recv_lens)
    }

    fn iall_to_all_wait(&mut self, handle: AllToAllHandle) -> Result<Vec<Vec<f64>>> {
        self.inner.iall_to_all_wait(handle)
    }

    fn barrier(&mut self) -> Result<()> {
        self.inject("barrier", 0)?;
        self.inner.barrier()
    }

    fn set_deadline(&mut self, deadline: Option<Duration>) {
        self.inner.set_deadline(deadline)
    }

    fn set_topology(&mut self, topology: Topology) {
        self.inner.set_topology(topology)
    }

    fn take_buf(&mut self, len: usize) -> Vec<f64> {
        self.inner.take_buf(len)
    }

    fn give_buf(&mut self, buf: Vec<f64>) {
        self.inner.give_buf(buf)
    }

    fn meter(&self) -> &CostMeter {
        self.inner.meter()
    }

    fn meter_mut(&mut self) -> &mut CostMeter {
        self.inner.meter_mut()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{run_spmd, SerialComm};

    #[test]
    fn default_spec_is_transparent() {
        let mut c = ChaosComm::new(SerialComm::new(), ChaosSpec::default());
        let mut buf = vec![1.0, 2.0, 3.0];
        c.allreduce_sum(&mut buf).unwrap();
        assert_eq!(buf, vec![1.0, 2.0, 3.0]);
        assert_eq!(c.meter().retries, 0);
        assert_eq!(c.meter().allreduces, 1);
        assert_eq!(c.op_idx(), 1);
    }

    #[test]
    fn transient_faults_retry_and_meter_without_changing_results() {
        let spec = ChaosSpec {
            seed: 7,
            transient_prob: 0.5,
            max_retries: 64,
            backoff_base_ms: 0,
            ..ChaosSpec::default()
        };
        let results = run_spmd(4, move |rank, comm| {
            // Move each rank's endpoint into a chaos wrapper.
            let inner = std::mem::replace(comm, ThreadCommStub::stub());
            let mut chaos = ChaosComm::new(inner, spec);
            let mut buf = vec![rank as f64; 8];
            for _ in 0..20 {
                chaos.allreduce_sum(&mut buf).unwrap();
            }
            let retries = chaos.meter().retries;
            *comm = chaos.into_inner();
            (buf[0], retries)
        });
        for (v, retries) in &results {
            // 20 allreduces of the rank sum: value is deterministic and
            // equal to the fault-free result regardless of retries.
            assert_eq!(*v, 6.0 * 4f64.powi(19), "faults changed the payload");
            assert!(*retries > 0, "p=0.5 over 20 collectives never retried");
        }
    }

    /// `run_spmd` hands out `&mut ThreadComm`; the chaos wrapper wants
    /// ownership. A one-rank placeholder group swaps in while the real
    /// endpoint is wrapped.
    struct ThreadCommStub;
    impl ThreadCommStub {
        fn stub() -> crate::comm::ThreadComm {
            let mut g = crate::comm::ThreadComm::group(1);
            let Some(c) = g.pop() else {
                unreachable!("group(1) returns one endpoint")
            };
            c
        }
    }

    #[test]
    fn retry_budget_exhaustion_is_an_error() {
        let spec = ChaosSpec {
            seed: 1,
            transient_prob: 1.0, // every attempt fails
            max_retries: 3,
            backoff_base_ms: 0,
            ..ChaosSpec::default()
        };
        let mut c = ChaosComm::new(SerialComm::new(), spec);
        let err = c.allreduce_sum(&mut [1.0]).unwrap_err();
        let msg = format!("{err:?}");
        assert!(msg.contains("transient attempts"), "{msg}");
        assert_eq!(c.meter().retries, 3, "budget must be fully consumed");
    }

    #[test]
    fn death_is_targeted_and_indexed() {
        let spec = ChaosSpec {
            die_at: Some(2),
            victim: 0,
            ..ChaosSpec::default()
        };
        let mut c = ChaosComm::new(SerialComm::new(), spec);
        c.allreduce_sum(&mut [1.0]).unwrap(); // idx 0
        c.barrier().unwrap(); // idx 1
        let err = c.allreduce_sum(&mut [1.0]).unwrap_err(); // idx 2
        assert!(format!("{err:?}").contains("died at collective 2"));
    }

    #[test]
    fn same_seed_same_fault_schedule() {
        let spec = ChaosSpec {
            seed: 42,
            transient_prob: 0.3,
            max_retries: 32,
            backoff_base_ms: 0,
            ..ChaosSpec::default()
        };
        let run = || {
            let mut c = ChaosComm::new(SerialComm::new(), spec);
            for _ in 0..50 {
                c.allreduce_sum(&mut [0.0]).unwrap();
            }
            c.meter().retries
        };
        assert_eq!(run(), run(), "fault schedule must be seed-deterministic");
    }
}
