//! Transport-independent collective protocol engine.
//!
//! The MPICH-style collective algorithms (recursive-doubling and
//! Rabenseifner allreduce, binomial-tree broadcast, and the hierarchical
//! two-level composition) are pure message-schedule logic: they only need
//! a way to send a tagged payload to a peer and to receive one under a
//! length contract. This module captures that seam as the crate-private
//! [`Wire`] trait and implements every algorithm once, generically — the
//! in-process [`crate::comm::ThreadComm`] and the multi-process
//! [`crate::comm::process::ProcessComm`] both delegate here, which is what
//! makes the two transports *bitwise identical*: same element order, same
//! exchange schedule, same arithmetic, different bytes-on-the-wire only.
//!
//! # Groups
//!
//! Algorithms run over a [`Group`]: a strided view of world ranks
//! (`world = index · stride`). The flat group (`stride = 1`) is the whole
//! communicator; the two-level collective reuses the *same* recursive
//! doubling / Rabenseifner code over the leader group (`stride =
//! node_size`) without any algorithm changes.
//!
//! # Hierarchical two-level allreduce
//!
//! With `topology = twolevel` and node size `m`, ranks are grouped into
//! nodes `[0..m)`, `[m..2m)`, …; the lowest rank of each node is its
//! *leader*. One allreduce then runs in three phases:
//!
//! 1. **Fan-in**: each member sends its full payload to its node leader
//!    (1 message, `len` words per member); the leader accumulates.
//! 2. **Leader exchange**: the `L = ⌈P/m⌉` leaders run the flat
//!    dispatch (recursive doubling or Rabenseifner, selected on `L` and
//!    `len`) over the strided leader group.
//! 3. **Fan-out**: each leader sends the reduced result back to its
//!    members (`m − 1` messages, `(m − 1)·len` words per full node).
//!
//! On a real cluster phase 1/3 traffic stays on-node (cheap links) and
//! only phase 2 crosses the network — the classic SMP-aware allreduce
//! (MPICH `MPIR_Allreduce_intra_smp`). The closed-form per-rank send
//! counts live in [`expected_two_level_allreduce_sends`] and are mirrored
//! by `costmodel::theory::two_level_allreduce_cost`; the hot-path bench
//! gates measured == formula.

use crate::comm::Algo;
use crate::comm::thread::RABENSEIFNER_MIN_WORDS;
use crate::error::Result;

/// Crate-private point-to-point seam the collective algorithms run over.
///
/// Implementations provide metered, operation-tagged sends and
/// length-contracted blocking receives (a mismatch poisons the group), plus
/// buffer recycling into the rank-local pool — everything else (algorithm
/// schedule, chunking, fold/unfold) lives here, shared by all transports.
pub(crate) trait Wire {
    /// This endpoint's world rank.
    fn wire_rank(&self) -> usize;
    /// Number of ranks in the communicator.
    fn wire_size(&self) -> usize;
    /// Metered send of a copied slice to world rank `dst` under the
    /// current operation tag.
    fn wire_send(&mut self, dst: usize, data: &[f64]) -> Result<()>;
    /// Blocking receive from world rank `src` under the current operation
    /// tag with a length contract; a mismatch poisons the group.
    fn wire_recv(&mut self, src: usize, len: usize) -> Result<Vec<f64>>;
    /// Return a received buffer to the rank-local pool.
    fn wire_recycle(&mut self, buf: Vec<f64>);
}

/// A strided sub-group of world ranks: member `i` (0-based `index` for the
/// caller) is world rank `i · stride`. The flat group is `stride = 1`.
#[derive(Clone, Copy, Debug)]
pub(crate) struct Group {
    /// Number of ranks in the group.
    pub size: usize,
    /// This endpoint's index within the group.
    pub index: usize,
    /// World-rank stride between consecutive group members.
    pub stride: usize,
}

impl Group {
    /// The whole communicator as a group.
    pub fn flat(size: usize, rank: usize) -> Group {
        Group {
            size,
            index: rank,
            stride: 1,
        }
    }

    /// World rank of group member `i`.
    pub fn world(&self, i: usize) -> usize {
        i * self.stride
    }
}

/// Largest power of two ≤ p (p ≥ 1).
pub(crate) fn pof2_below(p: usize) -> usize {
    if p.is_power_of_two() {
        p
    } else {
        p.next_power_of_two() >> 1
    }
}

/// Map a post-fold rank id back to its real group index (MPICH convention:
/// the first `2·rem` ranks collapse pairwise onto the odd member).
pub(crate) fn real_rank(newrank: usize, rem: usize) -> usize {
    if newrank < rem {
        2 * newrank + 1
    } else {
        newrank + rem
    }
}

/// Element-wise accumulate.
pub(crate) fn add_into(acc: &mut [f64], v: &[f64]) {
    for (a, b) in acc.iter_mut().zip(v) {
        *a += b;
    }
}

/// MPICH-style size dispatch for a flat `p`-rank group: Rabenseifner for
/// payloads at or above [`RABENSEIFNER_MIN_WORDS`] (when the chunking is
/// well-defined), recursive doubling otherwise.
pub(crate) fn select_algo(p: usize, len: usize) -> Algo {
    let pof2 = pof2_below(p);
    if len >= RABENSEIFNER_MIN_WORDS && len >= pof2 && pof2 >= 2 {
        Algo::Rabenseifner
    } else {
        Algo::RecursiveDoubling
    }
}

/// One protocol send that may have been posted already by a non-blocking
/// start (the flag is consumed by the first executed send).
fn send_round<W: Wire + ?Sized>(
    w: &mut W,
    dst: usize,
    data: &[f64],
    skip: &mut bool,
) -> Result<()> {
    if *skip {
        *skip = false;
        Ok(())
    } else {
        w.wire_send(dst, data)
    }
}

/// Fold phase shared by both core algorithms: the `2·rem` lowest group
/// members collapse pairwise onto the odd member; returns this member's
/// post-fold id (`None` = folded out until the unfold).
fn fold<W: Wire + ?Sized>(
    w: &mut W,
    g: &Group,
    buf: &mut [f64],
    rem: usize,
    skip: &mut bool,
) -> Result<Option<usize>> {
    let idx = g.index;
    if idx < 2 * rem {
        if idx % 2 == 0 {
            send_round(w, g.world(idx + 1), buf, skip)?;
            Ok(None)
        } else {
            let got = w.wire_recv(g.world(idx - 1), buf.len())?;
            add_into(buf, &got);
            w.wire_recycle(got);
            Ok(Some(idx / 2))
        }
    } else {
        Ok(Some(idx - rem))
    }
}

/// Unfold phase: the reduced result reaches the folded-out even members.
fn unfold<W: Wire + ?Sized>(w: &mut W, g: &Group, buf: &mut [f64], rem: usize) -> Result<()> {
    let idx = g.index;
    if idx < 2 * rem {
        if idx % 2 == 0 {
            let got = w.wire_recv(g.world(idx + 1), buf.len())?;
            buf.copy_from_slice(&got);
            w.wire_recycle(got);
        } else {
            w.wire_send(g.world(idx - 1), buf)?;
        }
    }
    Ok(())
}

/// Recursive doubling over `g`: ⌈log₂|g|⌉ pairwise exchange rounds of the
/// full payload. `skip_first_send` marks the round-0 send as already
/// posted (non-blocking start).
pub(crate) fn allreduce_rd<W: Wire + ?Sized>(
    w: &mut W,
    g: &Group,
    buf: &mut [f64],
    skip_first_send: bool,
) -> Result<()> {
    let p = g.size;
    let pof2 = pof2_below(p);
    let rem = p - pof2;
    let mut skip = skip_first_send;
    let newrank = fold(w, g, buf, rem, &mut skip)?;
    if let Some(nr) = newrank {
        let mut mask = 1usize;
        while mask < pof2 {
            let partner = g.world(real_rank(nr ^ mask, rem));
            send_round(w, partner, buf, &mut skip)?;
            let got = w.wire_recv(partner, buf.len())?;
            add_into(buf, &got);
            w.wire_recycle(got);
            mask <<= 1;
        }
    }
    unfold(w, g, buf, rem)
}

/// Rabenseifner over `g`: recursive-halving reduce-scatter, then the
/// mirrored recursive-doubling allgather. The payload is split into `pof2`
/// near-equal contiguous chunks; chunk boundaries are closed-form so the
/// protocol allocates nothing beyond pooled message buffers.
pub(crate) fn allreduce_rab<W: Wire + ?Sized>(
    w: &mut W,
    g: &Group,
    buf: &mut [f64],
    skip_first_send: bool,
) -> Result<()> {
    let p = g.size;
    let pof2 = pof2_below(p);
    let rem = p - pof2;
    let len = buf.len();
    debug_assert!(pof2 >= 2 && len >= pof2);
    let mut skip = skip_first_send;
    let newrank = fold(w, g, buf, rem, &mut skip)?;
    if let Some(nr) = newrank {
        let base = len / pof2;
        let ext = len % pof2;
        // Element offset of chunk boundary i (first `ext` chunks get +1).
        let displ = |i: usize| i * base + i.min(ext);
        // (partner, keep_lo, keep_hi, sent_lo, sent_hi) in chunk units,
        // logged for the mirrored allgather. log₂|g| ≤ 64 steps.
        let mut steps = [(0usize, 0usize, 0usize, 0usize, 0usize); 64];
        let mut nsteps = 0usize;
        let (mut clo, mut chi) = (0usize, pof2);
        let mut mask = pof2 >> 1;
        // Reduce-scatter: each round, exchange half the live chunk span
        // with the partner and accumulate into the kept half.
        while mask > 0 {
            let pn = nr ^ mask;
            let partner = g.world(real_rank(pn, rem));
            let mid = clo + (chi - clo) / 2;
            let (klo, khi, slo, shi) = if nr < pn {
                (clo, mid, mid, chi)
            } else {
                (mid, chi, clo, mid)
            };
            {
                let (lo_e, hi_e) = (displ(slo), displ(shi));
                send_round(w, partner, &buf[lo_e..hi_e], &mut skip)?;
            }
            let (klo_e, khi_e) = (displ(klo), displ(khi));
            let got = w.wire_recv(partner, khi_e - klo_e)?;
            add_into(&mut buf[klo_e..khi_e], &got);
            w.wire_recycle(got);
            steps[nsteps] = (partner, klo, khi, slo, shi);
            nsteps += 1;
            clo = klo;
            chi = khi;
            mask >>= 1;
        }
        // Allgather: replay the exchanges in reverse, swapping roles —
        // send the gathered kept range, receive the complementary one.
        for i in (0..nsteps).rev() {
            let (partner, klo, khi, slo, shi) = steps[i];
            let (klo_e, khi_e) = (displ(klo), displ(khi));
            w.wire_send(partner, &buf[klo_e..khi_e])?;
            let (slo_e, shi_e) = (displ(slo), displ(shi));
            let got = w.wire_recv(partner, shi_e - slo_e)?;
            buf[slo_e..shi_e].copy_from_slice(&got);
            w.wire_recycle(got);
        }
    }
    unfold(w, g, buf, rem)
}

/// Binomial-tree broadcast from group member `root_idx` over `g`.
pub(crate) fn broadcast_tree<W: Wire + ?Sized>(
    w: &mut W,
    g: &Group,
    root_idx: usize,
    buf: &mut [f64],
) -> Result<()> {
    let p = g.size;
    if p == 1 {
        return Ok(());
    }
    let rel = (g.index + p - root_idx) % p;
    // Receive phase.
    let mut mask = 1usize;
    while mask < p {
        if rel & mask != 0 {
            let src = g.world((g.index + p - mask) % p);
            let got = w.wire_recv(src, buf.len())?;
            buf.copy_from_slice(&got);
            w.wire_recycle(got);
            break;
        }
        mask <<= 1;
    }
    // Send phase (from the highest mask below our receive level down).
    mask >>= 1;
    while mask > 0 {
        if rel + mask < p {
            let dst = g.world((g.index + mask) % p);
            w.wire_send(dst, buf)?;
        }
        mask >>= 1;
    }
    Ok(())
}

// ---- hierarchical two-level composition ---------------------------------

/// Node geometry of the two-level topology for one endpoint: `(leader
/// world rank, one-past-the-end of this node's members, leader group)`.
/// `node_size` is clamped to `[1, p]` so degenerate configurations stay
/// well-defined (`node_size = 1` is the flat leader group; `node_size ≥ p`
/// is a single fan-in/fan-out star rooted at rank 0).
fn node_geometry(p: usize, node_size: usize, rank: usize) -> (usize, usize, Group) {
    let ns = node_size.clamp(1, p);
    let leader = rank - rank % ns;
    let node_end = (leader + ns).min(p);
    let leaders = p.div_ceil(ns);
    let g = Group {
        size: leaders,
        index: rank / ns,
        stride: ns,
    };
    (leader, node_end, g)
}

/// Hierarchical two-level allreduce (see the module docs): member fan-in
/// to the node leader, flat dispatch over the leader group, fan-out back.
/// `skip_first_send` marks the protocol's round-0 send — a member's fan-in
/// send, or a member-less leader's first leader-group send — as already
/// posted by [`two_level_post_first_send`].
pub(crate) fn two_level_allreduce<W: Wire + ?Sized>(
    w: &mut W,
    node_size: usize,
    buf: &mut [f64],
    skip_first_send: bool,
) -> Result<()> {
    let p = w.wire_size();
    let rank = w.wire_rank();
    if p == 1 {
        return Ok(());
    }
    let (leader, node_end, g) = node_geometry(p, node_size, rank);
    let mut skip = skip_first_send;
    if rank != leader {
        // Member: contribute, then wait for the reduced result.
        send_round(w, leader, buf, &mut skip)?;
        let got = w.wire_recv(leader, buf.len())?;
        buf.copy_from_slice(&got);
        w.wire_recycle(got);
        return Ok(());
    }
    // Leader: accumulate the node, exchange across leaders, fan out.
    for member in leader + 1..node_end {
        let got = w.wire_recv(member, buf.len())?;
        add_into(buf, &got);
        w.wire_recycle(got);
    }
    if g.size > 1 {
        match select_algo(g.size, buf.len()) {
            Algo::Rabenseifner => allreduce_rab(w, &g, buf, skip)?,
            _ => allreduce_rd(w, &g, buf, skip)?,
        }
    }
    for member in leader + 1..node_end {
        w.wire_send(member, buf)?;
    }
    Ok(())
}

/// Round-0 send of the two-level protocol, if this rank has one that
/// depends only on local data: members post their fan-in send; a leader
/// *with* members must accumulate before sending anything; a member-less
/// leader posts its leader-group round-0 send. Returns whether a send was
/// posted (consumed as `skip_first_send` by [`two_level_allreduce`]).
pub(crate) fn two_level_post_first_send<W: Wire + ?Sized>(
    w: &mut W,
    node_size: usize,
    buf: &[f64],
) -> Result<bool> {
    let p = w.wire_size();
    let rank = w.wire_rank();
    let (leader, node_end, g) = node_geometry(p, node_size, rank);
    if rank != leader {
        w.wire_send(leader, buf)?;
        return Ok(true);
    }
    if node_end > leader + 1 || g.size <= 1 {
        return Ok(false);
    }
    post_first_send(w, &g, buf, select_algo(g.size, buf.len()))
}

/// The flat protocol's unique round-0 send over `g`, if this member has
/// one that depends only on local data (everything except the folded-odd
/// role). Returns whether a send was posted.
pub(crate) fn post_first_send<W: Wire + ?Sized>(
    w: &mut W,
    g: &Group,
    buf: &[f64],
    algo: Algo,
) -> Result<bool> {
    let p = g.size;
    let idx = g.index;
    let pof2 = pof2_below(p);
    let rem = p - pof2;
    if idx < 2 * rem {
        if idx % 2 == 0 {
            w.wire_send(g.world(idx + 1), buf)?;
            return Ok(true);
        }
        // Folded-odd members must receive before their first send.
        return Ok(false);
    }
    let nr = idx - rem;
    match algo {
        Algo::Rabenseifner => {
            let len = buf.len();
            let base = len / pof2;
            let ext = len % pof2;
            let displ = |i: usize| i * base + i.min(ext);
            let mask = pof2 >> 1;
            let pn = nr ^ mask;
            let mid = pof2 / 2;
            let (slo, shi) = if nr < pn { (mid, pof2) } else { (0, mid) };
            let partner = g.world(real_rank(pn, rem));
            w.wire_send(partner, &buf[displ(slo)..displ(shi)])?;
        }
        _ => {
            let partner = g.world(real_rank(nr ^ 1, rem));
            w.wire_send(partner, buf)?;
        }
    }
    Ok(true)
}

/// Run the allreduce protocol selected by `algo` (the transports' shared
/// dispatch point — flat core algorithms over the whole communicator, or
/// the two-level composition).
pub(crate) fn allreduce_dispatch<W: Wire + ?Sized>(
    w: &mut W,
    algo: Algo,
    buf: &mut [f64],
    skip_first_send: bool,
) -> Result<()> {
    let g = Group::flat(w.wire_size(), w.wire_rank());
    match algo {
        Algo::RecursiveDoubling => allreduce_rd(w, &g, buf, skip_first_send),
        Algo::Rabenseifner => allreduce_rab(w, &g, buf, skip_first_send),
        Algo::TwoLevel { node_size } => two_level_allreduce(w, node_size, buf, skip_first_send),
    }
}

/// Round-0 send of the protocol selected by `algo` (non-blocking start
/// twin of [`allreduce_dispatch`]). Returns whether a send was posted.
pub(crate) fn post_first_dispatch<W: Wire + ?Sized>(
    w: &mut W,
    algo: Algo,
    buf: &[f64],
) -> Result<bool> {
    match algo {
        Algo::TwoLevel { node_size } => two_level_post_first_send(w, node_size, buf),
        _ => {
            let g = Group::flat(w.wire_size(), w.wire_rank());
            post_first_send(w, &g, buf, algo)
        }
    }
}

/// Exact per-rank (sends, send-words) of one two-level `allreduce_sum` of
/// `len` words on a `p`-rank group with node size `node_size` — the
/// message/word closed form of the hierarchical collective, mirrored by
/// `costmodel::theory::two_level_allreduce_cost` and gated (measured ==
/// formula) by the hot-path bench. Members send once (`len` words);
/// leaders send their leader-group flat-allreduce schedule plus one
/// fan-out copy per member.
pub fn expected_two_level_allreduce_sends(
    p: usize,
    node_size: usize,
    rank: usize,
    len: usize,
) -> (u64, u64) {
    if p <= 1 {
        return (0, 0);
    }
    let (leader, node_end, g) = node_geometry(p, node_size, rank);
    if rank != leader {
        return (1, len as u64);
    }
    let members = (node_end - leader - 1) as u64;
    let (mut msgs, mut words) = if g.size > 1 {
        crate::comm::thread::expected_allreduce_sends(g.size, g.index, len)
    } else {
        (0, 0)
    };
    msgs += members;
    words += members * len as u64;
    (msgs, words)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::thread::{expected_allreduce_sends, run_spmd, RABENSEIFNER_MIN_WORDS};
    use crate::comm::{Communicator, Topology};

    #[test]
    fn two_level_allreduce_sums_across_geometries() {
        for p in [2usize, 3, 4, 5, 6, 7, 8] {
            for ns in [1usize, 2, 3, 4, 8] {
                for len in [9usize, RABENSEIFNER_MIN_WORDS + 13] {
                    let results = run_spmd(p, move |rank, comm| {
                        comm.set_topology(Topology::TwoLevel { node_size: ns });
                        let mut buf: Vec<f64> =
                            (0..len).map(|i| ((rank + 1) * (i + 1)) as f64 * 0.5).collect();
                        comm.allreduce_sum(&mut buf).unwrap();
                        buf
                    });
                    for i in 0..len {
                        let expect: f64 =
                            (0..p).map(|r| ((r + 1) * (i + 1)) as f64 * 0.5).sum();
                        for (rank, r) in results.iter().enumerate() {
                            assert_eq!(r[i], expect, "p={p} ns={ns} len={len} rank={rank}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn two_level_nonblocking_is_bitwise_equal_to_blocking() {
        for p in [3usize, 4, 8] {
            for ns in [2usize, 3, 4] {
                for len in [7usize, RABENSEIFNER_MIN_WORDS + 5] {
                    let results = run_spmd(p, move |rank, comm| {
                        comm.set_topology(Topology::TwoLevel { node_size: ns });
                        let data: Vec<f64> =
                            (0..len).map(|i| ((rank + 1) * (i + 1)) as f64 * 0.37).collect();
                        let mut blocking = data.clone();
                        comm.allreduce_sum(&mut blocking).unwrap();
                        let h = comm.iallreduce_start(data).unwrap();
                        let nonblocking = comm.iallreduce_wait(h).unwrap();
                        (blocking, nonblocking)
                    });
                    for (rank, (b, nb)) in results.iter().enumerate() {
                        assert!(b == nb, "p={p} ns={ns} len={len} rank={rank}");
                    }
                }
            }
        }
    }

    #[test]
    fn two_level_matches_flat_result_bitwise() {
        // Leaders accumulate members in rank order, then the leader-group
        // fold accumulates in the same pairwise order as flat — the sums
        // are equal but association differs, so compare against a
        // rank-order serial sum tolerance-free only where exact: here we
        // check the values agree to high relative precision.
        for (p, ns) in [(4usize, 2usize), (6, 3), (8, 4)] {
            let results = run_spmd(p, move |rank, comm| {
                let mut flat = vec![rank as f64 + 0.25; 12];
                comm.allreduce_sum(&mut flat).unwrap();
                comm.set_topology(Topology::TwoLevel { node_size: ns });
                let mut hier = vec![rank as f64 + 0.25; 12];
                comm.allreduce_sum(&mut hier).unwrap();
                (flat, hier)
            });
            for (flat, hier) in results {
                for (x, y) in flat.iter().zip(&hier) {
                    assert!((x - y).abs() <= 1e-12 * x.abs().max(1.0), "{x} vs {y}");
                }
            }
        }
    }

    #[test]
    fn two_level_meters_match_closed_form() {
        for p in [2usize, 4, 5, 7, 8] {
            for ns in [1usize, 2, 3, 4] {
                for len in [16usize, RABENSEIFNER_MIN_WORDS + 13] {
                    let meters = run_spmd(p, move |_rank, comm| {
                        comm.set_topology(Topology::TwoLevel { node_size: ns });
                        let mut buf = vec![1.0; len];
                        comm.allreduce_sum(&mut buf).unwrap();
                        *comm.meter()
                    });
                    for (rank, m) in meters.iter().enumerate() {
                        let (msgs, words) =
                            expected_two_level_allreduce_sends(p, ns, rank, len);
                        assert_eq!(
                            (m.msgs, m.words),
                            (msgs, words),
                            "p={p} ns={ns} len={len} rank={rank}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn node_size_one_matches_flat_closed_form() {
        // ns = 1 makes every rank a leader: the leader group *is* the flat
        // group, so the two closed forms must coincide everywhere.
        for p in [2usize, 3, 5, 8] {
            for len in [8usize, RABENSEIFNER_MIN_WORDS + 1] {
                for rank in 0..p {
                    assert_eq!(
                        expected_two_level_allreduce_sends(p, 1, rank, len),
                        expected_allreduce_sends(p, rank, len),
                        "p={p} len={len} rank={rank}"
                    );
                }
            }
        }
    }

    #[test]
    fn star_topology_counts() {
        // ns ≥ p: a single node — rank 0 fans in P−1 payloads and fans
        // them back out; members send exactly once.
        let (p, len) = (5usize, 32usize);
        assert_eq!(
            expected_two_level_allreduce_sends(p, 16, 0, len),
            (4, 4 * len as u64)
        );
        for rank in 1..p {
            assert_eq!(expected_two_level_allreduce_sends(p, 16, rank, len), (1, len as u64));
        }
    }
}
