//! Multi-process socket transport: P ranks as OS processes, one loopback
//! TCP stream per rank pair, and the same MPICH-style collective engine
//! ([`crate::comm::proto`]) the thread transport runs — so the two are
//! bitwise identical end to end.
//!
//! # Architecture
//!
//! Each endpoint owns the write half of P−1 streams plus one detached
//! *reader thread per peer* that decodes frames off the socket and
//! forwards them into a single tagged inbox channel. The main thread then
//! runs exactly the thread transport's matching logic: receives match on
//! `(source, operation tag)`, out-of-order traffic is stashed per source,
//! and decoded payloads come from a shared rank-local buffer pool so the
//! steady state allocates nothing. Because every reader *always* drains
//! its socket into the (unbounded) inbox, a send can only block until the
//! peer's kernel buffer and reader catch up — never on collective
//! ordering — which rules out the classic send-send deadlock without any
//! extra protocol.
//!
//! # Wire format
//!
//! One frame per point-to-point message, little-endian:
//!
//! ```text
//! [ kind: u8 ][ tag: u64 ][ len: u64 ][ payload ]
//! ```
//!
//! `kind = 1` (data): payload is `len` f64 words as raw IEEE-754 bit
//! patterns — `f64::to_bits`/`from_bits`, so NaN payloads and packed
//! metadata cross the wire bit-exactly. `kind = 2` (poison): payload is a
//! `len`-byte UTF-8 failure message.
//!
//! # Failure semantics
//!
//! Identical to the thread transport, with one addition: a peer's socket
//! dying (ECONNRESET / EOF — e.g. a killed child process) is latched by
//! its reader as a *down* event. The first receive that needs that peer
//! converts it into a poisoned group, naming the peer, the op tag, and
//! the OS-level cause, and broadcasting poison to the survivors — so a
//! kill lands as one actionable `Error::Comm` everywhere instead of a
//! hang or a panic. Receive deadlines ([`Communicator::set_deadline`])
//! bound every blocking wait exactly as in the thread transport.
//!
//! Bootstrap (rendezvous listener, HELLO/MAP/PEER handshake) lives in
//! [`rendezvous`]; the launcher in `main.rs` re-execs children with the
//! rendezvous address in `CABCD_PROC_*` environment variables, and
//! externally launched ranks can call [`ProcessComm::connect`] directly.

mod rendezvous;

pub use rendezvous::{connect, Rendezvous};

use std::collections::VecDeque;
use std::io::{BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::comm::proto::{self, Group, Wire};
use crate::comm::{
    A2aState, Algo, AllToAllHandle, Communicator, CostMeter, HandleState, ReduceHandle, Topology,
};
use crate::error::{Error, Result};
use crate::telemetry;
use crate::trace::{self, OpClass, SpanKind};

/// Rendezvous address for launcher-spawned child ranks (`host:port`).
pub const ENV_ADDR: &str = "CABCD_PROC_ADDR";
/// This child's rank within the process group.
pub const ENV_RANK: &str = "CABCD_PROC_RANK";
/// Total number of ranks in the process group.
pub const ENV_RANKS: &str = "CABCD_PROC_RANKS";

/// Upper bound on pooled buffers retained per rank (mirrors the thread
/// transport's bound).
const POOL_MAX: usize = 64;
/// Frame kinds.
const FRAME_DATA: u8 = 1;
const FRAME_POISON: u8 = 2;
/// `[kind][tag][len]` prefix size in bytes.
const FRAME_HEADER_BYTES: usize = 17;
/// Sanity bound on one frame's payload length: anything larger is a
/// corrupt or hostile header, and latches the peer as down rather than
/// attempting a giant allocation.
const MAX_FRAME_WORDS: u64 = 1 << 31;

/// Read the launcher-provided child identity from the environment:
/// `(rendezvous address, rank, size)`, or `None` when not a child rank.
pub fn child_spec_from_env() -> Option<(String, usize, usize)> {
    let addr = std::env::var(ENV_ADDR).ok()?;
    let rank = std::env::var(ENV_RANK).ok()?.parse().ok()?;
    let size = std::env::var(ENV_RANKS).ok()?.parse().ok()?;
    Some((addr, rank, size))
}

/// Rank-local buffer pool shared between the main thread (recycling) and
/// the per-peer reader threads (decoding incoming payloads). Pool misses
/// are tallied atomically and folded into [`CostMeter::buf_allocs`] by the
/// endpoint at collective boundaries.
struct BufPool {
    bufs: Mutex<Vec<Vec<f64>>>,
    misses: AtomicU64,
}

impl BufPool {
    fn new() -> BufPool {
        BufPool {
            bufs: Mutex::new(Vec::new()),
            misses: AtomicU64::new(0),
        }
    }

    /// Take a cleared buffer, preferring one whose capacity already fits
    /// `len` (best-fit, as in the thread transport). A miss or capacity
    /// growth counts one allocation.
    fn take_for(&self, len: usize) -> Vec<f64> {
        let picked = {
            let mut pool = match self.bufs.lock() {
                Ok(g) => g,
                // A reader thread can only poison this lock by dying
                // mid-push; the Vec is still structurally sound.
                Err(p) => p.into_inner(),
            };
            match pool.iter().rposition(|v| v.capacity() >= len) {
                Some(i) => Some(pool.swap_remove(i)),
                None => pool.pop(),
            }
        };
        let mut v = picked.unwrap_or_default();
        if v.capacity() < len {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
        v.clear();
        v
    }

    fn give(&self, buf: Vec<f64>) {
        let mut pool = match self.bufs.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        if pool.len() < POOL_MAX {
            pool.push(buf);
        }
    }

    fn miss_count(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

/// What a reader thread forwards into the inbox.
enum InPacket {
    /// A decoded data frame: `(operation tag, payload)`.
    Data(u64, Vec<f64>),
    /// A peer's poison frame (group failure broadcast).
    Poison(String),
    /// The peer's socket died (EOF/ECONNRESET/protocol violation); the
    /// reader exits after sending this. Latched per peer by the endpoint.
    Down(String),
}

/// Decode one frame off the stream. `scratch` is the reader's reusable
/// byte buffer; payloads land in pooled `Vec<f64>`s so the steady state
/// allocates nothing.
fn read_frame(
    r: &mut BufReader<TcpStream>,
    scratch: &mut Vec<u8>,
    pool: &BufPool,
) -> std::result::Result<InPacket, String> {
    let mut hdr = [0u8; FRAME_HEADER_BYTES];
    r.read_exact(&mut hdr).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            "connection closed by peer".to_string()
        } else {
            format!("socket read failed: {e}")
        }
    })?;
    let kind = hdr[0];
    let mut w = [0u8; 8];
    w.copy_from_slice(&hdr[1..9]);
    let tag = u64::from_le_bytes(w);
    w.copy_from_slice(&hdr[9..17]);
    let len = u64::from_le_bytes(w);
    if len > MAX_FRAME_WORDS {
        return Err(format!("protocol error: oversized frame ({len} words)"));
    }
    match kind {
        FRAME_DATA => {
            let nbytes = len as usize * 8;
            scratch.resize(nbytes, 0);
            r.read_exact(&mut scratch[..nbytes])
                .map_err(|e| format!("socket read failed mid-frame: {e}"))?;
            let mut v = pool.take_for(len as usize);
            for chunk in scratch[..nbytes].chunks_exact(8) {
                w.copy_from_slice(chunk);
                v.push(f64::from_bits(u64::from_le_bytes(w)));
            }
            Ok(InPacket::Data(tag, v))
        }
        FRAME_POISON => {
            scratch.resize(len as usize, 0);
            r.read_exact(&mut scratch[..len as usize])
                .map_err(|e| format!("socket read failed mid-frame: {e}"))?;
            Ok(InPacket::Poison(
                String::from_utf8_lossy(&scratch[..len as usize]).into_owned(),
            ))
        }
        k => Err(format!("protocol error: unknown frame kind {k}")),
    }
}

/// Per-peer reader: decode frames until the socket dies or the endpoint
/// drops its inbox, forwarding everything tagged with the source rank.
fn reader_loop(src: usize, stream: TcpStream, tx: Sender<(usize, InPacket)>, pool: Arc<BufPool>) {
    let mut r = BufReader::with_capacity(1 << 16, stream);
    let mut scratch: Vec<u8> = Vec::new();
    loop {
        match read_frame(&mut r, &mut scratch, &pool) {
            Ok(pkt) => {
                if tx.send((src, pkt)).is_err() {
                    return; // endpoint dropped — nobody is listening
                }
            }
            Err(msg) => {
                let _ = tx.send((src, InPacket::Down(msg)));
                return;
            }
        }
    }
}

/// Rank-local endpoint of a P-rank multi-process communicator.
pub struct ProcessComm {
    rank: usize,
    size: usize,
    /// Write halves; `None` at our own index.
    peers: Vec<Option<TcpStream>>,
    inbox: Receiver<(usize, InPacket)>,
    /// Keeps the inbox alive even when every reader has exited (or none
    /// exist, at P=1), so deadline timeouts fire instead of `Disconnected`.
    _inbox_keepalive: Sender<(usize, InPacket)>,
    /// Out-of-order stash, as in the thread transport: `(tag, data)` per
    /// source, matched in FIFO order within an operation.
    pending: Vec<VecDeque<(u64, Vec<f64>)>>,
    /// Latched per-peer socket death, set from reader `Down` events.
    down: Vec<Option<String>>,
    pool: Arc<BufPool>,
    /// Reusable frame-encode buffer (grows to the largest frame, then
    /// stays — the encode path allocates nothing in the steady state).
    wbuf: Vec<u8>,
    /// Sticky failure state: once poisoned, every collective errors.
    poisoned: Option<String>,
    /// Monotone collective counter; SPMD determinism makes it a valid
    /// cross-rank match key (see the thread transport).
    op_seq: u64,
    cur_tag: u64,
    deadline: Option<Duration>,
    topology: Topology,
    /// Pool misses already folded into `meter.buf_allocs`.
    counted_misses: u64,
    meter: CostMeter,
}

impl ProcessComm {
    /// Join an existing group as rank `rank` by dialing rank 0's
    /// rendezvous address — for externally launched ranks (the in-tree
    /// launcher sets `CABCD_PROC_*` and calls this via
    /// [`child_spec_from_env`]).
    pub fn connect(addr: &str, rank: usize, size: usize) -> Result<ProcessComm> {
        rendezvous::connect(addr, rank, size)
    }

    /// Assemble an endpoint from an established full mesh: one stream per
    /// peer (`None` at `rank`), as produced by the rendezvous handshake.
    /// Spawns the per-peer reader threads.
    pub(crate) fn from_streams(
        rank: usize,
        size: usize,
        streams: Vec<Option<TcpStream>>,
    ) -> Result<ProcessComm> {
        if streams.len() != size {
            return Err(Error::Comm(format!(
                "process comm: {} streams for {size} ranks",
                streams.len()
            )));
        }
        let (tx, inbox) = channel();
        let pool = Arc::new(BufPool::new());
        for (src, s) in streams.iter().enumerate() {
            let Some(s) = s else {
                if src != rank {
                    return Err(Error::Comm(format!(
                        "process comm: rank {rank} missing a stream to rank {src}"
                    )));
                }
                continue;
            };
            // Collective rounds are latency-bound small writes; never
            // Nagle-delay them. Handshake read timeouts must not leak
            // into the reader's blocking loop.
            let _ = s.set_nodelay(true);
            s.set_read_timeout(None)
                .map_err(|e| Error::Comm(format!("process comm: clear read timeout: {e}")))?;
            let reader = s
                .try_clone()
                .map_err(|e| Error::Comm(format!("process comm: clone stream to {src}: {e}")))?;
            let (tx, pool) = (tx.clone(), pool.clone());
            std::thread::Builder::new()
                .name(format!("cabcd-rx-{rank}-{src}"))
                .spawn(move || reader_loop(src, reader, tx, pool))
                .map_err(|e| Error::Comm(format!("process comm: spawn reader: {e}")))?;
        }
        Ok(ProcessComm {
            rank,
            size,
            peers: streams,
            inbox,
            _inbox_keepalive: tx,
            pending: (0..size).map(|_| VecDeque::new()).collect(),
            down: (0..size).map(|_| None).collect(),
            pool,
            wbuf: Vec::new(),
            poisoned: None,
            op_seq: 0,
            cur_tag: 0,
            deadline: None,
            topology: Topology::Flat,
            counted_misses: 0,
            meter: CostMeter::default(),
        })
    }

    /// A full P-rank group inside one process, wired over real loopback
    /// sockets: rank 0 hosts the rendezvous, ranks 1..P connect from
    /// spawned threads. The socket path under test is exactly the
    /// multi-process path; only the launch vehicle differs.
    pub fn local_group(p: usize) -> Result<Vec<ProcessComm>> {
        let rv = Rendezvous::bind()?;
        let addr = rv.addr().to_string();
        let mut joiners = Vec::with_capacity(p.saturating_sub(1));
        for r in 1..p {
            let addr = addr.clone();
            let h = std::thread::Builder::new()
                .name(format!("cabcd-connect-{r}"))
                .spawn(move || connect(&addr, r, p))
                .map_err(|e| Error::Comm(format!("local_group: spawn failed: {e}")))?;
            joiners.push(h);
        }
        let root = rv.accept(p)?;
        let mut out = Vec::with_capacity(p);
        out.push(root);
        for h in joiners {
            let comm = h
                .join()
                .map_err(|_| Error::Comm("local_group: connect thread panicked".into()))??;
            out.push(comm);
        }
        Ok(out)
    }

    /// Explicitly poison the group (launcher/driver error paths: a child
    /// failing outside a collective still takes its peers down with an
    /// actionable message instead of leaving them to time out).
    pub fn abort(&mut self, msg: &str) -> Error {
        self.poison(msg.to_string())
    }

    // ---- buffer pool ----------------------------------------------------

    /// Fold reader-side pool misses into the meter (readers can't touch
    /// the meter directly; this runs at every collective boundary, so
    /// `buf_allocs` is exact up to the last completed operation).
    fn sync_allocs(&mut self) {
        let m = self.pool.miss_count();
        self.meter.buf_allocs += m - self.counted_misses;
        self.counted_misses = m;
    }

    fn take_buf_inner(&mut self, len: usize) -> Vec<f64> {
        let mut v = self.pool.take_for(len);
        v.resize(len, 0.0);
        v
    }

    // ---- point-to-point -------------------------------------------------

    /// Enter a new collective operation (see the thread transport).
    fn begin_op(&mut self) -> u64 {
        self.op_seq += 1;
        self.cur_tag = self.op_seq;
        self.op_seq
    }

    /// Encode `data` as one frame and write it to `dst`'s stream. An I/O
    /// failure means the peer's process or socket died mid-collective:
    /// surface an already-latched group poison if there is one, otherwise
    /// poison the group ourselves, naming the peer and the op tag.
    fn send_slice(&mut self, dst: usize, data: &[f64]) -> Result<()> {
        self.meter.record_send(data.len());
        let tag = self.cur_tag;
        let wbuf = &mut self.wbuf;
        wbuf.clear();
        wbuf.push(FRAME_DATA);
        wbuf.extend_from_slice(&tag.to_le_bytes());
        wbuf.extend_from_slice(&(data.len() as u64).to_le_bytes());
        for x in data {
            wbuf.extend_from_slice(&x.to_bits().to_le_bytes());
        }
        let Some(stream) = self.peers[dst].as_mut() else {
            return Err(Error::Comm(format!(
                "send {}→{dst}: no stream to peer",
                self.rank
            )));
        };
        let wrote = stream.write_all(wbuf).and_then(|_| stream.flush());
        if let Err(e) = wrote {
            self.check_poison()?;
            return Err(self.peer_lost(dst, tag, &format!("send failed: {e}")));
        }
        Ok(())
    }

    /// Send and recycle an owned buffer (all-to-all fan-out).
    fn send_owned(&mut self, dst: usize, buf: Vec<f64>) -> Result<()> {
        let res = self.send_slice(dst, &buf);
        self.pool.give(buf);
        res
    }

    fn poisoned_err(msg: &str) -> Error {
        Error::Comm(format!("group poisoned: {msg}"))
    }

    /// Broadcast a poison frame to every reachable peer, mark ourselves
    /// poisoned, and return the error to propagate. Write failures are
    /// ignored — a dead peer no longer needs the bad news.
    fn poison(&mut self, msg: String) -> Error {
        let mut frame = Vec::with_capacity(FRAME_HEADER_BYTES + msg.len());
        frame.push(FRAME_POISON);
        frame.extend_from_slice(&0u64.to_le_bytes());
        frame.extend_from_slice(&(msg.len() as u64).to_le_bytes());
        frame.extend_from_slice(msg.as_bytes());
        for s in self.peers.iter_mut().flatten() {
            let _ = s.write_all(&frame).and_then(|_| s.flush());
        }
        let err = Self::poisoned_err(&msg);
        self.poisoned = Some(msg);
        err
    }

    /// A peer's socket died under us mid-collective: poison the group
    /// with the peer, the op tag, and the OS-level cause named — the
    /// actionable form of ECONNRESET/EOF (kill-a-child regression).
    fn peer_lost(&mut self, peer: usize, tag: u64, cause: &str) -> Error {
        self.poison(format!(
            "rank {} lost rank {peer} mid-collective (op tag {tag}): {cause}",
            self.rank
        ))
    }

    /// Drain already-arrived packets (stashing data, latching poison and
    /// peer-down events) and fail if the group is poisoned.
    fn check_poison(&mut self) -> Result<()> {
        if self.poisoned.is_none() {
            while let Ok((from, pkt)) = self.inbox.try_recv() {
                match pkt {
                    InPacket::Data(tag, v) => self.pending[from].push_back((tag, v)),
                    InPacket::Poison(m) => {
                        self.poisoned = Some(m);
                        break;
                    }
                    InPacket::Down(m) => {
                        if self.down[from].is_none() {
                            self.down[from] = Some(m);
                        }
                    }
                }
            }
        }
        match &self.poisoned {
            Some(m) => Err(Self::poisoned_err(m)),
            None => Ok(()),
        }
    }

    /// Blocking receive from `src` for the current operation tag —
    /// identical matching, stashing, deadline, and poison semantics to
    /// the thread transport, plus the peer-down path: a latched or
    /// incoming `Down(src)` converts to a poisoned group naming the peer
    /// and op tag rather than waiting out the deadline.
    fn recv(&mut self, src: usize) -> Result<Vec<f64>> {
        if let Some(m) = &self.poisoned {
            return Err(Self::poisoned_err(m));
        }
        let tag = self.cur_tag;
        if let Some(pos) = self.pending[src].iter().position(|(t, _)| *t == tag) {
            let Some((_, v)) = self.pending[src].remove(pos) else {
                return Err(self.poison(format!(
                    "internal: stashed packet vanished (src {src}, tag {tag})"
                )));
            };
            self.meter.record_recv(v.len());
            return Ok(v);
        }
        if let Some(cause) = self.down[src].clone() {
            // The peer is gone and everything it ever sent is already
            // stashed — this message can never arrive.
            return Err(self.peer_lost(src, tag, &cause));
        }
        // Deadline armed once per receive, as in the thread transport.
        let expiry = self.deadline.map(|d| (Instant::now() + d, d));
        loop {
            let received = match expiry {
                None => self.inbox.recv().map_err(|_| None),
                Some((limit, budget)) => {
                    let remaining = limit.saturating_duration_since(Instant::now());
                    self.inbox.recv_timeout(remaining).map_err(|e| match e {
                        RecvTimeoutError::Timeout => Some(budget),
                        RecvTimeoutError::Disconnected => None,
                    })
                }
            };
            match received {
                Ok((from, InPacket::Data(t, v))) => {
                    if from == src && t == tag {
                        self.meter.record_recv(v.len());
                        return Ok(v);
                    }
                    self.pending[from].push_back((t, v));
                }
                Ok((_from, InPacket::Poison(m))) => {
                    let err = Self::poisoned_err(&m);
                    self.poisoned = Some(m);
                    return Err(err);
                }
                Ok((from, InPacket::Down(m))) => {
                    if from == src {
                        return Err(self.peer_lost(src, tag, &m));
                    }
                    if self.down[from].is_none() {
                        self.down[from] = Some(m);
                    }
                }
                Err(Some(budget)) => {
                    self.meter.timeouts += 1;
                    telemetry::count(telemetry::Counter::Timeouts, 1);
                    return Err(self.poison(format!(
                        "rank {} timed out after {budget:?} waiting for rank {src} (op tag {tag})",
                        self.rank,
                    )));
                }
                Err(None) => {
                    return Err(Error::Comm(format!(
                        "recv {}←{src}: inbox closed",
                        self.rank
                    )))
                }
            }
        }
    }

    /// Receive with a length contract; a mismatch poisons the group.
    fn recv_expect(&mut self, src: usize, len: usize) -> Result<Vec<f64>> {
        let v = self.recv(src)?;
        if v.len() != len {
            return Err(self.poison(format!(
                "payload length mismatch: rank {} expected {len} words from rank {src}, got {}",
                self.rank,
                v.len()
            )));
        }
        Ok(v)
    }

    /// Allreduce protocol selected by the current topology (identical to
    /// the thread transport's dispatch).
    fn algo_for(&self, len: usize) -> Algo {
        match self.topology {
            Topology::Flat => proto::select_algo(self.size, len),
            Topology::TwoLevel { node_size } => Algo::TwoLevel { node_size },
        }
    }

    /// Shared body of the personalized exchanges (see the thread
    /// transport — validation and poison semantics are identical).
    fn all_to_all_inner(
        &mut self,
        send: Vec<Vec<f64>>,
        recv_lens: Option<&[usize]>,
    ) -> Result<Vec<Vec<f64>>> {
        self.meter.all_to_alls += 1;
        let tag = self.begin_op();
        let words: u64 = send.iter().map(|v| v.len() as u64).sum();
        trace::mark(SpanKind::CollectiveStart, OpClass::AllToAll, tag, words);
        let t0 = trace::now();
        let u0 = telemetry::now();
        let res = self.all_to_all_body(send, recv_lens);
        trace::record(SpanKind::CollectiveWait, OpClass::AllToAll, tag, words, t0);
        telemetry::count(telemetry::Counter::Collectives, 1);
        telemetry::gauge(telemetry::Gauge::PayloadWords, words);
        telemetry::observe(telemetry::Hist::AllToAllWords, words);
        telemetry::observe_since(telemetry::Hist::AllToAllNs, u0);
        self.sync_allocs();
        res
    }

    fn all_to_all_body(
        &mut self,
        send: Vec<Vec<f64>>,
        recv_lens: Option<&[usize]>,
    ) -> Result<Vec<Vec<f64>>> {
        let p = self.size;
        if send.len() != p {
            return Err(self.poison(format!(
                "all_to_all: rank {} supplied {} buffers for {p} ranks",
                self.rank,
                send.len()
            )));
        }
        if let Some(lens) = recv_lens {
            if lens.len() != p {
                return Err(self.poison(format!(
                    "all_to_all: rank {} supplied {} receive lengths for {p} ranks",
                    self.rank,
                    lens.len()
                )));
            }
            if send[self.rank].len() != lens[self.rank] {
                return Err(self.poison(format!(
                    "all_to_all: rank {} self-payload {} words != expected {}",
                    self.rank,
                    send[self.rank].len(),
                    lens[self.rank]
                )));
            }
        }
        if p == 1 {
            return Ok(send);
        }
        self.check_poison()?;
        let mut out: Vec<Vec<f64>> = (0..p).map(|_| Vec::new()).collect();
        for (dst, bufv) in send.into_iter().enumerate() {
            if dst == self.rank {
                out[dst] = bufv;
            } else {
                self.send_owned(dst, bufv)?;
            }
        }
        for src in 0..p {
            if src != self.rank {
                out[src] = match recv_lens {
                    Some(lens) => self.recv_expect(src, lens[src])?,
                    None => self.recv(src)?,
                };
            }
        }
        Ok(out)
    }

    fn iall_to_all_start_body(
        &mut self,
        send: Vec<Vec<f64>>,
        recv_lens: &[usize],
        tag: u64,
    ) -> Result<AllToAllHandle> {
        let p = self.size;
        if send.len() != p {
            return Err(self.poison(format!(
                "iall_to_all: rank {} supplied {} buffers for {p} ranks",
                self.rank,
                send.len()
            )));
        }
        if recv_lens.len() != p {
            return Err(self.poison(format!(
                "iall_to_all: rank {} supplied {} receive lengths for {p} ranks",
                self.rank,
                recv_lens.len()
            )));
        }
        if send[self.rank].len() != recv_lens[self.rank] {
            return Err(self.poison(format!(
                "iall_to_all: rank {} self-payload {} words != expected {}",
                self.rank,
                send[self.rank].len(),
                recv_lens[self.rank]
            )));
        }
        if p == 1 {
            return Ok(AllToAllHandle {
                state: A2aState::Ready(send),
            });
        }
        self.check_poison()?;
        let mut out: Vec<Vec<f64>> = (0..p).map(|_| Vec::new()).collect();
        for (dst, bufv) in send.into_iter().enumerate() {
            if dst == self.rank {
                out[dst] = bufv;
            } else {
                self.send_owned(dst, bufv)?;
            }
        }
        Ok(AllToAllHandle {
            state: A2aState::Thread {
                tag,
                recv_lens: recv_lens.to_vec(),
                out,
            },
        })
    }

    /// Receive side of an in-flight all-to-all, resumed under its tag.
    fn iall_to_all_drain(
        &mut self,
        recv_lens: Vec<usize>,
        mut out: Vec<Vec<f64>>,
    ) -> Result<Vec<Vec<f64>>> {
        for src in 0..self.size {
            if src != self.rank {
                out[src] = self.recv_expect(src, recv_lens[src])?;
            }
        }
        Ok(out)
    }
}

/// Point-to-point seam of the shared collective engine — same wiring as
/// the thread transport: metered framed sends, tag-matched
/// length-contracted receives, pool recycling.
impl Wire for ProcessComm {
    fn wire_rank(&self) -> usize {
        self.rank
    }

    fn wire_size(&self) -> usize {
        self.size
    }

    fn wire_send(&mut self, dst: usize, data: &[f64]) -> Result<()> {
        self.send_slice(dst, data)
    }

    fn wire_recv(&mut self, src: usize, len: usize) -> Result<Vec<f64>> {
        self.recv_expect(src, len)
    }

    fn wire_recycle(&mut self, buf: Vec<f64>) {
        self.pool.give(buf)
    }
}

impl Communicator for ProcessComm {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn allreduce_sum(&mut self, buf: &mut [f64]) -> Result<()> {
        self.meter.allreduces += 1;
        let tag = self.begin_op();
        let words = buf.len() as u64;
        trace::mark(SpanKind::CollectiveStart, OpClass::Allreduce, tag, words);
        let t0 = trace::now();
        let u0 = telemetry::now();
        let algo = self.algo_for(buf.len());
        let res = if self.size == 1 {
            Ok(())
        } else {
            self.check_poison()
                .and_then(|_| proto::allreduce_dispatch(self, algo, buf, false))
        };
        trace::record(SpanKind::CollectiveWait, OpClass::Allreduce, tag, words, t0);
        telemetry::count(telemetry::Counter::Collectives, 1);
        telemetry::gauge(telemetry::Gauge::PayloadWords, words);
        telemetry::observe(telemetry::Hist::AllreduceWords, words);
        telemetry::observe_since(telemetry::Hist::AllreduceNs, u0);
        self.sync_allocs();
        res
    }

    fn iallreduce_start(&mut self, buf: Vec<f64>) -> Result<ReduceHandle> {
        self.meter.allreduces += 1;
        let tag = self.begin_op();
        let words = buf.len() as u64;
        let t0 = trace::now();
        let res = (|| {
            if self.size == 1 {
                return Ok(ReduceHandle {
                    buf,
                    state: HandleState::Done,
                });
            }
            self.check_poison()?;
            let algo = self.algo_for(buf.len());
            let first_sent = proto::post_first_dispatch(self, algo, &buf)?;
            Ok(ReduceHandle {
                buf,
                state: HandleState::Thread {
                    algo,
                    first_sent,
                    tag,
                },
            })
        })();
        trace::record(SpanKind::CollectiveStart, OpClass::Allreduce, tag, words, t0);
        telemetry::count(telemetry::Counter::Collectives, 1);
        telemetry::gauge(telemetry::Gauge::PayloadWords, words);
        telemetry::observe(telemetry::Hist::AllreduceWords, words);
        self.sync_allocs();
        res
    }

    fn iallreduce_wait(&mut self, handle: ReduceHandle) -> Result<Vec<f64>> {
        self.meter.collective_waits += 1;
        let ReduceHandle { mut buf, state } = handle;
        let words = buf.len() as u64;
        let t0 = trace::now();
        let u0 = telemetry::now();
        let (tag, res) = match state {
            HandleState::Done => (self.cur_tag, Ok(())),
            HandleState::Thread {
                algo,
                first_sent,
                tag,
            } => {
                // Resume under the operation tag assigned at start time.
                self.cur_tag = tag;
                let r = proto::allreduce_dispatch(self, algo, &mut buf, first_sent);
                (tag, r)
            }
        };
        trace::record(SpanKind::CollectiveWait, OpClass::Allreduce, tag, words, t0);
        telemetry::observe_since(telemetry::Hist::WaitNs, u0);
        self.sync_allocs();
        res.map(|()| buf)
    }

    fn broadcast(&mut self, root: usize, buf: &mut [f64]) -> Result<()> {
        self.begin_op();
        if self.size == 1 {
            return Ok(());
        }
        self.check_poison()?;
        let g = Group::flat(self.size, self.rank);
        let res = proto::broadcast_tree(self, &g, root, buf);
        self.sync_allocs();
        res
    }

    fn all_to_all(&mut self, send: Vec<Vec<f64>>) -> Result<Vec<Vec<f64>>> {
        self.all_to_all_inner(send, None)
    }

    fn all_to_all_expect(
        &mut self,
        send: Vec<Vec<f64>>,
        recv_lens: &[usize],
    ) -> Result<Vec<Vec<f64>>> {
        self.all_to_all_inner(send, Some(recv_lens))
    }

    fn iall_to_all_start(
        &mut self,
        send: Vec<Vec<f64>>,
        recv_lens: &[usize],
    ) -> Result<AllToAllHandle> {
        self.meter.all_to_alls += 1;
        let tag = self.begin_op();
        let words: u64 = send.iter().map(|v| v.len() as u64).sum();
        let t0 = trace::now();
        let res = self.iall_to_all_start_body(send, recv_lens, tag);
        trace::record(SpanKind::CollectiveStart, OpClass::AllToAll, tag, words, t0);
        telemetry::count(telemetry::Counter::Collectives, 1);
        telemetry::gauge(telemetry::Gauge::PayloadWords, words);
        telemetry::observe(telemetry::Hist::AllToAllWords, words);
        self.sync_allocs();
        res
    }

    fn iall_to_all_wait(&mut self, handle: AllToAllHandle) -> Result<Vec<Vec<f64>>> {
        self.meter.collective_waits += 1;
        let t0 = trace::now();
        let u0 = telemetry::now();
        let (tag, words_hint, res) = match handle.state {
            A2aState::Ready(out) => {
                let words: u64 = out.iter().map(|v| v.len() as u64).sum();
                (self.cur_tag, words, Ok(out))
            }
            A2aState::Thread {
                tag,
                recv_lens,
                out,
            } => {
                self.cur_tag = tag;
                let words: u64 = recv_lens.iter().map(|&l| l as u64).sum();
                (tag, words, self.iall_to_all_drain(recv_lens, out))
            }
        };
        trace::record(SpanKind::CollectiveWait, OpClass::AllToAll, tag, words_hint, t0);
        telemetry::observe_since(telemetry::Hist::WaitNs, u0);
        self.sync_allocs();
        res
    }

    fn barrier(&mut self) -> Result<()> {
        self.begin_op();
        if self.size == 1 {
            return Ok(());
        }
        self.check_poison()?;
        // Zero-payload recursive doubling, always flat (see ThreadComm).
        let u0 = telemetry::now();
        let g = Group::flat(self.size, self.rank);
        let res = proto::allreduce_rd(self, &g, &mut [], false);
        telemetry::count(telemetry::Counter::Collectives, 1);
        telemetry::observe_since(telemetry::Hist::BarrierNs, u0);
        self.sync_allocs();
        res
    }

    fn set_deadline(&mut self, deadline: Option<Duration>) {
        self.deadline = deadline;
    }

    fn set_topology(&mut self, topology: Topology) {
        self.topology = topology;
    }

    fn take_buf(&mut self, len: usize) -> Vec<f64> {
        let v = self.take_buf_inner(len);
        self.sync_allocs();
        v
    }

    fn give_buf(&mut self, buf: Vec<f64>) {
        self.pool.give(buf)
    }

    fn meter(&self) -> &CostMeter {
        &self.meter
    }

    fn meter_mut(&mut self) -> &mut CostMeter {
        &mut self.meter
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::thread::{run_spmd, RABENSEIFNER_MIN_WORDS};

    /// Run `f(rank, comm)` over a socket-backed local group, one thread
    /// per rank, collecting per-rank results in rank order.
    fn run_proc_spmd<T, F>(p: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize, &mut ProcessComm) -> T + Sync,
    {
        let comms = ProcessComm::local_group(p).unwrap();
        let mut out: Vec<Option<T>> = (0..p).map(|_| None).collect();
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(p);
            for (rank, mut comm) in comms.into_iter().enumerate() {
                let fref = &f;
                handles.push(scope.spawn(move || (rank, fref(rank, &mut comm))));
            }
            for h in handles {
                let (rank, val) = h.join().expect("process SPMD rank panicked");
                out[rank] = Some(val);
            }
        });
        out.into_iter().map(|v| v.unwrap()).collect()
    }

    #[test]
    fn allreduce_sums_across_ranks_over_sockets() {
        for p in [1usize, 2, 3, 5, 8] {
            let results = run_proc_spmd(p, |rank, comm| {
                let mut buf = vec![rank as f64, 1.0];
                comm.allreduce_sum(&mut buf).unwrap();
                buf
            });
            let expect = vec![(0..p).sum::<usize>() as f64, p as f64];
            for r in results {
                assert_eq!(r, expect, "p={p}");
            }
        }
    }

    #[test]
    fn rabenseifner_payloads_sum_over_sockets() {
        for p in [3usize, 4, 7] {
            let len = RABENSEIFNER_MIN_WORDS + 13;
            let results = run_proc_spmd(p, move |rank, comm| {
                let mut buf: Vec<f64> = (0..len).map(|i| (rank * len + i) as f64).collect();
                comm.allreduce_sum(&mut buf).unwrap();
                buf
            });
            for i in 0..len {
                let expect: f64 = (0..p).map(|r| (r * len + i) as f64).sum();
                for (rank, r) in results.iter().enumerate() {
                    assert_eq!(r[i], expect, "p={p} rank={rank} idx={i}");
                }
            }
        }
    }

    #[test]
    fn process_allreduce_is_bitwise_equal_to_thread() {
        // Same irrational-ish inputs through both transports; the shared
        // protocol engine must make the results bit-identical.
        for p in [2usize, 4, 5] {
            for len in [7usize, RABENSEIFNER_MIN_WORDS + 5] {
                let input = move |rank: usize| -> Vec<f64> {
                    (0..len)
                        .map(|i| ((rank + 1) * (i + 3)) as f64 * 0.317 + 1.0 / (i + 1) as f64)
                        .collect()
                };
                let via_thread = run_spmd(p, move |rank, comm| {
                    let mut buf = input(rank);
                    comm.allreduce_sum(&mut buf).unwrap();
                    buf
                });
                let via_proc = run_proc_spmd(p, move |rank, comm| {
                    let mut buf = input(rank);
                    comm.allreduce_sum(&mut buf).unwrap();
                    buf
                });
                for rank in 0..p {
                    assert!(
                        via_thread[rank] == via_proc[rank],
                        "p={p} len={len} rank={rank}: transports disagree bitwise"
                    );
                }
            }
        }
    }

    #[test]
    fn nonblocking_allreduce_is_bitwise_equal_to_blocking() {
        for p in [2usize, 3, 5] {
            for len in [7usize, RABENSEIFNER_MIN_WORDS + 5] {
                let results = run_proc_spmd(p, move |rank, comm| {
                    let data: Vec<f64> =
                        (0..len).map(|i| ((rank + 1) * (i + 1)) as f64 * 0.37).collect();
                    let mut blocking = data.clone();
                    comm.allreduce_sum(&mut blocking).unwrap();
                    let h = comm.iallreduce_start(data).unwrap();
                    let nonblocking = comm.iallreduce_wait(h).unwrap();
                    (blocking, nonblocking)
                });
                for (rank, (b, nb)) in results.iter().enumerate() {
                    assert!(b == nb, "p={p} len={len} rank={rank}: bitwise mismatch");
                }
            }
        }
    }

    #[test]
    fn two_level_topology_works_over_sockets_and_matches_closed_form() {
        for (p, ns) in [(4usize, 2usize), (5, 2), (6, 3)] {
            let len = 24usize;
            let results = run_proc_spmd(p, move |rank, comm| {
                comm.set_topology(Topology::TwoLevel { node_size: ns });
                let mut buf = vec![rank as f64 + 0.5; len];
                comm.allreduce_sum(&mut buf).unwrap();
                (buf, *comm.meter())
            });
            let expect: f64 = (0..p).map(|r| r as f64 + 0.5).sum();
            for (rank, (buf, m)) in results.iter().enumerate() {
                assert_eq!(buf, &vec![expect; len], "p={p} ns={ns} rank={rank}");
                let (msgs, words) =
                    proto::expected_two_level_allreduce_sends(p, ns, rank, len);
                assert_eq!(
                    (m.msgs, m.words),
                    (msgs, words),
                    "p={p} ns={ns} rank={rank}: meter vs closed form"
                );
            }
        }
    }

    #[test]
    fn all_to_all_permutes_payloads_over_sockets() {
        let p = 4;
        let results = run_proc_spmd(p, |rank, comm| {
            let send: Vec<Vec<f64>> = (0..p)
                .map(|dst| vec![(rank * 10 + dst) as f64])
                .collect();
            comm.all_to_all(send).unwrap()
        });
        for (rank, got) in results.iter().enumerate() {
            for (src, v) in got.iter().enumerate() {
                assert_eq!(v, &[(src * 10 + rank) as f64]);
            }
        }
    }

    #[test]
    fn all_to_all_expect_and_nonblocking_agree_over_sockets() {
        let p = 4;
        let results = run_proc_spmd(p, |rank, comm| {
            let send: Vec<Vec<f64>> = (0..p)
                .map(|dst| vec![(rank * 10 + dst) as f64; rank + 1])
                .collect();
            let lens: Vec<usize> = (0..p).map(|src| src + 1).collect();
            let blocking = comm.all_to_all_expect(send.clone(), &lens).unwrap();
            let h = comm.iall_to_all_start(send, &lens).unwrap();
            let nonblocking = comm.iall_to_all_wait(h).unwrap();
            (blocking, nonblocking)
        });
        for (rank, (b, nb)) in results.iter().enumerate() {
            assert!(b == nb, "rank={rank}");
            for (src, v) in b.iter().enumerate() {
                assert_eq!(v, &vec![(src * 10 + rank) as f64; src + 1]);
            }
        }
    }

    #[test]
    fn broadcast_preserves_payload_bits_exactly() {
        // Broadcast a quiet-NaN with a distinctive mantissa: the frame
        // codec must move raw bit patterns, not values.
        let pattern: u64 = 0x7ff8_dead_beef_cafe;
        let results = run_proc_spmd(3, move |rank, comm| {
            let mut buf = if rank == 0 {
                vec![f64::from_bits(pattern), 2.5]
            } else {
                vec![0.0, 0.0]
            };
            comm.broadcast(0, &mut buf).unwrap();
            (buf[0].to_bits(), buf[1])
        });
        for (bits, x) in results {
            assert_eq!(bits, pattern);
            assert_eq!(x, 2.5);
        }
    }

    #[test]
    fn barrier_completes_over_sockets() {
        run_proc_spmd(5, |_rank, comm| {
            for _ in 0..3 {
                comm.barrier().unwrap();
            }
        });
    }

    #[test]
    fn steady_state_allreduce_does_not_allocate() {
        for (p, len) in [(4usize, 8usize), (3, 300)] {
            run_proc_spmd(p, move |_rank, comm| {
                let mut buf = vec![1.0; len];
                for _ in 0..32 {
                    comm.allreduce_sum(&mut buf).unwrap();
                }
                let warm = comm.meter().buf_allocs;
                for _ in 0..16 {
                    comm.allreduce_sum(&mut buf).unwrap();
                }
                assert_eq!(
                    comm.meter().buf_allocs,
                    warm,
                    "pool missed after warmup (p={p}, len={len})"
                );
            });
        }
    }

    #[test]
    fn stalled_peer_times_out_and_poisons_the_group() {
        let results = run_proc_spmd(2, |rank, comm| {
            comm.set_deadline(Some(Duration::from_millis(40)));
            let mut buf = vec![rank as f64; 4];
            if rank == 1 {
                std::thread::sleep(Duration::from_millis(400));
            }
            let res = comm.allreduce_sum(&mut buf);
            (res.err(), comm.meter().timeouts)
        });
        let (err0, t0) = &results[0];
        let e0 = format!("{:?}", err0.as_ref().expect("rank 0 should time out"));
        assert!(e0.contains("timed out"), "{e0}");
        assert!(e0.contains("poisoned"), "{e0}");
        assert_eq!(*t0, 1, "timeout must be metered");
        let (err1, _) = &results[1];
        let e1 = format!("{:?}", err1.as_ref().expect("rank 1 should see poison"));
        assert!(e1.contains("poisoned"), "{e1}");
    }

    #[test]
    fn dead_peer_socket_names_peer_and_op_tag() {
        // Rank 1 drops its endpoint without participating — its sockets
        // close, rank 0's reader latches EOF, and the pending collective
        // must surface an Error::Comm naming the lost peer and the op
        // tag (the in-process twin of the kill-a-child regression).
        let comms = ProcessComm::local_group(2).unwrap();
        let mut it = comms.into_iter();
        let mut c0 = it.next().unwrap();
        let c1 = it.next().unwrap();
        drop(c1);
        c0.set_deadline(Some(Duration::from_secs(5)));
        let mut buf = vec![1.0; 4];
        let err = c0.allreduce_sum(&mut buf).expect_err("peer is gone");
        let msg = format!("{err:?}");
        assert!(msg.contains("lost rank 1"), "{msg}");
        assert!(msg.contains("op tag 1"), "{msg}");
        assert!(msg.contains("poisoned"), "{msg}");
        assert_eq!(c0.meter().timeouts, 0, "down peer must not wait out the deadline");
    }

    #[test]
    fn child_spec_round_trips_through_env() {
        std::env::set_var(ENV_ADDR, "127.0.0.1:12345");
        std::env::set_var(ENV_RANK, "2");
        std::env::set_var(ENV_RANKS, "4");
        assert_eq!(
            child_spec_from_env(),
            Some(("127.0.0.1:12345".to_string(), 2, 4))
        );
        std::env::remove_var(ENV_ADDR);
        std::env::remove_var(ENV_RANK);
        std::env::remove_var(ENV_RANKS);
        assert_eq!(child_spec_from_env(), None);
    }
}
