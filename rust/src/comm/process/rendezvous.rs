//! Rendezvous bootstrap for the multi-process socket transport.
//!
//! Rank 0 binds a loopback TCP listener ([`Rendezvous::bind`]) and hands
//! its address to the other ranks (the launcher passes it via environment
//! variables; externally launched ranks can receive it any way they
//! like). Wire-up then proceeds in three steps, all little-endian `u64`
//! words over plain TCP:
//!
//! 1. **HELLO** — every rank r ∈ 1..P dials rank 0 and sends
//!    `[MAGIC, VERSION, P, r, port]` where `port` is r's own freshly
//!    bound loopback listener. The stream stays open as the 0↔r link.
//! 2. **MAP** — after collecting P−1 hellos, rank 0 answers each child
//!    with `[MAGIC, P, port₁, …, port₍P₋₁₎]`: the full peer port table.
//! 3. **PEER mesh** — rank r dials every lower rank q ∈ 1..r at its
//!    advertised port and sends `[PEER_MAGIC, r]`; it then accepts one
//!    connection from every higher rank. Listeners are bound *before*
//!    the hello is sent, so a dial can never race its target's bind —
//!    the kernel backlog holds early connections.
//!
//! The result is a full mesh: every pair of ranks shares one dedicated
//! TCP stream, mirroring the thread transport's per-pair channel. Every
//! bootstrap wait (accepts, dials, handshake reads) is bounded by
//! [`BOOTSTRAP_TIMEOUT`] so a missing or crashed rank surfaces as
//! `Error::Comm` instead of a hang, even before the group exists and its
//! poison protocol can run.

use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use crate::comm::process::ProcessComm;
use crate::error::{Error, Result};

/// Version word carried in every HELLO; bumped on wire-format changes so
/// mismatched binaries fail the handshake instead of desynchronizing.
pub(super) const WIRE_VERSION: u64 = 1;
/// Marks rendezvous traffic (HELLO and MAP frames).
const HELLO_MAGIC: u64 = 0xCABC_D001_4E11_0001;
/// Marks peer-mesh identification frames.
const PEER_MAGIC: u64 = 0xCABC_D001_4E11_0002;
/// Bound on every bootstrap wait: generous enough for process spawn +
/// dynamic linking on a loaded CI machine, small enough that a dead rank
/// fails the job rather than wedging it.
const BOOTSTRAP_TIMEOUT: Duration = Duration::from_secs(30);
/// Accept/dial poll interval while waiting out the timeout.
const POLL: Duration = Duration::from_millis(5);

/// Rank 0's side of the bootstrap: a bound loopback listener whose
/// address the launcher distributes to the other ranks.
pub struct Rendezvous {
    listener: TcpListener,
    addr: String,
}

impl Rendezvous {
    /// Bind a fresh loopback listener on an OS-assigned port.
    pub fn bind() -> Result<Rendezvous> {
        let listener = TcpListener::bind("127.0.0.1:0")
            .map_err(|e| Error::Comm(format!("rendezvous: bind failed: {e}")))?;
        let addr = listener
            .local_addr()
            .map_err(|e| Error::Comm(format!("rendezvous: no local addr: {e}")))?
            .to_string();
        Ok(Rendezvous { listener, addr })
    }

    /// The `host:port` string peers dial (pass to [`connect`]).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Collect the P−1 hellos, answer each with the peer port map, and
    /// become rank 0 of the group. Consumes the rendezvous.
    pub fn accept(self, size: usize) -> Result<ProcessComm> {
        if size == 0 {
            return Err(Error::Comm("rendezvous: group size must be >= 1".into()));
        }
        let mut streams: Vec<Option<TcpStream>> = (0..size).map(|_| None).collect();
        let mut ports: Vec<u64> = vec![0; size];
        for _ in 1..size {
            let mut s = accept_deadline(&self.listener, "rendezvous: waiting for a rank's hello")?;
            arm_handshake_timeout(&s)?;
            let hello = read_words::<5>(&mut s, "rendezvous: hello")?;
            let [magic, version, their_size, rank, port] = hello;
            if magic != HELLO_MAGIC {
                return Err(Error::Comm(format!(
                    "rendezvous: bad hello magic {magic:#x} (not a cabcd rank?)"
                )));
            }
            if version != WIRE_VERSION {
                return Err(Error::Comm(format!(
                    "rendezvous: wire version mismatch: peer speaks v{version}, host v{WIRE_VERSION}"
                )));
            }
            if their_size as usize != size {
                return Err(Error::Comm(format!(
                    "rendezvous: peer expects {their_size} ranks, host launched {size}"
                )));
            }
            let r = rank as usize;
            if r == 0 || r >= size {
                return Err(Error::Comm(format!(
                    "rendezvous: hello from out-of-range rank {r} (size {size})"
                )));
            }
            if streams[r].is_some() {
                return Err(Error::Comm(format!("rendezvous: duplicate hello from rank {r}")));
            }
            ports[r] = port;
            streams[r] = Some(s);
        }
        let mut map = Vec::with_capacity(1 + size);
        map.push(HELLO_MAGIC);
        map.push(size as u64);
        map.extend_from_slice(&ports[1..]);
        for s in streams.iter_mut().flatten() {
            write_words(s, &map, "rendezvous: port map")?;
        }
        ProcessComm::from_streams(0, size, streams)
    }
}

/// Join a group as rank `rank` of `size` by dialing rank 0's rendezvous
/// address — the entry point for externally launched ranks (the in-tree
/// launcher calls it too, after re-exec'ing children with the address in
/// their environment). Rank 0 itself must host via [`Rendezvous`].
pub fn connect(addr: &str, rank: usize, size: usize) -> Result<ProcessComm> {
    if rank == 0 || rank >= size {
        return Err(Error::Comm(format!(
            "connect: rank must be in 1..{size} (rank 0 hosts the rendezvous), got {rank}"
        )));
    }
    let listener = TcpListener::bind("127.0.0.1:0")
        .map_err(|e| Error::Comm(format!("connect: rank {rank} bind failed: {e}")))?;
    let my_port = listener
        .local_addr()
        .map_err(|e| Error::Comm(format!("connect: rank {rank} no local addr: {e}")))?
        .port() as u64;
    let mut root = dial_deadline(addr, &format!("connect: rank {rank} dialing rank 0"))?;
    arm_handshake_timeout(&root)?;
    write_words(
        &mut root,
        &[HELLO_MAGIC, WIRE_VERSION, size as u64, rank as u64, my_port],
        "connect: hello",
    )?;
    let head = read_words::<2>(&mut root, "connect: port map header")?;
    if head[0] != HELLO_MAGIC || head[1] as usize != size {
        return Err(Error::Comm(format!(
            "connect: bad port map header [{:#x}, {}] (size {size})",
            head[0], head[1]
        )));
    }
    let mut ports = vec![0u64; size];
    for port in ports.iter_mut().skip(1) {
        *port = read_words::<1>(&mut root, "connect: port map")?[0];
    }
    let mut streams: Vec<Option<TcpStream>> = (0..size).map(|_| None).collect();
    streams[0] = Some(root);
    // Dial every lower peer…
    for q in 1..rank {
        let peer_addr = format!("127.0.0.1:{}", ports[q]);
        let mut s = dial_deadline(&peer_addr, &format!("connect: rank {rank} dialing rank {q}"))?;
        write_words(&mut s, &[PEER_MAGIC, rank as u64], "connect: peer hello")?;
        streams[q] = Some(s);
    }
    // …and accept one connection from every higher peer.
    for _ in rank + 1..size {
        let mut s = accept_deadline(&listener, "connect: waiting for a higher rank")?;
        arm_handshake_timeout(&s)?;
        let hello = read_words::<2>(&mut s, "connect: peer hello")?;
        if hello[0] != PEER_MAGIC {
            return Err(Error::Comm(format!(
                "connect: bad peer magic {:#x} at rank {rank}",
                hello[0]
            )));
        }
        let q = hello[1] as usize;
        if q <= rank || q >= size {
            return Err(Error::Comm(format!(
                "connect: unexpected peer rank {q} dialing rank {rank}"
            )));
        }
        if streams[q].is_some() {
            return Err(Error::Comm(format!(
                "connect: duplicate connection from rank {q}"
            )));
        }
        streams[q] = Some(s);
    }
    ProcessComm::from_streams(rank, size, streams)
}

/// Bound every handshake read so a wedged peer cannot stall the
/// bootstrap; [`ProcessComm::from_streams`] clears the timeout before the
/// reader threads take over.
fn arm_handshake_timeout(s: &TcpStream) -> Result<()> {
    s.set_read_timeout(Some(BOOTSTRAP_TIMEOUT))
        .map_err(|e| Error::Comm(format!("rendezvous: set_read_timeout failed: {e}")))
}

/// Accept one connection, polling non-blockingly until the bootstrap
/// timeout expires (std's `TcpListener` has no native accept timeout).
fn accept_deadline(listener: &TcpListener, what: &str) -> Result<TcpStream> {
    listener
        .set_nonblocking(true)
        .map_err(|e| Error::Comm(format!("{what}: set_nonblocking failed: {e}")))?;
    let t0 = Instant::now();
    loop {
        match listener.accept() {
            Ok((s, _)) => {
                let _ = listener.set_nonblocking(false);
                s.set_nonblocking(false)
                    .map_err(|e| Error::Comm(format!("{what}: unblock accepted stream: {e}")))?;
                return Ok(s);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                if t0.elapsed() >= BOOTSTRAP_TIMEOUT {
                    return Err(Error::Comm(format!(
                        "{what}: no connection within {BOOTSTRAP_TIMEOUT:?}"
                    )));
                }
                std::thread::sleep(POLL);
            }
            Err(e) => return Err(Error::Comm(format!("{what}: accept failed: {e}"))),
        }
    }
}

/// Dial with retries until the bootstrap timeout expires (covers the race
/// where an externally launched rank dials before the host finishes
/// binding).
fn dial_deadline(addr: &str, what: &str) -> Result<TcpStream> {
    let t0 = Instant::now();
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if t0.elapsed() >= BOOTSTRAP_TIMEOUT {
                    return Err(Error::Comm(format!(
                        "{what}: {addr} unreachable within {BOOTSTRAP_TIMEOUT:?}: {e}"
                    )));
                }
                std::thread::sleep(POLL);
            }
        }
    }
}

fn write_words(s: &mut TcpStream, words: &[u64], what: &str) -> Result<()> {
    let mut bytes = Vec::with_capacity(words.len() * 8);
    for w in words {
        bytes.extend_from_slice(&w.to_le_bytes());
    }
    s.write_all(&bytes)
        .and_then(|_| s.flush())
        .map_err(|e| Error::Comm(format!("{what}: write failed: {e}")))
}

fn read_words<const N: usize>(s: &mut TcpStream, what: &str) -> Result<[u64; N]> {
    let mut bytes = [0u8; 8];
    let mut out = [0u64; N];
    for w in out.iter_mut() {
        s.read_exact(&mut bytes)
            .map_err(|e| Error::Comm(format!("{what}: read failed: {e}")))?;
        *w = u64::from_le_bytes(bytes);
    }
    Ok(out)
}
