//! Crate-wide error type.

use thiserror::Error;

/// Unified error for every layer of the coordinator.
#[derive(Error, Debug)]
pub enum Error {
    #[error("shape mismatch: {0}")]
    Shape(String),

    #[error("invalid argument: {0}")]
    InvalidArg(String),

    #[error("linear algebra failure: {0}")]
    Linalg(String),

    #[error("communicator failure: {0}")]
    Comm(String),

    #[error("dataset error: {0}")]
    Dataset(String),

    #[error("artifact/runtime error: {0}")]
    Runtime(String),

    #[error("config error: {0}")]
    Config(String),

    #[error(transparent)]
    Io(#[from] std::io::Error),

    #[error("XLA/PJRT error: {0}")]
    Xla(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
