//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls — the crate builds offline with zero
//! external dependencies, so `thiserror` is not available.

use std::fmt;

/// Unified error for every layer of the coordinator.
#[derive(Debug)]
pub enum Error {
    Shape(String),
    InvalidArg(String),
    Linalg(String),
    Comm(String),
    Dataset(String),
    Runtime(String),
    Config(String),
    Io(std::io::Error),
    Xla(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Shape(m) => write!(f, "shape mismatch: {m}"),
            Error::InvalidArg(m) => write!(f, "invalid argument: {m}"),
            Error::Linalg(m) => write!(f, "linear algebra failure: {m}"),
            Error::Comm(m) => write!(f, "communicator failure: {m}"),
            Error::Dataset(m) => write!(f, "dataset error: {m}"),
            Error::Runtime(m) => write!(f, "artifact/runtime error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Io(e) => write!(f, "{e}"),
            Error::Xla(m) => write!(f, "XLA/PJRT error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_category_and_message() {
        assert_eq!(
            Error::Comm("rank 3 hung".into()).to_string(),
            "communicator failure: rank 3 hung"
        );
        assert!(Error::Shape("2 vs 3".into()).to_string().contains("2 vs 3"));
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(std::error::Error::source(&e).is_some());
    }
}
