//! The paper's analytic performance model: `T = γF + αL + βW` (§2.2) with
//! the per-algorithm critical-path costs of Theorems 1–9 and the machine
//! presets used by §5.2's modeled-performance experiments.

pub mod machine;
pub mod scaling;
pub mod theory;

pub use machine::Machine;
pub use scaling::{strong_scaling, weak_scaling, ScalingPoint, ScalingSeries};
pub use theory::{AlgoCosts, CostParams, Method};
