//! The paper's analytic performance model: `T = γF + αL + βW` (§2.2) with
//! the per-algorithm critical-path costs of Theorems 1–9, the machine
//! presets used by §5.2's modeled-performance experiments, and a measured
//! wire mode ([`Wire::Measured`]) calibrated to the packed-payload
//! RD/Rabenseifner collectives this crate actually runs.

pub mod machine;
pub mod scaling;
pub mod theory;

pub use machine::Machine;
pub use scaling::{
    strong_scaling, strong_scaling_wire, weak_scaling, weak_scaling_wire, ScalingPoint,
    ScalingSeries,
};
pub use theory::{measured_allreduce_cost, AlgoCosts, CostParams, Method, Wire};
