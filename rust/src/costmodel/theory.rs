//! Closed-form critical-path costs of Theorems 1–9 plus the §2.1 survey
//! rows (Table 2: Krylov, TSQR).
//!
//! Flops (F), latency (L, messages), bandwidth (W, words) and memory
//! (M, words/processor) as functions of the problem and algorithm
//! parameters. These regenerate Tables 1 and 2 and drive Figures 1, 3, 6,
//! 8 and 9.

/// Problem + algorithm parameters for one cost evaluation.
#[derive(Clone, Copy, Debug)]
pub struct CostParams {
    /// Feature dimension d.
    pub d: f64,
    /// Data-point dimension n.
    pub n: f64,
    /// Processor count P.
    pub p: f64,
    /// Block size (b for primal, b' for dual).
    pub b: f64,
    /// Loop-blocking factor s (1 = classical).
    pub s: f64,
    /// Iteration count (H or H').
    pub h: f64,
}

/// The algorithm whose Theorem we instantiate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Thm. 1 — BCD, 1D-block column.
    Bcd,
    /// Thm. 2 — BDCD, 1D-block row.
    Bdcd,
    /// Thm. 6 — CA-BCD, 1D-block column.
    CaBcd,
    /// Thm. 7 — CA-BDCD, 1D-block column (of Xᵀ).
    CaBdcd,
    /// Table 2 — Krylov (CG) with 1D layout, k = h iterations.
    Krylov,
    /// Table 2 — TSQR single-pass direct solve.
    Tsqr,
}

/// Critical-path costs.
#[derive(Clone, Copy, Debug, Default)]
pub struct AlgoCosts {
    pub flops: f64,
    pub latency: f64,
    pub bandwidth: f64,
    pub memory: f64,
}

impl AlgoCosts {
    /// Instantiate the Theorem for `method` at `cp`.
    ///
    /// The primal formulas contract along n, the dual along d — captured by
    /// swapping the roles of (d, n) for the dual methods, exactly as in
    /// Table 1.
    pub fn of(method: Method, cp: &CostParams) -> AlgoCosts {
        let CostParams { d, n, p, b, s, h } = *cp;
        let logp = p.log2().max(1.0);
        match method {
            Method::Bcd => AlgoCosts {
                // Thm. 1: F = O(Hb²n/P + Hb³), L = O(H log P),
                //         W = O(Hb² log P), M = O(dn/P + b²).
                flops: h * b * b * n / p + h * b * b * b,
                latency: h * logp,
                bandwidth: h * b * b * logp,
                memory: d * n / p + b * b,
            },
            Method::Bdcd => AlgoCosts {
                // Thm. 2: same with (d ↔ n), block size b'.
                flops: h * b * b * d / p + h * b * b * b,
                latency: h * logp,
                bandwidth: h * b * b * logp,
                memory: d * n / p + b * b,
            },
            Method::CaBcd => AlgoCosts {
                // Thm. 6: F = O(Hb²ns/P + Hb³), L = O((H/s) log P),
                //         W = O(Hb²s log P), M = O(dn/P + b²s²).
                flops: h * b * b * n * s / p + h * b * b * b,
                latency: (h / s) * logp,
                bandwidth: h * b * b * s * logp,
                memory: d * n / p + b * b * s * s,
            },
            Method::CaBdcd => AlgoCosts {
                // Thm. 7: (d ↔ n).
                flops: h * b * b * d * s / p + h * b * b * b,
                latency: (h / s) * logp,
                bandwidth: h * b * b * s * logp,
                memory: d * n / p + b * b * s * s,
            },
            Method::Krylov => AlgoCosts {
                // Table 2: F = O(k·dn/P), L = O(k log P),
                //          W = O(k·min(d,n)·log P), M = O(dn/P).
                flops: h * d * n / p,
                latency: h * logp,
                bandwidth: h * d.min(n) * logp,
                memory: d * n / p,
            },
            Method::Tsqr => AlgoCosts {
                // Table 2: F = O(min(d,n)²·max(d,n)/P), L = O(log P),
                //          W = O(min(d,n)² log P), M = O(dn/P).
                flops: d.min(n) * d.min(n) * d.max(n) / p,
                latency: logp,
                bandwidth: d.min(n) * d.min(n) * logp,
                memory: d * n / p,
            },
        }
    }

    /// Sequential-cost variant used by the paper's Figures 3/6 (flops
    /// summed over ranks, log P dropped from latency, constants ignored —
    /// see §5.1 "we plot the sequential flops cost ... ignore the log P
    /// factor").
    pub fn sequential(method: Method, cp: &CostParams) -> AlgoCosts {
        let mut one = *cp;
        one.p = 1.0;
        let mut c = AlgoCosts::of(method, &one);
        // log P factor dropped: with p=1 logp clamps to 1 already.
        c.memory = cp.d * cp.n + one.b * one.b * one.s * one.s;
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cp() -> CostParams {
        CostParams {
            d: 1000.0,
            n: 10000.0,
            p: 64.0,
            b: 8.0,
            s: 1.0,
            h: 100.0,
        }
    }

    #[test]
    fn ca_reduces_latency_by_s() {
        let mut p = cp();
        let base = AlgoCosts::of(Method::Bcd, &p);
        p.s = 8.0;
        let ca = AlgoCosts::of(Method::CaBcd, &p);
        assert!((base.latency / ca.latency - 8.0).abs() < 1e-9);
    }

    #[test]
    fn ca_increases_flops_bandwidth_by_s() {
        let mut p = cp();
        let base = AlgoCosts::of(Method::Bcd, &p);
        p.s = 4.0;
        let ca = AlgoCosts::of(Method::CaBcd, &p);
        // dominant term scales by s (the +Hb³ term doesn't, so ratio < s)
        assert!(ca.bandwidth / base.bandwidth == 4.0);
        assert!(ca.flops > base.flops);
        assert!(ca.flops < 4.0 * base.flops + 1.0);
    }

    #[test]
    fn s_equals_one_matches_classical() {
        let p = cp();
        let bcd = AlgoCosts::of(Method::Bcd, &p);
        let ca = AlgoCosts::of(Method::CaBcd, &p);
        assert_eq!(bcd.flops, ca.flops);
        assert_eq!(bcd.latency, ca.latency);
        assert_eq!(bcd.bandwidth, ca.bandwidth);
        assert_eq!(bcd.memory, ca.memory);
    }

    #[test]
    fn dual_swaps_dimensions() {
        let p = cp();
        let bcd = AlgoCosts::of(Method::Bcd, &p);
        let bdcd = AlgoCosts::of(Method::Bdcd, &p);
        // n=10000 vs d=1000: primal flops 10× dual flops (dominant term).
        assert!(bcd.flops > 5.0 * bdcd.flops);
        assert_eq!(bcd.latency, bdcd.latency);
    }

    #[test]
    fn tsqr_single_reduction() {
        let p = cp();
        let t = AlgoCosts::of(Method::Tsqr, &p);
        assert_eq!(t.latency, (64.0f64).log2());
        // min(d,n)² max(d,n) / P
        assert_eq!(t.flops, 1000.0 * 1000.0 * 10000.0 / 64.0);
    }

    #[test]
    fn memory_grows_s_squared() {
        let mut p = cp();
        p.s = 10.0;
        let ca = AlgoCosts::of(Method::CaBcd, &p);
        let expect = 1000.0 * 10000.0 / 64.0 + 64.0 * 100.0;
        assert_eq!(ca.memory, expect);
    }
}
