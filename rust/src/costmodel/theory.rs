//! Closed-form critical-path costs of Theorems 1–9 plus the §2.1 survey
//! rows (Table 2: Krylov, TSQR).
//!
//! Flops (F), latency (L, messages), bandwidth (W, words) and memory
//! (M, words/processor) as functions of the problem and algorithm
//! parameters. These regenerate Tables 1 and 2 and drive Figures 1, 3, 6,
//! 8 and 9.
//!
//! Two wire models ([`Wire`]): the Theorems' `O(b² log P)`-words-per-
//! allreduce charge, and the **measured** model calibrated to what
//! `comm::thread` actually moves — the packed `sb(sb+1)/2 + sb` `[G|r]`
//! payload under Rabenseifner (`≈2·len·(P−1)/P` words, `2·log₂P`
//! messages) or recursive doubling (`len·log₂P` words, `log₂P` messages),
//! selected by the same size crossover as the real communicator. This
//! closes the ROADMAP "calibrate the cost model" item.

/// Problem + algorithm parameters for one cost evaluation.
#[derive(Clone, Copy, Debug)]
pub struct CostParams {
    /// Feature dimension d.
    pub d: f64,
    /// Data-point dimension n.
    pub n: f64,
    /// Processor count P.
    pub p: f64,
    /// Block size (b for primal, b' for dual).
    pub b: f64,
    /// Loop-blocking factor s (1 = classical).
    pub s: f64,
    /// Iteration count (H or H').
    pub h: f64,
}

/// The algorithm whose Theorem we instantiate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Thm. 1 — BCD, 1D-block column.
    Bcd,
    /// Thm. 2 — BDCD, 1D-block row.
    Bdcd,
    /// Thm. 6 — CA-BCD, 1D-block column.
    CaBcd,
    /// Thm. 7 — CA-BDCD, 1D-block column (of Xᵀ).
    CaBdcd,
    /// Table 2 — Krylov (CG) with 1D layout, k = h iterations.
    Krylov,
    /// Table 2 — TSQR single-pass direct solve.
    Tsqr,
}

/// Which wire model the latency/bandwidth columns charge.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Wire {
    /// The paper's Theorems: every allreduce costs `O(log P)` messages and
    /// `O(b²s² log P)` words (constants dropped).
    Theory,
    /// Calibrated to the measured collectives: the packed
    /// `sb(sb+1)/2 + sb` `[G|r]` payload under the same
    /// Rabenseifner/recursive-doubling selection the thread communicator
    /// uses (`comm::thread::RABENSEIFNER_MIN_WORDS` crossover, closed
    /// forms of `expected_allreduce_sends` at power-of-two P).
    Measured,
}

/// Closed-form per-rank (messages, words) of one allreduce of `len` words
/// over `p` ranks, mirroring `comm::thread::expected_allreduce_sends` —
/// including its algorithm selection against the power-of-two core size
/// `pof2 = 2^⌊log₂P⌋` (the non-power-of-two fold/unfold adds O(len),
/// ignored at model granularity).
pub fn measured_allreduce_cost(p: f64, len: f64) -> (f64, f64) {
    let logp = p.log2().max(1.0);
    let pof2 = 2.0f64.powf(p.max(1.0).log2().floor());
    if len >= crate::comm::thread::RABENSEIFNER_MIN_WORDS as f64 && len >= pof2 && pof2 >= 2.0 {
        (2.0 * logp, 2.0 * len * (p - 1.0) / p.max(1.0))
    } else {
        (logp, len * logp)
    }
}

/// Closed-form per-rank (messages, words) of one **two-level** allreduce
/// of `len` words over `p` ranks with node size `node_size`, mirroring
/// `comm::expected_two_level_allreduce_sends` at full-node geometries:
/// returns `((leader_msgs, leader_words), (member_msgs, member_words))`.
/// Members send their payload once to the node leader; leaders pay the
/// flat [`measured_allreduce_cost`] over the `⌈P/node_size⌉` leader group
/// plus one fan-out copy per member. On a cluster only the leader-group
/// term crosses the network — the model's account of why the hierarchy
/// wins when intra-node links are cheap.
pub fn two_level_allreduce_cost(p: f64, node_size: f64, len: f64) -> ((f64, f64), (f64, f64)) {
    let ns = node_size.clamp(1.0, p.max(1.0));
    let leaders = (p / ns).ceil();
    let (mut msgs, mut words) = if leaders >= 2.0 {
        measured_allreduce_cost(leaders, len)
    } else {
        (0.0, 0.0)
    };
    let members = ns - 1.0;
    msgs += members;
    words += members * len;
    ((msgs, words), (1.0, len))
}

/// Critical-path costs.
#[derive(Clone, Copy, Debug, Default)]
pub struct AlgoCosts {
    pub flops: f64,
    pub latency: f64,
    pub bandwidth: f64,
    pub memory: f64,
}

impl AlgoCosts {
    /// Instantiate `method` at `cp` under the chosen wire model. The
    /// measured model replaces the Theorems' per-allreduce `O(log P)` /
    /// `O(b²s² log P)` charges for the four (CA-)BCD/BDCD methods with the
    /// calibrated packed-payload collective costs; Krylov and TSQR keep
    /// their survey-level Theory charges (their collectives are not
    /// implemented by this crate's communicator).
    pub fn of_wire(method: Method, cp: &CostParams, wire: Wire) -> AlgoCosts {
        let mut c = AlgoCosts::of(method, cp);
        if wire == Wire::Measured
            && matches!(
                method,
                Method::Bcd | Method::Bdcd | Method::CaBcd | Method::CaBdcd
            )
        {
            let CostParams { p, b, s, h, .. } = *cp;
            let sb = s * b;
            // Packed [G|r]: sb(sb+1)/2 + sb words, H/s collectives.
            let len = sb * (sb + 1.0) / 2.0 + sb;
            let (msgs, words) = measured_allreduce_cost(p, len);
            let collectives = h / s;
            c.latency = collectives * msgs;
            c.bandwidth = collectives * words;
        }
        c
    }

    /// Instantiate the Theorem for `method` at `cp`.
    ///
    /// The primal formulas contract along n, the dual along d — captured by
    /// swapping the roles of (d, n) for the dual methods, exactly as in
    /// Table 1.
    pub fn of(method: Method, cp: &CostParams) -> AlgoCosts {
        let CostParams { d, n, p, b, s, h } = *cp;
        let logp = p.log2().max(1.0);
        match method {
            Method::Bcd => AlgoCosts {
                // Thm. 1: F = O(Hb²n/P + Hb³), L = O(H log P),
                //         W = O(Hb² log P), M = O(dn/P + b²).
                flops: h * b * b * n / p + h * b * b * b,
                latency: h * logp,
                bandwidth: h * b * b * logp,
                memory: d * n / p + b * b,
            },
            Method::Bdcd => AlgoCosts {
                // Thm. 2: same with (d ↔ n), block size b'.
                flops: h * b * b * d / p + h * b * b * b,
                latency: h * logp,
                bandwidth: h * b * b * logp,
                memory: d * n / p + b * b,
            },
            Method::CaBcd => AlgoCosts {
                // Thm. 6: F = O(Hb²ns/P + Hb³), L = O((H/s) log P),
                //         W = O(Hb²s log P), M = O(dn/P + b²s²).
                flops: h * b * b * n * s / p + h * b * b * b,
                latency: (h / s) * logp,
                bandwidth: h * b * b * s * logp,
                memory: d * n / p + b * b * s * s,
            },
            Method::CaBdcd => AlgoCosts {
                // Thm. 7: (d ↔ n).
                flops: h * b * b * d * s / p + h * b * b * b,
                latency: (h / s) * logp,
                bandwidth: h * b * b * s * logp,
                memory: d * n / p + b * b * s * s,
            },
            Method::Krylov => AlgoCosts {
                // Table 2: F = O(k·dn/P), L = O(k log P),
                //          W = O(k·min(d,n)·log P), M = O(dn/P).
                flops: h * d * n / p,
                latency: h * logp,
                bandwidth: h * d.min(n) * logp,
                memory: d * n / p,
            },
            Method::Tsqr => AlgoCosts {
                // Table 2: F = O(min(d,n)²·max(d,n)/P), L = O(log P),
                //          W = O(min(d,n)² log P), M = O(dn/P).
                flops: d.min(n) * d.min(n) * d.max(n) / p,
                latency: logp,
                bandwidth: d.min(n) * d.min(n) * logp,
                memory: d * n / p,
            },
        }
    }

    /// Sequential-cost variant used by the paper's Figures 3/6 (flops
    /// summed over ranks, log P dropped from latency, constants ignored —
    /// see §5.1 "we plot the sequential flops cost ... ignore the log P
    /// factor").
    pub fn sequential(method: Method, cp: &CostParams) -> AlgoCosts {
        let mut one = *cp;
        one.p = 1.0;
        let mut c = AlgoCosts::of(method, &one);
        // log P factor dropped: with p=1 logp clamps to 1 already.
        c.memory = cp.d * cp.n + one.b * one.b * one.s * one.s;
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cp() -> CostParams {
        CostParams {
            d: 1000.0,
            n: 10000.0,
            p: 64.0,
            b: 8.0,
            s: 1.0,
            h: 100.0,
        }
    }

    #[test]
    fn ca_reduces_latency_by_s() {
        let mut p = cp();
        let base = AlgoCosts::of(Method::Bcd, &p);
        p.s = 8.0;
        let ca = AlgoCosts::of(Method::CaBcd, &p);
        assert!((base.latency / ca.latency - 8.0).abs() < 1e-9);
    }

    #[test]
    fn ca_increases_flops_bandwidth_by_s() {
        let mut p = cp();
        let base = AlgoCosts::of(Method::Bcd, &p);
        p.s = 4.0;
        let ca = AlgoCosts::of(Method::CaBcd, &p);
        // dominant term scales by s (the +Hb³ term doesn't, so ratio < s)
        assert!(ca.bandwidth / base.bandwidth == 4.0);
        assert!(ca.flops > base.flops);
        assert!(ca.flops < 4.0 * base.flops + 1.0);
    }

    #[test]
    fn s_equals_one_matches_classical() {
        let p = cp();
        let bcd = AlgoCosts::of(Method::Bcd, &p);
        let ca = AlgoCosts::of(Method::CaBcd, &p);
        assert_eq!(bcd.flops, ca.flops);
        assert_eq!(bcd.latency, ca.latency);
        assert_eq!(bcd.bandwidth, ca.bandwidth);
        assert_eq!(bcd.memory, ca.memory);
    }

    #[test]
    fn dual_swaps_dimensions() {
        let p = cp();
        let bcd = AlgoCosts::of(Method::Bcd, &p);
        let bdcd = AlgoCosts::of(Method::Bdcd, &p);
        // n=10000 vs d=1000: primal flops 10× dual flops (dominant term).
        assert!(bcd.flops > 5.0 * bdcd.flops);
        assert_eq!(bcd.latency, bdcd.latency);
    }

    #[test]
    fn tsqr_single_reduction() {
        let p = cp();
        let t = AlgoCosts::of(Method::Tsqr, &p);
        assert_eq!(t.latency, (64.0f64).log2());
        // min(d,n)² max(d,n) / P
        assert_eq!(t.flops, 1000.0 * 1000.0 * 10000.0 / 64.0);
    }

    #[test]
    fn theory_wire_is_identity() {
        let p = cp();
        for m in [Method::Bcd, Method::CaBcd, Method::Krylov, Method::Tsqr] {
            let a = AlgoCosts::of(m, &p);
            let b = AlgoCosts::of_wire(m, &p, Wire::Theory);
            assert_eq!(a.flops, b.flops);
            assert_eq!(a.latency, b.latency);
            assert_eq!(a.bandwidth, b.bandwidth);
            assert_eq!(a.memory, b.memory);
        }
    }

    #[test]
    fn measured_wire_charges_packed_rabenseifner_words() {
        // sb = 32 → packed payload 32·33/2 + 32 = 560 ≥ crossover at P=64:
        // Rabenseifner moves 2·560·63/64 words per collective, H/s times.
        let mut p = cp();
        p.s = 4.0; // sb = 32
        let c = AlgoCosts::of_wire(Method::CaBcd, &p, Wire::Measured);
        let len = 560.0;
        let expect_w = (p.h / p.s) * 2.0 * len * 63.0 / 64.0;
        let expect_l = (p.h / p.s) * 2.0 * 6.0;
        assert!((c.bandwidth - expect_w).abs() < 1e-9, "{}", c.bandwidth);
        assert!((c.latency - expect_l).abs() < 1e-9, "{}", c.latency);
        // Flops/memory keep the Theorem charge.
        let t = AlgoCosts::of(Method::CaBcd, &p);
        assert_eq!(c.flops, t.flops);
        assert_eq!(c.memory, t.memory);
        // The packed payload beats the Theorems' b²s²·log P charge.
        assert!(c.bandwidth < t.bandwidth);
    }

    #[test]
    fn measured_small_payload_uses_recursive_doubling() {
        // sb = 8 → packed payload 8·9/2 + 8 = 44 < 256 → RD charges.
        let p = cp(); // s = 1, b = 8, P = 64, H = 100
        let c = AlgoCosts::of_wire(Method::Bcd, &p, Wire::Measured);
        assert!((c.latency - 100.0 * 6.0).abs() < 1e-9);
        assert!((c.bandwidth - 100.0 * 44.0 * 6.0).abs() < 1e-9);
    }

    #[test]
    fn two_level_cost_matches_integer_closed_form() {
        // Full-node, power-of-two-leader geometries: the continuous model
        // must agree exactly with the communicator's integer closed form
        // (leader = rank 0, member = rank 1) in both the RD and the
        // Rabenseifner leader-group regimes.
        for (p, ns) in [(4usize, 2usize), (8, 4), (16, 4)] {
            for len in [32usize, 2144] {
                let ((lm, lw), (mm, mw)) =
                    two_level_allreduce_cost(p as f64, ns as f64, len as f64);
                let (elm, elw) = crate::comm::expected_two_level_allreduce_sends(p, ns, 0, len);
                assert_eq!((lm, lw), (elm as f64, elw as f64), "leader p={p} ns={ns} len={len}");
                let (emm, emw) = crate::comm::expected_two_level_allreduce_sends(p, ns, 1, len);
                assert_eq!((mm, mw), (emm as f64, emw as f64), "member p={p} ns={ns} len={len}");
            }
        }
    }

    #[test]
    fn two_level_degenerate_geometries() {
        // ns = 1: every rank is a leader — the hierarchy is the flat cost.
        let ((m, w), _) = two_level_allreduce_cost(8.0, 1.0, 64.0);
        assert_eq!((m, w), measured_allreduce_cost(8.0, 64.0));
        // ns ≥ p: a pure star rooted at rank 0.
        let ((m, w), (mm, mw)) = two_level_allreduce_cost(5.0, 64.0, 10.0);
        assert_eq!((m, w), (4.0, 40.0));
        assert_eq!((mm, mw), (1.0, 10.0));
    }

    #[test]
    fn two_level_leader_group_shrinks_inter_node_messages() {
        // The point of the hierarchy: at P=64, ns=8 the leader group is 8
        // ranks, so the cross-"node" message count drops from log₂64 = 6
        // to log₂8 = 3 (+7 cheap on-node fan-ins) — the model separates
        // the two classes so a cluster profile can weight them.
        let len = 32.0;
        let (flat_msgs, _) = measured_allreduce_cost(64.0, len);
        let ((leader_msgs, _), _) = two_level_allreduce_cost(64.0, 8.0, len);
        assert_eq!(flat_msgs, 6.0);
        assert_eq!(leader_msgs, 3.0 + 7.0);
    }

    #[test]
    fn memory_grows_s_squared() {
        let mut p = cp();
        p.s = 10.0;
        let ca = AlgoCosts::of(Method::CaBcd, &p);
        let expect = 1000.0 * 10000.0 / 64.0 + 64.0 * 100.0;
        assert_eq!(ca.memory, expect);
    }
}
