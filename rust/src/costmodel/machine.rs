//! Machine parameter presets for the α-β-γ model.
//!
//! The paper's §5.2 uses NERSC Cori: γ = 8·10⁻¹³ s/flop, α = 1·10⁻⁶ s per
//! message, β = 1.3·10⁻¹⁰ s/word — and models Spark as the same machine
//! with α = 1·10⁻³ (scheduling/centralization overhead of tree reductions,
//! citing Gittens et al.).

/// α-β-γ machine parameters (seconds).
#[derive(Clone, Copy, Debug)]
pub struct Machine {
    pub name: &'static str,
    /// Seconds per flop (1/peak rate).
    pub gamma: f64,
    /// Seconds of overhead per message (latency).
    pub alpha: f64,
    /// Seconds per word moved (1/bandwidth).
    pub beta: f64,
}

impl Machine {
    /// NERSC Cori, MPI at hardware peak (paper §5.2, citing [1]).
    pub const fn cori_mpi() -> Machine {
        Machine {
            name: "Cori-MPI",
            gamma: 8e-13,
            alpha: 1e-6,
            beta: 1.3e-10,
        }
    }

    /// Cori running Spark: flops/bandwidth unchanged, latency 1000×
    /// (paper's Spark overhead assumption, citing [20]).
    pub const fn cori_spark() -> Machine {
        Machine {
            name: "Cori-Spark",
            gamma: 8e-13,
            alpha: 1e-3,
            beta: 1.3e-10,
        }
    }

    /// Modeled running time of (F flops, L messages, W words).
    pub fn time(&self, f: f64, l: f64, w: f64) -> f64 {
        self.gamma * f + self.alpha * l + self.beta * w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper() {
        let m = Machine::cori_mpi();
        assert_eq!(m.gamma, 8e-13);
        assert_eq!(m.alpha, 1e-6);
        assert_eq!(m.beta, 1.3e-10);
        let s = Machine::cori_spark();
        assert_eq!(s.alpha, 1e-3);
        assert_eq!(s.gamma, m.gamma);
    }

    #[test]
    fn time_is_linear() {
        let m = Machine::cori_mpi();
        assert!((m.time(1.0, 0.0, 0.0) - 8e-13).abs() < 1e-25);
        assert!((m.time(0.0, 2.0, 0.0) - 2e-6).abs() < 1e-18);
        assert!((m.time(0.0, 0.0, 10.0) - 1.3e-9).abs() < 1e-20);
    }
}
