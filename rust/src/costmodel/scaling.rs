//! Modeled strong/weak scaling (paper §5.2, Figures 8 and 9).
//!
//! Strong scaling: fixed global problem (d, n), P swept over 2²…2²⁸;
//! weak scaling: fixed local problem n/P. For each P, the CA curve picks
//! the best `s` from a grid — mirroring the paper's "best speedups we
//! attained were … with s=…" methodology. Per §5.2 the model assumes
//! communication dominates local flops in the parallel setting, so the
//! reported time charges the communication terms αL + βW (each processor
//! "can execute each flop at peak machine rate"; flops per rank are equal
//! by the 1D-column layout and cancel in the speedup).

use super::machine::Machine;
use super::theory::{AlgoCosts, CostParams, Method, Wire};

/// One swept point of a scaling study.
#[derive(Clone, Copy, Debug)]
pub struct ScalingPoint {
    pub p: f64,
    /// Modeled time of the classical algorithm (seconds).
    pub t_classical: f64,
    /// Modeled time of the CA variant at its best s.
    pub t_ca: f64,
    /// The s that minimized the CA time.
    pub best_s: f64,
    pub speedup: f64,
}

/// A full sweep plus its headline (max) speedup.
#[derive(Clone, Debug)]
pub struct ScalingSeries {
    pub machine: &'static str,
    pub points: Vec<ScalingPoint>,
}

impl ScalingSeries {
    pub fn max_speedup(&self) -> (f64, f64, f64) {
        self.points
            .iter()
            .map(|pt| (pt.speedup, pt.p, pt.best_s))
            .fold((0.0, 0.0, 0.0), |acc, v| if v.0 > acc.0 { v } else { acc })
    }
}

/// Modeled time of `method` at `cp` on `m`, charging γF/P-peak flops plus
/// the communication critical path under the chosen wire model.
fn modeled_time(m: &Machine, method: Method, cp: &CostParams, wire: Wire) -> f64 {
    let c = AlgoCosts::of_wire(method, cp, wire);
    m.time(c.flops, c.latency, c.bandwidth)
}

/// Best-s CA time over a geometric s grid (1..=max_s).
fn best_ca_time(m: &Machine, cp: &CostParams, max_s: usize, wire: Wire) -> (f64, f64) {
    let mut best = (f64::INFINITY, 1.0);
    let mut s = 1.0f64;
    while s <= max_s as f64 {
        let mut c = *cp;
        c.s = s;
        let t = modeled_time(m, Method::CaBcd, &c, wire);
        if t < best.0 {
            best = (t, s);
        }
        // fine grid at small s, geometric afterwards
        s = if s < 16.0 { s + 1.0 } else { (s * 1.25).ceil() };
    }
    best
}

/// Figure 8: strong scaling of BCD vs CA-BCD (Theorem wire charges).
pub fn strong_scaling(
    m: &Machine,
    d: f64,
    n: f64,
    b: f64,
    h: f64,
    p_range: &[f64],
    max_s: usize,
) -> ScalingSeries {
    strong_scaling_wire(m, Wire::Theory, d, n, b, h, p_range, max_s)
}

/// Strong scaling under an explicit wire model — `Wire::Measured` charges
/// the packed `sb(sb+1)/2 + sb` payload through the calibrated
/// RD/Rabenseifner collective costs (the measured-machine mode of the
/// ROADMAP's cost-model-calibration item). Note the calibration tightens
/// the classical (s=1) bandwidth charge only for `b ≥ 3`, where
/// `b(b+1)/2 + b ≤ b²`; at b ≤ 2 the `+b` residual term exceeds the
/// Theorems' `b²` words-per-allreduce.
#[allow(clippy::too_many_arguments)]
pub fn strong_scaling_wire(
    m: &Machine,
    wire: Wire,
    d: f64,
    n: f64,
    b: f64,
    h: f64,
    p_range: &[f64],
    max_s: usize,
) -> ScalingSeries {
    let points = p_range
        .iter()
        .map(|&p| {
            let cp = CostParams { d, n, p, b, s: 1.0, h };
            let t_classical = modeled_time(m, Method::Bcd, &cp, wire);
            let (t_ca, best_s) = best_ca_time(m, &cp, max_s, wire);
            ScalingPoint {
                p,
                t_classical,
                t_ca,
                best_s,
                speedup: t_classical / t_ca,
            }
        })
        .collect();
    ScalingSeries {
        machine: m.name,
        points,
    }
}

/// Figure 9: weak scaling — n = n_per_p · P (Theorem wire charges).
pub fn weak_scaling(
    m: &Machine,
    d: f64,
    n_per_p: f64,
    b: f64,
    h: f64,
    p_range: &[f64],
    max_s: usize,
) -> ScalingSeries {
    weak_scaling_wire(m, Wire::Theory, d, n_per_p, b, h, p_range, max_s)
}

/// Weak scaling under an explicit wire model (see
/// [`strong_scaling_wire`]).
#[allow(clippy::too_many_arguments)]
pub fn weak_scaling_wire(
    m: &Machine,
    wire: Wire,
    d: f64,
    n_per_p: f64,
    b: f64,
    h: f64,
    p_range: &[f64],
    max_s: usize,
) -> ScalingSeries {
    let points = p_range
        .iter()
        .map(|&p| {
            let cp = CostParams {
                d,
                n: n_per_p * p,
                p,
                b,
                s: 1.0,
                h,
            };
            let t_classical = modeled_time(m, Method::Bcd, &cp, wire);
            let (t_ca, best_s) = best_ca_time(m, &cp, max_s, wire);
            ScalingPoint {
                p,
                t_classical,
                t_ca,
                best_s,
                speedup: t_classical / t_ca,
            }
        })
        .collect();
    ScalingSeries {
        machine: m.name,
        points,
    }
}

/// The paper's P sweep: 2², 2³, …, 2²⁸.
pub fn paper_p_range() -> Vec<f64> {
    (2..=28).map(|e| (1u64 << e) as f64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strong_scaling_speedup_grows_with_p() {
        let m = Machine::cori_mpi();
        let pr = paper_p_range();
        let ss = strong_scaling(&m, 1024.0, (1u64 << 35) as f64, 4.0, 100.0, &pr, 1000);
        // At small P flops dominate → s=1 is best, speedup ≈ 1.
        assert!(ss.points[0].speedup < 1.05);
        assert!((ss.points[0].best_s - 1.0).abs() < 1e-9);
        // At large P latency dominates → CA wins big.
        let last = ss.points.last().unwrap();
        assert!(last.speedup > 5.0, "speedup {}", last.speedup);
        let (mx, _, _) = ss.max_speedup();
        assert!(mx >= last.speedup * 0.99);
    }

    #[test]
    fn spark_speedup_exceeds_mpi() {
        let pr = paper_p_range();
        let mpi = strong_scaling(
            &Machine::cori_mpi(),
            1024.0,
            (1u64 << 35) as f64,
            4.0,
            100.0,
            &pr,
            1000,
        );
        let spark = strong_scaling(
            &Machine::cori_spark(),
            1024.0,
            (1u64 << 40) as f64,
            4.0,
            100.0,
            &pr,
            1000,
        );
        assert!(spark.max_speedup().0 > mpi.max_speedup().0);
    }

    #[test]
    fn weak_scaling_ca_always_at_least_classical() {
        let m = Machine::cori_spark();
        let pr = paper_p_range();
        let ws = weak_scaling(&m, 1024.0, 2048.0, 4.0, 100.0, &pr, 1000);
        for pt in &ws.points {
            assert!(pt.speedup >= 1.0 - 1e-12, "P={}: {}", pt.p, pt.speedup);
        }
    }

    #[test]
    fn measured_wire_still_rewards_ca_and_charges_less_bandwidth() {
        let m = Machine::cori_mpi();
        let pr = paper_p_range();
        let theory = strong_scaling(&m, 1024.0, (1u64 << 35) as f64, 4.0, 100.0, &pr, 1000);
        let measured = strong_scaling_wire(
            &m,
            Wire::Measured,
            1024.0,
            (1u64 << 35) as f64,
            4.0,
            100.0,
            &pr,
            1000,
        );
        // CA still wins in the communication-dominated tail…
        assert!(measured.points.last().unwrap().speedup > 2.0);
        // …while each point's classical time is charged no MORE wire than
        // the Theorems' b²·log P upper bound. (Holds at b = 4 since
        // b(b+1)/2 + b = 14 ≤ 16 = b²; at b ≤ 2 the +b residual term
        // tips the other way — see strong_scaling_wire's doc.)
        for (t, ms) in theory.points.iter().zip(&measured.points) {
            assert!(
                ms.t_classical <= t.t_classical * (1.0 + 1e-12),
                "P={}: measured {} > theory {}",
                ms.p,
                ms.t_classical,
                t.t_classical
            );
        }
    }
}
