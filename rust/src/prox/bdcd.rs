//! CA-Prox-BDCD — proximal dual block coordinate descent with the s-step
//! communication-avoiding unrolling.
//!
//! Mirrors [`crate::solvers::bdcd`] exactly on layout, sampling, the Gram
//! engine, and the one packed `[G|r]` allreduce per outer iteration; the
//! inner solve is [`crate::prox::solve::ca_prox_dual_inner_solve`] —
//! Lipschitz-scaled gradient steps on the dual objective
//! `D(α) = (1/(2λn²))‖Xα‖² + (1/(2n))‖α‖² + (1/n)yᵀα + ψ(α)` with the
//! regularizer's separable prox applied to the **dual** vector. This is
//! the seam box-constraint workloads (SVM hinge) and sparse-dual losses
//! plug into; `Reg::None` shares the classical BDCD fixed points (same
//! ridge solution, first-order instead of Newton steps).
//!
//! The loop lives in the shared pipeline core ([`crate::engine::drive`]);
//! like [`crate::prox::bcd`], `--overlap` now runs the engine's
//! **prefetch schedule** — the next iteration's Gram is computed under
//! the in-flight `[G|r]` reduction (previously only the tensor/gather
//! work was hidden; ROADMAP item closed by the engine port). Bitwise
//! identical trajectory, still exactly H/s collectives.
//!
//! Records are [`ProxRecord`]s over the dual iterate: penalized dual
//! objective, min-norm subgradient residual, and nnz(α). The Fenchel gap
//! field is `NaN` here — the primal-side certificate lives in
//! [`crate::prox::bcd`] (one record costs a meter-excluded `(n+1)`-word
//! allreduce).

use crate::comm::Communicator;
use crate::engine::{drive, CaStep, Checkpoint, Sample};
use crate::error::Result;
use crate::gram::ComputeBackend;
use crate::linalg::packed::packed_len;
use crate::matrix::Matrix;
use crate::metrics::{History, ProxRecord};
use crate::prox::{Reg, Regularizer};
use crate::sampling::{overlap_tensor_into, BlockSampler};
use crate::solvers::common::{metered_out, DualOutput, SolverOpts};

/// Run CA-Prox-BDCD on this rank's shard (layout contract of
/// [`crate::solvers::bdcd::run`]: `a_loc` is the `n × d_loc` feature
/// slice of `A = Xᵀ`, `y` and α replicated, `w_loc` partitioned). This is
/// the engine entry the [`Session`](crate::engine::Session) dispatches to
/// for non-L2 regularizers on the matched dual layout.
pub fn run<C: Communicator>(
    a_loc: &Matrix,
    y: &[f64],
    d_global: usize,
    d_offset: usize,
    opts: &SolverOpts,
    comm: &mut C,
    backend: &mut dyn ComputeBackend,
) -> Result<DualOutput> {
    let n = a_loc.rows();
    let d_loc = a_loc.cols();
    opts.validate(n)?;
    let (s, b) = (opts.s, opts.b);
    let sb = s * b;
    let mut history = History::default();
    let mut step = ProxBdcdStep {
        a_loc,
        y,
        backend,
        s,
        b,
        lam: opts.lam,
        inv_n: 1.0 / n as f64,
        w_scale: -1.0 / (opts.lam * n as f64),
        gl: packed_len(sb),
        reg: opts.reg,
        sampler: BlockSampler::new(n, opts.seed),
        alpha: vec![0.0; n],
        w_loc: vec![0.0; d_loc],
        a_blocks: vec![0.0; sb],
        y_blocks: vec![0.0; sb],
        scaled_deltas: vec![0.0; sb],
        overlap: vec![0.0; s * s * b * b],
    };
    drive(&mut step, opts, comm, &mut history)?;
    let w_full = metered_out(comm, |c| {
        let mut full = vec![0.0; d_global];
        full[d_offset..d_offset + step.w_loc.len()].copy_from_slice(&step.w_loc);
        c.allreduce_sum(&mut full)?;
        Ok(full)
    })?;
    Ok(DualOutput {
        w_loc: step.w_loc,
        w_full,
        alpha: step.alpha,
        history,
    })
}

/// The proximal dual method's per-iteration callbacks — identical to
/// [`BdcdStep`](crate::solvers::bdcd) except for the prox inner solve and
/// the dual certificate records.
struct ProxBdcdStep<'a> {
    a_loc: &'a Matrix,
    y: &'a [f64],
    backend: &'a mut dyn ComputeBackend,
    s: usize,
    b: usize,
    lam: f64,
    inv_n: f64,
    /// `−1/(λn)` precomputed with the classical loop's exact expression.
    w_scale: f64,
    gl: usize,
    reg: Reg,
    sampler: BlockSampler,
    alpha: Vec<f64>,
    w_loc: Vec<f64>,
    a_blocks: Vec<f64>,
    y_blocks: Vec<f64>,
    scaled_deltas: Vec<f64>,
    overlap: Vec<f64>,
}

impl<C: Communicator> CaStep<C> for ProxBdcdStep<'_> {
    fn payload_split(&self) -> (usize, usize) {
        (self.gl, self.s * self.b)
    }

    fn prefetch_gram(&self) -> bool {
        true
    }

    fn sample(&mut self, _comm: &mut C, k: usize) -> Result<Sample> {
        Ok(Sample::flatten(
            k,
            self.sampler.draw_blocks(self.s, self.b),
            self.b,
        ))
    }

    fn local_gram(&mut self, _comm: &mut C, smp: &Sample, head: &mut [f64]) -> Result<()> {
        // G = A[J,:]A[J,:]ᵀ (packed partial).
        self.backend.gram_only(self.a_loc, &smp.idx, head)
    }

    fn local_state(&mut self, smp: &Sample, tail: &mut [f64]) -> Result<()> {
        // r = A[J,:]·w_loc into the payload tail.
        self.backend
            .resid_only(self.a_loc, &smp.idx, &self.w_loc, tail)
    }

    fn local_payload(
        &mut self,
        _comm: &mut C,
        smp: &Sample,
        head: &mut [f64],
        tail: &mut [f64],
    ) -> Result<()> {
        // Same-iteration gram + residual: one fused backend call, like
        // the pre-engine blocking loop.
        self.backend
            .gram_resid(self.a_loc, &smp.idx, &self.w_loc, head, tail)
    }

    fn hidden_work(&mut self, smp: &Sample) -> Result<()> {
        overlap_tensor_into(&smp.blocks, &mut self.overlap);
        for (j, blk) in smp.blocks.iter().enumerate() {
            for (i, &row) in blk.iter().enumerate() {
                self.a_blocks[j * self.b + i] = self.alpha[row];
                self.y_blocks[j * self.b + i] = self.y[row];
            }
        }
        Ok(())
    }

    fn cond_probe(&self) -> Option<(f64, f64)> {
        // Θ-scale conditioning, same quantity as the smooth dual solver
        // (Figs. 7i–l): (1/(λn²))·G + (1/n)I.
        Some((self.inv_n * self.inv_n / self.lam, self.inv_n))
    }

    fn inner_solve(&mut self, smp: &Sample, head: &[f64], tail: &[f64]) -> Result<Vec<f64>> {
        // Replicated dual prox solve (ProxStep span nests inside the
        // engine's InnerSolve span).
        let t0 = crate::trace::now();
        let out = self.backend.ca_prox_dual_inner_solve(
            self.s,
            self.b,
            head,
            tail,
            &self.a_blocks,
            &self.y_blocks,
            &self.overlap,
            self.lam,
            self.inv_n,
            &self.reg,
        );
        crate::trace::record(
            crate::trace::SpanKind::ProxStep,
            crate::trace::OpClass::Compute,
            smp.k as u64,
            (head.len() + tail.len()) as u64,
            t0,
        );
        out
    }

    fn apply(&mut self, smp: &Sample, deltas: &[f64]) -> Result<()> {
        for (j, blk) in smp.blocks.iter().enumerate() {
            for (i, &row) in blk.iter().enumerate() {
                self.alpha[row] += deltas[j * self.b + i];
            }
        }
        for (sd, &dv) in self.scaled_deltas.iter_mut().zip(deltas) {
            *sd = self.w_scale * dv;
        }
        self.backend
            .alpha_update(self.a_loc, &smp.idx, &self.scaled_deltas, &mut self.w_loc)
    }

    fn record(&mut self, comm: &mut C, history: &mut History, h_now: usize) -> Result<()> {
        record(
            history,
            h_now,
            &self.alpha,
            &self.w_loc,
            self.y,
            self.a_loc,
            self.lam,
            &self.reg,
            comm,
        )
    }

    fn converged(&self, history: &History, tol: f64) -> bool {
        history.prox.last().is_some_and(|r| r.subgrad <= tol)
    }

    fn ckpt_kind(&self) -> &'static str {
        "prox_bdcd"
    }

    fn save_state(&self, ckpt: &mut Checkpoint) -> Result<()> {
        // Same state set as the smooth dual step: sampler RNG + dual
        // iterate + this rank's w slice (the block gathers and the
        // overlap tensor are per-iteration scratch).
        ckpt.rng = self.sampler.rng_state().to_vec();
        ckpt.push_f64("alpha", &self.alpha);
        ckpt.push_f64("w_loc", &self.w_loc);
        Ok(())
    }

    fn restore_state(&mut self, ckpt: &Checkpoint) -> Result<()> {
        self.sampler.set_rng_state(ckpt.rng_words()?);
        ckpt.read_f64_into("alpha", &mut self.alpha)?;
        ckpt.read_f64_into("w_loc", &mut self.w_loc)
    }
}

/// Meter-excluded dual certificate: one `(n+1)`-word allreduce gathers
/// `[A·w | ‖w_loc‖²]`, giving the smooth dual gradient
/// `∇D(α) = (−Xᵀw + α + y)/n` and `‖Xα‖²/(2λn²) = (λ/2)‖w‖²` without a
/// second pass over the data.
#[allow(clippy::too_many_arguments)]
fn record<C: Communicator>(
    history: &mut History,
    iter: usize,
    alpha: &[f64],
    w_loc: &[f64],
    y: &[f64],
    a_loc: &Matrix,
    lam: f64,
    reg: &Reg,
    comm: &mut C,
) -> Result<()> {
    let n = a_loc.rows();
    let payload = metered_out(comm, |c| {
        let mut payload = vec![0.0; n + 1];
        a_loc.matvec(w_loc, &mut payload[..n])?;
        payload[n] = w_loc.iter().map(|v| v * v).sum();
        c.allreduce_sum(&mut payload)?;
        Ok(payload)
    })?;
    let w_norm_sq = payload[n];
    let nf = n as f64;
    let mut smooth = 0.5 * lam * w_norm_sq; // (1/(2λn²))‖Xα‖²
    let mut grad = vec![0.0; n];
    for i in 0..n {
        smooth += alpha[i] * alpha[i] / (2.0 * nf) + y[i] * alpha[i] / nf;
        grad[i] = (-payload[i] + alpha[i] + y[i]) / nf;
    }
    history.prox.push(ProxRecord {
        iter,
        pen_obj: smooth + reg.penalty(alpha, lam),
        gap: f64::NAN,
        subgrad: reg.subgrad_residual(&grad, alpha, lam),
        nnz: Reg::nnz(alpha),
    });
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::SerialComm;
    use crate::gram::NativeBackend;
    use crate::matrix::DenseMatrix;
    use crate::solvers::bdcd;

    fn toy() -> (Matrix, Vec<f64>) {
        let mut st = 321u64;
        let data: Vec<f64> = (0..5 * 30)
            .map(|_| {
                st ^= st << 13;
                st ^= st >> 7;
                st ^= st << 17;
                (st as f64 / u64::MAX as f64) - 0.5
            })
            .collect();
        let x = Matrix::Dense(DenseMatrix::from_vec(5, 30, data));
        let mut y = vec![0.0; 30];
        x.matvec_t(&[0.5; 5], &mut y).unwrap();
        (x, y)
    }

    /// Prox-BDCD with Reg::None shares the classical BDCD fixed point: it
    /// must converge to the same ridge solution (first-order steps, so
    /// compare solutions, not trajectories).
    #[test]
    fn none_reg_converges_to_bdcd_solution() {
        let (x, y) = toy();
        let a = x.transpose();
        let lam = 0.2;
        let exact = SolverOpts {
            b: 4,
            s: 1,
            lam,
            iters: 4000,
            seed: 2,
            record_every: 0,
            ..Default::default()
        };
        let mut comm = SerialComm::new();
        let mut be = NativeBackend::new();
        let w_exact = bdcd::run(&a, &y, 5, 0, &exact, None, &mut comm, &mut be)
            .unwrap()
            .w_full;
        let prox_opts = SolverOpts {
            iters: 40000,
            reg: Reg::None,
            ..exact
        };
        let out = run(&a, &y, 5, 0, &prox_opts, &mut comm, &mut be).unwrap();
        for (p, q) in out.w_full.iter().zip(&w_exact) {
            assert!((p - q).abs() < 1e-6, "{p} vs {q}");
        }
    }

    #[test]
    fn dual_prox_overlap_is_bitwise_identical_serial() {
        let (x, y) = toy();
        let a = x.transpose();
        let mut opts = SolverOpts {
            b: 3,
            s: 4,
            lam: 0.2,
            iters: 40,
            seed: 6,
            record_every: 0,
            reg: Reg::L1,
            ..Default::default()
        };
        let mut comm = SerialComm::new();
        let mut be = NativeBackend::new();
        let w1 = run(&a, &y, 5, 0, &opts, &mut comm, &mut be).unwrap().w_full;
        opts.overlap = true;
        let w2 = run(&a, &y, 5, 0, &opts, &mut comm, &mut be).unwrap().w_full;
        assert_eq!(w1, w2, "overlap changed the dual prox trajectory");
    }
}
