//! CA-Prox-BDCD — proximal dual block coordinate descent with the s-step
//! communication-avoiding unrolling.
//!
//! Mirrors [`crate::solvers::bdcd`] exactly on layout, sampling, the Gram
//! engine, and the one packed `[G|r]` allreduce per outer iteration; the
//! inner solve is [`crate::prox::solve::ca_prox_dual_inner_solve`] —
//! Lipschitz-scaled gradient steps on the dual objective
//! `D(α) = (1/(2λn²))‖Xα‖² + (1/(2n))‖α‖² + (1/n)yᵀα + ψ(α)` with the
//! regularizer's separable prox applied to the **dual** vector. This is
//! the seam box-constraint workloads (SVM hinge) and sparse-dual losses
//! plug into; `Reg::None` shares the classical BDCD fixed points (same
//! ridge solution, first-order instead of Newton steps). Like
//! [`crate::prox::bcd`], `overlap` hides only the tensor/gather work —
//! the smooth solvers' Gram-prefetch pipeline is a ROADMAP follow-on.
//!
//! Records are [`ProxRecord`]s over the dual iterate: penalized dual
//! objective, min-norm subgradient residual, and nnz(α). The Fenchel gap
//! field is `NaN` here — the primal-side certificate lives in
//! [`crate::prox::bcd`] (one record costs a meter-excluded `(n+1)`-word
//! allreduce).

use crate::comm::Communicator;
use crate::error::Result;
use crate::gram::ComputeBackend;
use crate::linalg::packed::packed_len;
use crate::matrix::Matrix;
use crate::metrics::{History, ProxRecord};
use crate::prox::{Reg, Regularizer};
use crate::sampling::{overlap_tensor_into, BlockSampler};
use crate::solvers::common::{
    cond_stride, flatten_blocks, metered_out, packed_gram_cond, should_record, DualOutput,
    SolverOpts,
};

/// Run CA-Prox-BDCD on this rank's shard (layout contract of
/// [`crate::solvers::bdcd::run`]: `a_loc` is the `n × d_loc` feature
/// slice of `A = Xᵀ`, `y` and α replicated, `w_loc` partitioned).
pub fn run<C: Communicator>(
    a_loc: &Matrix,
    y: &[f64],
    d_global: usize,
    d_offset: usize,
    opts: &SolverOpts,
    comm: &mut C,
    backend: &mut dyn ComputeBackend,
) -> Result<DualOutput> {
    let n = a_loc.rows();
    let d_loc = a_loc.cols();
    opts.validate(n)?;
    let (s, b) = (opts.s, opts.b);
    let sb = s * b;
    let gl = packed_len(sb);
    let inv_n = 1.0 / n as f64;
    let lam = opts.lam;
    let reg = opts.reg;

    let mut alpha = vec![0.0; n];
    let mut w_loc = vec![0.0; d_loc];
    let mut history = History::default();

    let mut buf = vec![0.0; gl + sb]; // packed [G | r] allreduce payload
    let mut a_blocks = vec![0.0; sb];
    let mut y_blocks = vec![0.0; sb];
    let mut gram_scaled = vec![0.0; sb * sb];
    let mut idx_flat = vec![0usize; sb];
    let mut scaled_deltas = vec![0.0; sb];
    let mut overlap = vec![0.0; s * s * b * b];

    let mut sampler = BlockSampler::new(n, opts.seed);

    record(&mut history, 0, &alpha, &w_loc, y, a_loc, lam, &reg, comm)?;

    let outer = opts.outer_iters();
    let stride = cond_stride(sb, outer);
    'outer_loop: for k in 0..outer {
        let blocks = sampler.draw_blocks(s, b);
        flatten_blocks(&blocks, b, &mut idx_flat);

        // Raw partial [G | r]: G = A[J,:]A[J,:]ᵀ, r = A[J,:]·w_loc.
        {
            let (g_buf, r_buf) = buf.split_at_mut(gl);
            backend.gram_resid(a_loc, &idx_flat, &w_loc, g_buf, r_buf)?;
        }

        // THE communication of this outer iteration.
        if opts.overlap {
            let handle = comm.iallreduce_start(std::mem::take(&mut buf))?;
            overlap_tensor_into(&blocks, &mut overlap);
            gather_blocks(&blocks, b, &alpha, y, &mut a_blocks, &mut y_blocks);
            buf = comm.iallreduce_wait(handle)?;
        } else {
            comm.allreduce_sum(&mut buf)?;
            overlap_tensor_into(&blocks, &mut overlap);
            gather_blocks(&blocks, b, &alpha, y, &mut a_blocks, &mut y_blocks);
        }

        if opts.track_gram_cond && k % stride == 0 {
            // Θ-scale conditioning, same quantity as the smooth dual
            // solver (Figs. 7i–l): (1/(λn²))·G + (1/n)I.
            history.gram_conds.push(packed_gram_cond(
                &buf,
                sb,
                inv_n * inv_n / lam,
                inv_n,
                &mut gram_scaled,
            ));
        }

        // Replicated dual prox solve + deferred updates.
        let (g_buf, r_buf) = buf.split_at(gl);
        let deltas = backend.ca_prox_dual_inner_solve(
            s, b, g_buf, r_buf, &a_blocks, &y_blocks, &overlap, lam, inv_n, &reg,
        )?;
        for (j, blk) in blocks.iter().enumerate() {
            for (i, &row) in blk.iter().enumerate() {
                alpha[row] += deltas[j * b + i];
            }
        }
        let scale = -1.0 / (lam * n as f64);
        for (sd, &dv) in scaled_deltas.iter_mut().zip(&deltas) {
            *sd = scale * dv;
        }
        backend.alpha_update(a_loc, &idx_flat, &scaled_deltas, &mut w_loc)?;

        let h_now = (k + 1) * s;
        history.iters = h_now;
        if should_record(h_now, s, opts) || k + 1 == outer {
            record(&mut history, h_now, &alpha, &w_loc, y, a_loc, lam, &reg, comm)?;
            if let Some(tol) = opts.tol {
                if history.prox.last().is_some_and(|r| r.subgrad <= tol) {
                    break 'outer_loop;
                }
            }
        }
    }

    history.meter = *comm.meter();
    let w_full = metered_out(comm, |c| {
        let mut full = vec![0.0; d_global];
        full[d_offset..d_offset + w_loc.len()].copy_from_slice(&w_loc);
        c.allreduce_sum(&mut full)?;
        Ok(full)
    })?;
    Ok(DualOutput {
        w_loc,
        w_full,
        alpha,
        history,
    })
}

fn gather_blocks(
    blocks: &[Vec<usize>],
    b: usize,
    alpha: &[f64],
    y: &[f64],
    a_blocks: &mut [f64],
    y_blocks: &mut [f64],
) {
    for (j, blk) in blocks.iter().enumerate() {
        for (i, &row) in blk.iter().enumerate() {
            a_blocks[j * b + i] = alpha[row];
            y_blocks[j * b + i] = y[row];
        }
    }
}

/// Meter-excluded dual certificate: one `(n+1)`-word allreduce gathers
/// `[A·w | ‖w_loc‖²]`, giving the smooth dual gradient
/// `∇D(α) = (−Xᵀw + α + y)/n` and `‖Xα‖²/(2λn²) = (λ/2)‖w‖²` without a
/// second pass over the data.
#[allow(clippy::too_many_arguments)]
fn record<C: Communicator>(
    history: &mut History,
    iter: usize,
    alpha: &[f64],
    w_loc: &[f64],
    y: &[f64],
    a_loc: &Matrix,
    lam: f64,
    reg: &Reg,
    comm: &mut C,
) -> Result<()> {
    let n = a_loc.rows();
    let payload = metered_out(comm, |c| {
        let mut payload = vec![0.0; n + 1];
        a_loc.matvec(w_loc, &mut payload[..n])?;
        payload[n] = w_loc.iter().map(|v| v * v).sum();
        c.allreduce_sum(&mut payload)?;
        Ok(payload)
    })?;
    let w_norm_sq = payload[n];
    let nf = n as f64;
    let mut smooth = 0.5 * lam * w_norm_sq; // (1/(2λn²))‖Xα‖²
    let mut grad = vec![0.0; n];
    for i in 0..n {
        smooth += alpha[i] * alpha[i] / (2.0 * nf) + y[i] * alpha[i] / nf;
        grad[i] = (-payload[i] + alpha[i] + y[i]) / nf;
    }
    history.prox.push(ProxRecord {
        iter,
        pen_obj: smooth + reg.penalty(alpha, lam),
        gap: f64::NAN,
        subgrad: reg.subgrad_residual(&grad, alpha, lam),
        nnz: Reg::nnz(alpha),
    });
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::SerialComm;
    use crate::gram::NativeBackend;
    use crate::matrix::DenseMatrix;
    use crate::solvers::bdcd;

    fn toy() -> (Matrix, Vec<f64>) {
        let mut st = 321u64;
        let data: Vec<f64> = (0..5 * 30)
            .map(|_| {
                st ^= st << 13;
                st ^= st >> 7;
                st ^= st << 17;
                (st as f64 / u64::MAX as f64) - 0.5
            })
            .collect();
        let x = Matrix::Dense(DenseMatrix::from_vec(5, 30, data));
        let mut y = vec![0.0; 30];
        x.matvec_t(&[0.5; 5], &mut y).unwrap();
        (x, y)
    }

    /// Prox-BDCD with Reg::None shares the classical BDCD fixed point: it
    /// must converge to the same ridge solution (first-order steps, so
    /// compare solutions, not trajectories).
    #[test]
    fn none_reg_converges_to_bdcd_solution() {
        let (x, y) = toy();
        let a = x.transpose();
        let lam = 0.2;
        let exact = SolverOpts {
            b: 4,
            s: 1,
            lam,
            iters: 4000,
            seed: 2,
            record_every: 0,
            ..Default::default()
        };
        let mut comm = SerialComm::new();
        let mut be = NativeBackend::new();
        let w_exact = bdcd::run(&a, &y, 5, 0, &exact, None, &mut comm, &mut be)
            .unwrap()
            .w_full;
        let prox_opts = SolverOpts {
            iters: 40000,
            reg: Reg::None,
            ..exact
        };
        let out = run(&a, &y, 5, 0, &prox_opts, &mut comm, &mut be).unwrap();
        for (p, q) in out.w_full.iter().zip(&w_exact) {
            assert!((p - q).abs() < 1e-6, "{p} vs {q}");
        }
    }

    #[test]
    fn dual_prox_overlap_is_bitwise_identical_serial() {
        let (x, y) = toy();
        let a = x.transpose();
        let mut opts = SolverOpts {
            b: 3,
            s: 4,
            lam: 0.2,
            iters: 40,
            seed: 6,
            record_every: 0,
            reg: Reg::L1,
            ..Default::default()
        };
        let mut comm = SerialComm::new();
        let mut be = NativeBackend::new();
        let w1 = run(&a, &y, 5, 0, &opts, &mut comm, &mut be).unwrap().w_full;
        opts.overlap = true;
        let w2 = run(&a, &y, 5, 0, &opts, &mut comm, &mut be).unwrap().w_full;
        assert_eq!(w1, w2, "overlap changed the dual prox trajectory");
    }
}
