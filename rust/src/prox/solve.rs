//! Prox-aware s-step inner solves (the non-smooth twin of
//! [`crate::gram::ComputeBackend::ca_inner_solve`] /
//! [`crate::gram::ComputeBackend::ca_dual_inner_solve`]).
//!
//! Consumes the **same packed-triangle `[G|r]` payload** the smooth
//! solvers allreduce — the s-step recurrence needs nothing else, which is
//! why CA-Prox-BCD/BDCD communicate exactly H/s collectives of the
//! unchanged `sb(sb+1)/2 + sb` wire format (arXiv:1712.06047 carries the
//! paper's Gram-unrolling argument to the proximal setting).
//!
//! Per deferred step `j`, the state *as it would be after steps
//! `0..j` of the classical prox method* is reconstructed with zero
//! communication:
//!
//! * current residual  `r_j ← r_raw_j − Σ_{t<j} G[j,t] Δ_t`
//!   (sub-diagonal block rows of the packed triangle — contiguous runs),
//! * current iterate   `w_j ← w_blocks_j + Σ_{t<j} O[j,t] Δ_t`
//!   (the shared-seed overlap tensor handles duplicate coordinates),
//!
//! then one proximal-gradient step with the block Lipschitz bound
//! `L_j = ‖(1/n)·G[j,j]‖_∞` (row-sum norm ≥ λ_max for symmetric PSD):
//!
//! `w⁺ = prox_{ψ/L_j}( w_j − (1/L_j)·∇f_smooth(w_j) )`, elementwise.
//!
//! For `b = 1` the step is the **exact** coordinate minimizer (the
//! textbook soft-threshold CD update for the lasso); for `b > 1` it is
//! block proximal gradient (Beck–Tetruashvili), monotone under the L_j
//! bound. Because every step is a deterministic function of `(G, r,
//! w_blocks, overlap)`, trajectories are **s-invariant to fp tolerance**
//! exactly like the smooth CA recurrence (asserted in
//! `rust/tests/prox.rs`).

use crate::error::Result;
use crate::linalg::packed::{packed_len, pidx, tri_row};
use crate::prox::{Reg, Regularizer};

/// Primal prox s-step inner solve. `g_raw` is the allreduced packed
/// triangle, `r_raw = Σ_ranks Y(y − α)` raw, `w_blocks` the iterate at the
/// sampled coordinates gathered at the outer-iteration start, `overlap`
/// the `(s, s, b, b)` block-overlap tensor. Returns the flat `(s·b)` Δw.
#[allow(clippy::too_many_arguments)]
pub fn ca_prox_inner_solve(
    s: usize,
    b: usize,
    g_raw: &[f64],
    r_raw: &[f64],
    w_blocks: &[f64],
    overlap: &[f64],
    lam: f64,
    inv_n: f64,
    reg: &Reg,
) -> Result<Vec<f64>> {
    let sb = s * b;
    debug_assert_eq!(g_raw.len(), packed_len(sb));
    debug_assert_eq!(r_raw.len(), sb);
    let mut deltas = vec![0.0; sb];
    let mut w_cur = vec![0.0; b];
    let mut r_cur = vec![0.0; b];
    for j in 0..s {
        w_cur.copy_from_slice(&w_blocks[j * b..(j + 1) * b]);
        r_cur.copy_from_slice(&r_raw[j * b..(j + 1) * b]);
        // Deferred-state reconstruction from the strictly-lower block rows
        // (contiguous in the packed triangle) and the overlap tensor.
        for t in 0..j {
            let ov = &overlap[(j * s + t) * b * b..(j * s + t + 1) * b * b];
            let dt = &deltas[t * b..(t + 1) * b];
            for i in 0..b {
                let base = tri_row(j * b + i);
                let grow = &g_raw[base + t * b..base + (t + 1) * b];
                let orow = &ov[i * b..(i + 1) * b];
                let mut gacc = 0.0;
                let mut oacc = 0.0;
                for c in 0..b {
                    gacc += grow[c] * dt[c];
                    oacc += orow[c] * dt[c];
                }
                r_cur[i] -= gacc;
                w_cur[i] += oacc;
            }
        }
        // Block Lipschitz bound of the smooth data term (1/n)·G[j,j]:
        // the ∞-norm row sum dominates λ_max for a symmetric PSD block.
        let mut lip = 0.0f64;
        for i in 0..b {
            let mut row_sum = 0.0;
            for c in 0..b {
                row_sum += (inv_n * g_raw[pidx(j * b + i, j * b + c)]).abs();
            }
            lip = lip.max(row_sum);
        }
        if lip > 0.0 {
            let eta = 1.0 / lip;
            for i in 0..b {
                // Smooth data-term gradient at the reconstructed iterate:
                // ∇f(w)_i = −(1/n)·r_cur[i] (the μ₂ ridge component lives
                // in the prox, keeping b=1 steps exactly the CD closed
                // form).
                let v = w_cur[i] + eta * inv_n * r_cur[i];
                deltas[j * b + i] = reg.prox(v, eta, lam) - w_cur[i];
            }
        } else {
            // Zero Gram block ⇒ the sampled rows are all-zero: the data
            // term ignores these coordinates, so the penalized optimum is
            // w = 0 whenever any regularization is present.
            let (mu1, mu2) = reg.weights(lam);
            if mu1 > 0.0 || mu2 > 0.0 {
                for i in 0..b {
                    deltas[j * b + i] = -w_cur[i];
                }
            }
        }
    }
    Ok(deltas)
}

/// Dual prox s-step inner solve: proximal-gradient steps on the dual
/// objective `D(α) = (1/(2λn²))‖Xα‖² + (1/(2n))‖α‖² + (1/n)yᵀα + ψ(α)`
/// whose smooth block Hessian is `Θ_j = (1/(λn²))·G[j,j] + (1/n)I`
/// (identical to the exact solver's Θ). A separable regularizer on the
/// *dual* vector is the seam box-constraint/hinge workloads plug into
/// (`Reg::None` recovers plain BDCD fixed points). Signature mirrors
/// [`crate::gram::ComputeBackend::ca_dual_inner_solve`]; returns Δα.
#[allow(clippy::too_many_arguments)]
pub fn ca_prox_dual_inner_solve(
    s: usize,
    b: usize,
    g_raw: &[f64],
    r_raw: &[f64],
    a_blocks: &[f64],
    y_blocks: &[f64],
    overlap: &[f64],
    lam: f64,
    inv_n: f64,
    reg: &Reg,
) -> Result<Vec<f64>> {
    let sb = s * b;
    debug_assert_eq!(g_raw.len(), packed_len(sb));
    debug_assert_eq!(r_raw.len(), sb);
    let mut deltas = vec![0.0; sb];
    let mut a_cur = vec![0.0; b];
    let mut rhs_cur = vec![0.0; b];
    for j in 0..s {
        // rhs = −[Yw]_j + α_j + y_j, then the same deferred-state
        // reconstruction as the exact dual solver (PLUS-sign cross terms).
        for i in 0..b {
            a_cur[i] = a_blocks[j * b + i];
            rhs_cur[i] = -r_raw[j * b + i] + a_blocks[j * b + i] + y_blocks[j * b + i];
        }
        for t in 0..j {
            let ov = &overlap[(j * s + t) * b * b..(j * s + t + 1) * b * b];
            let dt = &deltas[t * b..(t + 1) * b];
            for i in 0..b {
                let base = tri_row(j * b + i);
                let grow = &g_raw[base + t * b..base + (t + 1) * b];
                let orow = &ov[i * b..(i + 1) * b];
                let mut gacc = 0.0;
                let mut oacc = 0.0;
                for c in 0..b {
                    gacc += grow[c] * dt[c];
                    oacc += orow[c] * dt[c];
                }
                rhs_cur[i] += (inv_n / lam) * gacc + oacc;
                a_cur[i] += oacc;
            }
        }
        // Lipschitz bound of Θ_j — always ≥ 1/n, no zero guard needed.
        let mut lip = 0.0f64;
        for i in 0..b {
            let mut row_sum = 0.0;
            for c in 0..b {
                let theta = (inv_n * inv_n / lam) * g_raw[pidx(j * b + i, j * b + c)]
                    + if i == c { inv_n } else { 0.0 };
                row_sum += theta.abs();
            }
            lip = lip.max(row_sum);
        }
        let eta = 1.0 / lip;
        for i in 0..b {
            // ∇D(α)_j = (1/n)·rhs_cur (see solvers::bdcd derivation).
            let v = a_cur[i] - eta * inv_n * rhs_cur[i];
            deltas[j * b + i] = reg.prox(v, eta, lam) - a_cur[i];
        }
    }
    Ok(deltas)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::packed::pack_lower;

    fn rngv(n: usize, seed: u64) -> Vec<f64> {
        let mut state = seed.wrapping_add(0x9E3779B97F4A7C15) | 1;
        (0..n)
            .map(|_| {
                state ^= state << 13;
                state ^= state >> 7;
                state ^= state << 17;
                (state as f64 / u64::MAX as f64) - 0.5
            })
            .collect()
    }

    /// b=1 prox step must equal the closed-form scalar lasso CD update
    /// u = S_{μ₁}(q·w + r/n) / (q + μ₂) with q = G/n.
    #[test]
    fn b1_step_is_exact_scalar_cd() {
        let (g, r, w) = (4.0, 0.7, -0.3);
        let (lam, inv_n) = (0.25, 1.0 / 10.0);
        let q = g * inv_n;
        for reg in [Reg::L1, Reg::Elastic { l1_ratio: 0.5 }, Reg::L2, Reg::None] {
            let (mu1, mu2) = reg.weights(lam);
            let d = ca_prox_inner_solve(1, 1, &[g], &[r], &[w], &[1.0], lam, inv_n, &reg)
                .unwrap();
            let c = q * w + r * inv_n;
            let expect = crate::prox::soft_threshold(c, mu1) / (q + mu2) - w;
            assert!(
                (d[0] - expect).abs() < 1e-14,
                "{reg:?}: {} vs {expect}",
                d[0]
            );
        }
    }

    /// The s-step unrolling must reproduce s sequential prox steps (each
    /// recomputing G and r from scratch) to fp accuracy — the CA claim in
    /// the proximal setting, including duplicate coordinates.
    #[test]
    fn s_step_unrolling_matches_sequential_prox_steps() {
        let (d, n, s, b) = (6usize, 24usize, 4usize, 2usize);
        let x = rngv(d * n, 3);
        let y = rngv(n, 4);
        let lam = 0.1;
        let inv_n = 1.0 / n as f64;
        // Fixed blocks with deliberate overlap across steps.
        let blocks: Vec<Vec<usize>> = vec![vec![0, 3], vec![3, 1], vec![2, 0], vec![5, 3]];
        let reg = Reg::L1;

        // Sequential: one prox step per block, recomputing the residual.
        let mut w_seq = rngv(d, 9);
        let w0 = w_seq.clone();
        let mut alpha = vec![0.0; n];
        for i in 0..d {
            for c in 0..n {
                alpha[c] += x[i * n + c] * w_seq[i];
            }
        }
        for blk in &blocks {
            // G = X[blk]X[blk]ᵀ, r = X[blk](y − α)
            let mut g = vec![0.0; b * b];
            let mut r = vec![0.0; b];
            for (ii, &ri) in blk.iter().enumerate() {
                for (jj, &rj) in blk.iter().enumerate() {
                    g[ii * b + jj] = (0..n).map(|c| x[ri * n + c] * x[rj * n + c]).sum();
                }
                r[ii] = (0..n).map(|c| x[ri * n + c] * (y[c] - alpha[c])).sum();
            }
            let mut gp = vec![0.0; packed_len(b)];
            pack_lower(&g, b, &mut gp);
            let wb: Vec<f64> = blk.iter().map(|&i| w_seq[i]).collect();
            let ov = crate::sampling::overlap_tensor(&[blk.clone()]);
            let dd = ca_prox_inner_solve(1, b, &gp, &r, &wb, &ov, lam, inv_n, &reg).unwrap();
            for (ii, &ri) in blk.iter().enumerate() {
                w_seq[ri] += dd[ii];
                for c in 0..n {
                    alpha[c] += x[ri * n + c] * dd[ii];
                }
            }
        }

        // CA: one fused s-step solve from the pre-update state.
        let sb = s * b;
        let flat: Vec<usize> = blocks.iter().flatten().copied().collect();
        let mut g_full = vec![0.0; sb * sb];
        let mut r_raw = vec![0.0; sb];
        let mut alpha0 = vec![0.0; n];
        for i in 0..d {
            for c in 0..n {
                alpha0[c] += x[i * n + c] * w0[i];
            }
        }
        for (ii, &ri) in flat.iter().enumerate() {
            for (jj, &rj) in flat.iter().enumerate() {
                g_full[ii * sb + jj] = (0..n).map(|c| x[ri * n + c] * x[rj * n + c]).sum();
            }
            r_raw[ii] = (0..n).map(|c| x[ri * n + c] * (y[c] - alpha0[c])).sum();
        }
        let mut gp = vec![0.0; packed_len(sb)];
        pack_lower(&g_full, sb, &mut gp);
        let w_blk: Vec<f64> = flat.iter().map(|&i| w0[i]).collect();
        let ov = crate::sampling::overlap_tensor(&blocks);
        let deltas =
            ca_prox_inner_solve(s, b, &gp, &r_raw, &w_blk, &ov, lam, inv_n, &reg).unwrap();
        let mut w_ca = w0;
        for (slot, &ri) in flat.iter().enumerate() {
            w_ca[ri] += deltas[slot];
        }

        for (i, (a, bb)) in w_seq.iter().zip(&w_ca).enumerate() {
            assert!((a - bb).abs() < 1e-10, "w[{i}]: seq {a} vs ca {bb}");
        }
    }

    /// Zero Gram blocks collapse regularized coordinates to exact zero and
    /// leave unregularized ones untouched.
    #[test]
    fn zero_block_prox_semantics() {
        let (lam, inv_n) = (0.5, 0.1);
        let g = [0.0];
        let d1 = ca_prox_inner_solve(1, 1, &g, &[0.0], &[2.0], &[1.0], lam, inv_n, &Reg::L1)
            .unwrap();
        assert_eq!(d1[0], -2.0);
        let d0 = ca_prox_inner_solve(1, 1, &g, &[0.0], &[2.0], &[1.0], lam, inv_n, &Reg::None)
            .unwrap();
        assert_eq!(d0[0], 0.0);
    }

    /// Dual b=1 step with Reg::None equals the plain gradient step on the
    /// dual objective with step 1/Θ (which for b'=1 is the exact Newton
    /// step the classical BDCD takes).
    #[test]
    fn dual_b1_none_step_is_exact_newton() {
        let (g, r, a, y) = (3.0, 0.4, -0.2, 0.9);
        let (lam, inv_n) = (0.6, 1.0 / 8.0);
        let theta = inv_n * inv_n / lam * g + inv_n;
        let rhs = -r + a + y;
        let expect = -inv_n * rhs / theta;
        let d = ca_prox_dual_inner_solve(
            1,
            1,
            &[g],
            &[r],
            &[a],
            &[y],
            &[1.0],
            lam,
            inv_n,
            &Reg::None,
        )
        .unwrap();
        assert!((d[0] - expect).abs() < 1e-14, "{} vs {expect}", d[0]);
    }
}
