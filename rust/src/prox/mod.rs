//! Proximal regularization subsystem — the non-smooth workload seam.
//!
//! The source paper derives its s-step recurrences for *regularized least
//! squares* but only exercises the smooth ridge case. Devarakonda,
//! Fountoulakis, Demmel & Mahoney, "Avoiding Synchronization in First-Order
//! Methods for Sparse Convex Optimization" (arXiv:1712.06047), show that
//! the same Gram-unrolling transformation carries over to **proximal**
//! block coordinate methods: the per-iteration information a rank needs —
//! the sampled Gram `G = Y Yᵀ` and residual `r = Y z` — is unchanged, so
//! the packed-triangle `[G|r]` payload, its `sb(sb+1)/2 + sb` wire volume,
//! and the H/s collective count of the CA solvers are reused **verbatim**.
//! Only the replicated inner solve changes: instead of the exact Cholesky
//! block solve of eq. (8)/(18), each deferred step takes a Lipschitz-scaled
//! gradient step on the smooth part and applies the regularizer's
//! **separable proximal operator** elementwise (for `b = 1` this IS the
//! exact coordinate minimizer — the classical soft-threshold coordinate
//! descent update for the lasso).
//!
//! The module provides:
//! * [`Reg`] — the configuration-level regularizer (`none | l2 | l1 |
//!   elastic`), carried by [`crate::solvers::SolverOpts::reg`]. Every
//!   regularizer decomposes as `ψ(w) = μ₁‖w‖₁ + (μ₂/2)‖w‖²` with
//!   `(μ₁, μ₂) = ` [`Reg::weights`]`(λ)`.
//! * [`Regularizer`] — the separable-operator trait (`penalty`, `prox`,
//!   min-norm subgradient residual, Fenchel conjugate) that [`Reg`]
//!   implements and future non-smooth workloads (group lasso, SVM hinge
//!   via box-constraint prox on the dual) plug into.
//! * [`solve`] — the prox-aware s-step inner solves consuming the packed
//!   `[G|r]` triangle ([`crate::gram::ComputeBackend`] exposes them as
//!   `ca_prox_inner_solve` / `ca_prox_dual_inner_solve` default methods).
//! * [`bcd`] / [`bdcd`] — the CA-Prox-BCD / CA-Prox-BDCD solver loops
//!   (entered transparently through the engine's
//!   [`Session`](crate::engine::Session) — and therefore through
//!   `solvers::bcd::run` / `solvers::bdcd::run` — whenever
//!   `SolverOpts::reg` is not the exact-L2 path), reporting the penalized
//!   objective, a CoCoA-style primal/dual objective-gap certificate, the
//!   min-norm subgradient residual, and iterate sparsity per record
//!   ([`crate::metrics::ProxRecord`]). Both run the engine's shared
//!   pipeline, so `--overlap` prefetches the next iteration's Gram under
//!   the in-flight `[G|r]` reduction exactly like the smooth solvers.
//!
//! With `Reg::L2` the solvers dispatch to the **pre-existing exact path**
//! — trajectories and per-rank CostMeter word counts are bitwise identical
//! to the smooth solvers (asserted in `rust/tests/prox.rs`).

pub mod bcd;
pub mod bdcd;
pub mod solve;

/// Separable regularizer selection, `ψ(w) = μ₁‖w‖₁ + (μ₂/2)‖w‖²`.
///
/// `λ` (from [`SolverOpts::lam`]) sets the overall strength; `Elastic`
/// splits it by `l1_ratio` ∈ [0, 1] (glmnet's α): `μ₁ = λ·ratio`,
/// `μ₂ = λ·(1 − ratio)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Reg {
    /// No regularizer (pure least squares through the prox machinery).
    None,
    /// Ridge `λ/2‖w‖²` — dispatches to the exact Cholesky solvers
    /// (bitwise-identical to the pre-prox code path).
    L2,
    /// Lasso `λ‖w‖₁` (prox = soft threshold).
    L1,
    /// Elastic net `λ(ratio‖w‖₁ + (1−ratio)/2‖w‖²)`.
    Elastic { l1_ratio: f64 },
}

impl Default for Reg {
    fn default() -> Self {
        Reg::L2
    }
}

/// Separable proximal-regularizer operations. Everything is elementwise
/// (coordinate-separable), which is what lets the prox ride the replicated
/// inner solve with zero extra communication.
pub trait Regularizer {
    /// Human-readable name (config/report value).
    fn name(&self) -> &'static str;

    /// `(μ₁, μ₂)` of the canonical decomposition given the strength λ.
    fn weights(&self, lam: f64) -> (f64, f64);

    /// Penalty value `ψ(w) = μ₁‖w‖₁ + (μ₂/2)‖w‖²`.
    fn penalty(&self, w: &[f64], lam: f64) -> f64 {
        let (mu1, mu2) = self.weights(lam);
        let mut l1 = 0.0;
        let mut l2 = 0.0;
        for &v in w {
            l1 += v.abs();
            l2 += v * v;
        }
        mu1 * l1 + 0.5 * mu2 * l2
    }

    /// Proximal operator `argmin_u (1/2η)(u−v)² + ψ(u)` — the closed form
    /// for the μ₁/μ₂ decomposition is a soft threshold followed by a
    /// shrinkage: `S_{η μ₁}(v) / (1 + η μ₂)`.
    fn prox(&self, v: f64, eta: f64, lam: f64) -> f64 {
        let (mu1, mu2) = self.weights(lam);
        soft_threshold(v, eta * mu1) / (1.0 + eta * mu2)
    }

    /// Minimum-norm element of `smooth_grad_i + ∂ψ(w_i)` — the
    /// subgradient-based optimality residual for coordinate `i`. Zero at
    /// every coordinate iff `w` is optimal.
    fn subgrad_coord(&self, smooth_grad_i: f64, w_i: f64, lam: f64) -> f64 {
        let (mu1, mu2) = self.weights(lam);
        let g = smooth_grad_i + mu2 * w_i;
        if w_i != 0.0 {
            g + mu1 * w_i.signum()
        } else {
            soft_threshold(g, mu1)
        }
    }

    /// ℓ2 norm of the min-norm subgradient over all coordinates, given the
    /// smooth gradient vector.
    fn subgrad_residual(&self, smooth_grad: &[f64], w: &[f64], lam: f64) -> f64 {
        debug_assert_eq!(smooth_grad.len(), w.len());
        smooth_grad
            .iter()
            .zip(w)
            .map(|(&g, &wi)| {
                let r = self.subgrad_coord(g, wi, lam);
                r * r
            })
            .sum::<f64>()
            .sqrt()
    }
}

impl Regularizer for Reg {
    fn name(&self) -> &'static str {
        match self {
            Reg::None => "none",
            Reg::L2 => "l2",
            Reg::L1 => "l1",
            Reg::Elastic { .. } => "elastic",
        }
    }

    fn weights(&self, lam: f64) -> (f64, f64) {
        match *self {
            Reg::None => (0.0, 0.0),
            Reg::L2 => (0.0, lam),
            Reg::L1 => (lam, 0.0),
            Reg::Elastic { l1_ratio } => (lam * l1_ratio, lam * (1.0 - l1_ratio)),
        }
    }
}

impl Reg {
    /// Whether this regularizer takes the pre-existing exact-Cholesky L2
    /// path (bitwise-identical trajectories and meters to the smooth
    /// solvers). Everything else routes through [`bcd`]/[`bdcd`].
    pub fn is_exact_l2(&self) -> bool {
        matches!(self, Reg::L2)
    }

    /// Validate regularizer parameters (config/CLI boundary).
    pub fn validate(&self) -> crate::error::Result<()> {
        if let Reg::Elastic { l1_ratio } = self {
            if !(0.0..=1.0).contains(l1_ratio) || !l1_ratio.is_finite() {
                return Err(crate::error::Error::InvalidArg(format!(
                    "l1_ratio {l1_ratio} outside [0, 1]"
                )));
            }
        }
        Ok(())
    }

    /// Exact zeros in the iterate — the sparsity certificate the prox
    /// records report (soft thresholding produces true zeros, not small
    /// values).
    pub fn nnz(w: &[f64]) -> usize {
        w.iter().filter(|v| **v != 0.0).count()
    }

    /// Fenchel duality gap of the penalized primal
    /// `P(w) = ‖z‖²/(2n) + ψ(w)` (with `z = y − Xᵀw`) against the dual
    /// candidate built from the scaled residual `u = −z/n`:
    ///
    /// `gap = P(w) + f*(u_c) + ψ*(σ_c)` with `σ = Xz/n`,
    /// `f*(u) = yᵀu + (n/2)‖u‖²`, and
    /// `ψ*(σ) = Σ_i S_{μ₁}(σ_i)²/(2μ₂)` when `μ₂ > 0` (no scaling
    /// needed), or the indicator of `‖σ‖_∞ ≤ μ₁` when `μ₂ = 0` — then
    /// `u` is scaled by `c = min(1, μ₁/‖σ‖_∞)` into feasibility (the
    /// standard lasso dual-certificate scaling). Returns `NaN` for
    /// [`Reg::None`] (no useful conjugate certificate; use the
    /// subgradient residual instead).
    ///
    /// Inputs are the three distributed scalars/vector one `d+2`-word
    /// allreduce produces: `resid_sq = ‖z‖²`, `y_dot_z = yᵀz`, and
    /// `sigma = Xz/n` (length d).
    pub fn duality_gap(
        &self,
        w: &[f64],
        sigma: &[f64],
        resid_sq: f64,
        y_dot_z: f64,
        n: usize,
        lam: f64,
    ) -> f64 {
        let (mu1, mu2) = self.weights(lam);
        if mu1 == 0.0 && mu2 == 0.0 {
            return f64::NAN;
        }
        let nf = n as f64;
        let primal = resid_sq / (2.0 * nf) + self.penalty(w, lam);
        if mu2 > 0.0 {
            // ψ* finite everywhere: no scaling, c = 1.
            let conj: f64 = sigma
                .iter()
                .map(|&s| {
                    let t = soft_threshold(s, mu1);
                    t * t
                })
                .sum::<f64>()
                / (2.0 * mu2);
            let f_star = -y_dot_z / nf + resid_sq / (2.0 * nf);
            primal + f_star + conj
        } else {
            // Pure L1: scale u into the ‖Xᵀ·‖_∞ ≤ μ₁ feasible set.
            let sig_inf = sigma.iter().fold(0.0f64, |a, &s| a.max(s.abs()));
            let c = if sig_inf > mu1 { mu1 / sig_inf } else { 1.0 };
            let f_star = -c * y_dot_z / nf + c * c * resid_sq / (2.0 * nf);
            primal + f_star
        }
    }
}

/// Soft-threshold operator `S_t(v) = sign(v)·max(|v| − t, 0)` (exact zeros
/// inside the threshold band — the source of prox-iterate sparsity).
#[inline]
pub fn soft_threshold(v: f64, t: f64) -> f64 {
    if v > t {
        v - t
    } else if v < -t {
        v + t
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn soft_threshold_band_and_shift() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(1.0, 1.0), 0.0);
    }

    #[test]
    fn weights_decompose_lambda() {
        let lam = 0.8;
        assert_eq!(Reg::None.weights(lam), (0.0, 0.0));
        assert_eq!(Reg::L2.weights(lam), (0.0, lam));
        assert_eq!(Reg::L1.weights(lam), (lam, 0.0));
        let (m1, m2) = Reg::Elastic { l1_ratio: 0.25 }.weights(lam);
        assert!((m1 - 0.2).abs() < 1e-15);
        assert!((m2 - 0.6).abs() < 1e-15);
    }

    #[test]
    fn prox_is_soft_threshold_then_shrink() {
        let r = Reg::Elastic { l1_ratio: 0.5 };
        let lam = 1.0; // μ₁ = μ₂ = 0.5
        let eta = 2.0;
        // S_{1.0}(3.0) = 2.0, then / (1 + 1.0) = 1.0
        assert!((r.prox(3.0, eta, lam) - 1.0).abs() < 1e-15);
        // Inside the band → exact zero.
        assert_eq!(r.prox(0.9, eta, lam), 0.0);
        // Pure L2: plain shrink, no band.
        assert!((Reg::L2.prox(3.0, 1.0, 1.0) - 1.5).abs() < 1e-15);
        // None: identity.
        assert_eq!(Reg::None.prox(3.0, 5.0, 1.0), 3.0);
    }

    #[test]
    fn prox_minimizes_the_scalar_subproblem() {
        // Verify prox(v, η, λ) against a fine grid search of
        // (1/2η)(u−v)² + μ₁|u| + μ₂/2 u².
        for (reg, lam) in [
            (Reg::L1, 0.7),
            (Reg::L2, 0.3),
            (Reg::Elastic { l1_ratio: 0.4 }, 0.9),
        ] {
            for &v in &[-2.0, -0.3, 0.0, 0.4, 1.7] {
                for &eta in &[0.5, 1.0, 3.0] {
                    let (mu1, mu2) = reg.weights(lam);
                    let obj = |u: f64| {
                        (u - v) * (u - v) / (2.0 * eta) + mu1 * u.abs() + 0.5 * mu2 * u * u
                    };
                    let p = reg.prox(v, eta, lam);
                    let mut best = (p, obj(p));
                    let mut u = -3.0;
                    while u <= 3.0 {
                        if obj(u) < best.1 {
                            best = (u, obj(u));
                        }
                        u += 1e-4;
                    }
                    assert!(
                        (best.0 - p).abs() < 1e-3,
                        "{reg:?} v={v} η={eta}: prox {p} vs grid {}",
                        best.0
                    );
                }
            }
        }
    }

    #[test]
    fn subgrad_residual_zero_at_scalar_optimum() {
        // d=1 lasso: minimize (q/2)w² − c·w + μ₁|w| with q=2, c=3, μ₁=1 →
        // w* = (c−μ₁)/q = 1. Smooth gradient at w*: q·w* − c = −1.
        let reg = Reg::L1;
        let r = reg.subgrad_coord(-1.0, 1.0, 1.0);
        assert!(r.abs() < 1e-15, "{r}");
        // Inside the band at w=0: gradient magnitude below μ₁ → residual 0.
        assert_eq!(reg.subgrad_coord(0.4, 0.0, 1.0), 0.0);
        // Beyond the band at w=0: the excess survives.
        assert!((reg.subgrad_coord(1.5, 0.0, 1.0) - 0.5).abs() < 1e-15);
    }

    #[test]
    fn nnz_counts_exact_zeros() {
        assert_eq!(Reg::nnz(&[0.0, 1.0, -2.0, 0.0, 1e-300]), 3);
    }

    #[test]
    fn elastic_ratio_validation() {
        assert!(Reg::Elastic { l1_ratio: 0.0 }.validate().is_ok());
        assert!(Reg::Elastic { l1_ratio: 1.0 }.validate().is_ok());
        assert!(Reg::Elastic { l1_ratio: 1.5 }.validate().is_err());
        assert!(Reg::Elastic { l1_ratio: -0.1 }.validate().is_err());
        assert!(Reg::Elastic { l1_ratio: f64::NAN }.validate().is_err());
        assert!(Reg::L1.validate().is_ok());
    }

    #[test]
    fn ridge_gap_vanishes_at_closed_form_optimum() {
        // 1-feature ridge: X = row vector x, minimize ‖xᵀw−y‖²/(2n) +
        // λ/2 w² → w* = xᵀy / (‖x‖² + nλ).
        let x = [1.0, 2.0, -1.0, 0.5];
        let y = [2.0, 1.0, 0.0, -1.0];
        let n = 4usize;
        let lam = 0.3;
        let xx: f64 = x.iter().map(|v| v * v).sum();
        let xy: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        let w = xy / (xx + n as f64 * lam);
        let z: Vec<f64> = x.iter().zip(&y).map(|(xi, yi)| yi - xi * w).collect();
        let resid_sq: f64 = z.iter().map(|v| v * v).sum();
        let y_dot_z: f64 = y.iter().zip(&z).map(|(a, b)| a * b).sum();
        let sigma = [x.iter().zip(&z).map(|(a, b)| a * b).sum::<f64>() / n as f64];
        let gap = Reg::L2.duality_gap(&[w], &sigma, resid_sq, y_dot_z, n, lam);
        assert!(gap.abs() < 1e-12, "ridge gap at optimum: {gap}");
    }

    #[test]
    fn lasso_gap_vanishes_at_zero_when_lambda_dominates() {
        // If λ ≥ ‖Xy‖_∞/n then w* = 0 for the lasso; the certificate must
        // report (near) zero gap there.
        let x = [1.0, -2.0, 0.5];
        let y = [0.4, 0.2, -0.6];
        let n = 3usize;
        let sig0: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum::<f64>() / n as f64;
        let lam = sig0.abs() * 1.5;
        let resid_sq: f64 = y.iter().map(|v| v * v).sum();
        let y_dot_z = resid_sq; // z = y at w = 0
        let gap = Reg::L1.duality_gap(&[0.0], &[sig0], resid_sq, y_dot_z, n, lam);
        assert!(gap.abs() < 1e-12, "lasso gap at w*=0: {gap}");
    }

    #[test]
    fn none_gap_is_nan() {
        assert!(Reg::None
            .duality_gap(&[0.0], &[1.0], 1.0, 1.0, 2, 0.5)
            .is_nan());
    }
}
